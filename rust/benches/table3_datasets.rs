//! Bench: **Table 3** — dataset statistics of the five synthetic analogs
//! (n, ñ, d, average nnz, C), mirroring the paper's data table, plus
//! generation throughput.
//!
//! Run: `cargo bench --bench table3_datasets`

use passcode::coordinator::experiments;
use passcode::data::registry;
use passcode::util::Timer;

fn main() {
    let scale = std::env::var("PASSCODE_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    println!("=== Table 3: dataset analogs (scale {scale}) ===\n");
    let t = Timer::start();
    let table = experiments::table3(scale).expect("table3");
    println!("{}", table.render());
    println!("generated + split all 5 datasets in {:.2}s", t.secs());

    // Generation throughput per dataset (init-cost context for §5.2).
    println!("\ngeneration throughput:");
    for spec in registry::REGISTRY {
        let s = spec.scaled(scale);
        let t = Timer::start();
        let ds = s.generate();
        let secs = t.secs();
        println!(
            "  {:<8} {:>9} rows  {:>11} nnz  {:>8.2} Mnnz/s",
            spec.name,
            ds.n(),
            ds.x.nnz(),
            ds.x.nnz() as f64 / secs / 1e6
        );
    }
}
