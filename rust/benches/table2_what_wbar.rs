//! Bench: **Table 2** — PASSCoDe-Wild prediction accuracy using ŵ
//! (maintained) vs w̄ = Σ α̂_i x_i (implied), against the LIBLINEAR
//! reference, for 4 and 8 threads on all five dataset analogs.
//!
//! Paper shape: acc(ŵ) ≈ LIBLINEAR on every dataset; acc(w̄) degrades,
//! worst on dense/low-d data (covtype) and at higher thread counts.
//! On this 1-core host real write races are rare, so the table is
//! reported twice: real threads, and the multicore simulator at 8 cores
//! (where lost writes actually accumulate).
//!
//! Run: `cargo bench --bench table2_what_wbar`

use passcode::coordinator::experiments;
use passcode::coordinator::metrics::TextTable;
use passcode::data::registry;
use passcode::eval;
use passcode::loss::Hinge;
use passcode::simcore::{self, Mechanism, SimConfig};

fn main() {
    let scale = std::env::var("PASSCODE_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.1);
    let epochs = 15;
    println!("=== Table 2: ŵ vs w̄ accuracy (scale {scale}, {epochs} epochs) ===\n");
    println!("-- real threads on this host --");
    let (table, rows) = experiments::table2(scale, epochs).expect("table2");
    println!("{}", table.render());

    println!("-- simulated 8 cores (Wild; lost writes accumulate) --");
    let mut sim_table =
        TextTable::new(&["dataset", "lost writes", "acc(ŵ)", "acc(w̄)"]);
    for spec in registry::REGISTRY {
        let (tr, te, c) = registry::load(spec.name, scale).unwrap();
        let loss = Hinge::new(c);
        let sim = simcore::simulate(
            &tr,
            &loss,
            &SimConfig {
                cores: 8,
                epochs,
                seed: 7,
                cost: Default::default(),
                mechanism: Mechanism::Wild, sockets: 1, },
        );
        let acc_what = eval::accuracy(&te, &sim.w);
        let wbar = eval::wbar_from_alpha(&tr, &sim.alpha);
        let acc_wbar = eval::accuracy(&te, &wbar);
        sim_table.row(&[
            spec.name.to_string(),
            sim.lost_writes.to_string(),
            format!("{acc_what:.3}"),
            format!("{acc_wbar:.3}"),
        ]);
    }
    println!("{}", sim_table.render());

    println!("paper-shape checks:");
    let worst_gap = rows
        .iter()
        .map(|r| (r.acc_liblinear - r.acc_what).abs())
        .fold(0.0, f64::max);
    println!(
        "  [{}] acc(ŵ) tracks LIBLINEAR within 3 points (worst gap {:.3})",
        if worst_gap < 0.03 { "PASS" } else { "FAIL" },
        worst_gap
    );
    let never_better = rows.iter().all(|r| r.acc_wbar <= r.acc_what + 0.01);
    println!(
        "  [{}] acc(w̄) never beats acc(ŵ) materially",
        if never_better { "PASS" } else { "FAIL" }
    );
}
