//! Bench: distributed Hybrid-DCA scaling — the loopback `dist-sim` at
//! K ∈ {1, 2, 4} workers on the rcv1 analog, same total epoch budget
//! per cell.
//!
//! Reports final primal objective, duality gap, merge/reject counts,
//! and the cluster-level backward-error gauge, so a PR that perturbs
//! the merge math shows up as an objective/gap drift in the K > 1
//! columns relative to K = 1 (which degenerates to plain warm-started
//! PASSCoDe with an HTTP round-trip per round).  A final `2*` row runs
//! K = 2 under the default `--chaos` fault plan: its primal must stay
//! inside the same 5% envelope, or a merge-robustness regression
//! (broken idempotence, bad damping) is showing through.
//!
//! Run: `cargo bench --bench dist_scaling [-- --smoke]`

use passcode::coordinator::metrics::TextTable;
use passcode::dist::{run_sim, FaultPlan, SimConfig};

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let scale = if smoke { 0.02 } else { 0.1 };
    // Fixed total budget: rounds × epochs_per_round is constant across
    // K, so the columns compare merge overhead, not extra epochs.
    let (rounds, epochs_per_round) = if smoke { (4, 1) } else { (12, 2) };

    println!(
        "=== dist-sim scaling (rcv1 analog @ scale {scale}, \
         {rounds}x{epochs_per_round} epochs/worker, max_lag 8) ===\n"
    );

    let mut table = TextTable::new(&[
        "workers", "merges", "rejects", "merge_epoch", "primal", "gap",
        "test_acc", "bwd_err",
    ]);
    let mut gaps = Vec::new();
    // The last cell repeats K = 2 under the default chaos plan (plus
    // op-clock leases): same budget, adversarial transport.
    let cells: [(usize, bool); 4] = [(1, false), (2, false), (4, false), (2, true)];
    for (workers, chaos) in cells {
        let report = run_sim(&SimConfig {
            dataset: "rcv1".into(),
            scale,
            workers,
            rounds,
            epochs_per_round,
            max_lag: 8,
            chaos: chaos.then(|| FaultPlan::moderate(42)),
            lease_ops: if chaos { 64 } else { 0 },
            ..Default::default()
        })
        .expect("dist-sim");
        table.row(&[
            format!("{workers}{}", if chaos { "*" } else { "" }),
            report.merges.to_string(),
            report.rejects.to_string(),
            report.merge_epoch.to_string(),
            format!("{:.6}", report.primal),
            format!("{:.3e}", report.gap),
            format!("{:.4}", report.test_accuracy),
            format!("{:.3e}", report.backward_error_ratio),
        ]);
        gaps.push((workers, chaos, report.gap, report.primal));
    }
    println!("{}", table.render());
    println!("(* = under the default --chaos fault plan, seed 42, lease-ops 64)\n");

    // Soft shape checks (report, don't panic the bench): every K must
    // end converged, and damped multi-worker merges — even under the
    // chaos plan — may trail K = 1 but not blow up the objective.
    let p1 = gaps[0].3;
    println!("shape checks:");
    for (k, chaos, gap, primal) in &gaps {
        let ok = gap.is_finite()
            && *gap >= -1e-9
            && (primal - p1).abs() <= 0.05 * p1.abs().max(1.0);
        println!(
            "  [{}] K={k}{}: gap {gap:.3e}, primal within 5% of K=1",
            if ok { "PASS" } else { "FAIL" },
            if *chaos { " (chaos)" } else { "" }
        );
    }
}
