//! Bench: **Table 1** — scaling of PASSCoDe-Lock/Atomic/Wild on the rcv1
//! analog (paper: 100 iterations, p ∈ {2,4,10}, speedup over serial DCD).
//!
//! Two measurements per cell:
//!  * simulated p-core time from the multicore DES (the paper-testbed
//!    substitution — this is the column to compare against Table 1), and
//!  * real wall-clock on this host (informational; the host has 1 core).
//!
//! Paper shape: Lock < 1× (slower than serial), Atomic ≈ 1.75/3.2/6.9×,
//! Wild ≈ 1.9/3.5/7.4× at p = 2/4/10.
//!
//! Run: `cargo bench --bench table1_scaling`

use passcode::coordinator::experiments;

fn main() {
    let scale = std::env::var("PASSCODE_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.2);
    let epochs = 20;
    println!("=== Table 1: PASSCoDe scaling (rcv1 analog @ scale {scale}, {epochs} epochs) ===\n");
    let (table, rows) = experiments::table1(scale, epochs).expect("table1");
    println!("{}", table.render());

    // Paper-shape assertions (soft: report, don't panic the bench).
    let at = |th: usize, m: &str| {
        rows.iter()
            .find(|r| r.threads == th && r.mechanism == m)
            .unwrap()
            .sim_speedup
    };
    let checks = [
        ("lock slower than serial at 10 threads", at(10, "lock") < 1.0),
        ("wild ≥ atomic at 10 threads", at(10, "wild") >= at(10, "atomic")),
        ("wild ≥ 5x at 10 threads", at(10, "wild") >= 5.0),
        ("atomic ≥ 3x at 10 threads", at(10, "atomic") >= 3.0),
        ("wild scales 2→4→10", at(2, "wild") < at(4, "wild") && at(4, "wild") < at(10, "wild")),
    ];
    println!("paper-shape checks:");
    for (name, ok) in checks {
        println!("  [{}] {name}", if ok { "PASS" } else { "FAIL" });
    }
}
