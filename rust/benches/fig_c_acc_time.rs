//! Bench: **Figures 2–6, panel (c)** — test accuracy vs time.
//!
//! Same protocol as panel (b): simulated p-core timelines; accuracy is
//! measured with the maintained ŵ (the Theorem-3-correct predictor).
//! Reports the paper's headline comparison: time for PASSCoDe-Wild /
//! -Atomic / serial DCD to reach 99% of the LIBLINEAR-reference accuracy
//! (cf. the webspam "2s vs 10s" abstract claim).
//!
//! Run: `cargo bench --bench fig_c_acc_time`

use passcode::data::registry;
use passcode::eval;
use passcode::loss::{Hinge, LossKind};
use passcode::simcore::{self, CostModel, Mechanism, SimConfig};
use passcode::solver::{lookup, Solver, SolveOptions};

fn main() {
    let scale = std::env::var("PASSCODE_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.1);
    let epochs = 12;
    let cores = 10;
    println!("=== Fig (c): test accuracy vs simulated time ({cores} cores, scale {scale}) ===");
    for dataset in ["news20", "covtype", "rcv1", "webspam", "kddb"] {
        let (tr, te, c) = registry::load(dataset, scale).unwrap();
        let loss = Hinge::new(c);
        let cost = CostModel::default();
        // LIBLINEAR-style reference accuracy via the solver registry.
        let mut reference = lookup("liblinear")
            .unwrap()
            .session(
                &tr,
                LossKind::Hinge,
                c,
                SolveOptions { epochs: 30, ..Default::default() },
            )
            .unwrap();
        reference.run_epochs(30).unwrap();
        let ref_acc = eval::accuracy(&te, reference.w_hat());
        let target = 0.99 * ref_acc;
        println!("\n--- {dataset} (reference acc {ref_acc:.4}, target {target:.4}) ---");
        println!("series,epoch,sim_secs,test_acc");
        let mut time_to_target: Vec<(String, Option<f64>)> = Vec::new();
        for (mech, name, sim_cores) in [
            (Mechanism::Wild, "passcode-wild", cores),
            (Mechanism::Atomic, "passcode-atomic", cores),
            (Mechanism::Wild, "dcd-serial", 1),
        ] {
            let mut reached = None;
            for e in [1, 2, 4, 8, epochs] {
                let sim = simcore::simulate(
                    &tr,
                    &loss,
                    &SimConfig {
                        cores: sim_cores,
                        epochs: e,
                        seed: 7,
                        cost,
                        mechanism: mech, sockets: 1, },
                );
                let acc = eval::accuracy(&te, &sim.w);
                let secs = sim.virtual_ns * 1e-9;
                println!("{name},{e},{secs:.6},{acc:.5}");
                if reached.is_none() && acc >= target {
                    reached = Some(secs);
                }
            }
            time_to_target.push((name.to_string(), reached));
        }
        print!("  time to {target:.3}: ");
        for (name, t) in &time_to_target {
            match t {
                Some(s) => print!("{name}={s:.4}s  "),
                None => print!("{name}=n/a  "),
            }
        }
        println!();
        if let (Some(w), Some(d)) = (time_to_target[0].1, time_to_target[2].1)
        {
            println!(
                "  [{}] wild reaches target faster than serial ({:.1}x)",
                if w < d { "PASS" } else { "FAIL" },
                d / w
            );
        }
    }
}
