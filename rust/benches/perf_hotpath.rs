//! §Perf bench: hot-path microbenchmarks for the L3 solver —
//! updates/second and effective nnz-throughput of serial DCD and each
//! PASSCoDe memory model (1 thread, the per-update cost that the
//! paper's near-linear Wild scaling multiplies), plus the simulator's
//! event throughput and the AOT margins-kernel throughput.
//!
//! This is the before/after instrument for EXPERIMENTS.md §Perf.
//!
//! Run: `cargo bench --bench perf_hotpath`

use passcode::data::registry;
use passcode::loss::{Hinge, LossKind};
use passcode::simcore::{self, Mechanism, SimConfig};
use passcode::solver::{
    lookup, MemoryModel, Passcode, SerialDcd, Solver, SolveOptions,
};
use passcode::util::stats::bench_secs;

fn main() {
    let (tr, _, c) = registry::load("rcv1", 0.25).unwrap();
    let loss = Hinge::new(c);
    let epochs = 5;
    let nnz = tr.x.nnz() as f64;
    let updates = (tr.n() * epochs) as f64;
    println!(
        "=== §Perf hot path (rcv1 analog: n = {}, nnz = {}) ===\n",
        tr.n(),
        tr.x.nnz()
    );

    println!("{:<22} {:>12} {:>14} {:>12}", "variant", "median (s)", "updates/s", "Mnnz/s");
    let report = |name: &str, median: f64| {
        println!(
            "{:<22} {:>12.4} {:>14.0} {:>12.1}",
            name,
            median,
            updates / median,
            nnz * epochs as f64 / median / 1e6
        );
    };

    let s = bench_secs(1, 5, || {
        let _ = SerialDcd::solve(
            &tr,
            &loss,
            &SolveOptions { epochs, ..Default::default() },
            None,
        );
    });
    report("serial-dcd", s.median);

    for (model, name) in [
        (MemoryModel::Wild, "passcode-wild@1"),
        (MemoryModel::Atomic, "passcode-atomic@1"),
        (MemoryModel::Lock, "passcode-lock@1"),
    ] {
        let s = bench_secs(1, 5, || {
            let _ = Passcode::solve(
                &tr,
                &loss,
                model,
                &SolveOptions {
                    threads: 1,
                    epochs,
                    eval_every: 0,
                    ..Default::default()
                },
                None,
            );
        });
        report(name, s.median);
    }

    // Registry/session path for the same solvers: measures the cost of
    // the `solver::api` dispatch (enum-loss calls + per-epoch warm-start
    // rendezvous) against the raw monomorphized rows above — the number
    // to watch if the TrainSession layer ever lands on a hot path.
    for name in ["dcd", "passcode-wild"] {
        let solver = lookup(name).unwrap();
        let s = bench_secs(1, 5, || {
            let mut session = solver
                .session(
                    &tr,
                    LossKind::Hinge,
                    c,
                    SolveOptions {
                        threads: 1,
                        epochs,
                        eval_every: 0,
                        ..Default::default()
                    },
                )
                .unwrap();
            session.run_epochs(epochs).unwrap();
        });
        report(&format!("session:{name}@1"), s.median);
    }

    // Simulator event throughput (events ≈ updates).
    let s = bench_secs(1, 3, || {
        let _ = simcore::simulate(
            &tr,
            &loss,
            &SimConfig {
                cores: 10,
                epochs,
                seed: 7,
                cost: Default::default(),
                mechanism: Mechanism::Wild, sockets: 1, },
        );
    });
    println!(
        "{:<22} {:>12.4} {:>14.0} {:>12}",
        "simulator@10cores",
        s.median,
        updates / s.median,
        "-"
    );

    // AOT margins kernel throughput (if artifacts exist).
    if let Ok(engine) = passcode::runtime::Engine::load_default() {
        let rb = engine.manifest.row_block;
        let fb = engine.manifest.feat_block;
        let x = vec![0.5f32; rb * fb];
        let w = vec![0.25f32; fb];
        let xl = passcode::runtime::Engine::literal_f32(
            &x,
            &[rb as i64, fb as i64],
        )
        .unwrap();
        let wl =
            passcode::runtime::Engine::literal_f32(&w, &[fb as i64, 1])
                .unwrap();
        let flops = 2.0 * (rb * fb) as f64;
        let s = bench_secs(2, 10, || {
            let _ = engine.execute("margins_block", &[xl.reshape(&[rb as i64, fb as i64]).unwrap(), wl.reshape(&[fb as i64, 1]).unwrap()]).unwrap();
        });
        println!(
            "{:<22} {:>12.6} {:>14} {:>12.2}",
            "aot-margins-kernel",
            s.median,
            "-",
            flops / s.median / 1e9
        );
        println!("  (last column = GFLOP/s for the margins kernel)");
    } else {
        println!("aot-margins-kernel: skipped (no artifacts)");
    }
}
