//! §Perf bench: hot-path microbenchmarks for the L3 solver —
//! updates/second and effective nnz-throughput of serial DCD and the
//! PASSCoDe memory models across thread counts, a **kernel ablation**
//! (pre-refactor baseline inner loop vs the fused kernels vs
//! fused + feature-locality remap), a **probe ablation** (the
//! `passcode::obs` telemetry probes off vs on, bar < 2% overhead
//! enabled), the session-dispatch overhead, the
//! simulator's event throughput, and the AOT margins-kernel throughput.
//!
//! This is the before/after instrument for EXPERIMENTS.md §Perf; results
//! are also recorded to `BENCH_hotpath.json` so the repo carries a perf
//! trajectory (CI's bench-smoke job refreshes it at reduced size).
//!
//! Run: `cargo bench --bench perf_hotpath [-- --smoke] [-- --out F.json]`

use std::sync::atomic::{AtomicU64, Ordering};

use passcode::data::{registry, Dataset};
use passcode::loss::{Hinge, Loss, LossKind, MIN_DELTA};
use passcode::simcore::{self, Mechanism, SimConfig};
use passcode::solver::{
    lookup, MemoryModel, Passcode, SerialDcd, Solver, SolveOptions,
};
use passcode::util::stats::bench_secs;
use passcode::util::{Json, Pcg32, SharedVec};

/// The pre-overhaul inner loop, kept verbatim as the ablation baseline:
/// scalar bounds-checked gathers, a fresh visit-list allocation per
/// epoch, two separate row walks per update (dot, then scatter) —
/// everything the fused kernels removed.  Wild discipline only (the
/// paper's fastest variant, and the one the 1.3× acceptance bar is on).
fn baseline_wild(ds: &Dataset, loss: &Hinge, threads: usize, epochs: usize) {
    let p = threads.max(1);
    let qii = ds.x.row_sqnorms_cached();
    let w = SharedVec::zeros(ds.d());
    let alpha = SharedVec::zeros(ds.n());
    let mut rng = Pcg32::new(42, 0xB10C);
    let perm = rng.permutation(ds.n());
    let base = ds.n() / p;
    let rem = ds.n() % p;
    let mut blocks: Vec<Vec<usize>> = Vec::with_capacity(p);
    let mut start = 0;
    for t in 0..p {
        let len = base + usize::from(t < rem);
        blocks.push(perm[start..start + len].to_vec());
        start += len;
    }
    let updates = AtomicU64::new(0);
    std::thread::scope(|s| {
        for (t, block) in blocks.iter().enumerate() {
            let (w, alpha, qii, updates) = (&w, &alpha, &qii, &updates);
            s.spawn(move || {
                let mut rng = Pcg32::new(42, 1 + t as u64);
                let mut order: Vec<usize> = block.clone();
                let mut local = 0u64;
                for _epoch in 0..epochs {
                    rng.shuffle(&mut order);
                    let iter_order: Vec<(usize, usize)> =
                        order.iter().map(|&i| (i, 0)).collect();
                    for &(i, _) in &iter_order {
                        let q = qii[i];
                        if q <= 0.0 {
                            continue;
                        }
                        let (idx, vals) = ds.x.row(i);
                        let mut wx = 0.0;
                        for (j, v) in idx.iter().zip(vals) {
                            wx += w.get(*j as usize) * v;
                        }
                        let a_old = alpha.get(i);
                        let a_new = loss.solve_subproblem(a_old, wx, q);
                        let delta = a_new - a_old;
                        local += 1;
                        if delta.abs() > MIN_DELTA {
                            alpha.set(i, a_new);
                            for (j, v) in idx.iter().zip(vals) {
                                w.add_wild(*j as usize, delta * v);
                            }
                        }
                    }
                }
                updates.fetch_add(local, Ordering::Relaxed);
            });
        }
    });
    let _ = updates.load(Ordering::Relaxed);
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let out_path = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|k| args.get(k + 1))
        .cloned()
        .unwrap_or_else(|| "BENCH_hotpath.json".to_string());

    let (scale, epochs, warmup, reps) =
        if smoke { (0.05, 3, 1, 3) } else { (0.25, 5, 1, 5) };
    let (tr, _, c) = registry::load("rcv1", scale).unwrap();
    let (tr_remap, _) = tr.remap_features();
    let loss = Hinge::new(c);
    let nnz = tr.x.nnz() as f64;
    let updates = (tr.n() * epochs) as f64;
    println!(
        "=== §Perf hot path (rcv1 analog: n = {}, nnz = {}{}) ===\n",
        tr.n(),
        tr.x.nnz(),
        if smoke { ", smoke" } else { "" }
    );

    println!(
        "{:<26} {:>12} {:>14} {:>12}",
        "variant", "median (s)", "updates/s", "Mnnz/s"
    );
    let mut rows: Vec<Json> = Vec::new();
    let mut report = |name: &str, threads: usize, kernel: &str, median: f64| {
        let ups = updates / median;
        println!(
            "{:<26} {:>12.4} {:>14.0} {:>12.1}",
            name,
            median,
            ups,
            nnz * epochs as f64 / median / 1e6
        );
        rows.push(Json::obj(vec![
            ("variant", Json::str(name)),
            ("threads", Json::num(threads as f64)),
            ("kernel", Json::str(kernel)),
            ("median_secs", Json::num(median)),
            ("updates_per_sec", Json::num(ups)),
        ]));
        ups
    };

    let s = bench_secs(warmup, reps, || {
        let _ = SerialDcd::solve(
            &tr,
            &loss,
            &SolveOptions { epochs, ..Default::default() },
            None,
        );
    });
    report("serial-dcd", 1, "fused", s.median);

    // Fused kernels: every memory model × {1, 2, 4} threads.
    let mut baseline_wild4 = f64::NAN;
    let mut fused_wild4 = f64::NAN;
    for (model, name) in [
        (MemoryModel::Wild, "passcode-wild"),
        (MemoryModel::Atomic, "passcode-atomic"),
        (MemoryModel::Lock, "passcode-lock"),
    ] {
        for threads in [1usize, 2, 4] {
            let s = bench_secs(warmup, reps, || {
                let _ = Passcode::solve(
                    &tr,
                    &loss,
                    model,
                    &SolveOptions {
                        threads,
                        epochs,
                        eval_every: 0,
                        ..Default::default()
                    },
                    None,
                );
            });
            let ups =
                report(&format!("{name}@{threads}"), threads, "fused", s.median);
            if model == MemoryModel::Wild && threads == 4 {
                fused_wild4 = ups;
            }
        }
    }

    // Kernel ablation on the paper's fastest variant: the pre-overhaul
    // baseline loop and the fused kernels on the remapped dataset.
    for threads in [1usize, 2, 4] {
        let s = bench_secs(warmup, reps, || {
            baseline_wild(&tr, &loss, threads, epochs);
        });
        let ups =
            report(&format!("wild-baseline@{threads}"), threads, "baseline", s.median);
        if threads == 4 {
            baseline_wild4 = ups;
        }

        let s = bench_secs(warmup, reps, || {
            let _ = Passcode::solve(
                &tr_remap,
                &loss,
                MemoryModel::Wild,
                &SolveOptions {
                    threads,
                    epochs,
                    eval_every: 0,
                    ..Default::default()
                },
                None,
            );
        });
        report(
            &format!("wild-fused+remap@{threads}"),
            threads,
            "fused+remap",
            s.median,
        );
    }
    let ablation_speedup = fused_wild4 / baseline_wild4;
    println!(
        "\nkernel ablation: passcode-wild@4 fused/baseline = {ablation_speedup:.2}x \
         (acceptance bar: >= 1.30x)"
    );

    // Probe ablation: the same fused wild@4 run with the `obs` telemetry
    // probes off (the default everywhere above) vs on — τ sampler,
    // CAS-retry/lock-wait ticks, epoch timers and all.  The probes are
    // branch-predictable no-ops when disabled, so the bar is on the
    // enabled side: < 2% overhead.
    let mut probes_median = [f64::NAN; 2];
    for enabled in [false, true] {
        passcode::obs::set_probes_enabled(enabled);
        let s = bench_secs(warmup, reps, || {
            let _ = Passcode::solve(
                &tr,
                &loss,
                MemoryModel::Wild,
                &SolveOptions {
                    threads: 4,
                    epochs,
                    eval_every: 0,
                    ..Default::default()
                },
                None,
            );
        });
        let (tag, kernel) = if enabled {
            ("wild-probes-on@4", "fused+probes")
        } else {
            ("wild-probes-off@4", "fused")
        };
        report(tag, 4, kernel, s.median);
        probes_median[usize::from(enabled)] = s.median;
    }
    passcode::obs::set_probes_enabled(false);
    let probes_overhead = probes_median[1] / probes_median[0] - 1.0;
    println!(
        "\nprobe ablation: passcode-wild@4 probes-on/probes-off = {:+.2}% \
         (acceptance bar: < 2%)",
        probes_overhead * 100.0
    );

    // Registry/session path: measures the `solver::api` dispatch cost
    // (enum-loss calls + per-epoch re-entry over the session's shared
    // buffers) against the raw monomorphized rows above.
    for name in ["dcd", "passcode-wild"] {
        let solver = lookup(name).unwrap();
        let s = bench_secs(warmup, reps, || {
            let mut session = solver
                .session(
                    &tr,
                    LossKind::Hinge,
                    c,
                    SolveOptions {
                        threads: 1,
                        epochs,
                        eval_every: 0,
                        ..Default::default()
                    },
                )
                .unwrap();
            session.run_epochs(epochs).unwrap();
        });
        report(&format!("session:{name}@1"), 1, "fused", s.median);
    }

    // Simulator event throughput (events ≈ updates).
    let s = bench_secs(1, 3, || {
        let _ = simcore::simulate(
            &tr,
            &loss,
            &SimConfig {
                cores: 10,
                epochs,
                seed: 7,
                cost: Default::default(),
                mechanism: Mechanism::Wild,
                sockets: 1,
            },
        );
    });
    println!(
        "{:<26} {:>12.4} {:>14.0} {:>12}",
        "simulator@10cores",
        s.median,
        updates / s.median,
        "-"
    );

    // AOT margins kernel throughput (if artifacts exist).
    if let Ok(engine) = passcode::runtime::Engine::load_default() {
        let rb = engine.manifest.row_block;
        let fb = engine.manifest.feat_block;
        let x = vec![0.5f32; rb * fb];
        let w = vec![0.25f32; fb];
        let xl = passcode::runtime::Engine::literal_f32(
            &x,
            &[rb as i64, fb as i64],
        )
        .unwrap();
        let wl =
            passcode::runtime::Engine::literal_f32(&w, &[fb as i64, 1])
                .unwrap();
        let flops = 2.0 * (rb * fb) as f64;
        let s = bench_secs(2, 10, || {
            let _ = engine.execute("margins_block", &[xl.reshape(&[rb as i64, fb as i64]).unwrap(), wl.reshape(&[fb as i64, 1]).unwrap()]).unwrap();
        });
        println!(
            "{:<26} {:>12.6} {:>14} {:>12.2}",
            "aot-margins-kernel",
            s.median,
            "-",
            flops / s.median / 1e9
        );
        println!("  (last column = GFLOP/s for the margins kernel)");
    } else {
        println!("aot-margins-kernel: skipped (no artifacts)");
    }

    // ---- record the trajectory --------------------------------------
    let doc = Json::obj(vec![
        ("format", Json::str("passcode-bench-hotpath-v1")),
        ("smoke", Json::Bool(smoke)),
        ("dataset", Json::str("rcv1")),
        ("scale", Json::num(scale)),
        ("n", Json::num(tr.n() as f64)),
        ("nnz", Json::num(tr.x.nnz() as f64)),
        ("epochs", Json::num(epochs as f64)),
        ("wild4_fused_over_baseline", Json::num(ablation_speedup)),
        ("wild4_probes_overhead", Json::num(probes_overhead)),
        ("rows", Json::Arr(rows)),
    ]);
    std::fs::write(&out_path, doc.to_pretty()).unwrap();
    println!("\nrecorded {out_path}");
}
