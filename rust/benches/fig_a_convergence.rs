//! Bench: **Figures 2–6, panel (a)** — primal objective vs *iterations*
//! (epochs) for PASSCoDe-Wild / PASSCoDe-Atomic / CoCoA / serial DCD
//! (LIBLINEAR-style reference), 10 threads; AsySCD included only on the
//! news20 analog (dense-Q memory guard — exactly the paper's situation).
//!
//! Paper shape: the PASSCoDe variants track serial DCD almost exactly;
//! CoCoA lags per-iteration; covtype (dense) is slowest for everyone.
//!
//! Output: one CSV block per dataset (= the figure's data series).
//!
//! Run: `cargo bench --bench fig_a_convergence`

use passcode::coordinator::experiments;

fn main() {
    let scale = std::env::var("PASSCODE_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.1);
    let epochs = 15;
    let threads = 10;
    println!(
        "=== Fig (a): primal objective vs epochs (scale {scale}, {threads} threads) ===");
    for dataset in ["news20", "covtype", "rcv1", "webspam", "kddb"] {
        let include_asyscd = dataset == "news20";
        println!("\n--- {dataset} ---");
        let logs = experiments::fig_convergence(
            dataset, scale, epochs, threads, include_asyscd,
        )
        .expect("fig_convergence");
        for log in &logs {
            print!("{}", log.to_csv());
        }
        // Shape check: both PASSCoDe variants end within 2% of serial DCD.
        let final_primal = |label: &str| {
            logs.iter()
                .find(|l| l.label == label)
                .and_then(|l| l.final_row())
                .map(|r| r.primal)
                .unwrap_or(f64::NAN)
        };
        let dcd = final_primal("dcd");
        let wild = final_primal("passcode-wild");
        let atomic = final_primal("passcode-atomic");
        let cocoa = final_primal("cocoa");
        let ok_wild = (wild - dcd).abs() < 0.02 * dcd.abs();
        let ok_atomic = (atomic - dcd).abs() < 0.02 * dcd.abs();
        let ok_cocoa = cocoa >= dcd - 0.01 * dcd.abs();
        println!(
            "  [{}] PASSCoDe-Wild within 2% of serial DCD after {epochs} epochs",
            if ok_wild { "PASS" } else { "FAIL" }
        );
        println!(
            "  [{}] PASSCoDe-Atomic within 2% of serial DCD",
            if ok_atomic { "PASS" } else { "FAIL" }
        );
        println!(
            "  [{}] CoCoA lags (P_cocoa ≥ P_dcd)",
            if ok_cocoa { "PASS" } else { "FAIL" }
        );
    }
}
