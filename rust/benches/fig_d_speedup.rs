//! Bench: **Figures 2–6, panel (d)** — speedup vs thread count.
//!
//! Paper protocol (§5.3): speedup = time(best serial reference) /
//! time(method @ p threads); shrinking off; init excluded.  Times come
//! from the multicore DES (testbed substitution).  Paper shape:
//! PASSCoDe-Wild reaches ~6–8× at 10 threads on every dataset, Atomic
//! slightly below, Lock well under 1×; AsySCD shows no *speedup* over
//! serial DCD even though it scales, because its per-update cost is
//! O(n) (shown on news20 where its Q fits).
//!
//! Run: `cargo bench --bench fig_d_speedup`

use passcode::coordinator::experiments;
use passcode::data::registry;
use passcode::loss::LossKind;
use passcode::solver::{lookup, Solver, SolveOptions};
use passcode::util::Timer;

fn main() {
    let scale = std::env::var("PASSCODE_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.1);
    let epochs = 10;
    println!("=== Fig (d): speedup vs threads (simulated, scale {scale}) ===");
    for dataset in ["news20", "covtype", "rcv1", "webspam", "kddb"] {
        println!("\n--- {dataset} ---");
        let (table, pts) =
            experiments::fig_speedup(dataset, scale, epochs, 10)
                .expect("fig_speedup");
        println!("{}", table.render());
        let wild10 = pts
            .iter()
            .find(|p| p.threads == 10 && p.mechanism == "wild")
            .unwrap()
            .speedup;
        println!(
            "  [{}] wild 10-thread speedup in the paper's 5–9x band ({wild10:.2}x)",
            if (5.0..9.5).contains(&wild10) { "PASS" } else { "FAIL" }
        );
    }

    // AsySCD's "scaling without speedup" (news20 only, like the paper):
    // wall-clock per epoch is dominated by the O(n) gradient scan.  Both
    // runs dispatch through the solver registry.
    println!("\n--- AsySCD vs serial DCD (news20 analog, real wall-clock) ---");
    let (tr, _, c) = registry::load("news20", (scale * 0.5).min(0.05)).unwrap();
    let run = |name: &str, threads: usize| -> f64 {
        let solver = lookup(name).unwrap();
        let t = Timer::start();
        let mut session = solver
            .session(
                &tr,
                LossKind::Hinge,
                c,
                SolveOptions { epochs, threads, ..Default::default() },
            )
            .unwrap();
        session.run_epochs(epochs).unwrap();
        t.secs()
    };
    let dcd_secs = run("dcd", 1);
    let asy_secs = run("asyscd", 2);
    println!("  serial DCD: {dcd_secs:.3}s   AsySCD(2 threads incl. Q init): {asy_secs:.3}s");
    println!(
        "  [{}] AsySCD slower than serial DCD ({:.0}x) — paper Fig 2(d)",
        if asy_secs > dcd_secs { "PASS" } else { "FAIL" },
        asy_secs / dcd_secs
    );
}
