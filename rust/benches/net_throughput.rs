//! §Net bench: end-to-end HTTP scoring throughput over loopback.
//!
//! Trains one model on the rcv1 analog, serves it on two routes, and
//! drives `POST /v1/score` traffic through real sockets with the
//! self-contained load generator at 1/2/4 server workers — QPS plus
//! client-observed p50/p95/p99 end-to-end latency per width.
//!
//! This is the before/after instrument for network-path PRs (parser
//! cost, keep-alive policy, worker pool shape, listener sharding).
//!
//! Run: `cargo bench --bench net_throughput`

use passcode::coordinator::config::RunConfig;
use passcode::coordinator::driver;
use passcode::coordinator::metrics::TextTable;
use passcode::data::registry as data_registry;
use passcode::net::{
    run_load, HttpClient, LoadConfig, Router, RoutesConfig, Server,
    ServerConfig, SparseRow,
};

fn main() {
    // ---- train once, save, and build a reusable two-route config ----
    let scale = 0.05;
    let cfg = RunConfig {
        dataset: "rcv1".into(),
        scale,
        epochs: 8,
        threads: 2,
        eval_every: 0,
        ..Default::default()
    };
    let (model, _) = driver::train_model(&cfg).expect("train");
    let dir = std::env::temp_dir().join("passcode_net_bench");
    std::fs::create_dir_all(&dir).unwrap();
    let model_path = dir.join("model.json");
    model.save(&model_path).expect("save model");
    let routes = RoutesConfig::from_json_text(&format!(
        r#"{{"routes": [
            {{"name": "a", "model": {path:?}, "shards": 2, "max_wait_us": 100}},
            {{"name": "b", "model": {path:?}, "shards": 2, "max_wait_us": 100}}
        ]}}"#,
        path = model_path.to_str().unwrap(),
    ))
    .expect("routes config");

    // Traffic: raw held-out rows, cycled by the load generator.
    let (_, test, _) = data_registry::load("rcv1", scale).expect("load data");
    let rows: Vec<SparseRow> =
        (0..test.n().min(256)).map(|i| test.raw_row(i)).collect();

    let load = LoadConfig { connections: 4, requests_per_conn: 500 };
    println!(
        "=== net throughput (rcv1 analog @ {scale}, {} rows cycled, \
         {} conns x {} reqs, 2 routes x 2 shards) ===\n",
        rows.len(),
        load.connections,
        load.requests_per_conn
    );
    let mut table = TextTable::new(&[
        "workers", "requests", "errors", "qps", "p50_ms", "p95_ms",
        "p99_ms", "srv_reqs",
    ]);
    for workers in [1usize, 2, 4] {
        let server = Server::start(
            Router::start(&routes).expect("router"),
            &ServerConfig {
                addr: "127.0.0.1:0".into(),
                workers,
                ..Default::default()
            },
        )
        .expect("server");
        let addr = server.addr();
        let report = run_load(addr, "a", &rows, &load).expect("load");

        // Server-side cross-check via the admin plane.
        let mut admin = HttpClient::new(addr);
        let stats = admin
            .get("/v1/stats")
            .and_then(|r| r.ok())
            .and_then(|r| r.json())
            .expect("stats");
        let srv_reqs = stats
            .get("routes")
            .and_then(|r| r.get("a"))
            .and_then(|a| a.get("requests"))
            .and_then(|n| n.as_usize())
            .expect("stats.requests");

        table.row(&[
            workers.to_string(),
            report.requests.to_string(),
            report.errors.to_string(),
            format!("{:.0}", report.qps),
            format!("{:.3}", report.p50_secs * 1e3),
            format!("{:.3}", report.p95_secs * 1e3),
            format!("{:.3}", report.p99_secs * 1e3),
            srv_reqs.to_string(),
        ]);
        server.shutdown();
    }
    println!("{}", table.render());
    println!(
        "(latency is client-observed end-to-end over loopback, so it \
         includes connect/parse/dispatch/microbatch/score/serialize; \
         srv_reqs is route a's own counter and must equal requests)"
    );
}
