//! Bench: **Figures 2–6, panel (b)** — primal objective vs *time*.
//!
//! Time axis: simulated p-core seconds from the multicore DES (the
//! testbed substitution), with one epoch-indexed convergence log mapped
//! onto each mechanism's simulated epoch timeline (init cost included,
//! as in §5.2).  Serial DCD provides the reference line.
//!
//! Run: `cargo bench --bench fig_b_obj_time`

use passcode::data::registry;
use passcode::eval;
use passcode::loss::Hinge;
use passcode::simcore::{self, CostModel, Mechanism, SimConfig};

fn main() {
    let scale = std::env::var("PASSCODE_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.1);
    let epochs = 12;
    let cores = 10;
    println!("=== Fig (b): primal objective vs simulated time ({cores} cores, scale {scale}) ===");
    for dataset in ["news20", "covtype", "rcv1", "webspam", "kddb"] {
        let (tr, _, c) = registry::load(dataset, scale).unwrap();
        let loss = Hinge::new(c);
        let cost = CostModel::default();
        // init cost model: one pass over nnz to compute ||x_i||² (§5.2)
        let init_ns = tr.x.nnz() as f64 * cost.t_read;
        println!("\n--- {dataset} (init {:.4}s simulated) ---", init_ns * 1e-9);
        println!("series,epoch,sim_secs,primal");
        for (mech, name, sim_cores) in [
            (Mechanism::Wild, "passcode-wild", cores),
            (Mechanism::Atomic, "passcode-atomic", cores),
            (Mechanism::Lock, "passcode-lock", cores),
            (Mechanism::Wild, "dcd-serial", 1),
        ] {
            // Re-simulate with increasing epoch budgets to sample the
            // curve (the DES is deterministic, so prefixes agree).
            for e in [1, 2, 4, 8, epochs] {
                let sim = simcore::simulate(
                    &tr,
                    &loss,
                    &SimConfig {
                        cores: sim_cores,
                        epochs: e,
                        seed: 7,
                        cost,
                        mechanism: mech, sockets: 1, },
                );
                let p = eval::primal_objective(&tr, &loss, &sim.w);
                println!(
                    "{name},{e},{:.6},{p:.6}",
                    (init_ns + sim.virtual_ns) * 1e-9
                );
            }
        }
    }
    println!("\nshape: wild reaches any objective level fastest; lock's");
    println!("timeline is longer than serial DCD's (Table 1 in time form).");
}
