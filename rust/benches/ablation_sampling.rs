//! Ablation X2: design choices of Algorithm 1/2 —
//!  * random permutation vs with-replacement sampling (§3.3),
//!  * shrinking on/off (the LIBLINEAR heuristic),
//! measured on the rcv1 analog: epochs-to-gap and updates performed.
//!
//! Run: `cargo bench --bench ablation_sampling`

use passcode::data::registry;
use passcode::eval;
use passcode::loss::Hinge;
use passcode::solver::{Sampling, SerialDcd, SolveOptions};
use passcode::util::Timer;

fn main() {
    let (tr, _, c) = registry::load("rcv1", 0.1).unwrap();
    let loss = Hinge::new(c);
    println!("=== Ablation: sampling scheme + shrinking (rcv1 analog) ===\n");
    println!(
        "{:<28} {:>8} {:>12} {:>12} {:>10}",
        "variant", "epochs", "updates", "gap", "time (s)"
    );
    for (name, sampling, shrinking) in [
        ("permutation", Sampling::Permutation, false),
        ("with-replacement", Sampling::WithReplacement, false),
        ("permutation + shrinking", Sampling::Permutation, true),
    ] {
        for epochs in [5usize, 15, 30] {
            let t = Timer::start();
            let r = SerialDcd::solve(
                &tr,
                &loss,
                &SolveOptions {
                    epochs,
                    sampling,
                    shrinking,
                    ..Default::default()
                },
                None,
            );
            let secs = t.secs();
            let gap = eval::duality_gap(&tr, &loss, &r.alpha);
            println!(
                "{:<28} {:>8} {:>12} {:>12.4e} {:>10.3}",
                name, epochs, r.updates, gap, secs
            );
        }
        println!();
    }
    println!("shape: permutation converges faster per epoch than");
    println!("with-replacement (LIBLINEAR's choice); shrinking cuts");
    println!("updates at equal quality once the active set stabilizes.");
}
