//! Ablation X2: design choices of Algorithm 1/2 —
//!  * random permutation vs with-replacement sampling (§3.3),
//!  * shrinking on/off (the LIBLINEAR heuristic),
//! measured on the rcv1 analog: epochs-to-gap and updates performed.
//!
//! Dispatch goes through the solver registry (`solver::lookup` +
//! `TrainSession`) so this bench cannot drift from the public API.
//!
//! Run: `cargo bench --bench ablation_sampling`

use passcode::data::registry;
use passcode::loss::LossKind;
use passcode::solver::{lookup, Sampling, Solver, SolveOptions};
use passcode::util::Timer;

fn main() {
    let (tr, _, c) = registry::load("rcv1", 0.1).unwrap();
    println!("=== Ablation: sampling scheme + shrinking (rcv1 analog) ===\n");
    println!(
        "{:<28} {:>8} {:>12} {:>12} {:>10}",
        "variant", "epochs", "updates", "gap", "time (s)"
    );
    for (name, sampling, shrinking) in [
        ("permutation", Sampling::Permutation, false),
        ("with-replacement", Sampling::WithReplacement, false),
        ("permutation + shrinking", Sampling::Permutation, true),
    ] {
        for epochs in [5usize, 15, 30] {
            let solver = lookup("dcd").unwrap();
            let t = Timer::start();
            let mut session = solver
                .session(
                    &tr,
                    LossKind::Hinge,
                    c,
                    SolveOptions {
                        epochs,
                        sampling,
                        shrinking,
                        ..Default::default()
                    },
                )
                .unwrap();
            session.run_epochs(epochs).unwrap();
            let secs = t.secs();
            let gap = session.duality_gap();
            println!(
                "{:<28} {:>8} {:>12} {:>12.4e} {:>10.3}",
                name,
                epochs,
                session.updates(),
                gap,
                secs
            );
        }
        println!();
    }
    println!("shape: permutation converges faster per epoch than");
    println!("with-replacement (LIBLINEAR's choice); shrinking cuts");
    println!("updates at equal quality once the active set stabilizes.");
}
