//! Ablation: **thread affinity / NUMA** (paper §3.3).
//!
//! The paper binds all threads to one socket to avoid remote-socket
//! memory access.  The simulator models the 2-socket testbed: cores
//! spread over 2 sockets pay `numa_remote_penalty` on every read of a
//! feature last written from the other socket.  Expectation: same-socket
//! affinity (sockets = 1) is faster than spreading (sockets = 2), and the
//! penalty grows with the dataset's write-sharing (dense covtype worst).
//!
//! Run: `cargo bench --bench ablation_numa`

use passcode::data::registry;
use passcode::loss::Hinge;
use passcode::simcore::{self, Mechanism, SimConfig};

fn main() {
    let epochs = 10;
    println!("=== Ablation: thread affinity (1 socket) vs spread (2 sockets) ===\n");
    println!(
        "{:<10} {:>7} {:>16} {:>16} {:>10}",
        "dataset", "cores", "1-socket (s)", "2-socket (s)", "slowdown"
    );
    for dataset in ["rcv1", "covtype", "news20"] {
        let (tr, _, c) = registry::load(dataset, 0.1).unwrap();
        let loss = Hinge::new(c);
        for cores in [4usize, 10] {
            let run = |sockets: usize| {
                simcore::simulate(
                    &tr,
                    &loss,
                    &SimConfig {
                        cores,
                        epochs,
                        seed: 7,
                        cost: Default::default(),
                        mechanism: Mechanism::Wild,
                        sockets,
                    },
                )
                .virtual_ns
                    * 1e-9
            };
            let t1 = run(1);
            let t2 = run(2);
            println!(
                "{:<10} {:>7} {:>16.5} {:>16.5} {:>9.2}x",
                dataset, cores, t1, t2, t2 / t1
            );
        }
    }
    println!(
        "\nshape: same-socket affinity wins everywhere — the paper §3.3\n\
         rationale for libnuma binding; the ~10% uniform tax matches the\n\
         read-fraction × (remote/local − 1) prediction of the cost model."
    );
}
