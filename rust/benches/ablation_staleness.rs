//! Ablation X3: staleness (τ) vs convergence — the quantity Lemma 1 and
//! Theorem 2 bound.  The DES reports mean in-flight updates at read time
//! (an empirical τ); sweeping core count shows how τ grows and how the
//! per-epoch convergence of Atomic/Wild degrades, checking the theory's
//! qualitative claim: convergence persists while τ ≪ √n.
//!
//! Run: `cargo bench --bench ablation_staleness`

use passcode::data::registry;
use passcode::eval;
use passcode::loss::Hinge;
use passcode::simcore::{self, Mechanism, SimConfig};

fn main() {
    let (tr, _, c) = registry::load("rcv1", 0.1).unwrap();
    let loss = Hinge::new(c);
    let epochs = 10;
    let sqrt_n = (tr.n() as f64).sqrt();
    println!("=== Ablation: staleness vs convergence (rcv1 analog, n = {}) ===", tr.n());
    println!("Lemma-1 regime bound: τ ≪ √n = {sqrt_n:.1}\n");
    println!(
        "{:>6} {:>10} {:>12} {:>14} {:>12} {:>12}",
        "cores", "mech", "mean τ", "lost writes", "gap", "P(ŵ)"
    );
    for mech in [Mechanism::Atomic, Mechanism::Wild] {
        for cores in [1usize, 2, 4, 8, 16, 32] {
            let sim = simcore::simulate(
                &tr,
                &loss,
                &SimConfig {
                    cores,
                    epochs,
                    seed: 7,
                    cost: Default::default(),
                    mechanism: mech, sockets: 1, },
            );
            let gap = eval::duality_gap(&tr, &loss, &sim.alpha);
            let p = eval::primal_objective(&tr, &loss, &sim.w);
            println!(
                "{:>6} {:>10} {:>12.2} {:>14} {:>12.4e} {:>12.5}",
                cores,
                format!("{mech:?}"),
                sim.mean_staleness,
                sim.lost_writes,
                gap,
                p
            );
        }
        println!();
    }
    println!("shape: τ grows ~linearly with cores; convergence quality");
    println!("(gap after {epochs} epochs) degrades gracefully while τ ≪ √n,");
    println!("matching the Lemma-1/Theorem-2 condition (6τ(τ+1)²eM/√n ≤ 1).");
}
