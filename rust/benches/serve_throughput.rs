//! §Serve bench: replay a held-out split through the online scoring
//! stack at 1/2/4/8 shards and report QPS, coalescing factor, and
//! p50/p95/p99 end-to-end latency, with the online trainer hot-swapping
//! retrained models mid-stream.
//!
//! This is the before/after instrument for serve-side scaling PRs
//! (sharding, caching, batching policy).
//!
//! Run: `cargo bench --bench serve_throughput`

use std::time::Duration;

use passcode::coordinator::metrics::TextTable;
use passcode::serve::{self, ReplayConfig};

fn main() {
    let base = ReplayConfig {
        dataset: "rcv1".into(),
        scale: 0.2,
        train_epochs: 10,
        train_threads: 2,
        online_rounds: 3,
        online_epochs: 1,
        max_batch: 64,
        max_wait: Duration::from_micros(200),
        pin_threads: false,
        seed: 42,
        shards: 1,
    };
    println!(
        "=== serve throughput (rcv1 analog @ {}, batch ≤ {}, wait {:?}, {} hot-swaps) ===\n",
        base.scale, base.max_batch, base.max_wait, base.online_rounds
    );
    let mut table = TextTable::new(&[
        "shards", "requests", "qps", "avg_batch", "p50_ms", "p95_ms",
        "p99_ms", "acc", "swaps",
    ]);
    for shards in [1usize, 2, 4, 8] {
        let cfg = ReplayConfig { shards, ..base.clone() };
        let rep = serve::replay(&cfg).expect("replay failed");
        let t = &rep.throughput;
        table.row(&[
            shards.to_string(),
            t.requests.to_string(),
            format!("{:.0}", t.qps),
            format!("{:.1}", t.avg_batch),
            format!("{:.3}", t.p50_secs * 1e3),
            format!("{:.3}", t.p95_secs * 1e3),
            format!("{:.3}", t.p99_secs * 1e3),
            format!("{:.4}", rep.accuracy),
            rep.swaps.to_string(),
        ]);
    }
    println!("{}", table.render());
    println!(
        "(offline warm-up training is excluded from the window; the \
         synchronous online rounds are included — see each report's \
         online_train_secs when comparing raw scoring QPS)"
    );
}
