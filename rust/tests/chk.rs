//! Acceptance invariants for the memory-model checker (`passcode::chk`):
//! the paper's claims as executable assertions, each over ≥ 100 seeded
//! schedules.
//!
//! * PASSCoDe-Lock and PASSCoDe-Atomic are race- and violation-free on
//!   every explored schedule;
//! * PASSCoDe-Wild races on `w` (and *must* — its plain read-add-store
//!   is the racy regime Theorem 3 analyzes) but never on α and never
//!   out of bounds;
//! * schedules are deterministic functions of their seed (the replay
//!   story), and the measured-τ / backward-error report round-trips
//!   through the repo's JSON.

use passcode::chk::{self, CheckConfig, CheckReport};
use passcode::solver::MemoryModel;
use passcode::util::Json;

fn cfg_100() -> CheckConfig {
    CheckConfig {
        threads: 3,
        rows: 9,
        features: 6,
        epochs: 1,
        schedules: 100,
        seed: 7,
        ..CheckConfig::default()
    }
}

#[test]
fn lock_kernel_is_race_free_across_100_schedules() {
    let rep = chk::check_model(MemoryModel::Lock, &cfg_100());
    assert!(rep.ok, "violating seed: {:?}", rep.first_violation_seed);
    assert_eq!(rep.races_w, 0);
    assert_eq!(rep.races_alpha, 0);
    assert_eq!(rep.oob + rep.unsorted_locks + rep.other_violations, 0);
    assert!(rep.updates > 0);
    // Serialized writes: ŵ equals Σ α_i x_i to rounding (Eq. 6 gap 0).
    assert!(rep.eps_ratio_max < 1e-9, "eps {}", rep.eps_ratio_max);
}

#[test]
fn cas_kernel_is_race_free_across_100_schedules() {
    let rep = chk::check_model(MemoryModel::Atomic, &cfg_100());
    assert!(rep.ok, "violating seed: {:?}", rep.first_violation_seed);
    assert_eq!(rep.races_w, 0);
    assert_eq!(rep.races_alpha, 0);
    assert_eq!(rep.oob + rep.unsorted_locks + rep.other_violations, 0);
    assert!(rep.eps_ratio_max < 1e-9, "eps {}", rep.eps_ratio_max);
}

#[test]
fn wild_kernel_races_on_w_only_across_100_schedules() {
    let rep = chk::check_model(MemoryModel::Wild, &cfg_100());
    assert!(rep.ok, "violating seed: {:?}", rep.first_violation_seed);
    assert!(rep.races_w > 0, "wild must race on w");
    assert_eq!(rep.races_alpha, 0, "α has a unique owner (§3.3)");
    assert_eq!(rep.oob, 0, "wild races must stay in bounds");
    assert_eq!(rep.unsorted_locks + rep.other_violations, 0);
    // Every multi-threaded schedule is racy: no lock edges order the
    // threads' plain accesses to the hot feature-0 cell.
    assert_eq!(rep.racy_schedules, rep.schedules);
}

#[test]
fn schedules_replay_deterministically_from_their_seed() {
    let cfg = CheckConfig { schedules: 1, ..cfg_100() };
    for model in
        [MemoryModel::Lock, MemoryModel::Atomic, MemoryModel::Wild]
    {
        let a = chk::run_schedule(model, &cfg, 0xDEAD_BEEF);
        let b = chk::run_schedule(model, &cfg, 0xDEAD_BEEF);
        assert_eq!(a, b, "{} schedule not replay-identical", model.name());
        assert!(!a.events.is_empty());
    }
}

#[test]
fn preempted_wild_schedules_measure_positive_tau() {
    // τ counts foreign w-writes inside an update's read→write window,
    // so it needs real interleaving: more threads and a bigger
    // preemption budget than the defaults.
    let cfg = CheckConfig {
        threads: 4,
        rows: 12,
        epochs: 2,
        schedules: 100,
        preemption_bound: 32,
        ..cfg_100()
    };
    let rep = chk::check_model(MemoryModel::Wild, &cfg);
    assert!(rep.ok, "violating seed: {:?}", rep.first_violation_seed);
    assert!(rep.tau_max > 0, "no staleness observed in 100 schedules");
    assert!(rep.tau_mean > 0.0);
    // Lost updates open the Theorem-3 gap between ŵ and Σ α_i x_i.
    assert!(rep.eps_ratio_max > 0.0);
}

#[test]
fn check_report_round_trips_through_json() {
    let cfg = CheckConfig { schedules: 5, ..cfg_100() };
    let rep = chk::run_check(&cfg);
    assert!(rep.ok);
    assert_eq!(rep.models.len(), 3);
    let text = rep.to_json().to_pretty();
    let parsed = Json::parse(&text).expect("report JSON re-parses");
    let back = CheckReport::from_json(&parsed).expect("report deserializes");
    assert_eq!(rep, back, "lossy JSON round-trip");
    // Human rendering mentions every model and the final verdict.
    let rendered = rep.render();
    for m in ["lock", "atomic", "wild"] {
        assert!(rendered.contains(m), "render missing {m}:\n{rendered}");
    }
    assert!(rendered.contains("result: OK"));
}

#[test]
fn single_model_subset_respects_the_selection() {
    let cfg = CheckConfig { schedules: 2, ..cfg_100() };
    let rep = chk::run_check_models(&cfg, &[MemoryModel::Atomic]);
    assert_eq!(rep.models.len(), 1);
    assert_eq!(rep.models[0].model, "atomic");
    assert!(rep.ok);
}
