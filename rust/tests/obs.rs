//! Integration tests for the observability layer: one `GET /metrics`
//! scrape must serve the *training* telemetry family (updates, epoch
//! timings, τ, backward error) and the *serving* family (per-route
//! QPS/latency/registry depth, HTTP totals) out of the same registry,
//! with a warm-start training round running mid-traffic — the PR's
//! acceptance property.  Plus: the exposition format parses back,
//! counters are monotonic under concurrent traffic, and per-route
//! labels stay isolated across a mid-traffic publish.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use passcode::coordinator::model_io::Model;
use passcode::data::registry;
use passcode::loss::LossKind;
use passcode::net::{HttpClient, Router, RoutesConfig, Server, ServerConfig};
use passcode::solver::{lookup, SolveOptions};

const D: usize = 8;

fn toy_model(tag: f64) -> Model {
    Model {
        w: vec![tag; D],
        loss: "hinge".into(),
        c: 1.0,
        solver: "test".into(),
        dataset: "toy".into(),
    }
}

/// Two-route loopback server with per-test route names (the metrics
/// registry is process-global, so label isolation across tests needs
/// distinct names).
fn server_with_routes(tag: &str, ra: &str, rb: &str) -> (Server, std::path::PathBuf) {
    let dir = std::env::temp_dir().join("passcode_obs_it").join(tag);
    std::fs::create_dir_all(&dir).unwrap();
    let path_a = dir.join("a.json");
    let path_b = dir.join("b.json");
    toy_model(1.0).save(&path_a).unwrap();
    toy_model(2.0).save(&path_b).unwrap();
    let cfg = RoutesConfig::from_json_text(&format!(
        r#"{{"routes": [
            {{"name": {ra:?}, "model": {:?}, "shards": 1}},
            {{"name": {rb:?}, "model": {:?}, "shards": 1}}
        ]}}"#,
        path_a.to_str().unwrap(),
        path_b.to_str().unwrap(),
    ))
    .unwrap();
    let server = Server::start(
        Router::start(&cfg).unwrap(),
        &ServerConfig { addr: "127.0.0.1:0".into(), workers: 2, ..Default::default() },
    )
    .unwrap();
    (server, dir)
}

/// Parse a Prometheus text exposition, asserting every line is
/// well-formed.  Returns (samples keyed by full name-with-labels,
/// types keyed by base name).
fn parse_exposition(text: &str) -> (BTreeMap<String, f64>, BTreeMap<String, String>) {
    let mut samples = BTreeMap::new();
    let mut types = BTreeMap::new();
    for line in text.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let base = it.next().expect("TYPE line has a name");
            let kind = it.next().expect("TYPE line has a kind");
            assert!(
                matches!(kind, "counter" | "gauge" | "summary"),
                "unknown metric kind in {line:?}"
            );
            types.insert(base.to_string(), kind.to_string());
            continue;
        }
        if line.starts_with('#') {
            continue; // HELP
        }
        let (name, value) = line.rsplit_once(' ').unwrap_or_else(|| {
            panic!("malformed sample line {line:?}");
        });
        let v: f64 = match value {
            "NaN" => f64::NAN,
            "+Inf" => f64::INFINITY,
            "-Inf" => f64::NEG_INFINITY,
            other => other.parse().unwrap_or_else(|e| {
                panic!("bad value in {line:?}: {e}");
            }),
        };
        // Metric-name grammar: base is [a-zA-Z_:][a-zA-Z0-9_:]*, with
        // an optional {label="value",...} suffix.
        let base = name.split('{').next().unwrap();
        assert!(
            !base.is_empty()
                && base.chars().next().unwrap().is_ascii_alphabetic()
                && base.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'),
            "bad metric name in {line:?}"
        );
        if name.contains('{') {
            assert!(name.ends_with('}'), "unbalanced labels in {line:?}");
        }
        // Every sample's base (or its _sum/_count parent) carries a
        // TYPE header by the time the scrape ends.
        samples.insert(name.to_string(), v);
    }
    // Cross-check: each TYPE header has at least one sample.
    for base in types.keys() {
        assert!(
            samples.keys().any(|n| {
                let b = n.split('{').next().unwrap();
                b == base || b == format!("{base}_sum") || b == format!("{base}_count")
            }),
            "TYPE {base} has no samples"
        );
    }
    (samples, types)
}

fn scrape(client: &mut HttpClient) -> (BTreeMap<String, f64>, BTreeMap<String, String>) {
    let resp = client
        .request("GET", "/metrics", "text/plain", b"")
        .unwrap()
        .ok()
        .unwrap();
    parse_exposition(std::str::from_utf8(&resp.body).unwrap())
}

#[test]
fn one_scrape_serves_training_and_serving_families() {
    passcode::obs::set_probes_enabled(true);
    let (server, _dir) = server_with_routes("families", "fam_a", "fam_b");
    let addr = server.addr();

    let stop = Arc::new(AtomicBool::new(false));
    std::thread::scope(|s| {
        // Concurrent scoring traffic on route fam_a.
        let traffic_stop = Arc::clone(&stop);
        s.spawn(move || {
            let mut client = HttpClient::new(addr);
            while !traffic_stop.load(Ordering::Acquire) {
                let resp = client
                    .request(
                        "POST",
                        "/v1/score?route=fam_a",
                        "application/json",
                        br#"{"idx": [0, 3], "vals": [1.0, -1.0]}"#,
                    )
                    .unwrap()
                    .ok()
                    .unwrap();
                assert_eq!(resp.status, 200);
            }
        });

        // Mid-traffic: a training session runs one cold epoch and one
        // warm-start round (PASSCoDe-Atomic, 2 threads) in-process.
        let (train, _test, c) = registry::load("rcv1", 0.02).unwrap();
        let solver = lookup("passcode-atomic").unwrap();
        let opts = SolveOptions { threads: 2, epochs: 2, ..Default::default() };
        let mut session = solver.session(&train, LossKind::Hinge, c, opts).unwrap();
        session.run_epochs(1).unwrap();
        session.run_epochs(1).unwrap(); // the warm-start round
        stop.store(true, Ordering::Release);
    });

    let mut client = HttpClient::new(addr);
    let (samples, types) = scrape(&mut client);

    // Training family — populated by the in-process session.
    assert!(samples["passcode_train_updates_total"] > 0.0);
    assert!(samples["passcode_train_epochs_total"] >= 2.0);
    assert!(samples["passcode_train_epoch_seconds_count"] > 0.0);
    assert!(samples.contains_key("passcode_train_tau_count"));
    assert!(samples.contains_key("passcode_train_backward_error_ratio"));
    assert!(samples["passcode_train_updates_per_sec"] > 0.0);
    assert_eq!(types["passcode_train_updates_total"], "counter");
    assert_eq!(types["passcode_train_epoch_seconds"], "summary");
    assert_eq!(types["passcode_train_backward_error_ratio"], "gauge");
    // The backward-error ratio of a converging run is small but real;
    // at the very least it must be finite and non-negative.
    let ratio = samples["passcode_train_backward_error_ratio"];
    assert!(ratio.is_finite() && ratio >= 0.0, "{ratio}");

    // Serving family — populated by the concurrent traffic, in the
    // same scrape.
    assert!(samples["passcode_route_requests_total{route=\"fam_a\"}"] > 0.0);
    assert!(samples.contains_key("passcode_route_qps{route=\"fam_a\"}"));
    let p99 = "passcode_route_latency_seconds{route=\"fam_a\",quantile=\"0.99\"}";
    assert!(samples.contains_key(p99));
    assert!(samples["passcode_route_versions_alive{route=\"fam_a\"}"] >= 1.0);
    assert!(samples["passcode_http_requests_total"] > 0.0);
    assert!(samples["passcode_http_request_seconds_count"] > 0.0);

    server.shutdown();
}

#[test]
fn counters_are_monotonic_and_labels_survive_mid_traffic_publish() {
    passcode::obs::set_probes_enabled(true);
    let (server, dir) = server_with_routes("monotonic", "mono_a", "mono_b");
    let addr = server.addr();

    // A model to hot-swap into mono_b mid-traffic.
    let path_b9 = dir.join("b9.json");
    toy_model(9.0).save(&path_b9).unwrap();
    let publish_body = format!("{{\"path\": {:?}}}", path_b9.to_str().unwrap());

    let stop = Arc::new(AtomicBool::new(false));
    std::thread::scope(|s| {
        let traffic_stop = Arc::clone(&stop);
        s.spawn(move || {
            let mut client = HttpClient::new(addr);
            while !traffic_stop.load(Ordering::Acquire) {
                client
                    .request(
                        "POST",
                        "/v1/score?route=mono_a",
                        "application/json",
                        br#"{"idx": [1], "vals": [2.0]}"#,
                    )
                    .unwrap()
                    .ok()
                    .unwrap();
            }
        });

        let mut client = HttpClient::new(addr);
        let (first, _) = scrape(&mut client);

        // Mid-traffic publish on mono_b.
        let resp = client
            .request(
                "POST",
                "/v1/models/mono_b/publish",
                "application/json",
                publish_body.as_bytes(),
            )
            .unwrap()
            .ok()
            .unwrap();
        assert_eq!(resp.status, 200);

        let (second, _) = scrape(&mut client);
        stop.store(true, Ordering::Release);

        // Monotonic under concurrent traffic: totals never regress
        // between scrapes.
        let a_total = "passcode_route_requests_total{route=\"mono_a\"}";
        for key in ["passcode_http_requests_total", a_total] {
            assert!(
                second[key] >= first[key],
                "{key} regressed: {} -> {}",
                first[key],
                second[key]
            );
        }
        assert!(second[a_total] > 0.0);

        // Label isolation: the publish bumped mono_b's epoch gauge and
        // only mono_b's; mono_a still serves registry epoch 0.
        assert_eq!(second["passcode_route_model_epoch{route=\"mono_b\"}"], 1.0);
        assert_eq!(second["passcode_route_model_epoch{route=\"mono_a\"}"], 0.0);
        assert_eq!(second["passcode_route_requests_total{route=\"mono_b\"}"], 0.0);
    });

    server.shutdown();
}

#[test]
fn trace_endpoint_dumps_http_and_training_spans() {
    passcode::obs::set_probes_enabled(true);
    let (server, _dir) = server_with_routes("trace", "tr_a", "tr_b");
    let addr = server.addr();
    let mut client = HttpClient::new(addr);
    client
        .request("GET", "/healthz", "text/plain", b"")
        .unwrap()
        .ok()
        .unwrap();

    // A tiny training round so train.epoch spans are in the ring (the
    // recorder is process-global, so runs from other tests may be
    // present too — that is fine, we only assert ours exist).
    let (train, _test, c) = registry::load("rcv1", 0.02).unwrap();
    let solver = lookup("passcode-wild").unwrap();
    let opts = SolveOptions { threads: 2, epochs: 1, ..Default::default() };
    let mut session = solver.session(&train, LossKind::Hinge, c, opts).unwrap();
    session.run_epochs(1).unwrap();

    let resp = client
        .request("GET", "/v1/trace", "application/json", b"")
        .unwrap()
        .ok()
        .unwrap();
    let j = resp.json().unwrap();
    assert_eq!(j.get("format").unwrap().as_str().unwrap(), "passcode-trace-v1");
    let events = j.get("events").unwrap().as_arr().unwrap();
    assert!(!events.is_empty());
    let mut kinds = Vec::new();
    let mut last_t = f64::NEG_INFINITY;
    for e in events {
        kinds.push(e.get("kind").unwrap().as_str().unwrap().to_string());
        // tid + monotonic timestamps on every event.
        assert!(e.get("tid").unwrap().as_f64().unwrap() >= 0.0);
        let t = e.get("t_us").unwrap().as_f64().unwrap();
        assert!(t >= last_t, "ring out of order: {last_t} then {t}");
        last_t = t;
        assert!(e.get("dur_us").unwrap().as_f64().unwrap() >= 0.0);
    }
    assert!(kinds.iter().any(|k| k == "http.request"), "{kinds:?}");
    assert!(kinds.iter().any(|k| k == "train.epoch"), "{kinds:?}");

    server.shutdown();
}
