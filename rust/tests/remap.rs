//! The feature-locality remap's correctness contracts: a remap is a pure
//! column permutation, so `remap → solve → inverse-remap` must reproduce
//! the unremapped solve's predictions exactly, and training checkpoints
//! taken on remapped datasets must round-trip through `model_io`
//! (checkpoint + persisted remap) into bit-exact resumption.

use passcode::coordinator::model_io::{
    load_checkpoint, load_remap, save_checkpoint, save_remap,
};
use passcode::data::{registry, Dataset, FeatureRemap};
use passcode::eval;
use passcode::loss::{Hinge, LossKind};
use passcode::solver::{lookup, SerialDcd, Solver, SolveOptions};

fn small() -> (Dataset, Dataset, f64) {
    let (tr, te, c) = registry::load("rcv1", 0.05).unwrap();
    (tr, te, c)
}

/// ±1 predictions of `w` on (folded) dataset rows.
fn predictions(ds: &Dataset, w: &[f64]) -> Vec<f64> {
    (0..ds.n())
        .map(|i| {
            let folded = ds.x.row_dot_dense(i, w);
            // folded margin > 0 ⇔ prediction matches the label
            if folded > 0.0 { ds.y[i] } else { -ds.y[i] }
        })
        .collect()
}

#[test]
fn remap_solve_inverse_remap_matches_unremapped_serial_dcd() {
    let (tr, te, c) = small();
    let loss = Hinge::new(c);
    let opts = SolveOptions { epochs: 15, ..Default::default() };

    let plain = SerialDcd::solve(&tr, &loss, &opts, None);

    let (tr_r, map) = tr.remap_features();
    let remapped = SerialDcd::solve(&tr_r, &loss, &opts, None);
    let w_back = map.unmap_w(&remapped.w_hat);

    // Predictions on the held-out split are bit-identical (±1 vectors).
    assert_eq!(
        predictions(&te, &w_back),
        predictions(&te, &plain.w_hat),
        "remap round trip changed predictions"
    );
    // And the weight vectors agree to float-summation noise: the remap
    // only reorders the per-row accumulation.
    let err = w_back
        .iter()
        .zip(&plain.w_hat)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    assert!(err < 1e-6, "‖w_back − w_plain‖∞ = {err}");
    // α lives in row space — untouched by a column permutation.
    let aerr = remapped
        .alpha
        .iter()
        .zip(&plain.alpha)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    assert!(aerr < 1e-6, "α diverged: {aerr}");
}

#[test]
fn remap_preserves_objective_and_gap() {
    let (tr, _, c) = small();
    let loss = Hinge::new(c);
    let opts = SolveOptions { epochs: 10, ..Default::default() };
    let (tr_r, map) = tr.remap_features();
    let r = SerialDcd::solve(&tr_r, &loss, &opts, None);
    // Objectives are permutation-invariant: evaluate the remapped run in
    // its own space and the unmapped weights in the original space.
    let p_in = eval::primal_objective(&tr_r, &loss, &r.w_hat);
    let p_out = eval::primal_objective(&tr, &loss, &map.unmap_w(&r.w_hat));
    assert!(
        (p_in - p_out).abs() < 1e-9 * p_in.abs().max(1.0),
        "{p_in} vs {p_out}"
    );
    let gap = eval::duality_gap(&tr_r, &loss, &r.alpha);
    assert!(gap >= -1e-9);
}

#[test]
fn checkpoint_roundtrips_through_model_io_on_remapped_dataset() {
    let (tr, _, c) = small();
    let (tr_r, map) = tr.remap_features();
    let solver = lookup("passcode-wild").unwrap();
    let opts = SolveOptions { epochs: 6, seed: 11, ..Default::default() };
    let (k, n) = (3usize, 6usize);

    let mut uninterrupted = solver
        .session(&tr_r, LossKind::Hinge, c, opts.clone())
        .unwrap();
    uninterrupted.run_epochs(n).unwrap();

    let mut first = solver
        .session(&tr_r, LossKind::Hinge, c, opts.clone())
        .unwrap();
    first.run_epochs(k).unwrap();

    // Persist checkpoint + remap, as a deployment would.
    let dir = std::env::temp_dir().join("passcode_remap_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt_path = dir.join("ckpt.json");
    let remap_path = dir.join("remap.json");
    save_checkpoint(&first.snapshot(), &ckpt_path).unwrap();
    save_remap(&map, &remap_path).unwrap();

    // A fresh process reconstructs the remapped dataset from the
    // persisted map and resumes from the persisted checkpoint.
    let loaded_map = load_remap(&remap_path).unwrap();
    assert_eq!(loaded_map, map);
    let tr_r2 = tr.remap_features_with(&loaded_map);
    let ckpt = load_checkpoint(&ckpt_path).unwrap();
    let mut resumed = solver
        .session(&tr_r2, LossKind::Hinge, c, opts)
        .unwrap();
    resumed.resume(&ckpt).unwrap();
    resumed.run_epochs(n - k).unwrap();

    // Single-worker session: the continuation replays exactly.
    assert_eq!(resumed.alpha(), uninterrupted.alpha(), "α diverged");
    assert_eq!(resumed.w_hat(), uninterrupted.w_hat(), "ŵ diverged");
    assert_eq!(resumed.updates(), uninterrupted.updates());
}

#[test]
fn remap_is_deterministic_and_bijective() {
    let (tr, _, _) = small();
    let a = FeatureRemap::by_doc_frequency(&tr.x);
    let b = FeatureRemap::by_doc_frequency(&tr.x);
    assert_eq!(a, b, "doc-frequency remap must be deterministic");
    assert_eq!(a.d(), tr.d());
    // forward ∘ inverse = id and the map orders by descending df.
    let df = tr.x.col_doc_frequency();
    for new in 1..a.d() {
        let (prev, cur) =
            (a.inverse()[new - 1] as usize, a.inverse()[new] as usize);
        assert!(
            df[prev] > df[cur] || (df[prev] == df[cur] && prev < cur),
            "slot {new} out of order"
        );
    }
    for old in 0..a.d() {
        assert_eq!(a.inverse()[a.forward()[old] as usize] as usize, old);
    }
}
