//! Integration: the PJRT AOT evaluation path vs the native sparse path.
//!
//! Skip-gated rather than hard-failing: every test no-ops with a
//! printed SKIP when the AOT artifacts are absent (fresh checkout before
//! `make artifacts`) or the engine cannot come up (e.g. a default build
//! without the `xla` cargo feature).

use passcode::data::registry;
use passcode::eval;
use passcode::loss::Hinge;
use passcode::runtime::{Engine, Evaluator, Manifest};
use passcode::solver::{SerialDcd, SolveOptions};

fn engine_or_skip() -> Option<Engine> {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!(
            "SKIP: no artifacts at {} (run `make artifacts`)",
            dir.display()
        );
        return None;
    }
    match Engine::load(dir) {
        Ok(engine) => Some(engine),
        Err(e) => {
            eprintln!("SKIP: AOT engine unavailable: {e:#}");
            None
        }
    }
}

#[test]
fn engine_loads_all_artifacts() {
    let Some(engine) = engine_or_skip() else { return };
    for name in [
        "margins_block",
        "eval_block",
        "eval_block_sqhinge",
        "loss_stats_block",
        "loss_stats_block_sq",
        "sumsq_block",
        "dcd_block_epoch",
    ] {
        assert!(
            engine.manifest.artifacts.contains_key(name),
            "missing artifact {name}"
        );
    }
    assert!(engine.platform().to_lowercase().contains("cpu"));
}

#[test]
fn margins_block_matches_manual_matmul() {
    let Some(engine) = engine_or_skip() else { return };
    let rb = engine.manifest.row_block;
    let fb = engine.manifest.feat_block;
    // x = row-index pattern, w = alternating ±1: closed-form margins.
    let mut x = vec![0f32; rb * fb];
    for r in 0..rb {
        x[r * fb + (r % fb)] = (r as f32) + 1.0;
    }
    let w: Vec<f32> =
        (0..fb).map(|j| if j % 2 == 0 { 1.0 } else { -1.0 }).collect();
    let xl = Engine::literal_f32(&x, &[rb as i64, fb as i64]).unwrap();
    let wl = Engine::literal_f32(&w, &[fb as i64, 1]).unwrap();
    let out = engine.execute("margins_block", &[xl, wl]).unwrap();
    let m = out[0].to_vec::<f32>().unwrap();
    for r in 0..rb {
        let want = ((r as f32) + 1.0) * w[r % fb];
        assert!(
            (m[r] - want).abs() < 1e-4,
            "row {r}: {} vs {want}",
            m[r]
        );
    }
}

#[test]
fn aot_eval_matches_native_on_dense_dataset() {
    let Some(engine) = engine_or_skip() else { return };
    // covtype analog: d = 54 fits one feature block.
    let (tr, _, c) = registry::load("covtype", 0.02).unwrap();
    let loss = Hinge::new(c);
    let r = SerialDcd::solve(
        &tr,
        &loss,
        &SolveOptions { epochs: 5, ..Default::default() },
        None,
    );
    let native_p = eval::primal_objective(&tr, &loss, &r.w_hat);
    let native_acc = eval::accuracy(&tr, &r.w_hat);

    let ev = Evaluator::new(&engine);
    let aot = ev.eval(&tr, &r.w_hat).unwrap();
    let aot_p = aot.primal(c);
    assert!(
        (aot_p - native_p).abs() < 1e-3 * native_p.abs().max(1.0),
        "primal mismatch: aot {aot_p} vs native {native_p}"
    );
    // correct-count can differ by a few rows at |margin| ~ f32 eps
    assert!(
        (aot.accuracy() - native_acc).abs() < 5e-3,
        "accuracy mismatch: {} vs {native_acc}",
        aot.accuracy()
    );
}

#[test]
fn aot_eval_matches_native_on_sparse_multiblock_dataset() {
    let Some(engine) = engine_or_skip() else { return };
    // rcv1 analog scaled: d ≈ 2.1k spans multiple 512-feature blocks.
    let (tr, _, c) = registry::load("rcv1", 0.01).unwrap();
    assert!(tr.d() > engine.manifest.feat_block, "want multi-block d");
    let loss = Hinge::new(c);
    let r = SerialDcd::solve(
        &tr,
        &loss,
        &SolveOptions { epochs: 5, ..Default::default() },
        None,
    );
    let native_p = eval::primal_objective(&tr, &loss, &r.w_hat);
    let ev = Evaluator::new(&engine);
    let aot = ev.eval(&tr, &r.w_hat).unwrap();
    let aot_p = aot.primal(c);
    assert!(
        (aot_p - native_p).abs() < 2e-3 * native_p.abs().max(1.0),
        "primal mismatch: aot {aot_p} vs native {native_p}"
    );
}

#[test]
fn dcd_block_epoch_improves_dual_objective() {
    let Some(engine) = engine_or_skip() else { return };
    let db = engine.manifest.dcd_row_block;
    let fb = engine.manifest.feat_block;
    // Tiny dense separable problem in the exported block shape.
    let mut rng = passcode::util::Pcg32::new(5, 0);
    let scale = 1.0 / (fb as f64).sqrt();
    let mut x = vec![0f32; db * fb];
    for v in x.iter_mut() {
        *v = (rng.gen_normal() * scale) as f32;
    }
    let qii: Vec<f32> = (0..db)
        .map(|r| x[r * fb..(r + 1) * fb].iter().map(|v| v * v).sum())
        .collect();
    let c = 1.0f32;
    let alpha = vec![0f32; db];
    let w = vec![0f32; fb];

    let run = |alpha: &[f32], w: &[f32]| {
        let out = engine
            .execute(
                "dcd_block_epoch",
                &[
                    Engine::literal_f32(&x, &[db as i64, fb as i64]).unwrap(),
                    Engine::literal_f32(&qii, &[db as i64, 1]).unwrap(),
                    Engine::literal_f32(&[c], &[1, 1]).unwrap(),
                    Engine::literal_f32(alpha, &[db as i64, 1]).unwrap(),
                    Engine::literal_f32(w, &[fb as i64, 1]).unwrap(),
                ],
            )
            .unwrap();
        (
            out[0].to_vec::<f32>().unwrap(),
            out[1].to_vec::<f32>().unwrap(),
        )
    };
    // Dual objective helper (hinge): 0.5||X^T a||^2 - sum a.
    let dual = |a: &[f32]| {
        let mut wbar = vec![0f64; fb];
        for r in 0..db {
            for j in 0..fb {
                wbar[j] += a[r] as f64 * x[r * fb + j] as f64;
            }
        }
        0.5 * wbar.iter().map(|v| v * v).sum::<f64>()
            - a.iter().map(|&v| v as f64).sum::<f64>()
    };
    let d0 = dual(&alpha);
    let (a1, w1) = run(&alpha, &w);
    let d1 = dual(&a1);
    let (a2, _w2) = run(&a1, &w1);
    let d2 = dual(&a2);
    assert!(d1 < d0, "first epoch made no progress: {d1} vs {d0}");
    assert!(d2 <= d1 + 1e-6, "second epoch regressed: {d2} vs {d1}");
    assert!(a1.iter().all(|&v| (0.0..=c).contains(&v)));
}
