//! Chaos tests for the distributed tier: seeded fault injection
//! against the full loopback stack (ISSUE 10).
//!
//! The acceptance properties:
//!
//! * under a fixed fault plan — dropped pushes, duplicated pushes, a
//!   permanent partition that expires a worker's lease — a 2-worker
//!   sim still converges to within 1e-3 relative primal of the
//!   fault-free run at the same per-worker epoch budget, while
//!   exercising at least one max-lag rejection and at least one shard
//!   reassignment, and the Σ-invariant `w = Σ_p X_pᵀ α_p` survives
//!   rollback and reassignment to near machine precision;
//! * replaying the same fault seed reproduces the identical fault
//!   sequence and merge-epoch trace, byte for byte; a different seed
//!   does not;
//! * the merge rule damps every stale-but-tolerated lag `1..=max_lag`
//!   by exactly `1/K`, rejects past the bound with a `Resync` the
//!   worker can recover from by rebasing, and answers a replayed
//!   `(worker, boot, round)` id from the recorded verdict without
//!   touching `w`.

use passcode::dist::{
    run_sim, DistCoordinator, FaultPlan, MergeConfig, PartitionSpec, PushDelta,
    PushOutcome, ScriptedFault, SimConfig, SimReport,
};

/// The pinned chaos scenario: worker 0's pushes 2..=8 are dropped (the
/// parked push retries the same id until the epoch has run past
/// `max_lag`, forcing a rejection), worker 0's first push is
/// duplicated (the replay must dedup, not double-merge), and worker 1
/// is partitioned away for good a few rounds in (its lease expires,
/// its contribution rolls back, its shard moves to worker 0).
fn pinned_plan(seed: u64) -> FaultPlan {
    let mut plan = FaultPlan::quiet(seed);
    plan.reorder_window = 1;
    plan.script.push(ScriptedFault {
        worker: 0,
        kind: "push".into(),
        nth: 1,
        fault: "dup".into(),
    });
    for nth in 2..=8 {
        plan.script.push(ScriptedFault {
            worker: 0,
            kind: "push".into(),
            nth,
            fault: "drop_request".into(),
        });
    }
    plan.partitions.push(PartitionSpec { worker: 1, from: 14, until: u64::MAX });
    plan
}

/// The shared run shape: small enough to be fast, enough rounds that
/// the survivor re-converges after adopting the dead worker's shard.
fn chaos_cfg() -> SimConfig {
    SimConfig {
        dataset: "rcv1".into(),
        scale: 0.02,
        workers: 2,
        rounds: 20,
        epochs_per_round: 2,
        max_lag: 1,
        seed: 42,
        chaos: Some(pinned_plan(42)),
        lease_ops: 8,
        ..Default::default()
    }
}

#[test]
fn chaos_run_survives_faults_and_matches_fault_free_primal() {
    let report = run_sim(&chaos_cfg()).unwrap();

    // The plan's scripted faults all fired.
    assert!(!report.fault_events.is_empty(), "no faults injected");
    assert!(
        report.fault_events.iter().any(|l| l.contains("scripted drop_request")),
        "scripted push drops missing: {:?}",
        report.fault_events
    );
    assert!(
        report.fault_events.iter().any(|l| l.contains("partitioned")),
        "partition never fired: {:?}",
        report.fault_events
    );
    assert!(
        report.fault_events.iter().any(|l| l.contains("duplicate")),
        "duplicate push never held: {:?}",
        report.fault_events
    );

    // The parked push outlived the lag bound: at least one rejection,
    // and the worker recovered (accepted merges kept happening after).
    assert!(report.rejects >= 1, "no max-lag rejection: {report:?}");
    assert!(
        report.merge_trace.iter().any(|l| l.contains("resync")),
        "no resync verdict in trace: {:?}",
        report.merge_trace
    );

    // The duplicated push was answered from the recorded verdict.
    assert!(
        report.merge_trace.iter().any(|l| l.contains("dedup")),
        "replayed push did not dedup: {:?}",
        report.merge_trace
    );

    // Worker 1's lease expired behind the partition: rollback, then
    // its shard range moved to the survivor.
    assert!(report.reassigns >= 1, "no shard reassignment: {report:?}");
    assert!(
        report.merge_trace.iter().any(|l| l.contains("lease-expire w1")),
        "worker 1 lease never expired: {:?}",
        report.merge_trace
    );
    assert!(
        report.merge_trace.iter().any(|l| l.contains("reassign")),
        "no reassignment in trace: {:?}",
        report.merge_trace
    );

    // Σ-invariant across merges, damping, rollback, and reassignment:
    // single-threaded local solves, so only float reassociation is
    // tolerated.
    assert!(
        report.sigma_residual < 1e-8,
        "w drifted from X^T alpha: residual {}",
        report.sigma_residual
    );

    // The chaos metrics family is non-empty in the final scrape.
    assert!(
        report
            .dist_metrics
            .iter()
            .any(|l| l.contains("passcode_dist_fault_injected_total")),
        "no fault metrics exported: {:?}",
        report.dist_metrics
    );

    // Equal per-worker epoch budget, no faults: the chaos run's final
    // primal must land within 1e-3 relative of this.
    let clean = run_sim(&SimConfig { chaos: None, lease_ops: 0, ..chaos_cfg() }).unwrap();
    let rel = (report.primal - clean.primal).abs() / clean.primal.abs().max(1e-12);
    assert!(
        rel < 1e-3,
        "chaos primal {} vs fault-free {} (relative {rel})",
        report.primal,
        clean.primal
    );
    // Both runs actually solved the problem (guards against the
    // comparison passing because neither made progress).
    assert!(clean.merges > 0 && report.merges > 0, "no merges happened");
    assert!(report.test_accuracy > 0.6, "chaos model did not learn: {report:?}");
}

#[test]
fn same_fault_seed_replays_identical_faults_and_merge_trace() {
    let cfg = SimConfig {
        dataset: "rcv1".into(),
        scale: 0.02,
        workers: 2,
        rounds: 4,
        epochs_per_round: 1,
        seed: 42,
        chaos: Some(noisy_plan(11)),
        ..Default::default()
    };
    let a = run_sim(&cfg).unwrap();
    let b = run_sim(&cfg).unwrap();
    assert!(!a.fault_events.is_empty(), "plan injected nothing — replay test is vacuous");
    assert_eq!(a.fault_events, b.fault_events, "fault sequence not reproducible");
    assert_eq!(a.merge_trace, b.merge_trace, "merge-epoch trace not reproducible");
    assert_eq!(a.merge_epoch, b.merge_epoch);

    // A different fault seed is a different adversary.
    let other = run_sim(&SimConfig { chaos: Some(noisy_plan(12)), ..cfg }).unwrap();
    assert_ne!(
        a.fault_events, other.fault_events,
        "fault seed does not drive the fault sequence"
    );

    fn noisy_plan(seed: u64) -> FaultPlan {
        let mut plan = FaultPlan::quiet(seed);
        plan.drop_prob = 0.25;
        plan.dup_prob = 0.4;
        plan.truncate_prob = 0.2;
        plan.reorder_window = 2;
        plan
    }

    // The plan itself round-trips through its JSON file format, so a
    // failing seed can be shipped as a repro artifact.
    let dir = std::env::temp_dir().join("passcode_dist_chaos_it");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("plan.json");
    let plan = noisy_plan(11);
    plan.save(&path).unwrap();
    assert_eq!(FaultPlan::load(&path).unwrap(), plan);
}

/// Drive the coordinator's merge rule directly: every tolerated lag
/// `1..=max_lag` is damped by exactly `1/K`, `max_lag + 1` draws a
/// `Resync`, rebasing recovers, and a replayed push id is answered
/// from the recorded verdict without touching `w`.
#[test]
fn deep_lag_damping_is_exactly_one_over_k_and_resync_recovers() {
    const K: usize = 4;
    const MAX_LAG: u64 = 3;
    const DIM: usize = 4;
    let coord = DistCoordinator::new(
        vec![0.0; DIM],
        MergeConfig { workers: K, max_lag: MAX_LAG, ..Default::default() },
    );
    let pd = |worker: u64, round: u64, base_epoch: u64, delta: Vec<f64>| PushDelta {
        worker,
        boot: 0,
        round,
        base_epoch,
        delta_err: 0.0,
        delta,
    };
    // The epoch advancer: fresh lag-0 pushes from worker 9, touching
    // only coordinate 1 so the victim's coordinate 0 stays readable.
    let mut adv_round = 0u64;
    let mut advance = |by: u64| {
        for _ in 0..by {
            let base = coord.pull().0;
            let out = coord.push(&pd(9, adv_round, base, vec![0.0, 1.0, 0.0, 0.0])).unwrap();
            adv_round += 1;
            assert!(
                matches!(out, PushOutcome::Accepted { weight, .. } if weight == 1.0),
                "advancer push not fresh: {out:?}"
            );
        }
    };

    // Lag 0 merges at weight 1.
    let base = coord.pull().0;
    let out = coord.push(&pd(5, 0, base, vec![1.0, 0.0, 0.0, 0.0])).unwrap();
    assert!(matches!(out, PushOutcome::Accepted { weight, .. } if weight == 1.0), "{out:?}");
    assert_eq!(coord.pull().1[0], 1.0);

    // Every tolerated lag merges at exactly 1/K — numerically, both in
    // the returned weight and in the merged w.
    let mut round = 1u64;
    let mut expect_w0 = 1.0;
    for lag in 1..=MAX_LAG {
        let base = coord.pull().0;
        advance(lag);
        let out = coord.push(&pd(5, round, base, vec![1.0, 0.0, 0.0, 0.0])).unwrap();
        round += 1;
        match out {
            PushOutcome::Accepted { weight, .. } => {
                assert_eq!(weight, 1.0 / K as f64, "lag {lag} damped wrongly");
            }
            other => panic!("lag {lag} should merge damped, got {other:?}"),
        }
        expect_w0 += 1.0 / K as f64;
        assert_eq!(coord.pull().1[0], expect_w0, "w drifted at lag {lag}");
    }

    // One past the bound: rejected, w untouched, and the advertised
    // epoch is current — rebasing on it merges fresh again.
    let stale_base = coord.pull().0;
    advance(MAX_LAG + 1);
    let out = coord.push(&pd(5, round, stale_base, vec![1.0, 0.0, 0.0, 0.0])).unwrap();
    round += 1;
    let resync_epoch = match out {
        PushOutcome::Resync { epoch } => epoch,
        other => panic!("lag {} should resync, got {other:?}", MAX_LAG + 1),
    };
    assert_eq!(coord.pull().1[0], expect_w0, "rejected delta leaked into w");
    assert_eq!(resync_epoch, coord.pull().0, "resync must advertise the current epoch");

    let out = coord.push(&pd(5, round, resync_epoch, vec![1.0, 0.0, 0.0, 0.0])).unwrap();
    assert!(
        matches!(out, PushOutcome::Accepted { weight, .. } if weight == 1.0),
        "rebased push should merge fresh: {out:?}"
    );
    expect_w0 += 1.0;
    assert_eq!(coord.pull().1[0], expect_w0);

    // Idempotence: replaying the same (worker, boot, round) id — even
    // with a different body — returns the recorded verdict and leaves
    // w alone.
    let replay = coord.push(&pd(5, round, resync_epoch, vec![7.0, 7.0, 7.0, 7.0])).unwrap();
    assert_eq!(replay, out, "replayed id must get the recorded verdict");
    assert_eq!(coord.pull().1[0], expect_w0, "replayed push touched w");
}

/// Compile-time pin of the report surface the CI smoke step and the
/// bench table consume.
#[allow(dead_code)]
fn report_surface(r: &SimReport) -> (u64, u64, f64, &[String], &[String]) {
    (r.rejects, r.reassigns, r.sigma_residual, &r.fault_events, &r.merge_trace)
}
