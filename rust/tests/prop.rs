//! Property-based tests (hand-rolled seeded sweeps — no proptest crate in
//! the offline image; see DESIGN.md §6).  Each property runs over many
//! randomly generated cases; failures print the offending seed so the
//! case replays exactly.

use passcode::data::{synthetic::SyntheticSpec, Dataset};
use passcode::eval;
use passcode::loss::{Hinge, Logistic, Loss, SquaredHinge};
use passcode::simcore::{self, Mechanism, SimConfig};
use passcode::solver::{MemoryModel, Passcode, SerialDcd, SolveOptions};
use passcode::util::{Json, Pcg32};

/// Random small dataset from a seed.
fn random_dataset(seed: u64) -> (Dataset, f64) {
    let mut rng = Pcg32::new(seed, 99);
    let n = 40 + rng.gen_range(120);
    let d = 30 + rng.gen_range(400);
    let avg = 3.0 + rng.gen_f64() * 10.0;
    let c = [0.0625, 0.5, 1.0, 2.0][rng.gen_range(4)];
    let ds = SyntheticSpec {
        name: format!("prop-{seed}"),
        n,
        d,
        avg_nnz: avg.min(d as f64),
        zipf_exponent: rng.gen_f64() * 1.3,
        label_noise: rng.gen_f64() * 0.1,
        wstar_density: 0.1 + rng.gen_f64() * 0.5,
        seed,
    }
    .generate();
    (ds, c)
}

#[test]
fn prop_dcd_dual_monotone_and_feasible() {
    for seed in 0..12u64 {
        let (ds, c) = random_dataset(seed);
        let loss = Hinge::new(c);
        let mut duals = Vec::new();
        let mut cb = |p: &passcode::solver::Progress<'_>| {
            duals.push(eval::dual_objective(&ds, &loss, p.alpha));
            p.alpha.iter().all(|&a| (-1e-9..=c + 1e-9).contains(&a))
        };
        let r = SerialDcd::solve(
            &ds,
            &loss,
            &SolveOptions { epochs: 6, eval_every: 1, seed, ..Default::default() },
            Some(&mut cb),
        );
        assert_eq!(r.epochs_run, 6, "seed {seed}: callback aborted (infeasible α)");
        for w in duals.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "seed {seed}: dual increased {duals:?}");
        }
    }
}

#[test]
fn prop_duality_gap_nonnegative_all_losses() {
    for seed in 0..8u64 {
        let (ds, c) = random_dataset(seed + 100);
        fn check<L: Loss>(ds: &Dataset, loss: &L, seed: u64) {
            let r = SerialDcd::solve(
                ds,
                loss,
                &SolveOptions { epochs: 4, seed, ..Default::default() },
                None,
            );
            let gap = eval::duality_gap(ds, loss, &r.alpha);
            assert!(gap >= -1e-7, "seed {seed} loss {}: gap {gap}", loss.name());
        }
        check(&ds, &Hinge::new(c), seed);
        check(&ds, &SquaredHinge::new(c), seed);
        check(&ds, &Logistic::new(c), seed);
    }
}

#[test]
fn prop_serial_eq3_exact_consistency() {
    // Serial (and 1-thread parallel) runs must keep ŵ = Σ α_i x_i.
    for seed in 0..10u64 {
        let (ds, c) = random_dataset(seed + 200);
        let loss = Hinge::new(c);
        let r = Passcode::solve(
            &ds,
            &loss,
            MemoryModel::Wild,
            &SolveOptions { threads: 1, epochs: 4, seed, ..Default::default() },
            None,
        );
        let wbar = eval::wbar_from_alpha(&ds, &r.alpha);
        let err = r
            .w_hat
            .iter()
            .zip(&wbar)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max);
        assert!(err < 1e-9, "seed {seed}: Eq.3 error {err}");
    }
}

#[test]
fn prop_parallel_atomic_eq3_consistency() {
    for seed in 0..6u64 {
        let (ds, c) = random_dataset(seed + 300);
        let loss = Hinge::new(c);
        let r = Passcode::solve(
            &ds,
            &loss,
            MemoryModel::Atomic,
            &SolveOptions {
                threads: 4,
                epochs: 4,
                seed,
                eval_every: 1,
                ..Default::default()
            },
            None,
        );
        let wbar = eval::wbar_from_alpha(&ds, &r.alpha);
        let err = r
            .w_hat
            .iter()
            .zip(&wbar)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max);
        assert!(err < 1e-6, "seed {seed}: atomic Eq.3 error {err}");
    }
}

#[test]
fn prop_simulator_deterministic_and_conservative() {
    for seed in 0..6u64 {
        let (ds, c) = random_dataset(seed + 400);
        let loss = Hinge::new(c);
        let cfg = SimConfig {
            cores: 1 + (seed as usize % 12),
            epochs: 3,
            seed,
            cost: Default::default(),
            mechanism: if seed % 2 == 0 {
                Mechanism::Atomic
            } else {
                Mechanism::Wild
            },
            sockets: 1,
        };
        let a = simcore::simulate(&ds, &loss, &cfg);
        let b = simcore::simulate(&ds, &loss, &cfg);
        assert_eq!(a.alpha, b.alpha, "seed {seed}: nondeterministic sim");
        assert_eq!(a.virtual_ns, b.virtual_ns);
        // Conservation: atomic never loses writes; any mechanism keeps α
        // in the box.
        if cfg.mechanism == Mechanism::Atomic {
            assert_eq!(a.lost_writes, 0, "seed {seed}");
        }
        assert!(
            a.alpha.iter().all(|&v| (-1e-9..=c + 1e-9).contains(&v)),
            "seed {seed}: α outside box"
        );
        // Virtual time must not be shorter than perfect linear scaling.
        let serial = simcore::serial_reference_ns(
            &ds, &loss, 3, seed, &cfg.cost,
        );
        assert!(
            a.virtual_ns * (cfg.cores as f64) >= serial * 0.7,
            "seed {seed}: superlinear speedup {} cores {}x",
            cfg.cores,
            serial / a.virtual_ns
        );
    }
}

#[test]
fn prop_subproblem_never_worsens_dual() {
    // For random (α, wx, q) the solved subproblem value is never worse
    // than staying put: D(α_new) ≤ D(α_old) along the coordinate.
    let mut rng = Pcg32::new(77, 0);
    for case in 0..500 {
        let c = 0.1 + rng.gen_f64() * 3.0;
        let q = 0.05 + rng.gen_f64() * 2.0;
        let wx = rng.gen_normal() * 2.0;
        let obj = |loss_cn: &dyn Fn(f64) -> f64, a0: f64, a: f64| {
            let delta = a - a0;
            0.5 * q * delta * delta + wx * delta + loss_cn(a)
        };
        // hinge
        let h = Hinge::new(c);
        let a0 = rng.gen_f64() * c;
        let a1 = h.solve_subproblem(a0, wx, q);
        let f = |a: f64| h.conjugate_neg(a);
        assert!(
            obj(&f, a0, a1) <= obj(&f, a0, a0) + 1e-12,
            "case {case}: hinge subproblem worsened"
        );
        // squared hinge
        let s = SquaredHinge::new(c);
        let a0 = rng.gen_f64() * 2.0 * c;
        let a1 = s.solve_subproblem(a0, wx, q);
        let g = |a: f64| s.conjugate_neg(a);
        assert!(
            obj(&g, a0, a1) <= obj(&g, a0, a0) + 1e-12,
            "case {case}: sq-hinge subproblem worsened"
        );
        // logistic
        let l = Logistic::new(c);
        let a0 = l.project(rng.gen_f64() * c);
        let a1 = l.solve_subproblem(a0, wx, q);
        let k = |a: f64| l.conjugate_neg(a);
        assert!(
            obj(&k, a0, a1) <= obj(&k, a0, a0) + 1e-9,
            "case {case}: logistic subproblem worsened"
        );
    }
}

#[test]
fn prop_json_roundtrip_random_documents() {
    // Random JSON documents serialize → parse → identical.
    fn random_json(rng: &mut Pcg32, depth: usize) -> Json {
        match if depth == 0 { rng.gen_range(4) } else { rng.gen_range(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.gen_f64() < 0.5),
            2 => Json::Num((rng.gen_normal() * 100.0 * 64.0).round() / 64.0),
            3 => Json::Str(
                (0..rng.gen_range(12))
                    .map(|_| {
                        let opts = ['a', 'ß', '"', '\\', '\n', '☃', 'z'];
                        opts[rng.gen_range(opts.len())]
                    })
                    .collect(),
            ),
            4 => Json::Arr(
                (0..rng.gen_range(4))
                    .map(|_| random_json(rng, depth - 1))
                    .collect(),
            ),
            _ => Json::Obj(
                (0..rng.gen_range(4))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    let mut rng = Pcg32::new(123, 5);
    for case in 0..200 {
        let doc = random_json(&mut rng, 3);
        let compact = Json::parse(&doc.to_string());
        let pretty = Json::parse(&doc.to_pretty());
        assert_eq!(compact.unwrap(), doc, "case {case} (compact)");
        assert_eq!(pretty.unwrap(), doc, "case {case} (pretty)");
    }
}

#[test]
fn prop_failure_injection_empty_rows_and_degenerate_data() {
    // Datasets with empty rows, all-same-label, single-feature rows must
    // not panic any solver and must keep invariants.
    use passcode::data::{CsrMatrix, Entry};
    for seed in 0..5u64 {
        let mut rng = Pcg32::new(seed, 1);
        let n = 30;
        let d = 10;
        let rows: Vec<Vec<Entry>> = (0..n)
            .map(|_| {
                if rng.gen_f64() < 0.2 {
                    vec![] // empty row (nnz = 0)
                } else {
                    let j = rng.gen_range(d) as u32;
                    vec![Entry { index: j, value: rng.gen_normal() }]
                }
            })
            .collect();
        let x = CsrMatrix::from_rows(&rows, d);
        let y: Vec<f64> = (0..n)
            .map(|i| if i % 5 == 0 { -1.0 } else { 1.0 })
            .collect();
        let ds = Dataset::new(x, y, format!("degenerate-{seed}"));
        let loss = Hinge::new(1.0);
        for model in [MemoryModel::Lock, MemoryModel::Atomic, MemoryModel::Wild]
        {
            let r = Passcode::solve(
                &ds,
                &loss,
                model,
                &SolveOptions {
                    threads: 3,
                    epochs: 3,
                    seed,
                    eval_every: 1,
                    ..Default::default()
                },
                None,
            );
            assert!(r.alpha.iter().all(|v| v.is_finite()));
            assert!(r.w_hat.iter().all(|v| v.is_finite()));
            let gap = eval::duality_gap(&ds, &loss, &r.alpha);
            assert!(gap >= -1e-9, "seed {seed} {model:?}: gap {gap}");
        }
    }
}
