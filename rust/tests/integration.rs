//! Cross-module integration tests: driver ↔ registry ↔ solvers ↔ eval ↔
//! metrics ↔ config files ↔ LIBSVM files ↔ simulator.

use passcode::coordinator::{driver, experiments, RunConfig, SolverKind};
use passcode::data::{libsvm, registry};
use passcode::eval;
use passcode::loss::Hinge;
use passcode::simcore::{self, Mechanism, SimConfig};
use passcode::solver::{MemoryModel, SerialDcd, Solver, SolveOptions};
use passcode::util::Json;

#[test]
fn full_run_emits_consistent_metrics_and_csv() {
    let cfg = RunConfig {
        dataset: "news20".into(),
        scale: 0.1,
        solver: SolverKind::Passcode(MemoryModel::Atomic),
        threads: 3,
        epochs: 6,
        eval_every: 2,
        ..Default::default()
    };
    let out = driver::run(&cfg).unwrap();
    assert_eq!(out.metrics.rows.len(), 3);
    // CSV round trip: header + 3 rows; primal column is decreasing.
    let csv = out.metrics.to_csv();
    let rows: Vec<&str> = csv.trim().lines().skip(1).collect();
    assert_eq!(rows.len(), 3);
    let primals: Vec<f64> = rows
        .iter()
        .map(|r| r.split(',').nth(3).unwrap().parse().unwrap())
        .collect();
    assert!(primals.windows(2).all(|w| w[1] <= w[0] + 1e-6), "{primals:?}");
    let last = out.metrics.final_row().unwrap();
    assert!((last.epoch) == 6);
    assert!(last.gap >= -1e-9);
}

#[test]
fn config_file_round_trip_drives_runs() {
    let dir = std::env::temp_dir().join("passcode_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("cfg.json");
    let cfg = RunConfig {
        dataset: "rcv1".into(),
        scale: 0.02,
        solver: SolverKind::Cocoa,
        epochs: 4,
        threads: 2,
        eval_every: 0,
        ..Default::default()
    };
    std::fs::write(&path, cfg.to_json().to_pretty()).unwrap();
    let loaded = RunConfig::from_file(path.to_str().unwrap()).unwrap();
    assert_eq!(loaded.solver, SolverKind::Cocoa);
    assert_eq!(loaded.epochs, 4);
    let out = driver::run(&loaded).unwrap();
    assert!(out.primal_final.is_finite());
}

#[test]
fn libsvm_file_to_trained_model() {
    // Write a registry dataset to LIBSVM, reload through the data_path
    // entry, train, and check accuracy survives the round trip.
    let (tr, _, _) = registry::load("rcv1", 0.02).unwrap();
    let dir = std::env::temp_dir().join("passcode_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("rcv1_small.svm");
    libsvm::save(&tr, &path).unwrap();

    let cfg = RunConfig {
        data_path: Some(path.to_str().unwrap().to_string()),
        c: Some(1.0),
        solver: SolverKind::Dcd,
        epochs: 10,
        eval_every: 0,
        ..Default::default()
    };
    let out = driver::run(&cfg).unwrap();
    assert!(out.acc_what > 0.6, "round-tripped accuracy {}", out.acc_what);
}

#[test]
fn simulator_and_real_solver_agree_on_final_objective() {
    // Same dataset, same epoch budget: the DES (8 virtual cores) and the
    // real threaded solver (4 threads, barriers) must land on primal
    // objectives within a few percent of each other — they run the same
    // algorithm, differing only in interleaving.
    let (tr, _, c) = registry::load("rcv1", 0.05).unwrap();
    let loss = Hinge::new(c);
    let epochs = 15;
    let sim = simcore::simulate(
        &tr,
        &loss,
        &SimConfig {
            cores: 8,
            epochs,
            seed: 3,
            cost: Default::default(),
            mechanism: Mechanism::Atomic, sockets: 1, },
    );
    let p_sim = eval::primal_objective(&tr, &loss, &sim.w);
    let real = passcode::solver::Passcode::solve(
        &tr,
        &loss,
        MemoryModel::Atomic,
        &SolveOptions {
            threads: 4,
            epochs,
            eval_every: 1,
            ..Default::default()
        },
        None,
    );
    let p_real = eval::primal_objective(&tr, &loss, &real.w_hat);
    assert!(
        (p_sim - p_real).abs() < 0.03 * p_real.abs(),
        "sim {p_sim} vs real {p_real}"
    );
}

#[test]
fn serial_solvers_agree_across_entry_points() {
    // A `lookup("dcd")` session driven directly vs the driver's registry
    // path, same seed → identical objective (both run the same derived
    // per-epoch streams); the legacy inherent solve lands in the same
    // converged neighbourhood.
    let (tr, _, c) = registry::load("news20", 0.05).unwrap();
    let loss = Hinge::new(c);
    let epochs = 15;
    let solver = passcode::solver::lookup("dcd").unwrap();
    let mut session = solver
        .session(
            &tr,
            passcode::loss::LossKind::Hinge,
            c,
            SolveOptions { epochs, seed: 42, ..Default::default() },
        )
        .unwrap();
    session.run_epochs(epochs).unwrap();
    let direct = session.into_result();
    let cfg = RunConfig {
        dataset: "news20".into(),
        scale: 0.05,
        solver: SolverKind::Dcd,
        epochs,
        seed: 42,
        eval_every: 0,
        ..Default::default()
    };
    let out = driver::run(&cfg).unwrap();
    let p_direct = eval::primal_objective(&tr, &loss, &direct.w_hat);
    assert!((out.primal_final - p_direct).abs() < 1e-9);

    let legacy = SerialDcd::solve(
        &tr,
        &loss,
        &SolveOptions { epochs, seed: 42, ..Default::default() },
        None,
    );
    let p_legacy = eval::primal_objective(&tr, &loss, &legacy.w_hat);
    assert!(
        (p_direct - p_legacy).abs() < 0.03 * p_legacy.abs().max(1.0),
        "session path {p_direct} vs legacy {p_legacy}"
    );
}

#[test]
fn experiments_backward_error_consistent_with_wild_run() {
    let be = experiments::backward_error("rcv1", 0.02, 10, 4).unwrap();
    assert!(be.eps_norm.is_finite() && be.w_norm > 0.0);
    // The perturbed-problem residual with ŵ should be comparable to (not
    // wildly worse than) the unperturbed residual with w̄ — Theorem 3.
    assert!(be.perturbed_residual < be.unperturbed_residual + 1.0);
}

#[test]
fn metrics_json_parseable_and_labeled() {
    let cfg = RunConfig {
        dataset: "rcv1".into(),
        scale: 0.02,
        epochs: 4,
        eval_every: 2,
        threads: 2,
        ..Default::default()
    };
    let out = driver::run(&cfg).unwrap();
    let j = out.metrics.to_json().to_pretty();
    let parsed = Json::parse(&j).unwrap();
    assert_eq!(
        parsed.get("label").unwrap().as_str().unwrap(),
        "passcode-wild"
    );
    assert_eq!(parsed.get("rows").unwrap().as_arr().unwrap().len(), 2);
}

#[test]
fn table2_shape_what_tracks_liblinear() {
    // Scale 0.1 keeps the test splits big enough that accuracy noise
    // (±1/√n_test) stays under the tolerance band.
    let (_, rows) = experiments::table2(0.1, 10).unwrap();
    assert_eq!(rows.len(), 10); // 5 datasets × 2 thread counts
    for r in &rows {
        assert!(
            (r.acc_liblinear - r.acc_what).abs() < 0.08,
            "{}@{}: ŵ {} vs LIBLINEAR {}",
            r.dataset,
            r.threads,
            r.acc_what,
            r.acc_liblinear
        );
    }
}
