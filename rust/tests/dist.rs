//! Integration tests for the distributed tier (`dist/`): shard
//! manifests, the 2-worker loopback simulation against single-process
//! PASSCoDe-Atomic, and worker kill/rejoin through a real coordinator.
//!
//! The acceptance properties (ISSUE 8):
//!
//! * a 2-worker `dist-sim` run reaches an objective within 1e-3 of the
//!   single-process PASSCoDe-Atomic solution on the same (synthetic
//!   registry) dataset;
//! * a worker killed mid-run and rejoined from its checkpoint neither
//!   stalls the coordinator nor corrupts the merged `w` — the merge
//!   epoch stays monotonic, the cluster invariant `w = Σ_p X_pᵀ α_p`
//!   holds, and the final model still converges.

use std::sync::Arc;

use passcode::data::registry;
use passcode::data::shard::{extract, plan_ranges, ShardManifest};
use passcode::dist::{
    DistClient, DistCoordinator, DistWorker, MergeConfig, SimConfig, WorkerConfig,
};
use passcode::eval;
use passcode::loss::{DynLoss, LossKind};
use passcode::net::{Router, Server, ServerConfig};
use passcode::solver::{lookup, Solver, SolveOptions};

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("passcode_dist_it").join(tag);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn manifest_round_trips_through_disk_and_slices_shards() {
    let dir = tmp_dir("manifest");
    let path = dir.join("shards.json");
    let m = ShardManifest::for_registry("rcv1", 0.02, 3).unwrap();
    m.save(&path).unwrap();
    let back = ShardManifest::load(&path).unwrap();
    assert_eq!(back, m);

    // The shards partition the training rows exactly, in order.
    let (train, _, _) = registry::load("rcv1", 0.02).unwrap();
    assert_eq!(back.n, train.n());
    let mut rows = 0;
    for (i, r) in back.shards.iter().enumerate() {
        assert_eq!(r.start, rows, "shard {i} not contiguous");
        rows = r.end;
        let shard = back.load_shard(i).unwrap();
        assert_eq!(shard.n(), r.len());
        assert_eq!(shard.d(), train.d());
        // First row of the shard is the matching global row.
        if !r.is_empty() {
            let (li, lv) = shard.x.row(0);
            let (gi, gv) = train.x.row(r.start);
            assert_eq!((li, lv), (gi, gv));
        }
    }
    assert_eq!(rows, train.n());
}

#[test]
fn two_worker_sim_matches_single_process_atomic() {
    // Equal epoch budget: 2 workers × 20 rounds × 2 epochs locally vs
    // 40 single-process epochs over the full dataset (both far past
    // convergence on the tiny registry sample, so the 1e-3 objective
    // tolerance is a property of the merge math, not of luck).
    let rounds = 20;
    let epochs_per_round = 2;
    let sim = passcode::dist::run_sim(&SimConfig {
        dataset: "rcv1".into(),
        scale: 0.02,
        workers: 2,
        rounds,
        epochs_per_round,
        solver: "passcode-atomic".into(),
        max_lag: 8,
        ..Default::default()
    })
    .unwrap();

    let (train, _, c) = registry::load("rcv1", 0.02).unwrap();
    let mut single = lookup("passcode-atomic")
        .unwrap()
        .session(&train, LossKind::Hinge, c, SolveOptions {
            epochs: rounds * epochs_per_round,
            ..Default::default()
        })
        .unwrap();
    single.run_epochs(rounds * epochs_per_round).unwrap();

    let loss = DynLoss::new(LossKind::Hinge, c);
    let p_single = eval::primal_objective(&train, &loss, single.w_hat());
    let gap_single = eval::duality_gap(&train, &loss, single.alpha());

    assert!(sim.merge_epoch > 0, "no merges happened");
    assert!(sim.w.iter().all(|v| v.is_finite()), "merged w has non-finite entries");
    assert!(
        (sim.primal - p_single).abs() <= 1e-3 * p_single.abs().max(1.0),
        "distributed primal {} vs single-process {}",
        sim.primal,
        p_single
    );
    assert!(
        sim.gap <= gap_single + 1e-3 * p_single.abs().max(1.0),
        "distributed gap {} vs single-process gap {}",
        sim.gap,
        gap_single
    );
    // The dist metric family must be live after a run.
    assert!(
        sim.dist_metrics.iter().any(|l| l.starts_with("passcode_dist_merges_total")),
        "missing merge counter in {:?}",
        sim.dist_metrics
    );
}

#[test]
fn killed_worker_rejoins_without_stalling_or_corrupting() {
    let dir = tmp_dir("rejoin");
    let ckpt = dir.join("shard1.ckpt");
    std::fs::remove_file(&ckpt).ok();

    let (train, _, c) = registry::load("rcv1", 0.02).unwrap();
    let ranges = plan_ranges(train.n(), 2);
    let shards: Vec<_> = ranges.iter().map(|r| extract(&train, r)).collect();
    let coord = Arc::new(DistCoordinator::new(
        vec![0.0; train.d()],
        MergeConfig { workers: 2, max_lag: 16, c, ..Default::default() },
    ));
    let server = Server::start(
        Router::empty().with_dist(Arc::clone(&coord)),
        &ServerConfig::default(),
    )
    .unwrap();
    let addr = server.addr();

    let wcfg = |id: u64, rounds: usize, checkpoint| WorkerConfig {
        id,
        c,
        rounds,
        epochs_per_round: 2,
        checkpoint,
        ..Default::default()
    };

    // Worker 0 runs its full budget up front.
    let mut client0 = DistClient::new(addr);
    let mut w0 = DistWorker::new(&shards[0], wcfg(0, 10, None)).unwrap();
    w0.run(&mut client0, None).unwrap();
    let epoch_after_w0 = coord.pull().0;
    assert!(epoch_after_w0 > 0);

    // Worker 1 does 3 rounds, checkpointing, then is "killed" (dropped).
    let mut client1 = DistClient::new(addr);
    {
        let mut w1 = DistWorker::new(&shards[1], wcfg(1, 3, Some(ckpt.clone()))).unwrap();
        w1.run(&mut client1, None).unwrap();
    }
    let epoch_mid = coord.pull().0;
    assert!(epoch_mid > epoch_after_w0, "worker 1 rounds did not merge");
    assert!(ckpt.exists(), "worker 1 left no checkpoint");

    // Rejoin: a brand-new worker 1 resumes its dual block from the
    // checkpoint, pulls the current merged w, and finishes its budget —
    // the coordinator needed no special handling for the dropout.
    let mut w1 = DistWorker::new(&shards[1], wcfg(1, 7, Some(ckpt.clone()))).unwrap();
    let report = w1.run(&mut client1, None).unwrap();
    assert_eq!(report.rounds, 7);
    let (epoch_final, w) = coord.pull();
    assert!(epoch_final > epoch_mid, "merge epoch must stay monotonic");
    assert!(w.iter().all(|v| v.is_finite()), "merged w corrupted");

    // Cluster invariant: the merged w is exactly the transpose-dot of
    // the concatenated committed duals (both workers ran 1 thread, so
    // there is no within-shard async write loss either).
    let mut alpha = w0.alpha().to_vec();
    alpha.extend_from_slice(w1.alpha());
    let wbar = train.x.transpose_dot(&alpha);
    let num = w
        .iter()
        .zip(&wbar)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        .sqrt();
    let den = w.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-12);
    assert!(num / den < 1e-8, "w = sum_p X_p^T alpha_p violated: {}", num / den);

    // And the final model converged: the duality gap shrank far below
    // its alpha = 0 starting value P(0) = C·n.
    let loss = DynLoss::new(LossKind::Hinge, c);
    let gap = eval::duality_gap(&train, &loss, &alpha);
    let gap0 = c * train.n() as f64;
    assert!(gap.is_finite() && gap < 0.1 * gap0, "gap {gap} vs initial {gap0}");

    server.shutdown();
    std::fs::remove_file(&ckpt).ok();
}
