//! Integration tests for the online scoring subsystem: registry ↔
//! batcher ↔ scorer ↔ online trainer ↔ replay harness.
//!
//! The acceptance property under test: hot-swapping a model mid-replay
//! (published by the online trainer) never blocks scorers and never
//! drops a request.  Every wait uses a generous timeout so a dropped
//! request fails the test instead of hanging it.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use passcode::coordinator::model_io::Model;
use passcode::data::registry as data_registry;
use passcode::eval;
use passcode::loss::LossKind;
use passcode::serve::{
    self, Batcher, ModelRegistry, OnlineConfig, OnlineTrainer, ReplayConfig,
    ScorerConfig, ServeConfig, ServeEngine, ServeStats, ShardPool,
};

const WAIT: Duration = Duration::from_secs(60);

fn toy_model(w: Vec<f64>) -> Model {
    Model {
        w,
        loss: "hinge".into(),
        c: 1.0,
        solver: "test".into(),
        dataset: "toy".into(),
    }
}

#[test]
fn hot_swap_mid_stream_never_blocks_or_drops() {
    // A publisher hammers the registry with hot-swaps while requests
    // stream through a 2-shard pool.  Every request must come back
    // (none dropped), scorers must keep making progress throughout
    // (never blocked by a publish), and each response must carry a
    // coherent model version.
    let d = 32;
    let registry = Arc::new(ModelRegistry::new(toy_model(vec![1.0; d]), None));
    let batcher = Arc::new(Batcher::new(8, Duration::from_micros(100)));
    let stats = Arc::new(ServeStats::new(2));
    let pool = ShardPool::start(
        Arc::clone(&registry),
        Arc::clone(&batcher),
        Arc::clone(&stats),
        &ScorerConfig { shards: 2, pin_threads: false },
    );

    let publishes = 50u64;
    let stop = Arc::new(AtomicBool::new(false));
    let publisher = {
        let registry = Arc::clone(&registry);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            // Version e serves w = e+1 everywhere, so margin/(d·x) tells
            // us which version scored a request.
            for e in 1..=publishes {
                registry.publish(toy_model(vec![(e + 1) as f64; d]), None);
                if stop.load(Ordering::Acquire) {
                    break;
                }
                std::thread::yield_now();
            }
        })
    };

    let n = 500usize;
    let tickets: Vec<_> = (0..n)
        .map(|i| batcher.submit(vec![(i % d) as u32], vec![1.0]))
        .collect();
    let mut received = 0usize;
    for t in tickets {
        let p = t.wait_timeout(WAIT).expect("request dropped under hot-swap");
        // Internally consistent scoring: version epoch e has w ≡ e+1.
        assert_eq!(
            p.margin,
            (p.model_epoch + 1) as f64,
            "torn model read at epoch {}",
            p.model_epoch
        );
        received += 1;
    }
    assert_eq!(received, n, "scorers dropped requests");
    stop.store(true, Ordering::Release);
    publisher.join().unwrap();
    batcher.close();
    pool.join();
    assert_eq!(stats.total_requests(), n as u64);
    assert_eq!(stats.latency.count(), n as u64);
}

#[test]
fn microbatcher_coalesces_under_load() {
    // Queue everything first, then start the pool: shards must drain in
    // full batches, so the batch counter stays well under the request
    // count.
    let registry = Arc::new(ModelRegistry::new(toy_model(vec![1.0; 4]), None));
    let batcher = Arc::new(Batcher::new(16, Duration::from_micros(50)));
    let stats = Arc::new(ServeStats::new(1));
    let n = 64usize;
    let tickets: Vec<_> =
        (0..n).map(|i| batcher.submit(vec![(i % 4) as u32], vec![1.0])).collect();
    let pool = ShardPool::start(
        registry,
        Arc::clone(&batcher),
        Arc::clone(&stats),
        &ScorerConfig { shards: 1, pin_threads: false },
    );
    for t in tickets {
        assert!(t.wait_timeout(WAIT).is_some(), "request dropped");
    }
    batcher.close();
    pool.join();
    let report = stats.report();
    assert_eq!(report.requests, n as u64);
    assert_eq!(report.batches, 4, "64 queued requests / batch cap 16");
    assert!((report.avg_batch - 16.0).abs() < 1e-9);
}

#[test]
fn online_trainer_publishes_while_engine_serves() {
    // Continuous-training loop against a live ServeEngine: scoring
    // traffic flows while the trainer ingests labeled rows and
    // hot-swaps retrained models into the same registry.
    let (tr, te, c) = data_registry::load("rcv1", 0.02).unwrap();
    let cold = Model {
        w: vec![0.0; tr.d()],
        loss: "hinge".into(),
        c,
        solver: "cold".into(),
        dataset: "rcv1".into(),
    };
    let engine = ServeEngine::start(
        cold,
        None,
        &ServeConfig {
            shards: 2,
            max_batch: 32,
            max_wait: Duration::from_micros(100),
            pin_threads: false,
        },
    );
    let trainer = Arc::new(OnlineTrainer::new(
        Arc::clone(engine.registry()),
        LossKind::Hinge,
        c,
        OnlineConfig {
            epochs_per_round: 3,
            max_window: tr.n(),
            ..Default::default()
        },
    ));

    // Stream labeled training rows in while traffic is being scored
    // (raw_row unfolds the stored x = y·ẋ).
    let mut tickets = Vec::new();
    for i in 0..tr.n() {
        let (idx, raw) = tr.raw_row(i);
        trainer.ingest(idx, raw, tr.y[i]);
        if i % 50 == 0 {
            let (tidx, traw) = te.raw_row(i % te.n());
            tickets.push(engine.submit(tidx, traw));
        }
    }
    for _ in 0..3 {
        assert!(trainer.train_round().is_some());
    }
    for t in tickets {
        assert!(t.wait_timeout(WAIT).is_some(), "request dropped");
    }
    assert_eq!(engine.registry().epoch(), 3);
    // The published model actually learned something.
    let live = engine.registry().current();
    let acc = eval::accuracy(&te, &live.model.w);
    assert!(acc > 0.7, "online-trained model accuracy {acc}");
    let report = engine.shutdown();
    assert!(report.requests > 0);
    assert!(report.p50_secs <= report.p95_secs);
    assert!(report.p95_secs <= report.p99_secs);
}

#[test]
fn online_round_stops_at_deadline_without_losing_dual_state() {
    // The acceptance run for deadline-bounded retraining: round 1 (ample
    // budget) accumulates real dual state; round 2 gets a deadline that
    // has already passed and a huge epoch budget — it must return
    // promptly, publish, and carry the accumulated (α, ŵ) through
    // unchanged instead of resetting or losing it.
    use std::time::Instant;

    let (tr, _, c) = data_registry::load("rcv1", 0.02).unwrap();
    let cold = Model {
        w: vec![0.0; tr.d()],
        loss: "hinge".into(),
        c,
        solver: "cold".into(),
        dataset: "rcv1".into(),
    };
    let registry = Arc::new(ModelRegistry::new(cold, None));
    let trainer = OnlineTrainer::new(
        Arc::clone(&registry),
        LossKind::Hinge,
        c,
        OnlineConfig {
            epochs_per_round: 1_000_000, // deadline is the real bound
            max_window: tr.n(),
            ..Default::default()
        },
    );
    for i in 0..tr.n() {
        let (idx, raw) = tr.raw_row(i);
        trainer.ingest(idx, raw, tr.y[i]);
    }

    // Round 1: a generous deadline; the million-epoch budget must not
    // matter — the round returns when its wall-clock budget runs out.
    let t0 = Instant::now();
    let epoch = trainer
        .train_round_with_deadline(Instant::now() + Duration::from_millis(200))
        .expect("non-empty window must publish");
    assert_eq!(epoch, 1);
    assert!(
        t0.elapsed() < Duration::from_secs(60),
        "round ignored its deadline: {:?}",
        t0.elapsed()
    );
    let v1 = registry.current();
    let alpha1 = v1.alpha.clone().expect("published dual state");
    assert!(
        alpha1.iter().any(|&a| a != 0.0),
        "round 1 accumulated no dual state"
    );

    // Round 2: the deadline has already passed — zero epochs run, and
    // the publish must carry the accumulated state through bit-for-bit.
    let t1 = Instant::now();
    let epoch = trainer
        .train_round_with_deadline(Instant::now())
        .expect("deadline-expired round still publishes");
    assert_eq!(epoch, 2);
    assert!(
        t1.elapsed() < Duration::from_secs(10),
        "expired deadline still trained: {:?}",
        t1.elapsed()
    );
    let v2 = registry.current();
    assert_eq!(
        v2.alpha.as_ref().expect("dual state republished"),
        &alpha1,
        "deadline-bounded round lost accumulated dual state"
    );
    assert_eq!(
        v2.model.w, v1.model.w,
        "zero-epoch round must not perturb the model"
    );
}

#[test]
fn replay_serves_heldout_split_with_hot_swaps() {
    // The acceptance-criteria run: replay a held-out split through the
    // batcher/scorer at 4 shards with mid-replay hot-swaps published by
    // the online trainer; nothing may be dropped and the report must
    // carry QPS + ordered latency percentiles.
    let cfg = ReplayConfig {
        dataset: "rcv1".into(),
        scale: 0.05,
        shards: 4,
        train_epochs: 8,
        train_threads: 2,
        online_rounds: 3,
        online_epochs: 1,
        max_batch: 32,
        max_wait: Duration::from_micros(100),
        pin_threads: false,
        seed: 42,
    };
    let (_, te, _) = data_registry::load(&cfg.dataset, cfg.scale).unwrap();
    let rep = serve::replay(&cfg).unwrap();

    // Never drops a request: every held-out row was scored exactly once.
    assert_eq!(rep.requests, te.n() as u64);
    assert_eq!(rep.throughput.requests, rep.requests);

    // The online trainer hot-swapped mid-replay...
    assert_eq!(rep.swaps, 3, "expected one publish per online round");
    // ...and the tail of the stream was scored by the newest model
    // (requests submitted after a publish must see it: registry reads
    // are monotone across the submit→score handoff).
    assert_eq!(rep.epoch_max, rep.swaps);
    assert!(rep.epoch_min <= rep.epoch_max);

    // Throughput/latency report is coherent.
    assert!(rep.throughput.qps > 0.0);
    assert!(rep.throughput.p50_secs <= rep.throughput.p95_secs);
    assert!(rep.throughput.p95_secs <= rep.throughput.p99_secs);
    assert!(rep.throughput.avg_batch >= 1.0);
    assert!(rep.accuracy > 0.6, "served accuracy {}", rep.accuracy);
}

#[test]
fn replay_scales_across_shard_counts() {
    // The bench harness shape (1/2/4 shards) must hold its invariants
    // at every width — same requests scored, nothing dropped.
    for shards in [1usize, 2, 4] {
        let cfg = ReplayConfig {
            scale: 0.02,
            shards,
            train_epochs: 4,
            online_rounds: 1,
            online_epochs: 1,
            ..Default::default()
        };
        let rep = serve::replay(&cfg).unwrap();
        assert_eq!(rep.throughput.shards, shards);
        assert_eq!(rep.throughput.requests, rep.requests);
        assert_eq!(rep.swaps, 1);
    }
}
