//! Integration tests for the `net` front end: a real
//! `TcpListener`-backed server with two routes, driven by the
//! self-contained HTTP client over loopback.
//!
//! The acceptance property: routes are isolated serving universes —
//! a batch scored on route A is unaffected by hot-swap publishes on
//! route B (distinct registries, queues, shard pools), while both
//! serve concurrent keep-alive clients without dropping a request.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use passcode::coordinator::model_io::Model;
use passcode::net::{HttpClient, Router, RoutesConfig, Server, ServerConfig};

const D: usize = 8;

fn toy_model(tag: f64) -> Model {
    Model {
        w: vec![tag; D],
        loss: "hinge".into(),
        c: 1.0,
        solver: "test".into(),
        dataset: "toy".into(),
    }
}

/// Two-route server over loopback: route `a` serves w ≡ 1, route `b`
/// serves w ≡ 2.  Returns the server and the temp dir for model files.
fn two_route_server(tag: &str, workers: usize) -> (Server, std::path::PathBuf) {
    let dir = std::env::temp_dir().join("passcode_net_it").join(tag);
    std::fs::create_dir_all(&dir).unwrap();
    let path_a = dir.join("a.json");
    let path_b = dir.join("b.json");
    toy_model(1.0).save(&path_a).unwrap();
    toy_model(2.0).save(&path_b).unwrap();
    let cfg = RoutesConfig::from_json_text(&format!(
        r#"{{"routes": [
            {{"name": "a", "model": {:?}, "shards": 2, "max_wait_us": 100}},
            {{"name": "b", "model": {:?}, "shards": 2, "max_wait_us": 100}}
        ]}}"#,
        path_a.to_str().unwrap(),
        path_b.to_str().unwrap(),
    ))
    .unwrap();
    let server = Server::start(
        Router::start(&cfg).unwrap(),
        &ServerConfig {
            addr: "127.0.0.1:0".into(),
            workers,
            ..Default::default()
        },
    )
    .unwrap();
    (server, dir)
}

fn score_one(client: &mut HttpClient, route: &str, idx: u32) -> (f64, u64) {
    let resp = client
        .score(route, &(vec![idx], vec![1.0]))
        .unwrap()
        .ok()
        .unwrap();
    let j = resp.json().unwrap();
    let p = &j.get("predictions").unwrap().as_arr().unwrap()[0];
    (
        p.get("margin").unwrap().as_f64().unwrap(),
        p.get("model_epoch").unwrap().as_usize().unwrap() as u64,
    )
}

#[test]
fn route_a_unaffected_by_hot_swaps_on_route_b() {
    let (server, dir) = two_route_server("isolation", 4);
    let addr = server.addr();

    // The model a publisher will hammer into route b.
    let path_b5 = dir.join("b5.json");
    toy_model(5.0).save(&path_b5).unwrap();
    let publish_body =
        format!("{{\"path\": {:?}}}", path_b5.to_str().unwrap());

    let stop = Arc::new(AtomicBool::new(false));
    let a_requests = Arc::new(AtomicU64::new(0));

    std::thread::scope(|s| {
        // Route-a scorer: batches of 4 rows, continuously, on one
        // keep-alive connection.  Every response must be scored by
        // epoch 0 with w ≡ 1 — publishes on b must never leak in.
        {
            let stop = Arc::clone(&stop);
            let a_requests = Arc::clone(&a_requests);
            s.spawn(move || {
                let mut client = HttpClient::new(addr);
                let body = br#"{"rows": [
                    {"idx": [0], "vals": [1.0]},
                    {"idx": [1, 2], "vals": [1.0, 1.0]},
                    {"idx": [3], "vals": [-2.0]},
                    {"idx": [0, 7], "vals": [0.5, 0.5]}
                ]}"#;
                let want = [1.0, 2.0, -2.0, 1.0];
                while !stop.load(Ordering::Acquire) {
                    let resp = client
                        .request("POST", "/v1/score?route=a", "application/json", body)
                        .unwrap()
                        .ok()
                        .unwrap();
                    let j = resp.json().unwrap();
                    let preds = j.get("predictions").unwrap().as_arr().unwrap();
                    assert_eq!(preds.len(), 4);
                    for (p, w) in preds.iter().zip(want) {
                        assert_eq!(
                            p.get("margin").unwrap().as_f64().unwrap(),
                            w,
                            "route a scored by a foreign model"
                        );
                        assert_eq!(
                            p.get("model_epoch").unwrap().as_usize().unwrap(),
                            0,
                            "route a saw an epoch bump from b's publishes"
                        );
                    }
                    a_requests.fetch_add(4, Ordering::Relaxed);
                }
            });
        }

        // Publisher: 20 hot-swaps on route b over HTTP, interleaved
        // with scores proving b actually swapped.
        let mut client = HttpClient::new(addr);
        assert_eq!(score_one(&mut client, "b", 0), (2.0, 0));
        for round in 1..=20u64 {
            let resp = client
                .request(
                    "POST",
                    "/v1/models/b/publish",
                    "application/json",
                    publish_body.as_bytes(),
                )
                .unwrap()
                .ok()
                .unwrap();
            let epoch = resp
                .json()
                .unwrap()
                .get("epoch")
                .unwrap()
                .as_usize()
                .unwrap() as u64;
            assert_eq!(epoch, round);
            let (margin, seen_epoch) = score_one(&mut client, "b", 0);
            assert_eq!(margin, 5.0, "publish did not land on b");
            assert_eq!(seen_epoch, round, "b served a stale epoch");
        }
        // Let the a-scorer overlap the publish storm a little longer
        // (bounded wait: a panicked scorer must fail the test, not
        // wedge it — the scope join below rethrows its panic).
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
        while a_requests.load(Ordering::Relaxed) < 40
            && std::time::Instant::now() < deadline
        {
            std::thread::yield_now();
        }
        stop.store(true, Ordering::Release);
    });

    let scored_on_a = a_requests.load(Ordering::Relaxed);
    assert!(scored_on_a >= 40, "route-a scorer made no progress");

    // Server-side observability agrees: a is untouched at epoch 0 with
    // one live version; b holds 21 versions at epoch 20.
    let mut client = HttpClient::new(addr);
    let stats = client.get("/v1/stats").unwrap().ok().unwrap().json().unwrap();
    let routes = stats.get("routes").unwrap();
    let a = routes.get("a").unwrap();
    let b = routes.get("b").unwrap();
    assert_eq!(a.get("epoch").unwrap().as_usize().unwrap(), 0);
    assert_eq!(a.get("versions_alive").unwrap().as_usize().unwrap(), 1);
    assert_eq!(b.get("epoch").unwrap().as_usize().unwrap(), 20);
    assert_eq!(b.get("versions_alive").unwrap().as_usize().unwrap(), 21);
    // Every row the a-scorer got an answer for was counted by a's own
    // engine (the publisher's probes all went to b).
    assert_eq!(
        a.get("requests").unwrap().as_usize().unwrap() as u64,
        scored_on_a
    );

    let reports = server.shutdown();
    assert_eq!(reports.len(), 2);
}

#[test]
fn concurrent_keep_alive_clients_across_routes() {
    let (server, _) = two_route_server("concurrent", 4);
    let addr = server.addr();
    let per_client = 50usize;
    std::thread::scope(|s| {
        for t in 0..4usize {
            s.spawn(move || {
                let route = if t % 2 == 0 { "a" } else { "b" };
                let want = if t % 2 == 0 { 1.0 } else { 2.0 };
                let mut client = HttpClient::new(addr);
                for i in 0..per_client {
                    let mut c = HttpClient::new(addr);
                    // Alternate between a shared keep-alive connection
                    // and a fresh one (exercises both paths).
                    let cl = if i % 10 == 9 { &mut c } else { &mut client };
                    let (margin, epoch) =
                        score_one(cl, route, (i % D) as u32);
                    assert_eq!(margin, want, "client {t} row {i}");
                    assert_eq!(epoch, 0);
                }
            });
        }
    });
    let reports = server.shutdown();
    let total: u64 = reports.iter().map(|(_, r)| r.requests).sum();
    assert_eq!(total, 4 * per_client as u64, "dropped requests");
}

#[test]
fn protocol_surface_over_socket() {
    let (server, _) = two_route_server("protocol", 2);
    let addr = server.addr();
    let mut client = HttpClient::new(addr);

    // Liveness + route listing.
    let health = client.get("/healthz").unwrap().ok().unwrap().json().unwrap();
    assert_eq!(health.get("status").unwrap().as_str().unwrap(), "ok");
    let names: Vec<String> = health
        .get("routes")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_str().unwrap().to_string())
        .collect();
    assert_eq!(names, vec!["a", "b"]);

    // LIBSVM body with labels: accuracy comes back (w ≡ 1 ⇒ both rows
    // score +1; the -1 labeled row is wrong).
    let resp = client
        .request(
            "POST",
            "/v1/score?route=a",
            "text/plain",
            b"+1 1:1.0\n-1 2:1.0\n",
        )
        .unwrap()
        .ok()
        .unwrap();
    let j = resp.json().unwrap();
    assert_eq!(j.get("accuracy").unwrap().as_f64().unwrap(), 0.5);

    // Error surface: unknown route, missing selector with two routes,
    // malformed body, unknown path, wrong method.
    let cases: &[(&str, &str, &str, u16)] = &[
        ("POST", "/v1/score?route=ghost", r#"{"idx":[0],"vals":[1.0]}"#, 404),
        ("POST", "/v1/score", r#"{"idx":[0],"vals":[1.0]}"#, 400),
        ("POST", "/v1/score?route=a", "{ not json", 400),
        ("POST", "/v1/score?route=a", r#"{"idx":[2,1],"vals":[1.0,1.0]}"#, 400),
        ("GET", "/v1/score", "", 405),
        ("GET", "/nope", "", 404),
        ("POST", "/v1/models/ghost/publish", r#"{"path":"x"}"#, 404),
        ("POST", "/v1/models/a/publish", r#"{"nope": 1}"#, 400),
    ];
    for (method, path, body, want) in cases {
        let resp = client
            .request(method, path, "application/json", body.as_bytes())
            .unwrap();
        assert_eq!(resp.status, *want, "{method} {path}");
    }

    // The connection survived all of the above (keep-alive).
    let (margin, _) = score_one(&mut client, "b", 3);
    assert_eq!(margin, 2.0);
    server.shutdown();
}
