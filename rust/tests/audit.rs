//! Acceptance tests for the static audit (`passcode::audit`):
//!
//! * each of the six rule families fires on a known-bad inline fixture,
//!   at the exact rule id and line the fixture plants;
//! * the shipped tree itself scans **clean** with an empty baseline
//!   (the audit's headline guarantee — this test is the tree's
//!   tamper-proofing);
//! * reports round-trip through the repo's JSON and baselines suppress
//!   by identity, not line number.
//!
//! This file is listed in `audit::policy::WIRE_REF_EXEMPT_FILES`: the
//! fixture snippets below deliberately contain violating tokens.

use passcode::audit::{self, policy, AuditConfig, AuditReport};
use passcode::audit::scan::SourceFile;
use passcode::util::Json;

/// Run the rule passes over one fixture file (fixture mode: whole-tree
/// presence checks off).
fn scan_one(path: &str, src: &str) -> Vec<audit::Finding> {
    let files = vec![SourceFile::from_source(path, src)];
    audit::audit_sources(&files, &[], &[], false)
}

#[test]
fn rule_atomic_ordering_fires_at_the_planted_line() {
    let src = "fn f(a: &std::sync::atomic::AtomicBool) {\n\
               \x20   a.store(true, Ordering::SeqCst);\n\
               }\n";
    let got = scan_one("src/net/server.rs", src);
    assert_eq!(got.len(), 1, "{got:?}");
    assert_eq!(got[0].rule, policy::RULE_ATOMIC);
    assert_eq!(got[0].line, 2);
    assert!(!got[0].hint.is_empty());
}

#[test]
fn rule_lock_discipline_fires_at_the_planted_line() {
    let src = "fn f() {}\n\
               use std::sync::Mutex;\n";
    let got = scan_one("src/data/shard.rs", src);
    assert_eq!(got.len(), 1, "{got:?}");
    assert_eq!(got[0].rule, policy::RULE_LOCK);
    assert_eq!(got[0].line, 2);
}

#[test]
fn rule_hot_path_alloc_fires_at_the_planted_line() {
    let src = "fn f() {\n\
               \x20   // audit: hot-path begin\n\
               \x20   let v = vec![0.0f64; 4];\n\
               \x20   // audit: hot-path end\n\
               \x20   drop(v);\n\
               }\n";
    let got = scan_one("src/solver/dcd.rs", src);
    assert_eq!(got.len(), 1, "{got:?}");
    assert_eq!(got[0].rule, policy::RULE_HOTPATH);
    assert_eq!(got[0].line, 3);
}

#[test]
fn rule_unsafe_containment_fires_at_the_planted_line() {
    let src = "fn f(v: &[f64]) -> f64 {\n\
               \x20   unsafe { *v.get_unchecked(0) }\n\
               }\n";
    let got = scan_one("src/serve/batcher.rs", src);
    // Both halves of the rule: non-whitelisted module + missing SAFETY.
    assert_eq!(got.len(), 2, "{got:?}");
    assert!(got.iter().all(|f| f.rule == policy::RULE_UNSAFE));
    assert!(got.iter().all(|f| f.line == 2));
}

#[test]
fn rule_probe_gating_fires_at_the_planted_line() {
    let src = "fn worker() {\n\
               \x20   crate::obs::probes::solver().updates.inc();\n\
               }\n";
    let got = scan_one("src/baselines/asyscd.rs", src);
    assert_eq!(got.len(), 1, "{got:?}");
    assert_eq!(got[0].rule, policy::RULE_PROBE);
    assert_eq!(got[0].line, 2);
}

#[test]
fn rule_wire_consistency_fires_at_the_planted_line() {
    let a = SourceFile::from_source(
        "src/dist/protocol.rs",
        "pub const MAGIC: &str = \"PDL1\";\n",
    );
    let b = SourceFile::from_source(
        "src/dist/worker.rs",
        "fn hdr() -> &'static str { \"PDL1\" }\n",
    );
    let got = audit::audit_sources(&[a, b], &[], &[], false);
    assert_eq!(got.len(), 1, "{got:?}");
    assert_eq!(got[0].rule, policy::RULE_WIRE);
    assert_eq!(got[0].file, "src/dist/worker.rs");
    assert_eq!(got[0].line, 1);
}

#[test]
fn exemption_comments_suppress_per_site() {
    let src = "// audit: allow(seqcst) — fixture: measuring fence cost\n\
               fn f(a: &std::sync::atomic::AtomicBool) {\n\
               \x20   a.store(true, Ordering::SeqCst);\n\
               }\n";
    assert!(scan_one("src/net/server.rs", src).is_empty());
}

/// The headline guarantee: the tree this test ships in is audit-clean
/// with an *empty* baseline, across the full scan (src + tests + docs,
/// all presence checks on).
#[test]
fn shipped_tree_is_audit_clean_with_empty_baseline() {
    let cfg = AuditConfig {
        root: std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")),
        smoke: false,
    };
    let (files_scanned, findings) = audit::run_audit(&cfg).unwrap();
    assert!(files_scanned > 50, "suspiciously small scan: {files_scanned}");
    let report = AuditReport::new(files_scanned, findings, None);
    assert!(
        report.ok,
        "shipped tree must be audit-clean:\n{}",
        report.render()
    );
    assert_eq!(report.baselined, 0);
}

/// Smoke mode still scans src/ and still passes.
#[test]
fn smoke_scan_is_clean_too() {
    let cfg = AuditConfig {
        root: std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")),
        smoke: true,
    };
    let (_, findings) = audit::run_audit(&cfg).unwrap();
    assert!(findings.is_empty(), "{findings:?}");
}

#[test]
fn report_roundtrips_and_baselines_by_identity() {
    let src = "fn f(a: &std::sync::atomic::AtomicBool) {\n\
               \x20   a.store(true, Ordering::SeqCst);\n\
               }\n";
    let findings = scan_one("src/net/server.rs", src);
    let report = AuditReport::new(1, findings.clone(), None);
    assert!(!report.ok);

    let text = report.to_json().to_pretty();
    let back = AuditReport::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(back, report);

    // The same finding at a different line is baselined (identity is
    // rule+file+message); a different message is not.
    let mut moved = findings.clone();
    moved[0].line = 77;
    let suppressed = AuditReport::new(1, moved, Some(&back));
    assert!(suppressed.ok);
    assert_eq!(suppressed.baselined, 1);

    let mut other = findings;
    other[0].message = "something new".to_string();
    let fresh = AuditReport::new(1, other, Some(&back));
    assert!(!fresh.ok);
}
