//! Property tests for the unified solver API (`solver::api`): for every
//! registry entry, a session that is snapshotted after `k` epochs and
//! resumed must match an uninterrupted run to `n` epochs — bit-for-bit
//! for deterministic (single-worker) configurations, to objective
//! tolerance for genuinely parallel ones — and resuming from a zeroed
//! checkpoint must equal a cold start.

use passcode::data::registry as data_registry;
use passcode::data::Dataset;
use passcode::eval;
use passcode::loss::{DynLoss, LossKind};
use passcode::solver::{
    lookup, solver_names, Checkpoint, Solver, SolveOptions, StopWhen,
};

/// Small dataset every solver (including AsySCD's dense-Q guard) accepts.
fn tiny() -> (Dataset, f64) {
    let (tr, _, c) = data_registry::load("news20", 0.05).unwrap();
    (tr, c)
}

fn opts(threads: usize, epochs: usize) -> SolveOptions {
    SolveOptions { threads, epochs, seed: 7, ..Default::default() }
}

#[test]
fn registry_covers_the_family_and_lists_names_on_error() {
    let names = solver_names();
    for expect in [
        "dcd",
        "liblinear",
        "passcode-lock",
        "passcode-atomic",
        "passcode-wild",
        "cocoa",
        "asyscd",
        "pegasos",
    ] {
        assert!(names.contains(&expect), "registry missing {expect}");
        assert_eq!(lookup(expect).unwrap().name(), expect);
    }
    let err = format!("{:#}", lookup("sgd").unwrap_err());
    for name in &names {
        assert!(err.contains(name), "unknown-solver error must list {name}");
    }
}

#[test]
fn snapshot_resume_is_bit_exact_for_deterministic_sessions() {
    // threads = 1 makes every backend deterministic (single worker), so
    // chunked and uninterrupted session runs must agree exactly.
    let (tr, c) = tiny();
    let (k, n) = (2usize, 5usize);
    for name in solver_names() {
        let solver = lookup(name).unwrap();

        let mut full =
            solver.session(&tr, LossKind::Hinge, c, opts(1, n)).unwrap();
        full.run_epochs(n).unwrap();

        let mut first =
            solver.session(&tr, LossKind::Hinge, c, opts(1, n)).unwrap();
        first.run_epochs(k).unwrap();
        let ckpt = first.snapshot();
        assert_eq!(ckpt.solver, name);
        assert_eq!(ckpt.epochs_done, k);

        let mut second =
            solver.session(&tr, LossKind::Hinge, c, opts(1, n)).unwrap();
        second.resume(&ckpt).unwrap();
        second.run_epochs(n - k).unwrap();

        assert_eq!(second.epochs(), full.epochs(), "{name}: epoch count");
        assert_eq!(
            second.updates(),
            full.updates(),
            "{name}: update count diverged"
        );
        assert_eq!(second.alpha(), full.alpha(), "{name}: α diverged");
        assert_eq!(second.w_hat(), full.w_hat(), "{name}: ŵ diverged");
    }
}

#[test]
fn snapshot_resume_matches_parallel_runs_to_objective_tolerance() {
    let (tr, c) = tiny();
    let loss = DynLoss::new(LossKind::Hinge, c);
    let (k, n) = (3usize, 8usize);
    for name in ["passcode-atomic", "passcode-wild", "cocoa"] {
        let solver = lookup(name).unwrap();

        let mut full =
            solver.session(&tr, LossKind::Hinge, c, opts(3, n)).unwrap();
        full.run_epochs(n).unwrap();

        let mut first =
            solver.session(&tr, LossKind::Hinge, c, opts(3, n)).unwrap();
        first.run_epochs(k).unwrap();
        let ckpt = first.snapshot();
        let mut second =
            solver.session(&tr, LossKind::Hinge, c, opts(3, n)).unwrap();
        second.resume(&ckpt).unwrap();
        second.run_epochs(n - k).unwrap();

        let p_full = eval::primal_objective(&tr, &loss, full.w_hat());
        let p_chunked = eval::primal_objective(&tr, &loss, second.w_hat());
        assert!(
            (p_full - p_chunked).abs() < 0.02 * p_full.abs().max(1.0),
            "{name}: chunked P = {p_chunked} vs uninterrupted P = {p_full}"
        );
    }
}

#[test]
fn resume_from_zeroed_checkpoint_equals_cold_solve() {
    let (tr, c) = tiny();
    for name in solver_names() {
        let solver = lookup(name).unwrap();

        let mut cold =
            solver.session(&tr, LossKind::Hinge, c, opts(1, 4)).unwrap();
        cold.run_epochs(4).unwrap();

        let mut warm =
            solver.session(&tr, LossKind::Hinge, c, opts(1, 4)).unwrap();
        warm.resume(&Checkpoint::zeroed(
            name,
            "hinge",
            c,
            7,
            tr.n(),
            tr.d(),
        ))
        .unwrap();
        warm.run_epochs(4).unwrap();

        assert_eq!(warm.alpha(), cold.alpha(), "{name}: α diverged");
        assert_eq!(warm.w_hat(), cold.w_hat(), "{name}: ŵ diverged");
    }
}

#[test]
fn sessions_make_progress_for_every_solver() {
    // Not just self-consistent: each session must actually learn (beat
    // the trivial w = 0 primal objective).
    let (tr, c) = tiny();
    let loss = DynLoss::new(LossKind::Hinge, c);
    let p_zero = eval::primal_objective(&tr, &loss, &vec![0.0; tr.d()]);
    for name in solver_names() {
        let solver = lookup(name).unwrap();
        let mut s =
            solver.session(&tr, LossKind::Hinge, c, opts(2, 6)).unwrap();
        s.run_epochs(6).unwrap();
        let p = eval::primal_objective(&tr, &loss, s.w_hat());
        assert!(
            p < p_zero,
            "{name}: no progress (P = {p} vs zero-model {p_zero})"
        );
        assert!(s.alpha().iter().all(|a| a.is_finite()), "{name}: α junk");
        assert!(s.w_hat().iter().all(|w| w.is_finite()), "{name}: ŵ junk");
    }
}

#[test]
fn pegasos_session_rejects_non_hinge_and_asyscd_guards_memory() {
    let (tr, c) = tiny();
    let err = lookup("pegasos")
        .unwrap()
        .session(&tr, LossKind::Logistic, c, opts(1, 2))
        .err()
        .expect("pegasos must reject non-hinge losses");
    assert!(format!("{err:#}").contains("hinge"), "{err:#}");

    // A deliberately tiny Q budget trips the guard at session open.
    let tight = passcode::baselines::Asyscd {
        q_budget: 1024,
        ..Default::default()
    };
    let err = tight
        .session(&tr, LossKind::Hinge, c, opts(1, 2))
        .err()
        .expect("dense-Q guard must fire at session open");
    assert!(format!("{err:#}").contains("Hessian"), "{err:#}");
}

#[test]
fn deadline_bounded_run_preserves_state_and_stops() {
    let (tr, c) = tiny();
    let solver = lookup("passcode-wild").unwrap();
    let mut s =
        solver.session(&tr, LossKind::Hinge, c, opts(2, 1_000_000)).unwrap();
    s.run_epochs(2).unwrap();
    let alpha_before = s.alpha().to_vec();

    // Deadline already passed: zero epochs, state untouched.
    let r = s
        .run_until(StopWhen::Deadline(std::time::Instant::now()))
        .unwrap();
    assert_eq!(r.epochs_run, 0);
    assert_eq!(s.alpha(), &alpha_before[..]);

    // A short real deadline: returns promptly despite the huge epoch cap.
    let t0 = std::time::Instant::now();
    let deadline = t0 + std::time::Duration::from_millis(50);
    s.run_until(StopWhen::Deadline(deadline)).unwrap();
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(30),
        "deadline-bounded run did not stop: {:?}",
        t0.elapsed()
    );
    assert!(s.epochs() >= 2, "accumulated state lost");
}
