//! Thread affinity (paper §3.3 "Thread Affinity").
//!
//! The paper binds each worker to a physical core (libnuma) to avoid
//! remote-socket access.  We implement the same with raw
//! `sched_setaffinity`; on hosts with fewer cores than workers the pin
//! wraps modulo the online-core count (graceful on this 1-core image,
//! faithful on a real multi-socket box).

/// Number of CPUs currently online.
pub fn online_cpus() -> usize {
    // SAFETY: sysconf is always safe to call.
    let n = unsafe { libc::sysconf(libc::_SC_NPROCESSORS_ONLN) };
    if n <= 0 {
        1
    } else {
        n as usize
    }
}

/// Pin the calling thread to core `core % online_cpus()`.
///
/// Returns the core actually pinned to, or `None` if the kernel refused
/// (e.g. restricted cpuset) — callers treat that as a soft failure.
pub fn pin_current_thread(core: usize) -> Option<usize> {
    let n = online_cpus();
    let target = core % n;
    // SAFETY: CPU_* only write into the local cpu_set_t.
    unsafe {
        let mut set: libc::cpu_set_t = std::mem::zeroed();
        libc::CPU_ZERO(&mut set);
        libc::CPU_SET(target, &mut set);
        let rc = libc::sched_setaffinity(
            0, // current thread
            std::mem::size_of::<libc::cpu_set_t>(),
            &set,
        );
        if rc == 0 {
            Some(target)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_cpus_is_positive() {
        assert!(online_cpus() >= 1);
    }

    #[test]
    fn pin_wraps_modulo_core_count() {
        // Must not error out even when `core` exceeds the host's count.
        let got = pin_current_thread(1_000_003);
        if let Some(c) = got {
            assert!(c < online_cpus());
        }
    }

    #[test]
    fn pin_core_zero_succeeds() {
        assert_eq!(pin_current_thread(0), Some(0));
    }
}
