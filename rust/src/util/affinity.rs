//! Thread affinity (paper §3.3 "Thread Affinity").
//!
//! The paper binds each worker to a physical core (libnuma) to avoid
//! remote-socket access.  We implement the same with raw
//! `sched_setaffinity`, declared directly against the platform C library
//! so the crate carries no `libc` dependency (the offline image vendors
//! only `anyhow`); on hosts with fewer cores than workers the pin wraps
//! modulo the online-core count (graceful on a 1-core image, faithful on
//! a real multi-socket box).

/// Linux `cpu_set_t`: a 1024-bit mask (16 × u64).
#[cfg(target_os = "linux")]
type CpuSet = [u64; 16];

#[cfg(target_os = "linux")]
extern "C" {
    /// `int sched_setaffinity(pid_t pid, size_t cpusetsize, const cpu_set_t *mask)`
    fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
}

/// Number of CPUs currently usable by this process.
pub fn online_cpus() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Pin the calling thread to core `core % online_cpus()`.
///
/// Returns the core actually pinned to, or `None` if the kernel refused
/// (e.g. restricted cpuset) — callers treat that as a soft failure.
#[cfg(target_os = "linux")]
pub fn pin_current_thread(core: usize) -> Option<usize> {
    let n = online_cpus();
    let target = core % n;
    let mut set: CpuSet = [0; 16];
    if target / 64 >= set.len() {
        return None; // beyond the 1024-cpu mask
    }
    set[target / 64] |= 1u64 << (target % 64);
    // SAFETY: the mask is a valid, fully initialized cpu_set_t-sized
    // buffer owned by this frame; pid 0 addresses the calling thread.
    let rc = unsafe {
        sched_setaffinity(0, std::mem::size_of::<CpuSet>(), set.as_ptr())
    };
    if rc == 0 {
        Some(target)
    } else {
        None
    }
}

/// Non-Linux hosts: affinity is a soft no-op.
#[cfg(not(target_os = "linux"))]
pub fn pin_current_thread(_core: usize) -> Option<usize> {
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_cpus_is_positive() {
        assert!(online_cpus() >= 1);
    }

    #[test]
    fn pin_wraps_modulo_core_count() {
        // Must not error out even when `core` exceeds the host's count.
        let got = pin_current_thread(1_000_003);
        if let Some(c) = got {
            assert!(c < online_cpus());
        }
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn pin_core_zero_succeeds() {
        assert_eq!(pin_current_thread(0), Some(0));
    }
}
