//! Minimal JSON reader/writer (no serde in the offline image).
//!
//! Covers exactly what the repo needs: the AOT `manifest.json`, experiment
//! config files, and metric dumps.  Numbers are f64, objects preserve
//! insertion order (deterministic output for golden tests).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (always an f64, like JavaScript).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// BTreeMap: deterministic key order on output.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document (trailing data is an error).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------
    /// The value as a number, or an error naming what it actually is.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => Err(anyhow!("expected number, got {other:?}")),
        }
    }

    /// The value as a non-negative integer (rejects fractions).
    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("expected non-negative integer, got {f}");
        }
        Ok(f as usize)
    }

    /// The value as a string slice.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(anyhow!("expected string, got {other:?}")),
        }
    }

    /// The value as a bool.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(anyhow!("expected bool, got {other:?}")),
        }
    }

    /// The value as an array slice.
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => Err(anyhow!("expected array, got {other:?}")),
        }
    }

    /// The value as an object map.
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Ok(o),
            other => Err(anyhow!("expected object, got {other:?}")),
        }
    }

    /// `obj["key"]` with a useful error.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    /// Optional lookup.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => o.get(key),
            _ => None,
        }
    }

    // ---- construction helpers --------------------------------------------
    /// An object from `(key, value)` pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// A number value.
    pub fn num(v: f64) -> Json {
        Json::Num(v)
    }

    /// A string value.
    pub fn str(v: &str) -> Json {
        Json::Str(v.to_string())
    }

    /// An array of numbers.
    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        let pad = "  ".repeat(depth + 1);
        let pad_end = "  ".repeat(depth);
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    v.write_pretty(out, depth + 1);
                }
                let _ = write!(out, "\n{pad_end}]");
            }
            Json::Obj(o) if !o.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                }
                let _ = write!(out, "\n{pad_end}}}");
            }
            other => other.write(out),
        }
    }
}

/// Compact serialization (and `to_string()` via the blanket `ToString`).
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!(
                "expected {:?} at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek()? as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| {
            anyhow!("bad number {s:?} at byte {start}: {e}")
        })?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| anyhow!("bad codepoint"))?,
                            );
                        }
                        other => bail!("bad escape \\{}", other as char),
                    }
                }
                c => {
                    // Re-walk UTF-8: collect continuation bytes.
                    let len = utf8_len(c);
                    let bytes = &self.b[self.i - 1..self.i - 1 + len];
                    self.i += len - 1;
                    s.push_str(std::str::from_utf8(bytes)?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                c => bail!("expected , or ] found {:?}", c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                c => bail!("expected , or }} found {:?}", c as char),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -3.5e2 ").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap().as_bool().unwrap(), false);
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"k":[1,2.5,"s"],"m":{"x":null}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        assert_eq!(Json::parse(&v.to_pretty()).unwrap(), v);
    }

    #[test]
    fn unicode_strings_roundtrip() {
        let v = Json::parse("\"héllo ☃ \\u00e9\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ☃ é");
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn errors_are_reported() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn as_usize_validates() {
        assert_eq!(Json::Num(5.0).as_usize().unwrap(), 5);
        assert!(Json::Num(5.5).as_usize().is_err());
        assert!(Json::Num(-1.0).as_usize().is_err());
    }

    #[test]
    fn manifest_like_document_parses() {
        let text = r#"{
          "artifacts": {
            "margins_block": {"file": "margins_block.hlo.txt",
                               "inputs": [[256,512],[512,1]],
                               "outputs": [[256,1]], "dtype": "f32"}
          },
          "feat_block": 512, "format": "hlo-text", "row_block": 256
        }"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("row_block").unwrap().as_usize().unwrap(), 256);
        let inputs = v
            .get("artifacts").unwrap()
            .get("margins_block").unwrap()
            .get("inputs").unwrap();
        assert_eq!(
            inputs.as_arr().unwrap()[0].as_arr().unwrap()[1]
                .as_usize()
                .unwrap(),
            512
        );
    }
}
