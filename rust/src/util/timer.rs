//! Wall-clock timing helpers and a tiny hierarchical stopwatch.
//!
//! The paper's timing protocol (Section 5.2) includes initialization in
//! end-to-end timings but *excludes* it from speedup computations; the
//! [`Phases`] stopwatch records named phases so benches can report both.

use std::time::{Duration, Instant};

/// One-shot stopwatch.
#[derive(Debug, Clone, Copy)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Named phase accumulator (init / train / eval …).
#[derive(Debug, Default, Clone)]
pub struct Phases {
    entries: Vec<(String, f64)>,
}

impl Phases {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under `name`, accumulating across calls.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t = Timer::start();
        let out = f();
        self.add(name, t.secs());
        out
    }

    /// Add `secs` to phase `name`.
    pub fn add(&mut self, name: &str, secs: f64) {
        if let Some(e) = self.entries.iter_mut().find(|(n, _)| n == name) {
            e.1 += secs;
        } else {
            self.entries.push((name.to_string(), secs));
        }
    }

    pub fn get(&self, name: &str) -> f64 {
        self.entries
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, s)| *s)
            .unwrap_or(0.0)
    }

    pub fn total(&self) -> f64 {
        self.entries.iter().map(|(_, s)| s).sum()
    }

    pub fn entries(&self) -> &[(String, f64)] {
        &self.entries
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_measures_time() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(10));
        assert!(t.secs() >= 0.009);
    }

    #[test]
    fn phases_accumulate() {
        let mut p = Phases::new();
        p.add("init", 1.0);
        p.add("train", 2.0);
        p.add("init", 0.5);
        assert_eq!(p.get("init"), 1.5);
        assert_eq!(p.get("train"), 2.0);
        assert_eq!(p.get("missing"), 0.0);
        assert!((p.total() - 3.5).abs() < 1e-12);
    }

    #[test]
    fn phases_time_closure() {
        let mut p = Phases::new();
        let v = p.time("work", || {
            std::thread::sleep(Duration::from_millis(5));
            42
        });
        assert_eq!(v, 42);
        assert!(p.get("work") > 0.004);
    }
}
