//! Small statistics helpers for the bench harness (no criterion in the
//! offline image): median/mean/stddev, min, and a repeat-runner that
//! reports them.

/// Summary statistics over a sample of measurements.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub median: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
                / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let median = if n % 2 == 1 {
            sorted[n / 2]
        } else {
            0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
        };
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            median,
            max: sorted[n - 1],
        }
    }
}

/// Run `f` `reps` times (after `warmup` unmeasured runs) and summarize
/// the wall-clock seconds.
pub fn bench_secs(warmup: usize, reps: usize, mut f: impl FnMut()) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = super::timer::Timer::start();
        f();
        samples.push(t.secs());
    }
    Summary::of(&samples)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_values() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.median - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        // sample std of 1..4 = sqrt(5/3)
        assert!((s.std - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_single_sample() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.median, 7.0);
    }

    #[test]
    fn odd_median() {
        let s = Summary::of(&[5.0, 1.0, 3.0]);
        assert_eq!(s.median, 3.0);
    }

    #[test]
    fn bench_runs_expected_times() {
        let mut count = 0;
        let s = bench_secs(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.n, 5);
    }
}
