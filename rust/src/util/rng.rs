//! Deterministic pseudo-random number generation.
//!
//! The offline image vendors no `rand` crate, so the repo carries its own
//! generators: [`Pcg32`] (O'Neill's PCG-XSH-RR 64/32) for the solver hot
//! paths and [`SplitMix64`] for seeding.  Determinism matters here beyond
//! hygiene: every experiment in EXPERIMENTS.md is reproducible from a seed,
//! and the multicore simulator requires replayable per-core streams.

/// SplitMix64: fast 64-bit generator used to derive seeds / stream ids.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSH-RR 64/32: small, fast, statistically solid; one independent
/// stream per (seed, stream) pair — each solver thread / virtual core gets
/// its own stream id.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    const MULT: u64 = 6364136223846793005;

    /// Create a generator for `(seed, stream)`; distinct streams are
    /// statistically independent.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed ^ stream.wrapping_mul(0xA3EC647659359ACD));
        let inc = (sm.next_u64() << 1) | 1;
        let mut rng = Self { state: sm.next_u64(), inc };
        rng.next_u32();
        rng
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(Self::MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform integer in `[0, bound)` via Lemire's unbiased method.
    #[inline]
    pub fn gen_range(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0 && bound <= u32::MAX as usize);
        let bound = bound as u32;
        let mut x = self.next_u32();
        let mut m = (x as u64) * (bound as u64);
        let mut l = m as u32;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (bound as u64);
                l = m as u32;
            }
        }
        (m >> 32) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53 random bits.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller (the solver only needs it for data
    /// synthesis, so the transcendental cost is irrelevant).
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.gen_f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// A fresh random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pcg_is_deterministic_per_seed_stream() {
        let mut a = Pcg32::new(42, 0);
        let mut b = Pcg32::new(42, 0);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn pcg_streams_differ() {
        let mut a = Pcg32::new(42, 0);
        let mut b = Pcg32::new(42, 1);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4, "streams nearly identical: {same}/64");
    }

    #[test]
    fn gen_range_is_in_bounds_and_covers() {
        let mut rng = Pcg32::new(7, 3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.gen_range(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "not all buckets hit: {seen:?}");
    }

    #[test]
    fn gen_f64_unit_interval_and_mean() {
        let mut rng = Pcg32::new(1, 1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = rng.gen_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn gen_normal_moments() {
        let mut rng = Pcg32::new(9, 0);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = rng.gen_normal();
            s1 += v;
            s2 += v * v;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut rng = Pcg32::new(3, 0);
        let p = rng.permutation(257);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..257).collect::<Vec<_>>());
    }

    #[test]
    fn shuffle_changes_order() {
        let mut rng = Pcg32::new(5, 5);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn splitmix_known_progression() {
        // Regression anchor: fixed seed must yield a stable stream.
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        let mut sm2 = SplitMix64::new(0);
        assert_eq!(a, sm2.next_u64());
    }
}
