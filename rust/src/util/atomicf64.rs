//! Shared-memory primal vector with the paper's three write disciplines,
//! laid out false-sharing-consciously.
//!
//! The heart of PASSCoDe is *how* `w ← w + Δα_i x_i` is written to shared
//! memory (Algorithm 2, step 3).  [`SharedVec`] stores `w` as
//! `AtomicU64`-encoded f64 and exposes exactly the three mechanisms:
//!
//! * [`SharedVec::add_atomic`] — a CAS loop (PASSCoDe-Atomic): no update
//!   is ever lost, but each write pays the RMW penalty.
//! * [`SharedVec::add_wild`] — relaxed load, add in register, relaxed
//!   store (PASSCoDe-Wild): compiles to plain loads/stores; concurrent
//!   writers can overwrite each other exactly like the paper's unguarded
//!   C++ `+=` (while staying defined behaviour in Rust — the data race of
//!   a literal non-atomic `+=` would be UB here, and `Relaxed` on x86 has
//!   identical codegen).
//! * reads are always plain relaxed loads ([`SharedVec::get`]) — all three
//!   variants read `w` without locks; only Lock additionally guards the
//!   *feature set* via [`crate::solver::locks::LockTable`].
//!
//! **Layout.** Cells are grouped into 64-byte cache-line-aligned blocks
//! ([`LINE_CELLS`] `AtomicU64`s per line), so the allocation starts on a
//! line boundary and no logical line ever straddles two hardware lines.
//! Whether two *features* share a line is then purely a function of their
//! index distance — which the feature-locality remap
//! ([`crate::data::FeatureRemap`]) exploits by packing high-document-
//! frequency features into the same few resident lines and pushing the
//! rarely-touched tail out of them (the memory-system effect Liu & Wright
//! 2015 identify as the async-CD scaling limiter).

use std::sync::atomic::{AtomicU64, Ordering};

/// `f64` cells per cache line (64 bytes / 8-byte cell).
pub const LINE_CELLS: usize = 8;
const LINE_SHIFT: u32 = 3;
const LINE_MASK: usize = LINE_CELLS - 1;

/// One cache line of atomically-accessed f64 bit patterns.  The `align`
/// guarantee is what makes [`SharedVec`] line-boundary-exact.
#[repr(align(64))]
struct Line {
    cells: [AtomicU64; LINE_CELLS],
}

impl Line {
    fn zeroed() -> Line {
        // f64 0.0 has an all-zero bit pattern.
        Line { cells: [0u64; LINE_CELLS].map(AtomicU64::new) }
    }
}

/// A fixed-size shared `f64` vector supporting lock-free concurrent
/// access, allocated in cache-line-aligned blocks.
pub struct SharedVec {
    lines: Vec<Line>,
    len: usize,
}

impl SharedVec {
    /// Zero-initialized vector of length `n`.
    pub fn zeros(n: usize) -> Self {
        let n_lines = (n + LINE_CELLS - 1) / LINE_CELLS;
        Self { lines: (0..n_lines).map(|_| Line::zeroed()).collect(), len: n }
    }

    /// Build from an existing slice.
    pub fn from_slice(v: &[f64]) -> Self {
        let out = Self::zeros(v.len());
        for (j, &x) in v.iter().enumerate() {
            out.set(j, x);
        }
        out
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The cell backing element `j`, bounds-checked against the logical
    /// length (the padding tail is not addressable).
    #[inline]
    fn cell(&self, j: usize) -> &AtomicU64 {
        assert!(j < self.len, "index {j} out of bounds (len {})", self.len);
        // SAFETY: `j < len ≤ lines.len() * LINE_CELLS` and `j & LINE_MASK
        // < LINE_CELLS` by construction.
        unsafe { self.cell_unchecked(j) }
    }

    /// The cell backing element `j`, no bounds check.
    ///
    /// # Safety
    /// `j` must be `< self.len()`.
    #[inline]
    unsafe fn cell_unchecked(&self, j: usize) -> &AtomicU64 {
        // SAFETY: the caller guarantees `j < self.len`, so `j >>
        // LINE_SHIFT < lines.len()` (lines cover `len` rounded up) and
        // `j & LINE_MASK < LINE_CELLS` by construction of the mask.
        unsafe {
            self.lines
                .get_unchecked(j >> LINE_SHIFT)
                .cells
                .get_unchecked(j & LINE_MASK)
        }
    }

    /// Relaxed read of element `j`.
    #[inline]
    pub fn get(&self, j: usize) -> f64 {
        f64::from_bits(self.cell(j).load(Ordering::Relaxed))
    }

    /// Relaxed read of element `j` without the bounds check — the fused
    /// kernels' gather, justified by the CSR construction invariant
    /// (column indices validated `< cols` once, at matrix build time).
    ///
    /// # Safety
    /// `j` must be `< self.len()`.
    #[inline]
    pub unsafe fn get_unchecked(&self, j: usize) -> f64 {
        // SAFETY: forwarded contract — the caller guarantees `j < len`.
        f64::from_bits(unsafe { self.cell_unchecked(j) }.load(Ordering::Relaxed))
    }

    /// Plain (relaxed) overwrite of element `j`.
    #[inline]
    pub fn set(&self, j: usize, v: f64) {
        self.cell(j).store(v.to_bits(), Ordering::Relaxed);
    }

    /// Lossless concurrent add via a compare-exchange loop
    /// (PASSCoDe-Atomic's step 3).
    #[inline]
    pub fn add_atomic(&self, j: usize, delta: f64) {
        Self::cas_add(self.cell(j), delta);
    }

    /// [`SharedVec::add_atomic`] without the bounds check.
    ///
    /// # Safety
    /// `j` must be `< self.len()`.
    #[inline]
    pub unsafe fn add_atomic_unchecked(&self, j: usize, delta: f64) {
        // SAFETY: forwarded contract — the caller guarantees `j < len`.
        Self::cas_add(unsafe { self.cell_unchecked(j) }, delta);
    }

    /// One initial load, then a pure CAS retry loop: on failure,
    /// `compare_exchange_weak` already hands back the current value, so
    /// the loop never re-loads the cell.
    ///
    /// All orderings are `Relaxed` deliberately.  PASSCoDe-Atomic only
    /// requires each `w_j += δ` to be *lossless on that one location*
    /// (no increment overwritten — the paper's Atomic model), which a
    /// single-cell RMW gives regardless of ordering; it never requires a
    /// write to `w_j` to *publish* other memory, and readers tolerate
    /// arbitrarily stale views of `w` (that is the staleness τ the
    /// convergence analysis charges for).  On x86-64 this compiles to
    /// `lock cmpxchg`, identical to a SeqCst version; on weaker ISAs
    /// Relaxed skips fences the algorithm does not need.
    #[inline]
    fn cas_add(cell: &AtomicU64, delta: f64) {
        let mut cur = cell.load(Ordering::Relaxed);
        loop {
            let new = (f64::from_bits(cur) + delta).to_bits();
            match cell.compare_exchange_weak(
                cur,
                new,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                // The failure value *is* the fresh load for the retry.
                // Contention telemetry rides the failure arm only, so
                // the uncontended success path is untouched.
                Err(actual) => {
                    crate::obs::probes::cas_retry_tick();
                    cur = actual;
                }
            }
        }
    }

    /// Racy read-modify-write (PASSCoDe-Wild's step 3): a concurrent
    /// writer between our load and store is silently overwritten — the
    /// memory-conflict behaviour analyzed by the paper's Theorem 3.
    #[inline]
    pub fn add_wild(&self, j: usize, delta: f64) {
        let cell = self.cell(j);
        let cur = f64::from_bits(cell.load(Ordering::Relaxed));
        cell.store((cur + delta).to_bits(), Ordering::Relaxed);
    }

    /// [`SharedVec::add_wild`] without the bounds check.
    ///
    /// # Safety
    /// `j` must be `< self.len()`.
    #[inline]
    pub unsafe fn add_wild_unchecked(&self, j: usize, delta: f64) {
        // SAFETY: forwarded contract — the caller guarantees `j < len`.
        let cell = unsafe { self.cell_unchecked(j) };
        let cur = f64::from_bits(cell.load(Ordering::Relaxed));
        cell.store((cur + delta).to_bits(), Ordering::Relaxed);
    }

    /// Snapshot into a plain `Vec<f64>` (evaluation path; not hot).
    pub fn to_vec(&self) -> Vec<f64> {
        (0..self.len()).map(|j| self.get(j)).collect()
    }

    /// Copy values out into an existing buffer (lengths must match) —
    /// the allocation-free sibling of [`SharedVec::to_vec`] used by
    /// `TrainSession`'s per-epoch sync.
    pub fn copy_into(&self, out: &mut [f64]) {
        assert_eq!(out.len(), self.len());
        for (j, slot) in out.iter_mut().enumerate() {
            *slot = self.get(j);
        }
    }

    /// Copy values in from a slice (lengths must match).
    pub fn copy_from(&self, v: &[f64]) {
        assert_eq!(v.len(), self.len());
        for (j, &x) in v.iter().enumerate() {
            self.set(j, x);
        }
    }
}

impl std::fmt::Debug for SharedVec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SharedVec(len={})", self.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn get_set_roundtrip() {
        let v = SharedVec::zeros(4);
        v.set(2, -3.25);
        assert_eq!(v.get(2), -3.25);
        assert_eq!(v.get(0), 0.0);
    }

    #[test]
    fn from_slice_and_to_vec() {
        let v = SharedVec::from_slice(&[1.0, 2.5, -7.0]);
        assert_eq!(v.to_vec(), vec![1.0, 2.5, -7.0]);
    }

    #[test]
    fn lines_are_cache_aligned_and_padding_is_not_addressable() {
        // Lengths that do not divide the line width still work, the
        // backing allocation is 64-byte aligned, and indexing past the
        // logical length panics even though padded cells exist.
        for n in [1usize, 7, 8, 9, 63, 64, 65] {
            let v = SharedVec::zeros(n);
            assert_eq!(v.len(), n);
            assert_eq!(v.lines.as_ptr() as usize % 64, 0, "len {n}");
            assert!(std::panic::catch_unwind(|| v.get(n)).is_err());
        }
    }

    #[test]
    fn copy_into_matches_to_vec() {
        let v = SharedVec::from_slice(&[3.0, -1.0, 0.5, 9.0]);
        let mut buf = vec![0.0; 4];
        v.copy_into(&mut buf);
        assert_eq!(buf, v.to_vec());
    }

    #[test]
    fn atomic_add_is_lossless_under_contention() {
        let v = Arc::new(SharedVec::zeros(1));
        let threads = 8;
        let per = if cfg!(miri) { 250 } else { 10_000 };
        std::thread::scope(|s| {
            for _ in 0..threads {
                let v = Arc::clone(&v);
                s.spawn(move || {
                    for _ in 0..per {
                        v.add_atomic(0, 1.0);
                    }
                });
            }
        });
        assert_eq!(v.get(0), (threads * per) as f64);
    }

    #[test]
    fn wild_add_single_thread_is_exact() {
        let v = SharedVec::zeros(1);
        for _ in 0..1000 {
            v.add_wild(0, 0.5);
        }
        assert_eq!(v.get(0), 500.0);
    }

    #[test]
    fn wild_add_may_lose_updates_but_never_corrupts() {
        // Under contention Wild can drop increments (that is the point of
        // the paper's backward-error analysis) but each stored value is a
        // valid f64 computed from a previously stored value: the final sum
        // is between one thread's total and the lossless total.
        let v = Arc::new(SharedVec::zeros(1));
        let threads = 4;
        let per = if cfg!(miri) { 500 } else { 50_000 };
        std::thread::scope(|s| {
            for _ in 0..threads {
                let v = Arc::clone(&v);
                s.spawn(move || {
                    for _ in 0..per {
                        v.add_wild(0, 1.0);
                    }
                });
            }
        });
        let total = v.get(0);
        assert!(total >= per as f64, "lost more than whole threads: {total}");
        assert!(total <= (threads * per) as f64);
        assert_eq!(total.fract(), 0.0, "corrupted value {total}");
    }

    #[test]
    fn copy_from_matches() {
        let v = SharedVec::zeros(3);
        v.copy_from(&[9.0, 8.0, 7.0]);
        assert_eq!(v.to_vec(), vec![9.0, 8.0, 7.0]);
    }
}
