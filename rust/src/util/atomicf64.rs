//! Shared-memory primal vector with the paper's three write disciplines.
//!
//! The heart of PASSCoDe is *how* `w ← w + Δα_i x_i` is written to shared
//! memory (Algorithm 2, step 3).  [`SharedVec`] stores `w` as
//! `AtomicU64`-encoded f64 and exposes exactly the three mechanisms:
//!
//! * [`SharedVec::add_atomic`] — a CAS loop (PASSCoDe-Atomic): no update
//!   is ever lost, but each write pays the RMW penalty.
//! * [`SharedVec::add_wild`] — relaxed load, add in register, relaxed
//!   store (PASSCoDe-Wild): compiles to plain loads/stores; concurrent
//!   writers can overwrite each other exactly like the paper's unguarded
//!   C++ `+=` (while staying defined behaviour in Rust — the data race of
//!   a literal non-atomic `+=` would be UB here, and `Relaxed` on x86 has
//!   identical codegen).
//! * reads are always plain relaxed loads ([`SharedVec::get`]) — all three
//!   variants read `w` without locks; only Lock additionally guards the
//!   *feature set* via [`crate::solver::locks::LockTable`].

use std::sync::atomic::{AtomicU64, Ordering};

/// A fixed-size shared `f64` vector supporting lock-free concurrent access.
pub struct SharedVec {
    bits: Vec<AtomicU64>,
}

impl SharedVec {
    /// Zero-initialized vector of length `n`.
    pub fn zeros(n: usize) -> Self {
        Self { bits: (0..n).map(|_| AtomicU64::new(0f64.to_bits())).collect() }
    }

    /// Build from an existing slice.
    pub fn from_slice(v: &[f64]) -> Self {
        Self { bits: v.iter().map(|&x| AtomicU64::new(x.to_bits())).collect() }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.bits.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.bits.is_empty()
    }

    /// Relaxed read of element `j`.
    #[inline]
    pub fn get(&self, j: usize) -> f64 {
        f64::from_bits(self.bits[j].load(Ordering::Relaxed))
    }

    /// Plain (relaxed) overwrite of element `j`.
    #[inline]
    pub fn set(&self, j: usize, v: f64) {
        self.bits[j].store(v.to_bits(), Ordering::Relaxed);
    }

    /// Lossless concurrent add via a compare-exchange loop
    /// (PASSCoDe-Atomic's step 3).
    #[inline]
    pub fn add_atomic(&self, j: usize, delta: f64) {
        let cell = &self.bits[j];
        let mut cur = cell.load(Ordering::Relaxed);
        loop {
            let new = (f64::from_bits(cur) + delta).to_bits();
            match cell.compare_exchange_weak(
                cur,
                new,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(actual) => cur = actual,
            }
        }
    }

    /// Racy read-modify-write (PASSCoDe-Wild's step 3): a concurrent
    /// writer between our load and store is silently overwritten — the
    /// memory-conflict behaviour analyzed by the paper's Theorem 3.
    #[inline]
    pub fn add_wild(&self, j: usize, delta: f64) {
        let cell = &self.bits[j];
        let cur = f64::from_bits(cell.load(Ordering::Relaxed));
        cell.store((cur + delta).to_bits(), Ordering::Relaxed);
    }

    /// Snapshot into a plain `Vec<f64>` (evaluation path; not hot).
    pub fn to_vec(&self) -> Vec<f64> {
        (0..self.len()).map(|j| self.get(j)).collect()
    }

    /// Copy values in from a slice (lengths must match).
    pub fn copy_from(&self, v: &[f64]) {
        assert_eq!(v.len(), self.len());
        for (j, &x) in v.iter().enumerate() {
            self.set(j, x);
        }
    }
}

impl std::fmt::Debug for SharedVec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SharedVec(len={})", self.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn get_set_roundtrip() {
        let v = SharedVec::zeros(4);
        v.set(2, -3.25);
        assert_eq!(v.get(2), -3.25);
        assert_eq!(v.get(0), 0.0);
    }

    #[test]
    fn from_slice_and_to_vec() {
        let v = SharedVec::from_slice(&[1.0, 2.5, -7.0]);
        assert_eq!(v.to_vec(), vec![1.0, 2.5, -7.0]);
    }

    #[test]
    fn atomic_add_is_lossless_under_contention() {
        let v = Arc::new(SharedVec::zeros(1));
        let threads = 8;
        let per = 10_000;
        std::thread::scope(|s| {
            for _ in 0..threads {
                let v = Arc::clone(&v);
                s.spawn(move || {
                    for _ in 0..per {
                        v.add_atomic(0, 1.0);
                    }
                });
            }
        });
        assert_eq!(v.get(0), (threads * per) as f64);
    }

    #[test]
    fn wild_add_single_thread_is_exact() {
        let v = SharedVec::zeros(1);
        for _ in 0..1000 {
            v.add_wild(0, 0.5);
        }
        assert_eq!(v.get(0), 500.0);
    }

    #[test]
    fn wild_add_may_lose_updates_but_never_corrupts() {
        // Under contention Wild can drop increments (that is the point of
        // the paper's backward-error analysis) but each stored value is a
        // valid f64 computed from a previously stored value: the final sum
        // is between one thread's total and the lossless total.
        let v = Arc::new(SharedVec::zeros(1));
        let threads = 4;
        let per = 50_000;
        std::thread::scope(|s| {
            for _ in 0..threads {
                let v = Arc::clone(&v);
                s.spawn(move || {
                    for _ in 0..per {
                        v.add_wild(0, 1.0);
                    }
                });
            }
        });
        let total = v.get(0);
        assert!(total >= per as f64, "lost more than whole threads: {total}");
        assert!(total <= (threads * per) as f64);
        assert_eq!(total.fract(), 0.0, "corrupted value {total}");
    }

    #[test]
    fn copy_from_matches() {
        let v = SharedVec::zeros(3);
        v.copy_from(&[9.0, 8.0, 7.0]);
        assert_eq!(v.to_vec(), vec![9.0, 8.0, 7.0]);
    }
}
