//! Utility substrate: RNG, shared atomic f64 vector, JSON, timers,
//! affinity, and bench statistics.  Everything here exists because the
//! offline image vendors no rand/serde/criterion — see DESIGN.md §7.

pub mod affinity;
pub mod atomicf64;
pub mod json;
pub mod rng;
pub mod stats;
pub mod timer;

pub use atomicf64::SharedVec;
pub use json::Json;
pub use rng::{Pcg32, SplitMix64};
pub use stats::Summary;
pub use timer::{Phases, Timer};
