//! The metrics registry: named lock-free counters, gauges, and
//! histograms with a Prometheus text renderer.
//!
//! The primitives generalize the serving layer's
//! [`crate::serve::LatencyHistogram`] (64 power-of-two buckets) and
//! borrow [`crate::util::SharedVec`]'s cache-line discipline: counter
//! cells are striped across 64-byte-aligned lines indexed by a
//! per-thread stripe, so concurrent `add` calls from solver workers do
//! not bounce a shared line.  Everything on the record path is a relaxed
//! atomic op — no locks, no allocation.  The registry map itself is
//! behind a `Mutex`, but it is touched only at registration and render
//! time (both off the hot path); hot-path users hold `Arc` handles.
//!
//! Metric names follow Prometheus conventions: `snake_case`, counters
//! end in `_total`, and a name may carry a fixed label set inline
//! (`passcode_route_qps{route="a"}`) — the full string is the registry
//! key, and the renderer groups samples by the base name (the part
//! before `{`) when emitting `# TYPE` headers.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Stripe count for counter cells.  Eight 8-byte cells fill exactly one
/// cache line per stripe; eight stripes cover typical worker counts
/// without a dependence on runtime thread counts.
const STRIPES: usize = 8;

/// One 64-byte line holding a single counter cell (the padding is the
/// point: two stripes never share a line).
#[repr(align(64))]
struct Cell(AtomicU64);

impl Cell {
    const fn new() -> Self {
        Cell(AtomicU64::new(0))
    }
}

/// A small per-thread stripe index: threads get consecutive stripes in
/// spawn order, wrapped to [`STRIPES`].  Reused by the probe statics in
/// [`crate::obs::probes`].
pub(crate) fn stripe() -> usize {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    thread_local! {
        static STRIPE: usize = NEXT.fetch_add(1, Ordering::Relaxed);
    }
    STRIPE.with(|s| *s) % STRIPES
}

/// A monotonic counter: striped relaxed adds, plus a `floor` register
/// for scrape-time synchronization with an engine that keeps its own
/// monotonic total (e.g. per-route request counts).  `value()` is the
/// max of the striped sum and the floor, so mixing both write paths can
/// never make the reported value go backwards.
pub struct Counter {
    cells: [Cell; STRIPES],
    floor: AtomicU64,
}

impl Counter {
    /// A zeroed counter (`const`, so probe counters can be statics).
    pub const fn new() -> Self {
        Counter {
            cells: [
                Cell::new(),
                Cell::new(),
                Cell::new(),
                Cell::new(),
                Cell::new(),
                Cell::new(),
                Cell::new(),
                Cell::new(),
            ],
            floor: AtomicU64::new(0),
        }
    }

    // audit: hot-path begin — counter record path (ticked from kernels).
    /// Add `n` to this thread's stripe (relaxed; lock- and
    /// allocation-free).
    #[inline]
    pub fn add(&self, n: u64) {
        self.cells[stripe()].0.fetch_add(n, Ordering::Relaxed);
    }

    /// Add one.
    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    /// Raise the floor to `total` (monotonic: `fetch_max`).  Use this
    /// to mirror an externally maintained monotonic total into the
    /// registry at scrape time; racing scrapes are safe.
    pub fn set_floor(&self, total: u64) {
        self.floor.fetch_max(total, Ordering::Relaxed);
    }
    // audit: hot-path end

    /// Current value: max(sum of stripes, floor).
    pub fn value(&self) -> u64 {
        let mut sum = 0u64;
        for c in &self.cells {
            sum += c.0.load(Ordering::Relaxed);
        }
        sum.max(self.floor.load(Ordering::Relaxed))
    }
}

impl Default for Counter {
    fn default() -> Self {
        Counter::new()
    }
}

/// An `f64` gauge stored as bits in an `AtomicU64` (last write wins).
pub struct Gauge {
    bits: AtomicU64,
}

impl Gauge {
    /// A gauge initialized to 0.0.
    pub const fn new() -> Self {
        Gauge { bits: AtomicU64::new(0) }
    }

    // audit: hot-path begin — gauge record path.
    /// Set the gauge.
    #[inline]
    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }
    // audit: hot-path end

    /// Read the gauge.
    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

impl Default for Gauge {
    fn default() -> Self {
        Gauge::new()
    }
}

/// Bucket count: power-of-two buckets indexed by bit length, same
/// layout as [`crate::serve::LatencyHistogram`].
const BUCKETS: usize = 64;

/// A lock-free histogram over raw `u64` samples (power-of-two buckets).
///
/// Samples are recorded in raw units (e.g. nanoseconds, or a unitless
/// staleness count); `scale` is applied only at render time so the
/// exposition can report seconds while `record` stays integer-cheap.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    scale: f64,
}

impl Histogram {
    /// An empty histogram whose rendered values are `raw * scale`
    /// (pass `1e-9` for nanosecond samples rendered as seconds, `1.0`
    /// for unitless samples).
    pub fn new(scale: f64) -> Self {
        Histogram {
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            scale,
        }
    }

    // audit: hot-path begin — histogram record path (τ and epoch probes).
    /// Record one raw sample (three relaxed atomic adds; no locks, no
    /// allocation).
    #[inline]
    pub fn record(&self, raw: u64) {
        let b = if raw == 0 {
            0
        } else {
            ((u64::BITS - raw.leading_zeros()) as usize).min(BUCKETS - 1)
        };
        self.buckets[b].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(raw, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }
    // audit: hot-path end

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of raw samples, scaled to rendered units.
    pub fn sum_scaled(&self) -> f64 {
        self.sum.load(Ordering::Relaxed) as f64 * self.scale
    }

    /// Approximate `q`-quantile in rendered units (bucket midpoint,
    /// like `LatencyHistogram::quantile_secs`).  Returns 0.0 when
    /// empty.  Tolerates racing writers: if the cumulative walk falls
    /// short of the target it falls back to the highest populated
    /// bucket.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        let mut highest = 0usize;
        for (b, cell) in self.buckets.iter().enumerate() {
            let n = cell.load(Ordering::Relaxed);
            if n > 0 {
                highest = b;
            }
            seen += n;
            if seen >= target {
                return self.midpoint(b);
            }
        }
        self.midpoint(highest)
    }

    /// Midpoint of bucket `b` in rendered units.
    fn midpoint(&self, b: usize) -> f64 {
        if b == 0 {
            return 0.0;
        }
        1.5 * (1u64 << (b - 1)) as f64 * self.scale
    }
}

/// One registered metric: the kind tag doubles as the `# TYPE` line.
enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

impl Metric {
    fn kind(&self) -> &'static str {
        match self {
            Metric::Counter(_) => "counter",
            Metric::Gauge(_) => "gauge",
            Metric::Histogram(_) => "summary",
        }
    }
}

struct Entry {
    help: String,
    metric: Metric,
}

/// A registry of named metrics with a Prometheus text renderer.
///
/// Registration is idempotent: asking for an existing name returns a
/// handle to the same metric (and panics if the name was registered as
/// a different kind — that is a programming error, not a runtime
/// condition).  The process-wide instance lives behind
/// [`crate::obs::registry()`].
pub struct MetricsRegistry {
    metrics: Mutex<BTreeMap<String, Entry>>,
}

impl MetricsRegistry {
    /// An empty registry.
    pub fn new() -> Self {
        MetricsRegistry { metrics: Mutex::new(BTreeMap::new()) }
    }

    /// Number of registered metrics.
    pub fn len(&self) -> usize {
        self.metrics.lock().expect("obs registry poisoned").len()
    }

    /// True when nothing is registered yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Get or create the counter `name` (full name including any
    /// inline labels).
    pub fn counter(&self, name: &str, help: &str) -> Arc<Counter> {
        let mut map = self.metrics.lock().expect("obs registry poisoned");
        let e = map.entry(name.to_string()).or_insert_with(|| Entry {
            help: help.to_string(),
            metric: Metric::Counter(Arc::new(Counter::new())),
        });
        match &e.metric {
            Metric::Counter(c) => Arc::clone(c),
            other => panic!("metric {name} already registered as {}", other.kind()),
        }
    }

    /// Get or create the gauge `name`.
    pub fn gauge(&self, name: &str, help: &str) -> Arc<Gauge> {
        let mut map = self.metrics.lock().expect("obs registry poisoned");
        let e = map.entry(name.to_string()).or_insert_with(|| Entry {
            help: help.to_string(),
            metric: Metric::Gauge(Arc::new(Gauge::new())),
        });
        match &e.metric {
            Metric::Gauge(g) => Arc::clone(g),
            other => panic!("metric {name} already registered as {}", other.kind()),
        }
    }

    /// Get or create the histogram `name` with render scale `scale`
    /// (see [`Histogram::new`]; the scale of the first registration
    /// wins).
    pub fn histogram(&self, name: &str, help: &str, scale: f64) -> Arc<Histogram> {
        let mut map = self.metrics.lock().expect("obs registry poisoned");
        let e = map.entry(name.to_string()).or_insert_with(|| Entry {
            help: help.to_string(),
            metric: Metric::Histogram(Arc::new(Histogram::new(scale))),
        });
        match &e.metric {
            Metric::Histogram(h) => Arc::clone(h),
            other => panic!("metric {name} already registered as {}", other.kind()),
        }
    }

    /// Render the whole registry in the Prometheus text exposition
    /// format (version 0.0.4): `# HELP` / `# TYPE` per base name, then
    /// one `name value` sample line per metric; histograms render as
    /// summaries (`{quantile="..."}` samples plus `_sum` / `_count`).
    pub fn render(&self) -> String {
        let map = self.metrics.lock().expect("obs registry poisoned");
        let mut out = String::new();
        let mut last_base = String::new();
        for (name, e) in map.iter() {
            let (base, labels) = split_name(name);
            if base != last_base {
                out.push_str(&format!("# HELP {base} {}\n", e.help));
                out.push_str(&format!("# TYPE {base} {}\n", e.metric.kind()));
                last_base = base.to_string();
            }
            match &e.metric {
                Metric::Counter(c) => {
                    out.push_str(&format!("{name} {}\n", c.value()));
                }
                Metric::Gauge(g) => {
                    out.push_str(&format!("{name} {}\n", fmt_f64(g.get())));
                }
                Metric::Histogram(h) => {
                    for q in ["0.5", "0.95", "0.99"] {
                        let qv: f64 = q.parse().unwrap();
                        let sample = with_label(base, labels, &format!("quantile=\"{q}\""));
                        out.push_str(&format!("{sample} {}\n", fmt_f64(h.quantile(qv))));
                    }
                    let sum = with_suffix(base, labels, "_sum");
                    let count = with_suffix(base, labels, "_count");
                    out.push_str(&format!("{sum} {}\n", fmt_f64(h.sum_scaled())));
                    out.push_str(&format!("{count} {}\n", h.count()));
                }
            }
        }
        out
    }
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        MetricsRegistry::new()
    }
}

/// Split a full metric name into (base, inline label body without
/// braces): `a{route="x"}` → `("a", Some("route=\"x\""))`.
fn split_name(name: &str) -> (&str, Option<&str>) {
    match name.split_once('{') {
        Some((base, rest)) => (base, Some(rest.trim_end_matches('}'))),
        None => (name, None),
    }
}

/// Rebuild a sample name with one extra label merged into the inline
/// label set.
fn with_label(base: &str, labels: Option<&str>, extra: &str) -> String {
    match labels {
        Some(l) if !l.is_empty() => format!("{base}{{{l},{extra}}}"),
        _ => format!("{base}{{{extra}}}"),
    }
}

/// Rebuild a sample name with a suffix appended to the base (for
/// `_sum` / `_count`), keeping the inline labels.
fn with_suffix(base: &str, labels: Option<&str>, suffix: &str) -> String {
    match labels {
        Some(l) if !l.is_empty() => format!("{base}{suffix}{{{l}}}"),
        _ => format!("{base}{suffix}"),
    }
}

/// Prometheus float formatting: finite values via Rust's shortest
/// round-trip display, specials as `NaN` / `+Inf` / `-Inf`.
fn fmt_f64(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v == f64::INFINITY {
        "+Inf".to_string()
    } else if v == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_stripes_and_floor_are_monotonic() {
        let c = Counter::new();
        c.add(3);
        c.inc();
        assert_eq!(c.value(), 4);
        // Floor below the striped sum changes nothing.
        c.set_floor(2);
        assert_eq!(c.value(), 4);
        // Floor above it wins; a lower later floor cannot regress it.
        c.set_floor(10);
        assert_eq!(c.value(), 10);
        c.set_floor(7);
        assert_eq!(c.value(), 10);
    }

    #[test]
    fn counter_concurrent_adds_sum_exactly() {
        let c = Arc::new(Counter::new());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let c = Arc::clone(&c);
                s.spawn(move || {
                    for _ in 0..10_000 {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.value(), 80_000);
    }

    #[test]
    fn gauge_round_trips_f64() {
        let g = Gauge::new();
        assert_eq!(g.get(), 0.0);
        g.set(-2.5e-9);
        assert_eq!(g.get(), -2.5e-9);
    }

    #[test]
    fn histogram_quantiles_and_scale() {
        let h = Histogram::new(1e-9);
        for _ in 0..100 {
            h.record(1_000); // bucket midpoint 1.5 * 512 ns
        }
        assert_eq!(h.count(), 100);
        let p50 = h.quantile(0.5);
        assert!((p50 - 1.5 * 512.0 * 1e-9).abs() < 1e-12, "{p50}");
        assert!((h.sum_scaled() - 100.0 * 1_000.0 * 1e-9).abs() < 1e-12);
        // q = 1.0 lands in the same (only) bucket.
        assert_eq!(h.quantile(1.0), p50);
        // Empty histogram reports 0.
        assert_eq!(Histogram::new(1.0).quantile(0.99), 0.0);
    }

    #[test]
    fn registry_is_idempotent_and_renders_groups() {
        let reg = MetricsRegistry::new();
        let c1 = reg.counter("t_total", "a counter");
        let c2 = reg.counter("t_total", "a counter");
        c1.add(2);
        c2.add(3);
        assert_eq!(c1.value(), 5);
        reg.gauge("t_qps{route=\"a\"}", "per-route qps").set(1.5);
        reg.gauge("t_qps{route=\"b\"}", "per-route qps").set(2.5);
        reg.histogram("t_seconds", "latency", 1e-9).record(2_000);
        let text = reg.render();
        assert!(text.contains("# TYPE t_total counter"), "{text}");
        assert!(text.contains("t_total 5"), "{text}");
        // One TYPE header for the two labeled gauges.
        assert_eq!(text.matches("# TYPE t_qps gauge").count(), 1, "{text}");
        assert!(text.contains("t_qps{route=\"a\"} 1.5"), "{text}");
        assert!(text.contains("t_qps{route=\"b\"} 2.5"), "{text}");
        assert!(text.contains("# TYPE t_seconds summary"), "{text}");
        assert!(text.contains("t_seconds{quantile=\"0.5\"}"), "{text}");
        assert!(text.contains("t_seconds_count 1"), "{text}");
        assert_eq!(reg.len(), 4);
    }

    #[test]
    #[should_panic(expected = "already registered")]
    fn registry_rejects_kind_mismatch() {
        let reg = MetricsRegistry::new();
        reg.counter("x_total", "c");
        reg.gauge("x_total", "g");
    }

    #[test]
    fn labeled_histogram_merges_quantile_label() {
        let reg = MetricsRegistry::new();
        reg.histogram("t_lat{route=\"a\"}", "lat", 1.0).record(8);
        let text = reg.render();
        assert!(text.contains("t_lat{route=\"a\",quantile=\"0.5\"}"), "{text}");
        assert!(text.contains("t_lat_sum{route=\"a\"} 8"), "{text}");
        assert!(text.contains("t_lat_count{route=\"a\"} 1"), "{text}");
    }
}
