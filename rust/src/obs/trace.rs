//! The flight recorder: a bounded ring buffer of recent spans/events
//! (HTTP requests, training epochs, publishes) with thread ids and
//! monotonic timestamps, dumpable as JSON via `GET /v1/trace` or
//! `passcode train --trace-out`.
//!
//! Events are request/epoch granularity — never per-coordinate — so a
//! short critical section around the ring is acceptable; the solver hot
//! loop goes through [`crate::obs::probes`] instead, which touches only
//! relaxed atomics.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::util::Json;

/// Trace dump format tag (`GET /v1/trace` and `--trace-out` payloads).
pub const TRACE_FORMAT: &str = "passcode-trace-v1";

/// One recorded span/event.
pub struct TraceEvent {
    /// Monotonic sequence number (total events recorded, including
    /// ones since evicted from the ring).
    pub seq: u64,
    /// Recorder-local thread id (dense small integers in first-record
    /// order, not OS tids).
    pub tid: u64,
    /// Microseconds since the recorder was created (monotonic clock).
    pub t_us: f64,
    /// Event kind, e.g. `"http.request"` or `"train.epoch"`.
    pub kind: &'static str,
    /// Free-form label (endpoint + status, epoch number, ...).
    pub label: String,
    /// Span duration in microseconds (0 for point events).
    pub dur_us: f64,
}

struct Ring {
    buf: VecDeque<TraceEvent>,
    seq: u64,
    dropped: u64,
}

/// A fixed-capacity ring of recent [`TraceEvent`]s.  The process-wide
/// instance lives behind [`crate::obs::recorder`].
pub struct FlightRecorder {
    start: Instant,
    cap: usize,
    ring: Mutex<Ring>,
}

/// Recorder-local dense thread id (first thread to record gets 0).
fn tid() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(0);
    thread_local! {
        static TID: u64 = NEXT.fetch_add(1, Ordering::Relaxed);
    }
    TID.with(|t| *t)
}

impl FlightRecorder {
    /// A recorder keeping the most recent `cap` events.
    pub fn new(cap: usize) -> Self {
        let ring = Ring { buf: VecDeque::with_capacity(cap), seq: 0, dropped: 0 };
        FlightRecorder { start: Instant::now(), cap, ring: Mutex::new(ring) }
    }

    /// Record a span of duration `dur` ending now (pass
    /// `Duration::ZERO` for point events).
    pub fn record(&self, kind: &'static str, label: impl Into<String>, dur: Duration) {
        let mut ev = TraceEvent {
            seq: 0,
            tid: tid(),
            t_us: self.start.elapsed().as_secs_f64() * 1e6,
            kind,
            label: label.into(),
            dur_us: dur.as_secs_f64() * 1e6,
        };
        let mut ring = self.ring.lock().expect("flight recorder poisoned");
        ev.seq = ring.seq;
        ring.seq += 1;
        if ring.buf.len() == self.cap {
            ring.buf.pop_front();
            ring.dropped += 1;
        }
        ring.buf.push_back(ev);
    }

    /// Number of events currently held.
    pub fn len(&self) -> usize {
        self.ring.lock().expect("flight recorder poisoned").buf.len()
    }

    /// True when nothing has been recorded (or everything was evicted).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events evicted so far to make room.
    pub fn dropped(&self) -> u64 {
        self.ring.lock().expect("flight recorder poisoned").dropped
    }

    /// Dump the ring (oldest first) as JSON:
    /// `{format, capacity, dropped, events: [{seq, tid, t_us, kind,
    /// label, dur_us}, ...]}`.
    pub fn to_json(&self) -> Json {
        let ring = self.ring.lock().expect("flight recorder poisoned");
        let events: Vec<Json> = ring
            .buf
            .iter()
            .map(|e| {
                Json::obj(vec![
                    ("seq", Json::num(e.seq as f64)),
                    ("tid", Json::num(e.tid as f64)),
                    ("t_us", Json::num(e.t_us)),
                    ("kind", Json::str(e.kind)),
                    ("label", Json::str(&e.label)),
                    ("dur_us", Json::num(e.dur_us)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("format", Json::str(TRACE_FORMAT)),
            ("capacity", Json::num(self.cap as f64)),
            ("dropped", Json::num(ring.dropped as f64)),
            ("events", Json::Arr(events)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_keeps_most_recent_and_counts_drops() {
        let rec = FlightRecorder::new(4);
        assert!(rec.is_empty());
        for i in 0..10 {
            rec.record("test.ev", format!("ev{i}"), Duration::ZERO);
        }
        assert_eq!(rec.len(), 4);
        assert_eq!(rec.dropped(), 6);
        let j = rec.to_json();
        let arr = j.get("events").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 4);
        // Oldest surviving event is seq 6, newest is seq 9.
        assert_eq!(arr[0].get("seq").unwrap().as_f64().unwrap(), 6.0);
        assert_eq!(arr[3].get("seq").unwrap().as_f64().unwrap(), 9.0);
        assert_eq!(arr[3].get("label").unwrap().as_str().unwrap(), "ev9");
    }

    #[test]
    fn timestamps_are_monotone_and_json_round_trips() {
        let rec = FlightRecorder::new(8);
        rec.record("a", "first", Duration::from_micros(5));
        rec.record("b", "second", Duration::ZERO);
        let text = rec.to_json().to_pretty();
        let back = Json::parse(&text).unwrap();
        let arr = back.get("events").unwrap().as_arr().unwrap();
        let t0 = arr[0].get("t_us").unwrap().as_f64().unwrap();
        let t1 = arr[1].get("t_us").unwrap().as_f64().unwrap();
        assert!(t1 >= t0, "{t0} {t1}");
        assert_eq!(arr[0].get("dur_us").unwrap().as_f64().unwrap(), 5.0);
        assert_eq!(back.get("format").unwrap().as_str().unwrap(), "passcode-trace-v1");
    }
}
