//! Solver hot-loop probes: branch-predictable no-ops when disabled,
//! striped relaxed counters when enabled.
//!
//! The contract `perf_hotpath` enforces (<2% overhead enabled, none
//! measurable disabled) shapes everything here:
//!
//! * the global switch is a single relaxed [`AtomicBool`] load — the
//!   cache line it lives on is read-shared and never written during a
//!   run, so the disabled path is a perfectly predicted branch;
//! * the tick counters are `static` [`Counter`]s (cache-line-striped
//!   cells), so a tick is one relaxed `fetch_add` on a mostly
//!   thread-local line — no `Arc`, no registry lookup, no allocation;
//! * everything per-update is counting; anything that costs more (the
//!   τ sample, epoch timing, the backward-error gauge) runs at epoch
//!   boundaries or behind a 1-in-[`TAU_SAMPLE_EVERY`] countdown.
//!
//! The registry only learns about these totals at synchronization
//! points ([`sync_hot_counters`]: end of a training round, `/metrics`
//! scrape) via `Counter::set_floor`, which keeps the exported values
//! monotonic under racing scrapes.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, OnceLock};

use super::registry::{Counter, Gauge, Histogram};

/// Sample one coordinate update in every this-many for the τ-staleness
/// probe (per worker, when probes are enabled).
pub const TAU_SAMPLE_EVERY: u32 = 1024;

static PROBES_ENABLED: AtomicBool = AtomicBool::new(false);

/// Whether solver probes are enabled (relaxed load; hoist out of inner
/// loops where convenient, but calling per update is cheap).
#[inline]
pub fn probes_enabled() -> bool {
    PROBES_ENABLED.load(Ordering::Relaxed)
}

/// Turn solver probes on or off (`passcode listen` enables them by
/// default, `passcode train --probes true` opts in, benches toggle
/// them around the ablation rows).
pub fn set_probes_enabled(on: bool) {
    PROBES_ENABLED.store(on, Ordering::Relaxed);
}

// audit: hot-path begin — tick fns are called from inside the kernels;
// with probes off they must be a single relaxed load and branch.
/// CAS retries in `SharedVec::cas_add` (PASSCoDe-Atomic contention).
static CAS_RETRIES: Counter = Counter::new();
/// Contended `LockTable::acquire_sorted` acquisitions (PASSCoDe-Lock).
static LOCK_WAITS: Counter = Counter::new();
/// Completed kernel scatters — the clock the τ probe reads.
static SCATTERS: Counter = Counter::new();

/// Count one CAS retry (no-op unless probes are enabled).
#[inline]
pub fn cas_retry_tick() {
    if probes_enabled() {
        CAS_RETRIES.inc();
    }
}

/// Count one contended lock acquisition (no-op unless probes are
/// enabled).
#[inline]
pub fn lock_wait_tick() {
    if probes_enabled() {
        LOCK_WAITS.inc();
    }
}

/// Count one completed scatter (no-op unless probes are enabled).
#[inline]
pub fn scatter_tick() {
    if probes_enabled() {
        SCATTERS.inc();
    }
}

/// Total scatters ticked so far.  The τ probe reads this before and
/// after one sampled update: the difference minus the update's own
/// write is the number of foreign `w`-writes that landed inside the
/// update's read→write span — the staleness parameter of Liu & Wright
/// (arXiv:1403.3862), measured on a free-running schedule (the `chk/`
/// checker measures the same span under its serialized scheduler).
pub fn scatter_ticks() -> u64 {
    SCATTERS.value()
}
// audit: hot-path end

/// Registry handles for the solver telemetry family, registered once
/// into the global [`crate::obs::registry()`].
pub struct SolverProbes {
    /// Coordinate updates performed (all training rounds).
    pub updates: Arc<Counter>,
    /// Epochs completed.
    pub epochs: Arc<Counter>,
    /// CAS retries (mirrors the hot static at sync points).
    pub cas_retries: Arc<Counter>,
    /// Contended lock acquisitions (mirrors the hot static).
    pub lock_waits: Arc<Counter>,
    /// Per-worker epoch wall time (recorded in ns, rendered seconds).
    pub epoch_seconds: Arc<Histogram>,
    /// Sampled τ staleness (foreign scatters inside an update span).
    pub tau: Arc<Histogram>,
    /// Empirical backward error ‖ŵ − Σᵢ αᵢ xᵢ‖ / ‖ŵ‖ (Eq. 6) at the
    /// last epoch boundary.
    pub backward_error: Arc<Gauge>,
    /// Updates/sec of the most recent training round.
    pub updates_per_sec: Arc<Gauge>,
}

/// The solver telemetry family (lazily registered on first use).
pub fn solver() -> &'static SolverProbes {
    static PROBES: OnceLock<SolverProbes> = OnceLock::new();
    PROBES.get_or_init(|| {
        let reg = crate::obs::registry();
        SolverProbes {
            updates: reg.counter(
                "passcode_train_updates_total",
                "Dual coordinate updates performed",
            ),
            epochs: reg.counter("passcode_train_epochs_total", "Training epochs completed"),
            cas_retries: reg.counter(
                "passcode_train_cas_retries_total",
                "CAS retries in SharedVec::cas_add (PASSCoDe-Atomic)",
            ),
            lock_waits: reg.counter(
                "passcode_train_lock_waits_total",
                "Contended acquisitions in LockTable::acquire_sorted (PASSCoDe-Lock)",
            ),
            epoch_seconds: reg.histogram(
                "passcode_train_epoch_seconds",
                "Per-worker epoch wall time",
                1e-9,
            ),
            tau: reg.histogram(
                "passcode_train_tau",
                "Sampled staleness: foreign w-writes inside one update's read->write span",
                1.0,
            ),
            backward_error: reg.gauge(
                "passcode_train_backward_error_ratio",
                "Empirical |w_hat - sum_i alpha_i x_i| / |w_hat| (Eq. 6, Theorem 3)",
            ),
            updates_per_sec: reg.gauge(
                "passcode_train_updates_per_sec",
                "Updates/sec of the most recent training round",
            ),
        }
    })
}

/// Registry handles for the distributed-tier telemetry family
/// (`passcode_dist_*`).  The coordinator drives the merge-side members
/// on every `push_delta`; workers register their own per-worker
/// labeled push/pull counters directly (label-in-name idiom, like
/// `passcode_route_*`).
pub struct DistProbes {
    /// Accepted delta merges (coordinator).
    pub merges: Arc<Counter>,
    /// Deltas rejected as staler than `--max-lag` (coordinator).
    pub rejects: Arc<Counter>,
    /// Current merge epoch of the global `w` (coordinator).
    pub merge_epoch: Arc<Gauge>,
    /// Staleness (merge-epoch lag) of each accepted delta.
    pub merge_lag: Arc<Histogram>,
    /// Accumulated worker-reported backward error of the merged `w`,
    /// relative to ‖w‖ — the distributed analog of the Theorem-3
    /// `passcode_train_backward_error_ratio` gauge.
    pub backward_error_ratio: Arc<Gauge>,
    /// Heartbeats handled (coordinator, lease mode).
    pub heartbeats: Arc<Counter>,
    /// Duplicate pushes answered from the `(worker, boot, round)`
    /// dedup record instead of merging twice.
    pub dedup_hits: Arc<Counter>,
    /// Worker leases expired (worker declared dead, contribution
    /// rolled back).
    pub lease_expired: Arc<Counter>,
    /// Shard ranges reassigned from a dead worker to a live one.
    pub reassigns: Arc<Counter>,
    /// Workers currently holding a live lease.
    pub workers_alive: Arc<Gauge>,
}

/// The distributed-tier telemetry family (lazily registered on first
/// use).  Unlike the solver hot counters these are never on a
/// per-update path — one merge per worker round — so they update their
/// registry handles directly, with no static mirror.
pub fn dist() -> &'static DistProbes {
    static PROBES: OnceLock<DistProbes> = OnceLock::new();
    PROBES.get_or_init(|| {
        let reg = crate::obs::registry();
        DistProbes {
            merges: reg.counter(
                "passcode_dist_merges_total",
                "Worker w-deltas accepted and merged into the global w",
            ),
            rejects: reg.counter(
                "passcode_dist_rejects_total",
                "Worker w-deltas rejected as staler than max-lag (resync forced)",
            ),
            merge_epoch: reg.gauge(
                "passcode_dist_merge_epoch",
                "Current merge epoch of the coordinator's global w",
            ),
            merge_lag: reg.histogram(
                "passcode_dist_merge_lag",
                "Merge-epoch staleness of accepted deltas (Hybrid-DCA bounded staleness)",
                1.0,
            ),
            backward_error_ratio: reg.gauge(
                "passcode_dist_backward_error_ratio",
                "Accumulated worker-reported |dw - X^T dalpha| over |w| of the merged model",
            ),
            heartbeats: reg.counter(
                "passcode_dist_heartbeats_total",
                "Worker heartbeats handled by the coordinator",
            ),
            dedup_hits: reg.counter(
                "passcode_dist_push_dedup_total",
                "Duplicate pushes answered from the (worker, boot, round) dedup record",
            ),
            lease_expired: reg.counter(
                "passcode_dist_lease_expired_total",
                "Worker leases expired: worker declared dead, contribution rolled back",
            ),
            reassigns: reg.counter(
                "passcode_dist_reassign_total",
                "Shard ranges reassigned from dead workers to live ones",
            ),
            workers_alive: reg.gauge(
                "passcode_dist_workers_alive",
                "Workers currently holding a live lease",
            ),
        }
    })
}

/// Mirror the hot tick statics into their registry counters.  Called
/// at training-round boundaries and on every `/metrics` scrape; cheap
/// and race-safe (`set_floor` is a `fetch_max`).
pub fn sync_hot_counters() {
    let p = solver();
    p.cas_retries.set_floor(CAS_RETRIES.value());
    p.lock_waits.set_floor(LOCK_WAITS.value());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ticks_are_gated_and_sync_mirrors_them() {
        // Serialize against other tests that toggle the global switch.
        let was = probes_enabled();
        set_probes_enabled(false);
        let cas0 = CAS_RETRIES.value();
        cas_retry_tick();
        lock_wait_tick();
        scatter_tick();
        assert_eq!(CAS_RETRIES.value(), cas0, "tick must be a no-op when disabled");
        set_probes_enabled(true);
        cas_retry_tick();
        lock_wait_tick();
        scatter_tick();
        assert!(CAS_RETRIES.value() > cas0);
        sync_hot_counters();
        assert!(solver().cas_retries.value() >= CAS_RETRIES.value());
        assert!(solver().lock_waits.value() >= 1);
        set_probes_enabled(was);
    }

    #[test]
    fn dist_family_registers_once_and_updates() {
        let a = dist().merges.as_ref() as *const Counter;
        let b = dist().merges.as_ref() as *const Counter;
        assert_eq!(a, b);
        let before = dist().merges.value();
        dist().merges.inc();
        dist().merge_epoch.set(3.0);
        dist().merge_lag.record(2);
        assert_eq!(dist().merges.value(), before + 1);
        assert!(dist().merge_lag.count() >= 1);
    }

    #[test]
    fn solver_family_registers_once() {
        let a = solver().updates.as_ref() as *const Counter;
        let b = solver().updates.as_ref() as *const Counter;
        assert_eq!(a, b);
        assert!(!crate::obs::registry().is_empty());
    }
}
