//! Crate-wide observability: a lock-free [`MetricsRegistry`], solver
//! hot-loop probes, and a ring-buffer [`FlightRecorder`].
//!
//! PASSCoDe's interesting behavior happens *inside* the asynchronous
//! hot loop — staleness τ, CAS/lock contention, the Theorem-3 backward
//! error — and this module turns those analysis quantities into live
//! production signals next to the serving metrics:
//!
//! * [`registry()`] — the process-wide [`MetricsRegistry`].  The solver
//!   family (`passcode_train_*`: updates, epochs, CAS retries, lock
//!   waits, per-worker epoch timings, sampled τ, backward-error ratio)
//!   registers via [`probes::solver`]; the HTTP/serving family
//!   (`passcode_http_*`, `passcode_route_*`) registers from
//!   `net/server.rs` and `Router::publish_metrics`; the distributed
//!   tier (`passcode_dist_*`: merges, rejects, merge epoch, merge-lag
//!   histogram, merged-`w` backward error, per-worker push/pull
//!   counters) registers via [`probes::dist`] and `dist/worker.rs`.
//!   `GET /metrics` renders everything in one Prometheus text scrape.
//! * [`probes`] — the hot-path half: a global enable switch plus
//!   static striped tick counters, shaped so the solver inner loop
//!   pays one predictable branch when probes are off (`perf_hotpath`
//!   carries the probes-on/off ablation; the bar is <2% enabled).
//! * [`recorder()`] — the process-wide [`FlightRecorder`]: recent spans
//!   (HTTP requests, training epochs) with thread ids and monotonic
//!   timestamps, served as JSON by `GET /v1/trace` and written by
//!   `passcode train --trace-out <file>`.
//!
//! Everything is std-only and allocation-free on the record path
//! (metric handles are `Arc`s resolved at registration time; the
//! recorder allocates only its bounded ring and per-event labels at
//! request/epoch granularity).

pub mod probes;
pub mod registry;
pub mod trace;

use std::sync::OnceLock;

pub use probes::{probes_enabled, set_probes_enabled};
pub use registry::{Counter, Gauge, Histogram, MetricsRegistry};
pub use trace::{FlightRecorder, TraceEvent};

/// Capacity of the process-wide flight recorder ring.
const RECORDER_CAPACITY: usize = 4096;

/// The process-wide metrics registry.
pub fn registry() -> &'static MetricsRegistry {
    static REGISTRY: OnceLock<MetricsRegistry> = OnceLock::new();
    REGISTRY.get_or_init(MetricsRegistry::new)
}

/// The process-wide flight recorder (most recent 4096 events).
pub fn recorder() -> &'static FlightRecorder {
    static RECORDER: OnceLock<FlightRecorder> = OnceLock::new();
    RECORDER.get_or_init(|| FlightRecorder::new(RECORDER_CAPACITY))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn globals_are_singletons() {
        assert!(std::ptr::eq(registry(), registry()));
        assert!(std::ptr::eq(recorder(), recorder()));
    }
}
