//! Native (sparse, CPU) evaluation of the paper's reported quantities:
//! primal objective P(w), dual objective D(α), duality gap, accuracy.
//!
//! These are the figures of merit in every plot of Section 5.  The PJRT
//! runtime (`crate::runtime`) provides an AOT-compiled dense path for the
//! same quantities; `rust/tests/runtime_aot.rs` cross-checks the two.

use crate::data::Dataset;
use crate::loss::Loss;

/// Primal objective `P(w) = ½‖w‖² + Σ_i ℓ_i(w·x_i)` (paper Eq. 1).
pub fn primal_objective<L: Loss>(ds: &Dataset, loss: &L, w: &[f64]) -> f64 {
    assert_eq!(w.len(), ds.d());
    let reg: f64 = 0.5 * w.iter().map(|v| v * v).sum::<f64>();
    let mut sum = 0.0;
    for i in 0..ds.n() {
        sum += loss.primal(ds.x.row_dot_dense(i, w));
    }
    reg + sum
}

/// Dual objective `D(α) = ½‖Σ_i α_i x_i‖² + Σ_i ℓ*_i(−α_i)` (paper Eq. 2).
///
/// α is projected onto the feasible domain before evaluating the
/// conjugate (PASSCoDe-Wild iterates can sit epsilon outside the box).
pub fn dual_objective<L: Loss>(ds: &Dataset, loss: &L, alpha: &[f64]) -> f64 {
    assert_eq!(alpha.len(), ds.n());
    let projected: Vec<f64> = alpha.iter().map(|&a| loss.project(a)).collect();
    let wbar = ds.x.transpose_dot(&projected);
    let reg: f64 = 0.5 * wbar.iter().map(|v| v * v).sum::<f64>();
    let conj: f64 = projected.iter().map(|&a| loss.conjugate_neg(a)).sum();
    reg + conj
}

/// `w̄ = Σ_i α_i x_i` — the primal vector implied by the dual iterate
/// (paper Eq. 3/6). For PASSCoDe-Wild this *differs* from the maintained ŵ.
pub fn wbar_from_alpha(ds: &Dataset, alpha: &[f64]) -> Vec<f64> {
    ds.x.transpose_dot(alpha)
}

/// Duality gap `P(w(α)) + D(α)` (P(w*) = −D(α*), so the gap of a
/// primal-dual pair is P + D ≥ 0).
pub fn duality_gap<L: Loss>(ds: &Dataset, loss: &L, alpha: &[f64]) -> f64 {
    let projected: Vec<f64> = alpha.iter().map(|&a| loss.project(a)).collect();
    let wbar = ds.x.transpose_dot(&projected);
    primal_objective(ds, loss, &wbar) + dual_objective(ds, loss, alpha)
}

/// Test accuracy: fraction of rows with positive margin (rows are folded).
pub fn accuracy(ds: &Dataset, w: &[f64]) -> f64 {
    ds.accuracy(w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{CsrMatrix, Entry};
    use crate::loss::Hinge;

    fn toy() -> Dataset {
        let x = CsrMatrix::from_rows(
            &[
                vec![Entry { index: 0, value: 0.8 }],
                vec![Entry { index: 1, value: 0.6 }],
                vec![
                    Entry { index: 0, value: -0.3 },
                    Entry { index: 1, value: 0.4 },
                ],
            ],
            2,
        );
        Dataset::new(x, vec![1.0, 1.0, -1.0], "toy")
    }

    #[test]
    fn primal_at_zero_w_is_sum_of_losses() {
        let ds = toy();
        let h = Hinge::new(2.0);
        // z = 0 for all rows: P = 0 + 3 * C*1
        assert!((primal_objective(&ds, &h, &[0.0, 0.0]) - 6.0).abs() < 1e-12);
    }

    #[test]
    fn dual_at_zero_alpha_is_zero() {
        let ds = toy();
        let h = Hinge::new(1.0);
        assert_eq!(dual_objective(&ds, &h, &[0.0; 3]), 0.0);
    }

    #[test]
    fn gap_nonnegative_and_zero_only_at_optimum() {
        let ds = toy();
        let h = Hinge::new(1.0);
        // Any feasible α has gap ≥ 0.
        for a in [[0.0, 0.0, 0.0], [0.5, 0.5, 0.5], [1.0, 1.0, 1.0]] {
            assert!(duality_gap(&ds, &h, &a) >= -1e-12);
        }
    }

    #[test]
    fn wbar_matches_manual_sum() {
        let ds = toy();
        let wbar = wbar_from_alpha(&ds, &[1.0, 2.0, 1.0]);
        // col0: 1*0.8 + 1*(-0.3) = 0.5 ; col1: 2*0.6 + 1*0.4 = 1.6
        assert!((wbar[0] - 0.5).abs() < 1e-12);
        assert!((wbar[1] - 1.6).abs() < 1e-12);
    }

    #[test]
    fn dual_projects_out_of_box_alphas() {
        let ds = toy();
        let h = Hinge::new(1.0);
        let a_in = [0.9, 0.9, 0.9];
        let a_out = [0.9, 1.3, -0.2]; // projected to [0.9, 1.0, 0.0]
        let d_out = dual_objective(&ds, &h, &a_out);
        let d_proj = dual_objective(&ds, &h, &[0.9, 1.0, 0.0]);
        assert!((d_out - d_proj).abs() < 1e-12);
        let _ = dual_objective(&ds, &h, &a_in); // must not panic
    }
}
