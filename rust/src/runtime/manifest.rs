//! Parse `artifacts/manifest.json` — the contract between the Python AOT
//! exporter (`python/compile/aot.py`) and the Rust runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::Json;

/// One exported computation.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactSpec {
    pub name: String,
    /// HLO text file, relative to the artifacts dir.
    pub file: String,
    /// Input shapes (row-major, f32).
    pub inputs: Vec<Vec<usize>>,
    /// Output shapes.
    pub outputs: Vec<Vec<usize>>,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub row_block: usize,
    pub feat_block: usize,
    pub dcd_row_block: usize,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))?;
        let json = Json::parse(&text).context("parse manifest.json")?;
        if json.get("format")?.as_str()? != "hlo-text" {
            bail!("unsupported artifact format");
        }
        let mut artifacts = BTreeMap::new();
        for (name, entry) in json.get("artifacts")?.as_obj()? {
            let shapes = |key: &str| -> Result<Vec<Vec<usize>>> {
                entry
                    .get(key)?
                    .as_arr()?
                    .iter()
                    .map(|s| {
                        s.as_arr()?
                            .iter()
                            .map(|d| d.as_usize())
                            .collect::<Result<Vec<_>>>()
                    })
                    .collect()
            };
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: entry.get("file")?.as_str()?.to_string(),
                    inputs: shapes("inputs")?,
                    outputs: shapes("outputs")?,
                },
            );
        }
        Ok(Manifest {
            dir,
            row_block: json.get("row_block")?.as_usize()?,
            feat_block: json.get("feat_block")?.as_usize()?,
            dcd_row_block: json.get("dcd_row_block")?.as_usize()?,
            artifacts,
        })
    }

    /// Absolute path of an artifact's HLO file.
    pub fn path_of(&self, name: &str) -> Result<PathBuf> {
        let a = self
            .artifacts
            .get(name)
            .with_context(|| format!("artifact {name:?} not in manifest"))?;
        Ok(self.dir.join(&a.file))
    }

    /// Locate the default artifacts dir: `$PASSCODE_ARTIFACTS`, else
    /// `./artifacts`, else `../artifacts` (for tests running in target/).
    pub fn default_dir() -> PathBuf {
        if let Ok(p) = std::env::var("PASSCODE_ARTIFACTS") {
            return PathBuf::from(p);
        }
        for cand in ["artifacts", "../artifacts", "../../artifacts"] {
            let p = PathBuf::from(cand);
            if p.join("manifest.json").exists() {
                return p;
            }
        }
        PathBuf::from("artifacts")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fixture(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"format":"hlo-text","jax_version":"0.8.2",
                "row_block":256,"feat_block":512,"dcd_row_block":128,
                "dcd_sweeps":1,
                "artifacts":{"margins_block":{"file":"margins_block.hlo.txt",
                  "inputs":[[256,512],[512,1]],"outputs":[[256,1]],
                  "dtype":"f32","note":"x"}}}"#,
        )
        .unwrap();
    }

    #[test]
    fn loads_fixture() {
        let dir = std::env::temp_dir().join("passcode_manifest_test");
        write_fixture(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.row_block, 256);
        assert_eq!(m.feat_block, 512);
        let a = &m.artifacts["margins_block"];
        assert_eq!(a.inputs, vec![vec![256, 512], vec![512, 1]]);
        assert!(m.path_of("margins_block").unwrap().ends_with("margins_block.hlo.txt"));
        assert!(m.path_of("nope").is_err());
    }

    #[test]
    fn rejects_wrong_format() {
        let dir = std::env::temp_dir().join("passcode_manifest_bad");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"format":"protobuf","artifacts":{},
                "row_block":1,"feat_block":1,"dcd_row_block":1}"#,
        )
        .unwrap();
        assert!(Manifest::load(&dir).is_err());
    }
}
