//! Graceful stand-ins for the PJRT engine when the crate is built
//! without the `xla` cargo feature (the default — the offline image has
//! no PJRT toolchain).
//!
//! The stub mirrors the public surface of [`super::engine`] exactly, but
//! [`Engine::load`] always returns a descriptive error, so every AOT
//! call site (CLI `--aot-eval`, `passcode eval`, benches, examples,
//! `rust/tests/runtime_aot.rs`) compiles unchanged and degrades to a
//! printed "skipped" at run time.  No value of [`Engine`] or [`Literal`]
//! can ever be constructed in a stub build, so the `&self` methods are
//! statically unreachable.

use std::path::Path;

use anyhow::{bail, Result};

use crate::data::Dataset;

use super::manifest::Manifest;

const NO_XLA: &str = "PJRT runtime unavailable: built without the `xla` \
                      cargo feature (enable it and provide the `xla` \
                      crate from the toolchain image to run AOT paths)";

/// Stand-in for `xla::Literal`; never constructible in stub builds.
pub struct Literal {
    _priv: (),
}

impl Literal {
    /// Unreachable in stub builds (no [`Literal`] can exist).
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unreachable!("{}", NO_XLA)
    }

    /// Unreachable in stub builds (no [`Literal`] can exist).
    pub fn reshape(&self, _shape: &[i64]) -> Result<Literal> {
        unreachable!("{}", NO_XLA)
    }
}

/// Stub engine: [`Engine::load`] always fails with a clear message.
pub struct Engine {
    /// Present for API parity with the real engine; never populated.
    pub manifest: Manifest,
    /// Present for API parity with the real engine; never populated.
    pub compile_secs: f64,
    _priv: (),
}

impl Engine {
    /// Always fails: this build has no PJRT backend.
    pub fn load(_dir: impl AsRef<Path>) -> Result<Engine> {
        bail!(NO_XLA)
    }

    /// Always fails: this build has no PJRT backend.
    pub fn load_default() -> Result<Engine> {
        bail!(NO_XLA)
    }

    /// Unreachable in stub builds (no [`Engine`] can exist).
    pub fn platform(&self) -> String {
        unreachable!("{}", NO_XLA)
    }

    /// Unreachable in stub builds (no [`Engine`] can exist).
    pub fn execute(
        &self,
        _name: &str,
        _inputs: &[Literal],
    ) -> Result<Vec<Literal>> {
        unreachable!("{}", NO_XLA)
    }

    /// Always fails: literals require the PJRT backend.
    pub fn literal_f32(_data: &[f32], _shape: &[i64]) -> Result<Literal> {
        bail!(NO_XLA)
    }
}

/// Dataset-level evaluation statistics (API parity with the real engine).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AotEval {
    /// Σ_i max(0, 1 − m_i) — unweighted hinge sum (caller multiplies C).
    pub hinge_sum: f64,
    /// Rows with margin > 0.
    pub correct: usize,
    /// ½‖w‖².
    pub half_sqnorm: f64,
    /// Rows evaluated.
    pub rows: usize,
}

impl AotEval {
    /// Primal objective for hinge loss with penalty `c`.
    pub fn primal(&self, c: f64) -> f64 {
        self.half_sqnorm + c * self.hinge_sum
    }

    /// Fraction of rows with positive margin.
    pub fn accuracy(&self) -> f64 {
        if self.rows == 0 {
            0.0
        } else {
            self.correct as f64 / self.rows as f64
        }
    }
}

/// Stub evaluator; unreachable because no [`Engine`] can exist.
pub struct Evaluator<'e> {
    _engine: &'e Engine,
}

impl<'e> Evaluator<'e> {
    /// Unreachable in stub builds (no [`Engine`] can exist).
    pub fn new(engine: &'e Engine) -> Self {
        Self { _engine: engine }
    }

    /// Unreachable in stub builds (no [`Engine`] can exist).
    pub fn eval(&self, _ds: &Dataset, _w: &[f64]) -> Result<AotEval> {
        unreachable!("{}", NO_XLA)
    }
}
