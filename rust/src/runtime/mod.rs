//! placeholder
pub mod engine;
pub mod manifest;
pub use engine::{AotEval, Engine, Evaluator};
pub use manifest::Manifest;
