//! AOT/PJRT runtime: loads the HLO-text artifacts exported by
//! `python/compile/aot.py` and executes them through the PJRT C API — so
//! evaluation runs with no Python anywhere on the path.
//!
//! The PJRT backend needs the `xla` crate from the baked toolchain
//! image, so the real [`engine`] is gated behind the `xla` cargo
//! feature.  Default builds get [`stub`], an API-identical engine whose
//! `load` returns a descriptive error: every AOT call site compiles and
//! degrades to "skipped" at run time.  [`Manifest`] parsing is pure Rust
//! and available in both builds.

pub mod manifest;
pub use manifest::Manifest;

#[cfg(feature = "xla")]
pub mod engine;
#[cfg(feature = "xla")]
pub use engine::{AotEval, Engine, Evaluator};

#[cfg(not(feature = "xla"))]
pub mod stub;
#[cfg(not(feature = "xla"))]
pub use stub::{AotEval, Engine, Evaluator};
