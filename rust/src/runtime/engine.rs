//! PJRT execution engine: loads the AOT HLO-text artifacts and runs them
//! on the CPU PJRT client — evaluation with **no Python on the path**.
//!
//! Pattern (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`.  Every executable is compiled once at
//! engine construction and cached.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::data::Dataset;
use crate::util::Timer;

use super::manifest::Manifest;

/// A loaded, compiled artifact set.
pub struct Engine {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    executables: BTreeMap<String, xla::PjRtLoadedExecutable>,
    /// Seconds spent compiling at load time (reported as init cost).
    pub compile_secs: f64,
}

impl Engine {
    /// Load every artifact in `<dir>/manifest.json` and compile it.
    pub fn load(dir: impl AsRef<Path>) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let t = Timer::start();
        let mut executables = BTreeMap::new();
        for name in manifest.artifacts.keys() {
            let path = manifest.path_of(name)?;
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parse HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compile artifact {name}"))?;
            executables.insert(name.clone(), exe);
        }
        Ok(Engine { manifest, client, executables, compile_secs: t.secs() })
    }

    /// Load from the default artifacts location (see
    /// [`Manifest::default_dir`]).
    pub fn load_default() -> Result<Engine> {
        Engine::load(Manifest::default_dir())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute artifact `name`; returns the decomposed output tuple
    /// (the AOT bridge lowers with `return_tuple=True`).
    pub fn execute(
        &self,
        name: &str,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self
            .executables
            .get(name)
            .with_context(|| format!("artifact {name:?} not loaded"))?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("execute {name}"))?[0][0]
            .to_literal_sync()?;
        Ok(result.to_tuple()?)
    }

    /// Build an f32 literal of the given shape from a slice.
    pub fn literal_f32(data: &[f32], shape: &[i64]) -> Result<xla::Literal> {
        let expect: i64 = shape.iter().product();
        anyhow::ensure!(
            expect as usize == data.len(),
            "shape {shape:?} wants {expect} elements, got {}",
            data.len()
        );
        Ok(xla::Literal::vec1(data).reshape(shape)?)
    }
}

/// Dataset-level evaluation statistics computed through the AOT path.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AotEval {
    /// Σ_i max(0, 1 − m_i) — unweighted hinge sum (caller multiplies C).
    pub hinge_sum: f64,
    /// Rows with margin > 0.
    pub correct: usize,
    /// ½‖w‖².
    pub half_sqnorm: f64,
    /// Rows evaluated.
    pub rows: usize,
}

impl AotEval {
    /// Primal objective for hinge loss with penalty `c`.
    pub fn primal(&self, c: f64) -> f64 {
        self.half_sqnorm + c * self.hinge_sum
    }

    pub fn accuracy(&self) -> f64 {
        if self.rows == 0 {
            0.0
        } else {
            self.correct as f64 / self.rows as f64
        }
    }
}

/// High-level evaluator: streams dense row/feature blocks of a (sparse)
/// dataset through the compiled artifacts.
pub struct Evaluator<'e> {
    engine: &'e Engine,
    rb: usize,
    fb: usize,
}

impl<'e> Evaluator<'e> {
    pub fn new(engine: &'e Engine) -> Self {
        let rb = engine.manifest.row_block;
        let fb = engine.manifest.feat_block;
        Self { engine, rb, fb }
    }

    /// Evaluate hinge statistics + accuracy of `w` over `ds`.
    ///
    /// Margins are accumulated across feature blocks with the
    /// `margins_block` artifact, reduced with `loss_stats_block`, and the
    /// regularizer comes from `sumsq_block` — all through PJRT.
    pub fn eval(&self, ds: &Dataset, w: &[f64]) -> Result<AotEval> {
        let (rb, fb) = (self.rb, self.fb);
        let n = ds.n();
        let d = ds.d();
        assert_eq!(w.len(), d);
        let n_fb = d.div_ceil(fb);

        // ---- ½‖w‖² over padded feature blocks -------------------------
        let mut half_sqnorm = 0.0f64;
        let mut wblk = vec![0f32; fb];
        for b in 0..n_fb {
            let lo = b * fb;
            let hi = (lo + fb).min(d);
            wblk.fill(0.0);
            for (k, j) in (lo..hi).enumerate() {
                wblk[k] = w[j] as f32;
            }
            let lit = Engine::literal_f32(&wblk, &[fb as i64, 1])?;
            let out = self.engine.execute("sumsq_block", &[lit])?;
            half_sqnorm += 0.5 * out[0].to_vec::<f32>()?[0] as f64;
        }

        // ---- margins + loss stats over row blocks ----------------------
        // The w-block literals are identical for every row block: build
        // them once per eval instead of once per (row × feature) block
        // (§Perf iteration 5 — saves n_rb× literal uploads).
        let w_lits: Vec<xla::Literal> = (0..n_fb)
            .map(|b| {
                let lo = b * fb;
                let hi = (lo + fb).min(d);
                wblk.fill(0.0);
                for (k, j) in (lo..hi).enumerate() {
                    wblk[k] = w[j] as f32;
                }
                Engine::literal_f32(&wblk, &[fb as i64, 1])
            })
            .collect::<Result<_>>()?;
        let mut hinge_sum = 0.0f64;
        let mut correct = 0usize;
        let n_rb = n.div_ceil(rb);
        let mut xblk = vec![0f32; rb * fb];
        let mut margins = vec![0f32; rb];
        let mut mask = vec![0f32; rb];
        for rbi in 0..n_rb {
            let row_lo = rbi * rb;
            let row_hi = (row_lo + rb).min(n);
            let live = row_hi - row_lo;
            margins.fill(0.0);
            for b in 0..n_fb {
                let col_lo = b * fb;
                let col_hi = (col_lo + fb).min(d);
                // densify the (row, feature) block
                xblk.fill(0.0);
                for (r, i) in (row_lo..row_hi).enumerate() {
                    let (idx, vals) = ds.x.row(i);
                    // rows are sorted: binary search the column window
                    let s = idx.partition_point(|&j| (j as usize) < col_lo);
                    let e = idx.partition_point(|&j| (j as usize) < col_hi);
                    for k in s..e {
                        xblk[r * fb + (idx[k] as usize - col_lo)] =
                            vals[k] as f32;
                    }
                }
                let xl = Engine::literal_f32(&xblk, &[rb as i64, fb as i64])?;
                let wl = w_lits[b].reshape(&[fb as i64, 1])?;
                let out = self.engine.execute("margins_block", &[xl, wl])?;
                let part = out[0].to_vec::<f32>()?;
                for (m, p) in margins.iter_mut().zip(&part) {
                    *m += p;
                }
            }
            mask.fill(0.0);
            mask[..live].fill(1.0);
            let ml = Engine::literal_f32(&margins, &[rb as i64, 1])?;
            let kl = Engine::literal_f32(&mask, &[rb as i64, 1])?;
            let out = self.engine.execute("loss_stats_block", &[ml, kl])?;
            hinge_sum += out[0].to_vec::<f32>()?[0] as f64;
            correct += out[1].to_vec::<f32>()?[0] as usize;
        }

        Ok(AotEval { hinge_sum, correct, half_sqnorm, rows: n })
    }
}
