//! Serving telemetry: a lock-free latency histogram (p50/p95/p99),
//! per-shard throughput counters, and the QPS report the `replay`
//! command and `benches/serve_throughput.rs` print through
//! [`crate::coordinator::metrics::TextTable`].
//!
//! Scorer shards record into shared atomics on every request — the same
//! "contended plain adds are fine" discipline PASSCoDe-Wild applies to
//! `w` is applied here to counters (where relaxed atomics are exact
//! anyway), so telemetry never serializes the hot path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use crate::coordinator::metrics::TextTable;
use crate::util::Json;

use super::registry::ModelRegistry;

/// Number of power-of-two latency buckets (covers 1 ns … ~584 years).
const BUCKETS: usize = 64;

/// A concurrent histogram over request latencies with geometric
/// (power-of-two nanosecond) buckets.
///
/// `record` is wait-free (two relaxed `fetch_add`s); quantiles are read
/// with relaxed loads, so a report taken while shards are still scoring
/// is a consistent-enough snapshot, exact once they have joined.
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_ns: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    /// An empty histogram.
    pub fn new() -> Self {
        Self {
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
        }
    }

    /// Record one latency measurement in nanoseconds.
    pub fn record_ns(&self, ns: u64) {
        // Bucket b holds values with highest set bit b-1, i.e. the range
        // [2^(b-1), 2^b); ns == 0 lands in bucket 0.  The bucket is
        // bumped before the count so Σ buckets ≥ count in program order
        // (a racing quantile read may still see them out of order; see
        // `quantile_secs`).
        let b = (u64::BITS - ns.leading_zeros()) as usize;
        self.buckets[b.min(BUCKETS - 1)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Record one latency measurement from a [`Duration`].
    pub fn record(&self, d: Duration) {
        self.record_ns(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    /// Total number of recorded measurements.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean latency in seconds (0 when empty).
    pub fn mean_secs(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_ns.load(Ordering::Relaxed) as f64 / n as f64 / 1e9
    }

    /// Approximate `q`-quantile latency in seconds (bucket midpoint; 0
    /// when empty).  `q` is clamped to `[0, 1]`.
    pub fn quantile_secs(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target =
            ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        let mut top = 0usize;
        for (b, cell) in self.buckets.iter().enumerate() {
            let n = cell.load(Ordering::Relaxed);
            if n > 0 {
                top = b;
            }
            cum += n;
            if cum >= target {
                return Self::bucket_midpoint_secs(b);
            }
        }
        // A quantile racing an in-flight `record_ns` can observe `count`
        // ahead of the bucket array (relaxed loads); fall back to the
        // highest populated bucket rather than panicking.
        Self::bucket_midpoint_secs(top)
    }

    /// Representative latency for bucket `b` (midpoint of [2^(b-1), 2^b)).
    fn bucket_midpoint_secs(b: usize) -> f64 {
        if b == 0 {
            0.0
        } else {
            1.5 * 2f64.powi(b as i32 - 1) / 1e9
        }
    }

    /// Fold another histogram into this one (bucketwise add).  With
    /// [`LatencyHistogram::snapshot_and_reset`] this supports windowed
    /// quantiles: keep a lifetime accumulator, periodically drain a
    /// live histogram into it, and report quantiles of either the
    /// drained window or the merged whole.
    pub fn merge(&self, other: &LatencyHistogram) {
        for (mine, theirs) in self.buckets.iter().zip(&other.buckets) {
            let n = theirs.load(Ordering::Relaxed);
            if n > 0 {
                mine.fetch_add(n, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(other.count(), Ordering::Relaxed);
        self.sum_ns.fetch_add(other.sum_ns.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Atomically-per-cell drain this histogram into a fresh snapshot
    /// and zero it (each cell is `swap(0)`), returning the drained
    /// interval.  Concurrent `record_ns` calls are never lost: an
    /// increment lands either in the returned snapshot or in the
    /// reset histogram, so `snapshot.merge(live)` conserves totals.
    /// A racing record *can* straddle the swap (bucket in the
    /// snapshot, count in the residual), which the quantile walk
    /// already tolerates.
    pub fn snapshot_and_reset(&self) -> LatencyHistogram {
        let snap = LatencyHistogram::new();
        for (live, cell) in self.buckets.iter().zip(&snap.buckets) {
            let n = live.swap(0, Ordering::Relaxed);
            if n > 0 {
                cell.store(n, Ordering::Relaxed);
            }
        }
        let n = self.count.swap(0, Ordering::Relaxed);
        snap.count.store(n, Ordering::Relaxed);
        let s = self.sum_ns.swap(0, Ordering::Relaxed);
        snap.sum_ns.store(s, Ordering::Relaxed);
        snap
    }
}

/// Per-shard throughput counters (relaxed atomics, exact).
#[derive(Debug, Default)]
pub struct ShardCounters {
    /// Requests this shard scored.
    pub requests: AtomicU64,
    /// Microbatches this shard drained.
    pub batches: AtomicU64,
}

/// Shared serving telemetry: one latency histogram plus per-shard
/// counters, all recordable concurrently from scorer threads.
#[derive(Debug)]
pub struct ServeStats {
    /// End-to-end (enqueue → response) latency across all shards.
    pub latency: LatencyHistogram,
    shards: Vec<ShardCounters>,
    started: Instant,
}

impl ServeStats {
    /// Fresh stats for a pool of `shards` scorer threads.
    pub fn new(shards: usize) -> Self {
        Self {
            latency: LatencyHistogram::new(),
            shards: (0..shards.max(1)).map(|_| ShardCounters::default()).collect(),
            started: Instant::now(),
        }
    }

    /// Counters for shard `i`.
    pub fn shard(&self, i: usize) -> &ShardCounters {
        &self.shards[i]
    }

    /// Number of shards tracked.
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Total requests scored across all shards.
    pub fn total_requests(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.requests.load(Ordering::Relaxed))
            .sum()
    }

    /// Total microbatches drained across all shards.
    pub fn total_batches(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.batches.load(Ordering::Relaxed))
            .sum()
    }

    /// Seconds since the stats object was created.
    pub fn elapsed_secs(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Per-shard `(requests, batches)` snapshot.
    pub fn per_shard(&self) -> Vec<(u64, u64)> {
        self.shards
            .iter()
            .map(|s| {
                (
                    s.requests.load(Ordering::Relaxed),
                    s.batches.load(Ordering::Relaxed),
                )
            })
            .collect()
    }

    /// Snapshot a throughput/latency report.  Registry observability
    /// (`versions_alive`, `epoch`) is zero here — use
    /// [`ServeStats::report_for`] when a [`ModelRegistry`] is at hand.
    pub fn report(&self) -> ThroughputReport {
        let requests = self.total_requests();
        let batches = self.total_batches();
        let elapsed = self.elapsed_secs();
        ThroughputReport {
            requests,
            batches,
            shards: self.shards.len(),
            elapsed_secs: elapsed,
            qps: if elapsed > 0.0 {
                requests as f64 / elapsed
            } else {
                0.0
            },
            avg_batch: if batches > 0 {
                requests as f64 / batches as f64
            } else {
                0.0
            },
            mean_secs: self.latency.mean_secs(),
            p50_secs: self.latency.quantile_secs(0.50),
            p95_secs: self.latency.quantile_secs(0.95),
            p99_secs: self.latency.quantile_secs(0.99),
            versions_alive: 0,
            epoch: 0,
        }
    }

    /// [`ServeStats::report`] plus registry depth observability: how
    /// many model versions the registry is keeping alive for wait-free
    /// readers and which epoch is current — the first instrument for
    /// the ROADMAP's epoch-based-reclamation item.
    pub fn report_for(&self, registry: &ModelRegistry) -> ThroughputReport {
        ThroughputReport {
            versions_alive: registry.versions(),
            epoch: registry.epoch(),
            ..self.report()
        }
    }
}

/// One QPS + latency-percentile snapshot of a serving run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ThroughputReport {
    /// Requests scored.
    pub requests: u64,
    /// Microbatches drained.
    pub batches: u64,
    /// Scorer shards in the pool.
    pub shards: usize,
    /// Wall-clock seconds covered by the counters.
    pub elapsed_secs: f64,
    /// Requests per second.
    pub qps: f64,
    /// Mean requests per microbatch (coalescing factor).
    pub avg_batch: f64,
    /// Mean end-to-end latency (seconds).
    pub mean_secs: f64,
    /// Median end-to-end latency (seconds).
    pub p50_secs: f64,
    /// 95th-percentile latency (seconds).
    pub p95_secs: f64,
    /// 99th-percentile latency (seconds).
    pub p99_secs: f64,
    /// Model versions the registry retains for wait-free readers
    /// (0 when the report was taken without a registry).
    pub versions_alive: usize,
    /// Registry epoch of the currently served model.
    pub epoch: u64,
}

impl ThroughputReport {
    /// Render as the fixed-width table the CLI and benches print.
    pub fn render(&self) -> String {
        let mut t = TextTable::new(&[
            "shards", "requests", "batches", "avg_batch", "qps", "p50_ms",
            "p95_ms", "p99_ms", "epoch", "alive",
        ]);
        t.row(&[
            self.shards.to_string(),
            self.requests.to_string(),
            self.batches.to_string(),
            format!("{:.1}", self.avg_batch),
            format!("{:.0}", self.qps),
            format!("{:.3}", self.p50_secs * 1e3),
            format!("{:.3}", self.p95_secs * 1e3),
            format!("{:.3}", self.p99_secs * 1e3),
            self.epoch.to_string(),
            self.versions_alive.to_string(),
        ]);
        t.render()
    }

    /// JSON export (provenance logs).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("requests", Json::num(self.requests as f64)),
            ("batches", Json::num(self.batches as f64)),
            ("shards", Json::num(self.shards as f64)),
            ("elapsed_secs", Json::num(self.elapsed_secs)),
            ("qps", Json::num(self.qps)),
            ("avg_batch", Json::num(self.avg_batch)),
            ("mean_secs", Json::num(self.mean_secs)),
            ("p50_secs", Json::num(self.p50_secs)),
            ("p95_secs", Json::num(self.p95_secs)),
            ("p99_secs", Json::num(self.p99_secs)),
            ("versions_alive", Json::num(self.versions_alive as f64)),
            ("epoch", Json::num(self.epoch as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_ordered_and_sane() {
        let h = LatencyHistogram::new();
        // 90 fast (~1 µs) and 10 slow (~1 ms) measurements.
        for _ in 0..90 {
            h.record_ns(1_000);
        }
        for _ in 0..10 {
            h.record_ns(1_000_000);
        }
        assert_eq!(h.count(), 100);
        let p50 = h.quantile_secs(0.50);
        let p95 = h.quantile_secs(0.95);
        let p99 = h.quantile_secs(0.99);
        assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        // p50 in the microsecond regime, p95/p99 in the millisecond one.
        assert!(p50 < 1e-5, "p50 {p50}");
        assert!(p95 > 1e-4, "p95 {p95}");
        let mean = h.mean_secs();
        assert!((mean - (90.0 * 1e-6 + 10.0 * 1e-3) / 100.0).abs() < 1e-5);
    }

    #[test]
    fn histogram_empty_is_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile_secs(0.5), 0.0);
        assert_eq!(h.mean_secs(), 0.0);
    }

    #[test]
    fn merge_of_disjoint_buckets_preserves_both_populations() {
        let fast = LatencyHistogram::new();
        let slow = LatencyHistogram::new();
        for _ in 0..90 {
            fast.record_ns(1_000); // ~1 µs
        }
        for _ in 0..10 {
            slow.record_ns(1_000_000); // ~1 ms
        }
        fast.merge(&slow);
        assert_eq!(fast.count(), 100);
        // Quantiles of the merged histogram see both populations: the
        // median stays in the microsecond bucket, the tail moves to
        // the millisecond one.
        assert!(fast.quantile_secs(0.50) < 1e-5);
        assert!(fast.quantile_secs(0.95) > 1e-4);
        let want_mean = (90.0 * 1e-6 + 10.0 * 1e-3) / 100.0;
        assert!((fast.mean_secs() - want_mean).abs() < 1e-5);
        // The merge source is untouched.
        assert_eq!(slow.count(), 10);
    }

    #[test]
    fn snapshot_and_reset_drains_the_window() {
        let h = LatencyHistogram::new();
        for _ in 0..50 {
            h.record_ns(1_000);
        }
        let window = h.snapshot_and_reset();
        assert_eq!(window.count(), 50);
        assert!(window.quantile_secs(0.5) > 0.0);
        // The live histogram restarts empty: the next window sees only
        // what arrived after the reset (per-interval quantiles).
        assert_eq!(h.count(), 0);
        for _ in 0..5 {
            h.record_ns(1_000_000);
        }
        let next = h.snapshot_and_reset();
        assert_eq!(next.count(), 5);
        assert!(next.quantile_secs(0.5) > window.quantile_secs(0.5));
    }

    #[test]
    fn racing_reset_and_record_conserve_totals() {
        let h = std::sync::Arc::new(LatencyHistogram::new());
        let total = std::sync::Arc::new(LatencyHistogram::new());
        const WRITERS: u64 = 4;
        const PER_WRITER: u64 = 20_000;
        std::thread::scope(|s| {
            for _ in 0..WRITERS {
                let h = std::sync::Arc::clone(&h);
                s.spawn(move || {
                    for i in 0..PER_WRITER {
                        h.record_ns(1 + (i % 1_000));
                    }
                });
            }
            let h = std::sync::Arc::clone(&h);
            let total = std::sync::Arc::clone(&total);
            s.spawn(move || {
                // Reap windows while the writers are running.
                for _ in 0..100 {
                    total.merge(&h.snapshot_and_reset());
                    std::hint::spin_loop();
                }
            });
        });
        // Whatever the interleaving, every record lands exactly once:
        // reaped windows plus the residual account for all writes.
        total.merge(&h.snapshot_and_reset());
        assert_eq!(total.count(), WRITERS * PER_WRITER);
        let mut bucket_sum = 0u64;
        for b in &total.buckets {
            bucket_sum += b.load(Ordering::Relaxed);
        }
        assert_eq!(bucket_sum, WRITERS * PER_WRITER);
    }

    #[test]
    fn histogram_concurrent_records_are_lossless() {
        let h = std::sync::Arc::new(LatencyHistogram::new());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let h = std::sync::Arc::clone(&h);
                s.spawn(move || {
                    for i in 0..10_000u64 {
                        h.record_ns(1 + i % 1000);
                    }
                });
            }
        });
        assert_eq!(h.count(), 40_000);
    }

    #[test]
    fn histogram_single_sample_all_quantiles_agree() {
        let h = LatencyHistogram::new();
        h.record_ns(1_000);
        let v = h.quantile_secs(0.5);
        assert!(v > 0.0);
        // With one sample, every quantile (extremes included) lands in
        // the same bucket.
        for q in [0.0, 0.25, 0.5, 0.99, 1.0] {
            assert_eq!(h.quantile_secs(q), v, "q={q}");
        }
        assert!((h.mean_secs() - 1e-6).abs() < 1e-9);
    }

    #[test]
    fn histogram_extreme_quantiles_hit_first_and_last_bucket() {
        let h = LatencyHistogram::new();
        for _ in 0..10 {
            h.record_ns(1_000); // ~µs bucket
        }
        h.record_ns(1_000_000_000); // ~s bucket
        // q=0 clamps to the smallest recorded bucket, q=1 to the largest;
        // out-of-range q is clamped into [0, 1].
        assert!(h.quantile_secs(0.0) < 1e-5);
        assert!(h.quantile_secs(1.0) > 0.5);
        assert_eq!(h.quantile_secs(-3.0), h.quantile_secs(0.0));
        assert_eq!(h.quantile_secs(7.0), h.quantile_secs(1.0));
    }

    #[test]
    fn histogram_overflow_clamps_to_top_bucket() {
        let h = LatencyHistogram::new();
        // u64::MAX ns would index bucket 64; it must clamp to the
        // overflow bucket (63) instead of panicking.
        h.record_ns(u64::MAX);
        h.record_ns(u64::MAX - 1);
        assert_eq!(h.count(), 2);
        let top = h.quantile_secs(1.0);
        assert_eq!(top, 1.5 * 2f64.powi(62) / 1e9);
        assert_eq!(h.quantile_secs(0.0), top);
    }

    #[test]
    fn histogram_empty_extremes_are_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.quantile_secs(0.0), 0.0);
        assert_eq!(h.quantile_secs(1.0), 0.0);
    }

    #[test]
    fn histogram_concurrent_records_with_racing_reader() {
        // Writers hammer record_ns while a reader takes quantile
        // snapshots mid-flight: snapshots must never panic and the
        // final tallies must be exact.
        let h = std::sync::Arc::new(LatencyHistogram::new());
        let writers = 4;
        let per_writer = 25_000u64;
        std::thread::scope(|s| {
            for t in 0..writers {
                let h = std::sync::Arc::clone(&h);
                s.spawn(move || {
                    for i in 0..per_writer {
                        // Spread across several buckets per thread.
                        h.record_ns(1 + (i + t) % 100_000);
                    }
                });
            }
            let h = std::sync::Arc::clone(&h);
            s.spawn(move || {
                while h.count() < writers * per_writer {
                    let q = h.quantile_secs(0.99);
                    assert!(q >= 0.0);
                    std::hint::spin_loop();
                }
            });
        });
        assert_eq!(h.count(), writers * per_writer);
        let p0 = h.quantile_secs(0.0);
        let p100 = h.quantile_secs(1.0);
        assert!(p0 <= p100);
        assert!(p100 < 1e-3, "largest sample is < 100 µs");
    }

    #[test]
    fn report_for_carries_registry_depth() {
        use crate::coordinator::model_io::Model;
        let m = |tag: f64| Model {
            w: vec![tag; 2],
            loss: "hinge".into(),
            c: 1.0,
            solver: "test".into(),
            dataset: "toy".into(),
        };
        let reg = ModelRegistry::new(m(0.0), None);
        let stats = ServeStats::new(1);
        let r0 = stats.report_for(&reg);
        assert_eq!((r0.versions_alive, r0.epoch), (1, 0));
        reg.publish(m(1.0), None);
        reg.publish(m(2.0), None);
        let r = stats.report_for(&reg);
        assert_eq!((r.versions_alive, r.epoch), (3, 2));
        let j = r.to_json();
        assert_eq!(j.get("versions_alive").unwrap().as_usize().unwrap(), 3);
        assert_eq!(j.get("epoch").unwrap().as_usize().unwrap(), 2);
        assert!(r.render().contains("alive"));
        // Registry-less reports stay well defined.
        assert_eq!(stats.report().versions_alive, 0);
    }

    #[test]
    fn report_math() {
        let stats = ServeStats::new(2);
        stats.shard(0).requests.fetch_add(30, Ordering::Relaxed);
        stats.shard(0).batches.fetch_add(3, Ordering::Relaxed);
        stats.shard(1).requests.fetch_add(10, Ordering::Relaxed);
        stats.shard(1).batches.fetch_add(2, Ordering::Relaxed);
        for _ in 0..40 {
            stats.latency.record_ns(10_000);
        }
        let r = stats.report();
        assert_eq!(r.requests, 40);
        assert_eq!(r.batches, 5);
        assert_eq!(r.shards, 2);
        assert!((r.avg_batch - 8.0).abs() < 1e-12);
        assert!(r.qps > 0.0);
        assert_eq!(stats.per_shard(), vec![(30, 3), (10, 2)]);
        let rendered = r.render();
        assert!(rendered.contains("qps"));
        let j = r.to_json().to_pretty();
        let back = Json::parse(&j).unwrap();
        assert_eq!(back.get("requests").unwrap().as_usize().unwrap(), 40);
    }
}
