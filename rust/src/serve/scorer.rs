//! Sharded scorer pool: worker threads drain microbatches from the
//! [`Batcher`](super::batcher::Batcher) and score them against the
//! registry's live model.
//!
//! Each shard reads the registry **once per microbatch** — the whole
//! batch is scored against one consistent
//! [`ModelVersion`](super::registry::ModelVersion) snapshot, so
//! a hot-swap landing mid-batch affects only subsequent batches (and a
//! swap can never block a shard: registry reads are wait-free).  Shards
//! reuse [`crate::util::affinity`] pinning, same as the solver's worker
//! threads (paper §3.3 "Thread Affinity"), and each scored row runs the
//! same fused, 4-way-unrolled sparse dot as the training loop
//! ([`Model::margin`](crate::coordinator::Model::margin) →
//! `data::sparse::dot_sparse_checked`).

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::util::affinity;

use super::batcher::{Batcher, Prediction};
use super::registry::ModelRegistry;
use super::stats::ServeStats;

/// Scorer pool configuration.
#[derive(Debug, Clone)]
pub struct ScorerConfig {
    /// Worker threads (each drains whole microbatches).
    pub shards: usize,
    /// Pin shard `t` to core `t % online_cpus()`.
    pub pin_threads: bool,
}

impl Default for ScorerConfig {
    fn default() -> Self {
        Self { shards: 4, pin_threads: false }
    }
}

/// A running pool of scorer shards.
///
/// Shards exit when the batcher is closed and drained; [`ShardPool::join`]
/// then reaps them.  Dropping the pool without joining detaches the
/// threads (they still exit on close).
#[derive(Debug)]
pub struct ShardPool {
    handles: Vec<JoinHandle<()>>,
}

impl ShardPool {
    /// Spawn `cfg.shards` scorer threads over a shared queue.
    pub fn start(
        registry: Arc<ModelRegistry>,
        batcher: Arc<Batcher>,
        stats: Arc<ServeStats>,
        cfg: &ScorerConfig,
    ) -> ShardPool {
        assert!(
            stats.shards() >= cfg.shards.max(1),
            "ServeStats sized for {} shards, pool wants {}",
            stats.shards(),
            cfg.shards
        );
        let handles = (0..cfg.shards.max(1))
            .map(|t| {
                let registry = Arc::clone(&registry);
                let batcher = Arc::clone(&batcher);
                let stats = Arc::clone(&stats);
                let pin = cfg.pin_threads;
                std::thread::Builder::new()
                    .name(format!("scorer-{t}"))
                    .spawn(move || {
                        if pin {
                            affinity::pin_current_thread(t);
                        }
                        while let Some(batch) = batcher.next_batch() {
                            // One wait-free registry read per batch: the
                            // microbatch scores against one snapshot.
                            let version = registry.current();
                            for req in &batch {
                                let margin =
                                    version.model.margin(&req.idx, &req.vals);
                                req.fulfil(Prediction {
                                    margin,
                                    label: if margin > 0.0 { 1.0 } else { -1.0 },
                                    model_epoch: version.epoch,
                                });
                                stats.latency.record(req.enqueued.elapsed());
                            }
                            let shard = stats.shard(t);
                            shard
                                .requests
                                .fetch_add(batch.len() as u64, Ordering::Relaxed);
                            shard.batches.fetch_add(1, Ordering::Relaxed);
                        }
                    })
                    .expect("spawn scorer shard")
            })
            .collect();
        ShardPool { handles }
    }

    /// Number of shards in the pool.
    pub fn shards(&self) -> usize {
        self.handles.len()
    }

    /// Wait for every shard to exit (call after closing the batcher).
    pub fn join(self) {
        for h in self.handles {
            h.join().expect("scorer shard panicked");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::model_io::Model;
    use std::time::Duration;

    fn registry(w: Vec<f64>) -> Arc<ModelRegistry> {
        Arc::new(ModelRegistry::new(
            Model {
                w,
                loss: "hinge".into(),
                c: 1.0,
                solver: "test".into(),
                dataset: "toy".into(),
            },
            None,
        ))
    }

    #[test]
    fn pool_scores_and_counts_then_exits_on_close() {
        let reg = registry(vec![1.0, -2.0, 0.5]);
        let batcher = Arc::new(Batcher::new(4, Duration::from_millis(1)));
        let stats = Arc::new(ServeStats::new(2));
        let pool = ShardPool::start(
            Arc::clone(&reg),
            Arc::clone(&batcher),
            Arc::clone(&stats),
            &ScorerConfig { shards: 2, pin_threads: false },
        );
        assert_eq!(pool.shards(), 2);
        let tickets: Vec<_> = (0..20)
            .map(|i| {
                // row = e_{i mod 3}: margin = w[i mod 3]
                batcher.submit(vec![(i % 3) as u32], vec![1.0])
            })
            .collect();
        for (i, t) in tickets.into_iter().enumerate() {
            let p = t
                .wait_timeout(Duration::from_secs(30))
                .expect("request dropped");
            let want = [1.0, -2.0, 0.5][i % 3];
            assert_eq!(p.margin, want);
            assert_eq!(p.label, if want > 0.0 { 1.0 } else { -1.0 });
            assert_eq!(p.model_epoch, 0);
        }
        batcher.close();
        pool.join();
        assert_eq!(stats.total_requests(), 20);
        assert!(stats.total_batches() >= 5, "20 reqs / max_batch 4");
        assert_eq!(stats.latency.count(), 20);
    }

    #[test]
    fn out_of_range_features_score_zero() {
        let reg = registry(vec![1.0]);
        let batcher = Arc::new(Batcher::new(2, Duration::from_millis(0)));
        let stats = Arc::new(ServeStats::new(1));
        let pool = ShardPool::start(
            reg,
            Arc::clone(&batcher),
            stats,
            &ScorerConfig { shards: 1, pin_threads: false },
        );
        let t = batcher.submit(vec![5], vec![9.0]); // feature 5 ∉ model
        let p = t.wait_timeout(Duration::from_secs(30)).expect("dropped");
        assert_eq!(p.margin, 0.0);
        assert_eq!(p.label, -1.0);
        batcher.close();
        pool.join();
    }
}
