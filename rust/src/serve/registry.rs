//! Versioned in-process model registry with atomic hot-swap.
//!
//! The serving claim piggybacks on the paper's Theorem 3: PASSCoDe-Wild
//! already proves that a `ŵ` read under racy, unsynchronized updates is
//! the exact solution of a *perturbed* primal — so scorer threads may
//! read the live model without locks while trainer threads publish new
//! ones.  [`ModelRegistry`] makes the publish itself atomic: a reader
//! sees either the old version or the new one, never a torn mix.
//!
//! Mechanics (manifest-registry idiom, SNIPPETS.md): every published
//! version is an immutable [`ModelVersion`] behind an `Arc`; the
//! registry keeps one epoch-tagged atomic pointer to the current
//! version.  **Readers never block** — [`ModelRegistry::current`] is a
//! relaxed-cost atomic load plus a reference-count bump; publishers
//! serialize only against each other on a mutex that readers never
//! touch.  Safety rests on a retention rule: the registry's `history`
//! holds every version it has ever pointed at alive until the registry
//! itself drops, so the pointer a reader loads is always valid (version
//! payloads are a few `Vec<f64>`s; a serving process that publishes once
//! per training round retains megabytes, not gigabytes).

use std::sync::atomic::{AtomicPtr, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::coordinator::model_io::Model;

/// One immutable published model version.
#[derive(Debug, Clone)]
pub struct ModelVersion {
    /// Registry epoch: 0 for the initial model, +1 per publish.
    pub epoch: u64,
    /// The model scorers read (`Model::margin` on the live `ŵ`).
    pub model: Model,
    /// Optional dual iterate paired with `model.w` — the warm-start
    /// state the online trainer's `TrainSession` resumes from.
    pub alpha: Option<Vec<f64>>,
}

/// Versioned model store with wait-free reads and atomic publishes.
pub struct ModelRegistry {
    /// Pointer to the current version's payload.  Every pointer ever
    /// stored here comes from an `Arc` retained in `history`.
    current: AtomicPtr<ModelVersion>,
    /// All versions ever published, in epoch order.  Keeps reader-visible
    /// payloads alive for the registry's lifetime (see module docs) and
    /// serializes publishers.
    history: Mutex<Vec<Arc<ModelVersion>>>,
    /// Epoch of the current version (monotone).
    epoch: AtomicU64,
}

impl ModelRegistry {
    /// Create a registry serving `model` at epoch 0.
    pub fn new(model: Model, alpha: Option<Vec<f64>>) -> ModelRegistry {
        let v = Arc::new(ModelVersion { epoch: 0, model, alpha });
        let ptr = Arc::as_ptr(&v) as *mut ModelVersion;
        ModelRegistry {
            current: AtomicPtr::new(ptr),
            history: Mutex::new(vec![v]),
            epoch: AtomicU64::new(0),
        }
    }

    /// Publish a new version and return its epoch.  Publishers serialize
    /// on the history lock; readers observe the swap atomically and are
    /// never blocked by it.
    pub fn publish(&self, model: Model, alpha: Option<Vec<f64>>) -> u64 {
        let mut history = self.history.lock().expect("registry poisoned");
        let epoch = self.epoch.load(Ordering::Relaxed) + 1;
        let v = Arc::new(ModelVersion { epoch, model, alpha });
        let ptr = Arc::as_ptr(&v) as *mut ModelVersion;
        // Retain before exposing: the pointer must already be backed by
        // `history` when a reader can first observe it.
        history.push(v);
        self.current.store(ptr, Ordering::Release);
        self.epoch.store(epoch, Ordering::Release);
        epoch
    }

    /// The current version — wait-free (one atomic load + one refcount
    /// increment, no locks).  The returned `Arc` stays valid even if a
    /// newer version is published immediately after.
    pub fn current(&self) -> Arc<ModelVersion> {
        let ptr = self.current.load(Ordering::Acquire);
        // SAFETY: `ptr` was produced by `Arc::as_ptr` on a version that
        // `history` retains until the registry drops (retention rule,
        // module docs), so it is a valid `Arc<ModelVersion>` allocation
        // with strong count ≥ 1 for the whole call; bumping the count
        // before `from_raw` hands the caller its own owned handle.
        unsafe {
            Arc::increment_strong_count(ptr);
            Arc::from_raw(ptr)
        }
    }

    /// Epoch of the current version (0 until the first publish).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// Number of versions retained (initial model included).
    pub fn versions(&self) -> usize {
        self.history.lock().expect("registry poisoned").len()
    }

    /// A past version by epoch (None if out of range).
    pub fn version(&self, epoch: u64) -> Option<Arc<ModelVersion>> {
        self.history
            .lock()
            .expect("registry poisoned")
            .get(epoch as usize)
            .cloned()
    }
}

impl std::fmt::Debug for ModelRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "ModelRegistry(epoch={}, versions={})",
            self.epoch(),
            self.versions()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(tag: f64, d: usize) -> Model {
        Model {
            w: vec![tag; d],
            loss: "hinge".into(),
            c: 1.0,
            solver: "test".into(),
            dataset: "toy".into(),
        }
    }

    #[test]
    fn initial_version_is_epoch_zero() {
        let r = ModelRegistry::new(model(1.0, 3), None);
        let v = r.current();
        assert_eq!(v.epoch, 0);
        assert_eq!(v.model.w, vec![1.0; 3]);
        assert_eq!(r.epoch(), 0);
        assert_eq!(r.versions(), 1);
    }

    #[test]
    fn publish_swaps_current_and_bumps_epoch() {
        let r = ModelRegistry::new(model(1.0, 2), None);
        assert_eq!(r.publish(model(2.0, 2), None), 1);
        assert_eq!(r.publish(model(3.0, 2), Some(vec![0.5])), 2);
        let v = r.current();
        assert_eq!(v.epoch, 2);
        assert_eq!(v.model.w, vec![3.0; 2]);
        assert_eq!(v.alpha, Some(vec![0.5]));
        assert_eq!(r.versions(), 3);
        // Old versions remain reachable by epoch.
        assert_eq!(r.version(1).unwrap().model.w, vec![2.0; 2]);
        assert!(r.version(9).is_none());
    }

    #[test]
    fn old_handles_survive_later_publishes() {
        let r = ModelRegistry::new(model(1.0, 2), None);
        let old = r.current();
        r.publish(model(2.0, 2), None);
        // The pre-swap handle still reads the old payload.
        assert_eq!(old.model.w, vec![1.0; 2]);
        assert_eq!(r.current().model.w, vec![2.0; 2]);
    }

    #[test]
    fn concurrent_readers_see_only_whole_versions() {
        // Publisher hammers swaps while readers spin on `current`; every
        // observed version must be internally consistent (w filled with
        // its epoch tag) and epochs must be monotone per reader.
        let r = std::sync::Arc::new(ModelRegistry::new(model(0.0, 16), None));
        let publishes = 200u64;
        std::thread::scope(|s| {
            let rp = std::sync::Arc::clone(&r);
            s.spawn(move || {
                for e in 1..=publishes {
                    rp.publish(model(e as f64, 16), None);
                }
            });
            for _ in 0..3 {
                let rr = std::sync::Arc::clone(&r);
                s.spawn(move || {
                    let mut last = 0u64;
                    loop {
                        let v = rr.current();
                        assert!(
                            v.model.w.iter().all(|&x| x == v.epoch as f64),
                            "torn read at epoch {}",
                            v.epoch
                        );
                        assert!(v.epoch >= last, "epoch went backwards");
                        last = v.epoch;
                        if v.epoch == publishes {
                            break;
                        }
                        std::hint::spin_loop();
                    }
                });
            }
        });
        assert_eq!(r.versions() as u64, publishes + 1);
    }
}
