//! Microbatching request queue: scoring requests are coalesced up to a
//! batch-size / latency budget and scored in one sparse pass.
//!
//! A [`Batcher::submit`] hands back a [`Ticket`] immediately; scorer
//! shards call [`Batcher::next_batch`], which blocks until work arrives,
//! then gives late arrivals up to `max_wait` (measured from the oldest
//! queued request, so the budget is a hard bound on queueing delay) to
//! fill the batch before draining up to `max_batch` requests.  One
//! registry read then scores the whole batch against a consistent model
//! snapshot (`serve::scorer`).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// The scored outcome of one request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Prediction {
    /// Raw margin `w·x` under the model that scored the request.
    pub margin: f64,
    /// Predicted label: `+1` if `margin > 0`, else `-1`.
    pub label: f64,
    /// Epoch of the registry version that scored it (observing this span
    /// a hot-swap is how tests prove mid-stream publishes land).
    pub model_epoch: u64,
}

/// One-shot response slot (hand-rolled oneshot: no channels in std that
/// fit the fulfil-from-any-shard shape better than a mutex + condvar).
#[derive(Debug, Default)]
struct Slot {
    ready: Mutex<Option<Prediction>>,
    cv: Condvar,
}

/// The caller's handle to an in-flight request.
#[derive(Debug)]
pub struct Ticket {
    slot: Arc<Slot>,
}

impl Ticket {
    /// Block until the request is scored.
    pub fn wait(self) -> Prediction {
        let mut g = self.slot.ready.lock().expect("slot poisoned");
        while g.is_none() {
            g = self.slot.cv.wait(g).expect("slot poisoned");
        }
        g.take().expect("checked above")
    }

    /// Block up to `timeout`; `None` if the request is still in flight
    /// (used by tests so a dropped request fails fast instead of
    /// hanging).
    pub fn wait_timeout(self, timeout: Duration) -> Option<Prediction> {
        let deadline = Instant::now() + timeout;
        let mut g = self.slot.ready.lock().expect("slot poisoned");
        while g.is_none() {
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (ng, _) = self
                .slot
                .cv
                .wait_timeout(g, deadline - now)
                .expect("slot poisoned");
            g = ng;
        }
        g.take()
    }
}

/// One queued scoring request: a raw (unfolded) sparse row plus the
/// response slot and its enqueue time (for end-to-end latency).
#[derive(Debug)]
pub struct ScoreRequest {
    /// Sparse feature indices (strictly increasing).
    pub idx: Vec<u32>,
    /// Values parallel to `idx`.
    pub vals: Vec<f64>,
    /// When the request entered the queue.
    pub enqueued: Instant,
    slot: Arc<Slot>,
}

impl ScoreRequest {
    /// Deliver the prediction to the waiting ticket.
    pub fn fulfil(&self, p: Prediction) {
        let mut g = self.slot.ready.lock().expect("slot poisoned");
        *g = Some(p);
        self.slot.cv.notify_one();
    }
}

#[derive(Debug, Default)]
struct Queue {
    q: VecDeque<ScoreRequest>,
    closed: bool,
}

/// The microbatching queue shared between submitters and scorer shards.
#[derive(Debug)]
pub struct Batcher {
    inner: Mutex<Queue>,
    not_empty: Condvar,
    max_batch: usize,
    max_wait: Duration,
    submitted: AtomicU64,
}

impl Batcher {
    /// A queue that coalesces up to `max_batch` requests, waiting at
    /// most `max_wait` past the oldest request's arrival to fill up.
    pub fn new(max_batch: usize, max_wait: Duration) -> Batcher {
        Batcher {
            inner: Mutex::new(Queue::default()),
            not_empty: Condvar::new(),
            max_batch: max_batch.max(1),
            max_wait,
            submitted: AtomicU64::new(0),
        }
    }

    /// Enqueue a raw sparse row for scoring; returns immediately.
    ///
    /// Panics if the batcher was closed — closing is the caller's own
    /// end-of-stream signal, so a submit afterwards is a logic error
    /// (better a loud panic than a ticket that never resolves).
    pub fn submit(&self, idx: Vec<u32>, vals: Vec<f64>) -> Ticket {
        let slot = Arc::new(Slot::default());
        let req = ScoreRequest {
            idx,
            vals,
            enqueued: Instant::now(),
            slot: Arc::clone(&slot),
        };
        {
            let mut g = self.inner.lock().expect("batcher poisoned");
            assert!(!g.closed, "submit on a closed Batcher");
            g.q.push_back(req);
            self.not_empty.notify_one();
        }
        self.submitted.fetch_add(1, Ordering::Relaxed);
        Ticket { slot }
    }

    /// Signal end-of-stream: blocked shards drain what is queued and
    /// then [`Batcher::next_batch`] returns `None` so they can exit.
    pub fn close(&self) {
        let mut g = self.inner.lock().expect("batcher poisoned");
        g.closed = true;
        self.not_empty.notify_all();
    }

    /// Whether [`Batcher::close`] has been called.
    pub fn is_closed(&self) -> bool {
        self.inner.lock().expect("batcher poisoned").closed
    }

    /// Requests submitted so far.
    pub fn submitted(&self) -> u64 {
        self.submitted.load(Ordering::Relaxed)
    }

    /// Requests currently queued (not yet drained into a batch).
    pub fn depth(&self) -> usize {
        self.inner.lock().expect("batcher poisoned").q.len()
    }

    /// Blocking drain of the next microbatch; `None` once the batcher is
    /// closed *and* empty (shard exit signal).
    pub fn next_batch(&self) -> Option<Vec<ScoreRequest>> {
        let mut g = self.inner.lock().expect("batcher poisoned");
        'restart: loop {
            loop {
                if !g.q.is_empty() {
                    break;
                }
                if g.closed {
                    return None;
                }
                g = self.not_empty.wait(g).expect("batcher poisoned");
            }
            // Coalesce: wait out the latency budget (anchored at the
            // oldest request so no request queues longer than `max_wait`
            // on our account) unless the batch fills or the stream
            // closes first.
            let deadline =
                g.q.front().expect("nonempty").enqueued + self.max_wait;
            while g.q.len() < self.max_batch && !g.closed {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (ng, timed_out) = self
                    .not_empty
                    .wait_timeout(g, deadline - now)
                    .expect("batcher poisoned");
                g = ng;
                if timed_out.timed_out() {
                    break;
                }
            }
            if g.q.is_empty() {
                // A competing shard drained the queue while this one was
                // waiting out the budget (the lock is released inside
                // `wait_timeout`); go back to sleep instead of handing
                // out an empty batch.
                continue 'restart;
            }
            let take = g.q.len().min(self.max_batch);
            let batch: Vec<ScoreRequest> = g.q.drain(..take).collect();
            if !g.q.is_empty() {
                // Hand the remainder to another waiting shard.
                self.not_empty.notify_one();
            }
            return Some(batch);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fulfil_all(batch: &[ScoreRequest], epoch: u64) {
        for r in batch {
            r.fulfil(Prediction { margin: 1.0, label: 1.0, model_epoch: epoch });
        }
    }

    #[test]
    fn single_request_round_trip() {
        let b = Batcher::new(8, Duration::from_millis(0));
        let t = b.submit(vec![0, 3], vec![1.0, -2.0]);
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].idx, vec![0, 3]);
        fulfil_all(&batch, 7);
        let p = t.wait();
        assert_eq!(p.model_epoch, 7);
    }

    #[test]
    fn queued_requests_coalesce_into_batches() {
        let b = Batcher::new(4, Duration::from_millis(0));
        let tickets: Vec<Ticket> =
            (0..10).map(|i| b.submit(vec![i as u32], vec![1.0])).collect();
        assert_eq!(b.depth(), 10);
        assert_eq!(b.submitted(), 10);
        let mut sizes = Vec::new();
        for _ in 0..3 {
            let batch = b.next_batch().unwrap();
            sizes.push(batch.len());
            fulfil_all(&batch, 0);
        }
        assert_eq!(sizes, vec![4, 4, 2]);
        for t in tickets {
            assert!(t.wait_timeout(Duration::from_secs(5)).is_some());
        }
    }

    #[test]
    fn close_drains_then_signals_exit() {
        let b = Batcher::new(4, Duration::from_millis(0));
        let t = b.submit(vec![0], vec![1.0]);
        b.close();
        assert!(b.is_closed());
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        fulfil_all(&batch, 0);
        t.wait();
        assert!(b.next_batch().is_none());
        assert!(b.next_batch().is_none(), "None must be sticky");
    }

    #[test]
    fn next_batch_blocks_until_submit() {
        let b = Arc::new(Batcher::new(2, Duration::from_millis(0)));
        std::thread::scope(|s| {
            let bc = Arc::clone(&b);
            let h = s.spawn(move || bc.next_batch());
            std::thread::sleep(Duration::from_millis(20));
            let t = b.submit(vec![1], vec![2.0]);
            let batch = h.join().unwrap().unwrap();
            assert_eq!(batch.len(), 1);
            fulfil_all(&batch, 0);
            t.wait();
        });
    }

    #[test]
    fn latency_budget_waits_for_stragglers() {
        // First request arrives alone; a straggler lands inside the
        // budget window and must ride the same batch.
        let b = Arc::new(Batcher::new(8, Duration::from_millis(200)));
        std::thread::scope(|s| {
            let bc = Arc::clone(&b);
            let straggler = s.spawn(move || {
                std::thread::sleep(Duration::from_millis(30));
                bc.submit(vec![2], vec![1.0])
            });
            let t0 = b.submit(vec![1], vec![1.0]);
            let batch = b.next_batch().unwrap();
            assert_eq!(batch.len(), 2, "straggler missed the batch");
            fulfil_all(&batch, 0);
            t0.wait();
            straggler.join().unwrap().wait();
        });
    }

    #[test]
    #[should_panic(expected = "closed Batcher")]
    fn submit_after_close_panics() {
        let b = Batcher::new(2, Duration::from_millis(0));
        b.close();
        let _ = b.submit(vec![0], vec![1.0]);
    }
}
