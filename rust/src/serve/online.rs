//! Async continuous training: PASSCoDe-Wild epochs over a stream of
//! freshly labeled rows, warm-started from the registry's live `(α, ŵ)`
//! and published back via atomic hot-swap.
//!
//! This is the paper's shared-memory asynchrony repurposed for the serve
//! path (the Hybrid-DCA / AsySCD observation): scorer threads read `w`
//! lock-free while trainer threads keep folding in new examples —
//! Theorem 3's backward-error analysis is what licenses predicting with
//! a `ŵ` that racy updates perturbed.  The trainer keeps a sliding
//! window of the most recent labeled rows with a per-row dual iterate
//! `α`; each round opens a [`crate::solver::TrainSession`], resumes it
//! from a [`Checkpoint`] built of the live model's `ŵ` and the window's
//! `α`, runs it under `run_until(Deadline)` so retraining respects the
//! serving latency budget, and publishes the result
//! ([`ModelRegistry::publish`]) without ever blocking scorers.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::model_io::Model;
use crate::data::{CsrMatrix, Dataset, Entry};
use crate::loss::LossKind;
use crate::solver::{
    Checkpoint, MemoryModel, PasscodeSolver, Solver, SolveOptions, StopWhen,
};

use super::registry::ModelRegistry;

/// Online-trainer configuration.
#[derive(Debug, Clone)]
pub struct OnlineConfig {
    /// PASSCoDe-Wild epochs per training round (each round publishes).
    pub epochs_per_round: usize,
    /// Solver worker threads per round.
    pub threads: usize,
    /// Most recent labeled rows retained in the sliding window.
    pub max_window: usize,
    /// Base RNG seed (xor-ed with the round counter).
    pub seed: u64,
    /// Wall-clock budget per training round: the round's session stops
    /// at `now + round_budget` (epoch-granular — an epoch in flight
    /// finishes) even if `epochs_per_round` epochs have not all run, so
    /// a retrain can never blow the serving latency budget.  The default
    /// is effectively unbounded.
    pub round_budget: Duration,
}

impl Default for OnlineConfig {
    fn default() -> Self {
        Self {
            epochs_per_round: 2,
            threads: 1,
            max_window: 4096,
            seed: 42,
            round_budget: Duration::from_secs(3600),
        }
    }
}

/// One labeled raw (unfolded) row awaiting training.
#[derive(Debug, Clone)]
struct LabeledRow {
    idx: Vec<u32>,
    vals: Vec<f64>,
    label: f64,
}

#[derive(Debug, Default)]
struct Window {
    rows: VecDeque<LabeledRow>,
    /// Dual iterate per window row, parallel to `rows` (warm-start state;
    /// new rows enter at `α = 0`, evicted rows take their α with them).
    alpha: VecDeque<f64>,
    /// Rows evicted since construction (aligns write-backs after a round
    /// trained on a snapshot that has since slid).
    evicted: u64,
}

/// The continuous trainer.
///
/// Thread-safe: `ingest` may race with a concurrent `train_round` (the
/// window is briefly locked to snapshot / write back); run one training
/// loop per registry — rounds are not meant to run concurrently with
/// each other.
#[derive(Debug)]
pub struct OnlineTrainer {
    registry: Arc<ModelRegistry>,
    loss: LossKind,
    c: f64,
    cfg: OnlineConfig,
    window: Mutex<Window>,
    rounds: AtomicU64,
    ingested: AtomicU64,
}

impl OnlineTrainer {
    /// A trainer feeding `registry`, optimizing `loss` with penalty `c`
    /// (both must match the loss the served model was trained with).
    pub fn new(
        registry: Arc<ModelRegistry>,
        loss: LossKind,
        c: f64,
        cfg: OnlineConfig,
    ) -> OnlineTrainer {
        assert!(cfg.max_window > 0, "max_window must be positive");
        assert!(c > 0.0, "penalty C must be positive");
        OnlineTrainer {
            registry,
            loss,
            c,
            cfg,
            window: Mutex::new(Window::default()),
            rounds: AtomicU64::new(0),
            ingested: AtomicU64::new(0),
        }
    }

    /// Feed one freshly labeled raw row (indices strictly increasing;
    /// any label > 0 maps to +1, else −1).  Oldest rows are evicted once
    /// the window is full.
    pub fn ingest(&self, idx: Vec<u32>, vals: Vec<f64>, label: f64) {
        debug_assert!(
            idx.windows(2).all(|w| w[0] < w[1]),
            "row indices must be strictly increasing"
        );
        debug_assert_eq!(idx.len(), vals.len());
        let label = if label > 0.0 { 1.0 } else { -1.0 };
        let mut w = self.window.lock().expect("window poisoned");
        if w.rows.len() == self.cfg.max_window {
            w.rows.pop_front();
            w.alpha.pop_front();
            w.evicted += 1;
        }
        w.rows.push_back(LabeledRow { idx, vals, label });
        w.alpha.push_back(0.0);
        drop(w);
        self.ingested.fetch_add(1, Ordering::Release);
    }

    /// Total rows ever ingested (monotone; drives [`Self::spawn_loop`]'s
    /// "only retrain on new data" gate).
    pub fn ingested(&self) -> u64 {
        self.ingested.load(Ordering::Acquire)
    }

    /// Rows currently buffered in the window.
    pub fn buffered(&self) -> usize {
        self.window.lock().expect("window poisoned").rows.len()
    }

    /// Training rounds completed so far.
    pub fn rounds(&self) -> u64 {
        self.rounds.load(Ordering::Relaxed)
    }

    /// Run one training round under the configured `round_budget`
    /// deadline.  See [`OnlineTrainer::train_round_with_deadline`].
    pub fn train_round(&self) -> Option<u64> {
        self.train_round_with_deadline(Instant::now() + self.cfg.round_budget)
    }

    /// Run one training round: snapshot the window, open a PASSCoDe-Wild
    /// `TrainSession`, resume it from a checkpoint of the registry's
    /// live `ŵ` plus the window's `α`, run it with
    /// `run_until(Deadline(deadline))` (at most `epochs_per_round`
    /// epochs), write the updated `α` back to surviving window rows, and
    /// publish the new model.
    ///
    /// A deadline already in the past publishes the resumed state
    /// unchanged — accumulated dual state is never lost to a missed
    /// budget.  Returns the published epoch, or `None` if the window is
    /// empty.  Scorers are never blocked: the only lock taken is the
    /// trainer's own window mutex (shared with `ingest`, not with
    /// scoring).
    pub fn train_round_with_deadline(&self, deadline: Instant) -> Option<u64> {
        // ---- snapshot the window ------------------------------------
        let (snapshot, alpha0, snap_evicted) = {
            let w = self.window.lock().expect("window poisoned");
            if w.rows.is_empty() {
                return None;
            }
            (
                w.rows.iter().cloned().collect::<Vec<LabeledRow>>(),
                w.alpha.iter().copied().collect::<Vec<f64>>(),
                w.evicted,
            )
        };
        let base = self.registry.current();
        let d = base.model.w.len();

        // ---- build the folded window dataset (x_i = y_i ẋ_i) --------
        let folded: Vec<Vec<Entry>> = snapshot
            .iter()
            .map(|r| {
                r.idx
                    .iter()
                    .zip(&r.vals)
                    .filter(|(&j, _)| (j as usize) < d)
                    .map(|(&j, &v)| Entry { index: j, value: r.label * v })
                    .collect()
            })
            .collect();
        let labels: Vec<f64> = snapshot.iter().map(|r| r.label).collect();
        let ds = Dataset::new(
            CsrMatrix::from_rows(&folded, d),
            labels,
            "online-window",
        );

        // ---- deadline-bounded Wild session, resumed warm ------------
        let round = self.rounds.fetch_add(1, Ordering::Relaxed);
        let seed = self.cfg.seed ^ (round.wrapping_mul(0x9E37_79B9));
        let opts = SolveOptions {
            epochs: self.cfg.epochs_per_round.max(1),
            threads: self.cfg.threads.max(1),
            seed,
            eval_every: 0,
            ..Default::default()
        };
        let solver = PasscodeSolver(MemoryModel::Wild);
        let mut session = solver
            .session(&ds, self.loss, self.c, opts)
            .expect("open online Wild session");
        let ckpt = Checkpoint {
            solver: solver.name().to_string(),
            loss: self.loss.name().to_string(),
            c: self.c,
            seed,
            epochs_done: 0,
            updates: 0,
            alpha: alpha0,
            w_hat: base.model.w.clone(),
            shrink: None,
        };
        session.resume(&ckpt).expect("resume online checkpoint");
        session
            .run_until(StopWhen::Deadline(deadline))
            .expect("online training round");
        let r = session.into_result();

        // ---- write α back to window rows that survived --------------
        {
            let mut w = self.window.lock().expect("window poisoned");
            let shift = (w.evicted - snap_evicted) as usize;
            for (i, &a) in r.alpha.iter().enumerate().skip(shift) {
                let pos = i - shift;
                if pos < w.alpha.len() {
                    w.alpha[pos] = a;
                }
            }
        }

        // ---- publish (atomic hot-swap; scorers never block) ---------
        let model = Model {
            w: r.w_hat,
            loss: base.model.loss.clone(),
            c: base.model.c,
            solver: "online-passcode-wild".into(),
            dataset: base.model.dataset.clone(),
        };
        Some(self.registry.publish(model, Some(r.alpha)))
    }

    /// Spawn the continuous-training loop: a round runs whenever at
    /// least `min_rows` rows are buffered *and* new rows have arrived
    /// since the previous round, until `stop` is raised.  Returns the
    /// loop's join handle.
    ///
    /// The new-data gate matters for long-running servers: without it
    /// a full-but-quiet window would retrain on identical data
    /// back-to-back, pegging a core and publishing an unbounded stream
    /// of versions into the registry's retained history.
    pub fn spawn_loop(
        trainer: Arc<OnlineTrainer>,
        stop: Arc<AtomicBool>,
        min_rows: usize,
    ) -> JoinHandle<u64> {
        std::thread::Builder::new()
            .name("online-trainer".into())
            .spawn(move || {
                let mut published = 0u64;
                let mut trained_at = 0u64;
                while !stop.load(Ordering::Acquire) {
                    let ingested = trainer.ingested();
                    if trainer.buffered() >= min_rows.max(1)
                        && ingested != trained_at
                    {
                        trained_at = ingested;
                        if trainer.train_round().is_some() {
                            published += 1;
                        }
                    } else {
                        std::thread::sleep(Duration::from_micros(500));
                    }
                }
                published
            })
            .expect("spawn online trainer")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::registry as data_registry;
    use crate::eval;

    fn zero_registry(d: usize, c: f64) -> Arc<ModelRegistry> {
        Arc::new(ModelRegistry::new(
            Model {
                w: vec![0.0; d],
                loss: "hinge".into(),
                c,
                solver: "cold".into(),
                dataset: "rcv1".into(),
            },
            None,
        ))
    }

    #[test]
    fn rounds_learn_from_ingested_stream() {
        let (tr, te, c) = data_registry::load("rcv1", 0.02).unwrap();
        let reg = zero_registry(tr.d(), c);
        let trainer = OnlineTrainer::new(
            Arc::clone(&reg),
            LossKind::Hinge,
            c,
            OnlineConfig {
                epochs_per_round: 3,
                max_window: tr.n(),
                ..Default::default()
            },
        );
        // Stream the training rows in as "freshly labeled" raw rows
        // (raw_row unfolds the stored x = y·ẋ).
        for i in 0..tr.n() {
            let (idx, raw) = tr.raw_row(i);
            trainer.ingest(idx, raw, tr.y[i]);
        }
        assert_eq!(trainer.buffered(), tr.n());
        let acc0 = eval::accuracy(&te, &reg.current().model.w);
        for _ in 0..3 {
            assert!(trainer.train_round().is_some());
        }
        assert_eq!(reg.epoch(), 3);
        assert_eq!(trainer.rounds(), 3);
        let v = reg.current();
        let acc = eval::accuracy(&te, &v.model.w);
        assert!(
            acc > acc0 && acc > 0.7,
            "online training did not learn: {acc0} -> {acc}"
        );
        // Warm-start state published and feasible.
        let alpha = v.alpha.as_ref().unwrap();
        assert_eq!(alpha.len(), tr.n());
        assert!(alpha.iter().all(|&a| (-1e-9..=c + 1e-9).contains(&a)));
    }

    #[test]
    fn empty_window_trains_nothing() {
        let reg = zero_registry(4, 1.0);
        let trainer = OnlineTrainer::new(
            reg,
            LossKind::Hinge,
            1.0,
            OnlineConfig::default(),
        );
        assert!(trainer.train_round().is_none());
        assert_eq!(trainer.rounds(), 0);
    }

    #[test]
    fn window_evicts_oldest_and_realigns_alpha() {
        let reg = zero_registry(3, 1.0);
        let trainer = OnlineTrainer::new(
            Arc::clone(&reg),
            LossKind::Hinge,
            1.0,
            OnlineConfig { max_window: 2, ..Default::default() },
        );
        trainer.ingest(vec![0], vec![1.0], 1.0);
        trainer.ingest(vec![1], vec![1.0], -1.0);
        trainer.ingest(vec![2], vec![1.0], 1.0); // evicts the first
        assert_eq!(trainer.buffered(), 2);
        assert!(trainer.train_round().is_some());
        // Out-of-range features are dropped rather than panicking.
        trainer.ingest(vec![0, 999], vec![1.0, 5.0], 1.0);
        assert!(trainer.train_round().is_some());
        assert_eq!(reg.epoch(), 2);
    }

    #[test]
    fn spawn_loop_goes_quiet_without_new_data() {
        let reg = zero_registry(3, 1.0);
        let trainer = Arc::new(OnlineTrainer::new(
            Arc::clone(&reg),
            LossKind::Hinge,
            1.0,
            OnlineConfig { epochs_per_round: 1, ..Default::default() },
        ));
        let stop = Arc::new(AtomicBool::new(false));
        let h = OnlineTrainer::spawn_loop(
            Arc::clone(&trainer),
            Arc::clone(&stop),
            2,
        );
        trainer.ingest(vec![0], vec![1.0], 1.0);
        trainer.ingest(vec![1], vec![1.0], -1.0);
        assert_eq!(trainer.ingested(), 2);
        let t0 = std::time::Instant::now();
        while reg.epoch() == 0 && t0.elapsed() < Duration::from_secs(30) {
            std::thread::sleep(Duration::from_millis(1));
        }
        // The gate allows at most one round per observed ingest count:
        // once the epoch stabilizes it must stay put (no retraining on
        // identical data), and a fresh ingest must wake the loop again.
        std::thread::sleep(Duration::from_millis(100));
        let settled = reg.epoch();
        assert!((1..=2).contains(&settled), "epoch {settled}");
        std::thread::sleep(Duration::from_millis(100));
        assert_eq!(reg.epoch(), settled, "retrained without new data");
        trainer.ingest(vec![2], vec![1.0], 1.0);
        let t1 = std::time::Instant::now();
        while reg.epoch() == settled && t1.elapsed() < Duration::from_secs(30)
        {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(reg.epoch() > settled, "new row did not trigger a round");
        stop.store(true, Ordering::Release);
        assert_eq!(h.join().unwrap(), reg.epoch());
    }

    #[test]
    fn spawn_loop_publishes_until_stopped() {
        let (tr, _, c) = data_registry::load("rcv1", 0.02).unwrap();
        let reg = zero_registry(tr.d(), c);
        let trainer = Arc::new(OnlineTrainer::new(
            Arc::clone(&reg),
            LossKind::Hinge,
            c,
            OnlineConfig { epochs_per_round: 1, ..Default::default() },
        ));
        let stop = Arc::new(AtomicBool::new(false));
        let h = OnlineTrainer::spawn_loop(
            Arc::clone(&trainer),
            Arc::clone(&stop),
            8,
        );
        for i in 0..64 {
            let (idx, raw) = tr.raw_row(i);
            trainer.ingest(idx, raw, tr.y[i]);
        }
        // Wait until at least one round lands, then stop.
        let t0 = std::time::Instant::now();
        while reg.epoch() == 0 && t0.elapsed() < Duration::from_secs(30) {
            std::thread::sleep(Duration::from_millis(1));
        }
        stop.store(true, Ordering::Release);
        let published = h.join().unwrap();
        assert!(published >= 1, "loop never published");
        assert_eq!(reg.epoch(), published);
    }
}
