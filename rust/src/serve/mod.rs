//! Online scoring subsystem: hot-swappable models, microbatching, and
//! async continuous training.
//!
//! This is the inference side of the crate — it turns a trained
//! [`Model`](crate::coordinator::model_io::Model) into a traffic-serving
//! engine:
//!
//! * [`registry`] — versioned in-process model store with atomic
//!   hot-swap; readers are wait-free, publishers bump an epoch-tagged
//!   pointer.
//! * [`batcher`] — microbatching request queue: score requests coalesce
//!   up to a batch-size / latency budget and are scored in one sparse
//!   pass.
//! * [`scorer`] — sharded worker pool (reusing
//!   [`crate::util::affinity`] pinning) with per-shard throughput
//!   counters.
//! * [`online`] — async continuous trainer: PASSCoDe-Wild epochs over a
//!   stream of freshly labeled rows, run as a deadline-bounded
//!   `TrainSession` resumed from the live `(α, ŵ)` (see
//!   [`crate::solver::TrainSession`]), published back through the
//!   registry.
//! * [`stats`] — latency histograms (p50/p95/p99) and QPS reporting
//!   through [`crate::coordinator::metrics`].
//!
//! The theory license is the paper's Theorem 3: a `ŵ` maintained under
//! racy updates is the exact solution of a perturbed primal, so serving
//! threads may read the model lock-free while trainer threads keep
//! folding in new examples — the same shared-memory asynchrony
//! Hybrid-DCA and AsySCD exploit for training, repurposed for serving.
//!
//! Entry points: [`ServeEngine`] (embed a scoring service), [`replay`]
//! (drive a held-out split through the stack as traffic — the
//! `passcode replay` subcommand and `benches/serve_throughput.rs`).

pub mod batcher;
pub mod online;
pub mod registry;
pub mod scorer;
pub mod stats;

pub use batcher::{Batcher, Prediction, ScoreRequest, Ticket};
pub use online::{OnlineConfig, OnlineTrainer};
pub use registry::{ModelRegistry, ModelVersion};
pub use scorer::{ScorerConfig, ShardPool};
pub use stats::{LatencyHistogram, ServeStats, ThroughputReport};

use std::fmt::Write as _;
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::coordinator::model_io::Model;
use crate::data::registry as data_registry;
use crate::loss::LossKind;
use crate::solver::{MemoryModel, PasscodeSolver, Solver, SolveOptions};

/// Engine-level configuration (queue + pool shape).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Scorer shards.
    pub shards: usize,
    /// Microbatch size cap.
    pub max_batch: usize,
    /// Latency budget a partial batch waits for stragglers.
    pub max_wait: Duration,
    /// Pin shard threads to cores.
    pub pin_threads: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            shards: 4,
            max_batch: 64,
            max_wait: Duration::from_micros(200),
            pin_threads: false,
        }
    }
}

/// A running scoring service: registry + batcher + shard pool.
///
/// ```no_run
/// use passcode::coordinator::Model;
/// use passcode::serve::{ServeConfig, ServeEngine};
///
/// let model = Model::load("m.json").unwrap();
/// let engine = ServeEngine::start(model, None, &ServeConfig::default());
/// let ticket = engine.submit(vec![0, 7], vec![0.5, -1.0]);
/// println!("margin = {}", ticket.wait().margin);
/// let report = engine.shutdown();
/// println!("{}", report.render());
/// ```
#[derive(Debug)]
pub struct ServeEngine {
    registry: Arc<ModelRegistry>,
    batcher: Arc<Batcher>,
    stats: Arc<ServeStats>,
    pool: Option<ShardPool>,
}

impl ServeEngine {
    /// Start serving `model` (optionally with its dual iterate for
    /// warm-started continuous training).
    pub fn start(
        model: Model,
        alpha: Option<Vec<f64>>,
        cfg: &ServeConfig,
    ) -> ServeEngine {
        let registry = Arc::new(ModelRegistry::new(model, alpha));
        let batcher = Arc::new(Batcher::new(cfg.max_batch, cfg.max_wait));
        let stats = Arc::new(ServeStats::new(cfg.shards));
        let pool = ShardPool::start(
            Arc::clone(&registry),
            Arc::clone(&batcher),
            Arc::clone(&stats),
            &ScorerConfig { shards: cfg.shards, pin_threads: cfg.pin_threads },
        );
        ServeEngine { registry, batcher, stats, pool: Some(pool) }
    }

    /// The model registry (hand this to an [`OnlineTrainer`] to publish
    /// retrained models into the live engine).
    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }

    /// Enqueue a raw sparse row for scoring.
    pub fn submit(&self, idx: Vec<u32>, vals: Vec<f64>) -> Ticket {
        self.batcher.submit(idx, vals)
    }

    /// Live telemetry.
    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }

    /// Snapshot a live throughput report (engine keeps serving),
    /// including registry depth (`versions_alive`, `epoch`).
    pub fn report(&self) -> ThroughputReport {
        self.stats.report_for(&self.registry)
    }

    /// Drain outstanding requests, stop the shards, and report.
    pub fn shutdown(mut self) -> ThroughputReport {
        self.batcher.close();
        if let Some(pool) = self.pool.take() {
            pool.join();
        }
        self.stats.report_for(&self.registry)
    }
}

impl Drop for ServeEngine {
    fn drop(&mut self) {
        // An engine dropped without an explicit `shutdown()` (early `?`
        // return, panic unwind) must still wind its shard threads down:
        // closing the batcher unblocks their condvar waits so they drain
        // and exit instead of leaking forever.  `close` is idempotent,
        // so the post-`shutdown` drop is a no-op.
        self.batcher.close();
    }
}

/// Configuration for [`replay`]: replay a registry dataset's held-out
/// split through the serving stack as traffic.
#[derive(Debug, Clone)]
pub struct ReplayConfig {
    /// Registry dataset name (`data::registry`).
    pub dataset: String,
    /// Scale factor in (0, 1].
    pub scale: f64,
    /// Scorer shards.
    pub shards: usize,
    /// Epochs for the initial (offline) PASSCoDe-Wild training run.
    pub train_epochs: usize,
    /// Solver threads (initial training and online rounds).
    pub train_threads: usize,
    /// Mid-replay online training rounds (each publishes a hot-swap).
    pub online_rounds: usize,
    /// Wild epochs per online round.
    pub online_epochs: usize,
    /// Microbatch size cap.
    pub max_batch: usize,
    /// Microbatch latency budget.
    pub max_wait: Duration,
    /// Pin scorer shards to cores.
    pub pin_threads: bool,
    /// RNG seed.
    pub seed: u64,
}

impl Default for ReplayConfig {
    fn default() -> Self {
        Self {
            dataset: "rcv1".into(),
            scale: 0.05,
            shards: 4,
            train_epochs: 10,
            train_threads: 2,
            online_rounds: 3,
            online_epochs: 2,
            max_batch: 64,
            max_wait: Duration::from_micros(200),
            pin_threads: false,
            seed: 42,
        }
    }
}

/// What a replay run produced.
#[derive(Debug, Clone)]
pub struct ReplayReport {
    /// QPS + latency percentiles from the scorer pool.
    pub throughput: ThroughputReport,
    /// Held-out accuracy of the served predictions.
    pub accuracy: f64,
    /// Models hot-swapped in during the replay (registry epoch at end).
    pub swaps: u64,
    /// Smallest model epoch that scored a request.
    pub epoch_min: u64,
    /// Largest model epoch that scored a request.
    pub epoch_max: u64,
    /// Requests replayed (== held-out rows; none may be dropped).
    pub requests: u64,
    /// Wall-clock seconds the replay thread spent inside synchronous
    /// online-training rounds.  The throughput window includes this time
    /// (scorers keep draining concurrently while a round runs), so
    /// subtract it mentally when comparing raw scoring QPS across
    /// configurations with different round counts.
    pub online_train_secs: f64,
}

impl ReplayReport {
    /// Human-readable summary (CLI output).
    pub fn render(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{}", self.throughput.render().trim_end());
        let _ = writeln!(
            s,
            "accuracy {:.4} over {} requests; {} hot-swaps (scored by \
             model epochs {}..={}; {:.3}s in online rounds, included in \
             the window)",
            self.accuracy,
            self.requests,
            self.swaps,
            self.epoch_min,
            self.epoch_max,
            self.online_train_secs
        );
        s
    }
}

/// Replay a dataset's held-out split through the batcher/scorer stack
/// while the online trainer hot-swaps retrained models mid-stream.
///
/// The replay thread streams raw (unfolded) test rows into the batcher;
/// after each of `online_rounds` evenly spaced chunks it runs one
/// synchronous online-training round — scorer shards keep draining
/// concurrently, so each publish lands while requests are in flight.
/// Every ticket is waited on: a dropped request would hang the replay,
/// so a completed run *is* the no-drop proof (the integration test adds
/// timeouts).
pub fn replay(cfg: &ReplayConfig) -> Result<ReplayReport> {
    let (train, test, c) = data_registry::load(&cfg.dataset, cfg.scale)?;

    // ---- offline warm-up: train the initial model -------------------
    let solver = PasscodeSolver(MemoryModel::Wild);
    let mut session = solver.session(
        &train,
        LossKind::Hinge,
        c,
        SolveOptions {
            epochs: cfg.train_epochs,
            threads: cfg.train_threads.max(1),
            seed: cfg.seed,
            eval_every: 0,
            ..Default::default()
        },
    )?;
    session.run_epochs(cfg.train_epochs)?;
    let r = session.into_result();
    let model = Model {
        w: r.w_hat,
        loss: "hinge".into(),
        c,
        solver: "passcode-wild".into(),
        dataset: cfg.dataset.clone(),
    };

    // ---- bring up the serving stack ---------------------------------
    let registry = Arc::new(ModelRegistry::new(model, Some(r.alpha)));
    let batcher = Arc::new(Batcher::new(cfg.max_batch, cfg.max_wait));
    let stats = Arc::new(ServeStats::new(cfg.shards));
    let pool = ShardPool::start(
        Arc::clone(&registry),
        Arc::clone(&batcher),
        Arc::clone(&stats),
        &ScorerConfig { shards: cfg.shards, pin_threads: cfg.pin_threads },
    );
    let trainer = OnlineTrainer::new(
        Arc::clone(&registry),
        LossKind::Hinge,
        c,
        OnlineConfig {
            epochs_per_round: cfg.online_epochs,
            threads: cfg.train_threads.max(1),
            max_window: test.n().max(1),
            seed: cfg.seed,
            ..Default::default()
        },
    );

    // ---- replay the held-out split as traffic -----------------------
    let n = test.n();
    let chunk = n.div_ceil(cfg.online_rounds + 1).max(1);
    let mut next_round_at = chunk;
    let mut online_train_secs = 0.0f64;
    let mut tickets = Vec::with_capacity(n);
    for i in 0..n {
        let y = test.y[i];
        // Stored rows are folded (x = y·ẋ); serve the raw features.
        let (idx, raw) = test.raw_row(i);
        tickets.push((batcher.submit(idx.clone(), raw.clone()), y));
        // The label "arrives" right after the request: feed the trainer.
        trainer.ingest(idx, raw, y);
        if i + 1 == next_round_at && i + 1 < n {
            // Hot-swap mid-replay: retrain + publish while the shards
            // keep draining the queue.
            let t = crate::util::Timer::start();
            trainer.train_round();
            online_train_secs += t.secs();
            next_round_at += chunk;
        }
    }
    batcher.close();

    // ---- collect every response (no request may be dropped) ---------
    let mut correct = 0usize;
    let mut epoch_min = u64::MAX;
    let mut epoch_max = 0u64;
    for (t, y) in tickets {
        let p = t.wait();
        if p.label == y {
            correct += 1;
        }
        epoch_min = epoch_min.min(p.model_epoch);
        epoch_max = epoch_max.max(p.model_epoch);
    }
    pool.join();
    if n == 0 {
        epoch_min = 0;
    }

    Ok(ReplayReport {
        throughput: stats.report_for(&registry),
        accuracy: correct as f64 / n.max(1) as f64,
        swaps: registry.epoch(),
        epoch_min,
        epoch_max,
        requests: n as u64,
        online_train_secs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_engine_scores_and_reports() {
        let model = Model {
            w: vec![2.0, -1.0],
            loss: "hinge".into(),
            c: 1.0,
            solver: "test".into(),
            dataset: "toy".into(),
        };
        let engine = ServeEngine::start(
            model,
            None,
            &ServeConfig {
                shards: 2,
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                pin_threads: false,
            },
        );
        let t1 = engine.submit(vec![0], vec![1.0]);
        let t2 = engine.submit(vec![1], vec![3.0]);
        assert_eq!(t1.wait().margin, 2.0);
        assert_eq!(t2.wait().label, -1.0);
        assert_eq!(engine.registry().epoch(), 0);
        let report = engine.shutdown();
        assert_eq!(report.requests, 2);
        assert_eq!(report.shards, 2);
    }

    #[test]
    fn replay_smoke_tiny() {
        let cfg = ReplayConfig {
            scale: 0.02,
            shards: 2,
            train_epochs: 5,
            online_rounds: 2,
            online_epochs: 1,
            ..Default::default()
        };
        let rep = replay(&cfg).unwrap();
        assert_eq!(rep.swaps, 2);
        assert!(rep.requests > 0);
        assert_eq!(rep.epoch_max, rep.swaps, "final chunk sees last swap");
        assert!(rep.accuracy > 0.6, "served accuracy {}", rep.accuracy);
        assert_eq!(
            rep.throughput.requests, rep.requests,
            "scored != submitted"
        );
        assert!(rep.render().contains("hot-swaps"));
    }
}
