//! AsySCD baseline (Liu & Wright 2014; Liu et al. ICML 2014) — the
//! asynchronous *standard* stochastic coordinate descent the paper
//! compares against (§5, news20 figures).
//!
//! Key contrast with PASSCoDe: AsySCD does **not** maintain the primal
//! `w`.  Following the paper's experimental setup, it precomputes the
//! dense Hessian `Q` (`Q_ij = x_i·x_j`) in the initialization stage —
//! `O(n · nnz)` time and `O(n²)` memory, which is why the paper could
//! only run it on news20 ("all other datasets are too large … to fit Q
//! in even 256 GB memory"); [`Asyscd::solve`] reproduces that behaviour
//! with an explicit memory guard.  Each coordinate update reads the
//! shared `α` and computes `∇_i D(α) = (Qα)_i − 1` in `O(n)`.
//!
//! Step size: the paper uses γ = 1/2 with shuffling period 10; we apply
//! the diagonally-scaled step `α_i ← Π_[0,C](α_i − γ ∇_i D / Q_ii)`.

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Result};

use crate::data::Dataset;
use crate::loss::Loss;
use crate::solver::kernel;
use crate::util::{Pcg32, Phases, SharedVec, Timer};

use super::super::solver::{Progress, ProgressFn, SolveOptions, SolveResult};

/// Default cap on the dense Q allocation (bytes). 1 GiB on this host;
/// the paper's machine capped at 256 GB — same guard, different budget.
pub const DEFAULT_Q_BUDGET: usize = 1 << 30;

/// AsySCD solver.
#[derive(Debug, Clone)]
pub struct Asyscd {
    /// Step size γ (paper: 1/2).
    pub gamma: f64,
    /// Re-shuffle period in epochs (paper: 10).
    pub shuffle_period: usize,
    /// Memory budget for Q in bytes.
    pub q_budget: usize,
}

impl Default for Asyscd {
    fn default() -> Self {
        Self { gamma: 0.5, shuffle_period: 10, q_budget: DEFAULT_Q_BUDGET }
    }
}

impl Asyscd {
    /// Check the dense-Q memory guard for an `n`-row problem.  Errors out
    /// (like the paper's OOM) when `n²·8` exceeds the budget.
    pub fn check_budget(&self, n: usize) -> Result<()> {
        let need = n.checked_mul(n).and_then(|x| x.checked_mul(8));
        match need {
            Some(bytes) if bytes <= self.q_budget => Ok(()),
            _ => bail!(
                "AsySCD needs {} bytes for the dense {n}x{n} Hessian Q, \
                 budget is {} — the paper hit the same wall on all \
                 datasets but news20",
                need.map(|b| b.to_string()).unwrap_or_else(|| "overflow".into()),
                self.q_budget
            ),
        }
    }

    /// Form the dense Gram matrix `Q` behind the memory guard — split out
    /// so a [`crate::solver::TrainSession`] can pay the `O(n·nnz)` cost
    /// once and reuse `Q` across epochs.
    pub fn gram(&self, ds: &Dataset) -> Result<Vec<f64>> {
        self.check_budget(ds.n())?;
        Ok(form_gram(ds))
    }

    /// Run AsySCD end to end (guard + Q formation + updates).
    ///
    /// Thin shim over [`Asyscd::gram`] + [`Asyscd::solve_with_gram`];
    /// prefer the [`crate::solver::Solver`] registry for resumable runs.
    pub fn solve<L: Loss>(
        &self,
        ds: &Dataset,
        loss: &L,
        opts: &SolveOptions,
        on_progress: Option<&mut ProgressFn<'_>>,
    ) -> Result<SolveResult> {
        let gram_t = Timer::start();
        let q = self.gram(ds)?;
        let gram_secs = gram_t.secs();
        let mut r =
            self.solve_with_gram(ds, loss, opts, &q, None, on_progress);
        // Q formation is init-stage work (the paper counts it that way).
        r.phases.add("init", gram_secs);
        Ok(r)
    }

    /// Run AsySCD over a precomputed Gram matrix `q` (row-major `n×n`),
    /// optionally warm-started from `α₀`.  `ŵ` is not maintained — the
    /// returned `w_hat` is materialized as `Σ α_i x_i` at the end.
    pub fn solve_with_gram<L: Loss>(
        &self,
        ds: &Dataset,
        loss: &L,
        opts: &SolveOptions,
        q: &[f64],
        alpha0: Option<&[f64]>,
        mut on_progress: Option<&mut ProgressFn<'_>>,
    ) -> SolveResult {
        let n = ds.n();
        assert_eq!(q.len(), n * n, "Gram matrix dimension");

        let p = opts.threads.max(1);
        let mut phases = Phases::new();

        // ---- init: partition setup (Q is formed by the caller) --------
        let init_t = Timer::start();
        let alpha = match alpha0 {
            Some(a0) => {
                assert_eq!(a0.len(), n, "warm-start α dimension");
                SharedVec::from_slice(a0)
            }
            None => SharedVec::zeros(n),
        };
        let mut rng = Pcg32::new(opts.seed, 0xA57);
        let perm = rng.permutation(n);
        let blocks: Vec<Vec<usize>> = {
            let base = n / p;
            let rem = n % p;
            let mut out = Vec::with_capacity(p);
            let mut start = 0;
            for t in 0..p {
                let len = base + usize::from(t < rem);
                out.push(perm[start..start + len].to_vec());
                start += len;
            }
            out
        };
        phases.add("init", init_t.secs());

        // ---- async updates ---------------------------------------------
        let train_t = Timer::start();
        let updates = AtomicU64::new(0);
        let stop = std::sync::atomic::AtomicBool::new(false);
        let epochs_done = AtomicU64::new(0);
        let barrier = std::sync::Barrier::new(p);
        let sync_every = opts.eval_every;

        std::thread::scope(|scope| {
            let mut leader_cb = on_progress.take();
            for (t, block) in blocks.iter().enumerate() {
                let q_ref = &q;
                let alpha_ref = &alpha;
                let updates_ref = &updates;
                let stop_ref = &stop;
                let epochs_done_ref = &epochs_done;
                let barrier_ref = &barrier;
                let mut cb = if t == 0 { leader_cb.take() } else { None };
                let gamma = self.gamma;
                let shuffle_period = self.shuffle_period;
                scope.spawn(move || {
                    let mut rng = Pcg32::new(opts.seed, 100 + t as u64);
                    let mut order = block.clone();
                    let mut local = 0u64;
                    for epoch in 0..opts.epochs {
                        // Relaxed: advisory stop flag — one stale epoch
                        // costs work, not correctness.
                        if stop_ref.load(Ordering::Relaxed) {
                            break;
                        }
                        if epoch % shuffle_period == 0 {
                            rng.shuffle(&mut order);
                        }
                        for &i in &order {
                            let qii = q_ref[i * n + i];
                            if qii <= 0.0 {
                                continue;
                            }
                            // ∇_i D(α) = (Qα)_i − 1 : the O(n) scan that
                            // makes AsySCD slow — no maintained w.  Runs
                            // through the unrolled dense·shared kernel
                            // (branchless; Gram rows are mostly dense).
                            let row = &q_ref[i * n..(i + 1) * n];
                            let g =
                                kernel::dot_dense_shared(row, alpha_ref) - 1.0;
                            let a_old = alpha_ref.get(i);
                            let a_new =
                                loss.project(a_old - gamma * g / qii);
                            alpha_ref.set(i, a_new);
                            local += 1;
                        }
                        if t == 0 {
                            // Relaxed: monotonic progress counter, read
                            // after the scope join.
                            epochs_done_ref
                                .store(epoch as u64 + 1, Ordering::Relaxed);
                        }
                        if sync_every > 0 && (epoch + 1) % sync_every == 0 {
                            barrier_ref.wait();
                            if t == 0 {
                                if let Some(cb) = cb.as_deref_mut() {
                                    let a_snap = alpha_ref.to_vec();
                                    // w is not maintained: materialize for
                                    // the snapshot only.
                                    let w_snap = ds.x.transpose_dot(&a_snap);
                                    let pr = Progress {
                                        epoch: epoch + 1,
                                        alpha: &a_snap,
                                        w: &w_snap,
                                        train_secs: train_t.secs(),
                                    };
                                    if !cb(&pr) {
                                        // Relaxed: the barrier below is
                                        // the synchronization edge.
                                        stop_ref.store(true, Ordering::Relaxed);
                                    }
                                }
                            }
                            barrier_ref.wait();
                        }
                    }
                    updates_ref.fetch_add(local, Ordering::Relaxed);
                });
            }
        });
        phases.add("train", train_t.secs());

        let alpha_v = alpha.to_vec();
        let w_hat = ds.x.transpose_dot(&alpha_v);
        SolveResult {
            alpha: alpha_v,
            w_hat,
            // Relaxed: thread::scope's join already synchronized.
            epochs_run: epochs_done.load(Ordering::Relaxed) as usize,
            updates: updates.load(Ordering::Relaxed),
            phases,
        }
    }
}

/// Dense Gram matrix `Q_ij = x_i · x_j` (row-major n×n).
fn form_gram(ds: &Dataset) -> Vec<f64> {
    let n = ds.n();
    let mut q = vec![0.0f64; n * n];
    // Scatter-based product: for each row i, densify then dot with all
    // later rows via column walk — O(n·nnz) like the paper states.
    let mut dense = vec![0.0f64; ds.d()];
    for i in 0..n {
        let (idx_i, vals_i) = ds.x.row(i);
        for (j, v) in idx_i.iter().zip(vals_i) {
            dense[*j as usize] = *v;
        }
        for j in i..n {
            let mut dot = 0.0;
            let (idx_j, vals_j) = ds.x.row(j);
            for (k, v) in idx_j.iter().zip(vals_j) {
                dot += dense[*k as usize] * v;
            }
            q[i * n + j] = dot;
            q[j * n + i] = dot;
        }
        for j in idx_i {
            dense[*j as usize] = 0.0;
        }
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::registry;
    use crate::eval;
    use crate::loss::Hinge;

    fn tiny() -> (Dataset, f64) {
        let (tr, _, c) = registry::load("news20", 0.05).unwrap();
        (tr, c)
    }

    #[test]
    fn gram_matches_direct_computation() {
        let (ds, _) = tiny();
        let q = form_gram(&ds);
        let n = ds.n();
        for &(i, j) in &[(0, 0), (1, 5), (7, 3)] {
            let wi: Vec<f64> = {
                let mut buf = vec![0.0; ds.d()];
                let (idx, vals) = ds.x.row(i);
                for (k, v) in idx.iter().zip(vals) {
                    buf[*k as usize] = *v;
                }
                buf
            };
            let want = ds.x.row_dot_dense(j, &wi);
            assert!((q[i * n + j] - want).abs() < 1e-12);
        }
    }

    #[test]
    fn converges_on_tiny_problem() {
        let (ds, c) = tiny();
        let loss = Hinge::new(c);
        // γ = 1/2 damped steps converge markedly slower than exact CD —
        // that is the paper's point; give it room.
        let opts =
            SolveOptions { threads: 2, epochs: 300, ..Default::default() };
        let r = Asyscd::default().solve(&ds, &loss, &opts, None).unwrap();
        let gap = eval::duality_gap(&ds, &loss, &r.alpha);
        let p = eval::primal_objective(&ds, &loss, &r.w_hat);
        assert!(gap < 0.05 * p.abs().max(1.0), "gap {gap} (P={p})");
    }

    #[test]
    fn rejects_oversized_problems_like_the_paper() {
        let (ds, c) = tiny();
        let loss = Hinge::new(c);
        let solver = Asyscd { q_budget: 1024, ..Default::default() };
        let err = solver
            .solve(&ds, &loss, &SolveOptions::default(), None)
            .unwrap_err();
        assert!(err.to_string().contains("Hessian"), "{err}");
    }

    #[test]
    fn alpha_stays_in_box() {
        let (ds, c) = tiny();
        let loss = Hinge::new(c);
        let opts = SolveOptions { threads: 2, epochs: 5, ..Default::default() };
        let r = Asyscd::default().solve(&ds, &loss, &opts, None).unwrap();
        assert!(r.alpha.iter().all(|&a| (0.0..=c).contains(&a)));
    }
}
