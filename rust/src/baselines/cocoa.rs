//! CoCoA baseline (Jaggi et al., NIPS 2014) — multi-core flavour, exactly
//! the paper's comparison setup (§5): `β_K = 1` and DCD as the local dual
//! solver.
//!
//! Each outer iteration: the K workers take a snapshot of the global `w`,
//! run one local DCD epoch over their own block against a *private* copy,
//! and the leader averages the accumulated deltas back in:
//!
//! ```text
//!   w ← w + (β_K / K) Σ_k Δw_k ,   α_k ← α_k + (β_K / K) Δα_k .
//! ```
//!
//! The synchronization barrier per outer round is the thing PASSCoDe
//! removes; the per-iteration convergence penalty of the (1/K) averaging
//! is what Figures 2–6(a) show.

// audit: allow(lock) — CoCoA's per-round merge buffer is the point of
// the baseline (synchronous rounds), not a per-update kernel path.
use std::sync::Mutex;

use crate::data::Dataset;
use crate::loss::{Loss, MIN_DELTA};
use crate::util::{Pcg32, Phases, Timer};

use super::super::solver::{Progress, ProgressFn, SolveOptions, SolveResult};

/// CoCoA solver (β_K = 1, local solver = one DCD epoch per round).
pub struct Cocoa;

impl Cocoa {
    /// Run CoCoA cold-started from `α = 0`, `w = 0`.
    ///
    /// Thin shim over [`Cocoa::solve_from`]; prefer the
    /// [`crate::solver::Solver`] registry for resumable training.
    pub fn solve<L: Loss>(
        ds: &Dataset,
        loss: &L,
        opts: &SolveOptions,
        on_progress: Option<&mut ProgressFn<'_>>,
    ) -> SolveResult {
        Self::solve_from(ds, loss, opts, None, on_progress)
    }

    /// Run CoCoA, optionally warm-started from `(α₀, ŵ₀)` — the resumable
    /// core [`crate::solver::TrainSession`] drives round by round.
    pub fn solve_from<L: Loss>(
        ds: &Dataset,
        loss: &L,
        opts: &SolveOptions,
        warm: Option<(&[f64], &[f64])>,
        mut on_progress: Option<&mut ProgressFn<'_>>,
    ) -> SolveResult {
        let n = ds.n();
        let d = ds.d();
        let k = opts.threads.max(1);
        let mut phases = Phases::new();

        let init_t = Timer::start();
        let qii = ds.x.row_sqnorms_cached();
        let (mut alpha, mut w) = match warm {
            Some((a0, w0)) => {
                assert_eq!(a0.len(), n, "warm-start α dimension");
                assert_eq!(w0.len(), d, "warm-start w dimension");
                (a0.to_vec(), w0.to_vec())
            }
            None => (vec![0.0f64; n], vec![0.0f64; d]),
        };
        let mut rng = Pcg32::new(opts.seed, 0xC0C0A);
        let perm = rng.permutation(n);
        let blocks: Vec<Vec<usize>> = split_blocks(&perm, k);
        phases.add("init", init_t.secs());

        let train_t = Timer::start();
        let mut updates: u64 = 0;
        let mut epochs_run = 0;
        let beta_k = 1.0;

        'outer: for epoch in 0..opts.epochs {
            // Workers run truly in parallel; results land in a mutex'd
            // vec (one entry per block — contention-free in practice).
            // audit: allow(lock) — epoch-granular merge, not per-update
            let results: Mutex<Vec<(usize, Vec<(usize, f64)>, Vec<f64>, u64)>> =
                Mutex::new(Vec::with_capacity(k));
            std::thread::scope(|scope| {
                for (bk, block) in blocks.iter().enumerate() {
                    let w_snapshot = &w;
                    let alpha_ref = &alpha;
                    let qii_ref = &qii;
                    let results_ref = &results;
                    scope.spawn(move || {
                        let mut rng =
                            Pcg32::new(opts.seed ^ (epoch as u64), bk as u64);
                        let mut order = block.clone();
                        rng.shuffle(&mut order);
                        let mut w_local = w_snapshot.clone();
                        let mut dalpha: Vec<(usize, f64)> = Vec::new();
                        let mut local_updates = 0u64;
                        for &i in &order {
                            let q = qii_ref[i];
                            if q <= 0.0 {
                                continue;
                            }
                            let wx = ds.x.row_dot_dense(i, &w_local);
                            // Local alpha view = global + accumulated delta.
                            let cur = alpha_ref[i]
                                + dalpha
                                    .iter()
                                    .rev()
                                    .find(|(j, _)| *j == i)
                                    .map(|(_, v)| *v)
                                    .unwrap_or(0.0);
                            let a_new = loss.solve_subproblem(cur, wx, q);
                            let delta = a_new - cur;
                            local_updates += 1;
                            if delta.abs() > MIN_DELTA {
                                dalpha.push((i, delta));
                                let (idx, vals) = ds.x.row(i);
                                for (j, v) in idx.iter().zip(vals) {
                                    w_local[*j as usize] += delta * v;
                                }
                            }
                        }
                        // Δw_k = w_local − w_snapshot
                        let dw: Vec<f64> = w_local
                            .iter()
                            .zip(w_snapshot)
                            .map(|(a, b)| a - b)
                            .collect();
                        results_ref
                            .lock()
                            .unwrap()
                            .push((bk, dalpha, dw, local_updates));
                    });
                }
            });

            // Reduce: w += (β/K) Σ Δw_k ; α += (β/K) Δα_k.
            let scale = beta_k / k as f64;
            for (_bk, dalpha, dw, u) in results.into_inner().unwrap() {
                updates += u;
                for (j, dv) in dw.iter().enumerate() {
                    w[j] += scale * dv;
                }
                for (i, da) in dalpha {
                    alpha[i] += scale * da;
                }
            }
            epochs_run = epoch + 1;

            if opts.eval_every > 0 && (epoch + 1) % opts.eval_every == 0 {
                if let Some(cb) = on_progress.as_deref_mut() {
                    let p = Progress {
                        epoch: epoch + 1,
                        alpha: &alpha,
                        w: &w,
                        train_secs: train_t.secs(),
                    };
                    if !cb(&p) {
                        break 'outer;
                    }
                }
            }
        }
        phases.add("train", train_t.secs());

        SolveResult { alpha, w_hat: w, epochs_run, updates, phases }
    }
}

fn split_blocks(perm: &[usize], k: usize) -> Vec<Vec<usize>> {
    let n = perm.len();
    let base = n / k;
    let rem = n % k;
    let mut out = Vec::with_capacity(k);
    let mut start = 0;
    for t in 0..k {
        let len = base + usize::from(t < rem);
        out.push(perm[start..start + len].to_vec());
        start += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::registry;
    use crate::eval;
    use crate::loss::Hinge;

    fn small() -> (Dataset, f64) {
        let (tr, _, c) = registry::load("rcv1", 0.02).unwrap();
        (tr, c)
    }

    #[test]
    fn converges_with_multiple_blocks() {
        let (ds, c) = small();
        let loss = Hinge::new(c);
        let opts = SolveOptions { threads: 4, epochs: 60, ..Default::default() };
        let r = Cocoa::solve(&ds, &loss, &opts, None);
        let gap = eval::duality_gap(&ds, &loss, &r.alpha);
        let p = eval::primal_objective(&ds, &loss, &r.w_hat);
        assert!(gap < 0.02 * p.abs().max(1.0), "gap {gap} (P = {p})");
    }

    #[test]
    fn maintains_primal_dual_consistency() {
        // CoCoA's reduce keeps w = Σ α_i x_i exactly (synchronized).
        let (ds, c) = small();
        let loss = Hinge::new(c);
        let opts = SolveOptions { threads: 4, epochs: 5, ..Default::default() };
        let r = Cocoa::solve(&ds, &loss, &opts, None);
        let wbar = eval::wbar_from_alpha(&ds, &r.alpha);
        let err = r.w_hat.iter().zip(&wbar)
            .map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
        assert!(err < 1e-8, "consistency error {err}");
    }

    #[test]
    fn single_block_equals_dcd_epoch_behaviour() {
        // K = 1 means no averaging: CoCoA degenerates to serial DCD.
        let (ds, c) = small();
        let loss = Hinge::new(c);
        let opts = SolveOptions { threads: 1, epochs: 20, ..Default::default() };
        let r = Cocoa::solve(&ds, &loss, &opts, None);
        let gap = eval::duality_gap(&ds, &loss, &r.alpha);
        assert!(gap < 1e-2, "gap {gap}");
    }

    #[test]
    fn per_epoch_progress_is_slower_than_dcd() {
        // The averaging tax: after the same number of epochs with K = 8,
        // CoCoA's dual objective must lag serial DCD's (paper Fig a).
        use crate::solver::SerialDcd;
        let (ds, c) = small();
        let loss = Hinge::new(c);
        let e = 5;
        let dcd = SerialDcd::solve(
            &ds, &loss,
            &SolveOptions { epochs: e, ..Default::default() }, None);
        let cocoa = Cocoa::solve(
            &ds, &loss,
            &SolveOptions { threads: 8, epochs: e, ..Default::default() },
            None);
        let d_dcd = eval::dual_objective(&ds, &loss, &dcd.alpha);
        let d_cocoa = eval::dual_objective(&ds, &loss, &cocoa.alpha);
        assert!(
            d_dcd < d_cocoa,
            "expected DCD ahead per-epoch: {d_dcd} vs {d_cocoa}"
        );
    }
}
