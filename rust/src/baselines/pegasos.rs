//! Pegasos (Shalev-Shwartz et al. 2007) — the primal SGD reference the
//! paper's introduction positions DCD against.  Included as a baseline
//! extension so the "dual CD beats primal SGD at scale" claim is
//! checkable in this repo too.
//!
//! Pegasos minimizes `λ/2‖w‖² + (1/n) Σ max(0, 1 − w·x_i)`; our primal
//! (Eq. 1) is `Cn` times that with `λ = 1/(Cn)`, so the two share the
//! same minimizer.  Update at step t (sample i):
//!
//! ```text
//!   η_t = 1/(λ t);   w ← (1 − η_t λ) w + η_t·𝟙[w·x_i < 1]·x_i / n · n
//!        = (1 − 1/t) w + (1/(λ t)) 𝟙[margin < 1] x_i
//! ```
//!
//! with the optional `1/√λ`-ball projection of the original paper.

use crate::data::Dataset;
use crate::loss::Loss;
use crate::util::{Pcg32, Phases, Timer};

use super::super::solver::{Progress, ProgressFn, SolveOptions, SolveResult};

/// Pegasos solver for hinge-loss SVM.
///
/// Takes the family-standard `(dataset, loss, options, progress)` shape:
/// the penalty `C` is read off the hinge loss itself (`ℓ(0) = C·max(0,
/// 1−0) = C`) and mapped to `λ = 1/(Cn)` internally.
#[derive(Debug, Clone)]
pub struct Pegasos {
    /// Apply the 1/√λ ball projection after each step.
    pub project_ball: bool,
}

impl Default for Pegasos {
    fn default() -> Self {
        Self { project_ball: true }
    }
}

impl Pegasos {
    /// Pegasos with the original paper's ball projection enabled.
    pub fn new() -> Self {
        Self::default()
    }

    /// Run Pegasos cold-started from `w = 0`.  `loss` must be the hinge
    /// loss (the driver and registry reject anything else up front).
    ///
    /// Thin shim over [`Pegasos::solve_from`]; prefer the
    /// [`crate::solver::Solver`] registry for resumable training.
    pub fn solve<L: Loss>(
        &self,
        ds: &Dataset,
        loss: &L,
        opts: &SolveOptions,
        on_progress: Option<&mut ProgressFn<'_>>,
    ) -> SolveResult {
        self.solve_from(ds, loss, opts, None, on_progress)
    }

    /// Run Pegasos, optionally warm-started from `(w₀, t₀)` where `t₀`
    /// is the global step counter the `1/(λt)` rate resumes from (after
    /// `e` uninterrupted epochs, `t = e·n`).
    pub fn solve_from<L: Loss>(
        &self,
        ds: &Dataset,
        loss: &L,
        opts: &SolveOptions,
        warm: Option<(&[f64], u64)>,
        mut on_progress: Option<&mut ProgressFn<'_>>,
    ) -> SolveResult {
        assert_eq!(
            loss.name(),
            "hinge",
            "Pegasos optimizes the hinge loss only"
        );
        let n = ds.n();
        let d = ds.d();
        // ℓ(0) = C for hinge: recover the penalty from the loss object.
        let c = loss.primal(0.0);
        let lambda = 1.0 / (c * n as f64);
        let mut phases = Phases::new();

        let init_t = Timer::start();
        let (mut w, mut t) = match warm {
            Some((w0, t0)) => {
                assert_eq!(w0.len(), d, "warm-start w dimension");
                (w0.to_vec(), t0)
            }
            None => (vec![0.0f64; d], 0),
        };
        let mut rng = Pcg32::new(opts.seed, 0x9E6A);
        phases.add("init", init_t.secs());

        let train_t = Timer::start();
        let mut updates = 0u64;
        let mut epochs_run = 0;
        'outer: for epoch in 0..opts.epochs {
            for _ in 0..n {
                t += 1;
                let i = rng.gen_range(n);
                let eta = 1.0 / (lambda * t as f64);
                let margin = ds.x.row_dot_dense(i, &w);
                // scale: w *= (1 − η λ) = (1 − 1/t)
                let shrink = 1.0 - 1.0 / t as f64;
                for v in w.iter_mut() {
                    *v *= shrink;
                }
                if margin < 1.0 {
                    // Stochastic subgradient of (1/n)Σℓ_i at sample i is
                    // ∇ℓ_i itself (the 1/n is absorbed by sampling).
                    let (idx, vals) = ds.x.row(i);
                    for (j, v) in idx.iter().zip(vals) {
                        w[*j as usize] += eta * v;
                    }
                }
                if self.project_ball {
                    let norm2: f64 = w.iter().map(|v| v * v).sum();
                    let cap = 1.0 / lambda;
                    if norm2 > cap {
                        let s = (cap / norm2).sqrt();
                        for v in w.iter_mut() {
                            *v *= s;
                        }
                    }
                }
                updates += 1;
            }
            epochs_run = epoch + 1;
            if opts.eval_every > 0 && (epoch + 1) % opts.eval_every == 0 {
                if let Some(cb) = on_progress.as_deref_mut() {
                    let alpha = vec![0.0; n]; // primal method: no dual
                    let p = Progress {
                        epoch: epoch + 1,
                        alpha: &alpha,
                        w: &w,
                        train_secs: train_t.secs(),
                    };
                    if !cb(&p) {
                        break 'outer;
                    }
                }
            }
        }
        phases.add("train", train_t.secs());

        SolveResult {
            alpha: vec![0.0; n],
            w_hat: w,
            epochs_run,
            updates,
            phases,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::registry;
    use crate::eval;
    use crate::loss::Hinge;
    use crate::solver::{SerialDcd, SolveOptions};

    #[test]
    fn approaches_dcd_objective() {
        let (ds, _, c) = registry::load("rcv1", 0.02).unwrap();
        let loss = Hinge::new(c);
        let dcd = SerialDcd::solve(
            &ds, &loss,
            &SolveOptions { epochs: 30, ..Default::default() }, None);
        let p_star = eval::primal_objective(&ds, &loss, &dcd.w_hat);

        let peg = Pegasos::default().solve(
            &ds,
            &loss,
            &SolveOptions { epochs: 50, ..Default::default() },
            None,
        );
        let p_peg = eval::primal_objective(&ds, &loss, &peg.w_hat);
        // SGD gets close but typically not as tight — accept 15% slack.
        assert!(
            p_peg < 1.15 * p_star,
            "Pegasos too far off: {p_peg} vs DCD {p_star}"
        );
        // And it must clearly beat the trivial w = 0 model.
        let p_zero = eval::primal_objective(&ds, &loss, &vec![0.0; ds.d()]);
        assert!(p_peg < p_zero, "no progress: {p_peg} vs zero {p_zero}");
    }

    #[test]
    fn accuracy_reasonable() {
        let (tr, te, c) = registry::load("rcv1", 0.02).unwrap();
        let peg = Pegasos::new().solve(
            &tr,
            &Hinge::new(c),
            &SolveOptions { epochs: 30, ..Default::default() },
            None,
        );
        let acc = eval::accuracy(&te, &peg.w_hat);
        assert!(acc > 0.8, "accuracy {acc}");
    }
}
