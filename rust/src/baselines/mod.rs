//! Comparison methods from the paper's evaluation (§5): CoCoA (synchronized
//! dual block ascent), AsySCD (asynchronous standard CD, no maintained w),
//! and Pegasos (primal SGD, intro-level reference).

pub mod asyscd;
pub mod cocoa;
pub mod pegasos;

pub use asyscd::Asyscd;
pub use cocoa::Cocoa;
pub use pegasos::Pegasos;
