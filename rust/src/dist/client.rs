//! Worker-side HTTP client for the coordinator's `/v1/dist/*` plane.
//!
//! A thin typed wrapper over [`net::HttpClient`](crate::net::HttpClient):
//! pulls decode into `(epoch, w)`, pushes encode a [`PushDelta`] and
//! decode the coordinator's [`PushOutcome`].  Pulls ride the bounded
//! retry-with-backoff GET path (idempotent — a dead coordinator
//! surfaces as an error after the retry budget instead of hanging the
//! worker); pushes are deliberately *not* retried, because a push that
//! dies mid-flight may already have been merged, and re-sending it
//! would double-count the delta.

use std::net::SocketAddr;
use std::time::Duration;

use anyhow::{ensure, Context, Result};

use crate::net::{ClientConfig, HttpClient};
use crate::util::Json;

use super::protocol::{self, PushDelta, PushOutcome};

/// A worker's connection to the coordinator.
#[derive(Debug)]
pub struct DistClient {
    http: HttpClient,
}

impl DistClient {
    /// Connect to the coordinator at `addr` with the dist-tier policy
    /// (5 s connect, 30 s read, 4 retries with doubling backoff from
    /// 100 ms on the pull path).
    pub fn new(addr: SocketAddr) -> DistClient {
        Self::with_config(
            addr,
            ClientConfig {
                connect_timeout: Duration::from_secs(5),
                read_timeout: Duration::from_secs(30),
                retries: 4,
                backoff: Duration::from_millis(100),
            },
        )
    }

    /// Connect with an explicit socket/retry policy (tests tighten it).
    pub fn with_config(addr: SocketAddr, cfg: ClientConfig) -> DistClient {
        DistClient { http: HttpClient::with_config(addr, cfg) }
    }

    /// Pull the current merged model: `(merge_epoch, w)`.
    pub fn pull_w(&mut self) -> Result<(u64, Vec<f64>)> {
        let resp = self
            .http
            .get_with_retry("/v1/dist/pull_w")
            .context("pull_w from coordinator")?
            .ok()?;
        protocol::decode_w(&resp.body)
    }

    /// Push one round's delta; the coordinator answers with the merge
    /// verdict.  Not retried (see module docs).
    pub fn push_delta(&mut self, p: &PushDelta) -> Result<PushOutcome> {
        let resp = self
            .http
            .request(
                "POST",
                "/v1/dist/push_delta",
                "application/octet-stream",
                &protocol::encode_push(p),
            )
            .context("push_delta to coordinator")?
            .ok()?;
        PushOutcome::from_json(&resp.json()?)
    }

    /// Fetch the coordinator's merge statistics (`GET /v1/dist/stats`).
    pub fn stats(&mut self) -> Result<Json> {
        self.http.get_with_retry("/v1/dist/stats")?.ok()?.json()
    }

    /// Scrape the coordinator's `/metrics` exposition text.
    pub fn metrics_text(&mut self) -> Result<String> {
        let resp = self.http.get_with_retry("/metrics")?.ok()?;
        let text = String::from_utf8(resp.body).context("non-UTF-8 /metrics body")?;
        ensure!(!text.is_empty(), "empty /metrics scrape");
        Ok(text)
    }
}
