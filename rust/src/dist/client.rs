//! Worker-side client for the coordinator's `/v1/dist/*` plane.
//!
//! [`DistClient`] is a thin typed layer — pulls decode into
//! `(epoch, w)`, pushes encode a [`PushDelta`] and decode the
//! coordinator's [`PushOutcome`], heartbeats round-trip the lease
//! protocol — over a [`Transport`] seam.  Production uses
//! [`HttpTransport`] (a [`net::HttpClient`](crate::net::HttpClient)
//! with the dist-tier socket policy); the chaos harness substitutes
//! [`FaultyTransport`](super::chaos::FaultyTransport) to inject
//! seeded delays, drops, duplicates, reordering, truncation, and
//! partitions *under* the typed layer, so the worker/coordinator
//! logic is exercised against exactly the failures real networks
//! produce.
//!
//! Both pulls and pushes ride bounded retry-with-backoff paths.
//! Pulls are idempotent GETs.  Pushes became retry-safe when the
//! protocol gained the `(worker, boot, round)` idempotence id: the
//! coordinator merges each id exactly once and answers a duplicate
//! with the recorded verdict, so a timed-out push is re-sent instead
//! of silently lost (pre-PDL2 behavior).

use std::net::SocketAddr;
use std::time::Duration;

use anyhow::{ensure, Context, Result};

use crate::net::{ClientConfig, HttpClient};
use crate::util::Json;

use super::protocol::{self, Heartbeat, HeartbeatReply, PushDelta, PushOutcome};

/// The byte-level request seam between the typed [`DistClient`] and
/// whatever carries the bytes.  Implementations own connection state,
/// retry policy, and (in the chaos harness) the fault schedule.
///
/// Contract: `post` bodies on the push path carry an idempotence id,
/// so an implementation may re-send them after ambiguous failures;
/// `get` is always idempotent.  An `Err` means the bytes may or may
/// not have reached the peer — callers must tolerate both.
pub trait Transport: Send {
    /// Issue a GET; returns the 2xx response body.
    fn get(&mut self, path: &str) -> Result<Vec<u8>>;
    /// Issue a POST of `body`; returns the 2xx response body.
    fn post(&mut self, path: &str, body: &[u8]) -> Result<Vec<u8>>;
}

/// The production [`Transport`]: one keep-alive HTTP/1.1 connection
/// with bounded retry-with-backoff on both verbs.
#[derive(Debug)]
pub struct HttpTransport {
    http: HttpClient,
}

impl HttpTransport {
    /// Connect to `addr` with an explicit socket/retry policy.
    pub fn new(addr: SocketAddr, cfg: ClientConfig) -> HttpTransport {
        HttpTransport { http: HttpClient::with_config(addr, cfg) }
    }
}

impl Transport for HttpTransport {
    fn get(&mut self, path: &str) -> Result<Vec<u8>> {
        Ok(self.http.get_with_retry(path)?.ok()?.body)
    }

    fn post(&mut self, path: &str, body: &[u8]) -> Result<Vec<u8>> {
        Ok(self
            .http
            .post_with_retry(path, "application/octet-stream", body)?
            .ok()?
            .body)
    }
}

/// A worker's connection to the coordinator.
pub struct DistClient {
    t: Box<dyn Transport>,
    worker: Option<u64>,
}

impl std::fmt::Debug for DistClient {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DistClient").field("worker", &self.worker).finish()
    }
}

impl DistClient {
    /// Connect to the coordinator at `addr` with the dist-tier policy
    /// (5 s connect, 30 s read, 4 retries with doubling backoff from
    /// 100 ms on both the pull and the idempotent push path).
    pub fn new(addr: SocketAddr) -> DistClient {
        Self::with_config(
            addr,
            ClientConfig {
                connect_timeout: Duration::from_secs(5),
                read_timeout: Duration::from_secs(30),
                retries: 4,
                backoff: Duration::from_millis(100),
            },
        )
    }

    /// Connect with an explicit socket/retry policy (tests tighten it).
    pub fn with_config(addr: SocketAddr, cfg: ClientConfig) -> DistClient {
        Self::over(Box::new(HttpTransport::new(addr, cfg)))
    }

    /// Build a client over an arbitrary [`Transport`] — the chaos
    /// harness wraps [`HttpTransport`] in a
    /// [`FaultyTransport`](super::chaos::FaultyTransport) here.
    pub fn over(t: Box<dyn Transport>) -> DistClient {
        DistClient { t, worker: None }
    }

    /// Identify this client's worker id so pulls can piggyback a lease
    /// refresh (`?worker=ID` on `pull_w`).  Optional: an anonymous
    /// client still pulls, it just doesn't refresh any lease.
    pub fn set_worker(&mut self, id: u64) {
        self.worker = Some(id);
    }

    /// Pull the current merged model: `(merge_epoch, w)`.
    pub fn pull_w(&mut self) -> Result<(u64, Vec<f64>)> {
        let path = match self.worker {
            Some(id) => format!("/v1/dist/pull_w?worker={id}"),
            None => "/v1/dist/pull_w".to_string(),
        };
        let body = self.t.get(&path).context("pull_w from coordinator")?;
        protocol::decode_w(&body)
    }

    /// Push one round's delta; the coordinator answers with the merge
    /// verdict.  Retried under the `(worker, boot, round)` idempotence
    /// id (see module docs).
    pub fn push_delta(&mut self, p: &PushDelta) -> Result<PushOutcome> {
        let body = self
            .t
            .post("/v1/dist/push_delta", &protocol::encode_push(p))
            .context("push_delta to coordinator")?;
        PushOutcome::from_json(&Json::parse(
            std::str::from_utf8(&body).context("non-UTF-8 push verdict")?,
        )?)
    }

    /// Send a liveness heartbeat; the coordinator answers with the
    /// current epoch and this worker's assigned shard ranges (or a
    /// revocation if the lease already expired).
    pub fn heartbeat(&mut self, h: &Heartbeat) -> Result<HeartbeatReply> {
        let body = self
            .t
            .post("/v1/dist/heartbeat", &protocol::encode_heartbeat(h))
            .context("heartbeat to coordinator")?;
        HeartbeatReply::from_json(&Json::parse(
            std::str::from_utf8(&body).context("non-UTF-8 heartbeat reply")?,
        )?)
    }

    /// Fetch the coordinator's merge statistics (`GET /v1/dist/stats`).
    pub fn stats(&mut self) -> Result<Json> {
        let body = self.t.get("/v1/dist/stats")?;
        Json::parse(std::str::from_utf8(&body).context("non-UTF-8 stats body")?)
    }

    /// Scrape the coordinator's `/metrics` exposition text.
    pub fn metrics_text(&mut self) -> Result<String> {
        let body = self.t.get("/metrics")?;
        let text = String::from_utf8(body).context("non-UTF-8 /metrics body")?;
        ensure!(!text.is_empty(), "empty /metrics scrape");
        Ok(text)
    }
}
