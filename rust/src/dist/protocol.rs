//! Wire format for the coordinator/worker delta exchange.
//!
//! Both directions carry dense `f64` vectors, so the bodies are binary
//! little-endian rather than JSON — a `d = 10^6` model is 8 MB raw but
//! would be ~20 MB of decimal text, reparsed on every round.  Each
//! body starts with a 4-byte magic + version tag so a stray request
//! (or a future format bump) fails loudly instead of decoding into
//! garbage coefficients:
//!
//! * push (`POST /v1/dist/push_delta`): [`PUSH_MAGIC`] `b"PDL2"`,
//!   worker id, the worker's boot nonce and per-life round sequence
//!   (together the idempotence key that makes client-side POST retry
//!   safe — a duplicated delta merges exactly once), the worker's base
//!   merge epoch, the worker-measured backward error of its delta,
//!   then the `Δŵ` vector.
//! * pull (`GET /v1/dist/pull_w` response): [`W_MAGIC`] `b"PWV1"`,
//!   the merge epoch the vector corresponds to, then `w` itself.
//! * heartbeat (`POST /v1/dist/heartbeat`): [`HEARTBEAT_MAGIC`]
//!   `b"PDH1"`, worker id, then the `(start, end)` row ranges the
//!   worker currently owns (announced on first contact; afterwards the
//!   coordinator's registry is authoritative).
//!
//! The coordinator's answer to a push is small and goes back as JSON
//! ([`PushOutcome`]): accepted-with-weight, a resync order when the
//! delta is staler than the lag bound, or a revocation when the
//! worker's lease already expired and its shard was reassigned.
//! Heartbeats are answered with a JSON [`HeartbeatReply`].

use anyhow::{bail, ensure, Result};

use crate::util::Json;

/// Magic + version prefix of a push body (`PASSCoDe Delta, v2` —
/// v2 added the `(boot, round)` idempotence id).
pub const PUSH_MAGIC: &[u8; 4] = b"PDL2";
/// Magic + version prefix of a pull response (`PASSCoDe W Vector, v1`).
pub const W_MAGIC: &[u8; 4] = b"PWV1";
/// Magic + version prefix of a heartbeat body (`PASSCoDe Heartbeat, v1`).
pub const HEARTBEAT_MAGIC: &[u8; 4] = b"PDH1";

/// One worker round's contribution: the `ŵ` delta accumulated over the
/// worker's local epochs since it last synced at `base_epoch`.
#[derive(Debug, Clone, PartialEq)]
pub struct PushDelta {
    /// Worker id (labels the per-worker metrics; not trusted for auth).
    pub worker: u64,
    /// Boot nonce: the merge epoch observed at this worker life's
    /// first successful sync.  Distinguishes the rounds of a restarted
    /// worker from those of its previous life, so the dedup key
    /// `(worker, boot, round)` stays unique across crashes.
    pub boot: u64,
    /// Per-life push sequence number.  A retried POST re-sends the
    /// same `(worker, boot, round)` and must merge exactly once.
    pub round: u64,
    /// Merge epoch of the global `w` this delta was computed against.
    pub base_epoch: u64,
    /// Worker-measured ‖Δŵ − X_pᵀΔα_p‖ on its own shard — the async
    /// write-loss this delta carries into the merged model.
    pub delta_err: f64,
    /// Dense `Δŵ`, length = feature dimension `d`.
    pub delta: Vec<f64>,
}

/// Encode a push body (see module docs for the layout).
pub fn encode_push(p: &PushDelta) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + 8 * 6 + 8 * p.delta.len());
    out.extend_from_slice(PUSH_MAGIC);
    out.extend_from_slice(&p.worker.to_le_bytes());
    out.extend_from_slice(&p.boot.to_le_bytes());
    out.extend_from_slice(&p.round.to_le_bytes());
    out.extend_from_slice(&p.base_epoch.to_le_bytes());
    out.extend_from_slice(&p.delta_err.to_le_bytes());
    out.extend_from_slice(&(p.delta.len() as u64).to_le_bytes());
    for v in &p.delta {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Decode and validate a push body.
pub fn decode_push(body: &[u8]) -> Result<PushDelta> {
    let mut r = Reader::new(body, PUSH_MAGIC)?;
    let worker = r.u64()?;
    let boot = r.u64()?;
    let round = r.u64()?;
    let base_epoch = r.u64()?;
    let delta_err = r.f64()?;
    let delta = r.vec_f64()?;
    r.finish()?;
    Ok(PushDelta { worker, boot, round, base_epoch, delta_err, delta })
}

/// Encode a pull response: the merge `epoch` and the global `w`.
pub fn encode_w(epoch: u64, w: &[f64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + 16 + 8 * w.len());
    out.extend_from_slice(W_MAGIC);
    out.extend_from_slice(&epoch.to_le_bytes());
    out.extend_from_slice(&(w.len() as u64).to_le_bytes());
    for v in w {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Decode a pull response into `(epoch, w)`.
pub fn decode_w(body: &[u8]) -> Result<(u64, Vec<f64>)> {
    let mut r = Reader::new(body, W_MAGIC)?;
    let epoch = r.u64()?;
    let w = r.vec_f64()?;
    r.finish()?;
    Ok((epoch, w))
}

/// A worker's liveness ping: its id plus the row ranges it owns.
#[derive(Debug, Clone, PartialEq)]
pub struct Heartbeat {
    /// Worker id.
    pub worker: u64,
    /// `(start, end)` half-open global row ranges the worker holds.
    pub ranges: Vec<(u64, u64)>,
}

/// Encode a heartbeat body (see module docs for the layout).
pub fn encode_heartbeat(h: &Heartbeat) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + 16 + 16 * h.ranges.len());
    out.extend_from_slice(HEARTBEAT_MAGIC);
    out.extend_from_slice(&h.worker.to_le_bytes());
    out.extend_from_slice(&(h.ranges.len() as u64).to_le_bytes());
    for (start, end) in &h.ranges {
        out.extend_from_slice(&start.to_le_bytes());
        out.extend_from_slice(&end.to_le_bytes());
    }
    out
}

/// Decode and validate a heartbeat body.
pub fn decode_heartbeat(body: &[u8]) -> Result<Heartbeat> {
    let mut r = Reader::new(body, HEARTBEAT_MAGIC)?;
    let worker = r.u64()?;
    let count = usize::try_from(r.u64()?)?;
    ensure!(
        count.checked_mul(16).is_some_and(|bytes| bytes <= r.remaining()),
        "PDH1 range count {count} exceeds remaining body ({} bytes)",
        r.remaining()
    );
    let mut ranges = Vec::with_capacity(count);
    for _ in 0..count {
        let start = r.u64()?;
        let end = r.u64()?;
        ensure!(start <= end, "PDH1 range start {start} > end {end}");
        ranges.push((start, end));
    }
    r.finish()?;
    Ok(Heartbeat { worker, ranges })
}

/// The coordinator's answer to a heartbeat.
#[derive(Debug, Clone, PartialEq)]
pub struct HeartbeatReply {
    /// True when the worker's lease expired and its shards were
    /// reassigned: the worker must stop pushing and exit (or rejoin
    /// under a fresh life).
    pub revoked: bool,
    /// Current merge epoch.
    pub epoch: u64,
    /// The row ranges the coordinator currently assigns this worker —
    /// a superset of the announced ranges once orphans are adopted.
    pub shards: Vec<(u64, u64)>,
}

impl HeartbeatReply {
    /// Serialize for the HTTP response body.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("status", Json::str(if self.revoked { "revoked" } else { "ok" })),
            ("epoch", Json::num(self.epoch as f64)),
            (
                "shards",
                Json::Arr(
                    self.shards
                        .iter()
                        .map(|&(start, end)| {
                            Json::obj(vec![
                                ("start", Json::num(start as f64)),
                                ("end", Json::num(end as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Parse a coordinator heartbeat response body.
    pub fn from_json(j: &Json) -> Result<HeartbeatReply> {
        let revoked = match j.get("status")?.as_str()? {
            "ok" => false,
            "revoked" => true,
            other => bail!("unknown heartbeat status {other:?}"),
        };
        let epoch = j.get("epoch")?.as_f64()? as u64;
        let mut shards = Vec::new();
        for s in j.get("shards")?.as_arr()? {
            shards.push((s.get("start")?.as_f64()? as u64, s.get("end")?.as_f64()? as u64));
        }
        Ok(HeartbeatReply { revoked, epoch, shards })
    }
}

/// The coordinator's verdict on a pushed delta.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PushOutcome {
    /// Merged.  `epoch` is the new merge epoch; `weight` is the factor
    /// the delta was scaled by (1 for a fresh delta, 1/K for a stale
    /// one within the lag bound) — the worker must scale its local
    /// dual by the same factor to keep `w = Σ_p X_pᵀ α_p` exact.
    Accepted {
        /// Merge epoch after this merge.
        epoch: u64,
        /// Damping factor applied to the delta (and owed to `α`).
        weight: f64,
    },
    /// Rejected: the delta was staler than the coordinator's lag bound.
    /// The worker must discard the round, pull `w` at `epoch`, and
    /// rebase before pushing again.
    Resync {
        /// Current merge epoch to rebase onto.
        epoch: u64,
    },
    /// Rejected for good: the worker's lease expired, its dual
    /// contribution was rolled out of `w`, and its shard ranges were
    /// reassigned.  The worker must stop pushing under this life.
    Revoked {
        /// Merge epoch at revocation time.
        epoch: u64,
    },
}

impl PushOutcome {
    /// Serialize for the HTTP response body.
    pub fn to_json(&self) -> Json {
        match *self {
            PushOutcome::Accepted { epoch, weight } => Json::obj(vec![
                ("status", Json::str("accepted")),
                ("epoch", Json::num(epoch as f64)),
                ("weight", Json::num(weight)),
            ]),
            PushOutcome::Resync { epoch } => Json::obj(vec![
                ("status", Json::str("resync")),
                ("epoch", Json::num(epoch as f64)),
            ]),
            PushOutcome::Revoked { epoch } => Json::obj(vec![
                ("status", Json::str("revoked")),
                ("epoch", Json::num(epoch as f64)),
            ]),
        }
    }

    /// Parse a coordinator response body.
    pub fn from_json(j: &Json) -> Result<PushOutcome> {
        let epoch = j.get("epoch")?.as_f64()? as u64;
        match j.get("status")?.as_str()? {
            "accepted" => Ok(PushOutcome::Accepted { epoch, weight: j.get("weight")?.as_f64()? }),
            "resync" => Ok(PushOutcome::Resync { epoch }),
            "revoked" => Ok(PushOutcome::Revoked { epoch }),
            other => bail!("unknown push outcome status {other:?}"),
        }
    }
}

/// Little-endian body reader: magic check, then sized scalar/vector
/// reads, then a trailing-bytes check.  Errors carry the wire magic
/// and the exact expected/actual byte counts so a truncated body (the
/// chaos layer produces them on purpose) is diagnosable from the
/// message alone.
struct Reader<'a> {
    b: &'a [u8],
    magic: &'static str,
    off: usize,
}

impl<'a> Reader<'a> {
    fn new(body: &'a [u8], magic: &'static [u8; 4]) -> Result<Reader<'a>> {
        ensure!(
            body.len() >= 4 && &body[..4] == magic,
            "bad body magic: want {:?}, got {:?} ({} body bytes)",
            String::from_utf8_lossy(magic),
            String::from_utf8_lossy(body.get(..4).unwrap_or(body)),
            body.len(),
        );
        Ok(Reader {
            b: &body[4..],
            magic: std::str::from_utf8(magic).unwrap_or("????"),
            off: 4,
        })
    }

    fn remaining(&self) -> usize {
        self.b.len()
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            self.b.len() >= n,
            "{} body truncated at byte {}: need {n} more bytes, have {}",
            self.magic,
            self.off,
            self.b.len()
        );
        let (head, rest) = self.b.split_at(n);
        self.b = rest;
        self.off += n;
        Ok(head)
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    fn vec_f64(&mut self) -> Result<Vec<f64>> {
        let len = self.u64()?;
        let len = usize::try_from(len)?;
        ensure!(
            len.checked_mul(8).is_some_and(|bytes| bytes <= self.b.len()),
            "{} vector length {len} ({} bytes) exceeds remaining body ({} bytes)",
            self.magic,
            len.saturating_mul(8),
            self.b.len()
        );
        let raw = self.take(len * 8)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().expect("8 bytes")))
            .collect())
    }

    fn finish(self) -> Result<()> {
        ensure!(
            self.b.is_empty(),
            "{} trailing bytes: {} extra after byte {}",
            self.magic,
            self.b.len(),
            self.off
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_round_trips() {
        let p = PushDelta {
            worker: 3,
            boot: 11,
            round: 4,
            base_epoch: 17,
            delta_err: 0.125,
            delta: vec![1.0, -2.5, 0.0, 1e-9],
        };
        assert_eq!(decode_push(&encode_push(&p)).unwrap(), p);
    }

    #[test]
    fn w_round_trips() {
        let w = vec![0.5, -0.25, 3.0];
        assert_eq!(decode_w(&encode_w(9, &w)).unwrap(), (9, w));
    }

    #[test]
    fn heartbeat_round_trips() {
        let h = Heartbeat { worker: 2, ranges: vec![(0, 100), (250, 400)] };
        assert_eq!(decode_heartbeat(&encode_heartbeat(&h)).unwrap(), h);
        let empty = Heartbeat { worker: 0, ranges: vec![] };
        assert_eq!(decode_heartbeat(&encode_heartbeat(&empty)).unwrap(), empty);
    }

    #[test]
    fn decode_rejects_bad_magic_truncation_and_trailing() {
        let p = PushDelta {
            worker: 0,
            boot: 0,
            round: 0,
            base_epoch: 0,
            delta_err: 0.0,
            delta: vec![1.0],
        };
        let mut good = encode_push(&p);
        assert!(decode_push(b"XXXX").is_err());
        assert!(decode_push(&good[..good.len() - 1]).is_err());
        good.push(0);
        assert!(decode_push(&good).is_err());
        // A length prefix larger than the body must not allocate.
        let mut huge = encode_w(0, &[]);
        let n = huge.len();
        huge[n - 8..].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(decode_w(&huge).is_err());
        // Heartbeat with a lying range count must not allocate either.
        let mut hb = encode_heartbeat(&Heartbeat { worker: 0, ranges: vec![] });
        let n = hb.len();
        hb[n - 8..].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(decode_heartbeat(&hb).is_err());
    }

    #[test]
    fn decode_errors_name_magic_and_byte_counts() {
        let p = PushDelta {
            worker: 1,
            boot: 0,
            round: 0,
            base_epoch: 0,
            delta_err: 0.0,
            delta: vec![2.0, 3.0],
        };
        let good = encode_push(&p);
        let err = format!("{:#}", decode_push(&good[..good.len() - 3]).unwrap_err());
        assert!(err.contains("PDL2"), "{err}");
        assert!(err.contains("need") && err.contains("have"), "{err}");
        let err = format!("{:#}", decode_w(b"PWV1").unwrap_err());
        assert!(err.contains("PWV1") && err.contains("need 8"), "{err}");
    }

    #[test]
    fn outcome_json_round_trips() {
        for o in [
            PushOutcome::Accepted { epoch: 5, weight: 0.5 },
            PushOutcome::Resync { epoch: 7 },
            PushOutcome::Revoked { epoch: 9 },
        ] {
            let j = Json::parse(&o.to_json().to_string()).unwrap();
            assert_eq!(PushOutcome::from_json(&j).unwrap(), o);
        }
        assert!(PushOutcome::from_json(&Json::obj(vec![
            ("status", Json::str("nope")),
            ("epoch", Json::num(1.0)),
        ]))
        .is_err());
    }

    #[test]
    fn heartbeat_reply_json_round_trips() {
        for r in [
            HeartbeatReply { revoked: false, epoch: 3, shards: vec![(0, 10), (20, 30)] },
            HeartbeatReply { revoked: true, epoch: 8, shards: vec![] },
        ] {
            let j = Json::parse(&r.to_json().to_string()).unwrap();
            assert_eq!(HeartbeatReply::from_json(&j).unwrap(), r);
        }
    }
}
