//! Wire format for the coordinator/worker delta exchange.
//!
//! Both directions carry dense `f64` vectors, so the bodies are binary
//! little-endian rather than JSON — a `d = 10^6` model is 8 MB raw but
//! would be ~20 MB of decimal text, reparsed on every round.  Each
//! body starts with a 4-byte magic + version tag so a stray request
//! (or a future format bump) fails loudly instead of decoding into
//! garbage coefficients:
//!
//! * push (`POST /v1/dist/push_delta`): [`PUSH_MAGIC`] `b"PDL1"`,
//!   worker id, the worker's base merge epoch, the worker-measured
//!   backward error of its delta, then the `Δŵ` vector.
//! * pull (`GET /v1/dist/pull_w` response): [`W_MAGIC`] `b"PWV1"`,
//!   the merge epoch the vector corresponds to, then `w` itself.
//!
//! The coordinator's answer to a push is small and goes back as JSON
//! ([`PushOutcome`]): accepted-with-weight, or a resync order when the
//! delta is staler than the lag bound.

use anyhow::{bail, ensure, Result};

use crate::util::Json;

/// Magic + version prefix of a push body (`PASSCoDe Delta, v1`).
pub const PUSH_MAGIC: &[u8; 4] = b"PDL1";
/// Magic + version prefix of a pull response (`PASSCoDe W Vector, v1`).
pub const W_MAGIC: &[u8; 4] = b"PWV1";

/// One worker round's contribution: the `ŵ` delta accumulated over the
/// worker's local epochs since it last synced at `base_epoch`.
#[derive(Debug, Clone, PartialEq)]
pub struct PushDelta {
    /// Worker id (labels the per-worker metrics; not trusted for auth).
    pub worker: u64,
    /// Merge epoch of the global `w` this delta was computed against.
    pub base_epoch: u64,
    /// Worker-measured ‖Δŵ − X_pᵀΔα_p‖ on its own shard — the async
    /// write-loss this delta carries into the merged model.
    pub delta_err: f64,
    /// Dense `Δŵ`, length = feature dimension `d`.
    pub delta: Vec<f64>,
}

/// Encode a push body (see module docs for the layout).
pub fn encode_push(p: &PushDelta) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + 8 * 4 + 8 * p.delta.len());
    out.extend_from_slice(PUSH_MAGIC);
    out.extend_from_slice(&p.worker.to_le_bytes());
    out.extend_from_slice(&p.base_epoch.to_le_bytes());
    out.extend_from_slice(&p.delta_err.to_le_bytes());
    out.extend_from_slice(&(p.delta.len() as u64).to_le_bytes());
    for v in &p.delta {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Decode and validate a push body.
pub fn decode_push(body: &[u8]) -> Result<PushDelta> {
    let mut r = Reader::new(body, PUSH_MAGIC)?;
    let worker = r.u64()?;
    let base_epoch = r.u64()?;
    let delta_err = r.f64()?;
    let delta = r.vec_f64()?;
    r.finish()?;
    Ok(PushDelta { worker, base_epoch, delta_err, delta })
}

/// Encode a pull response: the merge `epoch` and the global `w`.
pub fn encode_w(epoch: u64, w: &[f64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + 16 + 8 * w.len());
    out.extend_from_slice(W_MAGIC);
    out.extend_from_slice(&epoch.to_le_bytes());
    out.extend_from_slice(&(w.len() as u64).to_le_bytes());
    for v in w {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Decode a pull response into `(epoch, w)`.
pub fn decode_w(body: &[u8]) -> Result<(u64, Vec<f64>)> {
    let mut r = Reader::new(body, W_MAGIC)?;
    let epoch = r.u64()?;
    let w = r.vec_f64()?;
    r.finish()?;
    Ok((epoch, w))
}

/// The coordinator's verdict on a pushed delta.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PushOutcome {
    /// Merged.  `epoch` is the new merge epoch; `weight` is the factor
    /// the delta was scaled by (1 for a fresh delta, 1/K for a stale
    /// one within the lag bound) — the worker must scale its local
    /// dual by the same factor to keep `w = Σ_p X_pᵀ α_p` exact.
    Accepted {
        /// Merge epoch after this merge.
        epoch: u64,
        /// Damping factor applied to the delta (and owed to `α`).
        weight: f64,
    },
    /// Rejected: the delta was staler than the coordinator's lag bound.
    /// The worker must discard the round, pull `w` at `epoch`, and
    /// rebase before pushing again.
    Resync {
        /// Current merge epoch to rebase onto.
        epoch: u64,
    },
}

impl PushOutcome {
    /// Serialize for the HTTP response body.
    pub fn to_json(&self) -> Json {
        match *self {
            PushOutcome::Accepted { epoch, weight } => Json::obj(vec![
                ("status", Json::str("accepted")),
                ("epoch", Json::num(epoch as f64)),
                ("weight", Json::num(weight)),
            ]),
            PushOutcome::Resync { epoch } => Json::obj(vec![
                ("status", Json::str("resync")),
                ("epoch", Json::num(epoch as f64)),
            ]),
        }
    }

    /// Parse a coordinator response body.
    pub fn from_json(j: &Json) -> Result<PushOutcome> {
        let epoch = j.get("epoch")?.as_f64()? as u64;
        match j.get("status")?.as_str()? {
            "accepted" => Ok(PushOutcome::Accepted { epoch, weight: j.get("weight")?.as_f64()? }),
            "resync" => Ok(PushOutcome::Resync { epoch }),
            other => bail!("unknown push outcome status {other:?}"),
        }
    }
}

/// Little-endian body reader: magic check, then sized scalar/vector
/// reads, then a trailing-bytes check.
struct Reader<'a> {
    b: &'a [u8],
}

impl<'a> Reader<'a> {
    fn new(body: &'a [u8], magic: &[u8; 4]) -> Result<Reader<'a>> {
        ensure!(
            body.len() >= 4 && &body[..4] == magic,
            "bad body magic: want {:?}, got {:?}",
            String::from_utf8_lossy(magic),
            String::from_utf8_lossy(body.get(..4).unwrap_or(body)),
        );
        Ok(Reader { b: &body[4..] })
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.b.len() >= n, "body truncated: need {n} more bytes, have {}", self.b.len());
        let (head, rest) = self.b.split_at(n);
        self.b = rest;
        Ok(head)
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    fn vec_f64(&mut self) -> Result<Vec<f64>> {
        let len = self.u64()?;
        let len = usize::try_from(len)?;
        ensure!(
            len.checked_mul(8).is_some_and(|bytes| bytes <= self.b.len()),
            "vector length {len} exceeds remaining body ({} bytes)",
            self.b.len()
        );
        let raw = self.take(len * 8)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().expect("8 bytes")))
            .collect())
    }

    fn finish(self) -> Result<()> {
        ensure!(self.b.is_empty(), "{} trailing bytes after body", self.b.len());
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_round_trips() {
        let p = PushDelta {
            worker: 3,
            base_epoch: 17,
            delta_err: 0.125,
            delta: vec![1.0, -2.5, 0.0, 1e-9],
        };
        assert_eq!(decode_push(&encode_push(&p)).unwrap(), p);
    }

    #[test]
    fn w_round_trips() {
        let w = vec![0.5, -0.25, 3.0];
        assert_eq!(decode_w(&encode_w(9, &w)).unwrap(), (9, w));
    }

    #[test]
    fn decode_rejects_bad_magic_truncation_and_trailing() {
        let p = PushDelta { worker: 0, base_epoch: 0, delta_err: 0.0, delta: vec![1.0] };
        let mut good = encode_push(&p);
        assert!(decode_push(b"XXXX").is_err());
        assert!(decode_push(&good[..good.len() - 1]).is_err());
        good.push(0);
        assert!(decode_push(&good).is_err());
        // A length prefix larger than the body must not allocate.
        let mut huge = encode_w(0, &[]);
        let n = huge.len();
        huge[n - 8..].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(decode_w(&huge).is_err());
    }

    #[test]
    fn outcome_json_round_trips() {
        for o in [
            PushOutcome::Accepted { epoch: 5, weight: 0.5 },
            PushOutcome::Resync { epoch: 7 },
        ] {
            let j = Json::parse(&o.to_json().to_string()).unwrap();
            assert_eq!(PushOutcome::from_json(&j).unwrap(), o);
        }
        assert!(PushOutcome::from_json(&Json::obj(vec![
            ("status", Json::str("nope")),
            ("epoch", Json::num(1.0)),
        ]))
        .is_err());
    }
}
