//! Distributed training tier: coordinator/worker Hybrid-DCA over the
//! `net/` HTTP plane.
//!
//! PASSCoDe (the rest of this crate) is a shared-memory algorithm —
//! its scale ceiling is one machine.  This module adds the next tier,
//! following Hybrid-DCA (Pal et al., arXiv:1610.07184): rows are
//! sharded across worker processes ([`crate::data::shard`]), each
//! worker runs ordinary warm-started PASSCoDe epochs on its shard
//! through the existing [`TrainSession`](crate::solver::api::TrainSession)
//! machinery, and workers exchange `ŵ` deltas with a coordinator over
//! plain HTTP — asynchronously, with bounded staleness:
//!
//! * [`protocol`] — the binary little-endian push/pull/heartbeat
//!   bodies and the JSON merge verdict.  Pushes carry a
//!   `(worker, boot, round)` idempotence id.
//! * [`coordinator`] — the global `w`, the merge epoch, and the
//!   accept rule: fresh deltas merge at weight 1, stale-but-bounded
//!   ones are damped by `1/K`, beyond `--max-lag` the worker is told
//!   to resync.  With op-clock leases on, it also tracks worker
//!   liveness, rolls a dead worker's contribution out of `w`, and
//!   reassigns its shard ranges to a live worker.  Checkpoints
//!   through `model_io`.
//! * [`worker`] — the local solve loop; scales its committed dual by
//!   the coordinator's merge weight so `w = Σ_p X_pᵀ α_p` stays exact
//!   across the cluster, ships the measured Theorem-3 write loss of
//!   each delta, parks a push whose verdict was lost and re-sends the
//!   same id, and honors lease revocation.
//! * [`client`] — typed worker-side client over the [`Transport`]
//!   seam (bounded retry on the idempotent pull path *and*, thanks to
//!   the push id, on pushes).
//! * [`chaos`] — deterministic fault injection: a seeded
//!   [`FaultPlan`] (`passcode-faults-v1` JSON) drives a
//!   [`FaultyTransport`] that delays, drops, duplicates, reorders,
//!   truncates, and partitions requests — replayable from its seed
//!   like a `passcode check` schedule.
//! * [`sim`] — N in-process workers over a loopback coordinator: the
//!   whole tier in one process for tests, CI, and quick experiments;
//!   `--chaos` switches it to a deterministic single-threaded driver
//!   that survives injected faults, lease expiry, and shard
//!   reassignment.
//!
//! The HTTP surface lives on the ordinary [`crate::net::Server`]
//! (`POST /v1/dist/push_delta`, `GET /v1/dist/pull_w`,
//! `POST /v1/dist/heartbeat`, `GET /v1/dist/stats`, plus `/metrics`
//! with the `passcode_dist_*` family); the CLI surface is `passcode
//! dist-coord`, `dist-work`, and `dist-sim`.

pub mod chaos;
pub mod client;
pub mod coordinator;
pub mod protocol;
pub mod sim;
pub mod worker;

pub use chaos::{FaultLog, FaultPlan, FaultyTransport, PartitionSpec, ScriptedFault, FAULTS_FORMAT};
pub use client::{DistClient, HttpTransport, Transport};
pub use coordinator::{DistCoordinator, MergeConfig};
pub use protocol::{Heartbeat, HeartbeatReply, PushDelta, PushOutcome};
pub use sim::{run_sim, SimConfig, SimReport};
pub use worker::{DistWorker, WorkerConfig, WorkerReport};
