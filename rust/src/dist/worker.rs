//! The worker half of the distributed tier: warm-started PASSCoDe
//! epochs on one row shard, bracketed by pull/push exchanges with the
//! coordinator.
//!
//! A round is: sync (pull merged `w`, adopt it together with the
//! worker's committed dual), run `epochs_per_round` local PASSCoDe
//! epochs through the ordinary [`TrainSession`] machinery, then push
//! `Δŵ` to the coordinator.  The coordinator answers with the weight
//! it merged the delta at (1 fresh, 1/K stale), and the worker scales
//! its *committed* dual `α_base` by the same weight:
//!
//! ```text
//! coordinator:  w      += weight · Δŵ
//! worker:       α_base += weight · Δα
//! ```
//!
//! Because shards are disjoint row ranges, `w = Σ_p X_pᵀ α_p` stays
//! exact under this pairing (and `weight ∈ (0,1]` keeps each scaled
//! `α_i` inside its box constraint, since the update is a convex
//! combination of two feasible points).  On a resync order the round's
//! `Δα` is discarded along with `Δŵ` — the invariant survives
//! rejection too.  The only slack is the *within-shard* asynchronous
//! write loss ‖Δŵ − X_pᵀΔα_p‖ that PASSCoDe's Theorem 3 bounds; the
//! worker measures exactly that scalar each round and ships it with
//! the delta so the coordinator can expose the accumulated backward
//! error of the merged model.
//!
//! # Surviving a faulty transport
//!
//! Every push carries a `(worker, boot, round)` id (`boot` = the merge
//! epoch at this life's first successful pull, `round` a per-life
//! sequence).  A push whose transport call fails is *parked*, not
//! dropped: the worker holds the encoded delta and its `Δα`, does no
//! further local work, and re-sends the identical id next round until
//! the coordinator answers — the coordinator's dedup record makes the
//! retry merge exactly once no matter how many ghosts the network
//! delivered meanwhile.  A [`PushOutcome::Revoked`] verdict (or a
//! revoked heartbeat reply) ends the life: the coordinator already
//! rolled back this worker's contribution and reassigned its shard.
//!
//! Dropout/rejoin: each accepted round the worker checkpoints
//! `(α_base, merged w)` through `model_io`'s checkpoint schema; a
//! restarted worker resumes the dual from its checkpoint, pulls the
//! *current* `w`, and keeps going — the coordinator never waits for
//! it.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use crate::coordinator::model_io;
use crate::data::Dataset;
use crate::loss::LossKind;
use crate::obs::Counter;
use crate::solver::api::{lookup, TrainSession};
use crate::solver::SolveOptions;

use super::client::DistClient;
use super::protocol::{Heartbeat, PushDelta, PushOutcome};

/// Per-worker training policy.
#[derive(Debug, Clone)]
pub struct WorkerConfig {
    /// Worker id (shard id; also the metrics label).
    pub id: u64,
    /// Registry name of the local solver (`passcode-atomic` is the
    /// intended one; any registered solver works).
    pub solver: String,
    /// Loss to optimize.
    pub loss: LossKind,
    /// Penalty C.
    pub c: f64,
    /// Threads for the local PASSCoDe solve.
    pub threads: usize,
    /// Local epochs per push round.
    pub epochs_per_round: usize,
    /// Rounds to run before returning.
    pub rounds: usize,
    /// Base RNG seed (mixed with `id` so workers draw distinct
    /// permutation streams).
    pub seed: u64,
    /// Where to checkpoint `(α_base, merged w)` after each accepted
    /// round (None = no checkpoints, no rejoin).
    pub checkpoint: Option<PathBuf>,
    /// Send a lease heartbeat at the top of every round (lease-mode
    /// coordinators expect one; off by default).
    pub heartbeat: bool,
    /// Global `(start, end)` row ranges this worker holds — announced
    /// in heartbeats so the coordinator's registry can reassign them
    /// if this worker dies.
    pub ranges: Vec<(u64, u64)>,
}

impl Default for WorkerConfig {
    fn default() -> Self {
        Self {
            id: 0,
            solver: "passcode-atomic".into(),
            loss: LossKind::Hinge,
            c: 1.0,
            threads: 1,
            epochs_per_round: 2,
            rounds: 8,
            seed: 42,
            checkpoint: None,
            heartbeat: false,
            ranges: Vec::new(),
        }
    }
}

/// What one worker did over its rounds.
#[derive(Debug, Clone, Copy, Default)]
pub struct WorkerReport {
    /// Rounds completed (accepted + resynced).
    pub rounds: usize,
    /// Rounds whose delta the coordinator merged.
    pub accepted: usize,
    /// Rounds discarded on a resync order.
    pub resyncs: usize,
    /// Local epochs run.
    pub epochs: usize,
    /// Coordinate updates performed locally.
    pub updates: u64,
    /// True once the coordinator revoked this worker's lease — the
    /// life ended and its contribution was rolled back.
    pub revoked: bool,
}

/// One distributed worker bound to its shard.
pub struct DistWorker<'a> {
    shard: &'a Dataset,
    cfg: WorkerConfig,
    session: TrainSession<'a>,
    /// Committed dual: what the coordinator's `w` already accounts for
    /// from this shard (merge-weight scaled).
    alpha_base: Vec<f64>,
    /// Merged `w` adopted at the last sync.
    w_base: Vec<f64>,
    /// Merge epoch of `w_base`.
    base_epoch: u64,
    /// Whether `(w_base, base_epoch)` reflect the coordinator's
    /// current state (false forces a pull before the next local solve).
    synced: bool,
    /// Boot nonce: merge epoch at this life's first successful pull
    /// (None until then).  Half of the push idempotence id.
    boot: Option<u64>,
    /// Next push's per-life sequence number (the other half).
    round_seq: u64,
    /// A push the transport failed to deliver a verdict for, parked
    /// with its `Δα` until the coordinator answers.
    pending: Option<(PushDelta, Vec<f64>)>,
    revoked: bool,
    push_total: Arc<Counter>,
    pull_total: Arc<Counter>,
    report: WorkerReport,
}

impl<'a> DistWorker<'a> {
    /// Open a worker over `shard`.  If `cfg.checkpoint` names an
    /// existing file this is a *rejoin*: the committed dual is resumed
    /// from it (the merged `w` is re-pulled fresh on the first round).
    pub fn new(shard: &'a Dataset, cfg: WorkerConfig) -> Result<DistWorker<'a>> {
        let opts = SolveOptions {
            epochs: cfg.epochs_per_round * cfg.rounds.max(1),
            // Mix the id into the seed so workers don't draw identical
            // permutation streams (golden-ratio odd constant).
            seed: cfg.seed ^ cfg.id.wrapping_mul(0x9E37_79B9_7F4A_7C15),
            threads: cfg.threads,
            ..Default::default()
        };
        let mut session = lookup(&cfg.solver)?
            .session(shard, cfg.loss, cfg.c, opts)
            .with_context(|| format!("opening session for worker {}", cfg.id))?;
        if let Some(path) = cfg.checkpoint.as_ref().filter(|p| p.exists()) {
            let ckpt = model_io::load_checkpoint(path)
                .with_context(|| format!("worker {} rejoin checkpoint", cfg.id))?;
            session.resume(&ckpt).with_context(|| format!("worker {} rejoin", cfg.id))?;
        }
        let reg = crate::obs::registry();
        let alpha_base = session.alpha().to_vec();
        Ok(DistWorker {
            shard,
            push_total: reg.counter(
                &format!("passcode_dist_push_total{{worker=\"{}\"}}", cfg.id),
                "Delta pushes sent to the dist coordinator",
            ),
            pull_total: reg.counter(
                &format!("passcode_dist_pull_total{{worker=\"{}\"}}", cfg.id),
                "Merged-w pulls from the dist coordinator",
            ),
            cfg,
            alpha_base,
            w_base: vec![0.0; shard.d()],
            base_epoch: 0,
            synced: false,
            boot: None,
            round_seq: 0,
            pending: None,
            revoked: false,
            session,
            report: WorkerReport::default(),
        })
    }

    /// Open a worker over `shard` with an explicit committed dual —
    /// how the chaos driver rebuilds a worker after it adopts a dead
    /// peer's rows (its own committed `α` at its old offsets, zeros in
    /// the adopted rows, whose rolled-back dual really is zero).  The
    /// session aligns with `alpha_base` at the first sync's
    /// `adopt_state`.
    pub fn with_dual(
        shard: &'a Dataset,
        cfg: WorkerConfig,
        alpha_base: Vec<f64>,
    ) -> Result<DistWorker<'a>> {
        ensure!(
            alpha_base.len() == shard.n(),
            "dual length {} != shard rows {}",
            alpha_base.len(),
            shard.n()
        );
        let mut w = Self::new(shard, cfg)?;
        w.alpha_base = alpha_base;
        Ok(w)
    }

    /// The committed dual block (test hook: concatenating the shards'
    /// `alpha()` in shard order yields the global dual).
    pub fn alpha(&self) -> &[f64] {
        &self.alpha_base
    }

    /// What this worker has done so far.
    pub fn report(&self) -> WorkerReport {
        self.report
    }

    /// Whether the coordinator revoked this worker's lease.
    pub fn is_revoked(&self) -> bool {
        self.revoked
    }

    /// Whether a pushed delta is still waiting for a verdict.
    pub fn has_pending(&self) -> bool {
        self.pending.is_some()
    }

    /// Pull the coordinator's current `(epoch, w)` and adopt it
    /// together with the committed dual as the session state.
    fn resync(&mut self, client: &mut DistClient) -> Result<()> {
        let (epoch, w) = client.pull_w()?;
        self.pull_total.inc();
        self.session.adopt_state(&self.alpha_base, &w)?;
        self.w_base = w;
        self.base_epoch = epoch;
        if self.boot.is_none() {
            self.boot = Some(epoch);
        }
        self.synced = true;
        Ok(())
    }

    fn mark_revoked(&mut self) {
        self.revoked = true;
        self.report.revoked = true;
        self.pending = None;
    }

    /// Re-send a parked push, if any.  Returns `Ok(true)` when no push
    /// is parked anymore (settled, rejected, or revoked), `Ok(false)`
    /// when the transport failed again and the push stays parked.
    /// No local work may run while a push is parked: its `Δα` is
    /// already in the session but not yet in `α_base`.
    pub fn settle(&mut self, client: &mut DistClient) -> Result<bool> {
        let Some((p, dalpha)) = self.pending.take() else {
            return Ok(true);
        };
        match client.push_delta(&p) {
            Ok(outcome) => {
                self.push_total.inc();
                match outcome {
                    PushOutcome::Accepted { weight, .. } => {
                        for (b, d) in self.alpha_base.iter_mut().zip(&dalpha) {
                            *b += weight * d;
                        }
                        self.report.accepted += 1;
                        self.report.rounds += 1;
                    }
                    PushOutcome::Resync { .. } => {
                        self.report.resyncs += 1;
                        self.report.rounds += 1;
                    }
                    PushOutcome::Revoked { .. } => self.mark_revoked(),
                }
                self.synced = false;
                Ok(true)
            }
            Err(_) => {
                // Ambiguous: the coordinator may or may not have seen
                // it.  Park again; the id makes the re-send safe.
                self.pending = Some((p, dalpha));
                Ok(false)
            }
        }
    }

    /// Run one round: heartbeat, settle any parked push, sync if
    /// needed, solve locally, push the delta, settle `α_base` by the
    /// merge weight, re-sync, checkpoint.  Transport faults on the
    /// push path park the push and return `Ok` — the round stalls
    /// instead of dying; faults on the *initial* sync propagate (a
    /// coordinator that never answers must surface eventually).
    pub fn run_round(&mut self, client: &mut DistClient) -> Result<()> {
        if self.revoked {
            return Ok(());
        }
        client.set_worker(self.cfg.id);
        if self.cfg.heartbeat {
            let hb = Heartbeat { worker: self.cfg.id, ranges: self.cfg.ranges.clone() };
            match client.heartbeat(&hb) {
                Ok(reply) if reply.revoked => {
                    self.mark_revoked();
                    return Ok(());
                }
                // A lost heartbeat is survivable — pushes and pulls
                // refresh the lease too; next round retries.
                _ => {}
            }
        }
        if !self.settle(client)? {
            return Ok(()); // still parked: no local work this round
        }
        if self.revoked {
            return Ok(());
        }
        if !self.synced {
            self.resync(client)?;
        }
        let before_updates = self.session.updates();
        self.session
            .run_epochs(self.cfg.epochs_per_round)
            .with_context(|| format!("worker {} local epochs", self.cfg.id))?;
        self.report.epochs += self.cfg.epochs_per_round;
        self.report.updates += self.session.updates() - before_updates;

        let delta: Vec<f64> = self
            .session
            .w_hat()
            .iter()
            .zip(&self.w_base)
            .map(|(w, b)| w - b)
            .collect();
        let dalpha: Vec<f64> = self
            .session
            .alpha()
            .iter()
            .zip(&self.alpha_base)
            .map(|(a, b)| a - b)
            .collect();
        // ‖Δŵ − X_pᵀΔα‖: the asynchronous write loss this round's
        // delta carries (zero for serial/lock solvers, small for
        // atomic/wild — Theorem 3's quantity, measured not assumed).
        let exact = self.shard.x.transpose_dot(&dalpha);
        let delta_err = delta
            .iter()
            .zip(&exact)
            .map(|(d, e)| (d - e) * (d - e))
            .sum::<f64>()
            .sqrt();

        let p = PushDelta {
            worker: self.cfg.id,
            boot: self.boot.expect("synced implies a boot nonce"),
            round: self.round_seq,
            base_epoch: self.base_epoch,
            delta_err,
            delta,
        };
        self.round_seq += 1;
        match client.push_delta(&p) {
            Ok(outcome) => {
                self.push_total.inc();
                match outcome {
                    PushOutcome::Accepted { weight, .. } => {
                        for (b, d) in self.alpha_base.iter_mut().zip(&dalpha) {
                            *b += weight * d;
                        }
                        self.report.accepted += 1;
                    }
                    PushOutcome::Resync { .. } => {
                        // Round discarded on both sides; α_base already
                        // matches what the coordinator credited us with.
                        self.report.resyncs += 1;
                    }
                    PushOutcome::Revoked { .. } => {
                        self.mark_revoked();
                        return Ok(());
                    }
                }
            }
            Err(_) => {
                // Verdict unknown: park the push (with its Δα) and
                // stall until the coordinator answers the same id.
                self.pending = Some((p, dalpha));
                self.synced = false;
                return Ok(());
            }
        }
        self.report.rounds += 1;
        self.synced = false;
        // Rebase onto the post-merge w before checkpointing, so the
        // checkpoint pairs α_base with a w that includes (or excludes)
        // this round consistently.  A failed rebase just leaves the
        // worker unsynced — the next round's opening pull retries it —
        // and skips the checkpoint (its α/w pairing would be stale).
        if self.resync(client).is_ok() {
            if let Some(path) = &self.cfg.checkpoint {
                let ckpt = self.session.snapshot();
                if let Err(e) = model_io::save_checkpoint(&ckpt, path) {
                    eprintln!("dist-work {}: checkpoint failed: {e:#}", self.cfg.id);
                }
            }
        }
        Ok(())
    }

    /// Run `cfg.rounds` rounds (or until `stop` flips true between
    /// rounds — the dropout hook the kill/rejoin test uses, or until
    /// the coordinator revokes this worker's lease).  Drains any
    /// still-parked push before returning.
    pub fn run(
        &mut self,
        client: &mut DistClient,
        stop: Option<&AtomicBool>,
    ) -> Result<WorkerReport> {
        for _ in 0..self.cfg.rounds {
            if stop.is_some_and(|s| s.load(Ordering::Relaxed)) || self.revoked {
                break;
            }
            self.run_round(client)?;
        }
        let _ = self.settle(client);
        Ok(self.report)
    }
}

impl std::fmt::Debug for DistWorker<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DistWorker")
            .field("id", &self.cfg.id)
            .field("shard_rows", &self.shard.n())
            .field("base_epoch", &self.base_epoch)
            .field("synced", &self.synced)
            .field("boot", &self.boot)
            .field("round_seq", &self.round_seq)
            .field("pending", &self.pending.is_some())
            .field("revoked", &self.revoked)
            .field("report", &self.report)
            .finish()
    }
}
