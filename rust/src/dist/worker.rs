//! The worker half of the distributed tier: warm-started PASSCoDe
//! epochs on one row shard, bracketed by pull/push exchanges with the
//! coordinator.
//!
//! A round is: sync (pull merged `w`, adopt it together with the
//! worker's committed dual), run `epochs_per_round` local PASSCoDe
//! epochs through the ordinary [`TrainSession`] machinery, then push
//! `Δŵ` to the coordinator.  The coordinator answers with the weight
//! it merged the delta at (1 fresh, 1/K stale), and the worker scales
//! its *committed* dual `α_base` by the same weight:
//!
//! ```text
//! coordinator:  w      += weight · Δŵ
//! worker:       α_base += weight · Δα
//! ```
//!
//! Because shards are disjoint row ranges, `w = Σ_p X_pᵀ α_p` stays
//! exact under this pairing (and `weight ∈ (0,1]` keeps each scaled
//! `α_i` inside its box constraint, since the update is a convex
//! combination of two feasible points).  On a resync order the round's
//! `Δα` is discarded along with `Δŵ` — the invariant survives
//! rejection too.  The only slack is the *within-shard* asynchronous
//! write loss ‖Δŵ − X_pᵀΔα_p‖ that PASSCoDe's Theorem 3 bounds; the
//! worker measures exactly that scalar each round and ships it with
//! the delta so the coordinator can expose the accumulated backward
//! error of the merged model.
//!
//! Dropout/rejoin: each accepted round the worker checkpoints
//! `(α_base, merged w)` through `model_io`'s checkpoint schema; a
//! restarted worker resumes the dual from its checkpoint, pulls the
//! *current* `w`, and keeps going — the coordinator never waits for
//! it.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::model_io;
use crate::data::Dataset;
use crate::loss::LossKind;
use crate::obs::Counter;
use crate::solver::api::{lookup, TrainSession};
use crate::solver::SolveOptions;

use super::client::DistClient;
use super::protocol::{PushDelta, PushOutcome};

/// Per-worker training policy.
#[derive(Debug, Clone)]
pub struct WorkerConfig {
    /// Worker id (shard id; also the metrics label).
    pub id: u64,
    /// Registry name of the local solver (`passcode-atomic` is the
    /// intended one; any registered solver works).
    pub solver: String,
    /// Loss to optimize.
    pub loss: LossKind,
    /// Penalty C.
    pub c: f64,
    /// Threads for the local PASSCoDe solve.
    pub threads: usize,
    /// Local epochs per push round.
    pub epochs_per_round: usize,
    /// Rounds to run before returning.
    pub rounds: usize,
    /// Base RNG seed (mixed with `id` so workers draw distinct
    /// permutation streams).
    pub seed: u64,
    /// Where to checkpoint `(α_base, merged w)` after each accepted
    /// round (None = no checkpoints, no rejoin).
    pub checkpoint: Option<PathBuf>,
}

impl Default for WorkerConfig {
    fn default() -> Self {
        Self {
            id: 0,
            solver: "passcode-atomic".into(),
            loss: LossKind::Hinge,
            c: 1.0,
            threads: 1,
            epochs_per_round: 2,
            rounds: 8,
            seed: 42,
            checkpoint: None,
        }
    }
}

/// What one worker did over its rounds.
#[derive(Debug, Clone, Copy, Default)]
pub struct WorkerReport {
    /// Rounds completed (accepted + resynced).
    pub rounds: usize,
    /// Rounds whose delta the coordinator merged.
    pub accepted: usize,
    /// Rounds discarded on a resync order.
    pub resyncs: usize,
    /// Local epochs run.
    pub epochs: usize,
    /// Coordinate updates performed locally.
    pub updates: u64,
}

/// One distributed worker bound to its shard.
pub struct DistWorker<'a> {
    shard: &'a Dataset,
    cfg: WorkerConfig,
    session: TrainSession<'a>,
    /// Committed dual: what the coordinator's `w` already accounts for
    /// from this shard (merge-weight scaled).
    alpha_base: Vec<f64>,
    /// Merged `w` adopted at the last sync.
    w_base: Vec<f64>,
    /// Merge epoch of `w_base`.
    base_epoch: u64,
    /// Whether `(w_base, base_epoch)` reflect the coordinator's
    /// current state (false forces a pull before the next local solve).
    synced: bool,
    push_total: Arc<Counter>,
    pull_total: Arc<Counter>,
    report: WorkerReport,
}

impl<'a> DistWorker<'a> {
    /// Open a worker over `shard`.  If `cfg.checkpoint` names an
    /// existing file this is a *rejoin*: the committed dual is resumed
    /// from it (the merged `w` is re-pulled fresh on the first round).
    pub fn new(shard: &'a Dataset, cfg: WorkerConfig) -> Result<DistWorker<'a>> {
        let opts = SolveOptions {
            epochs: cfg.epochs_per_round * cfg.rounds.max(1),
            // Mix the id into the seed so workers don't draw identical
            // permutation streams (golden-ratio odd constant).
            seed: cfg.seed ^ cfg.id.wrapping_mul(0x9E37_79B9_7F4A_7C15),
            threads: cfg.threads,
            ..Default::default()
        };
        let mut session = lookup(&cfg.solver)?
            .session(shard, cfg.loss, cfg.c, opts)
            .with_context(|| format!("opening session for worker {}", cfg.id))?;
        if let Some(path) = cfg.checkpoint.as_ref().filter(|p| p.exists()) {
            let ckpt = model_io::load_checkpoint(path)
                .with_context(|| format!("worker {} rejoin checkpoint", cfg.id))?;
            session.resume(&ckpt).with_context(|| format!("worker {} rejoin", cfg.id))?;
        }
        let reg = crate::obs::registry();
        let alpha_base = session.alpha().to_vec();
        Ok(DistWorker {
            shard,
            push_total: reg.counter(
                &format!("passcode_dist_push_total{{worker=\"{}\"}}", cfg.id),
                "Delta pushes sent to the dist coordinator",
            ),
            pull_total: reg.counter(
                &format!("passcode_dist_pull_total{{worker=\"{}\"}}", cfg.id),
                "Merged-w pulls from the dist coordinator",
            ),
            cfg,
            alpha_base,
            w_base: vec![0.0; shard.d()],
            base_epoch: 0,
            synced: false,
            session,
            report: WorkerReport::default(),
        })
    }

    /// The committed dual block (test hook: concatenating the shards'
    /// `alpha()` in shard order yields the global dual).
    pub fn alpha(&self) -> &[f64] {
        &self.alpha_base
    }

    /// What this worker has done so far.
    pub fn report(&self) -> WorkerReport {
        self.report
    }

    /// Pull the coordinator's current `(epoch, w)` and adopt it
    /// together with the committed dual as the session state.
    fn resync(&mut self, client: &mut DistClient) -> Result<()> {
        let (epoch, w) = client.pull_w()?;
        self.pull_total.inc();
        self.session.adopt_state(&self.alpha_base, &w)?;
        self.w_base = w;
        self.base_epoch = epoch;
        self.synced = true;
        Ok(())
    }

    /// Run one round: sync if needed, solve locally, push the delta,
    /// settle `α_base` by the merge weight, re-sync, checkpoint.
    pub fn run_round(&mut self, client: &mut DistClient) -> Result<()> {
        if !self.synced {
            self.resync(client)?;
        }
        let before_updates = self.session.updates();
        self.session
            .run_epochs(self.cfg.epochs_per_round)
            .with_context(|| format!("worker {} local epochs", self.cfg.id))?;
        self.report.epochs += self.cfg.epochs_per_round;
        self.report.updates += self.session.updates() - before_updates;

        let delta: Vec<f64> = self
            .session
            .w_hat()
            .iter()
            .zip(&self.w_base)
            .map(|(w, b)| w - b)
            .collect();
        let dalpha: Vec<f64> = self
            .session
            .alpha()
            .iter()
            .zip(&self.alpha_base)
            .map(|(a, b)| a - b)
            .collect();
        // ‖Δŵ − X_pᵀΔα‖: the asynchronous write loss this round's
        // delta carries (zero for serial/lock solvers, small for
        // atomic/wild — Theorem 3's quantity, measured not assumed).
        let exact = self.shard.x.transpose_dot(&dalpha);
        let delta_err = delta
            .iter()
            .zip(&exact)
            .map(|(d, e)| (d - e) * (d - e))
            .sum::<f64>()
            .sqrt();

        let outcome = client.push_delta(&PushDelta {
            worker: self.cfg.id,
            base_epoch: self.base_epoch,
            delta_err,
            delta,
        })?;
        self.push_total.inc();
        match outcome {
            PushOutcome::Accepted { weight, .. } => {
                for (b, d) in self.alpha_base.iter_mut().zip(&dalpha) {
                    *b += weight * d;
                }
                self.report.accepted += 1;
            }
            PushOutcome::Resync { .. } => {
                // Round discarded on both sides; α_base already matches
                // what the coordinator credited us with.
                self.report.resyncs += 1;
            }
        }
        self.report.rounds += 1;
        // Rebase onto the post-merge w before checkpointing, so the
        // checkpoint pairs α_base with a w that includes (or excludes)
        // this round consistently.
        self.resync(client)?;
        if let Some(path) = &self.cfg.checkpoint {
            let ckpt = self.session.snapshot();
            if let Err(e) = model_io::save_checkpoint(&ckpt, path) {
                eprintln!("dist-work {}: checkpoint failed: {e:#}", self.cfg.id);
            }
        }
        Ok(())
    }

    /// Run `cfg.rounds` rounds (or until `stop` flips true between
    /// rounds — the dropout hook the kill/rejoin test uses).
    pub fn run(
        &mut self,
        client: &mut DistClient,
        stop: Option<&AtomicBool>,
    ) -> Result<WorkerReport> {
        for _ in 0..self.cfg.rounds {
            if stop.is_some_and(|s| s.load(Ordering::Relaxed)) {
                break;
            }
            self.run_round(client)?;
        }
        Ok(self.report)
    }
}

impl std::fmt::Debug for DistWorker<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DistWorker")
            .field("id", &self.cfg.id)
            .field("shard_rows", &self.shard.n())
            .field("base_epoch", &self.base_epoch)
            .field("synced", &self.synced)
            .field("report", &self.report)
            .finish()
    }
}
