//! The merge side of the distributed tier: one global `w`, a merge
//! epoch, and Hybrid-DCA's asynchronous bounded-staleness accept rule.
//!
//! Workers push `Δŵ` deltas computed against some past merge epoch.
//! With `lag = current_epoch − base_epoch`:
//!
//! * `lag == 0` — the delta is fresh (nothing merged since the worker
//!   synced); it is added at full weight 1.  With disjoint row shards
//!   the workers' dual blocks are independent, so a fresh delta is an
//!   exact block update of the global problem.
//! * `1 ≤ lag ≤ max_lag` — the delta raced with other merges; it is
//!   damped by `1/K` (K = configured worker count), the CoCoA-style
//!   conservative averaging weight that keeps the K-way race
//!   convergent (cf. `baselines/cocoa.rs`, β = 1/K).
//! * `lag > max_lag` — too stale to trust: rejected, the counters
//!   record it, and the worker is told to resync (pull the current
//!   `w`, rebase, and retry).  This is the bounded-staleness knob —
//!   `--max-lag 0` degenerates to fully synchronous merging.
//!
//! Every accepted merge returns the applied weight to the worker,
//! which scales its local dual by the same factor; that keeps the
//! invariant `w = Σ_p X_pᵀ α_p` exact across the cluster, so the
//! merged model remains a genuine PASSCoDe iterate rather than an
//! averaged approximation (see `dist/worker.rs`).

use std::collections::BTreeSet;
use std::path::PathBuf;
use std::sync::Mutex;

use anyhow::{ensure, Result};

use crate::coordinator::model_io::Model;
use crate::loss::LossKind;
use crate::obs::probes;
use crate::util::Json;

use super::protocol::{PushDelta, PushOutcome};

/// Coordinator policy: the merge rule's constants plus checkpointing
/// and the metadata stamped into saved models.
#[derive(Debug, Clone)]
pub struct MergeConfig {
    /// Configured worker count K — the damping denominator for stale
    /// deltas (weight `1/K`).
    pub workers: usize,
    /// Maximum tolerated merge-epoch lag; staler deltas are rejected
    /// with a resync order.
    pub max_lag: u64,
    /// Where to checkpoint the merged model (None = no checkpoints).
    pub checkpoint: Option<PathBuf>,
    /// Checkpoint every this many accepted merges (0 = only on
    /// explicit [`DistCoordinator::checkpoint_now`] calls).
    pub checkpoint_every: u64,
    /// Loss the workers optimize (stamped into checkpointed models).
    pub loss: LossKind,
    /// Penalty C (stamped into checkpointed models).
    pub c: f64,
    /// Dataset name (stamped into checkpointed models).
    pub dataset: String,
}

impl Default for MergeConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            max_lag: 8,
            checkpoint: None,
            checkpoint_every: 0,
            loss: LossKind::Hinge,
            c: 1.0,
            dataset: "dist".into(),
        }
    }
}

/// Everything the merge rule mutates, under one mutex.  A merge is a
/// single dense axpy — microseconds even at d = 10^6 — so a mutex (not
/// the solver's atomic scatter machinery) is the right tool: the
/// contended path is cross-process HTTP, not this lock.
#[derive(Debug)]
struct State {
    w: Vec<f64>,
    epoch: u64,
    merges: u64,
    rejects: u64,
    /// Σ weight·delta_err over accepted merges: the worker-reported
    /// backward error carried into `w` (numerator of the gauge).
    err_accum: f64,
    workers_seen: BTreeSet<u64>,
}

/// The coordinator: shared global `w` + the bounded-staleness merge.
///
/// `Arc<DistCoordinator>` is shared between the HTTP dispatch path
/// (`net/server.rs` routes `/v1/dist/*` here via `Router::with_dist`)
/// and whatever owns the process lifetime (`passcode dist-coord`,
/// `dist-sim`).
pub struct DistCoordinator {
    cfg: MergeConfig,
    state: Mutex<State>,
}

impl std::fmt::Debug for DistCoordinator {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.state.lock().expect("coordinator state poisoned");
        f.debug_struct("DistCoordinator")
            .field("epoch", &s.epoch)
            .field("merges", &s.merges)
            .field("rejects", &s.rejects)
            .field("dim", &s.w.len())
            .field("cfg", &self.cfg)
            .finish()
    }
}

impl DistCoordinator {
    /// Start coordinating from an initial `w` (zeros for a fresh run,
    /// a loaded model's `w` to continue one).
    pub fn new(w: Vec<f64>, cfg: MergeConfig) -> DistCoordinator {
        probes::dist().merge_epoch.set(0.0);
        DistCoordinator {
            cfg,
            state: Mutex::new(State {
                w,
                epoch: 0,
                merges: 0,
                rejects: 0,
                err_accum: 0.0,
                workers_seen: BTreeSet::new(),
            }),
        }
    }

    /// The configured merge policy.
    pub fn config(&self) -> &MergeConfig {
        &self.cfg
    }

    /// Apply the bounded-staleness merge rule to one pushed delta.
    ///
    /// Errors mean a malformed push (dimension mismatch, non-finite
    /// values, or a base epoch from the future) — the HTTP layer maps
    /// them to 400.  A *stale* push is not an error: it returns
    /// [`PushOutcome::Resync`] and the delta is discarded.
    pub fn push(&self, p: &PushDelta) -> Result<PushOutcome> {
        let mut s = self.state.lock().expect("coordinator state poisoned");
        ensure!(
            p.delta.len() == s.w.len(),
            "delta dimension {} != model dimension {}",
            p.delta.len(),
            s.w.len()
        );
        ensure!(
            p.delta.iter().all(|v| v.is_finite()) && p.delta_err.is_finite(),
            "worker {} pushed non-finite delta",
            p.worker
        );
        ensure!(
            p.base_epoch <= s.epoch,
            "worker {} claims base epoch {} but coordinator is at {}",
            p.worker,
            p.base_epoch,
            s.epoch
        );
        s.workers_seen.insert(p.worker);
        let lag = s.epoch - p.base_epoch;
        if lag > self.cfg.max_lag {
            s.rejects += 1;
            probes::dist().rejects.inc();
            return Ok(PushOutcome::Resync { epoch: s.epoch });
        }
        let weight =
            if lag == 0 { 1.0 } else { 1.0 / self.cfg.workers.max(1) as f64 };
        for (wi, di) in s.w.iter_mut().zip(&p.delta) {
            *wi += weight * di;
        }
        s.epoch += 1;
        s.merges += 1;
        s.err_accum += weight * p.delta_err;
        let probes = probes::dist();
        probes.merges.inc();
        probes.merge_epoch.set(s.epoch as f64);
        probes.merge_lag.record(lag);
        let norm = s.w.iter().map(|v| v * v).sum::<f64>().sqrt();
        probes
            .backward_error_ratio
            .set(if norm > 0.0 { s.err_accum / norm } else { 0.0 });
        let outcome = PushOutcome::Accepted { epoch: s.epoch, weight };
        let due = self.cfg.checkpoint_every > 0 && s.merges % self.cfg.checkpoint_every == 0;
        if due {
            // Best-effort: a full disk must not fail the merge the
            // worker already committed to.
            if let Err(e) = self.write_checkpoint(&s.w) {
                eprintln!("dist-coord: checkpoint failed: {e:#}");
            }
        }
        Ok(outcome)
    }

    /// Snapshot `(merge_epoch, w)` for a puller.
    pub fn pull(&self) -> (u64, Vec<f64>) {
        let s = self.state.lock().expect("coordinator state poisoned");
        (s.epoch, s.w.clone())
    }

    /// Merge statistics as JSON (served at `GET /v1/dist/stats`).
    pub fn stats_json(&self) -> Json {
        let s = self.state.lock().expect("coordinator state poisoned");
        let norm = s.w.iter().map(|v| v * v).sum::<f64>().sqrt();
        Json::obj(vec![
            ("merge_epoch", Json::num(s.epoch as f64)),
            ("merges", Json::num(s.merges as f64)),
            ("rejects", Json::num(s.rejects as f64)),
            ("dim", Json::num(s.w.len() as f64)),
            ("workers_seen", Json::num(s.workers_seen.len() as f64)),
            ("max_lag", Json::num(self.cfg.max_lag as f64)),
            ("w_norm", Json::num(norm)),
            (
                "backward_error_ratio",
                Json::num(if norm > 0.0 { s.err_accum / norm } else { 0.0 }),
            ),
        ])
    }

    /// Checkpoint the merged model now (no-op without a configured
    /// checkpoint path).
    pub fn checkpoint_now(&self) -> Result<()> {
        let w = {
            let s = self.state.lock().expect("coordinator state poisoned");
            s.w.clone()
        };
        self.write_checkpoint(&w)
    }

    fn write_checkpoint(&self, w: &[f64]) -> Result<()> {
        let Some(path) = &self.cfg.checkpoint else { return Ok(()) };
        Model {
            w: w.to_vec(),
            loss: self.cfg.loss.name().to_string(),
            c: self.cfg.c,
            solver: "dist-hybrid-dca".to_string(),
            dataset: self.cfg.dataset.clone(),
        }
        .save(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn push(worker: u64, base_epoch: u64, delta: Vec<f64>) -> PushDelta {
        PushDelta { worker, base_epoch, delta_err: 0.0, delta }
    }

    fn coord(max_lag: u64) -> DistCoordinator {
        DistCoordinator::new(
            vec![0.0; 3],
            MergeConfig { workers: 2, max_lag, ..Default::default() },
        )
    }

    #[test]
    fn fresh_delta_merges_at_full_weight() {
        let c = coord(4);
        match c.push(&push(0, 0, vec![1.0, 2.0, 3.0])).unwrap() {
            PushOutcome::Accepted { epoch, weight } => {
                assert_eq!(epoch, 1);
                assert_eq!(weight, 1.0);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(c.pull(), (1, vec![1.0, 2.0, 3.0]));
    }

    #[test]
    fn stale_delta_is_damped_by_one_over_k() {
        let c = coord(4);
        c.push(&push(0, 0, vec![1.0, 0.0, 0.0])).unwrap();
        // Worker 1 still based on epoch 0: lag 1, weight 1/2.
        match c.push(&push(1, 0, vec![0.0, 4.0, 0.0])).unwrap() {
            PushOutcome::Accepted { epoch, weight } => {
                assert_eq!(epoch, 2);
                assert_eq!(weight, 0.5);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(c.pull().1, vec![1.0, 2.0, 0.0]);
    }

    #[test]
    fn beyond_lag_is_rejected_and_epoch_monotonic() {
        let c = coord(1);
        for _ in 0..3 {
            c.push(&push(0, c.pull().0, vec![1.0, 0.0, 0.0])).unwrap();
        }
        let before = c.pull();
        // Base epoch 0 against coordinator epoch 3, max_lag 1: resync.
        match c.push(&push(1, 0, vec![9.0, 9.0, 9.0])).unwrap() {
            PushOutcome::Resync { epoch } => assert_eq!(epoch, 3),
            other => panic!("unexpected {other:?}"),
        }
        // Rejected delta must not touch w or the epoch.
        assert_eq!(c.pull(), before);
        let stats = c.stats_json();
        assert_eq!(stats.get("rejects").unwrap().as_usize().unwrap(), 1);
        assert_eq!(stats.get("merges").unwrap().as_usize().unwrap(), 3);
    }

    #[test]
    fn malformed_pushes_error() {
        let c = coord(4);
        assert!(c.push(&push(0, 0, vec![1.0])).is_err(), "dim mismatch accepted");
        assert!(
            c.push(&push(0, 0, vec![f64::NAN, 0.0, 0.0])).is_err(),
            "NaN accepted"
        );
        assert!(c.push(&push(0, 5, vec![0.0; 3])).is_err(), "future epoch accepted");
        // Errors never advance the epoch.
        assert_eq!(c.pull().0, 0);
    }

    #[test]
    fn checkpoints_land_through_model_io() {
        let dir = std::env::temp_dir().join("passcode-dist-ckpt-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.json");
        let c = DistCoordinator::new(
            vec![0.0; 2],
            MergeConfig {
                workers: 2,
                max_lag: 4,
                checkpoint: Some(path.clone()),
                checkpoint_every: 1,
                ..Default::default()
            },
        );
        c.push(&push(0, 0, vec![0.5, -0.5])).unwrap();
        let m = Model::load(&path).unwrap();
        assert_eq!(m.w, vec![0.5, -0.5]);
        assert_eq!(m.solver, "dist-hybrid-dca");
        std::fs::remove_file(&path).ok();
    }
}
