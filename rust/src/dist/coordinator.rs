//! The merge side of the distributed tier: one global `w`, a merge
//! epoch, and Hybrid-DCA's asynchronous bounded-staleness accept rule.
//!
//! Workers push `Δŵ` deltas computed against some past merge epoch.
//! With `lag = current_epoch − base_epoch`:
//!
//! * `lag == 0` — the delta is fresh (nothing merged since the worker
//!   synced); it is added at full weight 1.  With disjoint row shards
//!   the workers' dual blocks are independent, so a fresh delta is an
//!   exact block update of the global problem.
//! * `1 ≤ lag ≤ max_lag` — the delta raced with other merges; it is
//!   damped by `1/K` (K = configured worker count), the CoCoA-style
//!   conservative averaging weight that keeps the K-way race
//!   convergent (cf. `baselines/cocoa.rs`, β = 1/K).
//! * `lag > max_lag` — too stale to trust: rejected, the counters
//!   record it, and the worker is told to resync (pull the current
//!   `w`, rebase, and retry).  This is the bounded-staleness knob —
//!   `--max-lag 0` degenerates to fully synchronous merging.
//!
//! Every accepted merge returns the applied weight to the worker,
//! which scales its local dual by the same factor; that keeps the
//! invariant `w = Σ_p X_pᵀ α_p` exact across the cluster, so the
//! merged model remains a genuine PASSCoDe iterate rather than an
//! averaged approximation (see `dist/worker.rs`).
//!
//! # Exactly-once merging
//!
//! Every push carries a `(worker, boot, round)` id; the coordinator
//! records the verdict per id and answers a duplicate (a client retry
//! after an ambiguous failure, or a chaos-replayed ghost) from the
//! record without touching `w`.  That record is what makes the client
//! side's `post_with_retry` sound.
//!
//! # Leases and shard reassignment
//!
//! With `lease_ops > 0` the coordinator runs a worker registry on a
//! logical op clock (every push/pull/heartbeat ticks it; wall time
//! would not replay).  A worker whose lease goes `lease_ops` ticks
//! without refresh is declared dead: its accumulated contribution is
//! *rolled out* of `w` (restoring `w = Σ_live X_pᵀ α_p` exactly), the
//! epoch is bumped so survivors rebase, and its shard ranges are
//! reassigned to the live worker with the fewest rows (or parked as
//! orphans until one heartbeats).  A dead worker's later pushes and
//! heartbeats answer `Revoked` — its life is over.

use std::collections::{BTreeMap, BTreeSet};
use std::path::PathBuf;
use std::sync::Mutex;

use anyhow::{ensure, Result};

use crate::coordinator::model_io::Model;
use crate::loss::LossKind;
use crate::obs::probes;
use crate::util::Json;

use super::protocol::{Heartbeat, HeartbeatReply, PushDelta, PushOutcome};

/// Dedup verdicts retained per worker (newest rounds win).  Far more
/// than any retry window needs; bounds memory over long runs.
const DEDUP_KEEP: usize = 128;

/// Coordinator policy: the merge rule's constants plus checkpointing
/// and the metadata stamped into saved models.
#[derive(Debug, Clone)]
pub struct MergeConfig {
    /// Configured worker count K — the damping denominator for stale
    /// deltas (weight `1/K`).
    pub workers: usize,
    /// Maximum tolerated merge-epoch lag; staler deltas are rejected
    /// with a resync order.
    pub max_lag: u64,
    /// Lease length in logical coordinator ops (pushes + pulls +
    /// heartbeats).  0 disables the registry entirely — no lease
    /// tracking, no death, no reassignment (the pre-chaos behavior;
    /// idle workers must not be revoked in plain sims).
    pub lease_ops: u64,
    /// Record a deterministic per-verdict merge trace (chaos replay
    /// compares it across runs).  Off by default: the trace grows with
    /// every push.
    pub record_trace: bool,
    /// Where to checkpoint the merged model (None = no checkpoints).
    pub checkpoint: Option<PathBuf>,
    /// Checkpoint every this many accepted merges (0 = only on
    /// explicit [`DistCoordinator::checkpoint_now`] calls).
    pub checkpoint_every: u64,
    /// Loss the workers optimize (stamped into checkpointed models).
    pub loss: LossKind,
    /// Penalty C (stamped into checkpointed models).
    pub c: f64,
    /// Dataset name (stamped into checkpointed models).
    pub dataset: String,
}

impl Default for MergeConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            max_lag: 8,
            lease_ops: 0,
            record_trace: false,
            checkpoint: None,
            checkpoint_every: 0,
            loss: LossKind::Hinge,
            c: 1.0,
            dataset: "dist".into(),
        }
    }
}

/// Registry entry for one worker id.
#[derive(Debug)]
struct WorkerEntry {
    /// Op-clock tick of the last push/pull/heartbeat from this worker.
    last_seen_op: u64,
    /// False once the lease expired: the life is over for good.
    alive: bool,
    /// Row ranges currently assigned to this worker.
    ranges: Vec<(u64, u64)>,
    /// Σ weight·delta over this worker's accepted merges — exactly
    /// `X_pᵀ (α_committed − α_initial)`, the amount rolled out of `w`
    /// if the lease expires.
    contrib: Vec<f64>,
}

/// Everything the merge rule mutates, under one mutex.  A merge is a
/// single dense axpy — microseconds even at d = 10^6 — so a mutex (not
/// the solver's atomic scatter machinery) is the right tool: the
/// contended path is cross-process HTTP, not this lock.
#[derive(Debug)]
struct State {
    w: Vec<f64>,
    epoch: u64,
    merges: u64,
    rejects: u64,
    /// Σ weight·delta_err over accepted merges: the worker-reported
    /// backward error carried into `w` (numerator of the gauge).
    err_accum: f64,
    workers_seen: BTreeSet<u64>,
    /// Logical clock: one tick per push/pull/heartbeat handled.
    op_clock: u64,
    /// Worker registry (populated in lease mode; heartbeats populate
    /// it even without leases, for stats).
    registry: BTreeMap<u64, WorkerEntry>,
    /// Recorded verdicts keyed `(worker, boot, round)`.
    recent: BTreeMap<(u64, u64, u64), PushOutcome>,
    /// Shard ranges reassigned so far.
    reassigns: u64,
    /// Ranges of dead workers awaiting a live claimant.
    orphaned: Vec<(u64, u64)>,
    /// Deterministic verdict/lease trace (when `record_trace`).
    merge_trace: Vec<String>,
}

/// The coordinator: shared global `w` + the bounded-staleness merge.
///
/// `Arc<DistCoordinator>` is shared between the HTTP dispatch path
/// (`net/server.rs` routes `/v1/dist/*` here via `Router::with_dist`)
/// and whatever owns the process lifetime (`passcode dist-coord`,
/// `dist-sim`).
pub struct DistCoordinator {
    cfg: MergeConfig,
    state: Mutex<State>,
}

impl std::fmt::Debug for DistCoordinator {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.state.lock().expect("coordinator state poisoned");
        f.debug_struct("DistCoordinator")
            .field("epoch", &s.epoch)
            .field("merges", &s.merges)
            .field("rejects", &s.rejects)
            .field("dim", &s.w.len())
            .field("cfg", &self.cfg)
            .finish()
    }
}

impl DistCoordinator {
    /// Start coordinating from an initial `w` (zeros for a fresh run,
    /// a loaded model's `w` to continue one).
    pub fn new(w: Vec<f64>, cfg: MergeConfig) -> DistCoordinator {
        probes::dist().merge_epoch.set(0.0);
        DistCoordinator {
            cfg,
            state: Mutex::new(State {
                w,
                epoch: 0,
                merges: 0,
                rejects: 0,
                err_accum: 0.0,
                workers_seen: BTreeSet::new(),
                op_clock: 0,
                registry: BTreeMap::new(),
                recent: BTreeMap::new(),
                reassigns: 0,
                orphaned: Vec::new(),
                merge_trace: Vec::new(),
            }),
        }
    }

    /// The configured merge policy.
    pub fn config(&self) -> &MergeConfig {
        &self.cfg
    }

    fn trace(&self, s: &mut State, line: String) {
        if self.cfg.record_trace {
            s.merge_trace.push(line);
        }
    }

    /// Refresh `worker`'s lease at the current op tick, creating its
    /// registry entry on first contact.  Returns false if the worker
    /// is already dead (lease mode only).
    fn refresh_lease(&self, s: &mut State, worker: u64) -> bool {
        if self.cfg.lease_ops == 0 {
            return true;
        }
        let dim = s.w.len();
        let tick = s.op_clock;
        let entry = s.registry.entry(worker).or_insert_with(|| WorkerEntry {
            last_seen_op: tick,
            alive: true,
            ranges: Vec::new(),
            contrib: vec![0.0; dim],
        });
        if !entry.alive {
            return false;
        }
        entry.last_seen_op = tick;
        true
    }

    /// Expire overdue leases: roll each dead worker's contribution out
    /// of `w`, bump the epoch so survivors rebase, and reassign (or
    /// orphan) its shard ranges.  `exempt` is the worker whose request
    /// is being handled — its lease was just refreshed.
    fn expire_leases(&self, s: &mut State, exempt: u64) {
        if self.cfg.lease_ops == 0 {
            return;
        }
        let now = s.op_clock;
        let expired: Vec<u64> = s
            .registry
            .iter()
            .filter(|(id, e)| **id != exempt && e.alive && now - e.last_seen_op > self.cfg.lease_ops)
            .map(|(id, _)| *id)
            .collect();
        for id in expired {
            let (ranges, contrib) = {
                let e = s.registry.get_mut(&id).expect("expired entry exists");
                e.alive = false;
                (std::mem::take(&mut e.ranges), std::mem::take(&mut e.contrib))
            };
            for (wi, ci) in s.w.iter_mut().zip(&contrib) {
                *wi -= ci;
            }
            s.epoch += 1;
            probes::dist().lease_expired.inc();
            probes::dist().merge_epoch.set(s.epoch as f64);
            self.trace(
                s,
                format!("lease-expire w{id} op{now}: rollback, epoch->{}", s.epoch),
            );
            for range in ranges {
                self.reassign_range(s, id, range);
            }
        }
        let alive = s.registry.values().filter(|e| e.alive).count();
        probes::dist().workers_alive.set(alive as f64);
    }

    /// Hand `range` (owned by dead `from`) to the live worker holding
    /// the fewest rows (ties → smallest id), or park it as an orphan.
    fn reassign_range(&self, s: &mut State, from: u64, range: (u64, u64)) {
        let target = s
            .registry
            .iter()
            .filter(|(_, e)| e.alive)
            .min_by_key(|(id, e)| {
                (e.ranges.iter().map(|(a, b)| b - a).sum::<u64>(), **id)
            })
            .map(|(id, _)| *id);
        match target {
            Some(to) => {
                s.registry.get_mut(&to).expect("target exists").ranges.push(range);
                s.reassigns += 1;
                probes::dist().reassigns.inc();
                self.trace(
                    s,
                    format!("reassign [{}, {}) w{from} -> w{to}", range.0, range.1),
                );
            }
            None => {
                s.orphaned.push(range);
                self.trace(
                    s,
                    format!("orphan [{}, {}) from w{from} (no live worker)", range.0, range.1),
                );
            }
        }
    }

    /// Record `verdict` under the push id and prune old records.
    fn remember(&self, s: &mut State, p: &PushDelta, verdict: PushOutcome) {
        s.recent.insert((p.worker, p.boot, p.round), verdict);
        let worker_keys: Vec<(u64, u64, u64)> = s
            .recent
            .range((p.worker, 0, 0)..=(p.worker, u64::MAX, u64::MAX))
            .map(|(k, _)| *k)
            .collect();
        if worker_keys.len() > DEDUP_KEEP {
            for k in &worker_keys[..worker_keys.len() - DEDUP_KEEP] {
                s.recent.remove(k);
            }
        }
    }

    /// Apply the bounded-staleness merge rule to one pushed delta.
    ///
    /// Errors mean a malformed push (dimension mismatch, non-finite
    /// values, or a base epoch from the future) — the HTTP layer maps
    /// them to 400.  A *stale* push is not an error: it returns
    /// [`PushOutcome::Resync`] and the delta is discarded.  A push
    /// whose `(worker, boot, round)` id was already decided returns
    /// the recorded verdict without touching `w`; a push from a
    /// dead-leased worker returns [`PushOutcome::Revoked`].
    pub fn push(&self, p: &PushDelta) -> Result<PushOutcome> {
        let mut s = self.state.lock().expect("coordinator state poisoned");
        let s = &mut *s;
        s.op_clock += 1;
        // A revoked life stays revoked — even for a retried round the
        // original of which merged: that contribution was rolled back,
        // so confirming it would desynchronize the worker's dual.
        if !self.refresh_lease(s, p.worker) {
            self.trace(s, format!("push w{} boot{} round{}: revoked", p.worker, p.boot, p.round));
            return Ok(PushOutcome::Revoked { epoch: s.epoch });
        }
        self.expire_leases(s, p.worker);
        if let Some(v) = s.recent.get(&(p.worker, p.boot, p.round)).copied() {
            probes::dist().dedup_hits.inc();
            self.trace(
                s,
                format!("push w{} boot{} round{}: dedup -> {v:?}", p.worker, p.boot, p.round),
            );
            return Ok(v);
        }
        ensure!(
            p.delta.len() == s.w.len(),
            "delta dimension {} != model dimension {}",
            p.delta.len(),
            s.w.len()
        );
        ensure!(
            p.delta.iter().all(|v| v.is_finite()) && p.delta_err.is_finite(),
            "worker {} pushed non-finite delta",
            p.worker
        );
        ensure!(
            p.base_epoch <= s.epoch,
            "worker {} claims base epoch {} but coordinator is at {}",
            p.worker,
            p.base_epoch,
            s.epoch
        );
        s.workers_seen.insert(p.worker);
        let lag = s.epoch - p.base_epoch;
        if lag > self.cfg.max_lag {
            s.rejects += 1;
            probes::dist().rejects.inc();
            let verdict = PushOutcome::Resync { epoch: s.epoch };
            self.remember(s, p, verdict);
            self.trace(
                s,
                format!(
                    "push w{} boot{} round{} base{} lag{lag}: resync@{}",
                    p.worker, p.boot, p.round, p.base_epoch, s.epoch
                ),
            );
            return Ok(verdict);
        }
        let weight =
            if lag == 0 { 1.0 } else { 1.0 / self.cfg.workers.max(1) as f64 };
        for (wi, di) in s.w.iter_mut().zip(&p.delta) {
            *wi += weight * di;
        }
        s.epoch += 1;
        s.merges += 1;
        s.err_accum += weight * p.delta_err;
        if self.cfg.lease_ops > 0 {
            if let Some(e) = s.registry.get_mut(&p.worker) {
                for (ci, di) in e.contrib.iter_mut().zip(&p.delta) {
                    *ci += weight * di;
                }
            }
        }
        let probes = probes::dist();
        probes.merges.inc();
        probes.merge_epoch.set(s.epoch as f64);
        probes.merge_lag.record(lag);
        let norm = s.w.iter().map(|v| v * v).sum::<f64>().sqrt();
        probes
            .backward_error_ratio
            .set(if norm > 0.0 { s.err_accum / norm } else { 0.0 });
        let outcome = PushOutcome::Accepted { epoch: s.epoch, weight };
        self.remember(s, p, outcome);
        self.trace(
            s,
            format!(
                "push w{} boot{} round{} base{} lag{lag}: accepted@{} weight {weight}",
                p.worker, p.boot, p.round, p.base_epoch, s.epoch
            ),
        );
        let due = self.cfg.checkpoint_every > 0 && s.merges % self.cfg.checkpoint_every == 0;
        if due {
            // Best-effort: a full disk must not fail the merge the
            // worker already committed to.
            if let Err(e) = self.write_checkpoint(&s.w) {
                eprintln!("dist-coord: checkpoint failed: {e:#}");
            }
        }
        Ok(outcome)
    }

    /// Handle one worker heartbeat: refresh (or create) its lease,
    /// adopt announced ranges on first contact, hand it any orphaned
    /// ranges, then expire overdue peers.  A dead worker gets a
    /// revoked reply and must stop pushing.
    pub fn heartbeat(&self, h: &Heartbeat) -> HeartbeatReply {
        let mut s = self.state.lock().expect("coordinator state poisoned");
        let s = &mut *s;
        s.op_clock += 1;
        probes::dist().heartbeats.inc();
        s.workers_seen.insert(h.worker);
        if self.cfg.lease_ops == 0 {
            // No registry: echo the announced ranges, nothing expires.
            return HeartbeatReply { revoked: false, epoch: s.epoch, shards: h.ranges.clone() };
        }
        if !self.refresh_lease(s, h.worker) {
            self.trace(s, format!("heartbeat w{}: revoked", h.worker));
            return HeartbeatReply { revoked: true, epoch: s.epoch, shards: Vec::new() };
        }
        {
            let entry = s.registry.get_mut(&h.worker).expect("lease just refreshed");
            if entry.ranges.is_empty() {
                // First contact announces what the worker loaded; the
                // coordinator owns the assignment from here on.
                entry.ranges = h.ranges.clone();
            }
        }
        if !s.orphaned.is_empty() {
            let orphans = std::mem::take(&mut s.orphaned);
            for range in orphans {
                self.reassign_range(s, h.worker, range);
            }
        }
        self.expire_leases(s, h.worker);
        let entry = s.registry.get(&h.worker).expect("lease just refreshed");
        HeartbeatReply { revoked: false, epoch: s.epoch, shards: entry.ranges.clone() }
    }

    /// Lease refresh piggybacked on a pull (`GET /v1/dist/pull_w
    /// ?worker=ID`).  Ticks the op clock and may expire peers; a dead
    /// worker's pull still serves `w` (harmless — the revocation
    /// arrives with its next push or heartbeat).
    pub fn touch(&self, worker: u64) {
        let mut s = self.state.lock().expect("coordinator state poisoned");
        let s = &mut *s;
        s.op_clock += 1;
        if self.refresh_lease(s, worker) {
            self.expire_leases(s, worker);
        }
    }

    /// Snapshot `(merge_epoch, w)` for a puller.
    pub fn pull(&self) -> (u64, Vec<f64>) {
        let s = self.state.lock().expect("coordinator state poisoned");
        (s.epoch, s.w.clone())
    }

    /// The current assignment table: `(worker, alive, ranges)` per
    /// registered worker, sorted by id.  The in-process chaos driver
    /// reads this to rebuild workers after a reassignment; does not
    /// tick the op clock (it is introspection, not worker traffic).
    pub fn assignments(&self) -> Vec<(u64, bool, Vec<(u64, u64)>)> {
        let s = self.state.lock().expect("coordinator state poisoned");
        s.registry
            .iter()
            .map(|(id, e)| (*id, e.alive, e.ranges.clone()))
            .collect()
    }

    /// Shard ranges reassigned so far.
    pub fn reassign_count(&self) -> u64 {
        self.state.lock().expect("coordinator state poisoned").reassigns
    }

    /// The deterministic merge/lease trace (empty unless
    /// `record_trace` was set).
    pub fn merge_trace(&self) -> Vec<String> {
        self.state.lock().expect("coordinator state poisoned").merge_trace.clone()
    }

    /// Merge statistics as JSON (served at `GET /v1/dist/stats`).
    pub fn stats_json(&self) -> Json {
        let s = self.state.lock().expect("coordinator state poisoned");
        let norm = s.w.iter().map(|v| v * v).sum::<f64>().sqrt();
        let alive = s.registry.values().filter(|e| e.alive).count();
        Json::obj(vec![
            ("merge_epoch", Json::num(s.epoch as f64)),
            ("merges", Json::num(s.merges as f64)),
            ("rejects", Json::num(s.rejects as f64)),
            ("dim", Json::num(s.w.len() as f64)),
            ("workers_seen", Json::num(s.workers_seen.len() as f64)),
            ("workers_alive", Json::num(alive as f64)),
            ("reassigns", Json::num(s.reassigns as f64)),
            ("max_lag", Json::num(self.cfg.max_lag as f64)),
            ("lease_ops", Json::num(self.cfg.lease_ops as f64)),
            ("w_norm", Json::num(norm)),
            (
                "backward_error_ratio",
                Json::num(if norm > 0.0 { s.err_accum / norm } else { 0.0 }),
            ),
        ])
    }

    /// Checkpoint the merged model now (no-op without a configured
    /// checkpoint path).
    pub fn checkpoint_now(&self) -> Result<()> {
        let w = {
            let s = self.state.lock().expect("coordinator state poisoned");
            s.w.clone()
        };
        self.write_checkpoint(&w)
    }

    fn write_checkpoint(&self, w: &[f64]) -> Result<()> {
        let Some(path) = &self.cfg.checkpoint else { return Ok(()) };
        Model {
            w: w.to_vec(),
            loss: self.cfg.loss.name().to_string(),
            c: self.cfg.c,
            solver: "dist-hybrid-dca".to_string(),
            dataset: self.cfg.dataset.clone(),
        }
        .save(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn push(worker: u64, round: u64, base_epoch: u64, delta: Vec<f64>) -> PushDelta {
        PushDelta { worker, boot: 0, round, base_epoch, delta_err: 0.0, delta }
    }

    fn coord(max_lag: u64) -> DistCoordinator {
        DistCoordinator::new(
            vec![0.0; 3],
            MergeConfig { workers: 2, max_lag, ..Default::default() },
        )
    }

    #[test]
    fn fresh_delta_merges_at_full_weight() {
        let c = coord(4);
        match c.push(&push(0, 0, 0, vec![1.0, 2.0, 3.0])).unwrap() {
            PushOutcome::Accepted { epoch, weight } => {
                assert_eq!(epoch, 1);
                assert_eq!(weight, 1.0);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(c.pull(), (1, vec![1.0, 2.0, 3.0]));
    }

    #[test]
    fn stale_delta_is_damped_by_one_over_k() {
        let c = coord(4);
        c.push(&push(0, 0, 0, vec![1.0, 0.0, 0.0])).unwrap();
        // Worker 1 still based on epoch 0: lag 1, weight 1/2.
        match c.push(&push(1, 0, 0, vec![0.0, 4.0, 0.0])).unwrap() {
            PushOutcome::Accepted { epoch, weight } => {
                assert_eq!(epoch, 2);
                assert_eq!(weight, 0.5);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(c.pull().1, vec![1.0, 2.0, 0.0]);
    }

    #[test]
    fn beyond_lag_is_rejected_and_epoch_monotonic() {
        let c = coord(1);
        for round in 0..3 {
            c.push(&push(0, round, c.pull().0, vec![1.0, 0.0, 0.0])).unwrap();
        }
        let before = c.pull();
        // Base epoch 0 against coordinator epoch 3, max_lag 1: resync.
        match c.push(&push(1, 0, 0, vec![9.0, 9.0, 9.0])).unwrap() {
            PushOutcome::Resync { epoch } => assert_eq!(epoch, 3),
            other => panic!("unexpected {other:?}"),
        }
        // Rejected delta must not touch w or the epoch.
        assert_eq!(c.pull(), before);
        let stats = c.stats_json();
        assert_eq!(stats.get("rejects").unwrap().as_usize().unwrap(), 1);
        assert_eq!(stats.get("merges").unwrap().as_usize().unwrap(), 3);
    }

    #[test]
    fn duplicate_push_merges_exactly_once() {
        let c = coord(4);
        let p = push(0, 7, 0, vec![1.0, 2.0, 3.0]);
        let first = c.push(&p).unwrap();
        assert!(matches!(first, PushOutcome::Accepted { epoch: 1, .. }));
        // A byte-identical retry answers the recorded verdict and
        // leaves w, the epoch, and the merge count untouched.
        for _ in 0..3 {
            assert_eq!(c.push(&p).unwrap(), first);
        }
        assert_eq!(c.pull(), (1, vec![1.0, 2.0, 3.0]));
        let stats = c.stats_json();
        assert_eq!(stats.get("merges").unwrap().as_usize().unwrap(), 1);
        // A rejected round's retry re-answers the recorded Resync too.
        let c = coord(0);
        c.push(&push(0, 0, 0, vec![1.0, 0.0, 0.0])).unwrap();
        let stale = push(1, 0, 0, vec![0.0, 1.0, 0.0]);
        let v1 = c.push(&stale).unwrap();
        assert!(matches!(v1, PushOutcome::Resync { .. }));
        assert_eq!(c.push(&stale).unwrap(), v1);
        assert_eq!(c.stats_json().get("rejects").unwrap().as_usize().unwrap(), 1);
        // A different boot is a different life: not deduped.
        let c = coord(4);
        c.push(&push(0, 0, 0, vec![1.0, 0.0, 0.0])).unwrap();
        let mut rejoin = push(0, 0, 1, vec![1.0, 0.0, 0.0]);
        rejoin.boot = 1;
        assert!(matches!(c.push(&rejoin).unwrap(), PushOutcome::Accepted { epoch: 2, .. }));
    }

    #[test]
    fn lease_expiry_rolls_back_reassigns_and_revokes() {
        let c = DistCoordinator::new(
            vec![0.0; 3],
            MergeConfig {
                workers: 2,
                max_lag: 64,
                lease_ops: 3,
                record_trace: true,
                ..Default::default()
            },
        );
        // Both workers announce their shards and contribute once.
        assert!(!c.heartbeat(&Heartbeat { worker: 0, ranges: vec![(0, 50)] }).revoked);
        assert!(!c.heartbeat(&Heartbeat { worker: 1, ranges: vec![(50, 100)] }).revoked);
        c.push(&push(0, 0, 0, vec![1.0, 0.0, 0.0])).unwrap();
        c.push(&push(1, 0, 1, vec![0.0, 2.0, 0.0])).unwrap();
        let epoch_before = c.pull().0;
        // Worker 1 goes silent; worker 0 keeps the op clock moving
        // past the lease bound.
        for round in 1..6 {
            c.push(&push(0, round, c.pull().0, vec![1.0, 0.0, 0.0])).unwrap();
        }
        // Worker 1 is dead: its full-weight contribution was rolled
        // back out of w, its range moved to worker 0.
        let w = c.pull().1;
        assert_eq!(w[1], 0.0, "dead worker's contribution still in w: {w:?}");
        assert!(c.pull().0 > epoch_before);
        let assigns = c.assignments();
        let w0 = assigns.iter().find(|(id, _, _)| *id == 0).unwrap();
        let w1 = assigns.iter().find(|(id, _, _)| *id == 1).unwrap();
        assert!(w0.1 && !w1.1, "{assigns:?}");
        assert!(w0.2.contains(&(50, 100)), "{assigns:?}");
        assert!(w1.2.is_empty(), "{assigns:?}");
        assert_eq!(c.reassign_count(), 1);
        // The dead worker's later push and heartbeat answer Revoked.
        assert!(matches!(
            c.push(&push(1, 1, 0, vec![0.0, 1.0, 0.0])).unwrap(),
            PushOutcome::Revoked { .. }
        ));
        assert!(c.heartbeat(&Heartbeat { worker: 1, ranges: vec![(50, 100)] }).revoked);
        assert!(c.merge_trace().iter().any(|l| l.contains("lease-expire w1")));
        assert!(c.merge_trace().iter().any(|l| l.contains("reassign [50, 100) w1 -> w0")));
    }

    #[test]
    fn expired_ranges_pass_to_the_emptiest_live_worker() {
        let c = DistCoordinator::new(
            vec![0.0; 2],
            MergeConfig { workers: 2, max_lag: 64, lease_ops: 2, ..Default::default() },
        );
        c.heartbeat(&Heartbeat { worker: 0, ranges: vec![(0, 10)] });
        // Worker 0 goes silent; a newcomer's traffic moves the op
        // clock past the lease bound.  The newcomer holds no rows, so
        // the expired range lands on it.
        for _ in 0..4 {
            c.touch(7);
        }
        let reply = c.heartbeat(&Heartbeat { worker: 7, ranges: vec![] });
        assert!(!reply.revoked);
        assert_eq!(reply.shards, vec![(0, 10)]);
        assert_eq!(c.reassign_count(), 1);
    }

    #[test]
    fn malformed_pushes_error() {
        let c = coord(4);
        assert!(c.push(&push(0, 0, 0, vec![1.0])).is_err(), "dim mismatch accepted");
        assert!(
            c.push(&push(0, 1, 0, vec![f64::NAN, 0.0, 0.0])).is_err(),
            "NaN accepted"
        );
        assert!(c.push(&push(0, 2, 5, vec![0.0; 3])).is_err(), "future epoch accepted");
        // Errors never advance the epoch.
        assert_eq!(c.pull().0, 0);
    }

    #[test]
    fn checkpoints_land_through_model_io() {
        let dir = std::env::temp_dir().join("passcode-dist-ckpt-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.json");
        let c = DistCoordinator::new(
            vec![0.0; 2],
            MergeConfig {
                workers: 2,
                max_lag: 4,
                checkpoint: Some(path.clone()),
                checkpoint_every: 1,
                ..Default::default()
            },
        );
        c.push(&push(0, 0, 0, vec![0.5, -0.5])).unwrap();
        let m = Model::load(&path).unwrap();
        assert_eq!(m.w, vec![0.5, -0.5]);
        assert_eq!(m.solver, "dist-hybrid-dca");
        std::fs::remove_file(&path).ok();
    }
}
