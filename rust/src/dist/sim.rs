//! Single-process distributed simulation: N in-process workers over a
//! real loopback HTTP coordinator.
//!
//! `passcode dist-sim` (and the integration tests / CI smoke step)
//! exercise the full distributed path — sharding, worker sessions,
//! binary push/pull bodies, the bounded-staleness merge, metrics —
//! without any orchestration: one process, one `Server` on
//! `127.0.0.1:0`, one OS thread per worker.  Because the workers race
//! through the real coordinator, the run is a genuine asynchronous
//! Hybrid-DCA execution, just with loopback latency.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{anyhow, ensure, Context, Result};

use crate::data::shard::{extract, plan_ranges, ShardManifest};
use crate::data::registry;
use crate::eval;
use crate::loss::{DynLoss, LossKind};
use crate::net::{Router, Server, ServerConfig};

use super::client::DistClient;
use super::coordinator::{DistCoordinator, MergeConfig};
use super::worker::{DistWorker, WorkerConfig, WorkerReport};

/// Simulation shape.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Registry dataset to shard.
    pub dataset: String,
    /// Registry scale factor.
    pub scale: f64,
    /// Worker (= shard) count.
    pub workers: usize,
    /// Push rounds per worker.
    pub rounds: usize,
    /// Local epochs per round.
    pub epochs_per_round: usize,
    /// Local solver registry name.
    pub solver: String,
    /// Loss the workers optimize.
    pub loss: LossKind,
    /// Threads per worker's local solve.
    pub threads_per_worker: usize,
    /// Coordinator staleness bound.
    pub max_lag: u64,
    /// Base seed (each worker mixes in its id).
    pub seed: u64,
    /// Coordinator model checkpoint path (None = none).
    pub checkpoint: Option<PathBuf>,
    /// Write the shard manifest JSON here (None = don't).
    pub manifest_out: Option<PathBuf>,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self {
            dataset: "rcv1".into(),
            scale: 0.05,
            workers: 2,
            rounds: 6,
            epochs_per_round: 2,
            solver: "passcode-atomic".into(),
            loss: LossKind::Hinge,
            threads_per_worker: 1,
            max_lag: 8,
            seed: 42,
            checkpoint: None,
            manifest_out: None,
        }
    }
}

/// What a simulation run produced.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Final merged `w` pulled from the coordinator.
    pub w: Vec<f64>,
    /// Global dual: the workers' committed blocks concatenated in
    /// shard order.
    pub alpha: Vec<f64>,
    /// Final merge epoch (= accepted merges).
    pub merge_epoch: u64,
    /// Accepted merges.
    pub merges: u64,
    /// Rejected (resync'd) pushes.
    pub rejects: u64,
    /// Primal objective of the merged `w` on the training shard union.
    pub primal: f64,
    /// Duality gap of the concatenated dual.
    pub gap: f64,
    /// Test-set accuracy of the merged `w`.
    pub test_accuracy: f64,
    /// Coordinator's accumulated backward-error ratio.
    pub backward_error_ratio: f64,
    /// Per-worker round/epoch/update counts.
    pub workers: Vec<WorkerReport>,
    /// The `passcode_dist_*` lines of a final `/metrics` scrape.
    pub dist_metrics: Vec<String>,
}

/// Run the simulation: shard, boot a loopback coordinator, race the
/// workers through it, and score the merged model.
pub fn run_sim(cfg: &SimConfig) -> Result<SimReport> {
    ensure!(cfg.workers > 0, "need at least one worker");
    ensure!(cfg.rounds > 0, "need at least one round");
    let (train, test, c) = registry::load(&cfg.dataset, cfg.scale)?;
    let ranges = plan_ranges(train.n(), cfg.workers);
    let shards: Vec<_> = ranges.iter().map(|r| extract(&train, r)).collect();
    if let Some(path) = &cfg.manifest_out {
        ShardManifest {
            dataset: cfg.dataset.clone(),
            scale: cfg.scale,
            n: train.n(),
            d: train.d(),
            c,
            shards: ranges.clone(),
        }
        .save(path)?;
    }

    let coord = Arc::new(DistCoordinator::new(
        vec![0.0; train.d()],
        MergeConfig {
            workers: cfg.workers,
            max_lag: cfg.max_lag,
            checkpoint: cfg.checkpoint.clone(),
            checkpoint_every: if cfg.checkpoint.is_some() { cfg.workers as u64 } else { 0 },
            loss: cfg.loss,
            c,
            dataset: cfg.dataset.clone(),
        },
    ));
    let server = Server::start(
        Router::empty().with_dist(Arc::clone(&coord)),
        &ServerConfig { addr: "127.0.0.1:0".into(), ..Default::default() },
    )?;
    let addr = server.addr();

    let worker_results: Vec<Result<(WorkerReport, Vec<f64>)>> =
        std::thread::scope(|s| {
            let handles: Vec<_> = shards
                .iter()
                .enumerate()
                .map(|(id, shard)| {
                    let wcfg = WorkerConfig {
                        id: id as u64,
                        solver: cfg.solver.clone(),
                        loss: cfg.loss,
                        c,
                        threads: cfg.threads_per_worker,
                        epochs_per_round: cfg.epochs_per_round,
                        rounds: cfg.rounds,
                        seed: cfg.seed,
                        checkpoint: None,
                    };
                    s.spawn(move || -> Result<(WorkerReport, Vec<f64>)> {
                        let mut client = DistClient::new(addr);
                        let mut worker = DistWorker::new(shard, wcfg)?;
                        let report = worker.run(&mut client, None)?;
                        Ok((report, worker.alpha().to_vec()))
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap_or_else(|_| Err(anyhow!("worker thread panicked"))))
                .collect()
        });

    let mut reports = Vec::with_capacity(cfg.workers);
    let mut alpha = Vec::with_capacity(train.n());
    for (id, r) in worker_results.into_iter().enumerate() {
        let (report, block) = r.with_context(|| format!("worker {id} failed"))?;
        reports.push(report);
        alpha.extend_from_slice(&block);
    }
    ensure!(alpha.len() == train.n(), "dual blocks do not cover the dataset");

    let (merge_epoch, w) = coord.pull();
    let stats = coord.stats_json();
    let dist_metrics: Vec<String> = {
        crate::obs::probes::sync_hot_counters();
        crate::obs::registry()
            .render()
            .lines()
            .filter(|l| l.contains("passcode_dist_"))
            .map(str::to_string)
            .collect()
    };
    server.shutdown();

    let loss = DynLoss::new(cfg.loss, c);
    Ok(SimReport {
        primal: eval::primal_objective(&train, &loss, &w),
        gap: eval::duality_gap(&train, &loss, &alpha),
        test_accuracy: eval::accuracy(&test, &w),
        merge_epoch,
        merges: stats.get("merges")?.as_f64()? as u64,
        rejects: stats.get("rejects")?.as_f64()? as u64,
        backward_error_ratio: stats.get("backward_error_ratio")?.as_f64()?,
        w,
        alpha,
        workers: reports,
        dist_metrics,
    })
}
