//! Single-process distributed simulation: N in-process workers over a
//! real loopback HTTP coordinator.
//!
//! `passcode dist-sim` (and the integration tests / CI smoke step)
//! exercise the full distributed path — sharding, worker sessions,
//! binary push/pull bodies, the bounded-staleness merge, metrics —
//! without any orchestration: one process, one `Server` on
//! `127.0.0.1:0`, one OS thread per worker.  Because the workers race
//! through the real coordinator, the run is a genuine asynchronous
//! Hybrid-DCA execution, just with loopback latency.
//!
//! # Chaos mode
//!
//! With [`SimConfig::chaos`] set, the sim switches to a deterministic
//! single-threaded driver: every worker's [`DistClient`] rides a
//! [`FaultyTransport`] seeded from the [`FaultPlan`], workers are
//! stepped round-robin on one thread, and the coordinator runs with
//! op-clock leases ([`SimConfig::lease_ops`]) and its merge trace
//! recorder on.  Determinism is the point — the same plan replays the
//! same fault sequence and the same merge-epoch trace, so a chaos
//! failure is reproducible from its seed exactly like a `passcode
//! check` schedule.
//!
//! When a lease expires mid-run the coordinator rolls the dead
//! worker's contribution out of `w` and reassigns its row ranges; the
//! driver notices the new assignment map and rebuilds the affected
//! workers over their enlarged shards — committed dual carried over
//! for rows they already owned, zeros for adopted rows (whose dual the
//! rollback really did zero).  The Σ-invariant `w = Σ_p X_pᵀ α_p` is
//! checked at the end across everything that happened
//! ([`SimReport::sigma_residual`]).

use std::net::SocketAddr;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, ensure, Context, Result};

use crate::data::registry;
use crate::data::shard::{extract, plan_ranges, ShardManifest, ShardRange};
use crate::data::Dataset;
use crate::eval;
use crate::loss::{DynLoss, LossKind};
use crate::net::{ClientConfig, Router, Server, ServerConfig};

use super::chaos::{FaultLog, FaultPlan, FaultyTransport};
use super::client::{DistClient, HttpTransport};
use super::coordinator::{DistCoordinator, MergeConfig};
use super::protocol::Heartbeat;
use super::worker::{DistWorker, WorkerConfig, WorkerReport};

/// Simulation shape.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Registry dataset to shard.
    pub dataset: String,
    /// Registry scale factor.
    pub scale: f64,
    /// Worker (= shard) count.
    pub workers: usize,
    /// Push rounds per worker.
    pub rounds: usize,
    /// Local epochs per round.
    pub epochs_per_round: usize,
    /// Local solver registry name.
    pub solver: String,
    /// Loss the workers optimize.
    pub loss: LossKind,
    /// Threads per worker's local solve.
    pub threads_per_worker: usize,
    /// Coordinator staleness bound.
    pub max_lag: u64,
    /// Base seed (each worker mixes in its id).
    pub seed: u64,
    /// Coordinator model checkpoint path (None = none).
    pub checkpoint: Option<PathBuf>,
    /// Write the shard manifest JSON here (None = don't).
    pub manifest_out: Option<PathBuf>,
    /// Inject transport faults from this plan (switches the sim to the
    /// deterministic single-threaded chaos driver).
    pub chaos: Option<FaultPlan>,
    /// Coordinator lease length in logical ops (0 = no leases; chaos
    /// runs that want death/reassignment set this).
    pub lease_ops: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self {
            dataset: "rcv1".into(),
            scale: 0.05,
            workers: 2,
            rounds: 6,
            epochs_per_round: 2,
            solver: "passcode-atomic".into(),
            loss: LossKind::Hinge,
            threads_per_worker: 1,
            max_lag: 8,
            seed: 42,
            checkpoint: None,
            manifest_out: None,
            chaos: None,
            lease_ops: 0,
        }
    }
}

/// What a simulation run produced.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Final merged `w` pulled from the coordinator.
    pub w: Vec<f64>,
    /// Global dual in row order (the workers' committed blocks; rows
    /// of a dead worker are zero — their contribution was rolled back).
    pub alpha: Vec<f64>,
    /// Final merge epoch.
    pub merge_epoch: u64,
    /// Accepted merges.
    pub merges: u64,
    /// Rejected (resync'd) pushes.
    pub rejects: u64,
    /// Shard ranges reassigned off dead workers.
    pub reassigns: u64,
    /// Primal objective of the merged `w` on the training shard union.
    pub primal: f64,
    /// Duality gap of the concatenated dual.
    pub gap: f64,
    /// Test-set accuracy of the merged `w`.
    pub test_accuracy: f64,
    /// Coordinator's accumulated backward-error ratio.
    pub backward_error_ratio: f64,
    /// ‖w − Xᵀα‖ / ‖w‖ over the full training set — the Σ-invariant
    /// residual.  Near machine precision for single-threaded workers
    /// (faults must not perturb it); with multi-threaded local solves
    /// it absorbs their genuine Theorem-3 write loss.
    pub sigma_residual: f64,
    /// Per-worker round/epoch/update counts.
    pub workers: Vec<WorkerReport>,
    /// The `passcode_dist_*` lines of a final `/metrics` scrape.
    pub dist_metrics: Vec<String>,
    /// Chaos only: every injected fault, in injection order.
    pub fault_events: Vec<String>,
    /// Chaos only: the coordinator's per-verdict merge trace.
    pub merge_trace: Vec<String>,
}

/// Run the simulation: shard, boot a loopback coordinator, race the
/// workers through it (or step them deterministically under a fault
/// plan), and score the merged model.
pub fn run_sim(cfg: &SimConfig) -> Result<SimReport> {
    ensure!(cfg.workers > 0, "need at least one worker");
    ensure!(cfg.rounds > 0, "need at least one round");
    let (train, test, c) = registry::load(&cfg.dataset, cfg.scale)?;
    let ranges = plan_ranges(train.n(), cfg.workers);
    if let Some(path) = &cfg.manifest_out {
        ShardManifest {
            dataset: cfg.dataset.clone(),
            scale: cfg.scale,
            n: train.n(),
            d: train.d(),
            c,
            shards: ranges.clone(),
        }
        .save(path)?;
    }

    let coord = Arc::new(DistCoordinator::new(
        vec![0.0; train.d()],
        MergeConfig {
            workers: cfg.workers,
            max_lag: cfg.max_lag,
            lease_ops: cfg.lease_ops,
            record_trace: cfg.chaos.is_some(),
            checkpoint: cfg.checkpoint.clone(),
            checkpoint_every: if cfg.checkpoint.is_some() { cfg.workers as u64 } else { 0 },
            loss: cfg.loss,
            c,
            dataset: cfg.dataset.clone(),
        },
    ));
    let server = Server::start(
        Router::empty().with_dist(Arc::clone(&coord)),
        &ServerConfig { addr: "127.0.0.1:0".into(), ..Default::default() },
    )?;
    let addr = server.addr();

    let (reports, alpha, fault_events) = match &cfg.chaos {
        Some(plan) => run_chaos(cfg, &train, &ranges, plan, addr, &coord, c)?,
        None => run_threaded(cfg, &train, &ranges, addr, c)?,
    };
    ensure!(alpha.len() == train.n(), "dual does not cover the dataset");

    let (merge_epoch, w) = coord.pull();
    let stats = coord.stats_json();
    let dist_metrics: Vec<String> = {
        crate::obs::probes::sync_hot_counters();
        crate::obs::registry()
            .render()
            .lines()
            .filter(|l| l.contains("passcode_dist_"))
            .map(str::to_string)
            .collect()
    };
    let merge_trace = coord.merge_trace();
    let reassigns = coord.reassign_count();
    server.shutdown();

    // Σ-invariant: the merged w against X^T of the committed global
    // dual, across every merge, rollback, and reassignment that ran.
    let exact = train.x.transpose_dot(&alpha);
    let w_norm = w.iter().map(|v| v * v).sum::<f64>().sqrt();
    let resid =
        w.iter().zip(&exact).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
    let sigma_residual = if w_norm > 0.0 { resid / w_norm } else { resid };

    let loss = DynLoss::new(cfg.loss, c);
    Ok(SimReport {
        primal: eval::primal_objective(&train, &loss, &w),
        gap: eval::duality_gap(&train, &loss, &alpha),
        test_accuracy: eval::accuracy(&test, &w),
        merge_epoch,
        merges: stats.get("merges")?.as_f64()? as u64,
        rejects: stats.get("rejects")?.as_f64()? as u64,
        reassigns,
        backward_error_ratio: stats.get("backward_error_ratio")?.as_f64()?,
        sigma_residual,
        w,
        alpha,
        workers: reports,
        dist_metrics,
        fault_events,
        merge_trace,
    })
}

/// The fault-free path: one OS thread per worker, racing through the
/// coordinator for a genuinely asynchronous execution.
fn run_threaded(
    cfg: &SimConfig,
    train: &Dataset,
    ranges: &[ShardRange],
    addr: SocketAddr,
    c: f64,
) -> Result<(Vec<WorkerReport>, Vec<f64>, Vec<String>)> {
    let shards: Vec<_> = ranges.iter().map(|r| extract(train, r)).collect();
    let worker_results: Vec<Result<(WorkerReport, Vec<f64>)>> =
        std::thread::scope(|s| {
            let handles: Vec<_> = shards
                .iter()
                .enumerate()
                .map(|(id, shard)| {
                    let wcfg = WorkerConfig {
                        id: id as u64,
                        solver: cfg.solver.clone(),
                        loss: cfg.loss,
                        c,
                        threads: cfg.threads_per_worker,
                        epochs_per_round: cfg.epochs_per_round,
                        rounds: cfg.rounds,
                        seed: cfg.seed,
                        checkpoint: None,
                        heartbeat: false,
                        ranges: Vec::new(),
                    };
                    s.spawn(move || -> Result<(WorkerReport, Vec<f64>)> {
                        let mut client = DistClient::new(addr);
                        let mut worker = DistWorker::new(shard, wcfg)?;
                        let report = worker.run(&mut client, None)?;
                        Ok((report, worker.alpha().to_vec()))
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap_or_else(|_| Err(anyhow!("worker thread panicked"))))
                .collect()
        });

    let mut reports = Vec::with_capacity(cfg.workers);
    let mut alpha = Vec::new();
    for (id, r) in worker_results.into_iter().enumerate() {
        let (report, block) = r.with_context(|| format!("worker {id} failed"))?;
        reports.push(report);
        alpha.extend_from_slice(&block);
    }
    Ok((reports, alpha, Vec::new()))
}

/// Global row indices covered by `ranges`, in announcement order (the
/// order the union shard's rows are laid out in).
fn rows_of(ranges: &[(u64, u64)]) -> impl Iterator<Item = usize> + '_ {
    ranges.iter().flat_map(|&(a, b)| (a as usize)..(b as usize))
}

/// Slice the union of several global row ranges out of `ds` as one
/// shard (a reassignment can leave a worker holding non-adjacent
/// ranges; row order follows the range list).
fn union_extract(ds: &Dataset, ranges: &[(u64, u64)]) -> Dataset {
    let rows: Vec<usize> = rows_of(ranges).collect();
    Dataset::new(
        ds.x.select_rows(&rows),
        rows.iter().map(|&i| ds.y[i]).collect(),
        format!("{}[union of {} ranges]", ds.name, ranges.len()),
    )
}

/// The chaos path: deterministic round-robin stepping on one thread,
/// every client behind a seeded [`FaultyTransport`], with generation
/// rebuilds whenever the coordinator's assignment map changes.
fn run_chaos(
    cfg: &SimConfig,
    train: &Dataset,
    ranges: &[ShardRange],
    plan: &FaultPlan,
    addr: SocketAddr,
    coord: &Arc<DistCoordinator>,
    c: f64,
) -> Result<(Vec<WorkerReport>, Vec<f64>, Vec<String>)> {
    let k = cfg.workers;
    let plan = Arc::new(plan.clone());
    let log: FaultLog = Arc::new(Mutex::new(Vec::new()));
    // The faulty transport simulates drops/partitions above HTTP, so
    // the HTTP layer underneath keeps only a light real-socket retry.
    let client_cfg = ClientConfig {
        connect_timeout: Duration::from_secs(5),
        read_timeout: Duration::from_secs(10),
        retries: 1,
        backoff: Duration::from_millis(5),
    };
    let mut clients: Vec<DistClient> = (0..k)
        .map(|id| {
            let inner = HttpTransport::new(addr, client_cfg.clone());
            let mut cl = DistClient::over(Box::new(FaultyTransport::new(
                Box::new(inner),
                id as u64,
                Arc::clone(&plan),
                Arc::clone(&log),
            )));
            cl.set_worker(id as u64);
            cl
        })
        .collect();

    // Driver-side ownership map, kept in lockstep with the
    // coordinator's registry.
    let mut owned: Vec<Vec<(u64, u64)>> =
        ranges.iter().map(|r| vec![(r.start as u64, r.end as u64)]).collect();
    let mut dead = vec![false; k];
    let mut global_alpha = vec![0.0; train.n()];
    let mut acc = vec![WorkerReport::default(); k];

    // Register every worker (announce its ranges) before the fault
    // plan gets a chance to hide one from the lease registry.
    for id in 0..k {
        let hb = Heartbeat { worker: id as u64, ranges: owned[id].clone() };
        let registered = (0..16).any(|_| clients[id].heartbeat(&hb).is_ok());
        ensure!(registered, "worker {id} could not register (16 heartbeats faulted)");
    }

    let target = cfg.rounds * cfg.epochs_per_round;
    let max_steps = (cfg.rounds * k).saturating_mul(16) + 256;
    let mut steps = 0usize;
    let mut view = coord.assignments();

    'generations: loop {
        // Build this generation: a union shard and a worker life per
        // live owner.  Committed dual carries over for rows a worker
        // already owned; adopted rows start at zero (the dead owner's
        // rollback zeroed their contribution).
        let shards: Vec<Option<Dataset>> = (0..k)
            .map(|id| {
                (!dead[id] && !owned[id].is_empty())
                    .then(|| union_extract(train, &owned[id]))
            })
            .collect();
        let mut lives: Vec<Option<DistWorker>> = Vec::with_capacity(k);
        for id in 0..k {
            match &shards[id] {
                None => lives.push(None),
                Some(shard) => {
                    let wcfg = WorkerConfig {
                        id: id as u64,
                        solver: cfg.solver.clone(),
                        loss: cfg.loss,
                        c,
                        threads: cfg.threads_per_worker,
                        epochs_per_round: cfg.epochs_per_round,
                        rounds: cfg.rounds,
                        seed: cfg.seed,
                        checkpoint: None,
                        heartbeat: true,
                        ranges: owned[id].clone(),
                    };
                    let dual: Vec<f64> = rows_of(&owned[id]).map(|i| global_alpha[i]).collect();
                    lives.push(Some(
                        DistWorker::with_dual(shard, wcfg, dual)
                            .with_context(|| format!("rebuilding worker {id}"))?,
                    ));
                }
            }
        }

        loop {
            let mut progressed = false;
            for id in 0..k {
                let Some(worker) = lives[id].as_mut() else { continue };
                if worker.is_revoked()
                    || acc[id].epochs + worker.report().epochs >= target
                {
                    continue;
                }
                // A faulted round stalls the worker, it doesn't kill
                // the sim — that is the scenario under test.
                let _ = worker.run_round(&mut clients[id]);
                progressed = true;
                steps += 1;
                if steps >= max_steps {
                    break;
                }
            }
            let now = coord.assignments();
            let changed = now != view;
            let done = !progressed || steps >= max_steps;
            if !(changed || done) {
                continue;
            }

            // Tear the generation down: settle in-flight pushes, then
            // harvest each life's committed dual into global row
            // coordinates.  A worker the coordinator declared dead was
            // rolled back — its rows' committed dual is zero no matter
            // what the (possibly partitioned, still unaware) worker
            // believes.
            let coord_dead: Vec<bool> = (0..k)
                .map(|id| {
                    now.iter()
                        .find(|(wid, _, _)| *wid == id as u64)
                        .is_some_and(|(_, alive, _)| !alive)
                })
                .collect();
            for id in 0..k {
                let Some(worker) = lives[id].as_mut() else { continue };
                let is_dead = coord_dead[id] || worker.is_revoked();
                if !is_dead {
                    for _ in 0..32 {
                        if worker.is_revoked()
                            || worker.settle(&mut clients[id]).unwrap_or(false)
                        {
                            break;
                        }
                    }
                }
                let r = worker.report();
                acc[id].rounds += r.rounds;
                acc[id].accepted += r.accepted;
                acc[id].resyncs += r.resyncs;
                acc[id].epochs += r.epochs;
                acc[id].updates += r.updates;
                acc[id].revoked |= r.revoked;
                if coord_dead[id] || worker.is_revoked() {
                    for i in rows_of(&owned[id]) {
                        global_alpha[i] = 0.0;
                    }
                    dead[id] = true;
                    owned[id].clear();
                } else {
                    ensure!(
                        !worker.has_pending(),
                        "worker {id}: push still unsettled at generation teardown"
                    );
                    for (i, a) in rows_of(&owned[id]).zip(worker.alpha()) {
                        global_alpha[i] = *a;
                    }
                }
            }
            if done {
                break 'generations;
            }
            // Adopt the coordinator's new map and rebuild.
            for (wid, alive, r) in &now {
                let id = *wid as usize;
                if id >= k {
                    continue;
                }
                if *alive {
                    owned[id] = r.clone();
                } else {
                    dead[id] = true;
                    owned[id].clear();
                }
            }
            view = now;
            continue 'generations;
        }
    }

    let fault_events = log.lock().map_err(|_| anyhow!("fault log poisoned"))?.clone();
    Ok((acc, global_alpha, fault_events))
}
