//! Deterministic fault injection for the distributed tier.
//!
//! PASSCoDe's claim is robustness to stale, reordered updates; this
//! module makes that adversary a seeded, replayable input instead of
//! an accident of thread timing — the distributed analogue of the
//! schedule-exploring `passcode check` harness.  A [`FaultPlan`]
//! (JSON, [`FAULTS_FORMAT`], seeds as decimal strings like the
//! checker's reports) drives a [`FaultyTransport`] wrapped around the
//! real [`Transport`](super::client::Transport): per-op probabilistic
//! delay / drop / duplicate / reorder / truncate, timed partition
//! windows, and an exact per-op fault script for pinning specific
//! failure sequences in tests.
//!
//! Determinism model: each worker's transport owns one
//! [`Pcg32`](crate::util::Pcg32) stream `(plan.seed, worker)`, and
//! every decision is a function of (stream state, op index, op kind).
//! The op index — not wall clock — is the logical time base, so the
//! same plan over the same request sequence reproduces the identical
//! fault sequence, byte for byte.  Replays of duplicated pushes are
//! held in-transport and re-posted at a later op (the reorder window),
//! which is exactly the duplicate-late-delivery case the
//! `(worker, boot, round)` idempotence key exists for.
//!
//! Every injected fault increments
//! `passcode_dist_fault_injected_total{kind=...}` and appends a line
//! to a shared event log that [`run_sim`](super::run_sim) surfaces in
//! its report — the replay-determinism test compares these logs
//! across runs.

use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use crate::util::{Json, Pcg32};

use super::client::Transport;

/// Fault-plan file format tag, bumped on breaking layout changes.
pub const FAULTS_FORMAT: &str = "passcode-faults-v1";

/// A loopback partition: ops of `worker` in `from..until` (op index,
/// half-open) fail before the request leaves the transport.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PartitionSpec {
    /// Worker whose transport is partitioned.
    pub worker: u64,
    /// First op index (1-based) inside the partition.
    pub from: u64,
    /// First op index past the partition (`u64::MAX`-ish = forever).
    pub until: u64,
}

/// One exact scripted fault: the `nth` op of `kind` on `worker`'s
/// transport suffers `fault` instead of a probabilistic draw.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ScriptedFault {
    /// Worker whose transport the fault targets.
    pub worker: u64,
    /// Op kind: `"push"`, `"pull"`, or `"heartbeat"`.
    pub kind: String,
    /// 1-based attempt index within that kind on that transport.
    pub nth: u64,
    /// `"drop_request"`, `"drop_response"`, `"delay"`, `"truncate"`,
    /// or `"dup"`.
    pub fault: String,
}

/// A seeded, serializable chaos schedule (see module docs).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Root seed; worker `i`'s transport draws from stream
    /// `Pcg32::new(seed, i)`.
    pub seed: u64,
    /// Per-op probability of an injected delay.
    pub delay_prob: f64,
    /// Upper bound (inclusive, milliseconds) of an injected delay.
    pub delay_max_ms: u64,
    /// Per-op probability the op is dropped (request or response,
    /// an even coin decides which).
    pub drop_prob: f64,
    /// Per-push probability the accepted push is replayed later.
    pub dup_prob: f64,
    /// Max op-index gap a held replay may be deferred by (≥ 1).
    pub reorder_window: u64,
    /// Per-op probability the response body is truncated.
    pub truncate_prob: f64,
    /// Timed partition windows.
    pub partitions: Vec<PartitionSpec>,
    /// Exact scripted faults (win over probabilistic draws).
    pub script: Vec<ScriptedFault>,
}

impl FaultPlan {
    /// A benign plan: no probabilistic faults, no partitions, no
    /// script.  The identity element — useful as a base to extend.
    pub fn quiet(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            delay_prob: 0.0,
            delay_max_ms: 0,
            drop_prob: 0.0,
            dup_prob: 0.0,
            reorder_window: 1,
            truncate_prob: 0.0,
            partitions: Vec::new(),
            script: Vec::new(),
        }
    }

    /// The default `--chaos` profile: moderate probabilistic noise on
    /// every fault axis, plus one scripted dropped push response so a
    /// smoke run is guaranteed to exercise the idempotent-retry path
    /// (and the `passcode_dist_fault_*` family is provably non-empty).
    pub fn moderate(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            delay_prob: 0.10,
            delay_max_ms: 2,
            drop_prob: 0.05,
            dup_prob: 0.15,
            reorder_window: 3,
            truncate_prob: 0.05,
            partitions: Vec::new(),
            script: vec![ScriptedFault {
                worker: 0,
                kind: "push".into(),
                nth: 2,
                fault: "drop_response".into(),
            }],
        }
    }

    /// Serialize (seeds and op indices as decimal strings, like the
    /// checker's `passcode-chk-v1` reports — they exceed 2^53).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("format", Json::str(FAULTS_FORMAT)),
            ("seed", u64_str(self.seed)),
            ("delay_prob", Json::num(self.delay_prob)),
            ("delay_max_ms", u64_str(self.delay_max_ms)),
            ("drop_prob", Json::num(self.drop_prob)),
            ("dup_prob", Json::num(self.dup_prob)),
            ("reorder_window", u64_str(self.reorder_window)),
            ("truncate_prob", Json::num(self.truncate_prob)),
            (
                "partitions",
                Json::Arr(
                    self.partitions
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("worker", u64_str(p.worker)),
                                ("from", u64_str(p.from)),
                                ("until", u64_str(p.until)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "script",
                Json::Arr(
                    self.script
                        .iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("worker", u64_str(s.worker)),
                                ("kind", Json::str(&s.kind)),
                                ("nth", u64_str(s.nth)),
                                ("fault", Json::str(&s.fault)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Parse a plan; validates the format tag, probability ranges, and
    /// fault/kind vocabularies.
    pub fn from_json(j: &Json) -> Result<FaultPlan> {
        let format = j.get("format")?.as_str()?;
        ensure!(format == FAULTS_FORMAT, "unsupported fault-plan format {format:?}");
        let mut plan = FaultPlan {
            seed: parse_u64(j.get("seed")?, "seed")?,
            delay_prob: j.get("delay_prob")?.as_f64()?,
            delay_max_ms: parse_u64(j.get("delay_max_ms")?, "delay_max_ms")?,
            drop_prob: j.get("drop_prob")?.as_f64()?,
            dup_prob: j.get("dup_prob")?.as_f64()?,
            reorder_window: parse_u64(j.get("reorder_window")?, "reorder_window")?,
            truncate_prob: j.get("truncate_prob")?.as_f64()?,
            partitions: Vec::new(),
            script: Vec::new(),
        };
        for p in [plan.delay_prob, plan.drop_prob, plan.dup_prob, plan.truncate_prob] {
            ensure!((0.0..=1.0).contains(&p), "probability {p} outside [0, 1]");
        }
        for part in j.get("partitions")?.as_arr()? {
            let spec = PartitionSpec {
                worker: parse_u64(part.get("worker")?, "partition worker")?,
                from: parse_u64(part.get("from")?, "partition from")?,
                until: parse_u64(part.get("until")?, "partition until")?,
            };
            ensure!(spec.from <= spec.until, "partition from {} > until {}", spec.from, spec.until);
            plan.partitions.push(spec);
        }
        for s in j.get("script")?.as_arr()? {
            let fault = ScriptedFault {
                worker: parse_u64(s.get("worker")?, "script worker")?,
                kind: s.get("kind")?.as_str()?.to_string(),
                nth: parse_u64(s.get("nth")?, "script nth")?,
                fault: s.get("fault")?.as_str()?.to_string(),
            };
            match fault.kind.as_str() {
                "push" | "pull" | "heartbeat" => {}
                other => bail!("unknown scripted op kind {other:?}"),
            }
            match fault.fault.as_str() {
                "drop_request" | "drop_response" | "delay" | "truncate" | "dup" => {}
                other => bail!("unknown scripted fault {other:?}"),
            }
            ensure!(fault.nth >= 1, "script nth is 1-based, got 0");
            plan.script.push(fault);
        }
        Ok(plan)
    }

    /// Write the plan to `path` as pretty JSON.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_pretty())
            .with_context(|| format!("write fault plan {}", path.display()))
    }

    /// Load a plan from `path`.
    pub fn load(path: &Path) -> Result<FaultPlan> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read fault plan {}", path.display()))?;
        Self::from_json(&Json::parse(&text)?)
            .with_context(|| format!("parse fault plan {}", path.display()))
    }
}

fn u64_str(v: u64) -> Json {
    Json::str(&v.to_string())
}

fn parse_u64(v: &Json, what: &str) -> Result<u64> {
    let s = v.as_str().with_context(|| format!("{what}: expected decimal string"))?;
    s.parse::<u64>().with_context(|| format!("{what}: bad u64 {s:?}"))
}

/// The shared, append-only record of every injected fault, in
/// injection order.  One log spans all workers' transports so the
/// replay-determinism test can compare whole runs.
pub type FaultLog = Arc<Mutex<Vec<String>>>;

/// A replayed push held for later delivery.
struct HeldReplay {
    due_op: u64,
    path: String,
    body: Vec<u8>,
}

/// A [`Transport`] that injects the plan's faults around an inner
/// transport (see module docs for the decision order per op).
pub struct FaultyTransport {
    inner: Box<dyn Transport>,
    plan: Arc<FaultPlan>,
    worker: u64,
    rng: Pcg32,
    /// 1-based op index — the transport's logical clock.
    op: u64,
    /// 1-based per-kind attempt counters, indexed by [`op_kind`].
    attempts: [u64; 3],
    held: Vec<HeldReplay>,
    log: FaultLog,
}

/// Classify a dist-plane path for fault purposes.  `None` means the
/// op is harness introspection (`/v1/dist/stats`, `/metrics`) and
/// passes through unfaulted — chaos targets the training plane only.
fn op_kind(path: &str) -> Option<usize> {
    if path.starts_with("/v1/dist/push_delta") {
        Some(0)
    } else if path.starts_with("/v1/dist/pull_w") {
        Some(1)
    } else if path.starts_with("/v1/dist/heartbeat") {
        Some(2)
    } else {
        None
    }
}

const KIND_NAMES: [&str; 3] = ["push", "pull", "heartbeat"];

impl FaultyTransport {
    /// Wrap `inner` with the plan's faults for `worker`'s transport.
    /// All transports of a run share one `log`.
    pub fn new(
        inner: Box<dyn Transport>,
        worker: u64,
        plan: Arc<FaultPlan>,
        log: FaultLog,
    ) -> FaultyTransport {
        let rng = Pcg32::new(plan.seed, worker);
        FaultyTransport { inner, plan, worker, rng, op: 0, attempts: [0; 3], held: Vec::new(), log }
    }

    fn record(&self, kind: &str, detail: String) {
        crate::obs::registry()
            .counter(
                &format!("passcode_dist_fault_injected_total{{kind=\"{kind}\"}}"),
                "chaos-injected transport faults by kind",
            )
            .inc();
        self.log.lock().expect("fault log poisoned").push(detail);
    }

    fn partitioned(&self, op: u64) -> bool {
        self.plan
            .partitions
            .iter()
            .any(|p| p.worker == self.worker && p.from <= op && op < p.until)
    }

    fn scripted(&self, kind: usize, attempt: u64) -> Option<&str> {
        self.plan
            .script
            .iter()
            .find(|s| {
                s.worker == self.worker && s.kind == KIND_NAMES[kind] && s.nth == attempt
            })
            .map(|s| s.fault.as_str())
    }

    /// Deliver held replays that came due, unless the partition holds
    /// them back (they fire after heal — late delivery is the point).
    fn deliver_due(&mut self, op: u64) {
        let mut i = 0;
        while i < self.held.len() {
            if self.held[i].due_op <= op && !self.partitioned(op) {
                let r = self.held.remove(i);
                let gap = op.saturating_sub(r.due_op);
                self.record(
                    "reorder",
                    format!("w{} op{op}: replay of held push (deferred {gap} extra ops)", self.worker),
                );
                // The ghost retry: re-POST the recorded bytes, discard
                // whatever the coordinator answers.  Idempotence at
                // the coordinator is what keeps this harmless.
                let _ = self.inner.post(&r.path, &r.body);
            } else {
                i += 1;
            }
        }
    }

    fn forward(&mut self, is_post: bool, path: &str, body: &[u8]) -> Result<Vec<u8>> {
        if is_post {
            self.inner.post(path, body)
        } else {
            self.inner.get(path)
        }
    }

    fn faulted(&mut self, is_post: bool, path: &str, body: &[u8]) -> Result<Vec<u8>> {
        let kind = match op_kind(path) {
            Some(k) => k,
            None => return self.forward(is_post, path, body),
        };
        self.op += 1;
        let op = self.op;
        self.deliver_due(op);
        if self.partitioned(op) {
            self.record(
                "partition",
                format!("w{} op{op} {}#{}: partitioned", self.worker, KIND_NAMES[kind],
                        self.attempts[kind] + 1),
            );
            self.attempts[kind] += 1;
            bail!("chaos: partitioned (worker {}, op {op})", self.worker);
        }
        self.attempts[kind] += 1;
        let attempt = self.attempts[kind];
        let tag = format!("w{} op{op} {}#{attempt}", self.worker, KIND_NAMES[kind]);

        if let Some(fault) = self.scripted(kind, attempt) {
            let fault = fault.to_string();
            self.record(&scripted_metric_kind(&fault), format!("{tag}: scripted {fault}"));
            return match fault.as_str() {
                "drop_request" => bail!("chaos: scripted drop_request ({tag})"),
                "drop_response" => {
                    let _ = self.forward(is_post, path, body);
                    bail!("chaos: scripted drop_response ({tag})")
                }
                "delay" => {
                    std::thread::sleep(Duration::from_millis(self.plan.delay_max_ms));
                    self.forward(is_post, path, body)
                }
                "truncate" => {
                    let resp = self.forward(is_post, path, body)?;
                    Ok(resp[..resp.len() / 2].to_vec())
                }
                "dup" => {
                    let resp = self.forward(is_post, path, body)?;
                    if is_post {
                        self.hold_replay(op, path, body);
                    }
                    Ok(resp)
                }
                other => unreachable!("validated fault kind {other:?}"),
            };
        }

        if self.plan.delay_prob > 0.0 && self.rng.gen_f64() < self.plan.delay_prob {
            let ms = self.rng.gen_range(self.plan.delay_max_ms as usize + 1) as u64;
            self.record("delay", format!("{tag}: delay {ms}ms"));
            std::thread::sleep(Duration::from_millis(ms));
        }
        if self.plan.drop_prob > 0.0 && self.rng.gen_f64() < self.plan.drop_prob {
            if self.rng.gen_f64() < 0.5 {
                self.record("drop", format!("{tag}: drop(request)"));
                bail!("chaos: dropped request ({tag})");
            }
            self.record("drop", format!("{tag}: drop(response)"));
            let _ = self.forward(is_post, path, body);
            bail!("chaos: dropped response ({tag})");
        }
        let resp = self.forward(is_post, path, body)?;
        let resp = if self.plan.truncate_prob > 0.0
            && self.rng.gen_f64() < self.plan.truncate_prob
        {
            self.record("truncate", format!("{tag}: truncate {} -> {} bytes", resp.len(),
                                            resp.len() / 2));
            resp[..resp.len() / 2].to_vec()
        } else {
            resp
        };
        if is_post
            && kind == 0
            && self.plan.dup_prob > 0.0
            && self.rng.gen_f64() < self.plan.dup_prob
        {
            self.hold_replay(op, path, body);
        }
        Ok(resp)
    }

    fn hold_replay(&mut self, op: u64, path: &str, body: &[u8]) {
        let window = self.plan.reorder_window.max(1) as usize;
        let due_op = op + 1 + self.rng.gen_range(window) as u64;
        self.record(
            "duplicate",
            format!("w{} op{op}: duplicate push held until op{due_op}", self.worker),
        );
        self.held.push(HeldReplay { due_op, path: path.to_string(), body: body.to_vec() });
    }
}

/// The metric kind a scripted fault counts under.
fn scripted_metric_kind(fault: &str) -> String {
    match fault {
        "drop_request" | "drop_response" => "drop",
        "delay" => "delay",
        "truncate" => "truncate",
        "dup" => "duplicate",
        other => other,
    }
    .to_string()
}

impl Transport for FaultyTransport {
    fn get(&mut self, path: &str) -> Result<Vec<u8>> {
        self.faulted(false, path, b"")
    }

    fn post(&mut self, path: &str, body: &[u8]) -> Result<Vec<u8>> {
        self.faulted(true, path, body)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Inner transport that records every call and answers a canned
    /// body.
    struct Recorder {
        calls: Arc<Mutex<Vec<(String, Vec<u8>)>>>,
    }

    impl Transport for Recorder {
        fn get(&mut self, path: &str) -> Result<Vec<u8>> {
            self.calls.lock().unwrap().push((format!("GET {path}"), Vec::new()));
            Ok(b"pong-body".to_vec())
        }

        fn post(&mut self, path: &str, body: &[u8]) -> Result<Vec<u8>> {
            self.calls.lock().unwrap().push((format!("POST {path}"), body.to_vec()));
            Ok(b"post-ack".to_vec())
        }
    }

    fn harness(plan: FaultPlan) -> (FaultyTransport, Arc<Mutex<Vec<(String, Vec<u8>)>>>, FaultLog) {
        let calls = Arc::new(Mutex::new(Vec::new()));
        let log: FaultLog = Arc::new(Mutex::new(Vec::new()));
        let t = FaultyTransport::new(
            Box::new(Recorder { calls: Arc::clone(&calls) }),
            0,
            Arc::new(plan),
            Arc::clone(&log),
        );
        (t, calls, log)
    }

    #[test]
    fn plan_json_round_trips_and_validates() {
        let mut plan = FaultPlan::moderate(123);
        plan.partitions.push(PartitionSpec { worker: 1, from: 5, until: 9 });
        let j = Json::parse(&plan.to_json().to_string()).unwrap();
        assert_eq!(FaultPlan::from_json(&j).unwrap(), plan);
        // The seed survives as a decimal string even past 2^53.
        let mut big = FaultPlan::quiet(u64::MAX - 1);
        big.reorder_window = 2;
        let j = Json::parse(&big.to_json().to_string()).unwrap();
        assert_eq!(FaultPlan::from_json(&j).unwrap().seed, u64::MAX - 1);
        // Bad format tag, probability, and fault vocabulary all fail.
        let mut j = plan.to_json();
        if let Json::Obj(o) = &mut j {
            o.insert("format".into(), Json::str("passcode-faults-v0"));
        }
        assert!(FaultPlan::from_json(&j).is_err());
        let mut j = plan.to_json();
        if let Json::Obj(o) = &mut j {
            o.insert("drop_prob".into(), Json::num(1.5));
        }
        assert!(FaultPlan::from_json(&j).is_err());
        let mut bad = plan.clone();
        bad.script[0].fault = "explode".into();
        assert!(FaultPlan::from_json(&bad.to_json()).is_err());
    }

    #[test]
    fn scripted_drop_request_never_reaches_inner() {
        let mut plan = FaultPlan::quiet(7);
        plan.script.push(ScriptedFault {
            worker: 0,
            kind: "push".into(),
            nth: 2,
            fault: "drop_request".into(),
        });
        let (mut t, calls, _) = harness(plan);
        assert!(t.post("/v1/dist/push_delta", b"a").is_ok());
        assert!(t.post("/v1/dist/push_delta", b"b").is_err());
        assert!(t.post("/v1/dist/push_delta", b"c").is_ok());
        let seen: Vec<Vec<u8>> =
            calls.lock().unwrap().iter().map(|(_, b)| b.clone()).collect();
        assert_eq!(seen, vec![b"a".to_vec(), b"c".to_vec()]);
    }

    #[test]
    fn duplicate_push_is_replayed_on_a_later_op() {
        let mut plan = FaultPlan::quiet(7);
        plan.reorder_window = 1;
        plan.script.push(ScriptedFault {
            worker: 0,
            kind: "push".into(),
            nth: 1,
            fault: "dup".into(),
        });
        let (mut t, calls, log) = harness(plan);
        assert!(t.post("/v1/dist/push_delta", b"dup-me").is_ok());
        assert_eq!(calls.lock().unwrap().len(), 1);
        // Next op delivers the held replay before its own request.
        assert!(t.get("/v1/dist/pull_w").is_ok());
        let seen = calls.lock().unwrap();
        assert_eq!(seen.len(), 3, "{seen:?}");
        assert_eq!(seen[1].1, b"dup-me".to_vec());
        assert!(seen[2].0.starts_with("GET /v1/dist/pull_w"));
        let log = log.lock().unwrap();
        assert!(log.iter().any(|l| l.contains("duplicate")), "{log:?}");
        assert!(log.iter().any(|l| l.contains("replay")), "{log:?}");
    }

    #[test]
    fn partition_window_blocks_and_heals_by_op_index() {
        let mut plan = FaultPlan::quiet(7);
        plan.partitions.push(PartitionSpec { worker: 0, from: 2, until: 4 });
        let (mut t, calls, _) = harness(plan);
        assert!(t.get("/v1/dist/pull_w").is_ok()); // op 1
        assert!(t.get("/v1/dist/pull_w").is_err()); // op 2: partitioned
        assert!(t.get("/v1/dist/pull_w").is_err()); // op 3: partitioned
        assert!(t.get("/v1/dist/pull_w").is_ok()); // op 4: healed
        assert_eq!(calls.lock().unwrap().len(), 2);
        // Introspection paths bypass chaos entirely.
        let mut plan = FaultPlan::quiet(7);
        plan.partitions.push(PartitionSpec { worker: 0, from: 1, until: 100 });
        let (mut t, calls, _) = harness(plan);
        assert!(t.get("/metrics").is_ok());
        assert!(t.get("/v1/dist/stats").is_ok());
        assert_eq!(calls.lock().unwrap().len(), 2);
    }

    #[test]
    fn same_seed_reproduces_the_identical_fault_sequence() {
        let mut plan = FaultPlan::moderate(99);
        plan.delay_prob = 0.0; // keep the test sleep-free
        plan.drop_prob = 0.3;
        plan.truncate_prob = 0.2;
        plan.dup_prob = 0.3;
        let run = |plan: FaultPlan| {
            let (mut t, _, log) = harness(plan);
            for _ in 0..40 {
                let _ = t.post("/v1/dist/push_delta", b"x");
                let _ = t.get("/v1/dist/pull_w");
            }
            let log = log.lock().unwrap().clone();
            log
        };
        let a = run(plan.clone());
        let b = run(plan.clone());
        assert!(!a.is_empty(), "no faults injected at these probabilities");
        assert_eq!(a, b, "fault sequence not reproducible");
        // A different seed produces a different sequence.
        let mut other = plan;
        other.seed = 100;
        assert_ne!(a, run(other));
    }
}
