//! The LIBLINEAR shrinking heuristic (paper §3.3, Hsieh et al. 2008 §4).
//!
//! For box-constrained duals (hinge: α ∈ [0, C]) variables stuck at a
//! bound with a strongly-signed projected gradient are removed from the
//! active set; bounds `M̄`/`m̄` track the previous epoch's extreme
//! projected gradients.  When the active set converges, it is reset once
//! so the final pass re-checks all coordinates (LIBLINEAR's behaviour).

/// Per-run shrinking state.
#[derive(Debug)]
pub struct ShrinkState {
    /// None disables shrinking (no finite box → heuristic not applicable).
    upper: Option<f64>,
    active: Vec<bool>,
    n_active: usize,
    /// Extremes of the projected gradient seen in the previous epoch.
    pg_max_old: f64,
    pg_min_old: f64,
    /// Extremes accumulated in the current epoch.
    pg_max_new: f64,
    pg_min_new: f64,
}

impl ShrinkState {
    pub fn new(n: usize, upper: Option<f64>) -> Self {
        Self {
            upper,
            active: vec![true; n],
            n_active: n,
            pg_max_old: f64::INFINITY,
            pg_min_old: f64::NEG_INFINITY,
            pg_max_new: f64::NEG_INFINITY,
            pg_min_new: f64::INFINITY,
        }
    }

    /// Indices currently active (callers may permute).
    pub fn active_indices(&self) -> Vec<usize> {
        (0..self.active.len()).filter(|&i| self.active[i]).collect()
    }

    /// Fill `out` with the active indices, reusing its capacity — the
    /// allocation-free sibling of [`ShrinkState::active_indices`] for
    /// steady-state epoch loops.
    pub fn active_indices_into(&self, out: &mut Vec<usize>) {
        out.clear();
        out.extend((0..self.active.len()).filter(|&i| self.active[i]));
    }

    pub fn n_active(&self) -> usize {
        self.n_active
    }

    pub fn begin_epoch(&mut self) {
        self.pg_max_new = f64::NEG_INFINITY;
        self.pg_min_new = f64::INFINITY;
    }

    /// Decide whether coordinate `i` (dual value `alpha`, dual gradient
    /// `g`) should be skipped this epoch; updates the PG statistics.
    ///
    /// Projected gradient (for the box [0, C]):
    /// `PG = min(g, 0)` at α = 0, `max(g, 0)` at α = C, `g` inside.
    pub fn should_skip(&mut self, i: usize, alpha: f64, g: f64) -> bool {
        let Some(c) = self.upper else {
            return false; // no box → no shrinking
        };
        let at_lower = alpha <= 0.0;
        let at_upper = alpha >= c;
        let pg = if at_lower {
            if g > self.pg_max_old {
                self.deactivate(i);
                return true;
            }
            g.min(0.0)
        } else if at_upper {
            if g < self.pg_min_old {
                self.deactivate(i);
                return true;
            }
            g.max(0.0)
        } else {
            g
        };
        self.pg_max_new = self.pg_max_new.max(pg);
        self.pg_min_new = self.pg_min_new.min(pg);
        false
    }

    fn deactivate(&mut self, i: usize) {
        if self.active[i] {
            self.active[i] = false;
            self.n_active -= 1;
        }
    }

    /// Export the cross-epoch state for checkpointing: the active set
    /// plus the previous epoch's PG extremes `(M̄, m̄)`.  Per-epoch
    /// scratch (`pg_*_new`) is excluded — snapshots are taken at epoch
    /// boundaries where it is dead.
    pub fn export(&self) -> (Vec<bool>, f64, f64) {
        (self.active.clone(), self.pg_max_old, self.pg_min_old)
    }

    /// Rebuild from an [`ShrinkState::export`]ed snapshot, so a resumed
    /// `TrainSession` continues with exactly the active set and bounds
    /// an uninterrupted run would have.
    pub fn import(
        upper: Option<f64>,
        active: Vec<bool>,
        pg_max_old: f64,
        pg_min_old: f64,
    ) -> Self {
        let n_active = active.iter().filter(|&&a| a).count();
        Self {
            upper,
            active,
            n_active,
            pg_max_old,
            pg_min_old,
            pg_max_new: f64::NEG_INFINITY,
            pg_min_new: f64::INFINITY,
        }
    }

    /// Roll epoch statistics (LIBLINEAR: inflate when degenerate, and
    /// reactivate everything when the active problem looks solved).
    pub fn end_epoch(&mut self) {
        self.pg_max_old = if self.pg_max_new <= 0.0 {
            f64::INFINITY
        } else {
            self.pg_max_new
        };
        self.pg_min_old = if self.pg_min_new >= 0.0 {
            f64::NEG_INFINITY
        } else {
            self.pg_min_new
        };
        // Active problem nearly solved → un-shrink for a clean final pass.
        if self.pg_max_new - self.pg_min_new < 1e-6 {
            for a in &mut self.active {
                *a = true;
            }
            self.n_active = self.active.len();
            self.pg_max_old = f64::INFINITY;
            self.pg_min_old = f64::NEG_INFINITY;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_box_never_skips() {
        let mut s = ShrinkState::new(4, None);
        assert!(!s.should_skip(0, 0.0, 100.0));
        assert_eq!(s.n_active(), 4);
    }

    #[test]
    fn first_epoch_never_shrinks() {
        // pg_max_old starts at +inf so nothing can exceed it.
        let mut s = ShrinkState::new(4, Some(1.0));
        s.begin_epoch();
        assert!(!s.should_skip(0, 0.0, 1e9));
        assert_eq!(s.n_active(), 4);
    }

    #[test]
    fn shrinks_bound_variable_with_strong_gradient() {
        let mut s = ShrinkState::new(3, Some(1.0));
        s.begin_epoch();
        // Build statistics: interior coordinate with g in [-1, 1]
        assert!(!s.should_skip(1, 0.5, 1.0));
        assert!(!s.should_skip(2, 0.5, -1.0));
        s.end_epoch();
        s.begin_epoch();
        // α = 0 with g = 2 > pg_max_old = 1 → shrink.
        assert!(s.should_skip(0, 0.0, 2.0));
        assert_eq!(s.n_active(), 2);
        // α = C with g = -2 < pg_min_old = -1 → shrink.
        assert!(s.should_skip(1, 1.0, -2.0));
        assert_eq!(s.n_active(), 1);
    }

    #[test]
    fn interior_variables_never_skipped() {
        let mut s = ShrinkState::new(2, Some(1.0));
        s.begin_epoch();
        assert!(!s.should_skip(0, 0.5, 100.0));
        s.end_epoch();
        s.begin_epoch();
        assert!(!s.should_skip(0, 0.5, 100.0));
    }

    #[test]
    fn converged_epoch_unshrinks() {
        let mut s = ShrinkState::new(2, Some(1.0));
        s.begin_epoch();
        let _ = s.should_skip(0, 0.5, 1.0);
        let _ = s.should_skip(1, 0.5, -1.0);
        s.end_epoch();
        s.begin_epoch();
        assert!(s.should_skip(0, 0.0, 5.0));
        assert_eq!(s.n_active(), 1);
        // A "solved" epoch: all PGs ~ 0 → everything reactivates.
        s.end_epoch(); // pg range collapsed (nothing interior was seen)
        assert_eq!(s.n_active(), 2);
    }
}
