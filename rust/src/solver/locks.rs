//! Per-feature lock table for PASSCoDe-Lock.
//!
//! Step 1.5 of the paper: before updating coordinate `i`, lock every
//! `w_t` with `(x_i)_t ≠ 0`.  Deadlock is avoided by the paper's own
//! §3.3 recipe — a global ordering on locks; CSR rows are sorted by
//! feature index, so acquiring in row order *is* the ordered protocol.
//!
//! Locks are one-byte spinlocks (`AtomicBool`): the critical sections are
//! tens of nanoseconds, an OS mutex would dominate them.

use std::sync::atomic::{AtomicBool, Ordering};

/// The locking seam the PASSCoDe-Lock kernel is generic over.
///
/// [`LockTable`] is the production spinlock implementation; the dynamic
/// checker's [`crate::chk::CheckedLocks`] twin verifies the sorted-
/// acquisition protocol and cooperates with the schedule explorer
/// instead of spinning.
pub trait LockDiscipline: Sync {
    /// Number of feature locks in the table.
    fn len(&self) -> usize;

    /// Whether the table has zero locks.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Acquire the locks for a **sorted** feature list, blocking until
    /// all are held.  Sortedness is the deadlock-freedom protocol of the
    /// paper's §3.3 (a global order on lock acquisition).
    fn acquire_sorted(&self, features: &[u32]);

    /// Release previously-acquired locks (any order is fine).
    fn release(&self, features: &[u32]);
}

/// A table of `d` tiny spinlocks, one per feature.
pub struct LockTable {
    locks: Vec<AtomicBool>,
}

impl LockTable {
    pub fn new(d: usize) -> Self {
        Self { locks: (0..d).map(|_| AtomicBool::new(false)).collect() }
    }

    pub fn len(&self) -> usize {
        self.locks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.locks.is_empty()
    }

    /// Acquire the locks for a *sorted* feature list. Spin-waits.
    #[inline]
    pub fn acquire_sorted(&self, features: &[u32]) {
        debug_assert!(features.windows(2).all(|w| w[0] < w[1]));
        for &f in features {
            let lock = &self.locks[f as usize];
            // Contention telemetry: one tick per *contended*
            // acquisition (not per spin), recorded after the acquire
            // so the uncontended fast path stays a single CAS.
            let mut contended = false;
            while lock
                .compare_exchange_weak(
                    false,
                    true,
                    Ordering::Acquire,
                    Ordering::Relaxed,
                )
                .is_err()
            {
                contended = true;
                std::hint::spin_loop();
            }
            if contended {
                crate::obs::probes::lock_wait_tick();
            }
        }
    }

    /// Release previously-acquired locks (any order is fine).
    #[inline]
    pub fn release(&self, features: &[u32]) {
        for &f in features {
            self.locks[f as usize].store(false, Ordering::Release);
        }
    }

    /// Whether feature `f` is currently held (test/diagnostic only).
    pub fn is_held(&self, f: usize) -> bool {
        self.locks[f].load(Ordering::Relaxed)
    }
}

impl LockDiscipline for LockTable {
    fn len(&self) -> usize {
        LockTable::len(self)
    }

    fn acquire_sorted(&self, features: &[u32]) {
        LockTable::acquire_sorted(self, features);
    }

    fn release(&self, features: &[u32]) {
        LockTable::release(self, features);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::sync::Arc;

    #[test]
    fn acquire_release_roundtrip() {
        let t = LockTable::new(8);
        t.acquire_sorted(&[1, 3, 5]);
        assert!(t.is_held(1) && t.is_held(3) && t.is_held(5));
        assert!(!t.is_held(0));
        t.release(&[1, 3, 5]);
        assert!(!t.is_held(3));
    }

    #[test]
    fn lock_is_reacquirable_after_release() {
        let t = LockTable::new(4);
        for _ in 0..3 {
            t.acquire_sorted(&[0, 2]);
            assert!(t.is_held(0) && t.is_held(2));
            t.release(&[0, 2]);
            assert!(!t.is_held(0) && !t.is_held(2));
        }
    }

    #[test]
    fn discipline_seam_drives_the_table_generically() {
        fn exercise<L: LockDiscipline>(l: &L) {
            assert_eq!(l.len(), 6);
            assert!(!l.is_empty());
            l.acquire_sorted(&[1, 4]);
            l.release(&[1, 4]);
        }
        exercise(&LockTable::new(6));
    }

    #[test]
    fn mutual_exclusion_protects_counter() {
        // Two threads increment a (non-atomic via UnsafeCell-free trick:
        // use the lock to serialize accesses to a plain u64 behind a
        // raw pointer) — here we just verify the protocol with an atomic
        // relaxed counter that would *race* without the lock.
        let iters: u64 = if cfg!(miri) { 200 } else { 10_000 };
        let t = Arc::new(LockTable::new(4));
        let counter = Arc::new(AtomicU64::new(0));
        std::thread::scope(|s| {
            for _ in 0..4 {
                let t = Arc::clone(&t);
                let counter = Arc::clone(&counter);
                s.spawn(move || {
                    for _ in 0..iters {
                        t.acquire_sorted(&[2]);
                        // racy read-modify-write, serialized by the lock
                        let v = counter.load(Ordering::Relaxed);
                        counter.store(v + 1, Ordering::Relaxed);
                        t.release(&[2]);
                    }
                });
            }
        });
        assert_eq!(counter.load(Ordering::Relaxed), 4 * iters);
    }

    #[test]
    fn contention_smoke_multi_lock_sets_four_threads() {
        // Four threads hammer overlapping multi-lock sets, each guarding
        // plain relaxed RMWs on per-feature counters; the lock must make
        // every increment lossless.
        let iters = if cfg!(miri) { 100 } else { 5_000 };
        let t = Arc::new(LockTable::new(8));
        let counters: Arc<Vec<AtomicU64>> =
            Arc::new((0..8).map(|_| AtomicU64::new(0)).collect());
        std::thread::scope(|s| {
            for k in 0..4usize {
                let t = Arc::clone(&t);
                let counters = Arc::clone(&counters);
                s.spawn(move || {
                    let sets: [&[u32]; 4] =
                        [&[0, 3, 7], &[1, 3, 5], &[0, 1, 5, 7], &[3, 5]];
                    for it in 0..iters {
                        let set = sets[(k + it) % 4];
                        t.acquire_sorted(set);
                        for &f in set {
                            let c = &counters[f as usize];
                            let v = c.load(Ordering::Relaxed);
                            c.store(v + 1, Ordering::Relaxed);
                        }
                        t.release(set);
                    }
                });
            }
        });
        // Each thread cycles through all four sets (3 + 3 + 4 + 2 locks)
        // every four iterations, so 4 threads × iters iterations touch
        // 12 · iters cells in total.
        let total: u64 =
            counters.iter().map(|c| c.load(Ordering::Relaxed)).sum();
        assert_eq!(total, 12 * iters as u64);
    }

    #[test]
    fn ordered_acquisition_no_deadlock_on_overlap() {
        // Threads repeatedly take overlapping sorted sets; absence of
        // deadlock == the test terminates.
        let t = Arc::new(LockTable::new(16));
        std::thread::scope(|s| {
            for k in 0..4u32 {
                let t = Arc::clone(&t);
                s.spawn(move || {
                    let sets: [&[u32]; 3] =
                        [&[0, 5, 9], &[5, 9, 12], &[0, 12, 15]];
                    for _ in 0..5_000 {
                        let set = sets[(k as usize) % 3];
                        t.acquire_sorted(set);
                        t.release(set);
                    }
                });
            }
        });
    }
}
