//! Per-feature lock table for PASSCoDe-Lock.
//!
//! Step 1.5 of the paper: before updating coordinate `i`, lock every
//! `w_t` with `(x_i)_t ≠ 0`.  Deadlock is avoided by the paper's own
//! §3.3 recipe — a global ordering on locks; CSR rows are sorted by
//! feature index, so acquiring in row order *is* the ordered protocol.
//!
//! Locks are one-byte spinlocks (`AtomicBool`): the critical sections are
//! tens of nanoseconds, an OS mutex would dominate them.

use std::sync::atomic::{AtomicBool, Ordering};

/// A table of `d` tiny spinlocks, one per feature.
pub struct LockTable {
    locks: Vec<AtomicBool>,
}

impl LockTable {
    pub fn new(d: usize) -> Self {
        Self { locks: (0..d).map(|_| AtomicBool::new(false)).collect() }
    }

    pub fn len(&self) -> usize {
        self.locks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.locks.is_empty()
    }

    /// Acquire the locks for a *sorted* feature list. Spin-waits.
    #[inline]
    pub fn acquire_sorted(&self, features: &[u32]) {
        debug_assert!(features.windows(2).all(|w| w[0] < w[1]));
        for &f in features {
            let lock = &self.locks[f as usize];
            while lock
                .compare_exchange_weak(
                    false,
                    true,
                    Ordering::Acquire,
                    Ordering::Relaxed,
                )
                .is_err()
            {
                std::hint::spin_loop();
            }
        }
    }

    /// Release previously-acquired locks (any order is fine).
    #[inline]
    pub fn release(&self, features: &[u32]) {
        for &f in features {
            self.locks[f as usize].store(false, Ordering::Release);
        }
    }

    /// Whether feature `f` is currently held (test/diagnostic only).
    pub fn is_held(&self, f: usize) -> bool {
        self.locks[f].load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn acquire_release_roundtrip() {
        let t = LockTable::new(8);
        t.acquire_sorted(&[1, 3, 5]);
        assert!(t.is_held(1) && t.is_held(3) && t.is_held(5));
        assert!(!t.is_held(0));
        t.release(&[1, 3, 5]);
        assert!(!t.is_held(3));
    }

    #[test]
    fn mutual_exclusion_protects_counter() {
        // Two threads increment a (non-atomic via UnsafeCell-free trick:
        // use the lock to serialize accesses to a plain u64 behind a
        // raw pointer) — here we just verify the protocol with an atomic
        // relaxed counter that would *race* without the lock.
        let t = Arc::new(LockTable::new(4));
        let counter = Arc::new(std::sync::atomic::AtomicU64::new(0));
        std::thread::scope(|s| {
            for _ in 0..4 {
                let t = Arc::clone(&t);
                let counter = Arc::clone(&counter);
                s.spawn(move || {
                    for _ in 0..10_000 {
                        t.acquire_sorted(&[2]);
                        // racy read-modify-write, serialized by the lock
                        let v = counter.load(Ordering::Relaxed);
                        counter.store(v + 1, Ordering::Relaxed);
                        t.release(&[2]);
                    }
                });
            }
        });
        assert_eq!(counter.load(Ordering::Relaxed), 40_000);
    }

    #[test]
    fn ordered_acquisition_no_deadlock_on_overlap() {
        // Threads repeatedly take overlapping sorted sets; absence of
        // deadlock == the test terminates.
        let t = Arc::new(LockTable::new(16));
        std::thread::scope(|s| {
            for k in 0..4u32 {
                let t = Arc::clone(&t);
                s.spawn(move || {
                    let sets: [&[u32]; 3] =
                        [&[0, 5, 9], &[5, 9, 12], &[0, 12, 15]];
                    for _ in 0..5_000 {
                        let set = sets[(k as usize) % 3];
                        t.acquire_sorted(set);
                        t.release(set);
                    }
                });
            }
        });
    }
}
