//! PASSCoDe — Algorithm 2: asynchronous parallel stochastic dual
//! coordinate descent in shared memory, in the paper's three flavours.
//!
//! Every worker repeatedly: picks a coordinate from its own partition
//! (paper §3.3 "Random Permutation": `{1..n}` is randomly split into `p`
//! blocks, each thread permutes its block per epoch, so `α_i` has a
//! unique owner and only `w` is contended), solves the one-variable
//! subproblem against the *shared* `w`, and publishes `Δα_i x_i`:
//!
//! * [`MemoryModel::Lock`]   — ordered per-feature spinlocks around
//!   read-and-update (serializable; the paper's Table 1 shows it is
//!   slower than serial DCD — reproduced in `benches/table1_scaling.rs`);
//! * [`MemoryModel::Atomic`] — lock-free reads, CAS adds on `w` (linear
//!   convergence, Theorem 2);
//! * [`MemoryModel::Wild`]   — plain racy adds; `ŵ ≠ Σα_i x_i` at the end
//!   (Eq. 6), and Theorem 3's backward-error analysis says `ŵ` is the
//!   exact solution of a perturbed primal — so predict with `ŵ`.
//!
//! Threads free-run with **no barriers** when `opts.eval_every == 0`;
//! with eval enabled they rendezvous every `eval_every` epochs so the
//! leader can snapshot (α, ŵ) for the convergence curves.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Barrier;

use crate::data::Dataset;
use crate::loss::{Loss, MIN_DELTA};
use crate::util::{affinity, Pcg32, Phases, SharedVec, Timer};

use super::locks::LockTable;
use super::{Progress, ProgressFn, Sampling, SolveOptions, SolveResult};

/// Which mechanism guards step 3's write of `Δα_i x_i` into shared `w`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemoryModel {
    /// Lock all features in `N_i` (ordered; deadlock-free).
    Lock,
    /// Atomic (CAS) per-feature adds.
    Atomic,
    /// Unguarded read-modify-write (HOGWILD-style).
    Wild,
}

impl MemoryModel {
    /// Bare model name (`lock` / `atomic` / `wild`), the suffix of the
    /// registry's `passcode-*` solver names.
    pub fn name(&self) -> &'static str {
        match self {
            MemoryModel::Lock => "lock",
            MemoryModel::Atomic => "atomic",
            MemoryModel::Wild => "wild",
        }
    }

    /// Parse a bare model name — a thin view over the solver registry's
    /// `passcode-*` entries ([`crate::solver::SolverKind::parse`]), so
    /// the two name tables can never drift.
    pub fn parse(s: &str) -> Option<MemoryModel> {
        match super::api::SolverKind::parse(&format!("passcode-{s}")) {
            Ok(super::api::SolverKind::Passcode(m)) => Some(m),
            _ => None,
        }
    }
}

/// The PASSCoDe solver.
pub struct Passcode;

impl Passcode {
    /// Run Algorithm 2 with `opts.threads` workers, cold-started from
    /// `α = 0`, `w = 0`.
    ///
    /// The progress callback (leader-only) fires at epoch barriers every
    /// `opts.eval_every` epochs; returning `false` stops all workers at
    /// the next boundary.
    ///
    /// Thin shim over the warm-start core; prefer the
    /// [`crate::solver::Solver`] registry for epoch-granular control,
    /// deadlines, or checkpoint/restore.
    pub fn solve<L: Loss>(
        ds: &Dataset,
        loss: &L,
        model: MemoryModel,
        opts: &SolveOptions,
        on_progress: Option<&mut ProgressFn<'_>>,
    ) -> SolveResult {
        Self::solve_impl(ds, loss, model, opts, None, on_progress)
    }

    /// Run Algorithm 2 warm-started from an existing `(α, ŵ)` pair — the
    /// continuous-training entry point used by [`crate::serve::online`]:
    /// the online trainer resumes from the registry's live model instead
    /// of re-solving from zero on every publish.
    ///
    /// `alpha0.len()` must equal `ds.n()` and `w0.len()` must equal
    /// `ds.d()`.  The caller is responsible for `w0 ≈ Σ α0_i x_i` if it
    /// wants the dual/primal pairing to stay meaningful (PASSCoDe-Wild's
    /// Theorem 3 tolerates the drift either way).
    pub fn solve_warm<L: Loss>(
        ds: &Dataset,
        loss: &L,
        model: MemoryModel,
        opts: &SolveOptions,
        alpha0: &[f64],
        w0: &[f64],
        on_progress: Option<&mut ProgressFn<'_>>,
    ) -> SolveResult {
        assert_eq!(alpha0.len(), ds.n(), "warm-start α dimension");
        assert_eq!(w0.len(), ds.d(), "warm-start w dimension");
        Self::solve_impl(ds, loss, model, opts, Some((alpha0, w0)), on_progress)
    }

    fn solve_impl<L: Loss>(
        ds: &Dataset,
        loss: &L,
        model: MemoryModel,
        opts: &SolveOptions,
        warm: Option<(&[f64], &[f64])>,
        mut on_progress: Option<&mut ProgressFn<'_>>,
    ) -> SolveResult {
        let n = ds.n();
        let d = ds.d();
        let p = opts.threads.max(1);
        let mut phases = Phases::new();

        // ---- init (counted separately, as in §5.2; norms memoized) ------
        let init_t = Timer::start();
        let qii = ds.x.row_sqnorms_cached();
        let (w, alpha) = match warm {
            Some((a0, w0)) => {
                (SharedVec::from_slice(w0), SharedVec::from_slice(a0))
            }
            None => (SharedVec::zeros(d), SharedVec::zeros(n)),
        };
        let locks = match model {
            MemoryModel::Lock => Some(LockTable::new(d)),
            _ => None,
        };
        // Random partition of {0..n} into p blocks (paper §3.3).
        let mut rng = Pcg32::new(opts.seed, 0xB10C);
        let perm = rng.permutation(n);
        let blocks: Vec<&[usize]> = chunk_evenly(&perm, p);
        phases.add("init", init_t.secs());

        // ---- shared control ---------------------------------------------
        let stop = AtomicBool::new(false);
        let updates = AtomicU64::new(0);
        let epochs_done = AtomicU64::new(0);
        let sync_every = opts.eval_every; // 0 = free-run
        let barrier = Barrier::new(p);

        let train_t = Timer::start();
        std::thread::scope(|scope| {
            let mut leader_cb = on_progress.take();
            let alpha_ref = &alpha;
            let w_ref = &w;
            let qii_ref = &qii;
            let stop_ref = &stop;
            let updates_ref = &updates;
            let epochs_done_ref = &epochs_done;
            let barrier_ref = &barrier;
            let locks_ref = &locks;
            let blocks_ref = &blocks;

            for t in 0..p {
                let my_block: &[usize] = blocks_ref[t];
                let mut cb = if t == 0 { leader_cb.take() } else { None };
                scope.spawn(move || {
                    if opts.pin_threads {
                        affinity::pin_current_thread(t);
                    }
                    let mut rng = Pcg32::new(opts.seed, 1 + t as u64);
                    let mut order: Vec<usize> = my_block.to_vec();
                    let mut local_updates: u64 = 0;
                    // §3.3 "Shrinking Heuristic": each thread maintains
                    // an active set over *its own block* (local indices).
                    let mut shrink = if opts.shrinking {
                        Some((
                            super::shrinking::ShrinkState::new(
                                my_block.len(),
                                loss.upper_bound(),
                            ),
                            // local index of each order entry
                            (0..my_block.len()).collect::<Vec<usize>>(),
                        ))
                    } else {
                        None
                    };

                    for epoch in 0..opts.epochs {
                        if stop_ref.load(Ordering::SeqCst) {
                            break;
                        }
                        let iter_order: Vec<(usize, usize)> =
                            if let Some((st, _)) = shrink.as_mut() {
                                st.begin_epoch();
                                let mut act = st.active_indices();
                                rng.shuffle(&mut act);
                                act.iter().map(|&l| (my_block[l], l)).collect()
                            } else {
                                match opts.sampling {
                                    Sampling::Permutation => {
                                        rng.shuffle(&mut order)
                                    }
                                    Sampling::WithReplacement => {
                                        let m = my_block.len();
                                        for slot in order.iter_mut() {
                                            *slot =
                                                my_block[rng.gen_range(m)];
                                        }
                                    }
                                }
                                order.iter().map(|&i| (i, 0)).collect()
                            };
                        for &(i, local) in &iter_order {
                            let q = qii_ref[i];
                            if q <= 0.0 {
                                continue;
                            }
                            let (idx, vals) = ds.x.row(i);
                            if let Some(lt) = locks_ref {
                                lt.acquire_sorted(idx);
                            }
                            // step 2: read shared ŵ, solve the subproblem
                            let mut wx = 0.0;
                            for (j, v) in idx.iter().zip(vals) {
                                wx += w_ref.get(*j as usize) * v;
                            }
                            let a_old = alpha_ref.get(i);
                            if let Some((st, _)) = shrink.as_mut() {
                                let g = loss.dual_gradient(a_old, wx);
                                if st.should_skip(local, a_old, g) {
                                    if let Some(lt) = locks_ref {
                                        lt.release(idx);
                                    }
                                    continue;
                                }
                            }
                            let a_new = loss.solve_subproblem(a_old, wx, q);
                            let delta = a_new - a_old;
                            local_updates += 1;
                            if delta.abs() > MIN_DELTA {
                                alpha_ref.set(i, a_new);
                                // step 3: publish Δα_i x_i
                                match model {
                                    MemoryModel::Lock => {
                                        for (j, v) in idx.iter().zip(vals) {
                                            let j = *j as usize;
                                            w_ref.set(j, w_ref.get(j) + delta * v);
                                        }
                                    }
                                    MemoryModel::Atomic => {
                                        for (j, v) in idx.iter().zip(vals) {
                                            w_ref.add_atomic(*j as usize, delta * v);
                                        }
                                    }
                                    MemoryModel::Wild => {
                                        for (j, v) in idx.iter().zip(vals) {
                                            w_ref.add_wild(*j as usize, delta * v);
                                        }
                                    }
                                }
                            }
                            if let Some(lt) = locks_ref {
                                lt.release(idx);
                            }
                        }
                        if let Some((st, _)) = shrink.as_mut() {
                            st.end_epoch();
                        }

                        if t == 0 {
                            epochs_done_ref
                                .store(epoch as u64 + 1, Ordering::SeqCst);
                        }

                        // Rendezvous for evaluation snapshots.
                        if sync_every > 0 && (epoch + 1) % sync_every == 0 {
                            barrier_ref.wait();
                            if t == 0 {
                                if let Some(cb) = cb.as_deref_mut() {
                                    let a_snap = alpha_ref.to_vec();
                                    let w_snap = w_ref.to_vec();
                                    let pr = Progress {
                                        epoch: epoch + 1,
                                        alpha: &a_snap,
                                        w: &w_snap,
                                        train_secs: train_t.secs(),
                                    };
                                    if !cb(&pr) {
                                        stop_ref.store(true, Ordering::SeqCst);
                                    }
                                }
                            }
                            barrier_ref.wait();
                        }
                    }
                    updates_ref.fetch_add(local_updates, Ordering::Relaxed);
                });
            }
        });
        phases.add("train", train_t.secs());

        SolveResult {
            alpha: alpha.to_vec(),
            w_hat: w.to_vec(),
            epochs_run: epochs_done.load(Ordering::SeqCst) as usize,
            updates: updates.load(Ordering::Relaxed),
            phases,
        }
    }
}

/// Split a slice into `p` nearly-equal chunks (first `rem` get one extra).
fn chunk_evenly<T>(xs: &[T], p: usize) -> Vec<&[T]> {
    let n = xs.len();
    let base = n / p;
    let rem = n % p;
    let mut out = Vec::with_capacity(p);
    let mut start = 0;
    for t in 0..p {
        let len = base + usize::from(t < rem);
        out.push(&xs[start..start + len]);
        start += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::registry;
    use crate::eval;
    use crate::loss::Hinge;
    use crate::solver::SerialDcd;

    fn small() -> (Dataset, f64) {
        let (tr, _, c) = registry::load("rcv1", 0.02).unwrap();
        (tr, c)
    }

    fn opts(threads: usize, epochs: usize) -> SolveOptions {
        // eval_every = 1 puts a barrier at every epoch boundary.  On a
        // single-core host free-running workers are time-sliced so
        // coarsely that each finishes *all* its epochs in one quantum,
        // degenerating the run into sequential block-CD; the barrier
        // restores the per-epoch interleaving a real multi-core machine
        // gives for free (see DESIGN.md §3 on the 1-core substitution).
        SolveOptions { threads, epochs, eval_every: 1, ..Default::default() }
    }

    #[test]
    fn chunking_covers_everything() {
        let xs: Vec<usize> = (0..13).collect();
        let chunks = chunk_evenly(&xs, 4);
        assert_eq!(chunks.len(), 4);
        assert_eq!(chunks.iter().map(|c| c.len()).sum::<usize>(), 13);
        assert_eq!(chunks[0].len(), 4); // 13 = 4+3+3+3
        let flat: Vec<usize> = chunks.concat();
        assert_eq!(flat, xs);
    }

    #[test]
    fn single_thread_converges_like_serial() {
        let (ds, c) = small();
        let loss = Hinge::new(c);
        let r = Passcode::solve(
            &ds, &loss, MemoryModel::Wild, &opts(1, 30), None,
        );
        let gap = eval::duality_gap(&ds, &loss, &r.alpha);
        assert!(gap < 1e-3, "gap {gap}");
        // Single-threaded wild: no races → Eq. 3 must hold exactly.
        let wbar = eval::wbar_from_alpha(&ds, &r.alpha);
        let err = r.w_hat.iter().zip(&wbar)
            .map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
        assert!(err < 1e-9, "ŵ−w̄ = {err}");
    }

    #[test]
    fn all_models_reach_serial_objective_multithreaded() {
        let (ds, c) = small();
        let loss = Hinge::new(c);
        let serial = SerialDcd::solve(&ds, &loss, &opts(1, 60), None);
        let p_serial = eval::primal_objective(&ds, &loss, &serial.w_hat);
        for model in [MemoryModel::Lock, MemoryModel::Atomic, MemoryModel::Wild]
        {
            // Asynchrony on a tiny n (blocks of ~100) means high relative
            // staleness — convergence is slower per epoch; 60 epochs and a
            // 3% band is the honest check that all variants reach the
            // serial objective (Fig a's "almost identical" claim holds at
            // paper-scale n, see benches/fig_a_convergence.rs).
            let r = Passcode::solve(&ds, &loss, model, &opts(4, 60), None);
            let p = eval::primal_objective(&ds, &loss, &r.w_hat);
            assert!(
                (p - p_serial).abs() < 0.03 * p_serial.abs(),
                "{model:?}: P = {p} vs serial {p_serial}"
            );
        }
    }

    #[test]
    fn atomic_maintains_primal_dual_consistency() {
        // Atomic writes are lossless, so ŵ = Σ α_i x_i must hold at the
        // end (all threads joined) up to float addition reorder noise.
        let (ds, c) = small();
        let loss = Hinge::new(c);
        let r = Passcode::solve(
            &ds, &loss, MemoryModel::Atomic, &opts(4, 10), None,
        );
        let wbar = eval::wbar_from_alpha(&ds, &r.alpha);
        let err = r.w_hat.iter().zip(&wbar)
            .map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
        assert!(err < 1e-6, "atomic violated Eq. 3: {err}");
    }

    #[test]
    fn lock_is_serializable_consistent() {
        let (ds, c) = small();
        let loss = Hinge::new(c);
        let r = Passcode::solve(
            &ds, &loss, MemoryModel::Lock, &opts(4, 5), None,
        );
        let wbar = eval::wbar_from_alpha(&ds, &r.alpha);
        let err = r.w_hat.iter().zip(&wbar)
            .map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
        assert!(err < 1e-6, "lock violated Eq. 3: {err}");
    }

    #[test]
    fn progress_callback_fires_and_stops() {
        let (ds, c) = small();
        let loss = Hinge::new(c);
        let mut seen = Vec::new();
        let mut cb = |p: &Progress<'_>| {
            seen.push(p.epoch);
            p.epoch < 4
        };
        let mut o = opts(3, 100);
        o.eval_every = 2;
        let r = Passcode::solve(
            &ds, &loss, MemoryModel::Atomic, &o, Some(&mut cb),
        );
        assert_eq!(seen, vec![2, 4]);
        assert!(r.epochs_run <= 6, "ran {} epochs", r.epochs_run);
    }

    #[test]
    fn updates_counted_across_threads() {
        let (ds, c) = small();
        let loss = Hinge::new(c);
        let r = Passcode::solve(
            &ds, &loss, MemoryModel::Wild, &opts(4, 3), None,
        );
        // Every live coordinate visited once per epoch.
        let live = (0..ds.n()).filter(|&i| ds.x.row_nnz(i) > 0).count() as u64;
        assert_eq!(r.updates, live * 3);
    }

    #[test]
    fn with_replacement_parallel_converges() {
        let (ds, c) = small();
        let loss = Hinge::new(c);
        let mut o = opts(4, 150);
        o.sampling = Sampling::WithReplacement;
        let r = Passcode::solve(&ds, &loss, MemoryModel::Atomic, &o, None);
        let gap = eval::duality_gap(&ds, &loss, &r.alpha);
        let p = eval::primal_objective(&ds, &loss, &r.w_hat);
        assert!(gap < 0.03 * p.abs().max(1.0), "gap {gap} (P={p})");
    }

    #[test]
    fn per_thread_shrinking_matches_full_objective_and_skips_work() {
        // §3.3: each thread keeps an active set over its own block.
        let (ds, c) = small();
        let loss = Hinge::new(c);
        let full = Passcode::solve(
            &ds, &loss, MemoryModel::Atomic, &opts(4, 40), None,
        );
        let mut o = opts(4, 40);
        o.shrinking = true;
        let shr = Passcode::solve(&ds, &loss, MemoryModel::Atomic, &o, None);
        let p_full = eval::primal_objective(&ds, &loss, &full.w_hat);
        let p_shr = eval::primal_objective(&ds, &loss, &shr.w_hat);
        assert!(
            (p_full - p_shr).abs() < 0.02 * p_full.abs(),
            "shrinking changed the answer: {p_full} vs {p_shr}"
        );
        assert!(
            shr.updates < full.updates,
            "shrinking skipped nothing: {} vs {}",
            shr.updates,
            full.updates
        );
    }

    #[test]
    fn warm_start_resumes_where_cold_left_off() {
        // Solve 20 epochs cold; then warm-start one extra epoch from the
        // result.  The warm run must (a) not regress the objective and
        // (b) beat a 1-epoch cold start by a wide margin.
        let (ds, c) = small();
        let loss = Hinge::new(c);
        let base = Passcode::solve(
            &ds, &loss, MemoryModel::Wild, &opts(1, 20), None,
        );
        let p_base = eval::primal_objective(&ds, &loss, &base.w_hat);
        let warm = Passcode::solve_warm(
            &ds,
            &loss,
            MemoryModel::Wild,
            &opts(1, 1),
            &base.alpha,
            &base.w_hat,
            None,
        );
        let p_warm = eval::primal_objective(&ds, &loss, &warm.w_hat);
        assert!(p_warm <= p_base + 1e-6, "warm regressed: {p_warm} vs {p_base}");
        let cold1 = Passcode::solve(
            &ds, &loss, MemoryModel::Wild, &opts(1, 1), None,
        );
        let p_cold1 = eval::primal_objective(&ds, &loss, &cold1.w_hat);
        assert!(
            p_warm < p_cold1,
            "warm start no better than cold 1-epoch: {p_warm} vs {p_cold1}"
        );
    }

    #[test]
    fn warm_start_from_zeros_matches_cold_start() {
        let (ds, c) = small();
        let loss = Hinge::new(c);
        let cold = Passcode::solve(
            &ds, &loss, MemoryModel::Wild, &opts(1, 5), None,
        );
        let warm = Passcode::solve_warm(
            &ds,
            &loss,
            MemoryModel::Wild,
            &opts(1, 5),
            &vec![0.0; ds.n()],
            &vec![0.0; ds.d()],
            None,
        );
        assert_eq!(cold.alpha, warm.alpha);
        assert_eq!(cold.w_hat, warm.w_hat);
    }

    #[test]
    fn pinned_threads_run_fine() {
        let (ds, c) = small();
        let loss = Hinge::new(c);
        let mut o = opts(2, 3);
        o.pin_threads = true;
        let r = Passcode::solve(&ds, &loss, MemoryModel::Wild, &o, None);
        assert_eq!(r.epochs_run, 3);
    }
}
