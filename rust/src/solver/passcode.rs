//! PASSCoDe — Algorithm 2: asynchronous parallel stochastic dual
//! coordinate descent in shared memory, in the paper's three flavours.
//!
//! Every worker repeatedly: picks a coordinate from its own partition
//! (paper §3.3 "Random Permutation": `{1..n}` is randomly split into `p`
//! blocks, each thread permutes its block per epoch, so `α_i` has a
//! unique owner and only `w` is contended), solves the one-variable
//! subproblem against the *shared* `w`, and publishes `Δα_i x_i`:
//!
//! * [`MemoryModel::Lock`]   — ordered per-feature spinlocks around
//!   read-and-update (serializable; the paper's Table 1 shows it is
//!   slower than serial DCD — reproduced in `benches/table1_scaling.rs`);
//! * [`MemoryModel::Atomic`] — lock-free reads, CAS adds on `w` (linear
//!   convergence, Theorem 2);
//! * [`MemoryModel::Wild`]   — plain racy adds; `ŵ ≠ Σα_i x_i` at the end
//!   (Eq. 6), and Theorem 3's backward-error analysis says `ŵ` is the
//!   exact solution of a perturbed primal — so predict with `ŵ`.
//!
//! The inner loop runs through the fused kernels of
//! [`crate::solver::kernel`]: each worker is monomorphized over its
//! memory model's [`UpdateKernel`] (no per-update dispatch), each
//! coordinate is one fused `dot → solve → scatter` pass with unrolled
//! gathers, and the per-epoch visit orders live in reusable per-thread
//! buffers — steady-state epochs allocate nothing.
//!
//! Threads free-run with **no barriers** when `opts.eval_every == 0`;
//! with eval enabled they rendezvous every `eval_every` epochs so the
//! leader can snapshot (α, ŵ) for the convergence curves.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Barrier;

use crate::data::Dataset;
use crate::loss::{Loss, MIN_DELTA};
use crate::util::{affinity, Pcg32, Phases, SharedVec, Timer};

use super::kernel::{CasKernel, LockedKernel, UpdateKernel, WildKernel};
use super::locks::LockTable;
use super::{Progress, ProgressFn, Sampling, SolveOptions, SolveResult};

/// Which mechanism guards step 3's write of `Δα_i x_i` into shared `w`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemoryModel {
    /// Lock all features in `N_i` (ordered; deadlock-free).
    Lock,
    /// Atomic (CAS) per-feature adds.
    Atomic,
    /// Unguarded read-modify-write (HOGWILD-style).
    Wild,
}

impl MemoryModel {
    /// Bare model name (`lock` / `atomic` / `wild`), the suffix of the
    /// registry's `passcode-*` solver names.
    pub fn name(&self) -> &'static str {
        match self {
            MemoryModel::Lock => "lock",
            MemoryModel::Atomic => "atomic",
            MemoryModel::Wild => "wild",
        }
    }

    /// Parse a bare model name — a thin view over the solver registry's
    /// `passcode-*` entries ([`crate::solver::SolverKind`]), so the two
    /// name tables can never drift.  Matches against the registry table
    /// directly; no per-call allocation.
    pub fn parse(s: &str) -> Option<MemoryModel> {
        super::api::SolverKind::all().find_map(|k| match k {
            super::api::SolverKind::Passcode(m) if m.name() == s => Some(m),
            _ => None,
        })
    }
}

/// The PASSCoDe solver.
pub struct Passcode;

impl Passcode {
    /// Run Algorithm 2 with `opts.threads` workers, cold-started from
    /// `α = 0`, `w = 0`.
    ///
    /// The progress callback (leader-only) fires at epoch barriers every
    /// `opts.eval_every` epochs; returning `false` stops all workers at
    /// the next boundary.
    ///
    /// Thin shim over the warm-start core; prefer the
    /// [`crate::solver::Solver`] registry for epoch-granular control,
    /// deadlines, or checkpoint/restore.
    pub fn solve<L: Loss>(
        ds: &Dataset,
        loss: &L,
        model: MemoryModel,
        opts: &SolveOptions,
        on_progress: Option<&mut ProgressFn<'_>>,
    ) -> SolveResult {
        Self::solve_impl(ds, loss, model, opts, None, on_progress)
    }

    /// Run Algorithm 2 warm-started from an existing `(α, ŵ)` pair — the
    /// continuous-training entry point used by [`crate::serve::online`]:
    /// the online trainer resumes from the registry's live model instead
    /// of re-solving from zero on every publish.
    ///
    /// `alpha0.len()` must equal `ds.n()` and `w0.len()` must equal
    /// `ds.d()`.  The caller is responsible for `w0 ≈ Σ α0_i x_i` if it
    /// wants the dual/primal pairing to stay meaningful (PASSCoDe-Wild's
    /// Theorem 3 tolerates the drift either way).
    pub fn solve_warm<L: Loss>(
        ds: &Dataset,
        loss: &L,
        model: MemoryModel,
        opts: &SolveOptions,
        alpha0: &[f64],
        w0: &[f64],
        on_progress: Option<&mut ProgressFn<'_>>,
    ) -> SolveResult {
        assert_eq!(alpha0.len(), ds.n(), "warm-start α dimension");
        assert_eq!(w0.len(), ds.d(), "warm-start w dimension");
        Self::solve_impl(ds, loss, model, opts, Some((alpha0, w0)), on_progress)
    }

    fn solve_impl<L: Loss>(
        ds: &Dataset,
        loss: &L,
        model: MemoryModel,
        opts: &SolveOptions,
        warm: Option<(&[f64], &[f64])>,
        on_progress: Option<&mut ProgressFn<'_>>,
    ) -> SolveResult {
        let (w, alpha) = match warm {
            Some((a0, w0)) => {
                (SharedVec::from_slice(w0), SharedVec::from_slice(a0))
            }
            None => (SharedVec::zeros(ds.d()), SharedVec::zeros(ds.n())),
        };
        let (epochs_run, updates, phases) = Self::run_epochs_shared(
            ds,
            loss,
            model,
            opts,
            &alpha,
            &w,
            on_progress,
        );
        SolveResult {
            alpha: alpha.to_vec(),
            w_hat: w.to_vec(),
            epochs_run,
            updates,
            phases,
        }
    }

    /// Run `opts.epochs` epochs of Algorithm 2 *in place* over shared
    /// `(α, ŵ)` buffers — the zero-copy core behind every entry point.
    /// [`crate::solver::TrainSession`] owns a pair of [`SharedVec`]s for
    /// the session's lifetime and drives this once per epoch, which
    /// avoids re-allocating and copying the `(α, ŵ)` state every epoch.
    /// (Each *call* still pays its own init: partition, worker spawns,
    /// per-thread order buffers — the "steady-state epochs allocate
    /// nothing" property holds within one multi-epoch call.)
    ///
    /// Returns `(epochs_run, updates, phases)`.
    pub fn run_epochs_shared<L: Loss>(
        ds: &Dataset,
        loss: &L,
        model: MemoryModel,
        opts: &SolveOptions,
        alpha: &SharedVec,
        w: &SharedVec,
        mut on_progress: Option<&mut ProgressFn<'_>>,
    ) -> (usize, u64, Phases) {
        let n = ds.n();
        let d = ds.d();
        assert_eq!(alpha.len(), n, "shared α dimension");
        assert_eq!(w.len(), d, "shared ŵ dimension");
        let p = opts.threads.max(1);
        let mut phases = Phases::new();

        // ---- init (counted separately, as in §5.2; norms memoized) ----
        let init_t = Timer::start();
        let qii = ds.x.row_sqnorms_cached();
        let locks = match model {
            MemoryModel::Lock => Some(LockTable::new(d)),
            _ => None,
        };
        // Random partition of {0..n} into p blocks (paper §3.3).
        let mut rng = Pcg32::new(opts.seed, 0xB10C);
        let perm = rng.permutation(n);
        let blocks: Vec<&[usize]> = chunk_evenly(&perm, p);
        phases.add("init", init_t.secs());

        // ---- shared control -------------------------------------------
        let stop = AtomicBool::new(false);
        let updates = AtomicU64::new(0);
        let epochs_done = AtomicU64::new(0);
        let barrier = Barrier::new(p);
        let train_t = Timer::start();

        let ctx = WorkerCtx {
            ds,
            loss,
            opts,
            qii,
            alpha,
            w,
            stop: &stop,
            updates: &updates,
            epochs_done: &epochs_done,
            barrier: &barrier,
            train_t: &train_t,
        };

        std::thread::scope(|scope| {
            let mut leader_cb = on_progress.take();
            let ctx_ref = &ctx;
            let locks_ref = &locks;
            for (t, &my_block) in blocks.iter().enumerate() {
                let cb = if t == 0 { leader_cb.take() } else { None };
                scope.spawn(move || {
                    if ctx_ref.opts.pin_threads {
                        affinity::pin_current_thread(t);
                    }
                    // One memory-model dispatch per worker: the epoch
                    // loop below is monomorphized over the kernel.
                    match model {
                        MemoryModel::Wild => worker(
                            ctx_ref,
                            t,
                            my_block,
                            WildKernel::new(ctx_ref.w),
                            cb,
                        ),
                        MemoryModel::Atomic => worker(
                            ctx_ref,
                            t,
                            my_block,
                            CasKernel::new(ctx_ref.w),
                            cb,
                        ),
                        MemoryModel::Lock => worker(
                            ctx_ref,
                            t,
                            my_block,
                            LockedKernel::new(
                                ctx_ref.w,
                                locks_ref
                                    .as_ref()
                                    .expect("lock table built for Lock"),
                            ),
                            cb,
                        ),
                    }
                });
            }
        });
        phases.add("train", train_t.secs());

        // Relaxed: thread::scope's join synchronizes-with this read, so
        // the workers' final store is already visible.
        let epochs_run = epochs_done.load(Ordering::Relaxed) as usize;
        let total_updates = updates.load(Ordering::Relaxed);
        // Publish round totals into the metrics registry here (not in
        // the session layer) so every entry point that reaches the
        // shared core — sessions, cold solves, the online trainer's
        // free-running rounds — reports identically.
        if crate::obs::probes_enabled() {
            let probes = crate::obs::probes::solver();
            probes.updates.add(total_updates);
            probes.epochs.add(epochs_run as u64);
            crate::obs::probes::sync_hot_counters();
            let train_secs = phases.get("train");
            if train_secs > 0.0 {
                probes.updates_per_sec.set(total_updates as f64 / train_secs);
            }
        }

        (epochs_run, total_updates, phases)
    }
}

/// Everything a worker thread shares by reference.
struct WorkerCtx<'a, L: Loss> {
    ds: &'a Dataset,
    loss: &'a L,
    opts: &'a SolveOptions,
    qii: &'a [f64],
    alpha: &'a SharedVec,
    w: &'a SharedVec,
    stop: &'a AtomicBool,
    updates: &'a AtomicU64,
    epochs_done: &'a AtomicU64,
    barrier: &'a Barrier,
    train_t: &'a Timer,
}

/// One worker's whole run: `opts.epochs` epochs over its block through
/// the fused kernel `K`.  Per-epoch visit orders are built in the two
/// reusable buffers (`order` for the plain samplers, `locals` for the
/// shrinking active set), so after the first epoch the loop performs no
/// heap allocation.
fn worker<L: Loss, K: UpdateKernel>(
    ctx: &WorkerCtx<'_, L>,
    t: usize,
    my_block: &[usize],
    kernel: K,
    mut cb: Option<&mut ProgressFn<'_>>,
) {
    let mut rng = Pcg32::new(ctx.opts.seed, 1 + t as u64);
    let mut order: Vec<usize> = my_block.to_vec();
    // §3.3 "Shrinking Heuristic": each thread maintains an active set
    // over *its own block* (local indices).
    let mut locals: Vec<usize> = Vec::new();
    let mut shrink = if ctx.opts.shrinking {
        locals.reserve(my_block.len());
        Some(super::shrinking::ShrinkState::new(
            my_block.len(),
            ctx.loss.upper_bound(),
        ))
    } else {
        None
    };
    let sync_every = ctx.opts.eval_every; // 0 = free-run
    let mut local_updates: u64 = 0;
    // Telemetry rail: the flag is hoisted once per worker run, so the
    // probes-off hot loop pays one predictable branch per update in
    // `probed_update` and nothing else.  The countdown is only
    // decremented while probes are on.
    let probes_on = crate::obs::probes_enabled();
    let mut tau_countdown = crate::obs::probes::TAU_SAMPLE_EVERY;

    for epoch in 0..ctx.opts.epochs {
        // Relaxed: the stop flag is advisory — a worker may run one
        // extra epoch after it flips, which only costs work, never
        // correctness (α/w stay consistent under any interleaving).
        if ctx.stop.load(Ordering::Relaxed) {
            break;
        }
        let epoch_t = probes_on.then(Timer::start);

        // audit: hot-path begin — per-epoch update loops: no heap
        // allocation after the first epoch (buffers are reused).
        if let Some(st) = shrink.as_mut() {
            st.active_indices_into(&mut locals);
            rng.shuffle(&mut locals);
            st.begin_epoch();
            for &local in &locals {
                let i = my_block[local];
                let q = ctx.qii[i];
                if q <= 0.0 {
                    continue;
                }
                let (idx, vals) = ctx.ds.x.row(i);
                probed_update(&kernel, idx, vals, probes_on, &mut tau_countdown, |wx| {
                    let a_old = ctx.alpha.get(i);
                    let g = ctx.loss.dual_gradient(a_old, wx);
                    if st.should_skip(local, a_old, g) {
                        return None;
                    }
                    let a_new = ctx.loss.solve_subproblem(a_old, wx, q);
                    let delta = a_new - a_old;
                    local_updates += 1;
                    if delta.abs() > MIN_DELTA {
                        ctx.alpha.set(i, a_new);
                        Some(delta)
                    } else {
                        None
                    }
                });
            }
            st.end_epoch();
        } else {
            match ctx.opts.sampling {
                Sampling::Permutation => rng.shuffle(&mut order),
                Sampling::WithReplacement => {
                    let m = my_block.len();
                    for slot in order.iter_mut() {
                        *slot = my_block[rng.gen_range(m)];
                    }
                }
            }
            for &i in &order {
                let q = ctx.qii[i];
                if q <= 0.0 {
                    continue;
                }
                let (idx, vals) = ctx.ds.x.row(i);
                probed_update(&kernel, idx, vals, probes_on, &mut tau_countdown, |wx| {
                    let a_old = ctx.alpha.get(i);
                    let a_new = ctx.loss.solve_subproblem(a_old, wx, q);
                    let delta = a_new - a_old;
                    local_updates += 1;
                    if delta.abs() > MIN_DELTA {
                        ctx.alpha.set(i, a_new);
                        Some(delta)
                    } else {
                        None
                    }
                });
            }
        }
        // audit: hot-path end — epoch boundary below may allocate
        // (progress labels, eval snapshots).

        if let Some(timer) = epoch_t {
            let dur = timer.elapsed();
            let ns = dur.as_nanos().min(u64::MAX as u128) as u64;
            crate::obs::probes::solver().epoch_seconds.record(ns);
            if t == 0 {
                let label = format!("epoch {}", epoch + 1);
                crate::obs::recorder().record("train.epoch", label, dur);
            }
        }

        if t == 0 {
            // Relaxed: a monotonic progress counter read either after
            // the scope join (synchronized) or opportunistically.
            ctx.epochs_done.store(epoch as u64 + 1, Ordering::Relaxed);
        }

        // Rendezvous for evaluation snapshots.
        if sync_every > 0 && (epoch + 1) % sync_every == 0 {
            ctx.barrier.wait();
            if t == 0 {
                if let Some(cb) = cb.as_deref_mut() {
                    let a_snap = ctx.alpha.to_vec();
                    let w_snap = ctx.w.to_vec();
                    let pr = Progress {
                        epoch: epoch + 1,
                        alpha: &a_snap,
                        w: &w_snap,
                        train_secs: ctx.train_t.secs(),
                    };
                    if !cb(&pr) {
                        // Relaxed: the barrier wait below is the
                        // synchronization; the flag itself is advisory.
                        ctx.stop.store(true, Ordering::Relaxed);
                    }
                }
            }
            ctx.barrier.wait();
        }
    }
    ctx.updates.fetch_add(local_updates, Ordering::Relaxed);
}

/// One fused kernel update, with the sampled τ-staleness probe wrapped
/// around roughly 1-in-[`crate::obs::probes::TAU_SAMPLE_EVERY`] calls
/// when probes are on.  A sample reads the global scatter clock before
/// and after the update: foreign scatters landing inside that
/// read→write span, minus the update's own write, are the staleness τ
/// the convergence analysis charges for (Liu & Wright,
/// arXiv:1403.3862) — here measured on the free-running schedule,
/// complementing the serialized-schedule τ from `passcode check`.
// audit: hot-path begin — wraps every single coordinate update.
#[inline]
fn probed_update<K: UpdateKernel, F: FnOnce(f64) -> Option<f64>>(
    kernel: &K,
    idx: &[u32],
    vals: &[f64],
    probes_on: bool,
    countdown: &mut u32,
    solve: F,
) {
    if probes_on {
        *countdown -= 1;
        if *countdown == 0 {
            *countdown = crate::obs::probes::TAU_SAMPLE_EVERY;
            let before = crate::obs::probes::scatter_ticks();
            let wrote = kernel.update(idx, vals, solve);
            let after = crate::obs::probes::scatter_ticks();
            let tau = after.saturating_sub(before).saturating_sub(wrote as u64);
            crate::obs::probes::solver().tau.record(tau);
            return;
        }
    }
    kernel.update(idx, vals, solve);
}
// audit: hot-path end

/// Split a slice into `p` nearly-equal chunks (first `rem` get one extra).
fn chunk_evenly<T>(xs: &[T], p: usize) -> Vec<&[T]> {
    let n = xs.len();
    let base = n / p;
    let rem = n % p;
    let mut out = Vec::with_capacity(p);
    let mut start = 0;
    for t in 0..p {
        let len = base + usize::from(t < rem);
        out.push(&xs[start..start + len]);
        start += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::registry;
    use crate::eval;
    use crate::loss::Hinge;
    use crate::solver::SerialDcd;

    fn small() -> (Dataset, f64) {
        let (tr, _, c) = registry::load("rcv1", 0.02).unwrap();
        (tr, c)
    }

    fn opts(threads: usize, epochs: usize) -> SolveOptions {
        // eval_every = 1 puts a barrier at every epoch boundary.  On a
        // single-core host free-running workers are time-sliced so
        // coarsely that each finishes *all* its epochs in one quantum,
        // degenerating the run into sequential block-CD; the barrier
        // restores the per-epoch interleaving a real multi-core machine
        // gives for free (see DESIGN.md §3 on the 1-core substitution).
        SolveOptions { threads, epochs, eval_every: 1, ..Default::default() }
    }

    #[test]
    fn chunking_covers_everything() {
        let xs: Vec<usize> = (0..13).collect();
        let chunks = chunk_evenly(&xs, 4);
        assert_eq!(chunks.len(), 4);
        assert_eq!(chunks.iter().map(|c| c.len()).sum::<usize>(), 13);
        assert_eq!(chunks[0].len(), 4); // 13 = 4+3+3+3
        let flat: Vec<usize> = chunks.concat();
        assert_eq!(flat, xs);
    }

    #[test]
    fn memory_model_parse_tracks_registry() {
        assert_eq!(MemoryModel::parse("lock"), Some(MemoryModel::Lock));
        assert_eq!(MemoryModel::parse("atomic"), Some(MemoryModel::Atomic));
        assert_eq!(MemoryModel::parse("wild"), Some(MemoryModel::Wild));
        assert_eq!(MemoryModel::parse("passcode-wild"), None);
        assert_eq!(MemoryModel::parse("hogwild"), None);
        for m in [MemoryModel::Lock, MemoryModel::Atomic, MemoryModel::Wild] {
            assert_eq!(MemoryModel::parse(m.name()), Some(m));
        }
    }

    #[test]
    fn single_thread_converges_like_serial() {
        let (ds, c) = small();
        let loss = Hinge::new(c);
        let r = Passcode::solve(
            &ds, &loss, MemoryModel::Wild, &opts(1, 30), None,
        );
        let gap = eval::duality_gap(&ds, &loss, &r.alpha);
        assert!(gap < 1e-3, "gap {gap}");
        // Single-threaded wild: no races → Eq. 3 must hold exactly.
        let wbar = eval::wbar_from_alpha(&ds, &r.alpha);
        let err = r.w_hat.iter().zip(&wbar)
            .map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
        assert!(err < 1e-9, "ŵ−w̄ = {err}");
    }

    #[test]
    fn all_models_reach_serial_objective_multithreaded() {
        let (ds, c) = small();
        let loss = Hinge::new(c);
        let serial = SerialDcd::solve(&ds, &loss, &opts(1, 60), None);
        let p_serial = eval::primal_objective(&ds, &loss, &serial.w_hat);
        for model in [MemoryModel::Lock, MemoryModel::Atomic, MemoryModel::Wild]
        {
            // Asynchrony on a tiny n (blocks of ~100) means high relative
            // staleness — convergence is slower per epoch; 60 epochs and a
            // 3% band is the honest check that all variants reach the
            // serial objective (Fig a's "almost identical" claim holds at
            // paper-scale n, see benches/fig_a_convergence.rs).
            let r = Passcode::solve(&ds, &loss, model, &opts(4, 60), None);
            let p = eval::primal_objective(&ds, &loss, &r.w_hat);
            assert!(
                (p - p_serial).abs() < 0.03 * p_serial.abs(),
                "{model:?}: P = {p} vs serial {p_serial}"
            );
        }
    }

    #[test]
    fn atomic_maintains_primal_dual_consistency() {
        // Atomic writes are lossless, so ŵ = Σ α_i x_i must hold at the
        // end (all threads joined) up to float addition reorder noise.
        let (ds, c) = small();
        let loss = Hinge::new(c);
        let r = Passcode::solve(
            &ds, &loss, MemoryModel::Atomic, &opts(4, 10), None,
        );
        let wbar = eval::wbar_from_alpha(&ds, &r.alpha);
        let err = r.w_hat.iter().zip(&wbar)
            .map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
        assert!(err < 1e-6, "atomic violated Eq. 3: {err}");
    }

    #[test]
    fn lock_is_serializable_consistent() {
        let (ds, c) = small();
        let loss = Hinge::new(c);
        let r = Passcode::solve(
            &ds, &loss, MemoryModel::Lock, &opts(4, 5), None,
        );
        let wbar = eval::wbar_from_alpha(&ds, &r.alpha);
        let err = r.w_hat.iter().zip(&wbar)
            .map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
        assert!(err < 1e-6, "lock violated Eq. 3: {err}");
    }

    #[test]
    fn progress_callback_fires_and_stops() {
        let (ds, c) = small();
        let loss = Hinge::new(c);
        let mut seen = Vec::new();
        let mut cb = |p: &Progress<'_>| {
            seen.push(p.epoch);
            p.epoch < 4
        };
        let mut o = opts(3, 100);
        o.eval_every = 2;
        let r = Passcode::solve(
            &ds, &loss, MemoryModel::Atomic, &o, Some(&mut cb),
        );
        assert_eq!(seen, vec![2, 4]);
        assert!(r.epochs_run <= 6, "ran {} epochs", r.epochs_run);
    }

    #[test]
    fn updates_counted_across_threads() {
        let (ds, c) = small();
        let loss = Hinge::new(c);
        let r = Passcode::solve(
            &ds, &loss, MemoryModel::Wild, &opts(4, 3), None,
        );
        // Every live coordinate visited once per epoch.
        let live = (0..ds.n()).filter(|&i| ds.x.row_nnz(i) > 0).count() as u64;
        assert_eq!(r.updates, live * 3);
    }

    #[test]
    fn with_replacement_parallel_converges() {
        let (ds, c) = small();
        let loss = Hinge::new(c);
        let mut o = opts(4, 150);
        o.sampling = Sampling::WithReplacement;
        let r = Passcode::solve(&ds, &loss, MemoryModel::Atomic, &o, None);
        let gap = eval::duality_gap(&ds, &loss, &r.alpha);
        let p = eval::primal_objective(&ds, &loss, &r.w_hat);
        assert!(gap < 0.03 * p.abs().max(1.0), "gap {gap} (P={p})");
    }

    #[test]
    fn per_thread_shrinking_matches_full_objective_and_skips_work() {
        // §3.3: each thread keeps an active set over its own block.
        let (ds, c) = small();
        let loss = Hinge::new(c);
        let full = Passcode::solve(
            &ds, &loss, MemoryModel::Atomic, &opts(4, 40), None,
        );
        let mut o = opts(4, 40);
        o.shrinking = true;
        let shr = Passcode::solve(&ds, &loss, MemoryModel::Atomic, &o, None);
        let p_full = eval::primal_objective(&ds, &loss, &full.w_hat);
        let p_shr = eval::primal_objective(&ds, &loss, &shr.w_hat);
        assert!(
            (p_full - p_shr).abs() < 0.02 * p_full.abs(),
            "shrinking changed the answer: {p_full} vs {p_shr}"
        );
        assert!(
            shr.updates < full.updates,
            "shrinking skipped nothing: {} vs {}",
            shr.updates,
            full.updates
        );
    }

    #[test]
    fn warm_start_resumes_where_cold_left_off() {
        // Solve 20 epochs cold; then warm-start one extra epoch from the
        // result.  The warm run must (a) not regress the objective and
        // (b) beat a 1-epoch cold start by a wide margin.
        let (ds, c) = small();
        let loss = Hinge::new(c);
        let base = Passcode::solve(
            &ds, &loss, MemoryModel::Wild, &opts(1, 20), None,
        );
        let p_base = eval::primal_objective(&ds, &loss, &base.w_hat);
        let warm = Passcode::solve_warm(
            &ds,
            &loss,
            MemoryModel::Wild,
            &opts(1, 1),
            &base.alpha,
            &base.w_hat,
            None,
        );
        let p_warm = eval::primal_objective(&ds, &loss, &warm.w_hat);
        assert!(p_warm <= p_base + 1e-6, "warm regressed: {p_warm} vs {p_base}");
        let cold1 = Passcode::solve(
            &ds, &loss, MemoryModel::Wild, &opts(1, 1), None,
        );
        let p_cold1 = eval::primal_objective(&ds, &loss, &cold1.w_hat);
        assert!(
            p_warm < p_cold1,
            "warm start no better than cold 1-epoch: {p_warm} vs {p_cold1}"
        );
    }

    #[test]
    fn warm_start_from_zeros_matches_cold_start() {
        let (ds, c) = small();
        let loss = Hinge::new(c);
        let cold = Passcode::solve(
            &ds, &loss, MemoryModel::Wild, &opts(1, 5), None,
        );
        let warm = Passcode::solve_warm(
            &ds,
            &loss,
            MemoryModel::Wild,
            &opts(1, 5),
            &vec![0.0; ds.n()],
            &vec![0.0; ds.d()],
            None,
        );
        assert_eq!(cold.alpha, warm.alpha);
        assert_eq!(cold.w_hat, warm.w_hat);
    }

    #[test]
    fn shared_core_matches_solve_on_one_thread() {
        // The zero-copy session core and the cold-start shim are the
        // same algorithm: driving run_epochs_shared over owned buffers
        // must reproduce Passcode::solve bit-for-bit (serial path).
        let (ds, c) = small();
        let loss = Hinge::new(c);
        let o = opts(1, 5);
        let via_solve = Passcode::solve(
            &ds, &loss, MemoryModel::Wild, &o, None,
        );
        let alpha = SharedVec::zeros(ds.n());
        let w = SharedVec::zeros(ds.d());
        let (epochs_run, updates, _) = Passcode::run_epochs_shared(
            &ds, &loss, MemoryModel::Wild, &o, &alpha, &w, None,
        );
        assert_eq!(epochs_run, 5);
        assert_eq!(updates, via_solve.updates);
        assert_eq!(alpha.to_vec(), via_solve.alpha);
        assert_eq!(w.to_vec(), via_solve.w_hat);
    }

    #[test]
    fn pinned_threads_run_fine() {
        let (ds, c) = small();
        let loss = Hinge::new(c);
        let mut o = opts(2, 3);
        o.pin_threads = true;
        let r = Passcode::solve(&ds, &loss, MemoryModel::Wild, &o, None);
        assert_eq!(r.epochs_run, 3);
    }
}
