//! One-vs-rest multiclass training on top of the binary solvers —
//! LIBLINEAR's multiclass mode (cf. Keerthi et al. 2008, cited in the
//! paper §1) built from PASSCoDe binary problems.
//!
//! For K classes, K binary problems are trained (class k vs rest); each
//! binary problem is itself solved by any [`SolverKind`]-style engine —
//! here serial DCD or PASSCoDe with a chosen memory model.  Prediction
//! is argmax over the K margins.

use crate::data::{CsrMatrix, Dataset};
use crate::loss::Loss;

use super::passcode::{MemoryModel, Passcode};
use super::{SolveOptions, SolveResult};

/// A multiclass instance set: rows (unfolded) + integer labels `0..K`.
#[derive(Debug, Clone)]
pub struct MulticlassDataset {
    pub x: CsrMatrix,
    /// Class id per row, in `0..k`.
    pub labels: Vec<usize>,
    pub k: usize,
    pub name: String,
}

impl MulticlassDataset {
    pub fn new(
        x: CsrMatrix,
        labels: Vec<usize>,
        k: usize,
        name: impl Into<String>,
    ) -> Self {
        assert_eq!(x.rows(), labels.len());
        assert!(k >= 2);
        assert!(labels.iter().all(|&l| l < k), "label out of range");
        Self { x, labels, k, name: name.into() }
    }

    pub fn n(&self) -> usize {
        self.x.rows()
    }

    pub fn d(&self) -> usize {
        self.x.cols()
    }

    /// The binary one-vs-rest view for class `k`: rows folded with
    /// y = +1 for class k, −1 otherwise.
    pub fn ovr_view(&self, k: usize) -> Dataset {
        assert!(k < self.k);
        let mut rows = Vec::with_capacity(self.n());
        let mut y = Vec::with_capacity(self.n());
        for i in 0..self.n() {
            let label = if self.labels[i] == k { 1.0 } else { -1.0 };
            let (idx, vals) = self.x.row(i);
            rows.push(
                idx.iter()
                    .zip(vals)
                    .map(|(j, v)| crate::data::Entry {
                        index: *j,
                        value: label * v,
                    })
                    .collect::<Vec<_>>(),
            );
            y.push(label);
        }
        Dataset::new(
            CsrMatrix::from_rows(&rows, self.d()),
            y,
            format!("{}-ovr{}", self.name, k),
        )
    }
}

/// A trained one-vs-rest model: one weight vector per class.
#[derive(Debug, Clone)]
pub struct OvrModel {
    /// `k` weight vectors, each of length `d`.
    pub w: Vec<Vec<f64>>,
}

impl OvrModel {
    /// Train with PASSCoDe (or serial when `threads == 1`).
    pub fn train<L: Loss>(
        ds: &MulticlassDataset,
        loss: &L,
        model: MemoryModel,
        opts: &SolveOptions,
    ) -> (OvrModel, Vec<SolveResult>) {
        let mut w = Vec::with_capacity(ds.k);
        let mut results = Vec::with_capacity(ds.k);
        for k in 0..ds.k {
            let view = ds.ovr_view(k);
            let r = Passcode::solve(&view, loss, model, opts, None);
            w.push(r.w_hat.clone());
            results.push(r);
        }
        (OvrModel { w }, results)
    }

    /// Predicted class of a raw (unfolded) sparse row: argmax margin.
    pub fn predict_row(&self, idx: &[u32], vals: &[f64]) -> usize {
        let mut best = 0usize;
        let mut best_m = f64::NEG_INFINITY;
        for (k, wk) in self.w.iter().enumerate() {
            let mut m = 0.0;
            for (j, v) in idx.iter().zip(vals) {
                m += wk[*j as usize] * v;
            }
            if m > best_m {
                best_m = m;
                best = k;
            }
        }
        best
    }

    /// Accuracy over a multiclass dataset.
    pub fn accuracy(&self, ds: &MulticlassDataset) -> f64 {
        if ds.n() == 0 {
            return 0.0;
        }
        let correct = (0..ds.n())
            .filter(|&i| {
                let (idx, vals) = ds.x.row(i);
                self.predict_row(idx, vals) == ds.labels[i]
            })
            .count();
        correct as f64 / ds.n() as f64
    }
}

/// Synthetic multiclass generator: K planted separators, label = argmax.
pub fn synthetic_multiclass(
    n: usize,
    d: usize,
    k: usize,
    avg_nnz: f64,
    seed: u64,
) -> MulticlassDataset {
    use crate::util::Pcg32;
    let mut rng = Pcg32::new(seed, 0x3C1A55);
    let wstars: Vec<Vec<f64>> = (0..k)
        .map(|_| (0..d).map(|_| rng.gen_normal()).collect())
        .collect();
    let mut rows = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        let nnz = ((avg_nnz * (0.5 + rng.gen_f64())).round() as usize)
            .clamp(1, d);
        let mut feats: Vec<(u32, f64)> = Vec::with_capacity(nnz);
        while feats.len() < nnz {
            let j = rng.gen_range(d) as u32;
            if feats.iter().all(|&(i, _)| i != j) {
                feats.push((j, rng.gen_normal()));
            }
        }
        feats.sort_unstable_by_key(|&(i, _)| i);
        let label = (0..k)
            .max_by(|&a, &b| {
                let ma: f64 = feats
                    .iter()
                    .map(|&(j, v)| wstars[a][j as usize] * v)
                    .sum();
                let mb: f64 = feats
                    .iter()
                    .map(|&(j, v)| wstars[b][j as usize] * v)
                    .sum();
                ma.total_cmp(&mb)
            })
            .unwrap();
        rows.push(
            feats
                .iter()
                .map(|&(j, v)| crate::data::Entry { index: j, value: v })
                .collect::<Vec<_>>(),
        );
        labels.push(label);
    }
    let mut x = CsrMatrix::from_rows(&rows, d);
    x.normalize_rows_to_unit_max();
    MulticlassDataset::new(x, labels, k, format!("synthetic-{k}class"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::Hinge;

    fn data() -> MulticlassDataset {
        synthetic_multiclass(600, 80, 4, 12.0, 11)
    }

    #[test]
    fn generator_produces_all_classes() {
        let ds = data();
        for k in 0..4 {
            let c = ds.labels.iter().filter(|&&l| l == k).count();
            assert!(c > 30, "class {k} nearly empty: {c}");
        }
    }

    #[test]
    fn ovr_view_folds_correctly() {
        let ds = data();
        let v = ds.ovr_view(1);
        assert_eq!(v.n(), ds.n());
        let pos = v.y.iter().filter(|&&y| y > 0.0).count();
        let want = ds.labels.iter().filter(|&&l| l == 1).count();
        assert_eq!(pos, want);
    }

    #[test]
    fn ovr_training_beats_chance_by_far() {
        let ds = data();
        let loss = Hinge::new(1.0);
        let opts = SolveOptions {
            threads: 2,
            epochs: 20,
            eval_every: 1,
            ..Default::default()
        };
        let (model, results) =
            OvrModel::train(&ds, &loss, MemoryModel::Wild, &opts);
        assert_eq!(model.w.len(), 4);
        assert_eq!(results.len(), 4);
        let acc = model.accuracy(&ds);
        assert!(acc > 0.7, "multiclass accuracy {acc} (chance = 0.25)");
    }

    #[test]
    fn predict_row_is_argmax() {
        let model = OvrModel {
            w: vec![vec![1.0, 0.0], vec![0.0, 2.0], vec![-1.0, -1.0]],
        };
        assert_eq!(model.predict_row(&[0], &[1.0]), 0);
        assert_eq!(model.predict_row(&[1], &[1.0]), 1);
        assert_eq!(model.predict_row(&[0, 1], &[-1.0, -1.0]), 2);
    }

    #[test]
    #[should_panic(expected = "label out of range")]
    fn rejects_bad_labels() {
        let x = CsrMatrix::from_rows(&[vec![]], 1);
        MulticlassDataset::new(x, vec![5], 3, "bad");
    }
}
