//! The unified solver API: one [`Solver`] trait over the whole family
//! (serial DCD, PASSCoDe-Lock/Atomic/Wild, CoCoA, AsySCD, Pegasos) and a
//! resumable [`TrainSession`] that makes warm starts, deadline-bounded
//! retraining, and checkpoint/restore uniform instead of Passcode-only.
//!
//! The paper frames all of these as one family — "each thread repeatedly
//! selects a random dual variable and conducts coordinate updates" — and
//! this module is that framing as an API:
//!
//! * [`SolverKind`] + the single name table behind
//!   [`SolverKind::parse`] / [`lookup`] / [`solver_names`] — the CLI,
//!   `RunConfig::set`, and the registry all share it;
//! * [`Solver::session`] erases the `L: Loss` generic (enum dispatch via
//!   [`crate::loss::DynLoss`]) so a `Box<dyn Solver>` replaces per-call
//!   `match` dispatch blocks;
//! * [`TrainSession`] owns `(α, ŵ, epoch counter, phases)` and exposes
//!   [`TrainSession::run_epochs`], [`TrainSession::run_until`]
//!   (deadline / tolerance / update-budget), [`TrainSession::snapshot`]
//!   and [`TrainSession::resume`].
//!
//! **Determinism contract:** epoch `e` of a session always runs with the
//! same derived RNG stream regardless of how the run was chunked, so
//! `run k epochs → snapshot → resume → run to n` is bit-for-bit identical
//! to an uninterrupted `n`-epoch run for deterministic (single-worker)
//! solvers, and equal up to racy-float noise for the parallel ones.
//! Sessions rendezvous at every epoch boundary (each epoch is one
//! warm-started call into the solver core); the inherent `solve` entry
//! points remain for barrier-free free-running.

use std::time::Instant;

use anyhow::{bail, ensure, Result};

use crate::baselines::{Asyscd, Cocoa, Pegasos};
use crate::data::Dataset;
use crate::eval;
use crate::loss::{DynLoss, Loss, LossKind};
use crate::util::{Json, Phases, SharedVec, SplitMix64, Timer};

use super::dcd::SerialDcd;
use super::passcode::{MemoryModel, Passcode};
use super::shrinking::ShrinkState;
use super::{SolveOptions, SolveResult};

/// Which algorithm to run — the registry's key type.  The name table
/// behind [`SolverKind::parse`] / [`SolverKind::name`] is the single
/// source of solver names for the CLI, configs, and the registry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SolverKind {
    /// Serial DCD (Algorithm 1), shrinking off.
    Dcd,
    /// Serial DCD with shrinking = the paper's LIBLINEAR baseline.
    Liblinear,
    /// PASSCoDe with the given memory model.
    Passcode(MemoryModel),
    /// CoCoA (β_K = 1, local DCD).
    Cocoa,
    /// AsySCD (γ = 1/2, dense Q).
    Asyscd,
    /// Pegasos primal SGD.
    Pegasos,
}

/// The one solver name table (`--solver <name>`, `RunConfig::set`, and
/// [`lookup`] all resolve through it).
const NAME_TABLE: &[(&str, SolverKind)] = &[
    ("dcd", SolverKind::Dcd),
    ("liblinear", SolverKind::Liblinear),
    ("passcode-lock", SolverKind::Passcode(MemoryModel::Lock)),
    ("passcode-atomic", SolverKind::Passcode(MemoryModel::Atomic)),
    ("passcode-wild", SolverKind::Passcode(MemoryModel::Wild)),
    ("cocoa", SolverKind::Cocoa),
    ("asyscd", SolverKind::Asyscd),
    ("pegasos", SolverKind::Pegasos),
];

impl SolverKind {
    /// Parse a solver name; unknown names list the valid ones.
    pub fn parse(s: &str) -> Result<SolverKind> {
        for (name, kind) in NAME_TABLE {
            if *name == s {
                return Ok(*kind);
            }
        }
        bail!(
            "unknown solver {s:?}; valid solvers: {}",
            solver_names().join(", ")
        )
    }

    /// Registry name (what configs/logs print and `parse` accepts).
    pub fn name(&self) -> &'static str {
        NAME_TABLE
            .iter()
            .find(|(_, k)| k == self)
            .map(|(n, _)| *n)
            .expect("every SolverKind appears in NAME_TABLE")
    }

    /// All kinds, in registry order.
    pub fn all() -> impl Iterator<Item = SolverKind> {
        NAME_TABLE.iter().map(|(_, k)| *k)
    }

    /// Whether the solver runs single-threaded regardless of
    /// `SolveOptions::threads` (drives thread-count defaults in the
    /// experiment harness).
    pub fn is_serial(&self) -> bool {
        matches!(
            self,
            SolverKind::Dcd | SolverKind::Liblinear | SolverKind::Pegasos
        )
    }

    /// Build the registry entry for this kind.
    pub fn instantiate(&self) -> Box<dyn Solver> {
        match self {
            SolverKind::Dcd => Box::new(SerialDcd),
            SolverKind::Liblinear => Box::new(Liblinear),
            SolverKind::Passcode(m) => Box::new(PasscodeSolver(*m)),
            SolverKind::Cocoa => Box::new(Cocoa),
            SolverKind::Asyscd => Box::new(Asyscd::default()),
            SolverKind::Pegasos => Box::new(Pegasos::default()),
        }
    }
}

/// Every registry solver name, in table order.
pub fn solver_names() -> Vec<&'static str> {
    NAME_TABLE.iter().map(|(n, _)| *n).collect()
}

/// Look a solver up by registry name; unknown names error listing the
/// valid ones.
pub fn lookup(name: &str) -> Result<Box<dyn Solver>> {
    Ok(SolverKind::parse(name)?.instantiate())
}

/// A training algorithm as a first-class object.  Object-safe, so a
/// `Box<dyn Solver>` registry replaces the per-call-site `match
/// cfg.solver` dispatch the driver, tuner, benches, and serving path
/// used to hand-roll.
pub trait Solver: Send + Sync {
    /// The [`SolverKind`] this entry dispatches to.
    fn kind(&self) -> SolverKind;

    /// Registry name (the `--solver <name>` string).
    fn name(&self) -> &'static str {
        self.kind().name()
    }

    /// Open a resumable training session on `ds` optimizing `loss` with
    /// penalty `c`.  Fails fast on unsupported combinations (Pegasos ×
    /// non-hinge losses, AsySCD × problems whose dense `Q` exceeds the
    /// memory budget) instead of erroring mid-run.
    fn session<'a>(
        &self,
        ds: &'a Dataset,
        loss: LossKind,
        c: f64,
        opts: SolveOptions,
    ) -> Result<TrainSession<'a>>;
}

impl Solver for SerialDcd {
    fn kind(&self) -> SolverKind {
        SolverKind::Dcd
    }

    fn session<'a>(
        &self,
        ds: &'a Dataset,
        loss: LossKind,
        c: f64,
        opts: SolveOptions,
    ) -> Result<TrainSession<'a>> {
        TrainSession::new(
            ds,
            SolverKind::Dcd,
            Backend::Serial { shrink: None },
            loss,
            c,
            opts,
        )
    }
}

/// Serial DCD with the shrinking heuristic forced on — the paper's
/// LIBLINEAR baseline as a registry entry.
pub struct Liblinear;

impl Solver for Liblinear {
    fn kind(&self) -> SolverKind {
        SolverKind::Liblinear
    }

    fn session<'a>(
        &self,
        ds: &'a Dataset,
        loss: LossKind,
        c: f64,
        mut opts: SolveOptions,
    ) -> Result<TrainSession<'a>> {
        opts.shrinking = true;
        TrainSession::new(
            ds,
            SolverKind::Liblinear,
            Backend::Serial { shrink: None },
            loss,
            c,
            opts,
        )
    }
}

/// PASSCoDe as a registry entry: the memory model is part of the solver
/// identity (`passcode-lock` / `passcode-atomic` / `passcode-wild`).
pub struct PasscodeSolver(
    /// Which mechanism guards the shared-`w` writes.
    pub MemoryModel,
);

impl Solver for PasscodeSolver {
    fn kind(&self) -> SolverKind {
        SolverKind::Passcode(self.0)
    }

    fn session<'a>(
        &self,
        ds: &'a Dataset,
        loss: LossKind,
        c: f64,
        opts: SolveOptions,
    ) -> Result<TrainSession<'a>> {
        TrainSession::new(
            ds,
            self.kind(),
            Backend::Passcode { model: self.0, shared: None },
            loss,
            c,
            opts,
        )
    }
}

impl Solver for Cocoa {
    fn kind(&self) -> SolverKind {
        SolverKind::Cocoa
    }

    fn session<'a>(
        &self,
        ds: &'a Dataset,
        loss: LossKind,
        c: f64,
        opts: SolveOptions,
    ) -> Result<TrainSession<'a>> {
        TrainSession::new(ds, SolverKind::Cocoa, Backend::Cocoa, loss, c, opts)
    }
}

impl Solver for Asyscd {
    fn kind(&self) -> SolverKind {
        SolverKind::Asyscd
    }

    fn session<'a>(
        &self,
        ds: &'a Dataset,
        loss: LossKind,
        c: f64,
        opts: SolveOptions,
    ) -> Result<TrainSession<'a>> {
        // Fail the dense-Q memory guard at session-open time; the O(n·nnz)
        // Gram formation itself is deferred to the first epoch and cached
        // for the session's lifetime.
        self.check_budget(ds.n())?;
        TrainSession::new(
            ds,
            SolverKind::Asyscd,
            Backend::Asyscd { cfg: self.clone(), gram: None },
            loss,
            c,
            opts,
        )
    }
}

impl Solver for Pegasos {
    fn kind(&self) -> SolverKind {
        SolverKind::Pegasos
    }

    fn session<'a>(
        &self,
        ds: &'a Dataset,
        loss: LossKind,
        c: f64,
        opts: SolveOptions,
    ) -> Result<TrainSession<'a>> {
        ensure!(
            loss == LossKind::Hinge,
            "Pegasos baseline supports hinge loss only (got {})",
            loss.name()
        );
        TrainSession::new(
            ds,
            SolverKind::Pegasos,
            Backend::Pegasos { project_ball: self.project_ball },
            loss,
            c,
            opts,
        )
    }
}

/// Per-solver session state (cached cross-epoch artifacts live here).
enum Backend {
    Serial {
        /// Persistent shrinking state (created lazily when
        /// `SolveOptions::shrinking` is on): the heuristic's active set
        /// and PG bounds must survive across 1-epoch calls, or a fresh
        /// per-epoch state (bounds at ±∞) could never skip anything.
        shrink: Option<ShrinkState>,
    },
    Passcode {
        model: MemoryModel,
        /// Session-lifetime shared `(α, ŵ)` buffers.  Created from the
        /// session state on the first epoch and reused afterwards, which
        /// removes the four O(n+d) buffer allocations/copies the old
        /// `solve_warm`-per-epoch path paid (each epoch still re-derives
        /// its partition and re-spawns workers — that is what makes the
        /// per-epoch RNG streams chunking-independent).  Invalidated by
        /// `resume` (the checkpoint's state is re-imported on the next
        /// epoch).  Note: the per-thread shrinking heuristic is only
        /// effective on multi-epoch free-running calls — 1-epoch session
        /// calls re-warm its PG bounds each time, so `shrinking` on a
        /// Passcode session adds gradient checks without ever skipping.
        shared: Option<(SharedVec, SharedVec)>,
    },
    Cocoa,
    Asyscd {
        cfg: Asyscd,
        /// Dense Gram matrix, formed on the first epoch and reused.
        gram: Option<Vec<f64>>,
    },
    Pegasos {
        project_ball: bool,
    },
}

/// Stop condition for [`TrainSession::run_until`].  Every condition is
/// checked at epoch boundaries only — an epoch in flight always finishes
/// (the family's unit of work is one pass over the coordinates).
#[derive(Debug, Clone, Copy)]
pub enum StopWhen {
    /// Stop at the wall-clock deadline (checked *before* each epoch, so
    /// a deadline already in the past runs zero epochs).
    Deadline(Instant),
    /// Stop once the duality gap drops to `tol` (absolute; evaluated
    /// after each epoch at the cost of one pass over the data).
    Tolerance(f64),
    /// Stop once this many additional coordinate updates have been spent.
    Budget(u64),
}

/// Why a [`TrainSession::run_until`] / [`TrainSession::run_epochs`] call
/// returned.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopReason {
    /// The call's epoch budget (`SolveOptions::epochs` for `run_until`,
    /// `k` for `run_epochs`) was exhausted without the condition firing.
    Completed,
    /// The wall-clock deadline passed.
    DeadlineReached,
    /// The duality-gap tolerance was met.
    ToleranceReached,
    /// The update budget was spent.
    BudgetExhausted,
}

/// What one `run_*` call did.
#[derive(Debug, Clone)]
pub struct RunReport {
    /// Epochs completed by this call.
    pub epochs_run: usize,
    /// Coordinate updates performed by this call.
    pub updates: u64,
    /// Why the call stopped.
    pub stopped: StopReason,
}

/// Cross-epoch state of the shrinking heuristic, captured so a resumed
/// liblinear session continues with exactly the active set and PG bounds
/// an uninterrupted run would have.
#[derive(Debug, Clone, PartialEq)]
pub struct ShrinkCheckpoint {
    /// Active-set membership per coordinate.
    pub active: Vec<bool>,
    /// Previous epoch's max projected gradient `M̄` (may be `+∞`).
    pub pg_max_old: f64,
    /// Previous epoch's min projected gradient `m̄` (may be `−∞`).
    pub pg_min_old: f64,
}

/// Serializable training state: everything a [`TrainSession::resume`]
/// needs to continue a run, on this process or after a round trip
/// through `coordinator::model_io::save_checkpoint`.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    /// Registry name of the solver that produced it.
    pub solver: String,
    /// Canonical loss name.
    pub loss: String,
    /// Penalty parameter.
    pub c: f64,
    /// Base RNG seed of the session (adopted by `resume` so the derived
    /// per-epoch streams continue exactly).
    pub seed: u64,
    /// Epochs completed when the snapshot was taken.
    pub epochs_done: usize,
    /// Coordinate updates performed so far.
    pub updates: u64,
    /// Dual iterate.
    pub alpha: Vec<f64>,
    /// Maintained primal vector ŵ.
    pub w_hat: Vec<f64>,
    /// Shrinking-heuristic state (`Some` only for serial sessions that
    /// ran with shrinking on and materialized it).
    pub shrink: Option<ShrinkCheckpoint>,
}

impl Checkpoint {
    /// A zeroed checkpoint (`α = 0`, `ŵ = 0`, epoch 0) — resuming from
    /// it is identical to a cold start.
    pub fn zeroed(
        solver: &str,
        loss: &str,
        c: f64,
        seed: u64,
        n: usize,
        d: usize,
    ) -> Checkpoint {
        Checkpoint {
            solver: solver.to_string(),
            loss: loss.to_string(),
            c,
            seed,
            epochs_done: 0,
            updates: 0,
            alpha: vec![0.0; n],
            w_hat: vec![0.0; d],
            shrink: None,
        }
    }

    /// Serialize (the `passcode-checkpoint-v1` schema
    /// `coordinator::model_io` persists).  `seed`/`updates` are written
    /// as decimal strings and PG bounds as f64 bit patterns: both must
    /// round-trip *exactly* (JSON numbers are f64, which would corrupt
    /// 64-bit seeds and cannot carry ±∞).
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("format", Json::str("passcode-checkpoint-v1")),
            ("solver", Json::str(&self.solver)),
            ("loss", Json::str(&self.loss)),
            ("c", Json::num(self.c)),
            ("seed", Json::str(&self.seed.to_string())),
            ("epochs_done", Json::num(self.epochs_done as f64)),
            ("updates", Json::str(&self.updates.to_string())),
            ("n", Json::num(self.alpha.len() as f64)),
            ("d", Json::num(self.w_hat.len() as f64)),
            ("alpha", Json::arr_f64(&self.alpha)),
            ("w_hat", Json::arr_f64(&self.w_hat)),
        ];
        let shrink_json;
        if let Some(s) = &self.shrink {
            shrink_json = Json::obj(vec![
                (
                    "active",
                    Json::arr_f64(
                        &s.active
                            .iter()
                            .map(|&a| if a { 1.0 } else { 0.0 })
                            .collect::<Vec<f64>>(),
                    ),
                ),
                (
                    "pg_max_old_bits",
                    Json::str(&format!("{:016x}", s.pg_max_old.to_bits())),
                ),
                (
                    "pg_min_old_bits",
                    Json::str(&format!("{:016x}", s.pg_min_old.to_bits())),
                ),
            ]);
            pairs.push(("shrink", shrink_json));
        }
        Json::obj(pairs)
    }

    /// Deserialize, validating the format tag and dimension fields.
    pub fn from_json(json: &Json) -> Result<Checkpoint> {
        ensure!(
            json.get("format")?.as_str()? == "passcode-checkpoint-v1",
            "not a passcode checkpoint file"
        );
        let alpha: Vec<f64> = json
            .get("alpha")?
            .as_arr()?
            .iter()
            .map(|v| v.as_f64())
            .collect::<Result<_>>()?;
        let w_hat: Vec<f64> = json
            .get("w_hat")?
            .as_arr()?
            .iter()
            .map(|v| v.as_f64())
            .collect::<Result<_>>()?;
        ensure!(
            alpha.len() == json.get("n")?.as_usize()?,
            "checkpoint α dimension mismatch"
        );
        ensure!(
            w_hat.len() == json.get("d")?.as_usize()?,
            "checkpoint ŵ dimension mismatch"
        );
        let shrink = match json.opt("shrink") {
            None => None,
            Some(s) => {
                let active: Vec<bool> = s
                    .get("active")?
                    .as_arr()?
                    .iter()
                    .map(|v| Ok(v.as_f64()? != 0.0))
                    .collect::<Result<_>>()?;
                Some(ShrinkCheckpoint {
                    active,
                    pg_max_old: f64_from_bits_hex(
                        s.get("pg_max_old_bits")?.as_str()?,
                    )?,
                    pg_min_old: f64_from_bits_hex(
                        s.get("pg_min_old_bits")?.as_str()?,
                    )?,
                })
            }
        };
        Ok(Checkpoint {
            solver: json.get("solver")?.as_str()?.to_string(),
            loss: json.get("loss")?.as_str()?.to_string(),
            c: json.get("c")?.as_f64()?,
            seed: json.get("seed")?.as_str()?.parse()?,
            epochs_done: json.get("epochs_done")?.as_usize()?,
            updates: json.get("updates")?.as_str()?.parse()?,
            alpha,
            w_hat,
            shrink,
        })
    }
}

/// Exact f64 decode from the 16-hex-digit bit pattern `to_json` writes.
fn f64_from_bits_hex(s: &str) -> Result<f64> {
    Ok(f64::from_bits(u64::from_str_radix(s, 16)?))
}

/// Derived per-epoch seed: epoch `e` always runs the same RNG stream no
/// matter how the surrounding run was chunked — the property that makes
/// `snapshot → resume` bit-for-bit equal to an uninterrupted run.
fn epoch_seed(seed: u64, epoch: usize) -> u64 {
    let mut sm = SplitMix64::new(
        seed ^ (epoch as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
    );
    sm.next_u64()
}

/// A resumable training session: owns `(α, ŵ)`, the epoch counter that
/// drives the per-epoch RNG streams, and the accumulated phase timings.
/// Created by [`Solver::session`]; borrow of the dataset lasts for the
/// session's lifetime.
pub struct TrainSession<'a> {
    ds: &'a Dataset,
    kind: SolverKind,
    backend: Backend,
    loss: DynLoss,
    opts: SolveOptions,
    alpha: Vec<f64>,
    w_hat: Vec<f64>,
    epochs_done: usize,
    updates: u64,
    phases: Phases,
}

impl<'a> TrainSession<'a> {
    fn new(
        ds: &'a Dataset,
        kind: SolverKind,
        backend: Backend,
        loss: LossKind,
        c: f64,
        opts: SolveOptions,
    ) -> Result<TrainSession<'a>> {
        ensure!(c > 0.0, "penalty C must be positive (got {c})");
        Ok(TrainSession {
            ds,
            kind,
            backend,
            loss: DynLoss::new(loss, c),
            opts,
            alpha: vec![0.0; ds.n()],
            w_hat: vec![0.0; ds.d()],
            epochs_done: 0,
            updates: 0,
            phases: Phases::new(),
        })
    }

    /// The solver kind driving this session.
    pub fn kind(&self) -> SolverKind {
        self.kind
    }

    /// The dual iterate after the epochs run so far.
    pub fn alpha(&self) -> &[f64] {
        &self.alpha
    }

    /// The maintained primal vector ŵ.
    pub fn w_hat(&self) -> &[f64] {
        &self.w_hat
    }

    /// Epochs completed over the session's lifetime (resume included).
    pub fn epochs(&self) -> usize {
        self.epochs_done
    }

    /// Coordinate updates performed over the session's lifetime.
    pub fn updates(&self) -> u64 {
        self.updates
    }

    /// Cumulative training seconds (excludes init work and everything
    /// the caller does between `run_*` calls — evaluation is free).
    pub fn train_secs(&self) -> f64 {
        self.phases.get("train")
    }

    /// Cumulative init seconds (row norms, partitions, Gram formation).
    pub fn init_secs(&self) -> f64 {
        self.phases.get("init")
    }

    /// Duality gap of the current iterate (one pass over the data).
    pub fn duality_gap(&self) -> f64 {
        eval::duality_gap(self.ds, &self.loss, &self.alpha)
    }

    /// Run one epoch with its derived seed, folding the result back into
    /// the session state.
    fn run_one_epoch(&mut self) -> Result<()> {
        let mut o = self.opts.clone();
        o.epochs = 1;
        o.eval_every = 0;
        o.seed = epoch_seed(self.opts.seed, self.epochs_done);
        let loss = self.loss;
        let r = match &mut self.backend {
            Backend::Serial { shrink } => {
                if o.shrinking && shrink.is_none() {
                    *shrink = Some(ShrinkState::new(
                        self.ds.n(),
                        loss.upper_bound(),
                    ));
                }
                SerialDcd::solve_from(
                    self.ds,
                    &loss,
                    &o,
                    Some((&self.alpha, &self.w_hat)),
                    shrink.as_mut(),
                    None,
                )
            }
            Backend::Passcode { model, shared } => {
                // Zero-copy epoch: the session owns shared (α, ŵ)
                // buffers for its lifetime and drives the in-place core;
                // session state is synced out (no allocation) so
                // `alpha()`/`w_hat()`/`snapshot()` stay authoritative.
                if shared.is_none() {
                    *shared = Some((
                        SharedVec::from_slice(&self.alpha),
                        SharedVec::from_slice(&self.w_hat),
                    ));
                }
                let (a_sh, w_sh) =
                    shared.as_ref().expect("shared buffers initialized");
                let (_, updates, phases) = Passcode::run_epochs_shared(
                    self.ds,
                    &loss,
                    *model,
                    &o,
                    a_sh,
                    w_sh,
                    None,
                );
                a_sh.copy_into(&mut self.alpha);
                w_sh.copy_into(&mut self.w_hat);
                self.updates += updates;
                self.epochs_done += 1;
                self.phases.add("init", phases.get("init"));
                self.phases.add("train", phases.get("train"));
                // Epoch-boundary telemetry: the empirical backward
                // error ‖ŵ − Σᵢ αᵢ xᵢ‖ / ‖ŵ‖ (Eq. 6) — Theorem 3's ε,
                // measured on the live state.  One O(nnz) pass per
                // epoch, only when probes are on, and off the
                // free-running bench path entirely.
                if crate::obs::probes_enabled() {
                    let wbar = eval::wbar_from_alpha(self.ds, &self.alpha);
                    let mut err = 0.0f64;
                    let mut norm = 0.0f64;
                    for (wh, wb) in self.w_hat.iter().zip(&wbar) {
                        err += (wh - wb) * (wh - wb);
                        norm += wh * wh;
                    }
                    let ratio = if norm > 0.0 {
                        (err / norm).sqrt()
                    } else {
                        0.0
                    };
                    let probes = crate::obs::probes::solver();
                    probes.backward_error.set(ratio);
                }
                return Ok(());
            }
            Backend::Cocoa => Cocoa::solve_from(
                self.ds,
                &loss,
                &o,
                Some((&self.alpha, &self.w_hat)),
                None,
            ),
            Backend::Asyscd { cfg, gram } => {
                if gram.is_none() {
                    let t = Timer::start();
                    *gram = Some(cfg.gram(self.ds)?);
                    self.phases.add("init", t.secs());
                }
                cfg.solve_with_gram(
                    self.ds,
                    &loss,
                    &o,
                    gram.as_ref().expect("gram formed above"),
                    Some(&self.alpha),
                    None,
                )
            }
            Backend::Pegasos { project_ball } => {
                Pegasos { project_ball: *project_ball }.solve_from(
                    self.ds,
                    &loss,
                    &o,
                    Some((
                        &self.w_hat,
                        self.epochs_done as u64 * self.ds.n() as u64,
                    )),
                    None,
                )
            }
        };
        self.alpha = r.alpha;
        self.w_hat = r.w_hat;
        self.updates += r.updates;
        self.epochs_done += 1;
        self.phases.add("init", r.phases.get("init"));
        self.phases.add("train", r.phases.get("train"));
        Ok(())
    }

    /// Run exactly `k` more epochs.
    pub fn run_epochs(&mut self, k: usize) -> Result<RunReport> {
        let before = self.updates;
        for _ in 0..k {
            self.run_one_epoch()?;
        }
        Ok(RunReport {
            epochs_run: k,
            updates: self.updates - before,
            stopped: StopReason::Completed,
        })
    }

    /// Run until `stop` fires, capped at `SolveOptions::epochs` epochs
    /// per call (the configured round length) so a stalled tolerance or
    /// a generous deadline cannot spin forever.
    pub fn run_until(&mut self, stop: StopWhen) -> Result<RunReport> {
        let max_epochs = self.opts.epochs.max(1);
        let before = self.updates;
        let mut epochs_run = 0;
        let mut stopped = StopReason::Completed;
        for _ in 0..max_epochs {
            if let StopWhen::Deadline(d) = stop {
                if Instant::now() >= d {
                    stopped = StopReason::DeadlineReached;
                    break;
                }
            }
            self.run_one_epoch()?;
            epochs_run += 1;
            match stop {
                StopWhen::Tolerance(tol) => {
                    if self.duality_gap() <= tol {
                        stopped = StopReason::ToleranceReached;
                        break;
                    }
                }
                StopWhen::Budget(b) => {
                    if self.updates - before >= b {
                        stopped = StopReason::BudgetExhausted;
                        break;
                    }
                }
                StopWhen::Deadline(_) => {}
            }
        }
        Ok(RunReport {
            epochs_run,
            updates: self.updates - before,
            stopped,
        })
    }

    /// Snapshot the full resumable state (including the shrinking
    /// heuristic's active set for serial sessions that use it).
    pub fn snapshot(&self) -> Checkpoint {
        let shrink = match &self.backend {
            Backend::Serial { shrink: Some(s) } => {
                let (active, pg_max_old, pg_min_old) = s.export();
                Some(ShrinkCheckpoint { active, pg_max_old, pg_min_old })
            }
            _ => None,
        };
        Checkpoint {
            solver: self.kind.name().to_string(),
            loss: self.loss.kind().name().to_string(),
            c: self.loss.c(),
            seed: self.opts.seed,
            epochs_done: self.epochs_done,
            updates: self.updates,
            alpha: self.alpha.clone(),
            w_hat: self.w_hat.clone(),
            shrink,
        }
    }

    /// Restore a snapshot into this session.  The checkpoint must come
    /// from the same solver, loss, penalty `C`, and dimensions; its
    /// `seed` is adopted so the derived per-epoch RNG streams — and thus
    /// the remaining epochs — replay exactly what an uninterrupted run
    /// would have executed.
    pub fn resume(&mut self, ckpt: &Checkpoint) -> Result<()> {
        ensure!(
            ckpt.solver == self.kind.name(),
            "checkpoint is from solver {:?}, session runs {:?}",
            ckpt.solver,
            self.kind.name()
        );
        ensure!(
            ckpt.loss == self.loss.kind().name(),
            "checkpoint is for loss {:?}, session optimizes {:?}",
            ckpt.loss,
            self.loss.kind().name()
        );
        ensure!(
            ckpt.c.to_bits() == self.loss.c().to_bits(),
            "checkpoint penalty C = {} != session C = {}",
            ckpt.c,
            self.loss.c()
        );
        ensure!(
            ckpt.alpha.len() == self.ds.n(),
            "checkpoint α dimension {} != dataset n {}",
            ckpt.alpha.len(),
            self.ds.n()
        );
        ensure!(
            ckpt.w_hat.len() == self.ds.d(),
            "checkpoint ŵ dimension {} != dataset d {}",
            ckpt.w_hat.len(),
            self.ds.d()
        );
        if let Some(s) = &ckpt.shrink {
            ensure!(
                s.active.len() == self.ds.n(),
                "checkpoint shrink state dimension {} != dataset n {}",
                s.active.len(),
                self.ds.n()
            );
        }
        if let Backend::Serial { shrink } = &mut self.backend {
            *shrink = ckpt.shrink.as_ref().map(|s| {
                ShrinkState::import(
                    self.loss.upper_bound(),
                    s.active.clone(),
                    s.pg_max_old,
                    s.pg_min_old,
                )
            });
        }
        if let Backend::Passcode { shared, .. } = &mut self.backend {
            // Drop the session's shared buffers: the next epoch rebuilds
            // them from the checkpoint state adopted below.
            *shared = None;
        }
        self.opts.seed = ckpt.seed;
        self.alpha = ckpt.alpha.clone();
        self.w_hat = ckpt.w_hat.clone();
        self.epochs_done = ckpt.epochs_done;
        self.updates = ckpt.updates;
        Ok(())
    }

    /// Adopt an externally supplied `(α, ŵ)` pair as the session state,
    /// keeping the epoch/update counters and the derived RNG schedule.
    ///
    /// This is the warm-start hook for the distributed tier: after a
    /// merge round a `dist/` worker overwrites its local `ŵ` with the
    /// coordinator's merged vector and its `α` with the merge-weighted
    /// dual, then keeps running epochs from there — the Hybrid-DCA
    /// outer loop.  Unlike [`resume`](Self::resume) there is no
    /// provenance to validate, only dimensions; like `resume`, any
    /// backend caches (PASSCoDe shared buffers, serial shrink sets) are
    /// dropped so the next epoch rebuilds them from the adopted state.
    pub fn adopt_state(&mut self, alpha: &[f64], w_hat: &[f64]) -> Result<()> {
        ensure!(
            alpha.len() == self.ds.n(),
            "adopted α dimension {} != dataset n {}",
            alpha.len(),
            self.ds.n()
        );
        ensure!(
            w_hat.len() == self.ds.d(),
            "adopted ŵ dimension {} != dataset d {}",
            w_hat.len(),
            self.ds.d()
        );
        if let Backend::Serial { shrink } = &mut self.backend {
            // The shrunken active set was derived from the old α; it is
            // meaningless for the adopted state.
            *shrink = None;
        }
        if let Backend::Passcode { shared, .. } = &mut self.backend {
            *shared = None;
        }
        self.alpha.copy_from_slice(alpha);
        self.w_hat.copy_from_slice(w_hat);
        Ok(())
    }

    /// Finish the session, yielding the family-standard [`SolveResult`].
    pub fn into_result(self) -> SolveResult {
        SolveResult {
            alpha: self.alpha,
            w_hat: self.w_hat,
            epochs_run: self.epochs_done,
            updates: self.updates,
            phases: self.phases,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::registry;

    fn small() -> (Dataset, f64) {
        let (tr, _, c) = registry::load("rcv1", 0.02).unwrap();
        (tr, c)
    }

    fn opts(epochs: usize) -> SolveOptions {
        SolveOptions { epochs, eval_every: 0, ..Default::default() }
    }

    #[test]
    fn name_table_roundtrips_and_lists_on_error() {
        for (name, kind) in NAME_TABLE {
            assert_eq!(SolverKind::parse(name).unwrap(), *kind);
            assert_eq!(kind.name(), *name);
            assert_eq!(kind.instantiate().name(), *name);
        }
        let err = format!("{:#}", SolverKind::parse("sgd").unwrap_err());
        for (name, _) in NAME_TABLE {
            assert!(err.contains(name), "error should list {name}: {err}");
        }
        assert_eq!(SolverKind::all().count(), NAME_TABLE.len());
    }

    #[test]
    fn run_until_deadline_in_past_runs_zero_epochs() {
        let (ds, c) = small();
        let solver = lookup("passcode-wild").unwrap();
        let mut s = solver.session(&ds, LossKind::Hinge, c, opts(50)).unwrap();
        s.run_epochs(2).unwrap();
        let alpha_before = s.alpha().to_vec();
        let r = s.run_until(StopWhen::Deadline(Instant::now())).unwrap();
        assert_eq!(r.epochs_run, 0);
        assert_eq!(r.stopped, StopReason::DeadlineReached);
        assert_eq!(s.alpha(), &alpha_before[..], "state must be untouched");
        assert_eq!(s.epochs(), 2);
    }

    #[test]
    fn run_until_budget_stops_after_one_epoch() {
        let (ds, c) = small();
        let solver = lookup("dcd").unwrap();
        let mut s = solver.session(&ds, LossKind::Hinge, c, opts(50)).unwrap();
        let r = s.run_until(StopWhen::Budget(1)).unwrap();
        assert_eq!(r.epochs_run, 1, "first epoch must overshoot the budget");
        assert_eq!(r.stopped, StopReason::BudgetExhausted);
        assert!(r.updates >= 1);
    }

    #[test]
    fn run_until_tolerance_reaches_gap() {
        let (ds, c) = small();
        let solver = lookup("dcd").unwrap();
        let mut s = solver.session(&ds, LossKind::Hinge, c, opts(60)).unwrap();
        let r = s.run_until(StopWhen::Tolerance(1e-2)).unwrap();
        assert_eq!(r.stopped, StopReason::ToleranceReached);
        assert!(s.duality_gap() <= 1e-2);
        assert!(r.epochs_run < 60, "tolerance should fire before the cap");
    }

    #[test]
    fn run_until_caps_at_configured_epochs() {
        let (ds, c) = small();
        let solver = lookup("dcd").unwrap();
        let mut s = solver.session(&ds, LossKind::Hinge, c, opts(3)).unwrap();
        let r = s.run_until(StopWhen::Tolerance(0.0)).unwrap();
        assert_eq!(r.epochs_run, 3);
        assert_eq!(r.stopped, StopReason::Completed);
    }

    #[test]
    fn checkpoint_json_roundtrip() {
        let (ds, c) = small();
        let solver = lookup("passcode-atomic").unwrap();
        let mut s = solver.session(&ds, LossKind::Hinge, c, opts(3)).unwrap();
        s.run_epochs(3).unwrap();
        let ckpt = s.snapshot();
        let back =
            Checkpoint::from_json(&Json::parse(&ckpt.to_json().to_pretty()).unwrap())
                .unwrap();
        assert_eq!(back, ckpt);
        assert_eq!(back.solver, "passcode-atomic");
        assert_eq!(back.loss, "hinge");
        assert_eq!(back.epochs_done, 3);
    }

    #[test]
    fn resume_rejects_mismatched_checkpoints() {
        let (ds, c) = small();
        let wild = lookup("passcode-wild").unwrap();
        let mut s = wild.session(&ds, LossKind::Hinge, c, opts(2)).unwrap();
        // Wrong solver.
        let ckpt =
            Checkpoint::zeroed("dcd", "hinge", c, 42, ds.n(), ds.d());
        assert!(s.resume(&ckpt).is_err());
        // Wrong dimensions.
        let ckpt = Checkpoint::zeroed(
            "passcode-wild",
            "hinge",
            c,
            42,
            ds.n() + 1,
            ds.d(),
        );
        assert!(s.resume(&ckpt).is_err());
        // Matching checkpoint resumes fine.
        let ckpt = Checkpoint::zeroed(
            "passcode-wild",
            "hinge",
            c,
            42,
            ds.n(),
            ds.d(),
        );
        s.resume(&ckpt).unwrap();
        assert_eq!(s.epochs(), 0);
    }

    #[test]
    fn session_shrinking_persists_across_epochs_and_skips_work() {
        // The heuristic only works if its state survives the per-epoch
        // session calls: a fresh ShrinkState each epoch (bounds at ±∞)
        // can never deactivate anything.
        use crate::loss::Hinge;
        let (ds, c) = small();
        let mut full =
            lookup("dcd").unwrap().session(&ds, LossKind::Hinge, c, opts(40)).unwrap();
        full.run_epochs(40).unwrap();
        let mut shr = lookup("liblinear")
            .unwrap()
            .session(&ds, LossKind::Hinge, c, opts(40))
            .unwrap();
        shr.run_epochs(40).unwrap();
        assert!(
            shr.updates() < full.updates(),
            "shrinking skipped nothing through the session path: {} vs {}",
            shr.updates(),
            full.updates()
        );
        let loss = Hinge::new(c);
        let p_full = eval::primal_objective(&ds, &loss, full.w_hat());
        let p_shr = eval::primal_objective(&ds, &loss, shr.w_hat());
        assert!(
            (p_full - p_shr).abs() < 0.01 * p_full.abs(),
            "shrinking changed the answer: {p_full} vs {p_shr}"
        );
    }

    #[test]
    fn shrink_state_rides_checkpoints_exactly() {
        let (ds, c) = small();
        let solver = lookup("liblinear").unwrap();
        let (k, n) = (6usize, 14usize);
        let mut uninterrupted =
            solver.session(&ds, LossKind::Hinge, c, opts(n)).unwrap();
        uninterrupted.run_epochs(n).unwrap();

        let mut first =
            solver.session(&ds, LossKind::Hinge, c, opts(n)).unwrap();
        first.run_epochs(k).unwrap();
        let ckpt = first.snapshot();
        assert!(
            ckpt.shrink.is_some(),
            "liblinear snapshot must carry the shrinking state"
        );
        // The shrink state (±∞ bounds included) survives JSON exactly.
        let back = Checkpoint::from_json(
            &Json::parse(&ckpt.to_json().to_pretty()).unwrap(),
        )
        .unwrap();
        assert_eq!(back, ckpt);

        let mut second =
            solver.session(&ds, LossKind::Hinge, c, opts(n)).unwrap();
        second.resume(&back).unwrap();
        second.run_epochs(n - k).unwrap();
        assert_eq!(second.alpha(), uninterrupted.alpha(), "α diverged");
        assert_eq!(second.w_hat(), uninterrupted.w_hat(), "ŵ diverged");
        assert_eq!(second.updates(), uninterrupted.updates());
    }

    #[test]
    fn resume_adopts_seed_and_rejects_foreign_c() {
        let (ds, c) = small();
        let solver = lookup("dcd").unwrap();
        // Session opened with a different seed: resume adopts the
        // checkpoint's, so the continuation still replays exactly.
        let mut a = solver.session(&ds, LossKind::Hinge, c, opts(4)).unwrap();
        a.run_epochs(2).unwrap();
        let ckpt = a.snapshot();
        let mut o = opts(4);
        o.seed = 999;
        let mut b = solver.session(&ds, LossKind::Hinge, c, o).unwrap();
        b.resume(&ckpt).unwrap();
        a.run_epochs(2).unwrap();
        b.run_epochs(2).unwrap();
        assert_eq!(a.alpha(), b.alpha(), "seed not adopted on resume");

        // A checkpoint for a different penalty C must be refused.
        let bad = Checkpoint::zeroed("dcd", "hinge", c * 2.0, 42, ds.n(), ds.d());
        let mut s = solver.session(&ds, LossKind::Hinge, c, opts(4)).unwrap();
        assert!(s.resume(&bad).is_err(), "mismatched C accepted");
    }

    #[test]
    fn adopt_state_overwrites_and_validates_dims() {
        let (ds, c) = small();
        let solver = lookup("passcode-atomic").unwrap();
        let mut s = solver.session(&ds, LossKind::Hinge, c, opts(4)).unwrap();
        s.run_epochs(1).unwrap();
        let alpha = vec![0.25; ds.n()];
        let w = vec![0.5; ds.d()];
        s.adopt_state(&alpha, &w).unwrap();
        assert_eq!(s.alpha(), &alpha[..]);
        assert_eq!(s.w_hat(), &w[..]);
        // Training continues from the adopted state without panicking
        // (shared buffers were dropped and rebuilt).
        s.run_epochs(1).unwrap();
        assert_eq!(s.epochs(), 2);
        assert!(s.adopt_state(&alpha[1..], &w).is_err(), "short α accepted");
        assert!(s.adopt_state(&alpha, &w[1..]).is_err(), "short ŵ accepted");
    }

    #[test]
    fn session_matches_inherent_serial_solver_quality() {
        // The session path (per-epoch derived seeds) must reach the same
        // objective neighbourhood as the legacy inherent path.
        use crate::loss::Hinge;
        let (ds, c) = small();
        let legacy = SerialDcd::solve(
            &ds,
            &Hinge::new(c),
            &SolveOptions { epochs: 20, ..Default::default() },
            None,
        );
        let solver = lookup("dcd").unwrap();
        let mut s =
            solver.session(&ds, LossKind::Hinge, c, opts(20)).unwrap();
        s.run_epochs(20).unwrap();
        let loss = Hinge::new(c);
        let p_legacy = eval::primal_objective(&ds, &loss, &legacy.w_hat);
        let p_session = eval::primal_objective(&ds, &loss, s.w_hat());
        assert!(
            (p_legacy - p_session).abs() < 0.03 * p_legacy.abs(),
            "session {p_session} vs legacy {p_legacy}"
        );
        assert_eq!(s.epochs(), 20);
        assert!(s.updates() > 0);
        assert!(s.train_secs() >= 0.0 && s.init_secs() >= 0.0);
    }
}
