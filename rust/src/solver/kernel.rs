//! Fused per-coordinate update kernels — the hot-path layer every solver
//! threads its inner loop through.
//!
//! One PASSCoDe coordinate update is (Algorithm 2): read `wx = x_i·ŵ`
//! from shared memory, solve the one-variable subproblem for `Δα_i`, and
//! publish `Δα_i x_i` back.  The naive shape walks the row twice with a
//! scalar gather and re-dispatches on the memory model per update.  An
//! [`UpdateKernel`] instead packages the whole pass:
//!
//! * [`UpdateKernel::update`] is the **fused** entry — acquire (Lock
//!   only), dot, solve, conditional scatter, release, one call per
//!   coordinate, with the row slices hot in L1 for the scatter that
//!   follows the dot;
//! * the dot and scatter are **4-way unrolled with independent
//!   accumulators** so the gathers pipeline instead of serializing on
//!   one FP add chain ([`crate::data::sparse::dot_sparse_unchecked`] is
//!   the same primitive the serial solvers use);
//! * the memory-model dispatch happens **once per worker thread** — the
//!   epoch loop is monomorphized over the kernel type ([`WildKernel`],
//!   [`CasKernel`], [`LockedKernel`]), not branched per update.
//!
//! Bounds checks are hoisted: kernels gather/scatter unchecked against
//! the CSR construction invariant (column indices validated `< cols` at
//! matrix build; `w.len() == cols` asserted at solve entry), re-verified
//! by `debug_assert` in test builds.
//!
//! The kernels are generic over a [`MemAccess`] backing store (and the
//! Lock kernel over a [`LockDiscipline`]), defaulting to the production
//! [`SharedVec`]/[`LockTable`].  The only other implementation is the
//! dynamic checker's instrumented twin ([`crate::chk::CheckedVec`]),
//! which records every access for happens-before race detection — so
//! `passcode check` exercises *these* kernels, not a model of them.

use crate::data::sparse;
use crate::util::SharedVec;

use super::locks::{LockDiscipline, LockTable};

/// The backing-store seam the update kernels are generic over.
///
/// [`SharedVec`] is the production implementation.  The checker's
/// [`crate::chk::CheckedVec`] twin bounds-asserts every access
/// (including the `*_unchecked` entry points, which default to the
/// checked methods and are only overridden by [`SharedVec`]) and records
/// a trace with per-thread logical clocks.
pub trait MemAccess: Sync {
    /// Number of addressable cells.
    fn len(&self) -> usize;

    /// Whether the vector has zero cells.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Relaxed read of element `j`.
    fn get(&self, j: usize) -> f64;

    /// Plain (relaxed) overwrite of element `j`.
    fn set(&self, j: usize, v: f64);

    /// Lossless concurrent add (CAS loop) — PASSCoDe-Atomic's step 3.
    fn add_atomic(&self, j: usize, delta: f64);

    /// Racy read-add-store — PASSCoDe-Wild's step 3.
    fn add_wild(&self, j: usize, delta: f64);

    /// [`MemAccess::get`] with the bounds check waived.
    ///
    /// # Safety
    /// `j` must be `< self.len()`.
    #[inline]
    unsafe fn get_unchecked(&self, j: usize) -> f64 {
        self.get(j)
    }

    /// [`MemAccess::add_atomic`] with the bounds check waived.
    ///
    /// # Safety
    /// `j` must be `< self.len()`.
    #[inline]
    unsafe fn add_atomic_unchecked(&self, j: usize, delta: f64) {
        self.add_atomic(j, delta);
    }

    /// [`MemAccess::add_wild`] with the bounds check waived.
    ///
    /// # Safety
    /// `j` must be `< self.len()`.
    #[inline]
    unsafe fn add_wild_unchecked(&self, j: usize, delta: f64) {
        self.add_wild(j, delta);
    }
}

impl MemAccess for SharedVec {
    #[inline]
    fn len(&self) -> usize {
        SharedVec::len(self)
    }

    #[inline]
    fn get(&self, j: usize) -> f64 {
        SharedVec::get(self, j)
    }

    #[inline]
    fn set(&self, j: usize, v: f64) {
        SharedVec::set(self, j, v);
    }

    #[inline]
    fn add_atomic(&self, j: usize, delta: f64) {
        SharedVec::add_atomic(self, j, delta);
    }

    #[inline]
    fn add_wild(&self, j: usize, delta: f64) {
        SharedVec::add_wild(self, j, delta);
    }

    #[inline]
    unsafe fn get_unchecked(&self, j: usize) -> f64 {
        // SAFETY: forwarded contract — the caller guarantees `j < len`.
        unsafe { SharedVec::get_unchecked(self, j) }
    }

    #[inline]
    unsafe fn add_atomic_unchecked(&self, j: usize, delta: f64) {
        // SAFETY: forwarded contract — the caller guarantees `j < len`.
        unsafe { SharedVec::add_atomic_unchecked(self, j, delta) }
    }

    #[inline]
    unsafe fn add_wild_unchecked(&self, j: usize, delta: f64) {
        // SAFETY: forwarded contract — the caller guarantees `j < len`.
        unsafe { SharedVec::add_wild_unchecked(self, j, delta) }
    }
}

// audit: hot-path begin — the fused kernels and unrolled dots below
// run once per coordinate update; nothing here may allocate.
/// A memory-model-specific fused update kernel over the shared `w`.
///
/// Implementations are `Copy` handles (a reference or two) so worker
/// loops can be monomorphized over them for free.
pub trait UpdateKernel: Copy + Send + Sync {
    /// `x_i · ŵ` (4-way unrolled gather; relaxed atomic loads).
    fn dot(&self, idx: &[u32], vals: &[f64]) -> f64;

    /// Publish `delta · x_i` into the shared `w` under this kernel's
    /// write discipline.
    fn scatter(&self, idx: &[u32], vals: &[f64], delta: f64);

    /// Pre-update hook (Lock acquires the row's feature locks here).
    #[inline]
    fn begin(&self, _idx: &[u32]) {}

    /// Post-update hook (Lock releases here).
    #[inline]
    fn end(&self, _idx: &[u32]) {}

    /// The fused per-coordinate pass: `begin → dot → solve(wx) → scatter
    /// (iff `solve` returns a delta) → end`.  Returns whether a scatter
    /// happened.  `solve` owns all solver-side bookkeeping (α read/write,
    /// shrinking skips, update counting) and returns `None` to suppress
    /// the write — either a shrink skip or a below-threshold delta.
    #[inline]
    fn update<F: FnOnce(f64) -> Option<f64>>(
        &self,
        idx: &[u32],
        vals: &[f64],
        solve: F,
    ) -> bool {
        self.begin(idx);
        let wx = self.dot(idx, vals);
        let r = solve(wx);
        if let Some(delta) = r {
            self.scatter(idx, vals, delta);
            // Telemetry clock for the τ-staleness probe: one tick per
            // completed scatter (gated no-op unless probes are on).
            crate::obs::probes::scatter_tick();
        }
        self.end(idx);
        r.is_some()
    }
}

/// 4-way unrolled sparse dot against the shared vector (relaxed loads).
///
/// Callers guarantee every index is `< w.len()` (CSR construction
/// invariant); verified by `debug_assert` in test builds.
#[inline]
fn dot_shared<M: MemAccess>(idx: &[u32], vals: &[f64], w: &M) -> f64 {
    debug_assert!(idx.iter().all(|&j| (j as usize) < w.len()));
    let mut i4 = idx.chunks_exact(4);
    let mut v4 = vals.chunks_exact(4);
    let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    for (js, vs) in (&mut i4).zip(&mut v4) {
        // SAFETY: indices validated `< cols == w.len()` at CSR build.
        unsafe {
            a0 += w.get_unchecked(js[0] as usize) * vs[0];
            a1 += w.get_unchecked(js[1] as usize) * vs[1];
            a2 += w.get_unchecked(js[2] as usize) * vs[2];
            a3 += w.get_unchecked(js[3] as usize) * vs[3];
        }
    }
    let mut acc = (a0 + a2) + (a1 + a3);
    for (j, v) in i4.remainder().iter().zip(v4.remainder()) {
        // SAFETY: as above.
        acc += unsafe { w.get_unchecked(*j as usize) } * v;
    }
    acc
}

/// PASSCoDe-Wild: racy read-add-store scatter (Theorem 3's regime).
pub struct WildKernel<'w, M: MemAccess = SharedVec> {
    w: &'w M,
}

impl<M: MemAccess> Clone for WildKernel<'_, M> {
    fn clone(&self) -> Self {
        *self
    }
}

impl<M: MemAccess> Copy for WildKernel<'_, M> {}

impl<'w, M: MemAccess> WildKernel<'w, M> {
    /// Kernel over `w`; callers must only pass CSR rows of a matrix with
    /// `cols == w.len()`.
    pub fn new(w: &'w M) -> Self {
        Self { w }
    }
}

impl<M: MemAccess> UpdateKernel for WildKernel<'_, M> {
    #[inline]
    fn dot(&self, idx: &[u32], vals: &[f64]) -> f64 {
        dot_shared(idx, vals, self.w)
    }

    #[inline]
    fn scatter(&self, idx: &[u32], vals: &[f64], delta: f64) {
        debug_assert!(idx.iter().all(|&j| (j as usize) < self.w.len()));
        let mut i4 = idx.chunks_exact(4);
        let mut v4 = vals.chunks_exact(4);
        for (js, vs) in (&mut i4).zip(&mut v4) {
            // SAFETY: indices validated `< cols == w.len()` at CSR build.
            unsafe {
                self.w.add_wild_unchecked(js[0] as usize, delta * vs[0]);
                self.w.add_wild_unchecked(js[1] as usize, delta * vs[1]);
                self.w.add_wild_unchecked(js[2] as usize, delta * vs[2]);
                self.w.add_wild_unchecked(js[3] as usize, delta * vs[3]);
            }
        }
        for (j, v) in i4.remainder().iter().zip(v4.remainder()) {
            // SAFETY: as above.
            unsafe { self.w.add_wild_unchecked(*j as usize, delta * v) };
        }
    }
}

/// PASSCoDe-Atomic: lossless CAS-loop scatter.
pub struct CasKernel<'w, M: MemAccess = SharedVec> {
    w: &'w M,
}

impl<M: MemAccess> Clone for CasKernel<'_, M> {
    fn clone(&self) -> Self {
        *self
    }
}

impl<M: MemAccess> Copy for CasKernel<'_, M> {}

impl<'w, M: MemAccess> CasKernel<'w, M> {
    /// Kernel over `w`; callers must only pass CSR rows of a matrix with
    /// `cols == w.len()`.
    pub fn new(w: &'w M) -> Self {
        Self { w }
    }
}

impl<M: MemAccess> UpdateKernel for CasKernel<'_, M> {
    #[inline]
    fn dot(&self, idx: &[u32], vals: &[f64]) -> f64 {
        dot_shared(idx, vals, self.w)
    }

    #[inline]
    fn scatter(&self, idx: &[u32], vals: &[f64], delta: f64) {
        debug_assert!(idx.iter().all(|&j| (j as usize) < self.w.len()));
        for (j, v) in idx.iter().zip(vals) {
            // SAFETY: indices validated `< cols == w.len()` at CSR build.
            unsafe { self.w.add_atomic_unchecked(*j as usize, delta * v) };
        }
    }
}

/// PASSCoDe-Lock: ordered per-feature spinlocks held across the fused
/// pass; writes are plain under the lock.
pub struct LockedKernel<'w, M: MemAccess = SharedVec, L: LockDiscipline = LockTable> {
    w: &'w M,
    locks: &'w L,
}

impl<M: MemAccess, L: LockDiscipline> Clone for LockedKernel<'_, M, L> {
    fn clone(&self) -> Self {
        *self
    }
}

impl<M: MemAccess, L: LockDiscipline> Copy for LockedKernel<'_, M, L> {}

impl<'w, M: MemAccess, L: LockDiscipline> LockedKernel<'w, M, L> {
    /// Kernel over `w` guarded by `locks` (one lock per feature;
    /// `locks.len() == w.len()`).
    pub fn new(w: &'w M, locks: &'w L) -> Self {
        assert_eq!(locks.len(), w.len(), "lock table dimension");
        Self { w, locks }
    }
}

impl<M: MemAccess, L: LockDiscipline> UpdateKernel for LockedKernel<'_, M, L> {
    #[inline]
    fn dot(&self, idx: &[u32], vals: &[f64]) -> f64 {
        dot_shared(idx, vals, self.w)
    }

    #[inline]
    fn scatter(&self, idx: &[u32], vals: &[f64], delta: f64) {
        debug_assert!(idx.iter().all(|&j| (j as usize) < self.w.len()));
        // The row's locks are held (begin/end): plain adds are race-free.
        for (j, v) in idx.iter().zip(vals) {
            // SAFETY: indices validated `< cols == w.len()` at CSR build.
            unsafe { self.w.add_wild_unchecked(*j as usize, delta * v) };
        }
    }

    #[inline]
    fn begin(&self, idx: &[u32]) {
        self.locks.acquire_sorted(idx);
    }

    #[inline]
    fn end(&self, idx: &[u32]) {
        self.locks.release(idx);
    }
}

/// 4-way unrolled scatter `w += delta * x_i` into a dense mutable vector
/// — the serial solvers' step 3 (no atomics needed).
///
/// Callers guarantee every index is `< w.len()` (CSR construction
/// invariant); verified by `debug_assert` in test builds.
#[inline]
pub fn scatter_dense(idx: &[u32], vals: &[f64], delta: f64, w: &mut [f64]) {
    debug_assert!(idx.iter().all(|&j| (j as usize) < w.len()));
    let mut i4 = idx.chunks_exact(4);
    let mut v4 = vals.chunks_exact(4);
    for (js, vs) in (&mut i4).zip(&mut v4) {
        // SAFETY: indices validated `< cols == w.len()` at CSR build;
        // indices within a row are distinct (strictly increasing), so
        // the four writes never alias.
        unsafe {
            *w.get_unchecked_mut(js[0] as usize) += delta * vs[0];
            *w.get_unchecked_mut(js[1] as usize) += delta * vs[1];
            *w.get_unchecked_mut(js[2] as usize) += delta * vs[2];
            *w.get_unchecked_mut(js[3] as usize) += delta * vs[3];
        }
    }
    for (j, v) in i4.remainder().iter().zip(v4.remainder()) {
        // SAFETY: as above.
        unsafe { *w.get_unchecked_mut(*j as usize) += delta * v };
    }
}

/// 4-way unrolled dense·shared dot — AsySCD's O(n) gradient scan
/// `(Qα)_i` over the shared dual iterate.
pub fn dot_dense_shared<M: MemAccess>(q_row: &[f64], a: &M) -> f64 {
    assert_eq!(q_row.len(), a.len());
    let mut c4 = q_row.chunks_exact(4);
    let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    let mut k = 0usize;
    for qs in &mut c4 {
        // SAFETY: `k + 3 < q_row.len() == a.len()` within exact chunks.
        unsafe {
            a0 += qs[0] * a.get_unchecked(k);
            a1 += qs[1] * a.get_unchecked(k + 1);
            a2 += qs[2] * a.get_unchecked(k + 2);
            a3 += qs[3] * a.get_unchecked(k + 3);
        }
        k += 4;
    }
    let mut acc = (a0 + a2) + (a1 + a3);
    for q in c4.remainder() {
        // SAFETY: `k < a.len()` — the remainder finishes the row.
        acc += q * unsafe { a.get_unchecked(k) };
        k += 1;
    }
    acc
}
// audit: hot-path end

/// Re-export of the checked serving-side dot (unknown features score 0),
/// so kernel users need a single import path.
pub use crate::data::sparse::dot_sparse_checked;

/// Re-export of the unchecked unrolled sparse·dense dot (the serial
/// solvers' gather primitive).
pub use sparse::dot_sparse_unchecked;

#[cfg(test)]
mod tests {
    use super::*;

    fn scalar_dot(idx: &[u32], vals: &[f64], w: &[f64]) -> f64 {
        idx.iter().zip(vals).map(|(j, v)| w[*j as usize] * v).sum()
    }

    fn row(n: usize) -> (Vec<u32>, Vec<f64>) {
        (
            (0..n as u32).map(|k| k * 3).collect(),
            (0..n).map(|k| 0.25 * (k as f64 + 1.0)).collect(),
        )
    }

    #[test]
    fn shared_dot_matches_scalar_across_lengths() {
        let w_plain: Vec<f64> = (0..40).map(|k| (k as f64) - 11.0).collect();
        let w = SharedVec::from_slice(&w_plain);
        for n in 0..12 {
            let (idx, vals) = row(n);
            let want = scalar_dot(&idx, &vals, &w_plain);
            let got = dot_shared(&idx, &vals, &w);
            assert!((got - want).abs() < 1e-12, "n={n}: {got} vs {want}");
        }
    }

    #[test]
    fn every_kernel_scatters_the_same_delta() {
        let locks = LockTable::new(40);
        for n in [0usize, 1, 3, 4, 5, 8, 11] {
            let (idx, vals) = row(n);
            let base: Vec<f64> = (0..40).map(|k| 0.5 * k as f64).collect();
            let mut want = base.clone();
            scatter_dense(&idx, &vals, 2.0, &mut want);

            let wild = SharedVec::from_slice(&base);
            WildKernel::new(&wild).scatter(&idx, &vals, 2.0);
            assert_eq!(wild.to_vec(), want, "wild n={n}");

            let cas = SharedVec::from_slice(&base);
            CasKernel::new(&cas).scatter(&idx, &vals, 2.0);
            assert_eq!(cas.to_vec(), want, "cas n={n}");

            let locked = SharedVec::from_slice(&base);
            let k = LockedKernel::new(&locked, &locks);
            k.begin(&idx);
            k.scatter(&idx, &vals, 2.0);
            k.end(&idx);
            assert_eq!(locked.to_vec(), want, "locked n={n}");
        }
    }

    #[test]
    fn fused_update_skips_scatter_when_solve_declines() {
        let w = SharedVec::from_slice(&[1.0, 2.0, 3.0]);
        let k = WildKernel::new(&w);
        let mut seen_wx = f64::NAN;
        let wrote = k.update(&[0, 2], &[1.0, 1.0], |wx| {
            seen_wx = wx;
            None
        });
        assert!(!wrote);
        assert_eq!(seen_wx, 4.0);
        assert_eq!(w.to_vec(), vec![1.0, 2.0, 3.0]);

        let wrote = k.update(&[0, 2], &[1.0, 1.0], Some);
        assert!(wrote);
        assert_eq!(w.to_vec(), vec![5.0, 2.0, 7.0]);
    }

    #[test]
    fn locked_kernel_releases_after_update() {
        let w = SharedVec::zeros(8);
        let locks = LockTable::new(8);
        let k = LockedKernel::new(&w, &locks);
        k.update(&[1, 5], &[1.0, 1.0], |_| Some(1.0));
        assert!(!locks.is_held(1) && !locks.is_held(5));
        k.update(&[1, 5], &[1.0, 1.0], |_| None);
        assert!(!locks.is_held(1) && !locks.is_held(5));
        assert_eq!(w.get(1), 1.0);
    }

    #[test]
    fn dense_shared_dot_matches_scalar() {
        for n in [0usize, 1, 4, 7, 9] {
            let q: Vec<f64> = (0..n).map(|k| (k as f64) - 2.0).collect();
            let a_plain: Vec<f64> = (0..n).map(|k| 0.5 * k as f64).collect();
            let a = SharedVec::from_slice(&a_plain);
            let want: f64 = q.iter().zip(&a_plain).map(|(x, y)| x * y).sum();
            let got = dot_dense_shared(&q, &a);
            assert!((got - want).abs() < 1e-12, "n={n}");
        }
    }
}
