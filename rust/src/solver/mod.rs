//! Solver core: the serial DCD baseline (Algorithm 1) and the PASSCoDe
//! family (Algorithm 2) with its three memory models.
//!
//! Common vocabulary:
//! * an **epoch** is `n` coordinate updates (one pass, in expectation,
//!   over the dual variables) — the paper's "iteration" in the figures;
//! * solvers maintain the primal vector `w = Σ_i α_i x_i` incrementally
//!   (the O(nnz/n)-per-update trick that makes DCD fast);
//! * the returned [`SolveResult`] carries both the *maintained* `ŵ` and
//!   the dual iterate `α` — for PASSCoDe-Wild these disagree (Eq. 6) and
//!   the caller chooses which one to predict with (Table 2);
//! * every solver in the family (and the `baselines`) sits behind the
//!   [`api::Solver`] trait — [`lookup`] a registry name, open a
//!   [`TrainSession`], and drive it with epoch-granular control,
//!   deadlines, and checkpoint/restore.  The inherent `solve` fns remain
//!   as thin cold-start shims over the same cores;
//! * inner loops run through the fused, unrolled update kernels of
//!   [`kernel`] (one `dot → solve → scatter` pass per coordinate,
//!   memory-model dispatch hoisted to one decision per worker thread).

pub mod api;
pub mod dcd;
pub mod kernel;
pub mod locks;
pub mod multiclass;
pub mod passcode;
pub mod shrinking;

pub use api::{
    lookup, solver_names, Checkpoint, Liblinear, PasscodeSolver, RunReport,
    ShrinkCheckpoint, Solver, SolverKind, StopReason, StopWhen, TrainSession,
};
pub use dcd::SerialDcd;
pub use kernel::{MemAccess, UpdateKernel};
pub use locks::{LockDiscipline, LockTable};
pub use multiclass::{MulticlassDataset, OvrModel};
pub use passcode::{MemoryModel, Passcode};

use crate::util::Phases;

/// How coordinates are picked within an epoch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sampling {
    /// Fresh random permutation per epoch (LIBLINEAR's scheme; paper §3.3:
    /// every coordinate visited exactly once per epoch).
    Permutation,
    /// I.i.d. uniform sampling with replacement (the scheme analysed in
    /// the theory sections).
    WithReplacement,
}

/// Options shared by all solvers.
#[derive(Debug, Clone)]
pub struct SolveOptions {
    /// Number of epochs (n updates each).
    pub epochs: usize,
    /// RNG seed; every run is reproducible.
    pub seed: u64,
    /// Shrinking heuristic (serial DCD / per-thread active sets).
    pub shrinking: bool,
    /// Coordinate selection scheme.
    pub sampling: Sampling,
    /// Worker threads (ignored by serial solvers).
    pub threads: usize,
    /// Pin worker threads to cores (paper §3.3 Thread Affinity).
    pub pin_threads: bool,
    /// Invoke the progress callback every `eval_every` epochs (0 = never;
    /// parallel solvers then free-run with no epoch barriers at all).
    pub eval_every: usize,
}

impl Default for SolveOptions {
    fn default() -> Self {
        Self {
            epochs: 10,
            seed: 42,
            shrinking: false,
            sampling: Sampling::Permutation,
            threads: 1,
            pin_threads: false,
            eval_every: 0,
        }
    }
}

/// Snapshot handed to the progress callback at epoch boundaries.
#[derive(Debug)]
pub struct Progress<'a> {
    /// Epochs completed so far.
    pub epoch: usize,
    /// Dual iterate (projected view may be needed by the caller).
    pub alpha: &'a [f64],
    /// Maintained primal vector ŵ.
    pub w: &'a [f64],
    /// Seconds of training so far (excludes init).
    pub train_secs: f64,
}

/// Progress callback: return `false` to stop early.  `Send` because the
/// parallel solvers invoke it from the leader worker thread.
pub type ProgressFn<'a> = dyn FnMut(&Progress<'_>) -> bool + Send + 'a;

/// What a solver hands back.
#[derive(Debug, Clone)]
pub struct SolveResult {
    /// Final dual iterate.
    pub alpha: Vec<f64>,
    /// Final *maintained* primal vector ŵ (may violate Eq. 3 for Wild).
    pub w_hat: Vec<f64>,
    /// Epochs actually run (early stop may cut this short).
    pub epochs_run: usize,
    /// Total coordinate updates performed.
    pub updates: u64,
    /// Phase timings: "init" (norms, permutation setup — the paper counts
    /// this in end-to-end time but not in speedup) and "train".
    pub phases: Phases,
}

impl SolveResult {
    pub fn init_secs(&self) -> f64 {
        self.phases.get("init")
    }

    pub fn train_secs(&self) -> f64 {
        self.phases.get("train")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_options_are_sane() {
        let o = SolveOptions::default();
        assert_eq!(o.epochs, 10);
        assert_eq!(o.threads, 1);
        assert!(!o.shrinking);
        assert_eq!(o.sampling, Sampling::Permutation);
    }
}
