//! Serial stochastic dual coordinate descent — Algorithm 1 of the paper,
//! i.e. LIBLINEAR's dual solver (Hsieh et al., 2008).
//!
//! With `shrinking: true` this *is* the paper's "LIBLINEAR" baseline;
//! with `shrinking: false` it is the "DCD" baseline used as the serial
//! reference in the speedup plots (§5.3, point 2).

use crate::data::Dataset;
use crate::loss::{Loss, MIN_DELTA};
use crate::util::{Pcg32, Phases, Timer};

use super::kernel;
use super::shrinking::ShrinkState;
use super::{Progress, ProgressFn, Sampling, SolveOptions, SolveResult};

/// Serial DCD solver.
pub struct SerialDcd;

impl SerialDcd {
    /// Run Algorithm 1 cold-started from `α = 0`, `w = 0`.  `on_progress`
    /// fires every `opts.eval_every` epochs (if nonzero) and may stop the
    /// run by returning `false`.
    ///
    /// Thin shim over [`SerialDcd::solve_from`]; new code that wants
    /// epoch-granular control, deadlines, or checkpoint/restore should go
    /// through the [`crate::solver::Solver`] registry instead.
    pub fn solve<L: Loss>(
        ds: &Dataset,
        loss: &L,
        opts: &SolveOptions,
        on_progress: Option<&mut ProgressFn<'_>>,
    ) -> SolveResult {
        Self::solve_from(ds, loss, opts, None, None, on_progress)
    }

    /// Run Algorithm 1, optionally warm-started from an `(α₀, ŵ₀)` pair —
    /// the resumable core that [`crate::solver::TrainSession`] drives one
    /// epoch at a time.  The caller is responsible for `ŵ₀ = Σ α₀_i x_i`
    /// if the primal/dual pairing is to stay exact.
    ///
    /// `shrink` optionally supplies a *persistent* [`ShrinkState`] so the
    /// shrinking heuristic's active set and PG bounds survive across
    /// 1-epoch session calls (a fresh state per epoch can never shrink:
    /// its bounds start at ±∞).  `None` uses a run-local state — the
    /// right thing for a single multi-epoch call.
    pub fn solve_from<L: Loss>(
        ds: &Dataset,
        loss: &L,
        opts: &SolveOptions,
        warm: Option<(&[f64], &[f64])>,
        shrink: Option<&mut ShrinkState>,
        mut on_progress: Option<&mut ProgressFn<'_>>,
    ) -> SolveResult {
        let n = ds.n();
        let d = ds.d();
        let mut phases = Phases::new();

        // ---- init: row norms (memoized; one pass on first use, §5.2) --
        let init_t = Timer::start();
        let qii = ds.x.row_sqnorms_cached();
        let (mut alpha, mut w) = match warm {
            Some((a0, w0)) => {
                assert_eq!(a0.len(), n, "warm-start α dimension");
                assert_eq!(w0.len(), d, "warm-start w dimension");
                (a0.to_vec(), w0.to_vec())
            }
            None => (vec![0.0f64; n], vec![0.0f64; d]),
        };
        let mut rng = Pcg32::new(opts.seed, 0);
        // Reusable per-epoch visit-order buffers: `order` for the plain
        // samplers, `active_buf` for the shrinking active set — steady-
        // state epochs do zero heap allocation.
        let mut order: Vec<usize> = (0..n).collect();
        let mut active_buf: Vec<usize> = Vec::new();
        let mut local_shrink;
        let shrink: &mut ShrinkState = match shrink {
            Some(s) => s,
            None => {
                local_shrink = ShrinkState::new(n, loss.upper_bound());
                &mut local_shrink
            }
        };
        phases.add("init", init_t.secs());

        // ---- main loop -------------------------------------------------
        let train_t = Timer::start();
        let mut updates: u64 = 0;
        let mut epochs_run = 0;
        'outer: for epoch in 0..opts.epochs {
            let visit: &[usize] = if opts.shrinking {
                // permute the active set each epoch too
                shrink.active_indices_into(&mut active_buf);
                rng.shuffle(&mut active_buf);
                &active_buf
            } else {
                match opts.sampling {
                    Sampling::Permutation => rng.shuffle(&mut order),
                    Sampling::WithReplacement => {
                        for slot in order.iter_mut() {
                            *slot = rng.gen_range(n);
                        }
                    }
                }
                &order
            };

            // audit: hot-path begin — serial reference epoch loop:
            // buffers were allocated in init, none may appear here.
            shrink.begin_epoch();
            for &i in visit {
                let q = qii[i];
                if q <= 0.0 {
                    continue; // empty row
                }
                // Fused per-coordinate pass: one unrolled gather for the
                // dot, one unrolled scatter for the publish, row slices
                // hot in L1 in between.
                let wx = ds.x.row_dot_dense(i, &w);
                if opts.shrinking {
                    let g = loss.dual_gradient(alpha[i], wx);
                    if shrink.should_skip(i, alpha[i], g) {
                        continue;
                    }
                }
                let a_new = loss.solve_subproblem(alpha[i], wx, q);
                let delta = a_new - alpha[i];
                updates += 1;
                if delta.abs() > MIN_DELTA {
                    alpha[i] = a_new;
                    let (idx, vals) = ds.x.row(i);
                    kernel::scatter_dense(idx, vals, delta, &mut w);
                }
            }
            shrink.end_epoch();
            // audit: hot-path end
            epochs_run = epoch + 1;

            if opts.eval_every > 0 && (epoch + 1) % opts.eval_every == 0 {
                if let Some(cb) = on_progress.as_deref_mut() {
                    let p = Progress {
                        epoch: epoch + 1,
                        alpha: &alpha,
                        w: &w,
                        train_secs: train_t.secs(),
                    };
                    if !cb(&p) {
                        break 'outer;
                    }
                }
            }
        }
        phases.add("train", train_t.secs());

        SolveResult { alpha, w_hat: w, epochs_run, updates, phases }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::registry;
    use crate::eval;
    use crate::loss::{Hinge, Logistic, SquaredHinge};

    fn small() -> (Dataset, f64) {
        let (tr, _, c) = registry::load("rcv1", 0.02).unwrap();
        (tr, c)
    }

    #[test]
    fn hinge_converges_to_small_gap() {
        let (ds, c) = small();
        let loss = Hinge::new(c);
        let opts = SolveOptions { epochs: 30, ..Default::default() };
        let r = SerialDcd::solve(&ds, &loss, &opts, None);
        let gap = eval::duality_gap(&ds, &loss, &r.alpha);
        let p = eval::primal_objective(&ds, &loss, &r.w_hat);
        assert!(
            gap < 1e-3 * p.abs().max(1.0),
            "gap {gap} too large (P = {p})"
        );
    }

    #[test]
    fn maintained_w_matches_wbar_serially() {
        // In the serial algorithm Eq. 3 holds exactly (up to float error).
        let (ds, c) = small();
        let loss = Hinge::new(c);
        let opts = SolveOptions { epochs: 5, ..Default::default() };
        let r = SerialDcd::solve(&ds, &loss, &opts, None);
        let wbar = eval::wbar_from_alpha(&ds, &r.alpha);
        let err: f64 = r
            .w_hat
            .iter()
            .zip(&wbar)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max);
        assert!(err < 1e-9, "‖ŵ − w̄‖∞ = {err}");
    }

    #[test]
    fn alpha_stays_feasible() {
        let (ds, c) = small();
        let loss = Hinge::new(c);
        let opts = SolveOptions { epochs: 3, ..Default::default() };
        let r = SerialDcd::solve(&ds, &loss, &opts, None);
        assert!(r.alpha.iter().all(|&a| (0.0..=c).contains(&a)));
    }

    #[test]
    fn objective_decreases_monotonically() {
        let (ds, c) = small();
        let loss = Hinge::new(c);
        let mut duals: Vec<f64> = Vec::new();
        let mut cb = |p: &Progress<'_>| {
            duals.push(eval::dual_objective(&ds, &loss, p.alpha));
            true
        };
        let opts = SolveOptions {
            epochs: 8,
            eval_every: 1,
            ..Default::default()
        };
        SerialDcd::solve(&ds, &loss, &opts, Some(&mut cb));
        for pair in duals.windows(2) {
            assert!(pair[1] <= pair[0] + 1e-9, "dual increased: {duals:?}");
        }
    }

    #[test]
    fn early_stop_via_callback() {
        let (ds, c) = small();
        let loss = Hinge::new(c);
        let mut calls = 0;
        let mut cb = |_: &Progress<'_>| {
            calls += 1;
            calls < 2
        };
        let opts = SolveOptions {
            epochs: 50,
            eval_every: 1,
            ..Default::default()
        };
        let r = SerialDcd::solve(&ds, &loss, &opts, Some(&mut cb));
        assert_eq!(r.epochs_run, 2);
        assert_eq!(calls, 2);
    }

    #[test]
    fn shrinking_matches_full_solver_objective() {
        let (ds, c) = small();
        let loss = Hinge::new(c);
        let full = SerialDcd::solve(
            &ds,
            &loss,
            &SolveOptions { epochs: 40, ..Default::default() },
            None,
        );
        let shr = SerialDcd::solve(
            &ds,
            &loss,
            &SolveOptions { epochs: 40, shrinking: true, ..Default::default() },
            None,
        );
        let p_full = eval::primal_objective(&ds, &loss, &full.w_hat);
        let p_shr = eval::primal_objective(&ds, &loss, &shr.w_hat);
        assert!(
            (p_full - p_shr).abs() < 0.01 * p_full.abs(),
            "shrinking changed the answer: {p_full} vs {p_shr}"
        );
        // and skipped work:
        assert!(shr.updates < full.updates);
    }

    #[test]
    fn squared_hinge_and_logistic_also_converge() {
        let (ds, c) = small();
        let opts = SolveOptions { epochs: 30, ..Default::default() };

        let sq = SquaredHinge::new(c);
        let r = SerialDcd::solve(&ds, &sq, &opts, None);
        let gap = eval::duality_gap(&ds, &sq, &r.alpha);
        assert!(gap < 1e-2, "squared hinge gap {gap}");

        let lg = Logistic::new(c);
        let r = SerialDcd::solve(&ds, &lg, &opts, None);
        let gap = eval::duality_gap(&ds, &lg, &r.alpha);
        let p = eval::primal_objective(&ds, &lg, &r.w_hat);
        assert!(gap < 1e-2 * p.abs().max(1.0), "logistic gap {gap}");
    }

    #[test]
    fn with_replacement_sampling_also_converges() {
        let (ds, c) = small();
        let loss = Hinge::new(c);
        let opts = SolveOptions {
            epochs: 40,
            sampling: Sampling::WithReplacement,
            ..Default::default()
        };
        let r = SerialDcd::solve(&ds, &loss, &opts, None);
        let gap = eval::duality_gap(&ds, &loss, &r.alpha);
        assert!(gap < 1e-2, "gap {gap}");
    }

    #[test]
    fn warm_start_from_zeros_matches_cold_start() {
        let (ds, c) = small();
        let loss = Hinge::new(c);
        let opts = SolveOptions { epochs: 4, ..Default::default() };
        let cold = SerialDcd::solve(&ds, &loss, &opts, None);
        let warm = SerialDcd::solve_from(
            &ds,
            &loss,
            &opts,
            Some((&vec![0.0; ds.n()], &vec![0.0; ds.d()])),
            None,
            None,
        );
        assert_eq!(cold.alpha, warm.alpha);
        assert_eq!(cold.w_hat, warm.w_hat);
    }

    #[test]
    fn warm_start_does_not_regress_objective() {
        let (ds, c) = small();
        let loss = Hinge::new(c);
        let base = SerialDcd::solve(
            &ds,
            &loss,
            &SolveOptions { epochs: 15, ..Default::default() },
            None,
        );
        let p_base = eval::primal_objective(&ds, &loss, &base.w_hat);
        let warm = SerialDcd::solve_from(
            &ds,
            &loss,
            &SolveOptions { epochs: 1, ..Default::default() },
            Some((&base.alpha, &base.w_hat)),
            None,
            None,
        );
        let p_warm = eval::primal_objective(&ds, &loss, &warm.w_hat);
        assert!(p_warm <= p_base + 1e-9, "warm regressed: {p_warm} vs {p_base}");
    }

    #[test]
    fn deterministic_given_seed() {
        let (ds, c) = small();
        let loss = Hinge::new(c);
        let opts = SolveOptions { epochs: 3, ..Default::default() };
        let a = SerialDcd::solve(&ds, &loss, &opts, None);
        let b = SerialDcd::solve(&ds, &loss, &opts, None);
        assert_eq!(a.alpha, b.alpha);
        assert_eq!(a.w_hat, b.w_hat);
    }
}
