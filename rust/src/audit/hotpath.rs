//! Rules 3 and 5: hot-path allocation freedom and probe gating.
//!
//! Rule 3 (`hot-path-alloc`) scans the `// audit: hot-path begin/end`
//! regions — the allocation-free epoch loops PR 5 promised — for any
//! allocating token, checks the markers pair up, and requires the
//! files in [`crate::audit::policy::HOT_REQUIRED_FILES`] to carry at
//! least one region (so deleting the markers cannot silently retire
//! the guarantee).
//!
//! Rule 5 (`probe-gating`) pins the telemetry contract from PR 7: the
//! tick functions in `obs/probes.rs` must gate on `probes_enabled()`
//! before touching their counters, and solver-side code may only reach
//! the registry-publishing `probes::solver()` handle behind the same
//! gate (hoisted as `probes_on` in worker loops) — otherwise the
//! probes-off hot path re-acquires the registry mutex.

use super::policy;
use super::report::Finding;
use super::scan::SourceFile;

/// Run rule 3 over `files`.  `full` additionally enforces
/// [`policy::HOT_REQUIRED_FILES`].
pub fn check_hot_regions(files: &[SourceFile], full: bool, out: &mut Vec<Finding>) {
    for f in files {
        let regions = f.hot_regions();
        let begins = marker_count(f, "audit: hot-path begin");
        let ends = marker_count(f, "audit: hot-path end");
        if begins != ends {
            out.push(Finding::new(
                policy::RULE_HOTPATH,
                &f.path,
                regions.last().map(|r| r.0).unwrap_or(1),
                format!("unbalanced hot-path markers ({begins} begin / {ends} end)"),
                policy::HINT_HOTPATH,
            ));
        }
        if full
            && regions.is_empty()
            && policy::in_table(&f.path, policy::HOT_REQUIRED_FILES)
        {
            out.push(Finding::new(
                policy::RULE_HOTPATH,
                &f.path,
                1,
                "no hot-path region markers in a file that must guarantee \
                 allocation-free inner loops"
                    .to_string(),
                policy::HINT_HOTPATH,
            ));
        }
        for &(a, b) in &regions {
            for line in a..=b {
                let code = &f.code[line - 1];
                for tok in policy::HOT_BANNED_TOKENS {
                    if code.contains(tok) && !f.exempted(line, "alloc") {
                        out.push(Finding::new(
                            policy::RULE_HOTPATH,
                            &f.path,
                            line,
                            format!("allocating token `{tok}` inside a hot-path region"),
                            policy::HINT_HOTPATH,
                        ));
                        break;
                    }
                }
            }
        }
    }
}

fn marker_count(f: &SourceFile, marker: &str) -> usize {
    f.comments
        .iter()
        .filter(|c| c.trim_start().starts_with(marker))
        .count()
}

/// Run rule 5 over `files`.
pub fn check_probe_gating(files: &[SourceFile], out: &mut Vec<Finding>) {
    for f in files {
        if f.path == "src/obs/probes.rs" {
            check_tick_fns(f, out);
        }
        let solver_side = policy::path_matches(&f.path, "src/solver/")
            || policy::path_matches(&f.path, "src/baselines/");
        if !solver_side {
            continue;
        }
        let test_start = f.test_start();
        for (l0, code) in f.code.iter().enumerate() {
            let line = l0 + 1;
            if line >= test_start {
                break;
            }
            if !code.contains("probes::solver()") && !code.contains("probes::dist()") {
                continue;
            }
            let start = f.fn_start(line);
            let gated = (start..line).any(|l| {
                let c = &f.code[l - 1];
                policy::PROBE_GATE_TOKENS.iter().any(|t| c.contains(t))
            });
            if !gated && !f.exempted(line, "probe") {
                out.push(Finding::new(
                    policy::RULE_PROBE,
                    &f.path,
                    line,
                    "probes registry handle reached without a probes_enabled() \
                     gate earlier in the function"
                        .to_string(),
                    policy::HINT_PROBE,
                ));
            }
        }
    }
}

/// Every `pub fn *_tick` in `obs/probes.rs` must load the static gate
/// before incrementing: the fn bodies are the no-op guarantee the
/// solver call sites rely on (they call ticks ungated).
fn check_tick_fns(f: &SourceFile, out: &mut Vec<Finding>) {
    let n = f.len();
    for (l0, code) in f.code.iter().enumerate() {
        let line = l0 + 1;
        let trimmed = code.trim_start();
        if !(trimmed.starts_with("pub fn ") && trimmed.contains("_tick(")) {
            continue;
        }
        // Body: up to the first column-0 `}` (top-level fn end).
        let mut gate_at: Option<usize> = None;
        let mut inc_at: Option<usize> = None;
        for l in line + 1..=n {
            let c = &f.code[l - 1];
            if c.starts_with('}') {
                break;
            }
            if c.contains("probes_enabled()") && gate_at.is_none() {
                gate_at = Some(l);
            }
            if c.contains(".inc(") && inc_at.is_none() {
                inc_at = Some(l);
            }
        }
        if let Some(inc) = inc_at {
            if gate_at.map(|g| g > inc).unwrap_or(true) {
                out.push(Finding::new(
                    policy::RULE_PROBE,
                    &f.path,
                    line,
                    "tick function increments its counter without checking \
                     probes_enabled() first"
                        .to_string(),
                    policy::HINT_PROBE,
                ));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hot_findings(path: &str, src: &str) -> Vec<Finding> {
        let files = vec![SourceFile::from_source(path, src)];
        let mut out = Vec::new();
        check_hot_regions(&files, false, &mut out);
        out
    }

    #[test]
    fn allocation_inside_region_is_flagged() {
        let src = "fn f() {\n\
                   // audit: hot-path begin\n\
                   let v = Vec::new();\n\
                   // audit: hot-path end\n\
                   let w = Vec::new();\n\
                   }\n";
        let got = hot_findings("src/solver/dcd.rs", src);
        assert_eq!(got.len(), 1, "{got:?}");
        assert_eq!(got[0].rule, "hot-path-alloc");
        assert_eq!(got[0].line, 3);
    }

    #[test]
    fn unbalanced_markers_are_flagged() {
        let src = "// audit: hot-path begin\nlet x = 1;\n";
        let got = hot_findings("src/solver/dcd.rs", src);
        assert!(
            got.iter().any(|f| f.message.contains("unbalanced")),
            "{got:?}"
        );
    }

    #[test]
    fn required_files_must_have_regions_in_full_mode() {
        let files = vec![SourceFile::from_source("src/solver/kernel.rs", "fn f() {}\n")];
        let mut out = Vec::new();
        check_hot_regions(&files, true, &mut out);
        assert_eq!(out.len(), 1, "{out:?}");
        assert!(out[0].message.contains("no hot-path region"));
        let mut fixture = Vec::new();
        check_hot_regions(&files, false, &mut fixture);
        assert!(fixture.is_empty());
    }

    #[test]
    fn ungated_solver_probe_site_is_flagged() {
        let src = "fn worker() {\n\
                       crate::obs::probes::solver().updates.inc();\n\
                   }\n";
        let files = vec![SourceFile::from_source("src/solver/passcode.rs", src)];
        let mut out = Vec::new();
        check_probe_gating(&files, &mut out);
        assert_eq!(out.len(), 1, "{out:?}");
        assert_eq!(out[0].rule, "probe-gating");
        assert_eq!(out[0].line, 2);

        let gated = "fn worker() {\n\
                         let probes_on = crate::obs::probes_enabled();\n\
                         if probes_on {\n\
                             crate::obs::probes::solver().updates.inc();\n\
                         }\n\
                     }\n";
        let files = vec![SourceFile::from_source("src/solver/passcode.rs", gated)];
        let mut out = Vec::new();
        check_probe_gating(&files, &mut out);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn ungated_tick_fn_is_flagged() {
        let src = "pub fn cas_retry_tick() {\n\
                       CAS_RETRIES.inc();\n\
                   }\n";
        let files = vec![SourceFile::from_source("src/obs/probes.rs", src)];
        let mut out = Vec::new();
        check_probe_gating(&files, &mut out);
        assert_eq!(out.len(), 1, "{out:?}");
        assert_eq!(out[0].line, 1);

        let gated = "pub fn cas_retry_tick() {\n\
                         if probes_enabled() {\n\
                             CAS_RETRIES.inc();\n\
                         }\n\
                     }\n";
        let files = vec![SourceFile::from_source("src/obs/probes.rs", gated)];
        let mut out = Vec::new();
        check_probe_gating(&files, &mut out);
        assert!(out.is_empty(), "{out:?}");
    }
}
