//! Rules 1 and 2: the atomic-ordering allowlist and lock-discipline
//! containment.
//!
//! Rule 1 (`atomic-ordering`) checks every `Ordering::X` token against
//! the per-module allowlist in [`crate::audit::policy`]: `SeqCst` is
//! banned everywhere without an `audit: allow(seqcst)` exemption, the
//! kernel modules are pinned to `Relaxed`, and the publication edges
//! in `serve/registry.rs` must keep their Acquire/Release pair.
//!
//! Rule 2 (`lock-discipline`) keeps blocking synchronization out of
//! the kernel module trees: no `Mutex`/`RwLock`/`Condvar` there,
//! `impl LockDiscipline` only in `solver/locks.rs` and `chk/`, and raw
//! CAS inside `solver/` only in the lock table itself — kernels lock
//! via `acquire_sorted`, never ad hoc.

use super::policy;
use super::report::Finding;
use super::scan::SourceFile;

/// All ordering names an `Ordering::` token can name.
const ORDERINGS: &[&str] = &["SeqCst", "AcqRel", "Acquire", "Release", "Relaxed"];

/// Run rule 1 over `files`.  `full` additionally enforces the
/// required-presence table (meaningless on fixture snippets).
pub fn check_orderings(files: &[SourceFile], full: bool, out: &mut Vec<Finding>) {
    for f in files {
        let allowed = policy::ordering_allowlist(&f.path);
        for (l0, code) in f.code.iter().enumerate() {
            let line = l0 + 1;
            let mut rest = code.as_str();
            while let Some(pos) = rest.find("Ordering::") {
                rest = &rest[pos + "Ordering::".len()..];
                let Some(ord) = ORDERINGS.iter().find(|o| rest.starts_with(**o)) else {
                    continue;
                };
                if *ord == "SeqCst" {
                    if !f.exempted(line, "seqcst") {
                        out.push(Finding::new(
                            policy::RULE_ATOMIC,
                            &f.path,
                            line,
                            "Ordering::SeqCst is banned (no site in this crate needs \
                             a total order; PR 6 documents the per-edge choices)"
                                .to_string(),
                            policy::HINT_ATOMIC,
                        ));
                    }
                } else if !allowed.contains(ord) && !f.exempted(line, "ordering") {
                    out.push(Finding::new(
                        policy::RULE_ATOMIC,
                        &f.path,
                        line,
                        format!(
                            "Ordering::{ord} is outside this module's allowlist {allowed:?}"
                        ),
                        policy::HINT_ATOMIC,
                    ));
                }
            }
        }
    }
    if full {
        for (path, required) in policy::ORDERING_REQUIRED {
            let Some(f) = files.iter().find(|f| f.path == *path) else {
                continue;
            };
            for ord in *required {
                let token = format!("Ordering::{ord}");
                if !f.code.iter().any(|c| c.contains(&token)) {
                    out.push(Finding::new(
                        policy::RULE_ATOMIC,
                        &f.path,
                        1,
                        format!(
                            "publication edge lost its Ordering::{ord} (required in \
                             this file: a Relaxed swap would let readers see a \
                             partially initialized model version)"
                        ),
                        policy::HINT_ATOMIC,
                    ));
                }
            }
        }
    }
}

/// Run rule 2 over `files`.
pub fn check_locks(files: &[SourceFile], out: &mut Vec<Finding>) {
    for f in files {
        let test_start = f.test_start();
        let kernel_side = policy::in_table(&f.path, policy::LOCK_FREE_MODULES)
            && !policy::in_table(&f.path, policy::LOCK_ALLOWED_FILES);
        for (l0, code) in f.code.iter().enumerate() {
            let line = l0 + 1;
            if line >= test_start {
                break; // test modules may synchronize however they like
            }
            if kernel_side {
                for tok in ["Mutex", "RwLock", "Condvar"] {
                    if code.contains(tok) && !f.exempted(line, "lock") {
                        out.push(Finding::new(
                            policy::RULE_LOCK,
                            &f.path,
                            line,
                            format!("{tok} in a kernel module (blocking sync on a \
                                     training path)"),
                            policy::HINT_LOCK,
                        ));
                        break; // one finding per line is enough
                    }
                }
            }
            if code.contains("LockDiscipline for")
                && code.contains("impl")
                && !policy::in_table(&f.path, policy::LOCK_DISCIPLINE_IMPL_FILES)
            {
                out.push(Finding::new(
                    policy::RULE_LOCK,
                    &f.path,
                    line,
                    "LockDiscipline implemented outside solver/locks.rs and chk/ \
                     (the deadlock-freedom argument only covers those two)"
                        .to_string(),
                    policy::HINT_LOCK,
                ));
            }
            if code.contains("compare_exchange")
                && policy::path_matches(&f.path, "src/solver/")
                && !policy::in_table(&f.path, policy::SOLVER_CAS_ALLOWED)
            {
                out.push(Finding::new(
                    policy::RULE_LOCK,
                    &f.path,
                    line,
                    "raw compare_exchange in solver code outside the lock table \
                     (kernel locking must go through acquire_sorted)"
                        .to_string(),
                    policy::HINT_LOCK,
                ));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn findings_for(path: &str, src: &str) -> Vec<Finding> {
        let f = SourceFile::from_source(path, src);
        let files = vec![f];
        let mut out = Vec::new();
        check_orderings(&files, false, &mut out);
        check_locks(&files, &mut out);
        out
    }

    #[test]
    fn seqcst_is_flagged_unless_exempted() {
        let bad = findings_for(
            "src/net/server.rs",
            "fn f(a: &AtomicBool) { a.store(true, Ordering::SeqCst); }\n",
        );
        assert_eq!(bad.len(), 1);
        assert_eq!(bad[0].rule, "atomic-ordering");
        assert_eq!(bad[0].line, 1);

        let ok = findings_for(
            "src/net/server.rs",
            "// audit: allow(seqcst) — measuring fence cost in a bench harness\n\
             fn f(a: &AtomicBool) { a.store(true, Ordering::SeqCst); }\n",
        );
        assert!(ok.is_empty(), "{ok:?}");
    }

    #[test]
    fn module_allowlists_bind() {
        // Acquire is fine in net/ (default list) but not in the
        // Relaxed-only kernel modules.
        let ok = findings_for(
            "src/net/server.rs",
            "fn f(a: &AtomicU64) -> u64 { a.load(Ordering::Acquire) }\n",
        );
        assert!(ok.is_empty(), "{ok:?}");
        let bad = findings_for(
            "src/solver/passcode.rs",
            "fn f(a: &AtomicU64) -> u64 { a.load(Ordering::Acquire) }\n",
        );
        assert_eq!(bad.len(), 1, "{bad:?}");
        assert!(bad[0].message.contains("Acquire"), "{}", bad[0].message);
    }

    #[test]
    fn ordering_in_strings_and_comments_is_ignored() {
        let ok = findings_for(
            "src/solver/passcode.rs",
            "// Ordering::SeqCst would be wrong here, see PR 6.\n\
             fn f() -> &'static str { \"Ordering::SeqCst\" }\n",
        );
        assert!(ok.is_empty(), "{ok:?}");
    }

    #[test]
    fn required_presence_only_in_full_mode() {
        let f = SourceFile::from_source(
            "src/serve/registry.rs",
            "fn f(a: &AtomicU64) -> u64 { a.load(Ordering::Relaxed) }\n",
        );
        let files = vec![f];
        let mut fixture = Vec::new();
        check_orderings(&files, false, &mut fixture);
        assert!(fixture.is_empty(), "{fixture:?}");
        let mut full = Vec::new();
        check_orderings(&files, true, &mut full);
        assert_eq!(full.len(), 2, "{full:?}"); // Acquire and Release both missing
    }

    #[test]
    fn mutex_in_kernel_modules_is_flagged() {
        let bad = findings_for(
            "src/solver/helper.rs",
            "use std::sync::Mutex;\n",
        );
        assert_eq!(bad.len(), 1);
        assert_eq!(bad[0].rule, "lock-discipline");
        // The serving layer may lock freely.
        let ok = findings_for("src/serve/batcher.rs", "use std::sync::Mutex;\n");
        assert!(ok.is_empty(), "{ok:?}");
        // The lock table itself is the sanctioned home.
        let ok = findings_for("src/solver/locks.rs", "use std::sync::Mutex;\n");
        assert!(ok.is_empty(), "{ok:?}");
    }

    #[test]
    fn rogue_lock_discipline_impl_and_solver_cas_are_flagged() {
        let bad = findings_for(
            "src/serve/online.rs",
            "impl LockDiscipline for MyLocks {\n}\n",
        );
        assert_eq!(bad.len(), 1, "{bad:?}");
        let bad = findings_for(
            "src/solver/kernel.rs",
            "fn spin(b: &AtomicBool) { while b.compare_exchange(false, true, \
             Ordering::Relaxed, Ordering::Relaxed).is_err() {} }\n",
        );
        assert_eq!(bad.len(), 1, "{bad:?}");
        assert!(bad[0].message.contains("acquire_sorted"));
    }
}
