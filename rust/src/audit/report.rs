//! Machine-readable audit reports.
//!
//! Findings round-trip losslessly through the repo's own JSON (the
//! `chk/report.rs` precedent), so CI can archive `audit_report.json`
//! as an artifact and diff runs.  A *baseline* report can be
//! subtracted from a fresh run: baselined findings are acknowledged
//! debt and do not fail the build, anything new does.  Baseline
//! identity deliberately ignores the line number — code moving above a
//! known finding must not resurrect it.

use anyhow::{Context, Result};

use crate::util::Json;

/// Report format tag, bumped on breaking layout changes.
pub const REPORT_VERSION: &str = "passcode-audit-v1";

/// One rule violation at a concrete source location.
#[derive(Clone, Debug, PartialEq)]
pub struct Finding {
    /// Rule identifier (`atomic-ordering`, `lock-discipline`,
    /// `hot-path-alloc`, `unsafe-containment`, `probe-gating`,
    /// `wire-consistency`).
    pub rule: String,
    /// Package-relative path of the offending file.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// What was found.
    pub message: String,
    /// How to fix it (or how to register an exemption).
    pub hint: String,
}

impl Finding {
    /// Construct a finding; `rule`/`hint` usually come from
    /// [`crate::audit::policy`] tables.
    pub fn new(rule: &str, file: &str, line: usize, message: String, hint: &str) -> Finding {
        Finding {
            rule: rule.to_string(),
            file: file.to_string(),
            line,
            message,
            hint: hint.to_string(),
        }
    }

    /// Baseline identity: rule + file + message, line excluded.
    pub fn baseline_key(&self) -> (String, String, String) {
        (self.rule.clone(), self.file.clone(), self.message.clone())
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("rule", Json::str(&self.rule)),
            ("file", Json::str(&self.file)),
            ("line", Json::num(self.line as f64)),
            ("message", Json::str(&self.message)),
            ("hint", Json::str(&self.hint)),
        ])
    }

    fn from_json(v: &Json) -> Result<Finding> {
        Ok(Finding {
            rule: v.get("rule")?.as_str().context("rule")?.to_string(),
            file: v.get("file")?.as_str().context("file")?.to_string(),
            line: v.get("line")?.as_usize().context("line")?,
            message: v.get("message")?.as_str().context("message")?.to_string(),
            hint: v.get("hint")?.as_str().context("hint")?.to_string(),
        })
    }
}

/// The full `passcode audit` report: scan scope echo + findings.
#[derive(Clone, Debug, PartialEq)]
pub struct AuditReport {
    /// Report format tag ([`REPORT_VERSION`]).
    pub version: String,
    /// Source files scanned.
    pub files_scanned: usize,
    /// Findings suppressed by the baseline.
    pub baselined: usize,
    /// Non-baselined findings, sorted by (file, line, rule).
    pub findings: Vec<Finding>,
    /// Whether the tree is clean (no non-baselined findings).
    pub ok: bool,
}

impl AuditReport {
    /// Build a report from raw findings, subtracting `baseline` (a
    /// previously serialized report) when given.
    pub fn new(files_scanned: usize, mut findings: Vec<Finding>, baseline: Option<&AuditReport>) -> AuditReport {
        findings.sort_by(|a, b| {
            (&a.file, a.line, &a.rule).cmp(&(&b.file, b.line, &b.rule))
        });
        let mut baselined = 0usize;
        if let Some(base) = baseline {
            let known: std::collections::BTreeSet<_> =
                base.findings.iter().map(|f| f.baseline_key()).collect();
            findings.retain(|f| {
                let keep = !known.contains(&f.baseline_key());
                if !keep {
                    baselined += 1;
                }
                keep
            });
        }
        let ok = findings.is_empty();
        AuditReport {
            version: REPORT_VERSION.to_string(),
            files_scanned,
            baselined,
            findings,
            ok,
        }
    }

    /// Serialize for `--json` / baselines / round-tripping.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("version", Json::str(&self.version)),
            ("files_scanned", Json::num(self.files_scanned as f64)),
            ("baselined", Json::num(self.baselined as f64)),
            (
                "findings",
                Json::Arr(self.findings.iter().map(|f| f.to_json()).collect()),
            ),
            ("ok", Json::Bool(self.ok)),
        ])
    }

    /// Deserialize a report previously produced by
    /// [`AuditReport::to_json`].
    pub fn from_json(v: &Json) -> Result<AuditReport> {
        let version = v.get("version")?.as_str().context("version")?.to_string();
        if version != REPORT_VERSION {
            anyhow::bail!("unsupported audit report version {version:?}");
        }
        let findings = v
            .get("findings")?
            .as_arr()?
            .iter()
            .map(Finding::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(AuditReport {
            version,
            files_scanned: v.get("files_scanned")?.as_usize().context("files_scanned")?,
            baselined: v.get("baselined")?.as_usize().context("baselined")?,
            findings,
            ok: v.get("ok")?.as_bool()?,
        })
    }

    /// Human-readable summary (the CLI's stdout).
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(
            s,
            "static audit: {} files scanned, {} finding(s), {} baselined",
            self.files_scanned,
            self.findings.len(),
            self.baselined,
        );
        for f in &self.findings {
            let _ = writeln!(s, "  {}:{} [{}] {}", f.file, f.line, f.rule, f.message);
            let _ = writeln!(s, "      fix: {}", f.hint);
        }
        let _ = writeln!(
            s,
            "result: {}",
            if self.ok { "CLEAN" } else { "VIOLATIONS DETECTED" },
        );
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Finding {
        Finding::new(
            "atomic-ordering",
            "src/solver/passcode.rs",
            42,
            "Ordering::SeqCst outside the allowlist".to_string(),
            "downgrade or add `audit: allow(seqcst)`",
        )
    }

    #[test]
    fn report_roundtrips_through_json() {
        let r = AuditReport::new(7, vec![sample()], None);
        let back = AuditReport::from_json(&Json::parse(&r.to_json().to_pretty()).unwrap()).unwrap();
        assert_eq!(back, r);
        assert!(!back.ok);
        assert_eq!(back.findings[0].line, 42);
    }

    #[test]
    fn baseline_suppresses_by_identity_not_line() {
        let mut moved = sample();
        moved.line = 99; // the code drifted down the file
        let base = AuditReport::new(7, vec![sample()], None);
        let r = AuditReport::new(7, vec![moved], Some(&base));
        assert!(r.ok);
        assert_eq!(r.baselined, 1);

        let mut other = sample();
        other.message = "a different violation".to_string();
        let r2 = AuditReport::new(7, vec![other], Some(&base));
        assert!(!r2.ok, "new findings must not be baselined");
    }

    #[test]
    fn version_mismatch_is_rejected() {
        let mut r = AuditReport::new(0, vec![], None);
        r.version = "passcode-audit-v0".to_string();
        assert!(AuditReport::from_json(&r.to_json()).is_err());
    }

    #[test]
    fn render_names_rule_file_line() {
        let r = AuditReport::new(1, vec![sample()], None);
        let text = r.render();
        assert!(text.contains("src/solver/passcode.rs:42"));
        assert!(text.contains("[atomic-ordering]"));
        assert!(text.contains("VIOLATIONS DETECTED"));
    }
}
