//! Rule 6: cross-file wire/metric consistency.
//!
//! Two families of shared names cross file (and process) boundaries:
//!
//! * **Wire strings** — protocol magics and format tags
//!   ([`crate::audit::policy::WIRE_STRINGS`]).  Each must be defined
//!   exactly once in non-test source, as a `const`/`static`; a second
//!   inline copy is a future version-skew bug.  Tests and docs may
//!   repeat the literal: that is how the format is pinned from
//!   outside.
//! * **Metric names** — every `passcode_*` name registered with the
//!   metrics registry.  A name may only be registered from one file,
//!   and every metric reference in tests or `EXPERIMENTS.md` must
//!   resolve against a registered name (directly, via the histogram
//!   `_count`/`_sum`/`_bucket` series, or as a `passcode_x_*` family
//!   prefix).  This keeps the docs' scrape examples and the tests'
//!   assertions from drifting away from what the binary actually
//!   exports.

use std::collections::{BTreeMap, BTreeSet};

use super::policy;
use super::report::Finding;
use super::scan::SourceFile;

/// Run rule 6.  `src` is non-test crate source, `tests` the
/// integration-test files, `docs` raw (path, text) documents such as
/// `EXPERIMENTS.md`.  `full` enables the presence checks that only
/// make sense on the whole tree.
pub fn check_wire(
    src: &[SourceFile],
    tests: &[SourceFile],
    docs: &[(String, String)],
    full: bool,
    out: &mut Vec<Finding>,
) {
    check_wire_strings(src, full, out);
    let defs = metric_definitions(src, out);
    check_metric_refs(&defs, tests, docs, out);
}

/// Wire-string uniqueness: one `const`/`static` definition per magic.
fn check_wire_strings(src: &[SourceFile], full: bool, out: &mut Vec<Finding>) {
    for wire in policy::WIRE_STRINGS {
        // (file, line, is_const_line) for every non-test exact literal.
        let mut sites: Vec<(&str, usize, bool)> = Vec::new();
        for f in src {
            if policy::in_table(&f.path, policy::WIRE_DEF_EXEMPT_FILES) {
                continue; // the policy table names the strings, by design
            }
            let test_start = f.test_start();
            for (line, value) in &f.strings {
                if *line >= test_start || value != wire {
                    continue;
                }
                let code = &f.code[line - 1];
                sites.push((&f.path, *line, code.contains("const") || code.contains("static")));
            }
        }
        sites.sort();
        if sites.is_empty() {
            if full {
                out.push(Finding::new(
                    policy::RULE_WIRE,
                    "src",
                    1,
                    format!("wire string {wire:?} has no definition anywhere in src/"),
                    policy::HINT_WIRE,
                ));
            }
            continue;
        }
        if sites.len() > 1 {
            for (file, line, _) in &sites[1..] {
                out.push(Finding::new(
                    policy::RULE_WIRE,
                    file,
                    *line,
                    format!(
                        "wire string {wire:?} duplicated (canonical definition at {}:{})",
                        sites[0].0, sites[0].1
                    ),
                    policy::HINT_WIRE,
                ));
            }
        } else if !sites[0].2 {
            out.push(Finding::new(
                policy::RULE_WIRE,
                sites[0].0,
                sites[0].1,
                format!("wire string {wire:?} inlined at its only use — hoist to a const"),
                policy::HINT_WIRE,
            ));
        }
    }
}

/// Collect `passcode_*` metric names registered in non-test source
/// (the first such string within 3 lines of a `counter(` / `gauge(` /
/// `histogram(` call), flagging names registered from multiple files.
fn metric_definitions(src: &[SourceFile], out: &mut Vec<Finding>) -> BTreeSet<String> {
    let mut owners: BTreeMap<String, Vec<(String, usize)>> = BTreeMap::new();
    for f in src {
        let test_start = f.test_start();
        for (l0, code) in f.code.iter().enumerate() {
            let line = l0 + 1;
            if line >= test_start {
                break;
            }
            if !(code.contains("counter(") || code.contains("gauge(") || code.contains("histogram("))
            {
                continue;
            }
            let name = f
                .strings
                .iter()
                .filter(|(l, _)| *l >= line && *l <= line + 3)
                .filter_map(|(_, v)| v.starts_with("passcode_").then(|| base_name(v)))
                .next();
            if let Some(name) = name {
                owners.entry(name).or_default().push((f.path.clone(), line));
            }
        }
    }
    for (name, sites) in &owners {
        let files: BTreeSet<_> = sites.iter().map(|(f, _)| f.as_str()).collect();
        if files.len() > 1 {
            for (file, line) in &sites[1..] {
                out.push(Finding::new(
                    policy::RULE_WIRE,
                    file,
                    *line,
                    format!(
                        "metric {name:?} registered from multiple files (first at {}:{})",
                        sites[0].0, sites[0].1
                    ),
                    policy::HINT_WIRE,
                ));
            }
        }
    }
    owners.into_keys().collect()
}

/// The metric base name: a registration literal with inline labels
/// (`passcode_route_qps{{route="x"}}`) strips at the first `{`.
fn base_name(literal: &str) -> String {
    literal.split('{').next().unwrap_or(literal).to_string()
}

/// Resolve every metric *reference* in tests and docs against `defs`.
fn check_metric_refs(
    defs: &BTreeSet<String>,
    tests: &[SourceFile],
    docs: &[(String, String)],
    out: &mut Vec<Finding>,
) {
    for f in tests {
        if policy::in_table(&f.path, policy::WIRE_REF_EXEMPT_FILES) {
            continue; // the audit's own fixtures are deliberately bad
        }
        for (line, value) in &f.strings {
            for token in passcode_tokens(value) {
                check_one_ref(defs, &f.path, *line, &token, out);
            }
        }
    }
    for (path, text) in docs {
        for (l0, raw) in text.lines().enumerate() {
            for token in passcode_tokens(raw) {
                check_one_ref(defs, path, l0 + 1, &token, out);
            }
        }
    }
}

fn check_one_ref(
    defs: &BTreeSet<String>,
    file: &str,
    line: usize,
    token: &str,
    out: &mut Vec<Finding>,
) {
    let resolved = if token.ends_with('_') {
        // Family reference like `passcode_train_*` (token keeps the
        // trailing underscore once the `*` stops the scan).
        defs.iter().any(|d| d.starts_with(token))
    } else if policy::METRIC_REF_SUFFIXES.iter().any(|s| token.ends_with(s)) {
        defs.contains(token)
            || ["_count", "_sum", "_bucket"].iter().any(|series| {
                token
                    .strip_suffix(series)
                    .map(|base| defs.contains(base))
                    .unwrap_or(false)
            })
    } else {
        return; // not metric-shaped (temp dir names and the like)
    };
    if !resolved {
        out.push(Finding::new(
            policy::RULE_WIRE,
            file,
            line,
            format!("metric reference {token:?} does not match any registered metric"),
            policy::HINT_WIRE,
        ));
    }
}

/// Maximal `passcode_[a-z0-9_]*` runs in `text`.
fn passcode_tokens(text: &str) -> Vec<String> {
    let bytes = text.as_bytes();
    let mut tokens = Vec::new();
    let mut i = 0;
    while let Some(off) = text[i..].find("passcode_") {
        let start = i + off;
        // Skip matches glued to a longer identifier (`my_passcode_x`).
        if start > 0 && (bytes[start - 1].is_ascii_alphanumeric() || bytes[start - 1] == b'_') {
            i = start + "passcode_".len();
            continue;
        }
        let mut end = start;
        while end < bytes.len()
            && (bytes[end].is_ascii_lowercase() || bytes[end].is_ascii_digit() || bytes[end] == b'_')
        {
            end += 1;
        }
        tokens.push(text[start..end].to_string());
        i = end;
    }
    tokens
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wire_findings(files: Vec<SourceFile>, full: bool) -> Vec<Finding> {
        let mut out = Vec::new();
        check_wire(&files, &[], &[], full, &mut out);
        out
    }

    #[test]
    fn duplicated_wire_string_is_flagged() {
        let a = SourceFile::from_source(
            "src/dist/protocol.rs",
            "pub const MAGIC: &str = \"PDL2\";\n",
        );
        let b = SourceFile::from_source(
            "src/dist/worker.rs",
            "fn hdr() -> &'static str { \"PDL2\" }\n",
        );
        let got = wire_findings(vec![a, b], false);
        assert_eq!(got.len(), 1, "{got:?}");
        assert_eq!(got[0].rule, "wire-consistency");
        assert_eq!(got[0].file, "src/dist/worker.rs");
        assert!(got[0].message.contains("duplicated"));
    }

    #[test]
    fn inline_only_definition_wants_a_const() {
        let f = SourceFile::from_source(
            "src/obs/trace.rs",
            "fn fmt() -> &'static str { \"passcode-trace-v1\" }\n",
        );
        let got = wire_findings(vec![f], false);
        assert_eq!(got.len(), 1, "{got:?}");
        assert!(got[0].message.contains("hoist"));
    }

    #[test]
    fn missing_wire_string_only_flagged_in_full_mode() {
        let f = SourceFile::from_source("src/lib.rs", "fn f() {}\n");
        assert!(wire_findings(vec![f.clone()], false).is_empty());
        let got = wire_findings(vec![f], true);
        assert_eq!(got.len(), policy::WIRE_STRINGS.len(), "{got:?}");
    }

    #[test]
    fn metric_registered_twice_is_flagged() {
        let a = SourceFile::from_source(
            "src/obs/probes.rs",
            "fn f(reg: &R) { reg.counter(\n\"passcode_train_updates_total\",\n\"u\"); }\n",
        );
        let b = SourceFile::from_source(
            "src/net/server.rs",
            "fn f(reg: &R) { reg.counter(\"passcode_train_updates_total\", \"u\"); }\n",
        );
        let got = wire_findings(vec![a, b], false);
        assert_eq!(got.len(), 1, "{got:?}");
        assert!(got[0].message.contains("multiple files"));
    }

    #[test]
    fn labeled_registration_strips_to_base_name() {
        let src = SourceFile::from_source(
            "src/net/router.rs",
            "fn f(reg: &R, name: &str) {\n\
             \x20   reg.counter(&format!(\"passcode_route_requests_total{{route=\\\"{name}\\\"}}\"), \"d\");\n\
             }\n",
        );
        let tests = SourceFile::from_source(
            "tests/net.rs",
            "fn t() { assert!(s.contains(\"passcode_route_requests_total\")); }\n",
        );
        let mut out = Vec::new();
        check_wire(&[src], &[tests], &[], false, &mut out);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn unresolvable_metric_ref_is_flagged() {
        let src = SourceFile::from_source(
            "src/obs/probes.rs",
            "fn f(reg: &R) { reg.counter(\"passcode_train_updates_total\", \"u\"); }\n",
        );
        let tests = SourceFile::from_source(
            "tests/obs.rs",
            "fn t() { assert!(s.contains(\"passcode_train_misspelled_total\")); }\n",
        );
        let mut out = Vec::new();
        check_wire(&[src.clone()], &[tests], &[], false, &mut out);
        assert_eq!(out.len(), 1, "{out:?}");
        assert!(out[0].message.contains("misspelled"));

        // Histogram series and family refs resolve; temp names are ignored.
        let docs = vec![(
            "EXPERIMENTS.md".to_string(),
            "scrape `passcode_train_*` and watch the counters".to_string(),
        )];
        let tests_ok = SourceFile::from_source(
            "tests/obs.rs",
            "fn t() {\n\
             \x20   let d = std::env::temp_dir().join(\"passcode_obs_it\");\n\
             \x20   assert!(s.contains(\"passcode_train_updates_total\"));\n\
             }\n",
        );
        let mut out = Vec::new();
        check_wire(&[src], &[tests_ok], &docs, false, &mut out);
        assert!(out.is_empty(), "{out:?}");
    }
}
