//! Rule 4: unsafe containment.
//!
//! Two checks keep `unsafe` auditable:
//!
//! 1. `*_unchecked` accessors may only be called from the kernel
//!    whitelist ([`crate::audit::policy::UNCHECKED_ALLOWED`]) — the
//!    modules whose bounds invariants the kernel docs actually argue.
//! 2. Every `unsafe {` block must be preceded by a `// SAFETY:`
//!    comment (on the same line, or in the contiguous comment block
//!    directly above) stating the invariant that makes it sound.

use super::policy;
use super::report::Finding;
use super::scan::SourceFile;

/// Run rule 4 over `files`.
pub fn check_unsafe(files: &[SourceFile], out: &mut Vec<Finding>) {
    for f in files {
        let whitelisted = policy::in_table(&f.path, policy::UNCHECKED_ALLOWED);
        for (l0, code) in f.code.iter().enumerate() {
            let line = l0 + 1;
            if !whitelisted
                && code.contains("_unchecked(")
                && !f.exempted(line, "unchecked")
            {
                out.push(Finding::new(
                    policy::RULE_UNSAFE,
                    &f.path,
                    line,
                    "unchecked accessor outside the kernel whitelist".to_string(),
                    policy::HINT_UNSAFE,
                ));
            }
            if code.contains("unsafe {") && !has_safety_comment(f, line) {
                out.push(Finding::new(
                    policy::RULE_UNSAFE,
                    &f.path,
                    line,
                    "unsafe block without a `// SAFETY:` comment".to_string(),
                    policy::HINT_UNSAFE,
                ));
            }
        }
    }
}

/// Whether the `unsafe {` on `line` is covered by a SAFETY comment:
/// on the line itself, or anywhere in the contiguous run of
/// comment-only lines directly above it (multi-line SAFETY arguments
/// are common; see `serve/registry.rs`).
fn has_safety_comment(f: &SourceFile, line: usize) -> bool {
    if f.comments[line - 1].contains("SAFETY") {
        return true;
    }
    let mut l = line - 1; // 1-based line above
    while l >= 1 {
        let comment = &f.comments[l - 1];
        let code_empty = f.code[l - 1].trim().is_empty();
        if !code_empty {
            break; // hit a code line: comment block ended
        }
        if comment.contains("SAFETY") {
            return true;
        }
        if comment.trim().is_empty() {
            break; // blank line ends the contiguous block
        }
        l -= 1;
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    fn findings_for(path: &str, src: &str) -> Vec<Finding> {
        let files = vec![SourceFile::from_source(path, src)];
        let mut out = Vec::new();
        check_unsafe(&files, &mut out);
        out
    }

    #[test]
    fn unchecked_outside_whitelist_is_flagged() {
        let bad = findings_for(
            "src/net/server.rs",
            "fn f(v: &[f64]) -> f64 { unsafe { *v.get_unchecked(0) } }\n",
        );
        // Two findings: unchecked outside whitelist AND missing SAFETY.
        assert_eq!(bad.len(), 2, "{bad:?}");
        assert!(bad.iter().all(|f| f.rule == "unsafe-containment"));

        let ok = findings_for(
            "src/solver/kernel.rs",
            "// SAFETY: idx < v.len() by construction of the shard plan.\n\
             fn f(v: &[f64]) -> f64 { unsafe { *v.get_unchecked(0) } }\n",
        );
        assert!(ok.is_empty(), "{ok:?}");
    }

    #[test]
    fn unsafe_block_needs_safety_comment() {
        let bad = findings_for(
            "src/solver/kernel.rs",
            "fn f(p: *const f64) -> f64 { unsafe { *p } }\n",
        );
        assert_eq!(bad.len(), 1, "{bad:?}");
        assert!(bad[0].message.contains("SAFETY"));
    }

    #[test]
    fn multiline_safety_block_above_counts() {
        let ok = findings_for(
            "src/solver/kernel.rs",
            "fn f(p: *const f64) -> f64 {\n\
             \x20   // SAFETY: the pointer comes from a live SharedVec whose\n\
             \x20   // backing allocation outlives this call; alignment is\n\
             \x20   // guaranteed by Vec<f64>.\n\
             \x20   unsafe { *p }\n\
             }\n",
        );
        assert!(ok.is_empty(), "{ok:?}");
    }

    #[test]
    fn blank_line_breaks_the_safety_link() {
        let bad = findings_for(
            "src/solver/kernel.rs",
            "fn f(p: *const f64) -> f64 {\n\
             \x20   // SAFETY: stale comment about something else.\n\
             \n\
             \x20   unsafe { *p }\n\
             }\n",
        );
        assert_eq!(bad.len(), 1, "{bad:?}");
    }

    #[test]
    fn same_line_safety_counts() {
        let ok = findings_for(
            "src/solver/kernel.rs",
            "fn f(p: *const f64) -> f64 { unsafe { *p } // SAFETY: p is valid\n}\n",
        );
        assert!(ok.is_empty(), "{ok:?}");
    }
}
