//! The audit's rule book: every allowlist, module scope, banned token,
//! and fix hint in one place, so a policy change is a table edit — not
//! a rule-engine edit.
//!
//! Paths in these tables are package-relative (`src/...`) prefixes; an
//! entry ending in `/` scopes a whole module tree, otherwise it names
//! one file.  The most specific (longest) matching entry wins.

/// Rule id: atomic-ordering allowlist (rule 1).
pub const RULE_ATOMIC: &str = "atomic-ordering";
/// Rule id: lock-discipline containment (rule 2).
pub const RULE_LOCK: &str = "lock-discipline";
/// Rule id: hot-path allocation freedom (rule 3).
pub const RULE_HOTPATH: &str = "hot-path-alloc";
/// Rule id: unsafe containment (rule 4).
pub const RULE_UNSAFE: &str = "unsafe-containment";
/// Rule id: probe gating (rule 5).
pub const RULE_PROBE: &str = "probe-gating";
/// Rule id: cross-file wire/metric consistency (rule 6).
pub const RULE_WIRE: &str = "wire-consistency";

/// Memory orderings legal anywhere no stricter entry applies.
/// `SeqCst` is deliberately absent: PR 6 documented why every
/// synchronization edge in this crate is Relaxed/Acquire/Release, so a
/// new `SeqCst` is either an unjustified fence (hot-path cost) or a
/// misunderstanding — it needs an `audit: allow(seqcst)` comment
/// saying which.
pub const ORDERING_DEFAULT: &[&str] = &["Relaxed", "Acquire", "Release", "AcqRel"];

/// Stricter per-module ordering allowlists (longest prefix wins).
///
/// * `util/atomicf64.rs` — `SharedVec` is all-Relaxed by design: the
///   PASSCoDe iterates tolerate stale reads (that *is* the algorithm),
///   and PR 6's checker pins the race behavior the orderings imply.
/// * `solver/locks.rs` — the spinlock needs exactly Acquire on CAS
///   success and Release on unlock; everything else is Relaxed.
/// * `solver/`, `baselines/` — worker-loop flags (stop, epoch
///   counters) are advisory or joined by `thread::scope`, so Relaxed
///   is the strongest ordering any site may claim to need.
pub const ORDERING_POLICIES: &[(&str, &[&str])] = &[
    ("src/util/atomicf64.rs", &["Relaxed"]),
    ("src/solver/locks.rs", &["Relaxed", "Acquire", "Release"]),
    ("src/solver/", &["Relaxed"]),
    ("src/baselines/", &["Relaxed"]),
];

/// Orderings whose *presence* is required: publication edges that would
/// silently become racy if someone "simplified" them to Relaxed.
/// `serve/registry.rs` publishes model versions via Release store /
/// Acquire load on the current-version pointer.
pub const ORDERING_REQUIRED: &[(&str, &[&str])] = &[
    ("src/serve/registry.rs", &["Acquire", "Release"]),
];

/// Module trees that must stay free of blocking synchronization
/// (`Mutex`/`RwLock`/`Condvar`): the training kernels and everything
/// under them.  Lock-based coordination belongs in the serving/network
/// layers; kernel mutual exclusion goes through
/// `solver/locks.rs::acquire_sorted` only.
pub const LOCK_FREE_MODULES: &[&str] = &[
    "src/solver/",
    "src/data/",
    "src/util/",
    "src/loss/",
    "src/eval/",
    "src/simcore/",
    "src/baselines/",
];

/// Files inside [`LOCK_FREE_MODULES`] allowed to implement locking:
/// the lock table itself.
pub const LOCK_ALLOWED_FILES: &[&str] = &["src/solver/locks.rs"];

/// Where `impl LockDiscipline` may appear: the production table and
/// the checker's instrumented twin.
pub const LOCK_DISCIPLINE_IMPL_FILES: &[&str] = &["src/solver/locks.rs", "src/chk/"];

/// Within `solver/`, raw CAS (`compare_exchange*`) is the spinlock's
/// private primitive — kernels must lock via `acquire_sorted`, never
/// roll their own.
pub const SOLVER_CAS_ALLOWED: &[&str] = &["src/solver/locks.rs"];

/// Tokens that allocate (or reallocate) and are therefore banned
/// inside `// audit: hot-path begin/end` regions.
pub const HOT_BANNED_TOKENS: &[&str] = &[
    "Vec::new(",
    "vec![",
    ".to_vec(",
    "format!(",
    "String::new(",
    "String::from(",
    ".to_string(",
    ".to_owned(",
    "Box::new(",
    ".push(",
    ".push_str(",
    "with_capacity(",
    ".collect(",
    ".collect::<",
];

/// Files that must carry at least one marked hot-path region — the
/// allocation-free zones PR 5 promised.  Deleting the markers would
/// silently retire the guarantee, so their absence is itself a
/// finding.
pub const HOT_REQUIRED_FILES: &[&str] = &[
    "src/solver/kernel.rs",
    "src/solver/passcode.rs",
    "src/solver/dcd.rs",
    "src/obs/probes.rs",
    "src/obs/registry.rs",
];

/// Modules allowed to call `*_unchecked` accessors: the kernel layer,
/// its two backing primitives, and the checker (whose "unchecked"
/// twins still bounds-check).
pub const UNCHECKED_ALLOWED: &[&str] = &[
    "src/solver/kernel.rs",
    "src/data/sparse.rs",
    "src/util/atomicf64.rs",
    "src/chk/",
];

/// Registry-publication call paths that must be gated on
/// `probes_enabled()` (or a local hoist of it) in solver-side code:
/// `probes::solver()` lazily registers metrics (allocates, takes the
/// registry mutex), so reaching it from an ungated path would put
/// locks back on the hot loop.
pub const PROBE_GATE_TOKENS: &[&str] = &["probes_enabled", "probes_on"];

/// Wire-protocol magic/format strings: each must be defined exactly
/// once in non-test source, as a `const`/`static`.  Tests and docs may
/// repeat the literal to pin the format from outside.
pub const WIRE_STRINGS: &[&str] = &[
    "PDL2",
    "PWV1",
    "PDH1",
    "passcode-shards-v1",
    "passcode-trace-v1",
    "passcode-chk-v1",
    "passcode-audit-v1",
    "passcode-faults-v1",
];

/// Metric-name suffixes that mark a `passcode_*` token in tests or
/// docs as a metric *reference* (as opposed to, say, a temp-file
/// name), which must then resolve against a registered definition.
pub const METRIC_REF_SUFFIXES: &[&str] = &[
    "_total",
    "_count",
    "_sum",
    "_bucket",
    "_seconds",
    "_qps",
    "_per_sec",
    "_ratio",
    "_epoch",
    "_alive",
    "_lag",
];

/// Test files excluded from metric-reference scanning: the audit's own
/// fixture file deliberately contains violating snippets.
pub const WIRE_REF_EXEMPT_FILES: &[&str] = &["tests/audit.rs"];

/// Files excluded from wire-string *definition* scanning: this policy
/// table must name every wire string, and naming one is not defining
/// it.
pub const WIRE_DEF_EXEMPT_FILES: &[&str] = &["src/audit/policy.rs"];

/// Fix hints per rule (shown verbatim in findings).
pub const HINT_ATOMIC: &str = "use the weakest correct ordering for this module (see \
     audit::policy::ORDERING_POLICIES) or annotate the site with \
     `// audit: allow(seqcst) — <why>` / `// audit: allow(ordering) — <why>`";
/// Fix hint for lock-discipline findings.
pub const HINT_LOCK: &str = "kernel-side code must stay lock-free: synchronize through \
     solver/locks.rs::acquire_sorted or move the blocking state out of the kernel \
     modules (or annotate `// audit: allow(lock) — <why>` for non-kernel-path state)";
/// Fix hint for hot-path allocation findings.
pub const HINT_HOTPATH: &str = "hoist the allocation out of the marked epoch-loop region \
     (reuse a buffer allocated before the loop), or shrink the \
     `// audit: hot-path begin/end` region if the line is genuinely epoch-boundary code";
/// Fix hint for unsafe-containment findings.
pub const HINT_UNSAFE: &str = "keep unchecked accessors inside the kernel whitelist \
     (audit::policy::UNCHECKED_ALLOWED) and precede every `unsafe` block with a \
     `// SAFETY:` comment stating the invariant that makes it sound";
/// Fix hint for probe-gating findings.
pub const HINT_PROBE: &str = "dominate the probe site with `crate::obs::probes_enabled()` \
     (hoist it into a `probes_on` local for loops) so the probes-off path stays \
     allocation- and lock-free";
/// Fix hint for wire-consistency findings.
pub const HINT_WIRE: &str = "define the wire string / metric name once as a `const` (or a \
     single registration site) and reference that definition everywhere else";

/// Whether package-relative `path` matches a table `entry` (exact file
/// or `.../` prefix).
pub fn path_matches(path: &str, entry: &str) -> bool {
    if let Some(prefix) = entry.strip_suffix('/') {
        path.starts_with(prefix) && path.len() > prefix.len()
    } else {
        path == entry
    }
}

/// The ordering allowlist for `path`: the longest matching
/// [`ORDERING_POLICIES`] entry, else [`ORDERING_DEFAULT`].
pub fn ordering_allowlist(path: &str) -> &'static [&'static str] {
    ORDERING_POLICIES
        .iter()
        .filter(|(entry, _)| path_matches(path, entry))
        .max_by_key(|(entry, _)| entry.len())
        .map(|(_, allowed)| *allowed)
        .unwrap_or(ORDERING_DEFAULT)
}

/// Whether `path` matches any entry of `table`.
pub fn in_table(path: &str, table: &[&str]) -> bool {
    table.iter().any(|entry| path_matches(path, entry))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn longest_prefix_wins() {
        assert_eq!(
            ordering_allowlist("src/solver/locks.rs"),
            &["Relaxed", "Acquire", "Release"]
        );
        assert_eq!(ordering_allowlist("src/solver/passcode.rs"), &["Relaxed"]);
        assert_eq!(ordering_allowlist("src/net/server.rs"), ORDERING_DEFAULT);
        assert!(!ordering_allowlist("src/main.rs").contains(&"SeqCst"));
    }

    #[test]
    fn path_matching_distinguishes_files_and_trees() {
        assert!(path_matches("src/chk/trace.rs", "src/chk/"));
        assert!(!path_matches("src/chk", "src/chk/"));
        assert!(path_matches("src/solver/locks.rs", "src/solver/locks.rs"));
        assert!(!path_matches("src/solver/locks.rs", "src/solver/kernel.rs"));
        assert!(in_table("src/data/shard.rs", LOCK_FREE_MODULES));
        assert!(!in_table("src/net/server.rs", LOCK_FREE_MODULES));
    }
}
