//! Line-preserving Rust source scanner — the lexical substrate every
//! audit rule reads.
//!
//! In the style of `chk/`, this is a purpose-built lightweight pass,
//! not a real parser (no `syn`, no proc-macro machinery): each source
//! file is split into three parallel per-line channels —
//!
//! * **code** — the line with comments removed and string/char literal
//!   *contents* blanked (the quotes remain), so token scans like
//!   `Ordering::SeqCst` or `Mutex` never false-positive on prose or on
//!   the audit's own fixture strings;
//! * **comments** — the comment text of the line (`//`, `///`, and the
//!   per-line slices of `/* */` blocks), where the audit looks for its
//!   region markers, `// SAFETY:` justifications, and
//!   `// audit: allow(<rule>)` exemptions;
//! * **strings** — the string-literal values that *start* on the line,
//!   which the wire-consistency rule reads for magic tags and metric
//!   names.
//!
//! The scanner tracks nested block comments, raw strings (`r"…"`,
//! `r#"…"#`, any hash depth, with `b` prefixes), escapes, and the
//! char-literal vs. lifetime ambiguity (`'a'` vs `'a`).

/// One scanned source file: path plus the three per-line channels.
#[derive(Clone)]
pub struct SourceFile {
    /// Path as reported in findings (package-root-relative for real
    /// scans, whatever the caller chose for fixtures).
    pub path: String,
    /// Per-line code channel (comments stripped, literal bodies blanked).
    pub code: Vec<String>,
    /// Per-line comment text (empty string when the line has none).
    pub comments: Vec<String>,
    /// String-literal values by (1-based) starting line.
    pub strings: Vec<(usize, String)>,
}

/// Scanner state across physical lines.
enum Mode {
    /// Plain code.
    Code,
    /// Inside `/* */`, with the current nesting depth.
    Block(u32),
    /// Inside a `"…"` string (escape-aware).
    Str,
    /// Inside a raw string, closed by `"` followed by this many `#`s.
    RawStr(u32),
}

impl SourceFile {
    /// Scan `text` into the per-line channels.  `path` is recorded
    /// verbatim for findings.
    pub fn from_source(path: &str, text: &str) -> SourceFile {
        let mut code = Vec::new();
        let mut comments = Vec::new();
        let mut strings = Vec::new();
        let mut mode = Mode::Code;
        let mut cur_string = String::new();
        let mut string_start = 0usize;

        for (lineno0, line) in text.lines().enumerate() {
            let lineno = lineno0 + 1;
            let mut code_line = String::new();
            let mut comment_line = String::new();
            let bytes: Vec<char> = line.chars().collect();
            let mut i = 0usize;
            while i < bytes.len() {
                let c = bytes[i];
                match mode {
                    Mode::Block(depth) => {
                        if c == '*' && bytes.get(i + 1) == Some(&'/') {
                            i += 2;
                            if depth == 1 {
                                mode = Mode::Code;
                            } else {
                                mode = Mode::Block(depth - 1);
                            }
                        } else if c == '/' && bytes.get(i + 1) == Some(&'*') {
                            mode = Mode::Block(depth + 1);
                            i += 2;
                        } else {
                            comment_line.push(c);
                            i += 1;
                        }
                    }
                    Mode::Str => {
                        if c == '\\' {
                            // Keep the escape pair out of the blanked
                            // code but inside the recorded value.
                            if let Some(&n) = bytes.get(i + 1) {
                                cur_string.push(c);
                                cur_string.push(n);
                                i += 2;
                            } else {
                                cur_string.push(c);
                                i += 1;
                            }
                        } else if c == '"' {
                            code_line.push('"');
                            strings.push((string_start, std::mem::take(&mut cur_string)));
                            mode = Mode::Code;
                            i += 1;
                        } else {
                            cur_string.push(c);
                            i += 1;
                        }
                    }
                    Mode::RawStr(hashes) => {
                        if c == '"' {
                            let mut ok = true;
                            for k in 0..hashes as usize {
                                if bytes.get(i + 1 + k) != Some(&'#') {
                                    ok = false;
                                    break;
                                }
                            }
                            if ok {
                                code_line.push('"');
                                strings.push((string_start, std::mem::take(&mut cur_string)));
                                mode = Mode::Code;
                                i += 1 + hashes as usize;
                                continue;
                            }
                        }
                        cur_string.push(c);
                        i += 1;
                    }
                    Mode::Code => {
                        if c == '/' && bytes.get(i + 1) == Some(&'/') {
                            comment_line.push_str(&line[char_byte_offset(line, i + 2)..]);
                            break; // rest of the line is a comment
                        } else if c == '/' && bytes.get(i + 1) == Some(&'*') {
                            mode = Mode::Block(1);
                            i += 2;
                        } else if c == '"' {
                            code_line.push('"');
                            string_start = lineno;
                            cur_string.clear();
                            mode = Mode::Str;
                            i += 1;
                        } else if c == 'r'
                            && !prev_is_ident(&code_line)
                            && raw_str_hashes(&bytes[i..]).is_some()
                        {
                            let hashes = raw_str_hashes(&bytes[i..]).unwrap();
                            code_line.push('"');
                            string_start = lineno;
                            cur_string.clear();
                            mode = Mode::RawStr(hashes);
                            i += 2 + hashes as usize; // r, #s, "
                        } else if c == '\'' {
                            // Char literal vs lifetime: a literal is
                            // `'x'` or `'\…'`; a lifetime never has a
                            // closing quote right after its first char.
                            if bytes.get(i + 1) == Some(&'\\') {
                                // escaped char literal: skip to close
                                let mut j = i + 2;
                                while j < bytes.len() && bytes[j] != '\'' {
                                    j += 1;
                                }
                                code_line.push_str("' '");
                                i = j + 1;
                            } else if bytes.get(i + 2) == Some(&'\'') {
                                code_line.push_str("' '");
                                i += 3;
                            } else {
                                code_line.push(c); // lifetime tick
                                i += 1;
                            }
                        } else {
                            code_line.push(c);
                            i += 1;
                        }
                    }
                }
            }
            // A string spanning a line break keeps accumulating; record
            // the break so multi-line literals stay faithful.
            if matches!(mode, Mode::Str | Mode::RawStr(_)) {
                cur_string.push('\n');
            }
            code.push(code_line);
            comments.push(comment_line);
        }
        SourceFile { path: path.to_string(), code, comments, strings }
    }

    /// Number of physical lines.
    pub fn len(&self) -> usize {
        self.code.len()
    }

    /// Whether the file scanned to zero lines.
    pub fn is_empty(&self) -> bool {
        self.code.is_empty()
    }

    /// Whether (1-based) `line` or either of the two lines above it
    /// carries an `audit: allow(<tag>)` exemption comment.
    pub fn exempted(&self, line: usize, tag: &str) -> bool {
        let needle = format!("audit: allow({tag})");
        let lo = line.saturating_sub(3);
        (lo..line)
            .filter_map(|l| self.comments.get(l))
            .any(|c| c.contains(&needle))
    }

    /// (1-based) line of the first `#[cfg(test)]` attribute, or
    /// `usize::MAX` when the file has no test module.  By repo
    /// convention test modules sit at the end of the file, so
    /// everything at or past this line is test code (where, e.g., wire
    /// literals may be repeated to pin a format).
    pub fn test_start(&self) -> usize {
        self.code
            .iter()
            .position(|l| l.contains("#[cfg(test)]"))
            .map(|p| p + 1)
            .unwrap_or(usize::MAX)
    }

    /// (1-based) start line of the function enclosing (1-based)
    /// `line`: the nearest preceding line that declares a `fn` at an
    /// indentation of at most 4 spaces (top-level or impl-level — the
    /// repo's style never nests named fns deeper).  Returns 1 when no
    /// declaration precedes the line.
    pub fn fn_start(&self, line: usize) -> usize {
        (0..line.min(self.len()))
            .rev()
            .find(|&l| {
                let c = &self.code[l];
                let trimmed = c.trim_start();
                let indent = c.len() - trimmed.len();
                indent <= 4
                    && (trimmed.starts_with("fn ")
                        || trimmed.starts_with("pub fn ")
                        || trimmed.starts_with("pub(crate) fn ")
                        || trimmed.starts_with("unsafe fn ")
                        || trimmed.starts_with("pub unsafe fn ")
                        || trimmed.starts_with("pub(crate) unsafe fn "))
            })
            .map(|l| l + 1)
            .unwrap_or(1)
    }

    /// The `// audit: hot-path begin` / `end` regions of the file, as
    /// inclusive (1-based) line ranges.  An unclosed `begin` extends to
    /// the end of the file (the audit reports that separately).
    pub fn hot_regions(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        let mut open: Option<usize> = None;
        for (l0, c) in self.comments.iter().enumerate() {
            // Markers are whole-line comments; prose *mentioning* a
            // marker (docs, hints) never starts the comment with it.
            let c = c.trim_start();
            if c.starts_with("audit: hot-path begin") {
                open.get_or_insert(l0 + 1);
            } else if c.starts_with("audit: hot-path end") {
                if let Some(start) = open.take() {
                    out.push((start, l0 + 1));
                }
            }
        }
        if let Some(start) = open {
            out.push((start, self.len()));
        }
        out
    }

    /// Whether (1-based) `line` falls inside a hot-path region.
    pub fn in_hot_region(&self, line: usize, regions: &[(usize, usize)]) -> bool {
        regions.iter().any(|&(a, b)| a <= line && line <= b)
    }
}

/// Byte offset of char index `i` in `line` (the scanner walks chars,
/// slices need bytes).
fn char_byte_offset(line: &str, i: usize) -> usize {
    line.char_indices().nth(i).map(|(b, _)| b).unwrap_or(line.len())
}

/// Whether the accumulated code line ends in an identifier char — used
/// to keep `crate::r#fn`-style and `for r in` tokens from being taken
/// for a raw-string prefix.
fn prev_is_ident(code_line: &str) -> bool {
    code_line
        .chars()
        .last()
        .map(|c| c.is_alphanumeric() || c == '_')
        .unwrap_or(false)
}

/// If `chars` starts a raw string (`r"`, `r#"`, `br"`, ... — caller
/// has already matched the leading `r`), the number of `#`s; else None.
fn raw_str_hashes(chars: &[char]) -> Option<u32> {
    debug_assert_eq!(chars.first(), Some(&'r'));
    let mut hashes = 0u32;
    let mut k = 1usize;
    while chars.get(k) == Some(&'#') {
        hashes += 1;
        k += 1;
    }
    (chars.get(k) == Some(&'"')).then_some(hashes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_are_stripped_and_collected() {
        let f = SourceFile::from_source(
            "t.rs",
            "let x = 1; // trailing\n/* block\nstill block */ code();\n",
        );
        assert_eq!(f.code[0], "let x = 1; ");
        assert_eq!(f.comments[0], " trailing");
        assert_eq!(f.code[1], "");
        assert!(f.comments[1].contains("block"));
        assert!(f.code[2].contains("code();"));
    }

    #[test]
    fn string_bodies_are_blanked_but_recorded() {
        let f = SourceFile::from_source(
            "t.rs",
            "let s = \"Mutex::new // not a comment\";\nlet r = r#\"SeqCst\"#;\n",
        );
        assert!(!f.code[0].contains("Mutex"));
        assert!(!f.code[0].contains("not a comment"));
        assert!(!f.code[1].contains("SeqCst"));
        assert_eq!(f.strings[0], (1, "Mutex::new // not a comment".to_string()));
        assert_eq!(f.strings[1], (2, "SeqCst".to_string()));
    }

    #[test]
    fn char_literals_and_lifetimes_disambiguate() {
        let f = SourceFile::from_source(
            "t.rs",
            "fn f<'a>(x: &'a str) -> char { '\"' }\nlet c = 'y';\n",
        );
        // The quote char literal must not open a string.
        assert!(f.strings.is_empty());
        assert!(f.code[0].contains("fn f<'a>"));
    }

    #[test]
    fn nested_block_comments_close_correctly() {
        let f = SourceFile::from_source("t.rs", "/* a /* b */ still */ code();\n");
        assert!(f.code[0].contains("code();"));
        assert!(!f.code[0].contains("still"));
    }

    #[test]
    fn exemptions_look_up_to_two_lines_back() {
        let src = "// audit: allow(seqcst) — why\nlet a = 1;\nlet b = 2;\nlet c = 3;\n";
        let f = SourceFile::from_source("t.rs", src);
        assert!(f.exempted(1, "seqcst"));
        assert!(f.exempted(2, "seqcst"));
        assert!(f.exempted(3, "seqcst"));
        assert!(!f.exempted(4, "seqcst"));
        assert!(!f.exempted(2, "lock"));
    }

    #[test]
    fn hot_regions_pair_markers() {
        let src = "a();\n// audit: hot-path begin\nb();\n// audit: hot-path end\nc();\n";
        let f = SourceFile::from_source("t.rs", src);
        assert_eq!(f.hot_regions(), vec![(2, 4)]);
        let r = f.hot_regions();
        assert!(f.in_hot_region(3, &r));
        assert!(!f.in_hot_region(5, &r));
    }

    #[test]
    fn fn_start_finds_enclosing_declaration() {
        let src = "fn outer() {\n    let x = 1;\n}\n\npub fn later() {\n    x();\n}\n";
        let f = SourceFile::from_source("t.rs", src);
        assert_eq!(f.fn_start(2), 1);
        assert_eq!(f.fn_start(6), 5);
    }

    #[test]
    fn test_start_marks_cfg_test() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {}\n";
        let f = SourceFile::from_source("t.rs", src);
        assert_eq!(f.test_start(), 2);
        let none = SourceFile::from_source("t.rs", "fn a() {}\n");
        assert_eq!(none.test_start(), usize::MAX);
    }
}
