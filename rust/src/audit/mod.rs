//! `passcode audit` — a static analyzer for this crate's own
//! concurrency and consistency invariants.
//!
//! `cargo test` proves the code computes the right numbers;
//! [`crate::chk`] explores schedules against the declared memory
//! models.  What neither can catch is a *well-typed regression of a
//! design rule*: an innocent `Ordering::SeqCst` that quietly puts a
//! fence in the wild-kernel loop, a `Mutex` smuggled into `data/`, an
//! allocation inside the epoch loop PR 5 made allocation-free, a probe
//! site that re-acquires the registry mutex with telemetry off, or a
//! second copy of a wire string that will skew on the next version
//! bump.  Those compile, pass tests, and slowly rot the properties the
//! paper reproduction argues for — so the crate audits its own source.
//!
//! The audit is deliberately low-tech: a per-line lexer
//! ([`scan::SourceFile`]) that separates code, comments, and string
//! literals, plus rule passes that are mostly table lookups against
//! [`policy`].  No syntax tree, no `syn` — same std-only footing as
//! the rest of the crate, and the rules only need to know *which
//! tokens appear where*.
//!
//! Rule families (ids in parentheses):
//!
//! | rule | invariant |
//! |------|-----------|
//! | `atomic-ordering`    | per-module ordering allowlists; `SeqCst` banned without an exemption comment; required Acquire/Release publication edges stay present |
//! | `lock-discipline`    | kernel module trees stay `Mutex`/`RwLock`/`Condvar`-free; `impl LockDiscipline` and raw CAS only in the sanctioned files |
//! | `hot-path-alloc`     | no allocating tokens inside `// audit: hot-path begin/end` regions; the key kernel files must carry such regions |
//! | `unsafe-containment` | `*_unchecked` only from the kernel whitelist; every `unsafe {` preceded by `// SAFETY:` |
//! | `probe-gating`       | telemetry tick fns and solver-side `probes::solver()` uses dominated by the `probes_enabled()` static gate |
//! | `wire-consistency`   | wire magics defined once as consts; metric names registered once; test/doc metric references resolve |
//!
//! Exemptions are in-source and per-site: `// audit: allow(<tag>) —
//! <why>` on the line or up to two lines above (tags: `seqcst`,
//! `ordering`, `lock`, `alloc`, `unchecked`, `probe`).  A JSON
//! baseline (`--baseline`) additionally suppresses known findings by
//! (rule, file, message) identity — the shipped tree keeps an *empty*
//! baseline.

pub mod atomics;
pub mod hotpath;
pub mod policy;
pub mod report;
pub mod scan;
pub mod unsafety;
pub mod wire;

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

pub use report::{AuditReport, Finding, REPORT_VERSION};
use scan::SourceFile;

/// What to scan and how hard.
pub struct AuditConfig {
    /// Repo or package root; the scanner finds `src/` under it
    /// directly or via a `rust/` subdirectory.
    pub root: PathBuf,
    /// Smoke mode: scan `src/` only (skip tests and docs), for quick
    /// CI gates.
    pub smoke: bool,
}

/// Run the audit over the tree at `cfg.root`.  Returns the number of
/// files scanned and the raw findings (baseline subtraction happens in
/// [`AuditReport::new`]).
pub fn run_audit(cfg: &AuditConfig) -> Result<(usize, Vec<Finding>)> {
    let package = find_package_root(&cfg.root)?;
    let src = load_tree(&package, "src")?;
    anyhow::ensure!(!src.is_empty(), "no .rs files under {}", package.join("src").display());
    let tests = if cfg.smoke { Vec::new() } else { load_tree(&package, "tests")? };
    let mut docs = Vec::new();
    if !cfg.smoke {
        // EXPERIMENTS.md lives at the repo root, one level above the
        // cargo package when the crate sits in `rust/`.
        for dir in [package.as_path(), package.parent().unwrap_or(&package)] {
            let p = dir.join("EXPERIMENTS.md");
            if p.is_file() {
                let text = std::fs::read_to_string(&p)
                    .with_context(|| format!("reading {}", p.display()))?;
                docs.push(("EXPERIMENTS.md".to_string(), text));
                break;
            }
        }
    }
    let scanned = src.len() + tests.len() + docs.len();
    Ok((scanned, audit_sources(&src, &tests, &docs, true)))
}

/// Run every rule pass over already-scanned sources.  `full` enables
/// the whole-tree presence checks (required orderings, required
/// hot-path regions, wire-string existence) that are meaningless on
/// fixture snippets; the fixture tests in `tests/audit.rs` pass
/// `false`.
pub fn audit_sources(
    src: &[SourceFile],
    tests: &[SourceFile],
    docs: &[(String, String)],
    full: bool,
) -> Vec<Finding> {
    let mut out = Vec::new();
    atomics::check_orderings(src, full, &mut out);
    atomics::check_locks(src, &mut out);
    hotpath::check_hot_regions(src, full, &mut out);
    hotpath::check_probe_gating(src, &mut out);
    unsafety::check_unsafe(src, &mut out);
    wire::check_wire(src, tests, docs, full, &mut out);
    out
}

/// Locate the cargo package under `root`: `root` itself if it has
/// `src/`, else `root/rust`.
fn find_package_root(root: &Path) -> Result<PathBuf> {
    for candidate in [root.to_path_buf(), root.join("rust")] {
        if candidate.join("src").is_dir() {
            return Ok(candidate);
        }
    }
    anyhow::bail!("no src/ directory under {} (or its rust/ subdir)", root.display())
}

/// Scan every `.rs` file under `package/<dir>`, recursively, in
/// deterministic (sorted) order, with package-relative paths.
fn load_tree(package: &Path, dir: &str) -> Result<Vec<SourceFile>> {
    let top = package.join(dir);
    let mut paths = Vec::new();
    if top.is_dir() {
        collect_rs(&top, &mut paths)?;
    }
    paths.sort();
    let mut files = Vec::new();
    for p in paths {
        let text = std::fs::read_to_string(&p)
            .with_context(|| format!("reading {}", p.display()))?;
        let rel = p
            .strip_prefix(package)
            .unwrap_or(&p)
            .to_string_lossy()
            .replace('\\', "/");
        files.push(SourceFile::from_source(&rel, &text));
    }
    Ok(files)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    for entry in std::fs::read_dir(dir).with_context(|| format!("listing {}", dir.display()))? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn audit_sources_runs_every_rule_family() {
        // One deliberately rotten file trips rules 1-5; the wire pass
        // trips on a duplicated magic across two files.
        let rotten = SourceFile::from_source(
            "src/solver/helper.rs",
            "use std::sync::Mutex;\n\
             fn f(a: &AtomicBool, v: &[f64]) -> f64 {\n\
             \x20   a.store(true, Ordering::SeqCst);\n\
             \x20   crate::obs::probes::solver().updates.inc();\n\
             \x20   // audit: hot-path begin\n\
             \x20   let s = format!(\"x\");\n\
             \x20   // audit: hot-path end\n\
             \x20   unsafe { *v.get_unchecked(0) }\n\
             }\n\
             pub const A: &str = \"PDL1\";\n",
        );
        let dup = SourceFile::from_source(
            "src/solver/other.rs",
            "pub const B: &str = \"PDL1\";\n",
        );
        let findings = audit_sources(&[rotten, dup], &[], &[], false);
        let rules: std::collections::BTreeSet<_> =
            findings.iter().map(|f| f.rule.as_str()).collect();
        for rule in [
            policy::RULE_ATOMIC,
            policy::RULE_LOCK,
            policy::RULE_HOTPATH,
            policy::RULE_UNSAFE,
            policy::RULE_PROBE,
            policy::RULE_WIRE,
        ] {
            assert!(rules.contains(rule), "missing {rule}: {findings:?}");
        }
    }

    #[test]
    fn package_root_is_found_from_repo_or_package() {
        let dir = std::env::temp_dir().join("passcode_audit_root");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(dir.join("rust/src")).unwrap();
        assert_eq!(find_package_root(&dir).unwrap(), dir.join("rust"));
        assert_eq!(
            find_package_root(&dir.join("rust")).unwrap(),
            dir.join("rust")
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}
