//! FastTrack-lite happens-before race analysis over recorded traces.
//!
//! Per-thread vector clocks advance on every access event; checked-lock
//! acquire/release edges are the *only* synchronization — matching the
//! kernels, whose relaxed atomics impose no ordering the algorithm
//! relies on.  Two accesses to the same cell race iff they come from
//! different threads, are unordered by happens-before, at least one
//! writes, and at least one is plain (non-atomic): concurrent relaxed
//! atomics are not races (PASSCoDe-Atomic's discipline), while Wild's
//! plain read-add-store is.
//!
//! Keeping only the last read/write per `(cell, thread)` is sound for
//! race *existence*: within one thread accesses to a cell are totally
//! ordered, so if the latest is ordered before the current event, every
//! earlier one is too.
//!
//! The τ-staleness probe rides the same scan: for every coordinate
//! update it counts `w` writes by *other* threads landing between the
//! update's first `w` read (the dot) and its last `w` write (the
//! scatter) — the staleness parameter charged by the paper's analysis
//! and by Liu & Wright's AsySCD bounds (arXiv:1403.3862).

use std::collections::HashMap;

use super::trace::{AccessKind, ArrayId, TraceEvent};

/// Cap on stored concrete race samples per analyzed schedule.
pub const MAX_RACE_SAMPLES: usize = 8;

/// A fixed-width vector clock.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct VClock {
    c: Vec<u32>,
}

impl VClock {
    /// The zero clock over `n` threads.
    pub fn new(n: usize) -> VClock {
        VClock { c: vec![0; n] }
    }

    /// Component `t`.
    pub fn get(&self, t: usize) -> u32 {
        self.c[t]
    }

    /// Increment component `t`.
    pub fn tick(&mut self, t: usize) {
        self.c[t] += 1;
    }

    /// Pointwise maximum with `other`.
    pub fn join(&mut self, other: &VClock) {
        for (a, b) in self.c.iter_mut().zip(&other.c) {
            *a = (*a).max(*b);
        }
    }
}

/// One side of a detected race.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RaceAccess {
    /// Thread id.
    pub tid: u32,
    /// That thread's logical clock at the access.
    pub clock: u32,
    /// Access classification.
    pub kind: AccessKind,
    /// Coordinate whose update performed the access, if any.
    pub coord: Option<u32>,
}

/// A happens-before race between two accesses to one cell.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Race {
    /// Which array.
    pub array: ArrayId,
    /// Racing cell index.
    pub index: u32,
    /// The earlier access.
    pub prior: RaceAccess,
    /// The later access.
    pub current: RaceAccess,
}

/// Everything the offline pass derives from one schedule's trace.
#[derive(Clone, Debug, PartialEq)]
pub struct Analysis {
    /// Racing pairs detected on `w`.
    pub races_w: u64,
    /// Racing pairs detected on α.
    pub races_alpha: u64,
    /// Up to [`MAX_RACE_SAMPLES`] concrete racing pairs.
    pub samples: Vec<Race>,
    /// Per-update staleness τ (one entry per update that both read and
    /// wrote `w`), in update-completion order.
    pub tau: Vec<u32>,
}

impl Analysis {
    /// Largest observed τ (0 when no update scattered).
    pub fn tau_max(&self) -> u32 {
        self.tau.iter().copied().max().unwrap_or(0)
    }

    /// Mean observed τ (0 when no update scattered).
    pub fn tau_mean(&self) -> f64 {
        if self.tau.is_empty() {
            0.0
        } else {
            self.tau.iter().map(|&t| t as f64).sum::<f64>()
                / self.tau.len() as f64
        }
    }
}

struct CellState {
    last_read: Vec<Option<RaceAccess>>,
    last_write: Vec<Option<RaceAccess>>,
}

impl CellState {
    fn new(n: usize) -> CellState {
        CellState {
            last_read: vec![None; n],
            last_write: vec![None; n],
        }
    }
}

struct UpdateSpan {
    first_read: Option<usize>,
    last_write: Option<usize>,
}

/// Run the happens-before + τ analysis over one schedule's trace.
pub fn analyze(events: &[TraceEvent], threads: usize) -> Analysis {
    let n = threads.max(1);
    let mut tvc: Vec<VClock> = (0..n).map(|_| VClock::new(n)).collect();
    let mut lock_vc: HashMap<u32, VClock> = HashMap::new();
    let mut cells: HashMap<(ArrayId, u32), CellState> = HashMap::new();
    let mut races_w = 0u64;
    let mut races_alpha = 0u64;
    let mut samples: Vec<Race> = Vec::new();
    // τ bookkeeping: all w-writes (trace position, thread) plus the
    // [first w-read, last w-write] span of each in-flight update.
    let mut w_writes: Vec<(usize, u32)> = Vec::new();
    let mut active: Vec<Option<UpdateSpan>> = (0..n).map(|_| None).collect();
    let mut spans: Vec<(u32, usize, usize)> = Vec::new();

    for (seq, ev) in events.iter().enumerate() {
        match ev {
            TraceEvent::Access { tid, clock, array, index, kind, coord } => {
                let t = *tid as usize;
                if t >= n {
                    continue;
                }
                tvc[t].tick(t);
                debug_assert_eq!(tvc[t].get(t), *clock);
                let cell = cells
                    .entry((*array, *index))
                    .or_insert_with(|| CellState::new(n));
                let current = RaceAccess {
                    tid: *tid,
                    clock: *clock,
                    kind: *kind,
                    coord: *coord,
                };
                for u in 0..n {
                    if u == t {
                        continue;
                    }
                    let hb = tvc[t].get(u);
                    for prior in [&cell.last_write[u], &cell.last_read[u]] {
                        let Some(p) = prior else {
                            continue;
                        };
                        let ordered = p.clock <= hb;
                        let conflicting = (p.kind.is_write()
                            || kind.is_write())
                            && (p.kind.is_plain() || kind.is_plain());
                        if !ordered && conflicting {
                            match array {
                                ArrayId::W => races_w += 1,
                                ArrayId::Alpha => races_alpha += 1,
                            }
                            if samples.len() < MAX_RACE_SAMPLES {
                                samples.push(Race {
                                    array: *array,
                                    index: *index,
                                    prior: p.clone(),
                                    current: current.clone(),
                                });
                            }
                        }
                    }
                }
                if kind.is_write() {
                    cell.last_write[t] = Some(current);
                } else {
                    cell.last_read[t] = Some(current);
                }
                if *array == ArrayId::W {
                    if kind.is_write() {
                        w_writes.push((seq, *tid));
                        if let Some(span) = active[t].as_mut() {
                            span.last_write = Some(seq);
                        }
                    } else if let Some(span) = active[t].as_mut() {
                        if span.first_read.is_none() {
                            span.first_read = Some(seq);
                        }
                    }
                }
            }
            TraceEvent::LockAcquire { tid, lock } => {
                let t = *tid as usize;
                if t >= n {
                    continue;
                }
                if let Some(lvc) = lock_vc.get(lock) {
                    tvc[t].join(lvc);
                }
            }
            TraceEvent::LockRelease { tid, lock } => {
                let t = *tid as usize;
                if t >= n {
                    continue;
                }
                lock_vc.insert(*lock, tvc[t].clone());
            }
            TraceEvent::UpdateBegin { tid, .. } => {
                let t = *tid as usize;
                if t >= n {
                    continue;
                }
                active[t] =
                    Some(UpdateSpan { first_read: None, last_write: None });
            }
            TraceEvent::UpdateEnd { tid } => {
                let t = *tid as usize;
                if t >= n {
                    continue;
                }
                if let Some(span) = active[t].take() {
                    if let (Some(r0), Some(w1)) =
                        (span.first_read, span.last_write)
                    {
                        spans.push((*tid, r0, w1));
                    }
                }
            }
        }
    }

    // τ per update: foreign w-writes strictly inside (first read, last
    // write).  `w_writes` is already sorted by trace position.
    let tau = spans
        .iter()
        .map(|&(tid, r0, w1)| {
            let lo = w_writes.partition_point(|&(s, _)| s <= r0);
            let hi = w_writes.partition_point(|&(s, _)| s < w1);
            w_writes[lo..hi].iter().filter(|&&(_, t)| t != tid).count()
                as u32
        })
        .collect();

    Analysis { races_w, races_alpha, samples, tau }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn acc(
        tid: u32,
        clock: u32,
        array: ArrayId,
        index: u32,
        kind: AccessKind,
    ) -> TraceEvent {
        TraceEvent::Access { tid, clock, array, index, kind, coord: None }
    }

    #[test]
    fn unsynchronized_plain_writes_race() {
        let events = vec![
            acc(0, 1, ArrayId::W, 3, AccessKind::PlainStore),
            acc(1, 1, ArrayId::W, 3, AccessKind::PlainStore),
        ];
        let a = analyze(&events, 2);
        assert_eq!(a.races_w, 1);
        assert_eq!(a.races_alpha, 0);
        assert_eq!(a.samples.len(), 1);
        assert_eq!(a.samples[0].index, 3);
    }

    #[test]
    fn lock_edges_order_the_writes() {
        let events = vec![
            TraceEvent::LockAcquire { tid: 0, lock: 3 },
            acc(0, 1, ArrayId::W, 3, AccessKind::PlainStore),
            TraceEvent::LockRelease { tid: 0, lock: 3 },
            TraceEvent::LockAcquire { tid: 1, lock: 3 },
            acc(1, 1, ArrayId::W, 3, AccessKind::PlainStore),
            TraceEvent::LockRelease { tid: 1, lock: 3 },
        ];
        let a = analyze(&events, 2);
        assert_eq!(a.races_w, 0);
    }

    #[test]
    fn concurrent_atomics_do_not_race() {
        let events = vec![
            acc(0, 1, ArrayId::W, 0, AccessKind::AtomicRmw),
            acc(1, 1, ArrayId::W, 0, AccessKind::AtomicRmw),
            acc(0, 2, ArrayId::W, 0, AccessKind::AtomicLoad),
        ];
        let a = analyze(&events, 2);
        assert_eq!(a.races_w, 0);
    }

    #[test]
    fn atomic_load_races_with_foreign_plain_store() {
        let events = vec![
            acc(0, 1, ArrayId::W, 5, AccessKind::AtomicLoad),
            acc(1, 1, ArrayId::W, 5, AccessKind::PlainStore),
        ];
        let a = analyze(&events, 2);
        assert_eq!(a.races_w, 1);
    }

    #[test]
    fn different_cells_never_race() {
        let events = vec![
            acc(0, 1, ArrayId::W, 0, AccessKind::PlainStore),
            acc(1, 1, ArrayId::W, 1, AccessKind::PlainStore),
        ];
        let a = analyze(&events, 2);
        assert_eq!(a.races_w, 0);
    }

    #[test]
    fn tau_counts_foreign_writes_inside_the_span() {
        let events = vec![
            TraceEvent::UpdateBegin { tid: 0, coord: 4 },
            acc(0, 1, ArrayId::W, 0, AccessKind::AtomicLoad),
            acc(1, 1, ArrayId::W, 0, AccessKind::PlainStore),
            acc(1, 2, ArrayId::W, 1, AccessKind::PlainStore),
            acc(0, 2, ArrayId::W, 0, AccessKind::PlainStore),
            TraceEvent::UpdateEnd { tid: 0 },
        ];
        let a = analyze(&events, 2);
        assert_eq!(a.tau, vec![2]);
        assert_eq!(a.tau_max(), 2);
        assert!((a.tau_mean() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn updates_without_a_scatter_contribute_no_tau() {
        let events = vec![
            TraceEvent::UpdateBegin { tid: 0, coord: 0 },
            acc(0, 1, ArrayId::W, 0, AccessKind::AtomicLoad),
            TraceEvent::UpdateEnd { tid: 0 },
        ];
        let a = analyze(&events, 1);
        assert!(a.tau.is_empty());
        assert_eq!(a.tau_max(), 0);
        assert_eq!(a.tau_mean(), 0.0);
    }
}
