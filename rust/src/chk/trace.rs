//! Access tracing: instrumented twins of the production shared state.
//!
//! [`CheckedVec`] implements the [`MemAccess`] seam the kernels are
//! generic over, recording every load/store/CAS with thread id,
//! per-thread logical clock, and the coordinate being updated — so
//! `passcode check` exercises the *real* kernels, not a model of them.
//! Every access is bounds-asserted, including the `*_unchecked` entry
//! points (which deliberately keep the trait's checked defaults): an
//! out-of-bounds index is recorded as a [`Violation`] instead of
//! faulting, so one bug does not hide the rest of the schedule.
//!
//! [`CheckedLocks`] implements [`LockDiscipline`] with *logical* lock
//! state.  A blocked acquire hands the schedule token away (a forced
//! yield in [`super::sched`]) instead of spinning, so lock blocking
//! composes with the serialized scheduler, and the sorted-acquisition
//! protocol of the paper's §3.3 is verified on every call.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use crate::solver::kernel::MemAccess;
use crate::solver::locks::LockDiscipline;

use super::sched;

/// Which shared array an access touched.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArrayId {
    /// The shared primal vector `w`.
    W,
    /// The dual variables α (single-owner under coordinate partition).
    Alpha,
}

impl ArrayId {
    /// Stable lowercase name for reports.
    pub fn name(self) -> &'static str {
        match self {
            ArrayId::W => "w",
            ArrayId::Alpha => "alpha",
        }
    }
}

/// How a cell was touched.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AccessKind {
    /// Relaxed atomic load (every kernel's read path).
    AtomicLoad,
    /// Atomic read-modify-write (the CAS add).
    AtomicRmw,
    /// The plain load half of a wild read-add-store.
    PlainLoad,
    /// A plain store (wild/locked publish, α writes).
    PlainStore,
}

impl AccessKind {
    /// Whether the access writes the cell.
    pub fn is_write(self) -> bool {
        matches!(self, AccessKind::AtomicRmw | AccessKind::PlainStore)
    }

    /// Whether the access is non-atomic.  Two *atomic* accesses never
    /// race (PASSCoDe-Atomic's discipline); a plain one racing with any
    /// conflicting access is the Wild regime.
    pub fn is_plain(self) -> bool {
        matches!(self, AccessKind::PlainLoad | AccessKind::PlainStore)
    }

    /// Stable name for reports.
    pub fn name(self) -> &'static str {
        match self {
            AccessKind::AtomicLoad => "atomic_load",
            AccessKind::AtomicRmw => "atomic_rmw",
            AccessKind::PlainLoad => "plain_load",
            AccessKind::PlainStore => "plain_store",
        }
    }
}

/// One entry of a recorded interleaving.  Events are appended while the
/// recording thread holds the schedule token, so vector order *is* the
/// serialized execution order — which makes traces replay-comparable.
#[derive(Clone, Debug, PartialEq)]
pub enum TraceEvent {
    /// A shared-memory cell access.
    Access {
        /// Checker thread id.
        tid: u32,
        /// That thread's logical clock (increments per access).
        clock: u32,
        /// Which array.
        array: ArrayId,
        /// Cell index.
        index: u32,
        /// Load/store/RMW classification.
        kind: AccessKind,
        /// Coordinate whose update performed the access, if any.
        coord: Option<u32>,
    },
    /// A checked feature lock was acquired.
    LockAcquire {
        /// Checker thread id.
        tid: u32,
        /// Feature lock index.
        lock: u32,
    },
    /// A checked feature lock was released.
    LockRelease {
        /// Checker thread id.
        tid: u32,
        /// Feature lock index.
        lock: u32,
    },
    /// A coordinate update began.
    UpdateBegin {
        /// Checker thread id.
        tid: u32,
        /// Coordinate being updated.
        coord: u32,
    },
    /// The active coordinate update finished.
    UpdateEnd {
        /// Checker thread id.
        tid: u32,
    },
}

/// Protocol violations the instrumented twins detect directly (races,
/// by contrast, are derived offline by the vector-clock pass).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ViolationKind {
    /// An access indexed past the array / lock-table length.
    OutOfBounds,
    /// `acquire_sorted` got a non-strictly-increasing lock list —
    /// the paper's §3.3 deadlock-freedom protocol was broken.
    UnsortedLocks,
    /// A lock release by a thread that does not hold the lock.
    ForeignRelease,
    /// The schedule tripped the step bound or a blocked thread had no
    /// runnable sibling (livelock / deadlock under this interleaving).
    Stuck,
}

impl ViolationKind {
    /// Stable name for reports.
    pub fn name(self) -> &'static str {
        match self {
            ViolationKind::OutOfBounds => "out_of_bounds",
            ViolationKind::UnsortedLocks => "unsorted_locks",
            ViolationKind::ForeignRelease => "foreign_release",
            ViolationKind::Stuck => "stuck",
        }
    }
}

/// One detected protocol violation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Violation {
    /// Thread that tripped it (0 when outside a checked schedule).
    pub tid: u32,
    /// What went wrong.
    pub kind: ViolationKind,
    /// Human-readable specifics.
    pub detail: String,
}

struct RecInner {
    events: Vec<TraceEvent>,
    clocks: Vec<u32>,
    coords: Vec<Option<u32>>,
    violations: Vec<Violation>,
}

/// Shared trace sink for one schedule.  Records only from threads with
/// an installed worker context ([`sched::current_tid`]), so main-thread
/// setup/teardown accesses stay out of the trace; violations are
/// recorded unconditionally.
pub struct Recorder {
    inner: Mutex<RecInner>,
}

impl Recorder {
    /// A recorder for up to `threads` checker threads.
    pub fn new(threads: usize) -> Arc<Recorder> {
        let n = threads.max(1);
        Arc::new(Recorder {
            inner: Mutex::new(RecInner {
                events: Vec::new(),
                clocks: vec![0; n],
                coords: vec![None; n],
                violations: Vec::new(),
            }),
        })
    }

    fn lock(&self) -> MutexGuard<'_, RecInner> {
        self.inner.lock().expect("recorder poisoned")
    }

    /// Record a cell access by the calling instrumented thread.
    fn access(&self, array: ArrayId, index: u32, kind: AccessKind) {
        let Some(tid) = sched::current_tid() else {
            return;
        };
        let mut g = self.lock();
        g.clocks[tid] += 1;
        let ev = TraceEvent::Access {
            tid: tid as u32,
            clock: g.clocks[tid],
            array,
            index,
            kind,
            coord: g.coords[tid],
        };
        g.events.push(ev);
    }

    /// Mark the start of the update of coordinate `coord` (a yield
    /// point — the first thing a worker does, so the very first record
    /// of every thread already holds the schedule token).
    pub fn begin_update(&self, coord: u32) {
        let Some(tid) = sched::current_tid() else {
            return;
        };
        sched::yield_here(false);
        let mut g = self.lock();
        g.coords[tid] = Some(coord);
        g.events.push(TraceEvent::UpdateBegin { tid: tid as u32, coord });
    }

    /// Mark the end of the active update (a yield point).
    pub fn end_update(&self) {
        let Some(tid) = sched::current_tid() else {
            return;
        };
        sched::yield_here(false);
        let mut g = self.lock();
        g.coords[tid] = None;
        g.events.push(TraceEvent::UpdateEnd { tid: tid as u32 });
    }

    fn lock_acquired(&self, lock: u32) {
        let Some(tid) = sched::current_tid() else {
            return;
        };
        let mut g = self.lock();
        g.events.push(TraceEvent::LockAcquire { tid: tid as u32, lock });
    }

    fn lock_released(&self, lock: u32) {
        let Some(tid) = sched::current_tid() else {
            return;
        };
        let mut g = self.lock();
        g.events.push(TraceEvent::LockRelease { tid: tid as u32, lock });
    }

    /// Record a protocol violation (with or without a worker context).
    pub fn violation(&self, kind: ViolationKind, detail: String) {
        let tid = sched::current_tid().unwrap_or(0) as u32;
        self.lock().violations.push(Violation { tid, kind, detail });
    }

    /// Take the recorded trace and violations (post-join).
    pub fn drain(&self) -> (Vec<TraceEvent>, Vec<Violation>) {
        let mut g = self.lock();
        (std::mem::take(&mut g.events), std::mem::take(&mut g.violations))
    }
}

/// Instrumented twin of [`crate::util::SharedVec`]: the checker side of
/// the [`MemAccess`] seam.  Same API surface (the `*_unchecked` methods
/// keep the trait's checked defaults), every access bounds-asserted and
/// recorded; out-of-bounds indices become [`ViolationKind::OutOfBounds`]
/// records and the access is clamped so the schedule can continue.
pub struct CheckedVec {
    id: ArrayId,
    cells: Vec<AtomicU64>,
    rec: Arc<Recorder>,
}

impl CheckedVec {
    /// Zero-initialized checked vector of length `n`, tagged `id`.
    pub fn zeros(id: ArrayId, n: usize, rec: Arc<Recorder>) -> CheckedVec {
        CheckedVec {
            id,
            cells: (0..n).map(|_| AtomicU64::new(0)).collect(),
            rec,
        }
    }

    /// Snapshot to a plain vector.  Outside a worker context (the only
    /// place the harness calls it) the reads are not traced.
    pub fn to_vec(&self) -> Vec<f64> {
        (0..self.cells.len()).map(|j| MemAccess::get(self, j)).collect()
    }

    /// Bounds-check, yield, record: the common prefix of every access.
    /// Returns the (possibly clamped) index, or `None` for a vector
    /// with no cells at all.
    fn instr(&self, j: usize, kind: AccessKind) -> Option<usize> {
        let n = self.cells.len();
        if n == 0 {
            self.rec.violation(
                ViolationKind::OutOfBounds,
                format!("{} access at {} (len 0)", self.id.name(), j),
            );
            return None;
        }
        let j = if j < n {
            j
        } else {
            self.rec.violation(
                ViolationKind::OutOfBounds,
                format!("{} access at {} (len {})", self.id.name(), j, n),
            );
            j % n
        };
        sched::yield_here(false);
        self.rec.access(self.id, j as u32, kind);
        Some(j)
    }
}

impl MemAccess for CheckedVec {
    fn len(&self) -> usize {
        self.cells.len()
    }

    fn get(&self, j: usize) -> f64 {
        match self.instr(j, AccessKind::AtomicLoad) {
            Some(j) => f64::from_bits(self.cells[j].load(Ordering::Relaxed)),
            None => 0.0,
        }
    }

    fn set(&self, j: usize, v: f64) {
        if let Some(j) = self.instr(j, AccessKind::PlainStore) {
            self.cells[j].store(v.to_bits(), Ordering::Relaxed);
        }
    }

    fn add_atomic(&self, j: usize, delta: f64) {
        if let Some(j) = self.instr(j, AccessKind::AtomicRmw) {
            let cell = &self.cells[j];
            let mut cur = cell.load(Ordering::Relaxed);
            loop {
                let new = (f64::from_bits(cur) + delta).to_bits();
                match cell.compare_exchange_weak(
                    cur,
                    new,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => break,
                    Err(actual) => cur = actual,
                }
            }
        }
    }

    fn add_wild(&self, j: usize, delta: f64) {
        // The two halves are *separate* yield points, so the scheduler
        // can interleave a concurrent writer between the read and the
        // write-back — exactly the lost-update window Theorem 3's
        // backward-error analysis charges PASSCoDe-Wild for.
        if let Some(j) = self.instr(j, AccessKind::PlainLoad) {
            let cur = f64::from_bits(self.cells[j].load(Ordering::Relaxed));
            if let Some(j) = self.instr(j, AccessKind::PlainStore) {
                self.cells[j].store((cur + delta).to_bits(), Ordering::Relaxed);
            }
        }
    }
}

/// Instrumented twin of [`crate::solver::locks::LockTable`]: logical
/// lock state plus protocol verification.  Blocked acquires hand the
/// schedule token away instead of spinning; unsorted acquisition lists
/// are recorded as violations and then acquired in sorted order so the
/// schedule itself cannot deadlock on the broken protocol.
pub struct CheckedLocks {
    len: usize,
    held: Mutex<Vec<Option<u32>>>,
    rec: Arc<Recorder>,
}

impl CheckedLocks {
    /// A checked table of `d` feature locks reporting into `rec`.
    pub fn new(d: usize, rec: Arc<Recorder>) -> CheckedLocks {
        CheckedLocks { len: d, held: Mutex::new(vec![None; d]), rec }
    }

    fn state(&self) -> MutexGuard<'_, Vec<Option<u32>>> {
        self.held.lock().expect("lock state poisoned")
    }

    /// Whether feature lock `f` is currently held (diagnostics).
    pub fn is_held(&self, f: usize) -> bool {
        self.state().get(f).is_some_and(|s| s.is_some())
    }

    fn checked_lock_index(&self, f: u32) -> Option<usize> {
        if (f as usize) < self.len {
            return Some(f as usize);
        }
        self.rec.violation(
            ViolationKind::OutOfBounds,
            format!("lock index {} (table len {})", f, self.len),
        );
        if self.len == 0 {
            None
        } else {
            Some(f as usize % self.len)
        }
    }
}

impl LockDiscipline for CheckedLocks {
    fn len(&self) -> usize {
        self.len
    }

    fn acquire_sorted(&self, features: &[u32]) {
        let tid = sched::current_tid().unwrap_or(0) as u32;
        if !features.windows(2).all(|p| p[0] < p[1]) {
            self.rec.violation(
                ViolationKind::UnsortedLocks,
                format!("acquire_sorted got {features:?}"),
            );
        }
        // Acquire in locally sorted, deduplicated order regardless, so
        // the violation is reported without wedging the schedule.
        let mut order: Vec<u32> = features.to_vec();
        order.sort_unstable();
        order.dedup();
        for f in order {
            let Some(fi) = self.checked_lock_index(f) else {
                continue;
            };
            loop {
                sched::yield_here(false);
                let acquired = {
                    let mut h = self.state();
                    if h[fi].is_none() {
                        h[fi] = Some(tid);
                        true
                    } else {
                        false
                    }
                };
                if acquired {
                    self.rec.lock_acquired(f);
                    break;
                }
                // Blocked: hand the token to a thread that can make
                // progress.  Outside a schedule (or after a bail) fall
                // back to an OS yield so the retry cannot starve the
                // holder.
                if !sched::yield_here(true) {
                    std::thread::yield_now();
                }
            }
        }
    }

    fn release(&self, features: &[u32]) {
        let tid = sched::current_tid().unwrap_or(0) as u32;
        for &f in features {
            let Some(fi) = self.checked_lock_index(f) else {
                continue;
            };
            sched::yield_here(false);
            let owned = {
                let mut h = self.state();
                if h[fi] == Some(tid) {
                    h[fi] = None;
                    true
                } else {
                    false
                }
            };
            if owned {
                self.rec.lock_released(f);
            } else {
                self.rec.violation(
                    ViolationKind::ForeignRelease,
                    format!("release of lock {f} not held by thread {tid}"),
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chk::sched::{Scheduler, WorkerGuard};

    #[test]
    fn untraced_outside_worker_context() {
        let rec = Recorder::new(1);
        let v = CheckedVec::zeros(ArrayId::W, 4, Arc::clone(&rec));
        v.set(1, 2.5);
        assert_eq!(v.get(1), 2.5);
        let (events, violations) = rec.drain();
        assert!(events.is_empty());
        assert!(violations.is_empty());
    }

    #[test]
    fn accesses_recorded_under_context_with_clocks_and_coords() {
        let rec = Recorder::new(1);
        let v = CheckedVec::zeros(ArrayId::W, 4, Arc::clone(&rec));
        let sched = Scheduler::new(1, 1, 0, 10_000);
        let _g = WorkerGuard::install(sched, 0);
        rec.begin_update(7);
        v.add_wild(2, 1.0);
        rec.end_update();
        drop(_g);
        let (events, violations) = rec.drain();
        assert!(violations.is_empty());
        assert_eq!(events.len(), 4);
        assert_eq!(events[0], TraceEvent::UpdateBegin { tid: 0, coord: 7 });
        assert_eq!(
            events[1],
            TraceEvent::Access {
                tid: 0,
                clock: 1,
                array: ArrayId::W,
                index: 2,
                kind: AccessKind::PlainLoad,
                coord: Some(7),
            }
        );
        assert_eq!(
            events[2],
            TraceEvent::Access {
                tid: 0,
                clock: 2,
                array: ArrayId::W,
                index: 2,
                kind: AccessKind::PlainStore,
                coord: Some(7),
            }
        );
        assert_eq!(events[3], TraceEvent::UpdateEnd { tid: 0 });
    }

    #[test]
    fn out_of_bounds_is_clamped_and_reported() {
        let rec = Recorder::new(1);
        let v = CheckedVec::zeros(ArrayId::Alpha, 3, Arc::clone(&rec));
        v.set(5, 9.0); // clamps to 5 % 3 == 2
        assert_eq!(v.get(2), 9.0);
        let (_, violations) = rec.drain();
        assert_eq!(violations.len(), 1);
        assert_eq!(violations[0].kind, ViolationKind::OutOfBounds);
    }

    #[test]
    fn unchecked_accessors_still_bounds_check() {
        let rec = Recorder::new(1);
        let v = CheckedVec::zeros(ArrayId::W, 2, Arc::clone(&rec));
        // SAFETY: trivially in bounds; and the checker twin would clamp
        // + report rather than fault even if it were not.
        unsafe {
            v.add_wild_unchecked(1, 2.0);
            assert_eq!(v.get_unchecked(1), 2.0);
        }
        let (_, violations) = rec.drain();
        assert!(violations.is_empty());
    }

    #[test]
    fn unsorted_acquire_is_flagged_but_still_acquires() {
        let rec = Recorder::new(1);
        let locks = CheckedLocks::new(8, Arc::clone(&rec));
        let sched = Scheduler::new(1, 5, 0, 10_000);
        let _g = WorkerGuard::install(sched, 0);
        locks.acquire_sorted(&[3, 1]);
        assert!(locks.is_held(1) && locks.is_held(3));
        locks.release(&[1, 3]);
        assert!(!locks.is_held(1) && !locks.is_held(3));
        drop(_g);
        let (_, violations) = rec.drain();
        assert_eq!(violations.len(), 1);
        assert_eq!(violations[0].kind, ViolationKind::UnsortedLocks);
    }

    #[test]
    fn foreign_release_is_flagged() {
        let rec = Recorder::new(1);
        let locks = CheckedLocks::new(4, Arc::clone(&rec));
        let sched = Scheduler::new(1, 5, 0, 10_000);
        let _g = WorkerGuard::install(sched, 0);
        locks.release(&[2]);
        drop(_g);
        let (_, violations) = rec.drain();
        assert_eq!(violations.len(), 1);
        assert_eq!(violations[0].kind, ViolationKind::ForeignRelease);
    }
}
