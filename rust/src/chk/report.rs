//! Machine-readable check reports.
//!
//! Everything `passcode check` measures — race counts, protocol
//! violations, the measured staleness τ, and the empirical backward
//! error ‖ŵ − w̄‖/‖ŵ‖ of Theorem 3 — round-trips losslessly through the
//! repo's own JSON.  Seeds are serialized as decimal *strings* (the
//! `Checkpoint` precedent): they are full-width `u64`s and would lose
//! bits in an f64 JSON number.

use anyhow::{Context, Result};

use crate::util::Json;

/// Report format tag, bumped on breaking layout changes.
pub const REPORT_VERSION: &str = "passcode-chk-v1";

fn u64_str(v: u64) -> Json {
    Json::str(&v.to_string())
}

fn parse_u64(v: &Json, what: &str) -> Result<u64> {
    let s = v.as_str().with_context(|| format!("{what}: expected string"))?;
    s.parse::<u64>().with_context(|| format!("{what}: bad u64 {s:?}"))
}

fn get_u64(v: &Json, key: &str) -> Result<u64> {
    parse_u64(v.get(key)?, key)
}

fn get_count(v: &Json, key: &str) -> Result<u64> {
    Ok(v.get(key)?.as_usize().context(key)? as u64)
}

fn get_f64(v: &Json, key: &str) -> Result<f64> {
    v.get(key)?.as_f64().context(key)
}

fn get_str(v: &Json, key: &str) -> Result<String> {
    Ok(v.get(key)?.as_str().context(key)?.to_string())
}

/// One concrete racing pair, annotated with its replay seed.
#[derive(Clone, Debug, PartialEq)]
pub struct RaceSample {
    /// Seed of the schedule that produced the race (replays it).
    pub schedule_seed: u64,
    /// Array name (`"w"` / `"alpha"`).
    pub array: String,
    /// Racing cell index.
    pub index: u32,
    /// Earlier access: thread id.
    pub prior_tid: u32,
    /// Earlier access: coordinate id, or `-1` outside an update.
    pub prior_coord: i64,
    /// Earlier access: kind name.
    pub prior_kind: String,
    /// Later access: thread id.
    pub current_tid: u32,
    /// Later access: coordinate id, or `-1` outside an update.
    pub current_coord: i64,
    /// Later access: kind name.
    pub current_kind: String,
}

impl RaceSample {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schedule_seed", u64_str(self.schedule_seed)),
            ("array", Json::str(&self.array)),
            ("index", Json::num(self.index as f64)),
            ("prior_tid", Json::num(self.prior_tid as f64)),
            ("prior_coord", Json::num(self.prior_coord as f64)),
            ("prior_kind", Json::str(&self.prior_kind)),
            ("current_tid", Json::num(self.current_tid as f64)),
            ("current_coord", Json::num(self.current_coord as f64)),
            ("current_kind", Json::str(&self.current_kind)),
        ])
    }

    fn from_json(v: &Json) -> Result<RaceSample> {
        Ok(RaceSample {
            schedule_seed: get_u64(v, "schedule_seed")?,
            array: get_str(v, "array")?,
            index: get_count(v, "index")? as u32,
            prior_tid: get_count(v, "prior_tid")? as u32,
            prior_coord: get_f64(v, "prior_coord")? as i64,
            prior_kind: get_str(v, "prior_kind")?,
            current_tid: get_count(v, "current_tid")? as u32,
            current_coord: get_f64(v, "current_coord")? as i64,
            current_kind: get_str(v, "current_kind")?,
        })
    }
}

/// One concrete protocol violation, annotated with its replay seed.
#[derive(Clone, Debug, PartialEq)]
pub struct ViolationSample {
    /// Seed of the schedule that produced the violation (replays it).
    pub schedule_seed: u64,
    /// Thread that tripped it.
    pub tid: u32,
    /// Violation kind name (see `trace::ViolationKind`).
    pub kind: String,
    /// Human-readable specifics.
    pub detail: String,
}

impl ViolationSample {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schedule_seed", u64_str(self.schedule_seed)),
            ("tid", Json::num(self.tid as f64)),
            ("kind", Json::str(&self.kind)),
            ("detail", Json::str(&self.detail)),
        ])
    }

    fn from_json(v: &Json) -> Result<ViolationSample> {
        Ok(ViolationSample {
            schedule_seed: get_u64(v, "schedule_seed")?,
            tid: get_count(v, "tid")? as u32,
            kind: get_str(v, "kind")?,
            detail: get_str(v, "detail")?,
        })
    }
}

/// Aggregated check results for one memory model.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelReport {
    /// Memory model name (`lock` / `atomic` / `wild`).
    pub model: String,
    /// Schedules explored.
    pub schedules: u64,
    /// Schedules with at least one detected race.
    pub racy_schedules: u64,
    /// Coordinate updates that scattered, summed over schedules.
    pub updates: u64,
    /// Trace events recorded, summed over schedules.
    pub events: u64,
    /// Racing pairs detected on `w`.
    pub races_w: u64,
    /// Racing pairs detected on α.
    pub races_alpha: u64,
    /// Out-of-bounds accesses.
    pub oob: u64,
    /// Unsorted lock-acquisition violations.
    pub unsorted_locks: u64,
    /// Remaining violations (foreign releases, stuck schedules).
    pub other_violations: u64,
    /// Largest τ observed in any schedule.
    pub tau_max: u64,
    /// Mean τ over all scattering updates (all schedules pooled).
    pub tau_mean: f64,
    /// Largest ‖ŵ − w̄‖₂/‖ŵ‖₂ over schedules.
    pub eps_ratio_max: f64,
    /// Mean ‖ŵ − w̄‖₂/‖ŵ‖₂ over schedules.
    pub eps_ratio_mean: f64,
    /// Whether this model met its expectation: Lock/Atomic must be
    /// race- and violation-free; Wild must race on `w` only (and must
    /// actually race when run with ≥ 2 threads).
    pub ok: bool,
    /// Replay seed of the first schedule that broke the expectation.
    pub first_violation_seed: Option<u64>,
    /// Up to a handful of concrete races.
    pub race_samples: Vec<RaceSample>,
    /// Up to a handful of concrete violations.
    pub violation_samples: Vec<ViolationSample>,
}

impl ModelReport {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::str(&self.model)),
            ("schedules", Json::num(self.schedules as f64)),
            ("racy_schedules", Json::num(self.racy_schedules as f64)),
            ("updates", Json::num(self.updates as f64)),
            ("events", Json::num(self.events as f64)),
            ("races_w", Json::num(self.races_w as f64)),
            ("races_alpha", Json::num(self.races_alpha as f64)),
            ("oob", Json::num(self.oob as f64)),
            ("unsorted_locks", Json::num(self.unsorted_locks as f64)),
            ("other_violations", Json::num(self.other_violations as f64)),
            ("tau_max", Json::num(self.tau_max as f64)),
            ("tau_mean", Json::num(self.tau_mean)),
            ("eps_ratio_max", Json::num(self.eps_ratio_max)),
            ("eps_ratio_mean", Json::num(self.eps_ratio_mean)),
            ("ok", Json::Bool(self.ok)),
            (
                "first_violation_seed",
                match self.first_violation_seed {
                    Some(s) => u64_str(s),
                    None => Json::Null,
                },
            ),
            (
                "race_samples",
                Json::Arr(
                    self.race_samples.iter().map(|r| r.to_json()).collect(),
                ),
            ),
            (
                "violation_samples",
                Json::Arr(
                    self.violation_samples
                        .iter()
                        .map(|v| v.to_json())
                        .collect(),
                ),
            ),
        ])
    }

    fn from_json(v: &Json) -> Result<ModelReport> {
        let first_violation_seed = match v.get("first_violation_seed")? {
            Json::Null => None,
            other => Some(parse_u64(other, "first_violation_seed")?),
        };
        let race_samples = v
            .get("race_samples")?
            .as_arr()?
            .iter()
            .map(RaceSample::from_json)
            .collect::<Result<Vec<_>>>()?;
        let violation_samples = v
            .get("violation_samples")?
            .as_arr()?
            .iter()
            .map(ViolationSample::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(ModelReport {
            model: get_str(v, "model")?,
            schedules: get_count(v, "schedules")?,
            racy_schedules: get_count(v, "racy_schedules")?,
            updates: get_count(v, "updates")?,
            events: get_count(v, "events")?,
            races_w: get_count(v, "races_w")?,
            races_alpha: get_count(v, "races_alpha")?,
            oob: get_count(v, "oob")?,
            unsorted_locks: get_count(v, "unsorted_locks")?,
            other_violations: get_count(v, "other_violations")?,
            tau_max: get_count(v, "tau_max")?,
            tau_mean: get_f64(v, "tau_mean")?,
            eps_ratio_max: get_f64(v, "eps_ratio_max")?,
            eps_ratio_mean: get_f64(v, "eps_ratio_mean")?,
            ok: v.get("ok")?.as_bool()?,
            first_violation_seed,
            race_samples,
            violation_samples,
        })
    }
}

/// The full `passcode check` report: config echo + per-model results.
#[derive(Clone, Debug, PartialEq)]
pub struct CheckReport {
    /// Report format tag ([`REPORT_VERSION`]).
    pub version: String,
    /// Worker threads per schedule.
    pub threads: u64,
    /// Synthetic dataset rows.
    pub rows: u64,
    /// Synthetic dataset features.
    pub features: u64,
    /// Epochs per schedule.
    pub epochs: u64,
    /// Schedules explored per model.
    pub schedules: u64,
    /// Master seed the per-schedule seeds derive from.
    pub seed: u64,
    /// Preemption budget per schedule.
    pub preemption_bound: u64,
    /// Per-model results.
    pub models: Vec<ModelReport>,
    /// Conjunction of the per-model `ok` flags.
    pub ok: bool,
}

impl CheckReport {
    /// Serialize for `--out` / round-tripping.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("version", Json::str(&self.version)),
            ("threads", Json::num(self.threads as f64)),
            ("rows", Json::num(self.rows as f64)),
            ("features", Json::num(self.features as f64)),
            ("epochs", Json::num(self.epochs as f64)),
            ("schedules", Json::num(self.schedules as f64)),
            ("seed", u64_str(self.seed)),
            ("preemption_bound", Json::num(self.preemption_bound as f64)),
            (
                "models",
                Json::Arr(self.models.iter().map(|m| m.to_json()).collect()),
            ),
            ("ok", Json::Bool(self.ok)),
        ])
    }

    /// Deserialize a report previously produced by
    /// [`CheckReport::to_json`].
    pub fn from_json(v: &Json) -> Result<CheckReport> {
        let version = get_str(v, "version")?;
        if version != REPORT_VERSION {
            anyhow::bail!("unsupported report version {version:?}");
        }
        let models = v
            .get("models")?
            .as_arr()?
            .iter()
            .map(ModelReport::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(CheckReport {
            version,
            threads: get_count(v, "threads")?,
            rows: get_count(v, "rows")?,
            features: get_count(v, "features")?,
            epochs: get_count(v, "epochs")?,
            schedules: get_count(v, "schedules")?,
            seed: get_u64(v, "seed")?,
            preemption_bound: get_count(v, "preemption_bound")?,
            models,
            ok: v.get("ok")?.as_bool()?,
        })
    }

    /// Human-readable summary (the CLI's stdout).  Violating models
    /// print their replay seed — `passcode check --seed <that seed>
    /// --schedules 1` reproduces the exact interleaving.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(
            s,
            "memory-model check: {} schedules/model, {} threads, \
             {}x{} synthetic problem, {} epochs, seed {}",
            self.schedules,
            self.threads,
            self.rows,
            self.features,
            self.epochs,
            self.seed,
        );
        for m in &self.models {
            let _ = writeln!(
                s,
                "  {:<6} races(w)={:<5} races(α)={:<3} oob={} \
                 unsorted_locks={} other={} τ_max={} τ_mean={:.3} \
                 ‖ε‖/‖ŵ‖ max={:.3e} mean={:.3e}  [{}]",
                m.model,
                m.races_w,
                m.races_alpha,
                m.oob,
                m.unsorted_locks,
                m.other_violations,
                m.tau_max,
                m.tau_mean,
                m.eps_ratio_max,
                m.eps_ratio_mean,
                if m.ok { "ok" } else { "VIOLATION" },
            );
            if let Some(seed) = m.first_violation_seed {
                let _ = writeln!(
                    s,
                    "         replay: passcode check --model {} \
                     --schedules 1 --seed {}",
                    m.model, seed,
                );
            }
            for v in &m.violation_samples {
                let _ = writeln!(
                    s,
                    "         violation[seed {}] tid {} {}: {}",
                    v.schedule_seed, v.tid, v.kind, v.detail,
                );
            }
        }
        let _ = writeln!(
            s,
            "result: {}",
            if self.ok { "OK" } else { "VIOLATIONS DETECTED" },
        );
        s
    }
}
