//! In-crate dynamic concurrency checker for the memory-model kernels.
//!
//! `passcode check` runs the *production* update kernels
//! ([`crate::solver::kernel`]) over instrumented twins of the shared
//! state — [`trace::CheckedVec`] behind the `MemAccess` seam and
//! [`trace::CheckedLocks`] behind `LockDiscipline` — under a seeded
//! schedule-exploring executor ([`sched`], CHESS/PCT-style bounded
//! preemption), then analyzes each recorded trace with a vector-clock
//! happens-before race detector ([`vclock`], FastTrack-lite).
//!
//! The point is to *pin the paper's memory-model claims as executable
//! invariants* (PASSCoDe, Hsieh–Yu–Dhillon, ICML 2015):
//!
//! * **Lock** — ordered per-feature locks serialize conflicting writes:
//!   zero races across every explored schedule, and the §3.3
//!   sorted-acquisition (deadlock-freedom) protocol holds on every
//!   `acquire_sorted` call.
//! * **Atomic** — relaxed CAS adds on `w`: zero races (concurrent
//!   atomics are synchronization-free but not data races), matching the
//!   regime of Theorem 2's linear-convergence guarantee.
//! * **Wild** — plain read-add-store: races on `w` *by design* (and the
//!   checker demands they actually show up), but never on α (unique
//!   coordinate ownership under the §3.3 partition) and never out of
//!   bounds — the preconditions Theorem 3's backward-error analysis
//!   needs for `ŵ` to solve a nearby perturbed primal.
//!
//! Alongside race detection, each schedule measures the staleness τ
//! (foreign `w` writes landing inside an update's read→write window —
//! the delay parameter of Liu & Wright's AsySCD, arXiv:1403.3862, also
//! central to Cheung–Cole–Tao, arXiv:1811.03254) and the empirical
//! backward error `‖ŵ − w̄(α)‖₂ / ‖ŵ‖₂` of Eq. 6 / Theorem 3, and the
//! whole thing round-trips through JSON ([`report`]).
//!
//! Schedules are deterministic functions of their seed: a violation
//! report always carries the seed that reproduces it, and
//! `passcode check --model <m> --schedules 1 --seed <s>` replays the
//! exact interleaving.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::loss::{Hinge, Loss, MIN_DELTA};
use crate::solver::kernel::{
    CasKernel, LockedKernel, UpdateKernel, WildKernel,
};
use crate::solver::MemoryModel;
use crate::util::{Pcg32, SplitMix64};

pub mod report;
pub mod sched;
pub mod trace;
pub mod vclock;

pub use report::{CheckReport, ModelReport, RaceSample, ViolationSample};
pub use trace::{Violation, ViolationKind};
pub use vclock::Analysis;

use trace::{ArrayId, CheckedLocks, CheckedVec, Recorder, TraceEvent};

/// Configuration for one `passcode check` run.
#[derive(Clone, Debug)]
pub struct CheckConfig {
    /// Worker threads per schedule (≥ 1).
    pub threads: usize,
    /// Synthetic dataset rows (coordinates).
    pub rows: usize,
    /// Synthetic dataset features (≥ 2; feature 0 is shared by every
    /// row, so `w[0]` is contended in every schedule).
    pub features: usize,
    /// Epochs per schedule.
    pub epochs: usize,
    /// Schedules (seeded interleavings) explored per model.
    pub schedules: usize,
    /// Master seed; per-schedule replay seeds derive from it.
    pub seed: u64,
    /// Max random preemptions per schedule (the PCT-style bound).
    pub preemption_bound: u32,
    /// Yield-point budget per schedule (livelock/deadlock backstop).
    pub max_steps: u64,
    /// Hinge-loss penalty parameter `C`.
    pub c: f64,
}

impl Default for CheckConfig {
    fn default() -> Self {
        CheckConfig {
            threads: 3,
            rows: 9,
            features: 6,
            epochs: 2,
            schedules: 100,
            seed: 42,
            preemption_bound: 16,
            max_steps: 1 << 20,
            c: 1.0,
        }
    }
}

/// One synthetic training row with the label folded into the values
/// (the kernels compute `w·x` directly, so rows carry `y_i x_i`).
struct Row {
    idx: Vec<u32>,
    vals: Vec<f64>,
}

/// Tiny deterministic L1-SVM instance.  Every row touches feature 0
/// (guaranteed `w` contention) plus two rotating features, with values
/// varied enough that subproblem deltas stay above [`MIN_DELTA`] for
/// the first epochs.
fn synth_problem(n: usize, d: usize) -> (Vec<Row>, Vec<f64>) {
    debug_assert!(d >= 2);
    let mut rows = Vec::with_capacity(n);
    let mut qii = Vec::with_capacity(n);
    for i in 0..n {
        let mut feats = vec![0u32];
        for f in [1 + (i % (d - 1)), 1 + ((i / 2 + 1) % (d - 1))] {
            let f = f as u32;
            if !feats.contains(&f) {
                feats.push(f);
            }
        }
        feats.sort_unstable();
        let y = if i % 2 == 0 { 1.0 } else { -1.0 };
        let vals: Vec<f64> = feats
            .iter()
            .enumerate()
            .map(|(k, _)| y * (0.5 + 0.25 * ((i + k) % 4) as f64))
            .collect();
        let q: f64 = vals.iter().map(|v| v * v).sum();
        rows.push(Row { idx: feats, vals });
        qii.push(q);
    }
    (rows, qii)
}

/// Round-robin coordinate partition: block `t` owns `{i : i ≡ t mod p}`,
/// mirroring the §3.3 unique-owner property the α-race invariant needs.
fn chunk_evenly(n: usize, parts: usize) -> Vec<Vec<usize>> {
    let mut out = vec![Vec::new(); parts.max(1)];
    for i in 0..n {
        out[i % parts.max(1)].push(i);
    }
    out
}

/// Everything a checker worker needs besides its kernel.
struct WorkerArgs<'a> {
    rows: &'a [Row],
    qii: &'a [f64],
    alpha: &'a CheckedVec,
    rec: &'a Recorder,
    loss: Hinge,
    block: &'a [usize],
    epochs: usize,
    seed: u64,
    tid: usize,
}

/// The worker loop, monomorphized per kernel exactly like the real
/// solver ([`crate::solver::passcode`]): per-epoch block permutation,
/// then `begin_update → fused dot/solve/scatter → end_update` per
/// coordinate.  Returns the number of updates that scattered.
fn drive<K: UpdateKernel>(kernel: K, a: &WorkerArgs<'_>) -> u64 {
    let mut rng = Pcg32::new(a.seed, 1000 + a.tid as u64);
    let mut order: Vec<usize> = a.block.to_vec();
    let mut updates = 0u64;
    for _ in 0..a.epochs {
        rng.shuffle(&mut order);
        for &i in &order {
            a.rec.begin_update(i as u32);
            let row = &a.rows[i];
            let (alpha, loss, q) = (a.alpha, a.loss, a.qii[i]);
            let wrote = kernel.update(&row.idx, &row.vals, |wx| {
                let a_old = crate::solver::MemAccess::get(alpha, i);
                let a_new = loss.solve_subproblem(a_old, wx, q);
                let delta = a_new - a_old;
                if delta.abs() > MIN_DELTA {
                    crate::solver::MemAccess::set(alpha, i, a_new);
                    Some(delta)
                } else {
                    None
                }
            });
            if wrote {
                updates += 1;
            }
            a.rec.end_update();
        }
    }
    updates
}

/// Everything one explored schedule produced.  Two runs with the same
/// `(model, cfg, schedule_seed)` compare equal — the determinism the
/// replay workflow depends on (pinned in `tests/chk.rs`).
#[derive(Clone, Debug, PartialEq)]
pub struct ScheduleRun {
    /// The replay seed that produced this run.
    pub seed: u64,
    /// The full recorded trace, in serialized execution order.
    pub events: Vec<TraceEvent>,
    /// Protocol violations (including a `Stuck` entry when the
    /// scheduler tripped its step bound or deadlocked).
    pub violations: Vec<Violation>,
    /// Offline race + τ analysis of the trace.
    pub analysis: Analysis,
    /// Coordinate updates that scattered.
    pub updates: u64,
    /// Empirical backward error `‖ŵ − w̄(α)‖₂ / ‖ŵ‖₂` with
    /// `w̄(α) = Σ_i α_i x_i` recomputed from the final α (Eq. 6).
    pub eps_ratio: f64,
}

/// Run one seeded schedule of `model` and analyze it.
pub fn run_schedule(
    model: MemoryModel,
    cfg: &CheckConfig,
    schedule_seed: u64,
) -> ScheduleRun {
    let threads = cfg.threads.max(1);
    let d = cfg.features.max(2);
    let (rows, qii) = synth_problem(cfg.rows.max(1), d);
    let n = rows.len();

    let rec = Recorder::new(threads);
    let sched = sched::Scheduler::new(
        threads,
        schedule_seed,
        cfg.preemption_bound,
        cfg.max_steps,
    );
    let w = CheckedVec::zeros(ArrayId::W, d, Arc::clone(&rec));
    let alpha = CheckedVec::zeros(ArrayId::Alpha, n, Arc::clone(&rec));
    let locks = CheckedLocks::new(d, Arc::clone(&rec));
    let loss = Hinge::new(cfg.c);
    let blocks = chunk_evenly(n, threads);
    let total_updates = AtomicU64::new(0);

    let (rows_ref, qii_ref): (&[Row], &[f64]) = (&rows, &qii);
    let (w_ref, alpha_ref, locks_ref) = (&w, &alpha, &locks);
    let (rec_ref, updates_ref): (&Recorder, _) = (&rec, &total_updates);
    let epochs = cfg.epochs;
    std::thread::scope(|s| {
        for (tid, block) in blocks.iter().enumerate() {
            let sched = Arc::clone(&sched);
            s.spawn(move || {
                // First thing, so every later record holds the token;
                // declared first, so it drops (and hands off) last.
                let _guard = sched::WorkerGuard::install(sched, tid);
                let args = WorkerArgs {
                    rows: rows_ref,
                    qii: qii_ref,
                    alpha: alpha_ref,
                    rec: rec_ref,
                    loss,
                    block: block.as_slice(),
                    epochs,
                    seed: schedule_seed,
                    tid,
                };
                let u = match model {
                    MemoryModel::Wild => {
                        drive(WildKernel::new(w_ref), &args)
                    }
                    MemoryModel::Atomic => {
                        drive(CasKernel::new(w_ref), &args)
                    }
                    MemoryModel::Lock => {
                        drive(LockedKernel::new(w_ref, locks_ref), &args)
                    }
                };
                updates_ref.fetch_add(u, Ordering::Relaxed);
            });
        }
    });

    if sched.bailed() {
        let why = if sched.deadlocked() {
            "a blocked thread had no runnable sibling (deadlock)"
        } else {
            "the yield-point budget was exhausted (livelock?)"
        };
        rec.violation(
            ViolationKind::Stuck,
            format!("schedule stuck after {} steps: {}", sched.steps(), why),
        );
    }

    let (events, violations) = rec.drain();
    let analysis = vclock::analyze(&events, threads);

    // Backward error (Eq. 6): recompute w̄ = Σ_i α_i x_i from the final
    // α and compare with the ŵ the kernels actually produced.  Lock and
    // Atomic keep the two equal to rounding; Wild's lost updates open a
    // gap — the ε Theorem 3 charges to a perturbed primal.
    let w_hat = w.to_vec();
    let alpha_v = alpha.to_vec();
    let mut w_bar = vec![0.0f64; w_hat.len()];
    for (row, &a) in rows.iter().zip(&alpha_v) {
        for (&j, &v) in row.idx.iter().zip(&row.vals) {
            w_bar[j as usize] += a * v;
        }
    }
    let eps: f64 = w_hat
        .iter()
        .zip(&w_bar)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        .sqrt();
    let norm: f64 = w_hat.iter().map(|x| x * x).sum::<f64>().sqrt();
    let eps_ratio = eps / norm.max(1e-12);

    ScheduleRun {
        seed: schedule_seed,
        events,
        violations,
        analysis,
        updates: total_updates.load(Ordering::Relaxed),
        eps_ratio,
    }
}

/// Per-model pass/fail for one schedule: no protocol violations, and
/// races only where the model permits them (Wild: `w` only).
fn schedule_ok(model: MemoryModel, run: &ScheduleRun) -> bool {
    if !run.violations.is_empty() {
        return false;
    }
    match model {
        MemoryModel::Wild => run.analysis.races_alpha == 0,
        MemoryModel::Lock | MemoryModel::Atomic => {
            run.analysis.races_w == 0 && run.analysis.races_alpha == 0
        }
    }
}

/// Domain-separation tag so each model explores its own seed stream.
fn model_tag(model: MemoryModel) -> u64 {
    match model {
        MemoryModel::Lock => 0x4C4F_434B,   // "LOCK"
        MemoryModel::Atomic => 0x4154_4F4D, // "ATOM"
        MemoryModel::Wild => 0x5749_4C44,   // "WILD"
    }
}

/// Explore `cfg.schedules` seeded interleavings of `model` and
/// aggregate them into a [`ModelReport`].
pub fn check_model(model: MemoryModel, cfg: &CheckConfig) -> ModelReport {
    let mut seeds = SplitMix64::new(cfg.seed ^ model_tag(model));
    let mut racy_schedules = 0u64;
    let mut updates = 0u64;
    let mut events = 0u64;
    let (mut races_w, mut races_alpha) = (0u64, 0u64);
    let (mut oob, mut unsorted_locks, mut other_violations) =
        (0u64, 0u64, 0u64);
    let mut tau_max = 0u64;
    let (mut tau_sum, mut tau_n) = (0.0f64, 0u64);
    let (mut eps_max, mut eps_sum) = (0.0f64, 0.0f64);
    let mut first_violation_seed = None;
    let mut race_samples: Vec<RaceSample> = Vec::new();
    let mut violation_samples: Vec<ViolationSample> = Vec::new();
    let mut ok = true;

    for _ in 0..cfg.schedules {
        let seed = seeds.next_u64();
        let run = run_schedule(model, cfg, seed);
        if !schedule_ok(model, &run) {
            ok = false;
            if first_violation_seed.is_none() {
                first_violation_seed = Some(seed);
            }
        }
        let a = &run.analysis;
        if a.races_w + a.races_alpha > 0 {
            racy_schedules += 1;
        }
        races_w += a.races_w;
        races_alpha += a.races_alpha;
        updates += run.updates;
        events += run.events.len() as u64;
        for r in &a.samples {
            if race_samples.len() < vclock::MAX_RACE_SAMPLES {
                race_samples.push(race_sample(seed, r));
            }
        }
        for v in &run.violations {
            match v.kind {
                ViolationKind::OutOfBounds => oob += 1,
                ViolationKind::UnsortedLocks => unsorted_locks += 1,
                ViolationKind::ForeignRelease | ViolationKind::Stuck => {
                    other_violations += 1;
                }
            }
            if violation_samples.len() < vclock::MAX_RACE_SAMPLES {
                violation_samples.push(ViolationSample {
                    schedule_seed: seed,
                    tid: v.tid,
                    kind: v.kind.name().to_string(),
                    detail: v.detail.clone(),
                });
            }
        }
        tau_max = tau_max.max(a.tau_max() as u64);
        tau_sum += a.tau.iter().map(|&t| t as f64).sum::<f64>();
        tau_n += a.tau.len() as u64;
        eps_max = eps_max.max(run.eps_ratio);
        eps_sum += run.eps_ratio;
    }

    // Wild's expectation is positive, not just permissive: with real
    // concurrency its plain read-add-store *must* race on w — a silent
    // absence of races would mean the checker lost its teeth.
    let expect_races = model == MemoryModel::Wild
        && cfg.threads >= 2
        && cfg.schedules > 0
        && cfg.epochs > 0;
    if expect_races && races_w == 0 {
        ok = false;
    }

    ModelReport {
        model: model.name().to_string(),
        schedules: cfg.schedules as u64,
        racy_schedules,
        updates,
        events,
        races_w,
        races_alpha,
        oob,
        unsorted_locks,
        other_violations,
        tau_max,
        tau_mean: if tau_n > 0 { tau_sum / tau_n as f64 } else { 0.0 },
        eps_ratio_max: eps_max,
        eps_ratio_mean: if cfg.schedules > 0 {
            eps_sum / cfg.schedules as f64
        } else {
            0.0
        },
        ok,
        first_violation_seed,
        race_samples,
        violation_samples,
    }
}

fn race_sample(seed: u64, r: &vclock::Race) -> RaceSample {
    RaceSample {
        schedule_seed: seed,
        array: r.array.name().to_string(),
        index: r.index,
        prior_tid: r.prior.tid,
        prior_coord: r.prior.coord.map_or(-1, |c| c as i64),
        prior_kind: r.prior.kind.name().to_string(),
        current_tid: r.current.tid,
        current_coord: r.current.coord.map_or(-1, |c| c as i64),
        current_kind: r.current.kind.name().to_string(),
    }
}

/// Check an explicit subset of memory models (the CLI's `--model`).
pub fn run_check_models(
    cfg: &CheckConfig,
    models: &[MemoryModel],
) -> CheckReport {
    let reports: Vec<ModelReport> =
        models.iter().map(|&m| check_model(m, cfg)).collect();
    let ok = reports.iter().all(|r| r.ok);
    CheckReport {
        version: report::REPORT_VERSION.to_string(),
        threads: cfg.threads.max(1) as u64,
        rows: cfg.rows.max(1) as u64,
        features: cfg.features.max(2) as u64,
        epochs: cfg.epochs as u64,
        schedules: cfg.schedules as u64,
        seed: cfg.seed,
        preemption_bound: cfg.preemption_bound as u64,
        models: reports,
        ok,
    }
}

/// Check all three memory models — the default `passcode check`.
pub fn run_check(cfg: &CheckConfig) -> CheckReport {
    run_check_models(
        cfg,
        &[MemoryModel::Lock, MemoryModel::Atomic, MemoryModel::Wild],
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small(schedules: usize) -> CheckConfig {
        CheckConfig {
            threads: 2,
            rows: 6,
            features: 4,
            epochs: 1,
            schedules,
            seed: 11,
            ..CheckConfig::default()
        }
    }

    #[test]
    fn synth_problem_is_sorted_in_bounds_and_hot_on_feature_0() {
        let (rows, qii) = synth_problem(9, 6);
        assert_eq!(rows.len(), 9);
        for (row, &q) in rows.iter().zip(&qii) {
            assert_eq!(row.idx[0], 0);
            assert!(row.idx.windows(2).all(|p| p[0] < p[1]));
            assert!(row.idx.iter().all(|&j| j < 6));
            assert!(q > 0.0);
            assert_eq!(row.idx.len(), row.vals.len());
        }
    }

    #[test]
    fn chunk_evenly_partitions_every_coordinate_once() {
        let blocks = chunk_evenly(10, 3);
        let mut seen: Vec<usize> = blocks.concat();
        seen.sort_unstable();
        assert_eq!(seen, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn lock_schedule_is_race_and_violation_free() {
        let run = run_schedule(MemoryModel::Lock, &small(1), 99);
        assert!(run.violations.is_empty());
        assert_eq!(run.analysis.races_w, 0);
        assert_eq!(run.analysis.races_alpha, 0);
        assert!(run.updates > 0);
        assert!(run.eps_ratio < 1e-9);
    }

    #[test]
    fn atomic_schedule_is_race_free() {
        let run = run_schedule(MemoryModel::Atomic, &small(1), 99);
        assert!(run.violations.is_empty());
        assert_eq!(run.analysis.races_w, 0);
        assert_eq!(run.analysis.races_alpha, 0);
        assert!(run.eps_ratio < 1e-9);
    }

    #[test]
    fn wild_races_on_w_and_only_w() {
        // HB-unordered needs no preemption: with no lock edges, two
        // threads' plain accesses to w[0] race in *every* schedule.
        let rep = check_model(MemoryModel::Wild, &small(3));
        assert!(rep.races_w > 0);
        assert_eq!(rep.races_alpha, 0);
        assert_eq!(rep.oob, 0);
        assert!(rep.ok);
    }

    #[test]
    fn run_check_covers_all_three_models() {
        let rep = run_check(&small(2));
        let names: Vec<&str> =
            rep.models.iter().map(|m| m.model.as_str()).collect();
        assert_eq!(names, vec!["lock", "atomic", "wild"]);
        assert!(rep.ok);
    }
}
