//! Seeded schedule-exploring executor (CHESS/PCT-style).
//!
//! Worker threads run under a token-passing scheduler that keeps exactly
//! one thread runnable at a time; every instrumented shared-memory
//! access is a *yield point* where a seeded RNG may — while a bounded
//! preemption budget lasts — hand the token to another thread.  Because
//! every scheduling decision is drawn from a `Pcg32(seed)` stream over
//! logical thread sets (never from OS timing), an interleaving is
//! replayable bit-for-bit from its seed: the seed printed on a violation
//! *is* the repro.
//!
//! Blocking composes via *forced* yields: a thread that cannot make
//! progress (a checked lock held by a sibling) hands the token away
//! unconditionally and retries when rescheduled.  A step bound backstops
//! livelocks and true deadlocks — when it trips, the scheduler *bails*:
//! every yield point degrades to a no-op so all threads drain and join,
//! and the run is reported as stuck rather than wedging the process.

use std::cell::RefCell;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

use crate::util::Pcg32;

/// A preemption fires at a yield point with probability
/// `1 / PREEMPT_ONE_IN` while the budget lasts.
const PREEMPT_ONE_IN: usize = 4;

struct SchedState {
    active: usize,
    finished: Vec<bool>,
    rng: Pcg32,
    preemptions_left: u32,
    steps: u64,
    max_steps: u64,
    bail: bool,
    deadlock: bool,
}

/// Token-passing scheduler for one explored schedule.
pub struct Scheduler {
    m: Mutex<SchedState>,
    cv: Condvar,
}

impl Scheduler {
    /// A scheduler for `threads` workers replaying the interleaving
    /// drawn from `seed`, with at most `preemption_bound` random
    /// preemptions and `max_steps` total yield points (the livelock /
    /// deadlock backstop).
    pub fn new(
        threads: usize,
        seed: u64,
        preemption_bound: u32,
        max_steps: u64,
    ) -> Arc<Scheduler> {
        Arc::new(Scheduler {
            m: Mutex::new(SchedState {
                active: 0,
                finished: vec![false; threads.max(1)],
                rng: Pcg32::new(seed, 0x5CED),
                preemptions_left: preemption_bound,
                steps: 0,
                max_steps,
                bail: false,
                deadlock: false,
            }),
            cv: Condvar::new(),
        })
    }

    fn state(&self) -> MutexGuard<'_, SchedState> {
        self.m.lock().expect("scheduler state poisoned")
    }

    /// Whether the run tripped the step bound or detected a deadlock
    /// (yield points are no-ops from then on).
    pub fn bailed(&self) -> bool {
        self.state().bail
    }

    /// Whether a blocked thread found no runnable sibling to hand the
    /// token to — a deadlock under this schedule.
    pub fn deadlocked(&self) -> bool {
        self.state().deadlock
    }

    /// Yield points consumed so far (diagnostics).
    pub fn steps(&self) -> u64 {
        self.state().steps
    }

    fn pick_other(st: &mut SchedState, tid: usize) -> Option<usize> {
        let runnable: Vec<usize> = (0..st.finished.len())
            .filter(|&t| t != tid && !st.finished[t])
            .collect();
        if runnable.is_empty() {
            None
        } else {
            Some(runnable[st.rng.gen_range(runnable.len())])
        }
    }

    /// One yield point for `tid`.  Waits for the token, consumes a step,
    /// optionally hands the token away (always, when `forced`), then
    /// waits until rescheduled.  Returns `false` once the scheduler has
    /// bailed — callers in retry loops then fall back to OS yielding.
    fn yield_point(&self, tid: usize, forced: bool) -> bool {
        let mut st = self.state();
        while !st.bail && st.active != tid {
            st = self.cv.wait(st).expect("scheduler state poisoned");
        }
        if st.bail {
            return false;
        }
        st.steps += 1;
        if st.steps > st.max_steps {
            st.bail = true;
            self.cv.notify_all();
            return false;
        }
        if forced {
            match Self::pick_other(&mut st, tid) {
                Some(next) => st.active = next,
                None => {
                    // Blocked with nobody left to unblock us.
                    st.deadlock = true;
                    st.bail = true;
                    self.cv.notify_all();
                    return false;
                }
            }
            self.cv.notify_all();
        } else if st.preemptions_left > 0
            && st.rng.gen_range(PREEMPT_ONE_IN) == 0
        {
            if let Some(next) = Self::pick_other(&mut st, tid) {
                st.active = next;
                st.preemptions_left -= 1;
                self.cv.notify_all();
            }
        }
        while !st.bail && st.active != tid {
            st = self.cv.wait(st).expect("scheduler state poisoned");
        }
        !st.bail
    }

    /// Mark `tid` done and hand the token to a live sibling.
    fn finish(&self, tid: usize) {
        let mut st = self.state();
        st.finished[tid] = true;
        if st.active == tid {
            if let Some(next) = Self::pick_other(&mut st, tid) {
                st.active = next;
            }
        }
        self.cv.notify_all();
    }
}

struct WorkerCtx {
    sched: Arc<Scheduler>,
    tid: usize,
}

thread_local! {
    static WORKER: RefCell<Option<WorkerCtx>> = RefCell::new(None);
}

/// Registers the calling thread as checker worker `tid` for the guard's
/// lifetime.  Dropping (including on unwind) uninstalls the context and
/// hands the token away, so a panicking worker cannot wedge siblings.
pub struct WorkerGuard {
    sched: Arc<Scheduler>,
    tid: usize,
}

impl WorkerGuard {
    /// Install the calling thread as thread `tid` of `sched`.
    pub fn install(sched: Arc<Scheduler>, tid: usize) -> WorkerGuard {
        WORKER.with(|w| {
            *w.borrow_mut() =
                Some(WorkerCtx { sched: Arc::clone(&sched), tid });
        });
        WorkerGuard { sched, tid }
    }
}

impl Drop for WorkerGuard {
    fn drop(&mut self) {
        WORKER.with(|w| {
            *w.borrow_mut() = None;
        });
        self.sched.finish(self.tid);
    }
}

/// The checker thread id of the calling thread, if one is installed.
/// Instrumented structures record nothing outside a worker context, so
/// setup and teardown on the main thread stay out of the trace.
pub fn current_tid() -> Option<usize> {
    WORKER.with(|w| w.borrow().as_ref().map(|c| c.tid))
}

/// Scheduler yield point for the calling thread.  `forced` means the
/// thread cannot progress (blocked on a checked lock) and must hand the
/// token away.  Returns `false` when uninstrumented or after a bail —
/// retry loops then fall back to [`std::thread::yield_now`].
pub fn yield_here(forced: bool) -> bool {
    WORKER.with(|w| {
        let b = w.borrow();
        match b.as_ref() {
            Some(c) => c.sched.yield_point(c.tid, forced),
            None => false,
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn yield_without_context_is_a_noop() {
        assert!(!yield_here(false));
        assert_eq!(current_tid(), None);
    }

    #[test]
    fn single_thread_guard_schedules_and_uninstalls() {
        let sched = Scheduler::new(1, 7, 4, 1000);
        {
            let _g = WorkerGuard::install(Arc::clone(&sched), 0);
            assert_eq!(current_tid(), Some(0));
            for _ in 0..10 {
                assert!(yield_here(false));
            }
        }
        assert_eq!(current_tid(), None);
        assert!(!sched.bailed());
        assert_eq!(sched.steps(), 10);
    }

    #[test]
    fn step_bound_trips_to_bail() {
        let sched = Scheduler::new(1, 3, 0, 5);
        let _g = WorkerGuard::install(Arc::clone(&sched), 0);
        for _ in 0..5 {
            assert!(yield_here(false));
        }
        assert!(!yield_here(false));
        assert!(sched.bailed());
        assert!(!sched.deadlocked());
    }

    #[test]
    fn forced_yield_with_no_sibling_is_deadlock() {
        let sched = Scheduler::new(1, 3, 0, 100);
        let _g = WorkerGuard::install(Arc::clone(&sched), 0);
        assert!(!yield_here(true));
        assert!(sched.deadlocked());
    }
}
