//! LIBSVM text format reader/writer.
//!
//! Format: one instance per line, `label idx:val idx:val ...`, indices
//! 1-based.  This is the format the paper's datasets (news20, rcv1, …)
//! ship in; implementing it means real datasets drop into this repo
//! unchanged even though the experiments here run on synthetic analogs.

use std::io::{BufRead, BufReader, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::dataset::Dataset;
use super::sparse::{CsrMatrix, Entry};

/// Parse LIBSVM text into a (folded) [`Dataset`].
///
/// Labels may be any of `+1/-1/1/0` (0 is mapped to −1, the common
/// convention for binary LIBSVM exports); indices are 1-based and must be
/// strictly increasing per line.  `min_cols` lets callers force a feature
/// space wider than the data (e.g. to align train/test).
pub fn parse_reader<R: Read>(
    reader: R,
    name: &str,
    min_cols: usize,
) -> Result<Dataset> {
    let mut rows: Vec<Vec<Entry>> = Vec::new();
    let mut labels: Vec<f64> = Vec::new();
    let mut max_col = min_cols;
    for (lineno, line) in BufReader::new(reader).lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_ascii_whitespace();
        let label_tok = parts.next().context("empty line slipped through")?;
        let label: f64 = match label_tok {
            "+1" | "1" | "1.0" => 1.0,
            "-1" | "-1.0" => -1.0,
            "0" | "0.0" => -1.0,
            other => {
                let v: f64 = other.parse().with_context(|| {
                    format!("line {}: bad label {other:?}", lineno + 1)
                })?;
                if v > 0.0 {
                    1.0
                } else {
                    -1.0
                }
            }
        };
        let mut entries: Vec<Entry> = Vec::new();
        let mut prev: i64 = -1;
        for tok in parts {
            let (idx_s, val_s) = tok.split_once(':').with_context(|| {
                format!("line {}: expected idx:val, got {tok:?}", lineno + 1)
            })?;
            let idx1: u64 = idx_s.parse().with_context(|| {
                format!("line {}: bad index {idx_s:?}", lineno + 1)
            })?;
            if idx1 == 0 {
                bail!("line {}: LIBSVM indices are 1-based", lineno + 1);
            }
            let idx = (idx1 - 1) as u32;
            if (idx as i64) <= prev {
                bail!("line {}: indices not strictly increasing", lineno + 1);
            }
            prev = idx as i64;
            let val: f64 = val_s.parse().with_context(|| {
                format!("line {}: bad value {val_s:?}", lineno + 1)
            })?;
            // Fold the label in as we read (paper convention).
            entries.push(Entry { index: idx, value: label * val });
            max_col = max_col.max(idx as usize + 1);
        }
        rows.push(entries);
        labels.push(label);
    }
    Ok(Dataset::new(CsrMatrix::from_rows(&rows, max_col), labels, name))
}

/// Load a LIBSVM file from disk.
pub fn load(path: impl AsRef<Path>) -> Result<Dataset> {
    let path = path.as_ref();
    let f = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "libsvm".into());
    parse_reader(f, &name, 0)
}

/// Write a dataset back out in LIBSVM format (values un-folded).
pub fn write<W: Write>(ds: &Dataset, mut out: W) -> Result<()> {
    for i in 0..ds.n() {
        let y = ds.y[i];
        write!(out, "{}", if y > 0.0 { "+1" } else { "-1" })?;
        let (idx, vals) = ds.x.row(i);
        for (j, v) in idx.iter().zip(vals) {
            // un-fold: stored value = y * raw
            write!(out, " {}:{}", j + 1, v / y)?;
        }
        writeln!(out)?;
    }
    Ok(())
}

/// Save to a file.
pub fn save(ds: &Dataset, path: impl AsRef<Path>) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())?;
    write(ds, std::io::BufWriter::new(f))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
+1 1:0.5 3:2.0
-1 2:1.0
# comment line

+1 1:1.0 2:1.0 4:4.0
";

    #[test]
    fn parses_basic_file() {
        let ds = parse_reader(SAMPLE.as_bytes(), "t", 0).unwrap();
        assert_eq!(ds.n(), 3);
        assert_eq!(ds.d(), 4);
        assert_eq!(ds.y, vec![1.0, -1.0, 1.0]);
        // folding: row 1 (label -1) stores -1 * 1.0 at col index 1
        let (idx, vals) = ds.x.row(1);
        assert_eq!(idx, &[1]);
        assert_eq!(vals, &[-1.0]);
    }

    #[test]
    fn zero_label_maps_to_negative() {
        let ds = parse_reader("0 1:2.0\n".as_bytes(), "t", 0).unwrap();
        assert_eq!(ds.y, vec![-1.0]);
        let (_, vals) = ds.x.row(0);
        assert_eq!(vals, &[-2.0]);
    }

    #[test]
    fn min_cols_expands_feature_space() {
        let ds = parse_reader("+1 1:1\n".as_bytes(), "t", 10).unwrap();
        assert_eq!(ds.d(), 10);
    }

    #[test]
    fn roundtrip_preserves_data() {
        let ds = parse_reader(SAMPLE.as_bytes(), "t", 0).unwrap();
        let mut buf = Vec::new();
        write(&ds, &mut buf).unwrap();
        let ds2 = parse_reader(buf.as_slice(), "t2", 0).unwrap();
        assert_eq!(ds.y, ds2.y);
        assert_eq!(ds.x.nnz(), ds2.x.nnz());
        for i in 0..ds.n() {
            assert_eq!(ds.x.row(i), ds2.x.row(i));
        }
    }

    #[test]
    fn rejects_zero_index() {
        assert!(parse_reader("+1 0:1.0\n".as_bytes(), "t", 0).is_err());
    }

    #[test]
    fn rejects_unsorted_indices() {
        assert!(parse_reader("+1 3:1.0 2:1.0\n".as_bytes(), "t", 0).is_err());
    }

    #[test]
    fn rejects_malformed_pair() {
        assert!(parse_reader("+1 3=1.0\n".as_bytes(), "t", 0).is_err());
    }

    #[test]
    fn save_and_load_file() {
        let ds = parse_reader(SAMPLE.as_bytes(), "t", 0).unwrap();
        let dir = std::env::temp_dir().join("passcode_libsvm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sample.svm");
        save(&ds, &path).unwrap();
        let ds2 = load(&path).unwrap();
        assert_eq!(ds2.n(), 3);
        assert_eq!(ds2.name, "sample");
        std::fs::remove_file(&path).ok();
    }
}
