//! Compressed sparse row (CSR) matrix — the data substrate every solver
//! walks.  Indices are `u32` (paper-scale feature spaces fit), values
//! `f64` (the solvers accumulate in double precision like LIBLINEAR).

/// One nonzero entry of a sparse row.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Entry {
    pub index: u32,
    pub value: f64,
}

/// Below this many nonzeros [`CsrMatrix::transpose_dot`] stays serial:
/// thread spawns plus per-thread dense partials cost more than the scan.
const PAR_TRANSPOSE_MIN_NNZ: usize = 1 << 17;

/// Fixed chunk count for the parallel [`CsrMatrix::transpose_dot`] path.
/// Deliberately *not* derived from `available_parallelism`: the chunk
/// boundaries set the float reduction order, and a fixed count keeps
/// `w̄ = X^T α` — and every evaluation number derived from it —
/// identical across machines for a given seed + config.
const PAR_TRANSPOSE_CHUNKS: usize = 8;

/// 4-way unrolled sparse·dense dot with independent accumulators, so the
/// gathers pipeline and the FMAs do not serialize on one add chain — the
/// shared inner primitive behind [`CsrMatrix::row_dot_dense`] and the
/// solver kernels (`solver::kernel`).
///
/// # Safety
/// Every `idx[k] as usize` must be `< w.len()`.
#[inline]
pub unsafe fn dot_sparse_unchecked(idx: &[u32], vals: &[f64], w: &[f64]) -> f64 {
    debug_assert_eq!(idx.len(), vals.len());
    debug_assert!(idx.iter().all(|&j| (j as usize) < w.len()));
    let mut i4 = idx.chunks_exact(4);
    let mut v4 = vals.chunks_exact(4);
    let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    for (js, vs) in (&mut i4).zip(&mut v4) {
        // SAFETY: the caller guarantees every index is `< w.len()`.
        unsafe {
            a0 += w.get_unchecked(js[0] as usize) * vs[0];
            a1 += w.get_unchecked(js[1] as usize) * vs[1];
            a2 += w.get_unchecked(js[2] as usize) * vs[2];
            a3 += w.get_unchecked(js[3] as usize) * vs[3];
        }
    }
    let mut acc = (a0 + a2) + (a1 + a3);
    for (j, v) in i4.remainder().iter().zip(v4.remainder()) {
        // SAFETY: as above.
        acc += unsafe { w.get_unchecked(*j as usize) } * v;
    }
    acc
}

/// Bounds-tolerant unrolled sparse·dense dot: indices outside `w` simply
/// contribute zero.  The serving margin (`coordinator::model_io::Model`)
/// uses this — incoming rows may reference features the model never saw.
#[inline]
pub fn dot_sparse_checked(idx: &[u32], vals: &[f64], w: &[f64]) -> f64 {
    let n = idx.len().min(vals.len());
    let (idx, vals) = (&idx[..n], &vals[..n]);
    let mut i4 = idx.chunks_exact(4);
    let mut v4 = vals.chunks_exact(4);
    let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    let at = |j: u32| w.get(j as usize).copied().unwrap_or(0.0);
    for (js, vs) in (&mut i4).zip(&mut v4) {
        a0 += at(js[0]) * vs[0];
        a1 += at(js[1]) * vs[1];
        a2 += at(js[2]) * vs[2];
        a3 += at(js[3]) * vs[3];
    }
    let mut acc = (a0 + a2) + (a1 + a3);
    for (j, v) in i4.remainder().iter().zip(v4.remainder()) {
        acc += at(*j) * v;
    }
    acc
}

/// CSR sparse matrix.
#[derive(Debug, Clone, Default)]
pub struct CsrMatrix {
    /// Row start offsets, length `rows + 1`.
    indptr: Vec<usize>,
    /// Column indices, CSR order (strictly increasing within a row).
    indices: Vec<u32>,
    /// Nonzero values, parallel to `indices`.
    values: Vec<f64>,
    /// Number of columns.
    cols: usize,
    /// Memoized row squared norms ([`CsrMatrix::row_sqnorms_cached`]);
    /// reset by the one mutating method (`normalize_rows_to_unit_max`).
    sqnorms: std::sync::OnceLock<Vec<f64>>,
}

impl CsrMatrix {
    /// Build from per-row entry lists. Column count is `cols`; every index
    /// must be `< cols` and strictly increasing within a row.
    pub fn from_rows(rows: &[Vec<Entry>], cols: usize) -> Self {
        let mut indptr = Vec::with_capacity(rows.len() + 1);
        let nnz: usize = rows.iter().map(|r| r.len()).sum();
        let mut indices = Vec::with_capacity(nnz);
        let mut values = Vec::with_capacity(nnz);
        indptr.push(0);
        for row in rows {
            let mut prev: i64 = -1;
            for e in row {
                assert!(
                    (e.index as usize) < cols,
                    "index {} out of bounds (cols={cols})",
                    e.index
                );
                assert!(
                    (e.index as i64) > prev,
                    "indices must be strictly increasing within a row"
                );
                prev = e.index as i64;
                indices.push(e.index);
                values.push(e.value);
            }
            indptr.push(indices.len());
        }
        Self { indptr, indices, values, cols, sqnorms: Default::default() }
    }

    /// Build directly from raw CSR arrays (trusted caller).
    pub fn from_raw(
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f64>,
        cols: usize,
    ) -> Self {
        assert!(!indptr.is_empty());
        assert_eq!(indices.len(), values.len());
        assert_eq!(*indptr.last().unwrap(), indices.len());
        Self { indptr, indices, values, cols, sqnorms: Default::default() }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.indptr.len() - 1
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Average nonzeros per row (the paper's `d̄` in Table 3).
    pub fn avg_nnz(&self) -> f64 {
        if self.rows() == 0 {
            0.0
        } else {
            self.nnz() as f64 / self.rows() as f64
        }
    }

    /// Index/value slices of row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let (s, e) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[s..e], &self.values[s..e])
    }

    #[inline]
    pub fn row_nnz(&self, i: usize) -> usize {
        self.indptr[i + 1] - self.indptr[i]
    }

    /// Squared 2-norm of row `i`.
    pub fn row_sqnorm(&self, i: usize) -> f64 {
        let (_, vals) = self.row(i);
        vals.iter().map(|v| v * v).sum()
    }

    /// All row squared norms (the `Q_ii = ||x_i||^2` precomputation of
    /// Algorithm 1; one pass over the data, counted as init time).
    pub fn all_row_sqnorms(&self) -> Vec<f64> {
        (0..self.rows()).map(|i| self.row_sqnorm(i)).collect()
    }

    /// Memoized view of [`CsrMatrix::all_row_sqnorms`]: computed on the
    /// first call, shared afterwards.  Solver `TrainSession`s re-enter
    /// the cores once per epoch and must not pay the O(nnz) norm pass
    /// each time; repeated `solve` calls over one dataset benefit too.
    pub fn row_sqnorms_cached(&self) -> &[f64] {
        self.sqnorms.get_or_init(|| self.all_row_sqnorms())
    }

    /// Sparse dot `x_i . w` against a dense vector.
    ///
    /// Hot path of every solver (O(nnz/n) per coordinate update).  The
    /// gather is unchecked: indices are validated once at construction
    /// (`from_rows`) against `cols`, and `w.len() >= cols` is asserted
    /// here — see EXPERIMENTS.md §Perf.
    #[inline]
    pub fn row_dot_dense(&self, i: usize, w: &[f64]) -> f64 {
        assert!(w.len() >= self.cols);
        let (idx, vals) = self.row(i);
        // SAFETY: `*j < cols ≤ w.len()` enforced at construction.
        unsafe { dot_sparse_unchecked(idx, vals, w) }
    }

    /// `w_out = X^T a` (dense output), used to materialize `w̄ = Σ α_i x_i`.
    ///
    /// Parallelized over row chunks with per-thread partial accumulators
    /// once the matrix is large enough to amortize the thread spawns —
    /// this runs on every evaluation snapshot (`wbar_from_alpha`,
    /// backward-error eval) and was O(nnz) serial.  The chunk count and
    /// reduction order are fixed constants (not `available_parallelism`),
    /// so results are bit-identical across machines and calls.
    pub fn transpose_dot(&self, a: &[f64]) -> Vec<f64> {
        assert_eq!(a.len(), self.rows());
        let chunks = if self.nnz() >= PAR_TRANSPOSE_MIN_NNZ {
            PAR_TRANSPOSE_CHUNKS.min(self.rows().max(1))
        } else {
            1
        };
        if chunks <= 1 {
            return self.transpose_dot_range(a, 0, self.rows());
        }
        let rows = self.rows();
        let per = rows / chunks;
        let rem = rows % chunks;
        std::thread::scope(|s| {
            let mut start = 0;
            let handles: Vec<_> = (0..chunks)
                .map(|t| {
                    let len = per + usize::from(t < rem);
                    let (lo, hi) = (start, start + len);
                    start = hi;
                    s.spawn(move || self.transpose_dot_range(a, lo, hi))
                })
                .collect();
            let mut w = vec![0.0; self.cols];
            for h in handles {
                let part = h.join().expect("transpose_dot worker panicked");
                for (acc, x) in w.iter_mut().zip(&part) {
                    *acc += x;
                }
            }
            w
        })
    }

    /// Serial scatter of rows `lo..hi` of `X^T a` into a full-width
    /// output (the per-chunk body of [`CsrMatrix::transpose_dot`]).
    fn transpose_dot_range(&self, a: &[f64], lo: usize, hi: usize) -> Vec<f64> {
        let mut w = vec![0.0; self.cols];
        for i in lo..hi {
            let ai = a[i];
            if ai == 0.0 {
                continue;
            }
            let (idx, vals) = self.row(i);
            for (j, v) in idx.iter().zip(vals) {
                w[*j as usize] += ai * v;
            }
        }
        w
    }

    /// Dense margins `m = X w`.
    pub fn dot_dense(&self, w: &[f64]) -> Vec<f64> {
        (0..self.rows()).map(|i| self.row_dot_dense(i, w)).collect()
    }

    /// Scale every row to at most unit 2-norm if `max > 1`, matching the
    /// paper's `R_max = 1` normalization assumption. Returns the scaling
    /// factor applied (1.0 if none).
    pub fn normalize_rows_to_unit_max(&mut self) -> f64 {
        let max_sq = (0..self.rows())
            .map(|i| self.row_sqnorm(i))
            .fold(0.0_f64, f64::max);
        if max_sq <= 1.0 || max_sq == 0.0 {
            return 1.0;
        }
        let scale = 1.0 / max_sq.sqrt();
        for v in &mut self.values {
            *v *= scale;
        }
        // Values changed: drop any memoized norms.
        self.sqnorms = Default::default();
        scale
    }

    /// Materialize row `i` into a dense f32 buffer (runtime eval path).
    pub fn write_row_dense_f32(&self, i: usize, out: &mut [f32]) {
        out.fill(0.0);
        let (idx, vals) = self.row(i);
        for (j, v) in idx.iter().zip(vals) {
            out[*j as usize] = *v as f32;
        }
    }

    /// Select a subset of rows into a new matrix (dataset splits).
    pub fn select_rows(&self, rows: &[usize]) -> CsrMatrix {
        let mut indptr = Vec::with_capacity(rows.len() + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for &i in rows {
            let (idx, vals) = self.row(i);
            indices.extend_from_slice(idx);
            values.extend_from_slice(vals);
            indptr.push(indices.len());
        }
        CsrMatrix {
            indptr,
            indices,
            values,
            cols: self.cols,
            sqnorms: Default::default(),
        }
    }

    /// Documents-containing-feature count per column (document frequency)
    /// — the statistic the feature-locality remap orders by.
    pub fn col_doc_frequency(&self) -> Vec<u32> {
        let mut df = vec![0u32; self.cols];
        for j in &self.indices {
            df[*j as usize] += 1;
        }
        df
    }

    /// Relabel columns through `forward` (`forward[old] = new`, a
    /// permutation of `0..cols`) and re-sort each row by the new index.
    /// Row membership, values, and norms are unchanged; only the column
    /// order moves — see [`crate::data::FeatureRemap`].
    pub fn remap_columns(&self, forward: &[u32]) -> CsrMatrix {
        assert_eq!(forward.len(), self.cols, "remap dimension");
        let mut indices = Vec::with_capacity(self.nnz());
        let mut values = Vec::with_capacity(self.nnz());
        let mut scratch: Vec<(u32, f64)> = Vec::new();
        for i in 0..self.rows() {
            let (idx, vals) = self.row(i);
            scratch.clear();
            scratch.extend(
                idx.iter().zip(vals).map(|(j, v)| (forward[*j as usize], *v)),
            );
            scratch.sort_unstable_by_key(|e| e.0);
            for (j, v) in &scratch {
                indices.push(*j);
                values.push(*v);
            }
        }
        CsrMatrix {
            indptr: self.indptr.clone(),
            indices,
            values,
            cols: self.cols,
            sqnorms: Default::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CsrMatrix {
        // [[1, 0, 2],
        //  [0, 3, 0],
        //  [0, 0, 0]]
        CsrMatrix::from_rows(
            &[
                vec![Entry { index: 0, value: 1.0 }, Entry { index: 2, value: 2.0 }],
                vec![Entry { index: 1, value: 3.0 }],
                vec![],
            ],
            3,
        )
    }

    #[test]
    fn dims_and_nnz() {
        let m = sample();
        assert_eq!(m.rows(), 3);
        assert_eq!(m.cols(), 3);
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.row_nnz(0), 2);
        assert_eq!(m.row_nnz(2), 0);
        assert!((m.avg_nnz() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn row_access() {
        let m = sample();
        let (idx, vals) = m.row(0);
        assert_eq!(idx, &[0, 2]);
        assert_eq!(vals, &[1.0, 2.0]);
    }

    #[test]
    fn sqnorms() {
        let m = sample();
        assert_eq!(m.row_sqnorm(0), 5.0);
        assert_eq!(m.all_row_sqnorms(), vec![5.0, 9.0, 0.0]);
        // Memoized view agrees and is stable across calls.
        assert_eq!(m.row_sqnorms_cached(), &[5.0, 9.0, 0.0]);
        assert_eq!(m.row_sqnorms_cached(), &[5.0, 9.0, 0.0]);
    }

    #[test]
    fn dots() {
        let m = sample();
        let w = [1.0, 2.0, 3.0];
        assert_eq!(m.row_dot_dense(0, &w), 7.0);
        assert_eq!(m.dot_dense(&w), vec![7.0, 6.0, 0.0]);
    }

    #[test]
    fn transpose_dot_matches_manual() {
        let m = sample();
        let a = [2.0, -1.0, 5.0];
        // X^T a = [2*1, -1*3, 2*2] = [2, -3, 4]
        assert_eq!(m.transpose_dot(&a), vec![2.0, -3.0, 4.0]);
    }

    #[test]
    fn normalization_caps_max_row_norm() {
        let mut m = sample();
        let s = m.normalize_rows_to_unit_max();
        assert!(s < 1.0);
        let max = (0..m.rows())
            .map(|i| m.row_sqnorm(i))
            .fold(0.0_f64, f64::max);
        assert!((max - 1.0).abs() < 1e-12);
    }

    #[test]
    fn normalization_noop_when_already_unit() {
        let mut m = CsrMatrix::from_rows(
            &[vec![Entry { index: 0, value: 0.6 }, Entry { index: 1, value: 0.8 }]],
            2,
        );
        assert_eq!(m.normalize_rows_to_unit_max(), 1.0);
    }

    #[test]
    fn dense_row_materialization() {
        let m = sample();
        let mut buf = vec![9f32; 3];
        m.write_row_dense_f32(0, &mut buf);
        assert_eq!(buf, vec![1.0, 0.0, 2.0]);
    }

    #[test]
    fn select_rows_subsets() {
        let m = sample();
        let s = m.select_rows(&[2, 0]);
        assert_eq!(s.rows(), 2);
        assert_eq!(s.row_nnz(0), 0);
        let (idx, _) = s.row(1);
        assert_eq!(idx, &[0, 2]);
    }

    #[test]
    fn unrolled_dot_matches_scalar_reference() {
        // Cross length-mod-4 boundaries: 0..=9 nonzeros per row.
        for n in 0..10usize {
            let idx: Vec<u32> = (0..n as u32).map(|k| k * 2).collect();
            let vals: Vec<f64> = (0..n).map(|k| 0.5 + k as f64).collect();
            let w: Vec<f64> = (0..20).map(|k| (k as f64) - 7.5).collect();
            let want: f64 = idx
                .iter()
                .zip(&vals)
                .map(|(j, v)| w[*j as usize] * v)
                .sum();
            // SAFETY: all indices are `< 20 == w.len()` by construction.
            let got = unsafe { dot_sparse_unchecked(&idx, &vals, &w) };
            assert!((got - want).abs() < 1e-12, "n={n}: {got} vs {want}");
            assert!(
                (dot_sparse_checked(&idx, &vals, &w) - want).abs() < 1e-12
            );
        }
    }

    #[test]
    fn checked_dot_ignores_out_of_range() {
        let w = [2.0, 3.0];
        assert_eq!(dot_sparse_checked(&[0, 9], &[1.0, 100.0], &w), 2.0);
    }

    #[test]
    fn doc_frequency_counts_columns() {
        let m = sample();
        assert_eq!(m.col_doc_frequency(), vec![1, 1, 1]);
        let m2 = CsrMatrix::from_rows(
            &[
                vec![Entry { index: 0, value: 1.0 }, Entry { index: 1, value: 1.0 }],
                vec![Entry { index: 1, value: 2.0 }],
            ],
            3,
        );
        assert_eq!(m2.col_doc_frequency(), vec![1, 2, 0]);
    }

    #[test]
    fn remap_columns_permutes_and_keeps_rows_sorted() {
        let m = sample();
        // forward: 0->2, 1->0, 2->1
        let r = m.remap_columns(&[2, 0, 1]);
        assert_eq!(r.rows(), 3);
        assert_eq!(r.cols(), 3);
        assert_eq!(r.nnz(), 3);
        // row 0 was [(0,1.0),(2,2.0)] -> new cols [(2,1.0),(1,2.0)],
        // re-sorted to [(1,2.0),(2,1.0)].
        let (idx, vals) = r.row(0);
        assert_eq!(idx, &[1, 2]);
        assert_eq!(vals, &[2.0, 1.0]);
        // row 1 was [(1,3.0)] -> [(0,3.0)].
        assert_eq!(r.row(1), (&[0u32][..], &[3.0f64][..]));
        // norms unchanged.
        assert_eq!(r.all_row_sqnorms(), m.all_row_sqnorms());
    }

    #[test]
    fn transpose_dot_parallel_path_matches_serial() {
        // Build a matrix big enough to cross PAR_TRANSPOSE_MIN_NNZ.
        let cols = 64usize;
        let rows: Vec<Vec<Entry>> = (0..(PAR_TRANSPOSE_MIN_NNZ / 4))
            .map(|i| {
                // k*13 mod 64 is distinct for k in 0..4, so each row has
                // four distinct indices; sort to satisfy CSR order.
                let mut row: Vec<Entry> = (0..4usize)
                    .map(|k| Entry {
                        index: ((i * 7 + k * 13) % cols) as u32,
                        value: ((i + k) % 5) as f64 - 2.0,
                    })
                    .collect();
                row.sort_by_key(|e| e.index);
                row
            })
            .collect();
        let m = CsrMatrix::from_rows(&rows, cols);
        let a: Vec<f64> = (0..m.rows()).map(|i| (i % 3) as f64 - 1.0).collect();
        let serial = m.transpose_dot_range(&a, 0, m.rows());
        let parallel = m.transpose_dot(&a);
        let err = serial
            .iter()
            .zip(&parallel)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f64, f64::max);
        assert!(err < 1e-9, "parallel transpose_dot diverged: {err}");
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn rejects_out_of_bounds_index() {
        CsrMatrix::from_rows(&[vec![Entry { index: 5, value: 1.0 }]], 3);
    }

    #[test]
    #[should_panic(expected = "strictly increasing")]
    fn rejects_unsorted_row() {
        CsrMatrix::from_rows(
            &[vec![
                Entry { index: 2, value: 1.0 },
                Entry { index: 1, value: 1.0 },
            ]],
            3,
        );
    }
}
