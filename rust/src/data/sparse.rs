//! Compressed sparse row (CSR) matrix — the data substrate every solver
//! walks.  Indices are `u32` (paper-scale feature spaces fit), values
//! `f64` (the solvers accumulate in double precision like LIBLINEAR).

/// One nonzero entry of a sparse row.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Entry {
    pub index: u32,
    pub value: f64,
}

/// CSR sparse matrix.
#[derive(Debug, Clone, Default)]
pub struct CsrMatrix {
    /// Row start offsets, length `rows + 1`.
    indptr: Vec<usize>,
    /// Column indices, CSR order (strictly increasing within a row).
    indices: Vec<u32>,
    /// Nonzero values, parallel to `indices`.
    values: Vec<f64>,
    /// Number of columns.
    cols: usize,
    /// Memoized row squared norms ([`CsrMatrix::row_sqnorms_cached`]);
    /// reset by the one mutating method (`normalize_rows_to_unit_max`).
    sqnorms: std::sync::OnceLock<Vec<f64>>,
}

impl CsrMatrix {
    /// Build from per-row entry lists. Column count is `cols`; every index
    /// must be `< cols` and strictly increasing within a row.
    pub fn from_rows(rows: &[Vec<Entry>], cols: usize) -> Self {
        let mut indptr = Vec::with_capacity(rows.len() + 1);
        let nnz: usize = rows.iter().map(|r| r.len()).sum();
        let mut indices = Vec::with_capacity(nnz);
        let mut values = Vec::with_capacity(nnz);
        indptr.push(0);
        for row in rows {
            let mut prev: i64 = -1;
            for e in row {
                assert!(
                    (e.index as usize) < cols,
                    "index {} out of bounds (cols={cols})",
                    e.index
                );
                assert!(
                    (e.index as i64) > prev,
                    "indices must be strictly increasing within a row"
                );
                prev = e.index as i64;
                indices.push(e.index);
                values.push(e.value);
            }
            indptr.push(indices.len());
        }
        Self { indptr, indices, values, cols, sqnorms: Default::default() }
    }

    /// Build directly from raw CSR arrays (trusted caller).
    pub fn from_raw(
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f64>,
        cols: usize,
    ) -> Self {
        assert!(!indptr.is_empty());
        assert_eq!(indices.len(), values.len());
        assert_eq!(*indptr.last().unwrap(), indices.len());
        Self { indptr, indices, values, cols, sqnorms: Default::default() }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.indptr.len() - 1
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Average nonzeros per row (the paper's `d̄` in Table 3).
    pub fn avg_nnz(&self) -> f64 {
        if self.rows() == 0 {
            0.0
        } else {
            self.nnz() as f64 / self.rows() as f64
        }
    }

    /// Index/value slices of row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let (s, e) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[s..e], &self.values[s..e])
    }

    #[inline]
    pub fn row_nnz(&self, i: usize) -> usize {
        self.indptr[i + 1] - self.indptr[i]
    }

    /// Squared 2-norm of row `i`.
    pub fn row_sqnorm(&self, i: usize) -> f64 {
        let (_, vals) = self.row(i);
        vals.iter().map(|v| v * v).sum()
    }

    /// All row squared norms (the `Q_ii = ||x_i||^2` precomputation of
    /// Algorithm 1; one pass over the data, counted as init time).
    pub fn all_row_sqnorms(&self) -> Vec<f64> {
        (0..self.rows()).map(|i| self.row_sqnorm(i)).collect()
    }

    /// Memoized view of [`CsrMatrix::all_row_sqnorms`]: computed on the
    /// first call, shared afterwards.  Solver `TrainSession`s re-enter
    /// the cores once per epoch and must not pay the O(nnz) norm pass
    /// each time; repeated `solve` calls over one dataset benefit too.
    pub fn row_sqnorms_cached(&self) -> &[f64] {
        self.sqnorms.get_or_init(|| self.all_row_sqnorms())
    }

    /// Sparse dot `x_i . w` against a dense vector.
    ///
    /// Hot path of every solver (O(nnz/n) per coordinate update).  The
    /// gather is unchecked: indices are validated once at construction
    /// (`from_rows`) against `cols`, and `w.len() == cols` is asserted
    /// here — see EXPERIMENTS.md §Perf iteration 2.
    #[inline]
    pub fn row_dot_dense(&self, i: usize, w: &[f64]) -> f64 {
        debug_assert!(w.len() >= self.cols);
        let (idx, vals) = self.row(i);
        let mut acc = 0.0;
        for (j, v) in idx.iter().zip(vals) {
            // SAFETY: `*j < cols ≤ w.len()` enforced at construction.
            acc += unsafe { w.get_unchecked(*j as usize) } * v;
        }
        acc
    }

    /// `w_out = X^T a` (dense output), used to materialize `w̄ = Σ α_i x_i`.
    pub fn transpose_dot(&self, a: &[f64]) -> Vec<f64> {
        assert_eq!(a.len(), self.rows());
        let mut w = vec![0.0; self.cols];
        for i in 0..self.rows() {
            let ai = a[i];
            if ai == 0.0 {
                continue;
            }
            let (idx, vals) = self.row(i);
            for (j, v) in idx.iter().zip(vals) {
                w[*j as usize] += ai * v;
            }
        }
        w
    }

    /// Dense margins `m = X w`.
    pub fn dot_dense(&self, w: &[f64]) -> Vec<f64> {
        (0..self.rows()).map(|i| self.row_dot_dense(i, w)).collect()
    }

    /// Scale every row to at most unit 2-norm if `max > 1`, matching the
    /// paper's `R_max = 1` normalization assumption. Returns the scaling
    /// factor applied (1.0 if none).
    pub fn normalize_rows_to_unit_max(&mut self) -> f64 {
        let max_sq = (0..self.rows())
            .map(|i| self.row_sqnorm(i))
            .fold(0.0_f64, f64::max);
        if max_sq <= 1.0 || max_sq == 0.0 {
            return 1.0;
        }
        let scale = 1.0 / max_sq.sqrt();
        for v in &mut self.values {
            *v *= scale;
        }
        // Values changed: drop any memoized norms.
        self.sqnorms = Default::default();
        scale
    }

    /// Materialize row `i` into a dense f32 buffer (runtime eval path).
    pub fn write_row_dense_f32(&self, i: usize, out: &mut [f32]) {
        out.fill(0.0);
        let (idx, vals) = self.row(i);
        for (j, v) in idx.iter().zip(vals) {
            out[*j as usize] = *v as f32;
        }
    }

    /// Select a subset of rows into a new matrix (dataset splits).
    pub fn select_rows(&self, rows: &[usize]) -> CsrMatrix {
        let mut indptr = Vec::with_capacity(rows.len() + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for &i in rows {
            let (idx, vals) = self.row(i);
            indices.extend_from_slice(idx);
            values.extend_from_slice(vals);
            indptr.push(indices.len());
        }
        CsrMatrix {
            indptr,
            indices,
            values,
            cols: self.cols,
            sqnorms: Default::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CsrMatrix {
        // [[1, 0, 2],
        //  [0, 3, 0],
        //  [0, 0, 0]]
        CsrMatrix::from_rows(
            &[
                vec![Entry { index: 0, value: 1.0 }, Entry { index: 2, value: 2.0 }],
                vec![Entry { index: 1, value: 3.0 }],
                vec![],
            ],
            3,
        )
    }

    #[test]
    fn dims_and_nnz() {
        let m = sample();
        assert_eq!(m.rows(), 3);
        assert_eq!(m.cols(), 3);
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.row_nnz(0), 2);
        assert_eq!(m.row_nnz(2), 0);
        assert!((m.avg_nnz() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn row_access() {
        let m = sample();
        let (idx, vals) = m.row(0);
        assert_eq!(idx, &[0, 2]);
        assert_eq!(vals, &[1.0, 2.0]);
    }

    #[test]
    fn sqnorms() {
        let m = sample();
        assert_eq!(m.row_sqnorm(0), 5.0);
        assert_eq!(m.all_row_sqnorms(), vec![5.0, 9.0, 0.0]);
        // Memoized view agrees and is stable across calls.
        assert_eq!(m.row_sqnorms_cached(), &[5.0, 9.0, 0.0]);
        assert_eq!(m.row_sqnorms_cached(), &[5.0, 9.0, 0.0]);
    }

    #[test]
    fn dots() {
        let m = sample();
        let w = [1.0, 2.0, 3.0];
        assert_eq!(m.row_dot_dense(0, &w), 7.0);
        assert_eq!(m.dot_dense(&w), vec![7.0, 6.0, 0.0]);
    }

    #[test]
    fn transpose_dot_matches_manual() {
        let m = sample();
        let a = [2.0, -1.0, 5.0];
        // X^T a = [2*1, -1*3, 2*2] = [2, -3, 4]
        assert_eq!(m.transpose_dot(&a), vec![2.0, -3.0, 4.0]);
    }

    #[test]
    fn normalization_caps_max_row_norm() {
        let mut m = sample();
        let s = m.normalize_rows_to_unit_max();
        assert!(s < 1.0);
        let max = (0..m.rows())
            .map(|i| m.row_sqnorm(i))
            .fold(0.0_f64, f64::max);
        assert!((max - 1.0).abs() < 1e-12);
    }

    #[test]
    fn normalization_noop_when_already_unit() {
        let mut m = CsrMatrix::from_rows(
            &[vec![Entry { index: 0, value: 0.6 }, Entry { index: 1, value: 0.8 }]],
            2,
        );
        assert_eq!(m.normalize_rows_to_unit_max(), 1.0);
    }

    #[test]
    fn dense_row_materialization() {
        let m = sample();
        let mut buf = vec![9f32; 3];
        m.write_row_dense_f32(0, &mut buf);
        assert_eq!(buf, vec![1.0, 0.0, 2.0]);
    }

    #[test]
    fn select_rows_subsets() {
        let m = sample();
        let s = m.select_rows(&[2, 0]);
        assert_eq!(s.rows(), 2);
        assert_eq!(s.row_nnz(0), 0);
        let (idx, _) = s.row(1);
        assert_eq!(idx, &[0, 2]);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn rejects_out_of_bounds_index() {
        CsrMatrix::from_rows(&[vec![Entry { index: 5, value: 1.0 }]], 3);
    }

    #[test]
    #[should_panic(expected = "strictly increasing")]
    fn rejects_unsorted_row() {
        CsrMatrix::from_rows(
            &[vec![
                Entry { index: 2, value: 1.0 },
                Entry { index: 1, value: 1.0 },
            ]],
            3,
        );
    }
}
