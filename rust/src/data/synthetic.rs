//! Synthetic dataset generators — the data substitution layer.
//!
//! The paper evaluates on news20 / covtype / rcv1 / webspam / kddb
//! (Table 3).  This offline image has none of them, so we generate
//! *shape-matched analogs* (DESIGN.md §3): same sparsity regime, power-law
//! feature popularity, a planted separator `w*` with controllable label
//! noise so a linear SVM attains high accuracy, and row norms capped at 1
//! (the paper's `R_max = 1` assumption).

use super::dataset::Dataset;
use super::sparse::{CsrMatrix, Entry};
use crate::util::Pcg32;

/// Parameters of a synthetic binary-classification problem.
#[derive(Debug, Clone)]
pub struct SyntheticSpec {
    pub name: String,
    /// Number of instances (train + test together).
    pub n: usize,
    /// Feature-space dimensionality.
    pub d: usize,
    /// Mean nonzeros per row (Table 3's `d̄`).
    pub avg_nnz: f64,
    /// Power-law exponent for feature popularity (0 = uniform; text-like
    /// corpora sit near 1.0–1.4).
    pub zipf_exponent: f64,
    /// Probability a label is flipped after the planted separator votes.
    pub label_noise: f64,
    /// Fraction of `w*` coordinates that are nonzero.
    pub wstar_density: f64,
    /// RNG seed (every dataset is reproducible from its spec).
    pub seed: u64,
}

impl SyntheticSpec {
    /// Generate the dataset.
    ///
    /// Construction: feature `j` is drawn with probability ∝ `(j+1)^-z`
    /// (shuffled so popularity is not index-correlated), values are
    /// N(0,1)-scaled; a sparse `w*` is planted, labels are
    /// `sign(w*.x + noise)` with `label_noise` random flips, rows are
    /// folded (`x_i ← y_i x_i`) and globally rescaled so max ||x_i|| = 1.
    pub fn generate(&self) -> Dataset {
        assert!(self.n > 0 && self.d > 0);
        assert!(self.avg_nnz >= 1.0 && self.avg_nnz <= self.d as f64);
        let mut rng = Pcg32::new(self.seed, 0x5EED);

        // --- feature popularity: cumulative power-law table -------------
        let weights: Vec<f64> = (0..self.d)
            .map(|j| 1.0 / ((j + 1) as f64).powf(self.zipf_exponent))
            .collect();
        let mut cum: Vec<f64> = Vec::with_capacity(self.d);
        let mut acc = 0.0;
        for w in &weights {
            acc += w;
            cum.push(acc);
        }
        let total = acc;
        // Shuffled identity so that "popular" feature ids are scattered.
        let mut feat_map: Vec<u32> = (0..self.d as u32).collect();
        rng.shuffle(&mut feat_map);

        // --- planted separator ------------------------------------------
        let mut wstar = vec![0.0f64; self.d];
        let k = ((self.d as f64) * self.wstar_density).ceil() as usize;
        let support = rng.permutation(self.d);
        for &j in support.iter().take(k.max(1)) {
            wstar[j] = rng.gen_normal();
        }

        // --- rows ---------------------------------------------------------
        let mut rows: Vec<Vec<Entry>> = Vec::with_capacity(self.n);
        let mut labels: Vec<f64> = Vec::with_capacity(self.n);
        let mut scratch: Vec<(u32, f64)> = Vec::new();
        for _ in 0..self.n {
            // Row nnz ~ max(1, Poisson-ish around avg_nnz) via geometric
            // mixture — cheap and produces realistic variance.
            let lam = self.avg_nnz;
            let jitter = 0.5 + rng.gen_f64(); // 0.5..1.5
            let nnz = ((lam * jitter).round() as usize).clamp(1, self.d);
            // Margin-rejection sampling: redraw rows whose planted margin
            // is ambiguous (|w*·x| under half the conditional std) so the
            // analogs are margin-separated like the paper's text corpora
            // (news20/rcv1/webspam all sit near 97–99% accuracy).
            let mut dot = 0.0;
            for _attempt in 0..8 {
                scratch.clear();
                // Sample distinct features by popularity (reject dups).
                let mut tries = 0;
                while scratch.len() < nnz && tries < 20 * nnz {
                    tries += 1;
                    let u = rng.gen_f64() * total;
                    let pos = cum.partition_point(|&c| c < u).min(self.d - 1);
                    let f = feat_map[pos];
                    if scratch.iter().all(|&(i, _)| i != f) {
                        scratch.push((f, rng.gen_normal()));
                    }
                }
                dot = 0.0;
                let mut cond_var = 0.0;
                for &(i, v) in &scratch {
                    dot += wstar[i as usize] * v;
                    cond_var += wstar[i as usize] * wstar[i as usize];
                }
                if dot.abs() >= 0.5 * cond_var.sqrt() {
                    break;
                }
            }
            scratch.sort_unstable_by_key(|&(i, _)| i);
            let mut y = if dot >= 0.0 { 1.0 } else { -1.0 };
            if rng.gen_f64() < self.label_noise {
                y = -y;
            }
            rows.push(
                scratch
                    .iter()
                    .map(|&(i, v)| Entry { index: i, value: y * v })
                    .collect(),
            );
            labels.push(y);
        }
        let mut x = CsrMatrix::from_rows(&rows, self.d);
        x.normalize_rows_to_unit_max();
        Dataset::new(x, labels, self.name.clone())
    }
}

/// Fully-dense generator (the covtype analog): every feature present.
pub fn generate_dense(
    name: &str,
    n: usize,
    d: usize,
    label_noise: f64,
    seed: u64,
) -> Dataset {
    let mut rng = Pcg32::new(seed, 0xDE45E);
    let wstar: Vec<f64> = (0..d).map(|_| rng.gen_normal()).collect();
    let mut rows = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        let feats: Vec<f64> = (0..d).map(|_| rng.gen_normal()).collect();
        let dot: f64 = feats.iter().zip(&wstar).map(|(a, b)| a * b).sum();
        let mut y = if dot >= 0.0 { 1.0 } else { -1.0 };
        if rng.gen_f64() < label_noise {
            y = -y;
        }
        rows.push(
            feats
                .iter()
                .enumerate()
                .map(|(j, &v)| Entry { index: j as u32, value: y * v })
                .collect::<Vec<_>>(),
        );
        labels.push(y);
    }
    let mut x = CsrMatrix::from_rows(&rows, d);
    x.normalize_rows_to_unit_max();
    Dataset::new(x, labels, name)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> SyntheticSpec {
        SyntheticSpec {
            name: "syn".into(),
            n: 500,
            d: 1000,
            avg_nnz: 20.0,
            zipf_exponent: 1.0,
            label_noise: 0.02,
            wstar_density: 0.2,
            seed: 13,
        }
    }

    #[test]
    fn shape_matches_spec() {
        let ds = spec().generate();
        assert_eq!(ds.n(), 500);
        assert_eq!(ds.d(), 1000);
        let avg = ds.x.avg_nnz();
        assert!(
            (avg - 20.0).abs() < 5.0,
            "avg nnz {avg} far from requested 20"
        );
    }

    #[test]
    fn rows_are_unit_capped() {
        let ds = spec().generate();
        let max = (0..ds.n())
            .map(|i| ds.x.row_sqnorm(i))
            .fold(0.0_f64, f64::max);
        assert!(max <= 1.0 + 1e-9, "max row sqnorm {max}");
        assert!(max > 0.5, "normalization collapsed the data: {max}");
    }

    #[test]
    fn generation_is_deterministic() {
        let a = spec().generate();
        let b = spec().generate();
        assert_eq!(a.y, b.y);
        assert_eq!(a.x.nnz(), b.x.nnz());
    }

    #[test]
    fn both_classes_present() {
        let ds = spec().generate();
        let pos = ds.y.iter().filter(|&&y| y > 0.0).count();
        assert!(pos > 50 && pos < 450, "degenerate class balance: {pos}/500");
    }

    #[test]
    fn labels_are_learnable() {
        // The planted separator itself must achieve well-above-chance
        // accuracy on the folded rows (margin > 0).
        let s = spec();
        let ds = s.generate();
        // Recover w* by regenerating with the same seed stream.
        // Cheaper: train-free sanity — random w gives ~0.5, so just check
        // *some* linear model does better: use w̄ = Σ x_i (mean of folded
        // rows — a crude centroid classifier).
        let ones = vec![1.0; ds.n()];
        let centroid = ds.x.transpose_dot(&ones);
        let acc = ds.accuracy(&centroid);
        assert!(acc > 0.6, "centroid accuracy only {acc}");
    }

    #[test]
    fn dense_generator_is_fully_dense() {
        let ds = generate_dense("dense", 50, 10, 0.0, 1);
        assert_eq!(ds.x.nnz(), 500);
        assert_eq!(ds.x.avg_nnz(), 10.0);
    }

    #[test]
    fn zipf_skews_feature_popularity() {
        let mut s = spec();
        s.zipf_exponent = 1.3;
        s.n = 2000;
        let ds = s.generate();
        // Count feature frequencies; the most popular feature should be
        // much more frequent than the median one.
        let mut freq = vec![0usize; ds.d()];
        for i in 0..ds.n() {
            let (idx, _) = ds.x.row(i);
            for &j in idx {
                freq[j as usize] += 1;
            }
        }
        freq.sort_unstable_by(|a, b| b.cmp(a));
        let used: Vec<usize> = freq.iter().copied().filter(|&f| f > 0).collect();
        assert!(used[0] >= 10 * used[used.len() / 2].max(1),
            "no popularity skew: top={} median={}", used[0], used[used.len()/2]);
    }
}
