//! Data substrate: CSR sparse matrices, the LIBSVM format, labeled
//! datasets (label-folded, paper convention), synthetic generators,
//! the Table-3 analog registry, and row-range sharding for the
//! distributed tier ([`shard`]).

pub mod dataset;
pub mod libsvm;
pub mod registry;
pub mod remap;
pub mod shard;
pub mod sparse;
pub mod synthetic;

pub use dataset::Dataset;
pub use registry::{load as load_dataset, spec as dataset_spec, DatasetSpec, REGISTRY};
pub use remap::FeatureRemap;
pub use shard::{extract as extract_shard, plan_ranges, ShardManifest, ShardRange};
pub use sparse::{CsrMatrix, Entry};
pub use synthetic::SyntheticSpec;
