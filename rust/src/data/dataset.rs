//! Labeled dataset container with the paper's label-folding convention.
//!
//! Throughout the paper `x_i = y_i ẋ_i` — labels are folded into the rows,
//! so the margin `w·x_i > 0` means a correct prediction and the hinge loss
//! is `C·max(0, 1 − w·x_i)`.  [`Dataset`] stores the *folded* matrix plus
//! the raw labels (for bookkeeping and LIBSVM round-trips).

use super::sparse::CsrMatrix;
use crate::util::Pcg32;

/// A binary-classification dataset, rows label-folded.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Folded design matrix (`x_i = y_i ẋ_i`).
    pub x: CsrMatrix,
    /// Raw labels in {-1, +1}, `len == x.rows()`.
    pub y: Vec<f64>,
    /// Human-readable name for logs/metrics.
    pub name: String,
}

impl Dataset {
    pub fn new(x: CsrMatrix, y: Vec<f64>, name: impl Into<String>) -> Self {
        assert_eq!(x.rows(), y.len());
        assert!(y.iter().all(|&l| l == 1.0 || l == -1.0), "labels must be ±1");
        Self { x, y, name: name.into() }
    }

    pub fn n(&self) -> usize {
        self.x.rows()
    }

    pub fn d(&self) -> usize {
        self.x.cols()
    }

    /// Split into (train, test) with `test_frac` of rows held out,
    /// deterministically from `seed`.
    pub fn split(&self, test_frac: f64, seed: u64) -> (Dataset, Dataset) {
        assert!((0.0..1.0).contains(&test_frac));
        let n = self.n();
        let mut rng = Pcg32::new(seed, 0xDA7A);
        let perm = rng.permutation(n);
        let n_test = ((n as f64) * test_frac).round() as usize;
        let (test_rows, train_rows) = perm.split_at(n_test);
        let take = |rows: &[usize], tag: &str| {
            Dataset::new(
                self.x.select_rows(rows),
                rows.iter().map(|&i| self.y[i]).collect(),
                format!("{}-{tag}", self.name),
            )
        };
        (take(train_rows, "train"), take(test_rows, "test"))
    }

    /// Row `i` with the label folding undone: `(indices, y_i · x_i)` =
    /// the raw features `ẋ_i` as a caller outside the training loop
    /// (e.g. the serving path) would see them.
    pub fn raw_row(&self, i: usize) -> (Vec<u32>, Vec<f64>) {
        let (idx, vals) = self.x.row(i);
        let y = self.y[i];
        (idx.to_vec(), vals.iter().map(|v| v * y).collect())
    }

    /// Fraction of rows with margin > 0 under `w` (accuracy on folded rows).
    pub fn accuracy(&self, w: &[f64]) -> f64 {
        if self.n() == 0 {
            return 0.0;
        }
        let correct = (0..self.n())
            .filter(|&i| self.x.row_dot_dense(i, w) > 0.0)
            .count();
        correct as f64 / self.n() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse::Entry;

    fn toy() -> Dataset {
        // Folded rows: positive class at +e0, negative at -e1 (folded:
        // -1 * (+e1) = -e1 ... keep it simple: rows already folded).
        let x = CsrMatrix::from_rows(
            &[
                vec![Entry { index: 0, value: 1.0 }],
                vec![Entry { index: 1, value: 1.0 }],
                vec![Entry { index: 0, value: 0.5 }],
                vec![Entry { index: 1, value: -0.5 }],
            ],
            2,
        );
        Dataset::new(x, vec![1.0, -1.0, 1.0, -1.0], "toy")
    }

    #[test]
    fn dims() {
        let d = toy();
        assert_eq!(d.n(), 4);
        assert_eq!(d.d(), 2);
    }

    #[test]
    fn accuracy_counts_positive_margins() {
        let d = toy();
        // w = (1, 1): margins = [1, 1, .5, -.5] -> 3/4 correct
        assert!((d.accuracy(&[1.0, 1.0]) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn raw_row_unfolds_labels() {
        let d = toy();
        // Row 3 is folded with y = -1: raw values flip sign.
        let (idx, vals) = d.raw_row(3);
        assert_eq!(idx, vec![1]);
        assert_eq!(vals, vec![0.5]);
        // Row 0 (y = +1) is unchanged.
        assert_eq!(d.raw_row(0).1, vec![1.0]);
    }

    #[test]
    fn split_partitions_rows() {
        let d = toy();
        let (tr, te) = d.split(0.25, 7);
        assert_eq!(tr.n() + te.n(), d.n());
        assert_eq!(te.n(), 1);
        assert_eq!(tr.d(), d.d());
    }

    #[test]
    fn split_is_deterministic() {
        let d = toy();
        let (a, _) = d.split(0.5, 3);
        let (b, _) = d.split(0.5, 3);
        assert_eq!(a.y, b.y);
    }

    #[test]
    #[should_panic(expected = "labels must be ±1")]
    fn rejects_bad_labels() {
        let x = CsrMatrix::from_rows(&[vec![]], 1);
        Dataset::new(x, vec![0.5], "bad");
    }
}
