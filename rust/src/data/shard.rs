//! Row-range sharding for the distributed tier (`dist/`).
//!
//! Hybrid-DCA (Pal et al., arXiv:1610.07184) partitions rows across
//! nodes; each node runs PASSCoDe-style local epochs on its block of
//! the dual and ships `w` deltas to a coordinator.  This module is the
//! data half of that story: contiguous row-range shards of a
//! [`Dataset`] (so the global dual vector is the concatenation of the
//! per-shard duals, in order), plus a small JSON **shard manifest**
//! (`passcode-shards-v1`) so independent worker processes can agree on
//! the partition without talking to each other.
//!
//! Contiguity is load-bearing: with shard `p` owning rows
//! `[start_p, end_p)` and the shards covering `0..n` in order, the
//! coordinator's merged `w = Σ_p X_pᵀ α_p` and the concatenated α are
//! exactly a single-process PASSCoDe state — which is what lets
//! `dist-sim` compare against the sequential solver in tests.

use anyhow::{bail, ensure, Context, Result};

use super::dataset::Dataset;
use super::registry;
use crate::util::Json;

/// One shard's row range: rows `[start, end)` of the global dataset.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardRange {
    /// Shard id, `0..k`, also its position in the manifest.
    pub id: usize,
    /// First global row (inclusive).
    pub start: usize,
    /// One past the last global row (exclusive).
    pub end: usize,
}

impl ShardRange {
    /// Number of rows in this shard.
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    /// Whether the shard holds no rows (possible when `k > n`).
    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }
}

/// Plan `k` contiguous near-equal row ranges covering `0..n` in order.
/// The first `n % k` shards get one extra row, matching the usual
/// balanced block decomposition.
pub fn plan_ranges(n: usize, k: usize) -> Vec<ShardRange> {
    assert!(k > 0, "shard count must be positive");
    let base = n / k;
    let extra = n % k;
    let mut ranges = Vec::with_capacity(k);
    let mut start = 0;
    for id in 0..k {
        let len = base + usize::from(id < extra);
        ranges.push(ShardRange { id, start, end: start + len });
        start += len;
    }
    debug_assert_eq!(start, n);
    ranges
}

/// Slice a shard out of `ds`: rows `[r.start, r.end)` with their
/// labels, same column dimension, name tagged with the range.
pub fn extract(ds: &Dataset, r: &ShardRange) -> Dataset {
    assert!(r.end <= ds.n(), "shard range {}..{} out of bounds (n={})", r.start, r.end, ds.n());
    let rows: Vec<usize> = (r.start..r.end).collect();
    Dataset::new(
        ds.x.select_rows(&rows),
        rows.iter().map(|&i| ds.y[i]).collect(),
        format!("{}[{}..{}]", ds.name, r.start, r.end),
    )
}

/// The shard manifest: the partition plan plus enough dataset metadata
/// (registry name, scale, dims, C) for a worker process to rebuild its
/// shard and training config from the manifest alone.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardManifest {
    /// Registry dataset name (e.g. `"rcv1"`).
    pub dataset: String,
    /// Registry scale factor in `(0, 1]`.
    pub scale: f64,
    /// Global training-row count the plan covers.
    pub n: usize,
    /// Feature dimension (columns of the folded design matrix).
    pub d: usize,
    /// Regularization constant C from the registry.
    pub c: f64,
    /// The contiguous ranges, in shard-id order, covering `0..n`.
    pub shards: Vec<ShardRange>,
}

/// Manifest format tag written to / required from the JSON.
pub const MANIFEST_FORMAT: &str = "passcode-shards-v1";

impl ShardManifest {
    /// Build a manifest for a registry dataset split into `k` shards
    /// (loads the dataset once to learn `n`, `d`, and C).
    pub fn for_registry(dataset: &str, scale: f64, k: usize) -> Result<ShardManifest> {
        ensure!(k > 0, "shard count must be positive");
        let (train, _test, c) = registry::load(dataset, scale)?;
        Ok(ShardManifest {
            dataset: dataset.to_string(),
            scale,
            n: train.n(),
            d: train.d(),
            c,
            shards: plan_ranges(train.n(), k),
        })
    }

    /// Load shard `id`'s rows from the registry (a worker process calls
    /// this with its own id; only the slice is kept).
    pub fn load_shard(&self, id: usize) -> Result<Dataset> {
        let r = self
            .shards
            .get(id)
            .with_context(|| format!("shard id {id} out of range (have {})", self.shards.len()))?;
        let (train, _test, _c) = registry::load(&self.dataset, self.scale)?;
        ensure!(
            train.n() == self.n && train.d() == self.d,
            "registry dataset {}@{} is {}x{}, manifest says {}x{}",
            self.dataset,
            self.scale,
            train.n(),
            train.d(),
            self.n,
            self.d
        );
        Ok(extract(&train, r))
    }

    /// Serialize to the `passcode-shards-v1` JSON form.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("format", Json::str(MANIFEST_FORMAT)),
            ("dataset", Json::str(&self.dataset)),
            ("scale", Json::num(self.scale)),
            ("n", Json::num(self.n as f64)),
            ("d", Json::num(self.d as f64)),
            ("c", Json::num(self.c)),
            (
                "shards",
                Json::Arr(
                    self.shards
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("id", Json::num(r.id as f64)),
                                ("start", Json::num(r.start as f64)),
                                ("end", Json::num(r.end as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Parse and validate a manifest: format tag, sequential shard ids,
    /// and contiguous ranges exactly covering `0..n`.
    pub fn from_json(j: &Json) -> Result<ShardManifest> {
        let format = j.get("format")?.as_str()?;
        ensure!(format == MANIFEST_FORMAT, "unsupported manifest format {format:?}");
        let n = j.get("n")?.as_usize()?;
        let mut shards = Vec::new();
        for (i, s) in j.get("shards")?.as_arr()?.iter().enumerate() {
            let r = ShardRange {
                id: s.get("id")?.as_usize()?,
                start: s.get("start")?.as_usize()?,
                end: s.get("end")?.as_usize()?,
            };
            ensure!(r.id == i, "shard ids must be sequential: slot {i} has id {}", r.id);
            ensure!(r.start <= r.end, "shard {i} has start {} > end {}", r.start, r.end);
            shards.push(r);
        }
        if shards.is_empty() {
            bail!("manifest has no shards");
        }
        let mut cursor = 0;
        for r in &shards {
            ensure!(
                r.start == cursor,
                "shards must be contiguous: shard {} starts at {}, expected {cursor}",
                r.id,
                r.start
            );
            cursor = r.end;
        }
        ensure!(cursor == n, "shards cover 0..{cursor} but manifest n = {n}");
        Ok(ShardManifest {
            dataset: j.get("dataset")?.as_str()?.to_string(),
            scale: j.get("scale")?.as_f64()?,
            n,
            d: j.get("d")?.as_usize()?,
            c: j.get("c")?.as_f64()?,
            shards,
        })
    }

    /// Write the manifest JSON (pretty) to `path`.
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        let path = path.as_ref();
        std::fs::write(path, self.to_json().to_pretty())
            .with_context(|| format!("writing shard manifest {}", path.display()))
    }

    /// Read and validate a manifest from `path`.
    pub fn load(path: impl AsRef<std::path::Path>) -> Result<ShardManifest> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading shard manifest {}", path.display()))?;
        Self::from_json(&Json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse::{CsrMatrix, Entry};

    fn toy(n: usize) -> Dataset {
        let rows: Vec<Vec<Entry>> = (0..n)
            .map(|i| vec![Entry { index: (i % 3) as u32, value: 1.0 + i as f64 }])
            .collect();
        let y: Vec<f64> = (0..n).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        Dataset::new(CsrMatrix::from_rows(&rows, 3), y, "toy")
    }

    #[test]
    fn plan_covers_and_balances() {
        let r = plan_ranges(10, 3);
        assert_eq!(r.len(), 3);
        assert_eq!((r[0].start, r[0].end), (0, 4));
        assert_eq!((r[1].start, r[1].end), (4, 7));
        assert_eq!((r[2].start, r[2].end), (7, 10));
        // More shards than rows: trailing shards are empty but valid.
        let r = plan_ranges(2, 4);
        assert_eq!(r.iter().map(ShardRange::len).sum::<usize>(), 2);
        assert!(r[3].is_empty());
    }

    #[test]
    fn extract_slices_rows_and_labels() {
        let ds = toy(7);
        let r = plan_ranges(7, 2);
        let a = extract(&ds, &r[0]);
        let b = extract(&ds, &r[1]);
        assert_eq!(a.n() + b.n(), 7);
        assert_eq!(a.d(), 3);
        assert_eq!(b.y, ds.y[r[1].start..].to_vec());
        // Row content survives the slice.
        let (idx, vals) = b.x.row(0);
        let (gidx, gvals) = ds.x.row(r[1].start);
        assert_eq!(idx, gidx);
        assert_eq!(vals, gvals);
    }

    #[test]
    fn manifest_json_round_trip() {
        let m = ShardManifest {
            dataset: "rcv1".into(),
            scale: 0.05,
            n: 10,
            d: 4,
            c: 1.0,
            shards: plan_ranges(10, 3),
        };
        let j = Json::parse(&m.to_json().to_pretty()).unwrap();
        assert_eq!(ShardManifest::from_json(&j).unwrap(), m);
    }

    #[test]
    fn manifest_rejects_gaps_and_bad_ids() {
        let mut m = ShardManifest {
            dataset: "rcv1".into(),
            scale: 0.05,
            n: 10,
            d: 4,
            c: 1.0,
            shards: plan_ranges(10, 2),
        };
        m.shards[1].start = 6; // gap after shard 0 (ends at 5)
        let j = Json::parse(&m.to_json().to_string()).unwrap();
        assert!(ShardManifest::from_json(&j).is_err());
        m.shards = plan_ranges(10, 2);
        m.shards[1].id = 7;
        let j = Json::parse(&m.to_json().to_string()).unwrap();
        assert!(ShardManifest::from_json(&j).is_err());
        m.shards = plan_ranges(10, 2);
        m.n = 11; // shards cover 0..10 only
        let j = Json::parse(&m.to_json().to_string()).unwrap();
        assert!(ShardManifest::from_json(&j).is_err());
    }

    #[test]
    fn for_registry_plans_over_train_rows() {
        let m = ShardManifest::for_registry("rcv1", 0.02, 2).unwrap();
        assert_eq!(m.shards.len(), 2);
        assert_eq!(m.shards.iter().map(ShardRange::len).sum::<usize>(), m.n);
        let shard0 = m.load_shard(0).unwrap();
        assert_eq!(shard0.n(), m.shards[0].len());
        assert_eq!(shard0.d(), m.d);
        assert!(m.load_shard(2).is_err());
    }
}
