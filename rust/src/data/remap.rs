//! Feature-locality remap: reorder columns by descending document
//! frequency so the hot features every row touches pack into a few
//! resident cache lines of the shared `w`, and the cold tail stops
//! false-sharing lines with them.
//!
//! The asynchronous solvers contend on `w` through the memory system
//! (Liu & Wright 2015's analysis of async coordinate descent; the
//! HOGWILD lineage) — which *physical lines* a feature lands on is a
//! pure artifact of its column index.  [`FeatureRemap`] makes that
//! artifact deliberate: `forward[old] = new` sorts columns by document
//! frequency (descending, ties by original index — fully deterministic),
//! [`FeatureRemap::unmap_w`] translates a trained weight vector back to
//! the original feature space at the export boundary (`coordinator`),
//! and [`FeatureRemap::map_row`] translates incoming raw rows for
//! anything that wants to score *in* the remapped space.
//!
//! The remap is a permutation, so objectives, duality gaps, and
//! predictions are mathematically unchanged — only the memory layout
//! (and float summation order) moves.

use anyhow::{ensure, Result};

use crate::util::Json;

use super::dataset::Dataset;
use super::sparse::CsrMatrix;

/// A bijective column relabeling (`forward[old] = new`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FeatureRemap {
    /// `forward[old] = new`.
    forward: Vec<u32>,
    /// `inverse[new] = old`.
    inverse: Vec<u32>,
}

impl FeatureRemap {
    /// Order columns by descending document frequency (ties broken by
    /// original index).  Deterministic for a given matrix.
    pub fn by_doc_frequency(x: &CsrMatrix) -> FeatureRemap {
        let df = x.col_doc_frequency();
        let mut inverse: Vec<u32> = (0..x.cols() as u32).collect();
        inverse.sort_by(|&a, &b| {
            df[b as usize].cmp(&df[a as usize]).then(a.cmp(&b))
        });
        Self::from_inverse(inverse)
    }

    /// The identity remap on `d` features.
    pub fn identity(d: usize) -> FeatureRemap {
        Self::from_inverse((0..d as u32).collect())
    }

    fn from_inverse(inverse: Vec<u32>) -> FeatureRemap {
        let mut forward = vec![0u32; inverse.len()];
        for (new, &old) in inverse.iter().enumerate() {
            forward[old as usize] = new as u32;
        }
        FeatureRemap { forward, inverse }
    }

    /// Number of features the map covers.
    pub fn d(&self) -> usize {
        self.forward.len()
    }

    /// `forward[old] = new`, a permutation of `0..d`.
    pub fn forward(&self) -> &[u32] {
        &self.forward
    }

    /// `inverse[new] = old`, a permutation of `0..d`.
    pub fn inverse(&self) -> &[u32] {
        &self.inverse
    }

    /// Translate a weight vector trained in the remapped space back to
    /// the original feature space (`w_orig[old] = w[forward[old]]`) —
    /// applied at every export boundary (model save, serving, eval in
    /// original coordinates).
    pub fn unmap_w(&self, w: &[f64]) -> Vec<f64> {
        assert_eq!(w.len(), self.d(), "remap dimension");
        self.forward.iter().map(|&new| w[new as usize]).collect()
    }

    /// Translate an original-space weight vector into the remapped space
    /// (`w_new[new] = w[inverse[new]]`); inverse of
    /// [`FeatureRemap::unmap_w`].
    pub fn map_w(&self, w: &[f64]) -> Vec<f64> {
        assert_eq!(w.len(), self.d(), "remap dimension");
        self.inverse.iter().map(|&old| w[old as usize]).collect()
    }

    /// Translate a raw sparse row into the remapped space, returning the
    /// entries sorted by new index.  Indices outside the map (features
    /// unseen at training time) are dropped — the same semantics the
    /// serving margin applies to unknown features.
    pub fn map_row(&self, idx: &[u32], vals: &[f64]) -> (Vec<u32>, Vec<f64>) {
        let mut pairs: Vec<(u32, f64)> = idx
            .iter()
            .zip(vals)
            .filter(|(j, _)| (**j as usize) < self.d())
            .map(|(j, v)| (self.forward[*j as usize], *v))
            .collect();
        pairs.sort_unstable_by_key(|e| e.0);
        (pairs.iter().map(|e| e.0).collect(), pairs.iter().map(|e| e.1).collect())
    }

    /// Serialize (the `passcode-remap-v1` schema persisted by
    /// `coordinator::model_io::save_remap`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("format", Json::str("passcode-remap-v1")),
            ("d", Json::num(self.d() as f64)),
            (
                "inverse",
                Json::arr_f64(
                    &self.inverse.iter().map(|&j| j as f64).collect::<Vec<f64>>(),
                ),
            ),
        ])
    }

    /// Deserialize, validating that the stored map is a permutation.
    pub fn from_json(json: &Json) -> Result<FeatureRemap> {
        ensure!(
            json.get("format")?.as_str()? == "passcode-remap-v1",
            "not a passcode remap file"
        );
        let d = json.get("d")?.as_usize()?;
        let inverse: Vec<u32> = json
            .get("inverse")?
            .as_arr()?
            .iter()
            .map(|v| Ok(v.as_usize()? as u32))
            .collect::<Result<_>>()?;
        ensure!(inverse.len() == d, "remap dimension mismatch");
        let mut seen = vec![false; d];
        for &j in &inverse {
            ensure!((j as usize) < d, "remap index {j} out of range");
            ensure!(!seen[j as usize], "remap index {j} repeated");
            seen[j as usize] = true;
        }
        Ok(Self::from_inverse(inverse))
    }
}

impl Dataset {
    /// Build the document-frequency remap for this dataset and return
    /// the remapped copy plus the map (apply the same map to held-out
    /// splits with [`Dataset::remap_features_with`]).
    pub fn remap_features(&self) -> (Dataset, FeatureRemap) {
        let remap = FeatureRemap::by_doc_frequency(&self.x);
        (self.remap_features_with(&remap), remap)
    }

    /// Apply an existing [`FeatureRemap`] (e.g. the training split's) to
    /// this dataset.
    pub fn remap_features_with(&self, remap: &FeatureRemap) -> Dataset {
        Dataset::new(
            self.x.remap_columns(remap.forward()),
            self.y.clone(),
            format!("{}-remap", self.name),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse::Entry;

    fn toy() -> Dataset {
        // df: col0 = 1, col1 = 3, col2 = 2 → order [1, 2, 0].
        let x = CsrMatrix::from_rows(
            &[
                vec![
                    Entry { index: 0, value: 1.0 },
                    Entry { index: 1, value: 2.0 },
                ],
                vec![
                    Entry { index: 1, value: 3.0 },
                    Entry { index: 2, value: 4.0 },
                ],
                vec![
                    Entry { index: 1, value: 5.0 },
                    Entry { index: 2, value: 6.0 },
                ],
            ],
            3,
        );
        Dataset::new(x, vec![1.0, -1.0, 1.0], "toy")
    }

    #[test]
    fn doc_frequency_order_is_deterministic() {
        let ds = toy();
        let a = FeatureRemap::by_doc_frequency(&ds.x);
        let b = FeatureRemap::by_doc_frequency(&ds.x);
        assert_eq!(a, b);
        // Most frequent column (1) maps to slot 0, then 2, then 0 → 2.
        assert_eq!(a.inverse(), &[1, 2, 0]);
        assert_eq!(a.forward(), &[2, 0, 1]);
    }

    #[test]
    fn forward_inverse_are_mutual() {
        let ds = toy();
        let m = FeatureRemap::by_doc_frequency(&ds.x);
        for old in 0..m.d() {
            assert_eq!(m.inverse()[m.forward()[old] as usize] as usize, old);
        }
    }

    #[test]
    fn w_map_roundtrip_is_identity() {
        let ds = toy();
        let m = FeatureRemap::by_doc_frequency(&ds.x);
        let w = vec![10.0, 20.0, 30.0];
        assert_eq!(m.unmap_w(&m.map_w(&w)), w);
        assert_eq!(m.map_w(&m.unmap_w(&w)), w);
    }

    #[test]
    fn remapped_dataset_preserves_margins() {
        let ds = toy();
        let (ds_r, m) = ds.remap_features();
        assert_eq!(ds_r.n(), ds.n());
        assert_eq!(ds_r.d(), ds.d());
        // A margin computed in remapped space with the mapped weights
        // equals the original margin.
        let w = vec![0.5, -1.5, 2.0];
        let w_r = m.map_w(&w);
        for i in 0..ds.n() {
            let a = ds.x.row_dot_dense(i, &w);
            let b = ds_r.x.row_dot_dense(i, &w_r);
            assert!((a - b).abs() < 1e-12, "row {i}: {a} vs {b}");
        }
    }

    #[test]
    fn map_row_sorts_and_drops_unknown() {
        let ds = toy();
        let m = FeatureRemap::by_doc_frequency(&ds.x);
        // Raw row touching cols 0 (→2), 1 (→0) and an unseen col 9.
        let (idx, vals) = m.map_row(&[0, 1, 9], &[7.0, 8.0, 9.0]);
        assert_eq!(idx, vec![0, 2]);
        assert_eq!(vals, vec![8.0, 7.0]);
    }

    #[test]
    fn identity_is_a_noop() {
        let m = FeatureRemap::identity(4);
        let w = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(m.unmap_w(&w), w);
        assert_eq!(m.forward(), &[0, 1, 2, 3]);
    }

    #[test]
    fn json_roundtrip_and_validation() {
        let ds = toy();
        let m = FeatureRemap::by_doc_frequency(&ds.x);
        let back = FeatureRemap::from_json(
            &Json::parse(&m.to_json().to_pretty()).unwrap(),
        )
        .unwrap();
        assert_eq!(back, m);
        // A non-permutation must be rejected.
        let bad = r#"{"format":"passcode-remap-v1","d":2,"inverse":[0,0]}"#;
        assert!(FeatureRemap::from_json(&Json::parse(bad).unwrap()).is_err());
        let bad = r#"{"format":"passcode-remap-v1","d":2,"inverse":[0,5]}"#;
        assert!(FeatureRemap::from_json(&Json::parse(bad).unwrap()).is_err());
        assert!(FeatureRemap::from_json(&Json::parse("{}").unwrap()).is_err());
    }
}
