//! Named dataset registry — the Table 3 analogs.
//!
//! Each entry mirrors one of the paper's five benchmark datasets, scaled
//! 10–100× down so the full experiment suite runs on this 1-core host
//! (DESIGN.md §3).  The *ratios* that drive (PASS)DCD behaviour — n vs d,
//! sparsity, density regime — follow Table 3; `C` values are the paper's.

use anyhow::{bail, Result};

use super::dataset::Dataset;
use super::synthetic::{generate_dense, SyntheticSpec};

/// A registry entry: how to produce the dataset and its experiment config.
#[derive(Debug, Clone)]
pub struct DatasetSpec {
    pub name: &'static str,
    /// The paper dataset this stands in for.
    pub paper_analog: &'static str,
    /// Paper's penalty parameter C (Table 3).
    pub c: f64,
    /// Held-out fraction (approximates the paper's ñ/n ratio).
    pub test_frac: f64,
    /// Shape parameters.
    pub n: usize,
    pub d: usize,
    pub avg_nnz: f64,
    pub dense: bool,
    pub zipf_exponent: f64,
    pub label_noise: f64,
    pub seed: u64,
}

/// All registered analogs, in the paper's Table 3 order.
pub const REGISTRY: &[DatasetSpec] = &[
    DatasetSpec {
        name: "news20",
        paper_analog: "news20 (n=16k, d=1.36M, d̄=455.5, C=2)",
        c: 2.0,
        test_frac: 0.2,
        n: 6_000,
        d: 40_000,
        avg_nnz: 80.0,
        dense: false,
        zipf_exponent: 1.1,
        label_noise: 0.01,
        seed: 20,
    },
    DatasetSpec {
        name: "covtype",
        paper_analog: "covtype (n=500k, d=54, d̄=11.9, C=0.0625)",
        c: 0.0625,
        test_frac: 0.14,
        n: 24_000,
        d: 54,
        avg_nnz: 54.0,
        dense: true,
        zipf_exponent: 0.0,
        label_noise: 0.12,
        seed: 54,
    },
    DatasetSpec {
        name: "rcv1",
        paper_analog: "rcv1 (n=677k, d=47k, d̄=73.2, C=1)",
        c: 1.0,
        test_frac: 0.03,
        n: 20_000,
        d: 15_000,
        avg_nnz: 60.0,
        dense: false,
        zipf_exponent: 1.2,
        label_noise: 0.015,
        seed: 1,
    },
    DatasetSpec {
        name: "webspam",
        paper_analog: "webspam (n=280k, d=16.6M, d̄=3727.7, C=1)",
        c: 1.0,
        test_frac: 0.25,
        n: 8_000,
        d: 60_000,
        avg_nnz: 350.0,
        dense: false,
        zipf_exponent: 0.9,
        label_noise: 0.005,
        seed: 2,
    },
    DatasetSpec {
        name: "kddb",
        paper_analog: "kddb (n=19.3M, d=29.9M, d̄=29.4, C=1)",
        c: 1.0,
        test_frac: 0.04,
        n: 60_000,
        d: 150_000,
        avg_nnz: 25.0,
        dense: false,
        zipf_exponent: 1.25,
        label_noise: 0.08,
        seed: 3,
    },
];

/// Look up a spec by name.
pub fn spec(name: &str) -> Result<&'static DatasetSpec> {
    REGISTRY
        .iter()
        .find(|s| s.name == name)
        .ok_or_else(|| {
            let names: Vec<_> = REGISTRY.iter().map(|s| s.name).collect();
            anyhow::anyhow!("unknown dataset {name:?}; known: {names:?}")
        })
}

impl DatasetSpec {
    /// Generate the full dataset (train + test together).
    pub fn generate(&self) -> Dataset {
        if self.dense {
            generate_dense(self.name, self.n, self.d, self.label_noise, self.seed)
        } else {
            SyntheticSpec {
                name: self.name.to_string(),
                n: self.n,
                d: self.d,
                avg_nnz: self.avg_nnz,
                zipf_exponent: self.zipf_exponent,
                label_noise: self.label_noise,
                wstar_density: 0.3,
                seed: self.seed,
            }
            .generate()
        }
    }

    /// Generate and split into (train, test).
    pub fn load_split(&self) -> (Dataset, Dataset) {
        self.generate().split(self.test_frac, self.seed ^ 0x7E57)
    }

    /// A reduced-size variant (for fast tests / CI smoke runs).
    pub fn scaled(&self, factor: f64) -> DatasetSpec {
        let mut s = self.clone();
        s.n = ((s.n as f64) * factor).max(64.0) as usize;
        if !s.dense {
            s.d = ((s.d as f64) * factor.sqrt()).max(32.0) as usize;
            s.avg_nnz = s.avg_nnz.min(s.d as f64);
        }
        s
    }
}

/// Load a dataset by name with an optional scale factor.
pub fn load(name: &str, scale: f64) -> Result<(Dataset, Dataset, f64)> {
    let s = spec(name)?;
    if scale <= 0.0 || scale > 1.0 {
        bail!("scale must be in (0, 1], got {scale}");
    }
    let s = if scale < 1.0 { s.scaled(scale) } else { s.clone() };
    let (tr, te) = s.load_split();
    Ok((tr, te, s.c))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_all_five_paper_datasets() {
        let names: Vec<_> = REGISTRY.iter().map(|s| s.name).collect();
        assert_eq!(names, vec!["news20", "covtype", "rcv1", "webspam", "kddb"]);
    }

    #[test]
    fn spec_lookup() {
        assert_eq!(spec("rcv1").unwrap().c, 1.0);
        assert_eq!(spec("covtype").unwrap().c, 0.0625);
        assert!(spec("mnist").is_err());
    }

    #[test]
    fn scaled_load_produces_split() {
        let (tr, te, c) = load("rcv1", 0.05).unwrap();
        assert!(tr.n() > te.n());
        assert_eq!(c, 1.0);
        assert_eq!(tr.d(), te.d());
    }

    #[test]
    fn covtype_analog_is_dense() {
        let s = spec("covtype").unwrap().scaled(0.02);
        let ds = s.generate();
        assert_eq!(ds.x.avg_nnz(), ds.d() as f64);
    }

    #[test]
    fn load_rejects_bad_scale() {
        assert!(load("rcv1", 0.0).is_err());
        assert!(load("rcv1", 2.0).is_err());
    }
}
