//! # PASSCoDe
//!
//! A production-grade reproduction of **"PASSCoDe: Parallel ASynchronous
//! Stochastic dual Co-ordinate Descent"** (Hsieh, Yu & Dhillon, ICML 2015)
//! as a three-layer Rust + JAX/Pallas stack:
//!
//! * **Layer 3 (this crate)** — the paper's contribution: serial DCD
//!   (Algorithm 1, = LIBLINEAR's dual solver), the PASSCoDe family
//!   (Algorithm 2: Lock / Atomic / Wild), the CoCoA / AsySCD / Pegasos
//!   baselines, a discrete-event multicore simulator (the hardware
//!   substitution for the paper's 10-core testbed), datasets, metrics,
//!   and the experiment harness behind every table and figure.
//! * **Layer 2/1 (python/, build-time only)** — the JAX evaluation graph
//!   and its Pallas kernels, AOT-lowered to HLO text in `artifacts/`.
//! * **Runtime** — [`runtime`] loads those artifacts through the PJRT C
//!   API (behind the `xla` cargo feature; the default build ships a stub
//!   engine so no external toolchain is required).
//!
//! Training quick start — every solver in the family (serial DCD, the
//! three PASSCoDe memory models, CoCoA, AsySCD, Pegasos) sits behind the
//! [`solver::Solver`] trait; [`solver::lookup`] resolves a registry name
//! and [`solver::TrainSession`] gives epoch-granular control with warm
//! starts, deadlines, and checkpoint/restore uniform across the family:
//!
//! ```no_run
//! use passcode::data::registry;
//! use passcode::loss::LossKind;
//! use passcode::solver::{lookup, Solver, SolveOptions, StopWhen};
//!
//! let (train, test, c) = registry::load("rcv1", 0.1).unwrap();
//! let solver = lookup("passcode-wild").unwrap();
//! let opts = SolveOptions { threads: 4, epochs: 10, ..Default::default() };
//! let mut session = solver.session(&train, LossKind::Hinge, c, opts).unwrap();
//! session.run_epochs(5).unwrap();          // first half of the budget
//! let ckpt = session.snapshot();           // resumable state (α, ŵ, epoch)
//! // ... persist via coordinator::model_io::save_checkpoint, or resume
//! // in place; run_until bounds work by deadline/tolerance/updates:
//! session.run_until(StopWhen::Tolerance(1e-3)).unwrap();
//! println!("accuracy = {}", passcode::eval::accuracy(&test, session.w_hat()));
//! # let _ = ckpt;
//! ```
//!
//! **Migration note:** the inherent entry points (`SerialDcd::solve`,
//! `Passcode::solve` / `solve_warm`, `Cocoa::solve`, `Asyscd::solve`,
//! `Pegasos::solve`) remain as thin cold-start shims over the same
//! cores — existing code keeps working — but they are soft-deprecated
//! for new code: the registry + session API is the supported surface
//! for dispatch, warm starts, and resumable training.
//!
//! Serving quick start ([`serve`] — the inference side): a trained model
//! becomes a traffic-serving engine with wait-free hot-swap, request
//! microbatching, sharded scoring, and continuous training:
//!
//! ```no_run
//! use passcode::coordinator::Model;
//! use passcode::serve::{ServeConfig, ServeEngine};
//!
//! let model = Model::load("model.json").unwrap();
//! let engine = ServeEngine::start(model, None, &ServeConfig::default());
//! let ticket = engine.submit(vec![0, 7], vec![0.5, -1.0]);
//! println!("margin = {}", ticket.wait().margin);
//! println!("{}", engine.shutdown().render());
//! ```
//!
//! Or end to end from the CLI: `passcode replay --dataset rcv1 --shards 4`
//! replays a held-out split through the stack and reports QPS and
//! p50/p95/p99 latency while the online trainer hot-swaps models
//! mid-stream.
//!
//! HTTP serving ([`net`] — the network front end): `passcode listen`
//! puts a std-only HTTP/1.1 server in front of one [`serve`] engine
//! per route, with hot-swap publishes and stats on an admin plane:
//!
//! ```text
//! passcode listen --routes routes.json --addr 127.0.0.1:8080 --workers 4
//!
//! # score one sparse row (single-route setups may omit ?route=)
//! curl -s -X POST 'http://127.0.0.1:8080/v1/score?route=a' \
//!      -d '{"idx": [0, 7], "vals": [0.5, -1.0]}'
//! # batch rows, or LIBSVM lines (labels are scored for accuracy and
//! # fed to the route's online trainer when one is attached)
//! curl -s -X POST 'http://127.0.0.1:8080/v1/score?route=a' \
//!      -d '{"rows": [{"idx": [0], "vals": [1.0]}, {"idx": [3], "vals": [2.0]}]}'
//! curl -s -X POST 'http://127.0.0.1:8080/v1/score?route=a' \
//!      --data-binary @heldout.svm
//! # hot-swap a retrained model into route a; b is untouched
//! curl -s -X POST http://127.0.0.1:8080/v1/models/a/publish \
//!      -d '{"path": "retrained.json"}'
//! # per-route QPS/latency plus registry depth (versions_alive, epoch)
//! curl -s http://127.0.0.1:8080/v1/stats
//! curl -s http://127.0.0.1:8080/healthz
//! ```
//!
//! `routes.json` maps route/tenant names to independent engines —
//! `{"routes": [{"name": "a", "model": "a.json", "shards": 2},
//! {"name": "b", "dataset": "rcv1", "online": true}]}` — so A/B models
//! and per-dataset models serve side by side in one process
//! ([`net::router`]).  `benches/net_throughput.rs` measures the wire
//! path end to end over loopback.

#![warn(missing_docs)]

pub mod baselines;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod loss;
pub mod net;
pub mod runtime;
pub mod serve;
pub mod simcore;
pub mod solver;
pub mod util;
