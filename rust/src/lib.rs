//! # PASSCoDe
//!
//! A production-grade reproduction of **"PASSCoDe: Parallel ASynchronous
//! Stochastic dual Co-ordinate Descent"** (Hsieh, Yu & Dhillon, ICML 2015)
//! as a three-layer Rust + JAX/Pallas stack:
//!
//! * **Layer 3 (this crate)** — the paper's contribution: serial DCD
//!   (Algorithm 1, = LIBLINEAR's dual solver), the PASSCoDe family
//!   (Algorithm 2: Lock / Atomic / Wild), the CoCoA / AsySCD / Pegasos
//!   baselines, a discrete-event multicore simulator (the hardware
//!   substitution for the paper's 10-core testbed), datasets, metrics,
//!   and the experiment harness behind every table and figure.
//! * **Layer 2/1 (python/, build-time only)** — the JAX evaluation graph
//!   and its Pallas kernels, AOT-lowered to HLO text in `artifacts/`.
//! * **Runtime** — [`runtime`] loads those artifacts through the PJRT C
//!   API (behind the `xla` cargo feature; the default build ships a stub
//!   engine so no external toolchain is required).
//!
//! Training quick start — every solver in the family (serial DCD, the
//! three PASSCoDe memory models, CoCoA, AsySCD, Pegasos) sits behind the
//! [`solver::Solver`] trait; [`solver::lookup`] resolves a registry name
//! and [`solver::TrainSession`] gives epoch-granular control with warm
//! starts, deadlines, and checkpoint/restore uniform across the family:
//!
//! ```no_run
//! use passcode::data::registry;
//! use passcode::loss::LossKind;
//! use passcode::solver::{lookup, Solver, SolveOptions, StopWhen};
//!
//! let (train, test, c) = registry::load("rcv1", 0.1).unwrap();
//! let solver = lookup("passcode-wild").unwrap();
//! let opts = SolveOptions { threads: 4, epochs: 10, ..Default::default() };
//! let mut session = solver.session(&train, LossKind::Hinge, c, opts).unwrap();
//! session.run_epochs(5).unwrap();          // first half of the budget
//! let ckpt = session.snapshot();           // resumable state (α, ŵ, epoch)
//! // ... persist via coordinator::model_io::save_checkpoint, or resume
//! // in place; run_until bounds work by deadline/tolerance/updates:
//! session.run_until(StopWhen::Tolerance(1e-3)).unwrap();
//! println!("accuracy = {}", passcode::eval::accuracy(&test, session.w_hat()));
//! # let _ = ckpt;
//! ```
//!
//! **Migration note:** the inherent entry points (`SerialDcd::solve`,
//! `Passcode::solve` / `solve_warm`, `Cocoa::solve`, `Asyscd::solve`,
//! `Pegasos::solve`) remain as thin cold-start shims over the same
//! cores — existing code keeps working — but they are soft-deprecated
//! for new code: the registry + session API is the supported surface
//! for dispatch, warm starts, and resumable training.
//!
//! # Performance notes
//!
//! The asynchronous inner loop is the product (paper §5: updates/sec is
//! the axis every speedup plot shares).  Three layers keep it fast:
//!
//! * **Fused update kernels** ([`solver::kernel`]) — one
//!   `dot → solve → scatter` pass per coordinate, 4-way unrolled with
//!   independent accumulators, in three flavours matching the memory
//!   models (plain/wild, CAS, locked).  Workers are monomorphized over
//!   the kernel, so the memory-model dispatch happens once per thread,
//!   not once per update.  Serial solvers and the serving margin use the
//!   same unrolled gather (`data::sparse::dot_sparse_checked` /
//!   `dot_sparse_unchecked`).
//! * **Cache-conscious shared `w`** — [`util::SharedVec`] allocates in
//!   64-byte-aligned cache-line blocks, and the optional
//!   **feature-locality remap** ([`data::FeatureRemap`], CLI
//!   `--remap-features true`) reorders columns by descending document
//!   frequency so hot features pack into a few resident lines.  The
//!   remap is a pure permutation: objectives and predictions are
//!   unchanged, and the driver translates `ŵ` back to the original
//!   feature space at every export boundary.
//! * **Allocation-free epochs** — per-thread visit orders and shrink
//!   active sets live in reusable buffers, so steady-state epochs of a
//!   multi-epoch solve perform zero heap allocation; `TrainSession`
//!   additionally keeps shared `(α, ŵ)` buffers for its lifetime and
//!   drives [`solver::Passcode::run_epochs_shared`] in place, removing
//!   the per-epoch state copies of the old warm-start path (per-epoch
//!   partition setup remains — it is what keeps the derived RNG streams
//!   chunking-independent).
//!
//! `cargo bench --bench perf_hotpath` measures all of it (kernel
//! ablation: baseline vs fused vs fused+remap; updates/sec per memory
//! model × thread count) and records the numbers to `BENCH_hotpath.json`
//! — CI's bench-smoke job keeps the trajectory honest.  EXPERIMENTS.md
//! §Perf documents the methodology and current numbers.
//!
//! Serving quick start ([`serve`] — the inference side): a trained model
//! becomes a traffic-serving engine with wait-free hot-swap, request
//! microbatching, sharded scoring, and continuous training:
//!
//! ```no_run
//! use passcode::coordinator::Model;
//! use passcode::serve::{ServeConfig, ServeEngine};
//!
//! let model = Model::load("model.json").unwrap();
//! let engine = ServeEngine::start(model, None, &ServeConfig::default());
//! let ticket = engine.submit(vec![0, 7], vec![0.5, -1.0]);
//! println!("margin = {}", ticket.wait().margin);
//! println!("{}", engine.shutdown().render());
//! ```
//!
//! Or end to end from the CLI: `passcode replay --dataset rcv1 --shards 4`
//! replays a held-out split through the stack and reports QPS and
//! p50/p95/p99 latency while the online trainer hot-swaps models
//! mid-stream.
//!
//! HTTP serving ([`net`] — the network front end): `passcode listen`
//! puts a std-only HTTP/1.1 server in front of one [`serve`] engine
//! per route, with hot-swap publishes and stats on an admin plane:
//!
//! ```text
//! passcode listen --routes routes.json --addr 127.0.0.1:8080 --workers 4
//!
//! # score one sparse row (single-route setups may omit ?route=)
//! curl -s -X POST 'http://127.0.0.1:8080/v1/score?route=a' \
//!      -d '{"idx": [0, 7], "vals": [0.5, -1.0]}'
//! # batch rows, or LIBSVM lines (labels are scored for accuracy and
//! # fed to the route's online trainer when one is attached)
//! curl -s -X POST 'http://127.0.0.1:8080/v1/score?route=a' \
//!      -d '{"rows": [{"idx": [0], "vals": [1.0]}, {"idx": [3], "vals": [2.0]}]}'
//! curl -s -X POST 'http://127.0.0.1:8080/v1/score?route=a' \
//!      --data-binary @heldout.svm
//! # hot-swap a retrained model into route a; b is untouched
//! curl -s -X POST http://127.0.0.1:8080/v1/models/a/publish \
//!      -d '{"path": "retrained.json"}'
//! # per-route QPS/latency plus registry depth (versions_alive, epoch)
//! curl -s http://127.0.0.1:8080/v1/stats
//! curl -s http://127.0.0.1:8080/healthz
//! ```
//!
//! `routes.json` maps route/tenant names to independent engines —
//! `{"routes": [{"name": "a", "model": "a.json", "shards": 2},
//! {"name": "b", "dataset": "rcv1", "online": true}]}` — so A/B models
//! and per-dataset models serve side by side in one process
//! ([`net::router`]).  `benches/net_throughput.rs` measures the wire
//! path end to end over loopback.
//!
//! # Observability quick start
//!
//! The telemetry layer ([`obs`]) exports the paper's analysis
//! quantities — sampled staleness τ, CAS-retry/lock-wait contention,
//! per-worker epoch timings, the Theorem-3 backward-error ratio — next
//! to the serving metrics (per-route QPS, latency quantiles, registry
//! depth), all out of one lock-free [`obs::MetricsRegistry`]:
//!
//! ```text
//! passcode listen --routes routes.json --addr 127.0.0.1:8080
//!
//! # Prometheus text exposition: passcode_train_* (updates/sec, tau,
//! # cas retries, backward error, epoch timings) + passcode_http_* /
//! # passcode_route_* (QPS, p50/p95/p99, versions_alive, epoch)
//! curl -s http://127.0.0.1:8080/metrics
//! # flight recorder: recent spans (HTTP requests, training epochs)
//! # with tid + monotonic timestamps, as JSON
//! curl -s http://127.0.0.1:8080/v1/trace
//! ```
//!
//! `listen` enables the solver probes by default (`--probes false`
//! opts out); offline runs opt in and can dump the same span JSON:
//!
//! ```text
//! passcode train --dataset rcv1 --solver passcode-atomic --threads 4 \
//!     --probes true --trace-out spans.json
//! ```
//!
//! The probes are branch-predictable no-ops when disabled —
//! `perf_hotpath` carries a probes-on/off ablation row and the
//! acceptance bar is <2% overhead enabled, none disabled (see
//! EXPERIMENTS.md §Observability for how the live τ and backward-error
//! gauges relate to Theorem 3 and `passcode check`).
//!
//! # Distributed training quick start
//!
//! The distributed tier ([`dist`]) scales past one machine the
//! Hybrid-DCA way: rows shard across worker processes
//! ([`data::shard`]), each worker runs ordinary PASSCoDe epochs on its
//! shard, and a coordinator merges `ŵ` deltas asynchronously with
//! bounded staleness (fresh deltas at weight 1, stale ones damped by
//! 1/K, beyond `--max-lag` the worker is told to resync):
//!
//! ```text
//! # one coordinator...
//! passcode dist-coord --addr 127.0.0.1:8920 --dataset rcv1 --scale 0.1 \
//!     --workers 2 --max-lag 8 --checkpoint w.json --for-secs 600
//! # ...and one process per shard (ids 0 and 1)
//! passcode dist-work --coord 127.0.0.1:8920 --dataset rcv1 --scale 0.1 \
//!     --workers 2 --shard 0 --rounds 20 --ckpt shard0.ckpt
//! passcode dist-work --coord 127.0.0.1:8920 --dataset rcv1 --scale 0.1 \
//!     --workers 2 --shard 1 --rounds 20 --ckpt shard1.ckpt
//! # the merge plane is ordinary HTTP on the coordinator:
//! curl -s http://127.0.0.1:8920/v1/dist/stats     # merge epoch, rejects, ...
//! curl -s http://127.0.0.1:8920/metrics | grep passcode_dist_
//! ```
//!
//! A killed worker just stops contributing; restarting it with the
//! same `--ckpt` rejoins — it resumes its dual block from the
//! checkpoint and pulls the current merged `w`.  With `--lease-ops N`
//! the coordinator goes further: a worker silent for N logical ops is
//! declared dead, its contribution is rolled out of `w`, and its shard
//! ranges are reassigned to a live worker.  For tests and CI,
//! `passcode dist-sim --workers 2 --smoke` runs the whole tier
//! (sharding, HTTP, merge, metrics) in one process over loopback, and
//! `--chaos` (or `--faults plan.json`) puts every worker's transport
//! behind a seeded deterministic fault injector ([`dist::FaultPlan`])
//! — drops, duplicates, reorders, partitions — replayable from its
//! seed like a `passcode check` schedule:
//!
//! ```text
//! passcode dist-sim --workers 2 --chaos --fault-seed 7 --lease-ops 64
//! ```
//!
//! EXPERIMENTS.md §Distributed relates the merge rule to Hybrid-DCA
//! and to the τ/backward-error gauges; §Chaos covers the fault model,
//! idempotent pushes, leases, and reassignment.
//!
//! # Memory-model checking quick start
//!
//! The paper's correctness story is a *memory-model* story: Lock is
//! serializable, Atomic is race-free by CAS discipline (Theorem 2's
//! regime), and Wild races on `w` on purpose — Theorem 3 then shows the
//! racy `ŵ` is the exact solution of a nearby perturbed primal.  The
//! in-crate checker ([`chk`]) pins all of that as executable invariants
//! by running the *production* kernels over instrumented state under a
//! seeded schedule-exploring scheduler with a vector-clock race
//! detector, and measures the staleness τ plus the empirical backward
//! error `‖ε‖/‖ŵ‖` while it is at it:
//!
//! ```no_run
//! use passcode::chk::{self, CheckConfig};
//!
//! let report = chk::run_check(&CheckConfig {
//!     schedules: 25,
//!     ..CheckConfig::default()
//! });
//! print!("{}", report.render());
//! assert!(report.ok);
//! ```
//!
//! From the CLI: `passcode check` (or `passcode check --smoke` in CI);
//! any violation prints the schedule seed that deterministically
//! replays it.
//!
//! # Static analysis quick start
//!
//! The checker explores runtime schedules; the static audit ([`audit`])
//! pins the *source-level* invariants those schedules rely on, and that
//! `cargo test` cannot see eroding: per-module atomic-ordering
//! allowlists (`SeqCst` is banned without an in-source exemption),
//! lock-discipline containment (no `Mutex` in the kernel module trees),
//! allocation-freedom of the marked hot-path regions, `unsafe`/
//! `*_unchecked` containment with mandatory `// SAFETY:` comments,
//! probe gating, and cross-file wire-string/metric-name consistency:
//!
//! ```text
//! passcode audit                         # scan src/, tests/, EXPERIMENTS.md
//! passcode audit --smoke                 # src/ only (CI bench-smoke gate)
//! passcode audit --json audit_report.json --baseline audit_baseline.json
//! ```
//!
//! Every finding carries `file:line`, a rule id, and a fix hint; any
//! non-baselined finding exits nonzero.  The shipped tree is
//! audit-clean with an **empty** baseline — see EXPERIMENTS.md §Static
//! analysis for the rule table and the exemption-comment grammar.

#![warn(missing_docs)]
#![deny(unsafe_op_in_unsafe_fn)]

pub mod audit;
pub mod baselines;
pub mod chk;
pub mod coordinator;
pub mod data;
pub mod dist;
pub mod eval;
pub mod loss;
pub mod net;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod simcore;
pub mod solver;
pub mod util;
