//! # PASSCoDe
//!
//! A production-grade reproduction of **"PASSCoDe: Parallel ASynchronous
//! Stochastic dual Co-ordinate Descent"** (Hsieh, Yu & Dhillon, ICML 2015)
//! as a three-layer Rust + JAX/Pallas stack:
//!
//! * **Layer 3 (this crate)** — the paper's contribution: serial DCD
//!   (Algorithm 1, = LIBLINEAR's dual solver), the PASSCoDe family
//!   (Algorithm 2: Lock / Atomic / Wild), the CoCoA / AsySCD / Pegasos
//!   baselines, a discrete-event multicore simulator (the hardware
//!   substitution for the paper's 10-core testbed), datasets, metrics,
//!   and the experiment harness behind every table and figure.
//! * **Layer 2/1 (python/, build-time only)** — the JAX evaluation graph
//!   and its Pallas kernels, AOT-lowered to HLO text in `artifacts/`.
//! * **Runtime** — [`runtime`] loads those artifacts through the PJRT C
//!   API (`xla` crate) so evaluation runs with no Python anywhere.
//!
//! Quick start:
//!
//! ```no_run
//! use passcode::data::registry;
//! use passcode::loss::Hinge;
//! use passcode::solver::{MemoryModel, Passcode, SolveOptions};
//!
//! let (train, test, c) = registry::load("rcv1", 0.1).unwrap();
//! let loss = Hinge::new(c);
//! let opts = SolveOptions { threads: 4, epochs: 10, ..Default::default() };
//! let r = Passcode::solve(&train, &loss, MemoryModel::Wild, &opts, None);
//! println!("accuracy = {}", passcode::eval::accuracy(&test, &r.w_hat));
//! ```

pub mod baselines;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod loss;
pub mod runtime;
pub mod simcore;
pub mod solver;
pub mod util;
