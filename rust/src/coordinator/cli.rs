//! Hand-rolled CLI argument parsing (no clap in the offline image):
//! `passcode <command> [--key value]...`.

use anyhow::{bail, Result};

/// Parsed command line.
#[derive(Debug, Clone, PartialEq)]
pub struct Cli {
    pub command: String,
    /// Positional arguments after the command.
    pub positional: Vec<String>,
    /// `--key value` pairs, `--flag` becomes `("flag", "true")`.
    pub options: Vec<(String, String)>,
}

impl Cli {
    /// Parse an argv (excluding the binary name).
    pub fn parse(args: &[String]) -> Result<Cli> {
        let mut it = args.iter().peekable();
        let command = match it.next() {
            Some(c) if !c.starts_with('-') => c.clone(),
            _ => bail!("usage: passcode <command> [--key value]..."),
        };
        let mut positional = Vec::new();
        let mut options = Vec::new();
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--") {
                let is_value = it
                    .peek()
                    .map(|v| !v.starts_with("--"))
                    .unwrap_or(false);
                if is_value {
                    options.push((key.to_string(), it.next().unwrap().clone()));
                } else {
                    options.push((key.to_string(), "true".to_string()));
                }
            } else {
                positional.push(tok.clone());
            }
        }
        Ok(Cli { command, positional, options })
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    pub fn opt_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.opt(key).unwrap_or(default)
    }

    pub fn opt_parse<T: std::str::FromStr>(
        &self,
        key: &str,
        default: T,
    ) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{key} {v:?}: {e}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn parses_command_options_positionals() {
        let c = Cli::parse(&argv(
            "train rcv1 --threads 8 --solver passcode-wild --verbose",
        ))
        .unwrap();
        assert_eq!(c.command, "train");
        assert_eq!(c.positional, vec!["rcv1"]);
        assert_eq!(c.opt("threads"), Some("8"));
        assert_eq!(c.opt("solver"), Some("passcode-wild"));
        assert_eq!(c.opt("verbose"), Some("true"));
        assert_eq!(c.opt("missing"), None);
    }

    #[test]
    fn opt_parse_defaults_and_errors() {
        let c = Cli::parse(&argv("x --n 5")).unwrap();
        assert_eq!(c.opt_parse("n", 1usize).unwrap(), 5);
        assert_eq!(c.opt_parse("m", 7usize).unwrap(), 7);
        let bad = Cli::parse(&argv("x --n five")).unwrap();
        assert!(bad.opt_parse("n", 1usize).is_err());
    }

    #[test]
    fn rejects_empty_or_flag_first() {
        assert!(Cli::parse(&[]).is_err());
        assert!(Cli::parse(&argv("--flag")).is_err());
    }

    #[test]
    fn later_options_win() {
        let c = Cli::parse(&argv("x --k 1 --k 2")).unwrap();
        assert_eq!(c.opt("k"), Some("2"));
    }
}
