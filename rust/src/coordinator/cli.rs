//! Hand-rolled CLI argument parsing (no clap in the offline image):
//! `passcode <command> [--key value]...`.

use anyhow::{bail, Result};

/// One-line summaries of every subcommand, printed on parse errors and
/// unknown commands so the CLI is self-describing.
pub const SUBCOMMANDS: &[(&str, &str)] = &[
    ("train", "train a model (--dataset, --solver, --threads, ...)"),
    ("datasets", "print Table-3 analog statistics (--scale)"),
    ("calibrate", "probe the simulator's hardware cost model"),
    (
        "experiment",
        "reproduce a paper artifact (table1|table2|table3|fig-a|fig-d|backward-error)",
    ),
    ("eval", "AOT vs native evaluation cross-check (--dataset, --scale)"),
    ("predict", "batch-score a LIBSVM file (--model, --data, [--out])"),
    (
        "serve",
        "score traffic through the online stack (--model|--dataset, --data|stdin, --shards)",
    ),
    (
        "replay",
        "replay a held-out split as traffic with mid-stream hot-swaps (--dataset, --shards)",
    ),
    (
        "listen",
        "serve scoring traffic over HTTP (--routes cfg.json | --model|--dataset; --addr, --workers)",
    ),
    (
        "check",
        "race-check the memory-model kernels over seeded schedules (--model, --schedules, --seed, --smoke)",
    ),
    (
        "dist-coord",
        "run the distributed merge coordinator (--addr, --dataset|--dim, --workers, --max-lag, --lease-ops, --checkpoint)",
    ),
    (
        "dist-work",
        "run one distributed worker over its shard (--coord, --shard, --dataset|--manifest, --rounds, --ckpt)",
    ),
    (
        "dist-sim",
        "N in-process dist workers over a loopback coordinator (--workers, --rounds, --max-lag, --smoke; --chaos/--faults for seeded fault injection)",
    ),
    (
        "audit",
        "statically audit the crate's own sources for concurrency-invariant rot (--json, --baseline, --smoke)",
    ),
];

/// Parsed command line.
#[derive(Debug, Clone, PartialEq)]
pub struct Cli {
    /// The subcommand (first non-flag token).
    pub command: String,
    /// Positional arguments after the command.
    pub positional: Vec<String>,
    /// `--key value` pairs, `--flag` becomes `("flag", "true")`.
    pub options: Vec<(String, String)>,
}

impl Cli {
    /// The full usage listing (all subcommands, one per line).
    pub fn usage() -> String {
        let width = SUBCOMMANDS
            .iter()
            .map(|(name, _)| name.len())
            .max()
            .unwrap_or(0);
        let mut s = String::from(
            "usage: passcode <command> [--key value]...\n\ncommands:\n",
        );
        for (name, what) in SUBCOMMANDS {
            s.push_str(&format!("  {name:<width$}  {what}\n"));
        }
        s
    }

    /// Parse an argv (excluding the binary name).
    pub fn parse(args: &[String]) -> Result<Cli> {
        let mut it = args.iter().peekable();
        let command = match it.next() {
            Some(c) if !c.starts_with('-') => c.clone(),
            _ => bail!("{}", Cli::usage()),
        };
        let mut positional = Vec::new();
        let mut options = Vec::new();
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--") {
                let is_value = it
                    .peek()
                    .map(|v| !v.starts_with("--"))
                    .unwrap_or(false);
                if is_value {
                    options.push((key.to_string(), it.next().unwrap().clone()));
                } else {
                    options.push((key.to_string(), "true".to_string()));
                }
            } else {
                positional.push(tok.clone());
            }
        }
        Ok(Cli { command, positional, options })
    }

    /// Last value of `--key` (later occurrences win), if present.
    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    /// Value of `--key`, or `default` when absent.
    pub fn opt_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.opt(key).unwrap_or(default)
    }

    /// Reject flags outside `allowed`, printing the offending flag plus
    /// the full usage listing — a typo'd `--shard` must not be silently
    /// ignored (nor bubble up as a bare anyhow error).
    pub fn check_flags(&self, allowed: &[&str]) -> Result<()> {
        for (k, _) in &self.options {
            if !allowed.contains(&k.as_str()) {
                bail!(
                    "unknown flag --{k} for `{}`\n\n{}",
                    self.command,
                    Cli::usage()
                );
            }
        }
        Ok(())
    }

    /// Parse `--key` as `T`, or `default` when absent.
    pub fn opt_parse<T: std::str::FromStr>(
        &self,
        key: &str,
        default: T,
    ) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{key} {v:?}: {e}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn parses_command_options_positionals() {
        let c = Cli::parse(&argv(
            "train rcv1 --threads 8 --solver passcode-wild --verbose",
        ))
        .unwrap();
        assert_eq!(c.command, "train");
        assert_eq!(c.positional, vec!["rcv1"]);
        assert_eq!(c.opt("threads"), Some("8"));
        assert_eq!(c.opt("solver"), Some("passcode-wild"));
        assert_eq!(c.opt("verbose"), Some("true"));
        assert_eq!(c.opt("missing"), None);
    }

    #[test]
    fn opt_parse_defaults_and_errors() {
        let c = Cli::parse(&argv("x --n 5")).unwrap();
        assert_eq!(c.opt_parse("n", 1usize).unwrap(), 5);
        assert_eq!(c.opt_parse("m", 7usize).unwrap(), 7);
        let bad = Cli::parse(&argv("x --n five")).unwrap();
        assert!(bad.opt_parse("n", 1usize).is_err());
    }

    #[test]
    fn rejects_empty_or_flag_first() {
        assert!(Cli::parse(&[]).is_err());
        assert!(Cli::parse(&argv("--flag")).is_err());
    }

    #[test]
    fn later_options_win() {
        let c = Cli::parse(&argv("x --k 1 --k 2")).unwrap();
        assert_eq!(c.opt("k"), Some("2"));
    }

    #[test]
    fn check_flags_rejects_unknown_with_usage() {
        let c = Cli::parse(&argv("serve --model m.json --bogus 1")).unwrap();
        assert!(c.check_flags(&["model", "bogus"]).is_ok());
        let err = format!("{:#}", c.check_flags(&["model"]).unwrap_err());
        assert!(err.contains("--bogus"), "{err}");
        assert!(err.contains("serve"), "{err}");
        assert!(err.contains("commands:"), "{err}");
    }

    #[test]
    fn audit_is_a_known_subcommand() {
        assert!(SUBCOMMANDS.iter().any(|(name, _)| *name == "audit"));
        let c = Cli::parse(&argv("audit --json out.json --smoke")).unwrap();
        assert!(c.check_flags(&["json", "baseline", "smoke", "root"]).is_ok());
        let bad = Cli::parse(&argv("audit --basline b.json")).unwrap();
        let err = format!(
            "{:#}",
            bad.check_flags(&["json", "baseline", "smoke", "root"]).unwrap_err()
        );
        assert!(err.contains("--basline"), "{err}");
        assert!(err.contains("audit"), "{err}");
        assert!(err.contains("commands:"), "{err}");
    }

    #[test]
    fn usage_lists_every_subcommand() {
        let u = Cli::usage();
        for (name, _) in SUBCOMMANDS {
            assert!(u.contains(name), "usage missing {name}");
        }
        assert!(u.contains("serve"));
        assert!(u.contains("replay"));
        // Parse errors carry the listing too.
        let err = format!("{:#}", Cli::parse(&[]).unwrap_err());
        assert!(err.contains("commands:"));
    }
}
