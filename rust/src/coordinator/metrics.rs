//! Metric logging: per-epoch rows, CSV/JSON export, and the aligned text
//! tables the bench harness prints (no external plotting here — the CSV
//! is the figure data).

use std::fmt::Write as _;

use crate::util::Json;

/// One evaluation snapshot.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricRow {
    pub epoch: usize,
    /// Training seconds so far (excl. init).
    pub train_secs: f64,
    /// P(ŵ) — the paper plots this even for Wild (§5.1).
    pub primal: f64,
    /// D(α).
    pub dual: f64,
    /// P(w̄) + D(α) ≥ 0.
    pub gap: f64,
    /// Test accuracy with the maintained ŵ.
    pub test_acc: f64,
}

/// A labeled series of metric rows.
#[derive(Debug, Clone, Default)]
pub struct MetricsLog {
    pub label: String,
    pub rows: Vec<MetricRow>,
}

impl MetricsLog {
    pub fn new(label: impl Into<String>) -> Self {
        Self { label: label.into(), rows: Vec::new() }
    }

    pub fn push(&mut self, row: MetricRow) {
        self.rows.push(row);
    }

    /// First training time (secs) at which the primal objective dips
    /// under `threshold`; `None` if never.
    pub fn time_to_primal(&self, threshold: f64) -> Option<f64> {
        self.rows
            .iter()
            .find(|r| r.primal <= threshold)
            .map(|r| r.train_secs)
    }

    /// First training time at which test accuracy reaches `threshold`.
    pub fn time_to_accuracy(&self, threshold: f64) -> Option<f64> {
        self.rows
            .iter()
            .find(|r| r.test_acc >= threshold)
            .map(|r| r.train_secs)
    }

    pub fn final_row(&self) -> Option<&MetricRow> {
        self.rows.last()
    }

    /// CSV with a header; `label` becomes the first column.
    pub fn to_csv(&self) -> String {
        let mut s =
            String::from("label,epoch,train_secs,primal,dual,gap,test_acc\n");
        for r in &self.rows {
            let _ = writeln!(
                s,
                "{},{},{:.6},{:.8},{:.8},{:.3e},{:.5}",
                self.label, r.epoch, r.train_secs, r.primal, r.dual, r.gap,
                r.test_acc
            );
        }
        s
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::str(&self.label)),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("epoch", Json::num(r.epoch as f64)),
                                ("train_secs", Json::num(r.train_secs)),
                                ("primal", Json::num(r.primal)),
                                ("dual", Json::num(r.dual)),
                                ("gap", Json::num(r.gap)),
                                ("test_acc", Json::num(r.test_acc)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Minimal fixed-width text table (bench harness output).
#[derive(Debug, Default)]
pub struct TextTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TextTable {
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut width = vec![0usize; cols];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    out.push_str("  ");
                }
                let _ = write!(out, "{:<w$}", c, w = width[i]);
            }
            out.push('\n');
        };
        fmt_row(&self.header, &width, &mut out);
        let total: usize = width.iter().sum::<usize>() + 2 * (cols - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            fmt_row(row, &width, &mut out);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn log() -> MetricsLog {
        let mut m = MetricsLog::new("test");
        for e in 1..=3 {
            m.push(MetricRow {
                epoch: e,
                train_secs: e as f64 * 0.5,
                primal: 10.0 / e as f64,
                dual: -9.0,
                gap: 1.0 / e as f64,
                test_acc: 0.8 + 0.05 * e as f64,
            });
        }
        m
    }

    #[test]
    fn thresholds() {
        let m = log();
        assert_eq!(m.time_to_primal(5.0), Some(1.0)); // epoch 2
        assert_eq!(m.time_to_primal(1.0), None);
        assert_eq!(m.time_to_accuracy(0.9), Some(1.0));
        assert_eq!(m.final_row().unwrap().epoch, 3);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let csv = log().to_csv();
        let lines: Vec<&str> = csv.trim().lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("label,epoch"));
        assert!(lines[1].starts_with("test,1,"));
    }

    #[test]
    fn json_export_parses_back() {
        let j = log().to_json();
        let txt = j.to_pretty();
        let back = crate::util::Json::parse(&txt).unwrap();
        assert_eq!(back.get("label").unwrap().as_str().unwrap(), "test");
        assert_eq!(back.get("rows").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = TextTable::new(&["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer".into(), "2.5".into()]);
        let s = t.render();
        assert!(s.contains("name"));
        assert!(s.lines().count() == 4);
        // all data lines same length
        let lens: Vec<usize> =
            s.lines().map(|l| l.trim_end().len()).collect();
        assert!(lens[2] >= 8);
    }
}
