//! The experiment driver: dataset loading, registry-dispatched training,
//! per-epoch evaluation, and provenance — one [`RunConfig`] in, one
//! [`RunOutput`] out.  Every bench and example funnels through here.
//!
//! Dispatch goes through the `solver::api` registry: the config's
//! [`SolverKind`](super::config::SolverKind) instantiates a `dyn Solver`,
//! and the driver drives its `TrainSession` `eval_every` epochs at a
//! time — evaluation happens *between* `run_epochs` calls, so the logged
//! `train_secs` exclude it by construction (paper §5.3 protocol).

use anyhow::{Context, Result};

use crate::data::{libsvm, registry, Dataset, FeatureRemap};
use crate::eval;
use crate::loss::DynLoss;
use crate::solver::{Solver, SolveOptions, SolveResult};

use super::config::RunConfig;
use super::metrics::{MetricRow, MetricsLog};
use super::model_io::Model;

/// Everything a run produces.
#[derive(Debug)]
pub struct RunOutput {
    pub config: RunConfig,
    pub result: SolveResult,
    pub metrics: MetricsLog,
    /// Test accuracy predicting with the maintained ŵ.
    pub acc_what: f64,
    /// Test accuracy predicting with w̄ = Σ α_i x_i (Table 2's contrast).
    pub acc_wbar: f64,
    /// Final primal objective P(ŵ) on the training set.
    pub primal_final: f64,
    /// Final duality gap (projected α).
    pub gap_final: f64,
    /// The feature-locality remap applied during training, when
    /// `RunConfig::remap_features` was set.  `result.w_hat` is already
    /// translated back to the original feature space; the map is exposed
    /// for callers that need to persist it next to a checkpoint
    /// (`coordinator::model_io::save_remap`) or score in remapped space.
    pub remap: Option<FeatureRemap>,
}

/// Load the dataset pair for a config.
pub fn load_data(cfg: &RunConfig) -> Result<(Dataset, Dataset, f64)> {
    if let Some(path) = &cfg.data_path {
        let ds = libsvm::load(path)?;
        let (tr, te) = ds.split(0.2, cfg.seed);
        let c = cfg.c.unwrap_or(1.0);
        return Ok((tr, te, c));
    }
    let (tr, te, c_default) = registry::load(&cfg.dataset, cfg.scale)?;
    Ok((tr, te, cfg.c.unwrap_or(c_default)))
}

/// Train a model for the serving path: run `cfg` end to end and package
/// the result as `(Model, SolveResult)` — `ŵ` for scoring plus the dual
/// iterate `α` for the online trainer's warm starts
/// (`crate::serve::OnlineTrainer`).
pub fn train_model(cfg: &RunConfig) -> Result<(Model, SolveResult)> {
    // Resolve C the same way load_data does, but without generating the
    // dataset a second time (run() loads it already).
    let c = match (cfg.c, &cfg.data_path) {
        (Some(c), _) => c,
        (None, Some(_)) => 1.0,
        (None, None) => registry::spec(&cfg.dataset)?.c,
    };
    let out = run(cfg)?;
    let model = Model::from_run(cfg, c, out.result.w_hat.clone());
    Ok((model, out.result))
}

/// Run a config end to end.
pub fn run(cfg: &RunConfig) -> Result<RunOutput> {
    let (train, test, c) = load_data(cfg)?;
    // Feature-locality remap (`--remap-features true`): train in the
    // remapped column space — every reported quantity is permutation-
    // invariant — and translate ŵ back at the export boundary below.
    let (train, test, remap) = if cfg.remap_features {
        let (tr, map) = train.remap_features();
        let te = test.remap_features_with(&map);
        (tr, te, Some(map))
    } else {
        (train, test, None)
    };
    let loss = DynLoss::new(cfg.loss, c);
    let opts = SolveOptions {
        epochs: cfg.epochs,
        seed: cfg.seed,
        shrinking: cfg.shrinking,
        sampling: cfg.sampling,
        threads: cfg.threads,
        pin_threads: cfg.pin_threads,
        eval_every: cfg.eval_every,
    };

    let solver = cfg.solver.instantiate();
    let mut session = solver
        .session(&train, cfg.loss, c, opts)
        .with_context(|| format!("open {} session", solver.name()))?;

    let mut metrics = MetricsLog::new(cfg.solver.name());
    if cfg.eval_every > 0 {
        while session.epochs() < cfg.epochs {
            let k = cfg.eval_every.min(cfg.epochs - session.epochs());
            session.run_epochs(k)?;
            metrics.push(MetricRow {
                epoch: session.epochs(),
                train_secs: session.train_secs(),
                primal: eval::primal_objective(&train, &loss, session.w_hat()),
                dual: eval::dual_objective(&train, &loss, session.alpha()),
                gap: eval::duality_gap(&train, &loss, session.alpha()),
                test_acc: eval::accuracy(&test, session.w_hat()),
            });
        }
    } else {
        session.run_epochs(cfg.epochs)?;
    }
    let mut result: SolveResult = session.into_result();

    let acc_what = eval::accuracy(&test, &result.w_hat);
    let wbar = eval::wbar_from_alpha(&train, &result.alpha);
    let acc_wbar = eval::accuracy(&test, &wbar);
    let primal_final = eval::primal_objective(&train, &loss, &result.w_hat);
    let gap_final = eval::duality_gap(&train, &loss, &result.alpha);

    // Export boundary: everything downstream (model save, serving,
    // original-space eval) sees ŵ in the original feature order.
    if let Some(map) = &remap {
        result.w_hat = map.unmap_w(&result.w_hat);
    }

    Ok(RunOutput {
        config: cfg.clone(),
        result,
        metrics,
        acc_what,
        acc_wbar,
        primal_final,
        gap_final,
        remap,
    })
}

#[cfg(test)]
mod tests {
    use super::super::config::{LossKind, SolverKind};
    use super::*;
    use crate::solver::MemoryModel;

    fn base() -> RunConfig {
        RunConfig {
            dataset: "rcv1".into(),
            scale: 0.02,
            epochs: 10,
            threads: 2,
            eval_every: 2,
            ..Default::default()
        }
    }

    #[test]
    fn driver_runs_passcode_wild() {
        let out = run(&base()).unwrap();
        assert_eq!(out.metrics.rows.len(), 5);
        assert!(out.acc_what > 0.7, "acc {}", out.acc_what);
        assert!(out.gap_final >= -1e-9);
        // metrics rows are in epoch order with nondecreasing time
        for w in out.metrics.rows.windows(2) {
            assert!(w[1].epoch > w[0].epoch);
            assert!(w[1].train_secs >= w[0].train_secs - 1e-9);
        }
    }

    #[test]
    fn driver_runs_every_solver() {
        for solver in [
            SolverKind::Dcd,
            SolverKind::Liblinear,
            SolverKind::Passcode(MemoryModel::Atomic),
            SolverKind::Cocoa,
            SolverKind::Pegasos,
        ] {
            let mut cfg = base();
            cfg.solver = solver;
            cfg.epochs = 3;
            let out = run(&cfg).unwrap();
            assert!(
                out.primal_final.is_finite(),
                "{:?} returned junk",
                solver
            );
        }
    }

    #[test]
    fn remap_features_run_exports_original_space_model() {
        let mut cfg = base();
        cfg.eval_every = 0;
        cfg.solver = SolverKind::Dcd;
        cfg.epochs = 10;
        let plain = run(&cfg).unwrap();
        assert!(plain.remap.is_none());
        cfg.remap_features = true;
        let remapped = run(&cfg).unwrap();
        assert!(remapped.remap.is_some());
        // Same data, same serial algorithm, permuted columns: the
        // exported ŵ is back in the original feature order and must
        // match the unremapped run up to float summation noise.
        let err = plain
            .result
            .w_hat
            .iter()
            .zip(&remapped.result.w_hat)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        assert!(err < 1e-6, "remap changed the exported model: {err}");
        assert!((plain.acc_what - remapped.acc_what).abs() < 0.02);
    }

    #[test]
    fn train_model_packages_w_and_alpha() {
        let mut cfg = base();
        cfg.eval_every = 0;
        let (model, result) = train_model(&cfg).unwrap();
        assert_eq!(model.w, result.w_hat);
        assert_eq!(model.loss, "hinge");
        assert_eq!(model.solver, "passcode-wild");
        assert!(result.alpha.iter().any(|&a| a != 0.0));
    }

    #[test]
    fn asyscd_runs_on_tiny_news20() {
        let cfg = RunConfig {
            dataset: "news20".into(),
            scale: 0.05,
            solver: SolverKind::Asyscd,
            epochs: 5,
            threads: 2,
            eval_every: 0,
            ..Default::default()
        };
        let out = run(&cfg).unwrap();
        assert!(out.primal_final.is_finite());
    }

    #[test]
    fn asyscd_oom_guard_fires_at_full_scale() {
        let cfg = RunConfig {
            dataset: "kddb".into(),
            scale: 1.0,
            solver: SolverKind::Asyscd,
            epochs: 1,
            eval_every: 0,
            ..Default::default()
        };
        assert!(run(&cfg).is_err(), "expected the dense-Q memory guard");
    }

    #[test]
    fn squared_hinge_and_logistic_dispatch() {
        for loss in [
            LossKind::SquaredHinge,
            LossKind::Logistic,
            LossKind::Square,
        ] {
            let mut cfg = base();
            cfg.loss = loss;
            cfg.epochs = 3;
            cfg.solver = SolverKind::Dcd;
            let out = run(&cfg).unwrap();
            assert!(out.primal_final.is_finite());
        }
    }

    #[test]
    fn pegasos_rejects_non_hinge() {
        let mut cfg = base();
        cfg.solver = SolverKind::Pegasos;
        cfg.loss = LossKind::Logistic;
        assert!(run(&cfg).is_err());
    }
}
