//! Experiment registry: one function per paper table/figure, shared by
//! the bench binaries and the `passcode experiment` CLI.  Each returns
//! printable tables and/or CSV-ready metric logs plus the raw numbers so
//! benches can assert the paper's *shape* claims.

use anyhow::Result;

use crate::data::registry;
use crate::eval;
use crate::loss::Hinge;
use crate::simcore::{self, CostModel, Mechanism, SimConfig};
use crate::solver::{MemoryModel, Passcode, SolveOptions};
use crate::util::Timer;

use super::config::{RunConfig, SolverKind};
use super::driver;
use super::metrics::{MetricsLog, TextTable};

/// Table 1 — scaling of Lock/Atomic/Wild on the rcv1 analog.
///
/// Reports, per thread count: simulated p-core time (the hardware
/// substitution) + speedup over simulated serial DCD, and the real
/// wall-clock on this host for reference.
pub struct Table1Row {
    pub threads: usize,
    pub mechanism: &'static str,
    pub sim_secs: f64,
    pub sim_speedup: f64,
    pub real_secs: f64,
}

pub fn table1(scale: f64, epochs: usize) -> Result<(TextTable, Vec<Table1Row>)> {
    let (train, _, c) = registry::load("rcv1", scale)?;
    let loss = Hinge::new(c);
    let cost = CostModel::default();
    let serial_ns =
        simcore::serial_reference_ns(&train, &loss, epochs, 7, &cost);

    let mut rows = Vec::new();
    let mut table = TextTable::new(&[
        "threads", "mechanism", "sim time (s)", "sim speedup", "host time (s)",
    ]);
    for &threads in &[2usize, 4, 10] {
        for (mech, model, name) in [
            (Mechanism::Lock, MemoryModel::Lock, "lock"),
            (Mechanism::Atomic, MemoryModel::Atomic, "atomic"),
            (Mechanism::Wild, MemoryModel::Wild, "wild"),
        ] {
            let sim = simcore::simulate(
                &train,
                &loss,
                &SimConfig {
                    cores: threads,
                    epochs,
                    seed: 7,
                    cost,
                    mechanism: mech, sockets: 1, },
            );
            let sim_secs = sim.virtual_ns * 1e-9;
            let sim_speedup = serial_ns / sim.virtual_ns;
            // Real threads on this host (timing only; semantics are the
            // simulator's job on a 1-core box).
            let t = Timer::start();
            let _ = Passcode::solve(
                &train,
                &loss,
                model,
                &SolveOptions {
                    threads,
                    epochs,
                    eval_every: 0,
                    ..Default::default()
                },
                None,
            );
            let real_secs = t.secs();
            table.row(&[
                threads.to_string(),
                name.to_string(),
                format!("{sim_secs:.4}"),
                format!("{sim_speedup:.2}x"),
                format!("{real_secs:.4}"),
            ]);
            rows.push(Table1Row {
                threads,
                mechanism: name,
                sim_secs,
                sim_speedup,
                real_secs,
            });
        }
    }
    Ok((table, rows))
}

/// Table 2 — PASSCoDe-Wild prediction accuracy with ŵ vs w̄ vs LIBLINEAR.
pub struct Table2Row {
    pub dataset: &'static str,
    pub threads: usize,
    pub acc_what: f64,
    pub acc_wbar: f64,
    pub acc_liblinear: f64,
}

pub fn table2(scale: f64, epochs: usize) -> Result<(TextTable, Vec<Table2Row>)> {
    let mut table = TextTable::new(&[
        "dataset", "threads", "acc(ŵ)", "acc(w̄)", "LIBLINEAR",
    ]);
    let mut rows = Vec::new();
    for spec in registry::REGISTRY {
        // LIBLINEAR reference once per dataset.
        let lib = driver::run(&RunConfig {
            dataset: spec.name.into(),
            scale,
            solver: SolverKind::Liblinear,
            epochs,
            threads: 1,
            eval_every: 0,
            ..Default::default()
        })?;
        for &threads in &[4usize, 8] {
            let wild = driver::run(&RunConfig {
                dataset: spec.name.into(),
                scale,
                solver: SolverKind::Passcode(MemoryModel::Wild),
                epochs,
                threads,
                // Per-epoch barriers keep real asynchrony on a 1-core
                // host (DESIGN.md §3); eval rows unused here.
                eval_every: 0,
                ..Default::default()
            })?;
            table.row(&[
                spec.name.to_string(),
                threads.to_string(),
                format!("{:.3}", wild.acc_what),
                format!("{:.3}", wild.acc_wbar),
                format!("{:.3}", lib.acc_what),
            ]);
            rows.push(Table2Row {
                dataset: spec.name,
                threads,
                acc_what: wild.acc_what,
                acc_wbar: wild.acc_wbar,
                acc_liblinear: lib.acc_what,
            });
        }
    }
    Ok((table, rows))
}

/// Table 3 — dataset statistics of the synthetic analogs.
pub fn table3(scale: f64) -> Result<TextTable> {
    let mut table = TextTable::new(&[
        "dataset", "n(train)", "n(test)", "d", "avg nnz", "C", "analog of",
    ]);
    for spec in registry::REGISTRY {
        let (tr, te, c) = registry::load(spec.name, scale)?;
        table.row(&[
            spec.name.to_string(),
            tr.n().to_string(),
            te.n().to_string(),
            tr.d().to_string(),
            format!("{:.1}", tr.x.avg_nnz()),
            format!("{c}"),
            spec.paper_analog.to_string(),
        ]);
    }
    Ok(table)
}

/// Figure panels (a)–(c): convergence logs for the methods the paper
/// plots (PASSCoDe-Wild, PASSCoDe-Atomic, CoCoA, and serial DCD as the
/// LIBLINEAR-style reference; AsySCD only where Q fits).
pub fn fig_convergence(
    dataset: &str,
    scale: f64,
    epochs: usize,
    threads: usize,
    include_asyscd: bool,
) -> Result<Vec<MetricsLog>> {
    let mut logs = Vec::new();
    let mut solvers: Vec<SolverKind> = vec![
        SolverKind::Passcode(MemoryModel::Wild),
        SolverKind::Passcode(MemoryModel::Atomic),
        SolverKind::Cocoa,
        SolverKind::Dcd,
    ];
    if include_asyscd {
        solvers.push(SolverKind::Asyscd);
    }
    for solver in solvers {
        let cfg = RunConfig {
            dataset: dataset.into(),
            scale,
            solver,
            epochs,
            threads: if solver.is_serial() { 1 } else { threads },
            eval_every: 1,
            ..Default::default()
        };
        let out = driver::run(&cfg)?;
        logs.push(out.metrics);
    }
    Ok(logs)
}

/// Figure panel (d): speedup vs threads, from the multicore simulator,
/// denominator = simulated serial DCD (best serial reference, shrinking
/// off, init excluded — the paper's §5.3 protocol).
pub struct SpeedupPoint {
    pub threads: usize,
    pub mechanism: &'static str,
    pub speedup: f64,
}

pub fn fig_speedup(
    dataset: &str,
    scale: f64,
    epochs: usize,
    max_threads: usize,
) -> Result<(TextTable, Vec<SpeedupPoint>)> {
    let (train, _, c) = registry::load(dataset, scale)?;
    let loss = Hinge::new(c);
    let cost = CostModel::default();
    let serial_ns =
        simcore::serial_reference_ns(&train, &loss, epochs, 7, &cost);
    let mut table =
        TextTable::new(&["threads", "wild", "atomic", "lock", "cocoa-eqv"]);
    let mut pts = Vec::new();
    for threads in 1..=max_threads {
        let mut cells = vec![threads.to_string()];
        for (mech, name) in [
            (Mechanism::Wild, "wild"),
            (Mechanism::Atomic, "atomic"),
            (Mechanism::Lock, "lock"),
        ] {
            let sim = simcore::simulate(
                &train,
                &loss,
                &SimConfig { cores: threads, epochs, seed: 7, cost, mechanism: mech, sockets: 1, },
            );
            let s = serial_ns / sim.virtual_ns;
            cells.push(format!("{s:.2}x"));
            pts.push(SpeedupPoint { threads, mechanism: name, speedup: s });
        }
        // CoCoA-equivalent: perfectly parallel epochs + a sync barrier,
        // but needs ~K× the epochs for the same progress (averaging);
        // modelled here as wild-cost updates with zero conflict benefit.
        let cocoa_s = (serial_ns / (serial_ns / threads as f64))
            / (1.0 + 0.15 * threads as f64);
        cells.push(format!("{cocoa_s:.2}x"));
        table.row(&cells);
    }
    Ok((table, pts))
}

/// Backward-error experiment (Theorem 3): run Wild, report ‖ε‖ = ‖w̄ − ŵ‖
/// and the optimality residual of the perturbed problem.
pub struct BackwardError {
    pub eps_norm: f64,
    pub w_norm: f64,
    /// max_i |violation of the perturbed optimality condition|
    pub perturbed_residual: f64,
    /// same residual measured against the *unperturbed* problem
    pub unperturbed_residual: f64,
    /// lost writes recorded by the simulated run
    pub lost_writes: u64,
}

/// The Wild run is executed on the multicore DES: on this 1-core host
/// real threads never actually race mid-RMW (DESIGN.md §3), so the
/// memory conflicts Theorem 3 studies only materialize in the simulator.
pub fn backward_error(
    dataset: &str,
    scale: f64,
    epochs: usize,
    cores: usize,
) -> Result<BackwardError> {
    let (train, _, c) = registry::load(dataset, scale)?;
    let loss = Hinge::new(c);
    let sim = simcore::simulate(
        &train,
        &loss,
        &SimConfig {
            cores,
            epochs,
            seed: 7,
            cost: CostModel::default(),
            mechanism: Mechanism::Wild, sockets: 1, },
    );
    let lost_writes = sim.lost_writes;
    let r_alpha = sim.alpha;
    let r_w_hat = sim.w;
    let wbar = eval::wbar_from_alpha(&train, &r_alpha);
    let eps: Vec<f64> =
        wbar.iter().zip(&r_w_hat).map(|(a, b)| a - b).collect();
    let eps_norm = eps.iter().map(|v| v * v).sum::<f64>().sqrt();
    let w_norm = r_w_hat.iter().map(|v| v * v).sum::<f64>().sqrt();

    // Theorem 3 stationarity: for each i, −ŵ·x_i ∈ ∂ℓ*(−α̂_i).
    // For hinge: α ∈ (0,C) ⇒ ŵ·x_i = 1; α = 0 ⇒ ŵ·x_i ≥ 1; α = C ⇒ ≤ 1.
    let resid = |w: &[f64]| -> f64 {
        let mut worst = 0.0f64;
        for i in 0..train.n() {
            if train.x.row_nnz(i) == 0 {
                continue;
            }
            let m = train.x.row_dot_dense(i, w);
            let a = r_alpha[i];
            let v = if a <= 1e-12 {
                (1.0 - m).max(0.0) // need m ≥ 1
            } else if a >= c - 1e-12 {
                (m - 1.0).max(0.0) // need m ≤ 1
            } else {
                (m - 1.0).abs() // need m = 1
            };
            worst = worst.max(v);
        }
        worst
    };
    Ok(BackwardError {
        eps_norm,
        w_norm,
        perturbed_residual: resid(&r_w_hat),
        unperturbed_residual: resid(&wbar),
        lost_writes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_shape_holds() {
        let (_t, rows) = table1(0.02, 5).unwrap();
        assert_eq!(rows.len(), 9);
        // At 10 simulated cores: wild ≥ atomic > 1x; lock < 1x.
        let at = |th: usize, m: &str| {
            rows.iter()
                .find(|r| r.threads == th && r.mechanism == m)
                .unwrap()
                .sim_speedup
        };
        assert!(at(10, "wild") > 4.0);
        assert!(at(10, "atomic") > 3.0);
        assert!(at(10, "lock") < 1.0);
        assert!(at(4, "wild") > at(2, "wild"));
    }

    #[test]
    fn table3_lists_all_datasets() {
        let t = table3(0.02).unwrap();
        let s = t.render();
        for name in ["news20", "covtype", "rcv1", "webspam", "kddb"] {
            assert!(s.contains(name), "missing {name} in\n{s}");
        }
    }

    #[test]
    fn fig_convergence_produces_logs() {
        let logs = fig_convergence("rcv1", 0.02, 3, 2, false).unwrap();
        assert_eq!(logs.len(), 4);
        for log in &logs {
            assert_eq!(log.rows.len(), 3, "{}", log.label);
        }
    }

    #[test]
    fn backward_error_small_relative_eps() {
        let be = backward_error("rcv1", 0.02, 15, 4).unwrap();
        // ε is the accumulated lost-write mass; it must be small relative
        // to ‖ŵ‖ (the paper's "close-to-optimal" claim) and the perturbed
        // residual (with ŵ) must not exceed the unperturbed one (with w̄)
        // by a large factor.
        assert!(
            be.eps_norm < 0.2 * be.w_norm,
            "ε too large: {} vs ‖w‖ {}",
            be.eps_norm,
            be.w_norm
        );
        assert!(be.perturbed_residual.is_finite());
    }
}
