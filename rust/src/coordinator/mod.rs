//! Coordinator: configs, the experiment driver, metric logging, the
//! experiment registry (one entry per paper table/figure), and the CLI.

pub mod cli;
pub mod config;
pub mod driver;
pub mod experiments;
pub mod metrics;
pub mod model_io;
pub mod tuning;

pub use cli::Cli;
pub use config::{LossKind, RunConfig, SolverKind};
pub use driver::{run, RunOutput};
pub use metrics::{MetricRow, MetricsLog, TextTable};
pub use model_io::Model;
