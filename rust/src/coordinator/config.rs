//! Experiment configuration: JSON files + `--key value` CLI overrides.
//!
//! One [`RunConfig`] fully determines a training run (dataset analog,
//! solver, memory model, loss, thread count, epochs, seed, …) — every
//! metric row this repo produces is reproducible from its config dump.

use anyhow::{anyhow, bail, Result};

use crate::solver::{MemoryModel, Sampling};
use crate::util::Json;

// The kind enums live with the layers they key into: `SolverKind` is the
// solver registry's key type (one name table shared by the CLI, configs,
// and `solver::lookup`), `LossKind` the loss library's.  Re-exported here
// so config-level code keeps its historical import paths.
pub use crate::loss::LossKind;
pub use crate::solver::SolverKind;

/// Full run configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Registry dataset name (or a path to a LIBSVM file, see `data_path`).
    pub dataset: String,
    /// Optional explicit LIBSVM path overriding the registry.
    pub data_path: Option<String>,
    /// Scale factor in (0, 1] applied to the registry analog.
    pub scale: f64,
    pub solver: SolverKind,
    pub loss: LossKind,
    /// Penalty C; `None` = registry default for the dataset.
    pub c: Option<f64>,
    pub threads: usize,
    pub epochs: usize,
    pub eval_every: usize,
    pub seed: u64,
    pub shrinking: bool,
    pub sampling: Sampling,
    pub pin_threads: bool,
    /// Evaluate through the AOT/PJRT path as well (cross-check).
    pub aot_eval: bool,
    /// Reorder features by descending document frequency before training
    /// (cache-locality optimization for the shared `w`; the trained
    /// model is translated back to the original feature space at export).
    pub remap_features: bool,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            dataset: "rcv1".into(),
            data_path: None,
            scale: 1.0,
            solver: SolverKind::Passcode(MemoryModel::Wild),
            loss: LossKind::Hinge,
            c: None,
            threads: 4,
            epochs: 20,
            eval_every: 1,
            seed: 42,
            shrinking: false,
            sampling: Sampling::Permutation,
            pin_threads: false,
            aot_eval: false,
            remap_features: false,
        }
    }
}

impl RunConfig {
    /// Apply a single `key value` override (the CLI surface).  Keys may
    /// use `-` or `_` separators (JSON dumps use `_`).
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        let key = key.replace('_', "-");
        match key.as_str() {
            "dataset" => self.dataset = value.into(),
            "data-path" => self.data_path = Some(value.into()),
            "scale" => self.scale = value.parse()?,
            "solver" => self.solver = SolverKind::parse(value)?,
            "loss" => self.loss = LossKind::parse(value)?,
            "c" => self.c = Some(value.parse()?),
            "threads" => self.threads = value.parse()?,
            "epochs" => self.epochs = value.parse()?,
            "eval-every" => self.eval_every = value.parse()?,
            "seed" => self.seed = value.parse()?,
            "shrinking" => self.shrinking = value.parse()?,
            "sampling" => {
                self.sampling = match value {
                    "permutation" => Sampling::Permutation,
                    "replacement" => Sampling::WithReplacement,
                    other => bail!("unknown sampling {other:?}"),
                }
            }
            "pin-threads" => self.pin_threads = value.parse()?,
            "aot-eval" => self.aot_eval = value.parse()?,
            "remap-features" => self.remap_features = value.parse()?,
            other => bail!("unknown config key {other:?}"),
        }
        Ok(())
    }

    /// Load from a JSON object (string keys matching [`RunConfig::set`]).
    pub fn from_json(json: &Json) -> Result<RunConfig> {
        let mut cfg = RunConfig::default();
        for (k, v) in json.as_obj()? {
            if matches!(v, Json::Null) {
                continue; // null = keep default
            }
            let s = match v {
                Json::Str(s) => s.clone(),
                Json::Num(n) => {
                    if n.fract() == 0.0 {
                        format!("{}", *n as i64)
                    } else {
                        format!("{n}")
                    }
                }
                Json::Bool(b) => b.to_string(),
                other => bail!("config key {k}: unsupported value {other:?}"),
            };
            cfg.set(k, &s)?;
        }
        Ok(cfg)
    }

    /// Load a JSON config file.
    pub fn from_file(path: &str) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("read {path}: {e}"))?;
        Self::from_json(&Json::parse(&text)?)
    }

    /// Serialize for provenance logging.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dataset", Json::str(&self.dataset)),
            ("scale", Json::num(self.scale)),
            ("solver", Json::str(self.solver.name())),
            ("loss", Json::str(self.loss.name())),
            (
                "c",
                self.c.map(Json::num).unwrap_or(Json::Null),
            ),
            ("threads", Json::num(self.threads as f64)),
            ("epochs", Json::num(self.epochs as f64)),
            ("eval_every", Json::num(self.eval_every as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("shrinking", Json::Bool(self.shrinking)),
            (
                "sampling",
                Json::str(match self.sampling {
                    Sampling::Permutation => "permutation",
                    Sampling::WithReplacement => "replacement",
                }),
            ),
            ("pin_threads", Json::Bool(self.pin_threads)),
            ("aot_eval", Json::Bool(self.aot_eval)),
            ("remap_features", Json::Bool(self.remap_features)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solver_kinds_roundtrip() {
        for s in [
            "dcd", "liblinear", "passcode-lock", "passcode-atomic",
            "passcode-wild", "cocoa", "asyscd", "pegasos",
        ] {
            assert_eq!(SolverKind::parse(s).unwrap().name(), s);
        }
        assert!(SolverKind::parse("sgd").is_err());
    }

    #[test]
    fn set_overrides() {
        let mut c = RunConfig::default();
        c.set("dataset", "webspam").unwrap();
        c.set("threads", "10").unwrap();
        c.set("solver", "cocoa").unwrap();
        c.set("c", "0.5").unwrap();
        c.set("sampling", "replacement").unwrap();
        c.set("remap-features", "true").unwrap();
        assert_eq!(c.dataset, "webspam");
        assert_eq!(c.threads, 10);
        assert_eq!(c.solver, SolverKind::Cocoa);
        assert_eq!(c.c, Some(0.5));
        assert_eq!(c.sampling, Sampling::WithReplacement);
        assert!(c.remap_features);
        assert!(c.set("bogus", "1").is_err());
    }

    #[test]
    fn json_roundtrip() {
        let mut c = RunConfig::default();
        c.set("solver", "passcode-atomic").unwrap();
        c.set("epochs", "7").unwrap();
        c.set("remap-features", "true").unwrap();
        let j = c.to_json();
        let c2 = RunConfig::from_json(&j).unwrap();
        assert_eq!(c2.solver.name(), "passcode-atomic");
        assert_eq!(c2.epochs, 7);
        assert_eq!(c2.dataset, c.dataset);
        assert!(c2.remap_features);
    }

    #[test]
    fn from_json_rejects_bad_keys() {
        let j = Json::parse(r#"{"nope": 1}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
    }
}
