//! Hyper-parameter tuning: k-fold cross-validated grid search over the
//! penalty `C` — how the paper's Table-3 `C` values would be picked in
//! practice (LIBLINEAR ships the same facility as `-C`).
//!
//! The trainer is any [`Solver`] registry entry — fold models are fit
//! through `TrainSession`s, so every algorithm in the family can back
//! the grid search.

use anyhow::Result;

use crate::data::Dataset;
use crate::eval;
use crate::loss::LossKind;
use crate::solver::{Solver, SolveOptions};
use crate::util::Pcg32;

/// Result of one grid point.
#[derive(Debug, Clone)]
pub struct GridPoint {
    pub c: f64,
    /// Mean validation accuracy across folds.
    pub mean_acc: f64,
    /// Per-fold accuracies.
    pub fold_accs: Vec<f64>,
}

/// k-fold CV over a C grid with `solver` (any registry entry) as the
/// trainer, optimizing the hinge loss.
///
/// Returns all grid points (sorted by C) and the argmax.
pub fn grid_search_c(
    ds: &Dataset,
    grid: &[f64],
    folds: usize,
    opts: &SolveOptions,
    solver: &dyn Solver,
) -> Result<(Vec<GridPoint>, f64)> {
    anyhow::ensure!(folds >= 2, "need at least 2 folds");
    anyhow::ensure!(!grid.is_empty(), "empty C grid");
    let n = ds.n();
    let mut rng = Pcg32::new(opts.seed, 0xCF01D);
    let perm = rng.permutation(n);

    // Fold row-index sets.
    let fold_rows: Vec<Vec<usize>> = (0..folds)
        .map(|f| {
            perm.iter()
                .enumerate()
                .filter(|(pos, _)| pos % folds == f)
                .map(|(_, &i)| i)
                .collect()
        })
        .collect();

    let mut points = Vec::with_capacity(grid.len());
    for &c in grid {
        let mut fold_accs = Vec::with_capacity(folds);
        for f in 0..folds {
            let val_rows = &fold_rows[f];
            let train_rows: Vec<usize> = (0..folds)
                .filter(|&g| g != f)
                .flat_map(|g| fold_rows[g].iter().copied())
                .collect();
            let train = Dataset::new(
                ds.x.select_rows(&train_rows),
                train_rows.iter().map(|&i| ds.y[i]).collect(),
                format!("{}-cv{f}", ds.name),
            );
            let val = Dataset::new(
                ds.x.select_rows(val_rows),
                val_rows.iter().map(|&i| ds.y[i]).collect(),
                format!("{}-val{f}", ds.name),
            );
            let mut session =
                solver.session(&train, LossKind::Hinge, c, opts.clone())?;
            session.run_epochs(opts.epochs)?;
            fold_accs.push(eval::accuracy(&val, session.w_hat()));
        }
        let mean_acc = fold_accs.iter().sum::<f64>() / folds as f64;
        points.push(GridPoint { c, mean_acc, fold_accs });
    }
    let best = points
        .iter()
        .max_by(|a, b| a.mean_acc.total_cmp(&b.mean_acc))
        .unwrap()
        .c;
    Ok((points, best))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::registry;
    use crate::solver::{lookup, MemoryModel, PasscodeSolver};

    #[test]
    fn grid_search_runs_and_orders_sanely() {
        let (tr, _, _) = registry::load("rcv1", 0.02).unwrap();
        let opts = SolveOptions {
            threads: 2,
            epochs: 8,
            eval_every: 1,
            ..Default::default()
        };
        let grid = [0.01, 1.0, 100.0];
        let solver = PasscodeSolver(MemoryModel::Wild);
        let (points, best) =
            grid_search_c(&tr, &grid, 3, &opts, &solver).unwrap();
        assert_eq!(points.len(), 3);
        assert!(grid.contains(&best));
        for p in &points {
            assert_eq!(p.fold_accs.len(), 3);
            assert!(p.mean_acc > 0.4, "C={} acc {}", p.c, p.mean_acc);
        }
    }

    #[test]
    fn rejects_degenerate_inputs() {
        let (tr, _, _) = registry::load("rcv1", 0.01).unwrap();
        let opts = SolveOptions::default();
        let solver = PasscodeSolver(MemoryModel::Wild);
        assert!(grid_search_c(&tr, &[], 3, &opts, &solver).is_err());
        assert!(grid_search_c(&tr, &[1.0], 1, &opts, &solver).is_err());
    }

    #[test]
    fn folds_partition_the_data() {
        // indirectly: every row appears in exactly one validation fold —
        // verified by fold sizes summing to n.
        let (tr, _, _) = registry::load("rcv1", 0.02).unwrap();
        let opts = SolveOptions {
            threads: 1,
            epochs: 2,
            ..Default::default()
        };
        let solver = lookup("passcode-wild").unwrap();
        let (points, _) =
            grid_search_c(&tr, &[1.0], 4, &opts, solver.as_ref()).unwrap();
        assert_eq!(points[0].fold_accs.len(), 4);
    }

    #[test]
    fn any_registry_solver_can_back_the_grid() {
        let (tr, _, _) = registry::load("rcv1", 0.02).unwrap();
        let opts =
            SolveOptions { threads: 1, epochs: 3, ..Default::default() };
        let solver = lookup("dcd").unwrap();
        let (points, best) =
            grid_search_c(&tr, &[0.5, 2.0], 2, &opts, solver.as_ref())
                .unwrap();
        assert_eq!(points.len(), 2);
        assert!([0.5, 2.0].contains(&best));
    }
}
